//! Parallelism advisor — the paper's Future Work §VII made executable:
//! "automated parallelism selection tools that dynamically choose optimal
//! configurations based on infrastructure characteristics and workload
//! requirements".
//!
//! Built entirely on the library facade: `DeploymentPlan::sweep` yields
//! every feasible (TP, PP) plan of a model on a GPU budget, and each plan
//! is analyzed (`analyze()`) and simulated (`simulate()`) for the
//! workload, then recommended per objective (interactive latency /
//! long-form generation / bandwidth-constrained).
//!
//! Run: `cargo run --release --example parallelism_advisor [model] [gpus] [sp] [sd]`

use commsim::analysis::ParallelLayout;
use commsim::model::ModelArch;
use commsim::plan::{DeploymentPlan, SloResult};
use commsim::report::{fmt_bytes, render_table};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let arch = ModelArch::by_name(args.first().map(|s| s.as_str()).unwrap_or("13b"))
        .ok_or_else(|| anyhow::anyhow!("unknown model"))?;
    let gpus: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(8);
    let sp: usize = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(128);
    let sd: usize = args.get(3).map(|s| s.parse()).transpose()?.unwrap_or(128);

    println!(
        "advisor: {} on {} GPUs ({} nodes x 4), Sp={sp} Sd={sd}\n",
        arch.name,
        gpus,
        gpus.div_ceil(4).max(1)
    );

    let plans: Vec<DeploymentPlan> = DeploymentPlan::sweep(&arch, gpus)
        .map(|p| p.with_workload(sp, sd))
        .collect::<Result<_, _>>()?;
    if plans.is_empty() {
        anyhow::bail!("no feasible (TP, PP) layout for {} on {gpus} GPUs", arch.name);
    }

    let mut rows = Vec::new();
    let mut results = Vec::new();
    for plan in &plans {
        let r = plan.simulate();
        let vol = plan.analyze().total_bytes();
        let shape = plan.shape();
        results.push((plan.layout(), r, vol));
        rows.push(vec![
            plan.layout().label(),
            format!("{:.1}", r.ttft_s * 1e3),
            format!("{:.2}", r.tpot_s * 1e3),
            format!("{:.2}", r.e2e_s),
            fmt_bytes(vol),
            format!("{:.0}%", r.comm_fraction(shape) * 100.0),
        ]);
    }
    print!(
        "{}",
        render_table(
            "Feasible layouts",
            &["Layout", "TTFT (ms)", "TPOT (ms)", "E2E (s)", "Comm volume", "Comm share"],
            &rows,
        )
    );

    let best_by = |f: &dyn Fn(&(ParallelLayout, SloResult, f64)) -> f64| {
        results
            .iter()
            .min_by(|a, b| f(a).partial_cmp(&f(b)).unwrap())
            .unwrap()
    };
    let ttft = best_by(&|x| x.1.ttft_s);
    let tpot = best_by(&|x| x.1.tpot_s);
    let e2e = best_by(&|x| x.1.e2e_s);
    let vol = best_by(&|x| x.2);
    println!("\nrecommendations (paper §V.C key takeaways):");
    println!("  interactive / TTFT-critical : {}", ttft.0.label());
    println!("  sustained decode (TPOT)     : {}", tpot.0.label());
    println!("  overall latency (E2E)       : {}", e2e.0.label());
    println!("  bandwidth-constrained fabric: {} ({} total)", vol.0.label(), fmt_bytes(vol.2));
    Ok(())
}
