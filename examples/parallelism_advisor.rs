//! Parallelism advisor — the paper's Future Work §VII made executable:
//! "automated parallelism selection tools that dynamically choose optimal
//! configurations based on infrastructure characteristics and workload
//! requirements".
//!
//! Enumerates every feasible (TP, PP) layout of a model on a given cluster,
//! simulates TTFT/TPOT/E2E + communication volume for the workload, and
//! recommends per objective (interactive latency / long-form generation /
//! bandwidth-constrained).
//!
//! Run: `cargo run --release --example parallelism_advisor [model] [gpus] [sp] [sd]`

use commsim::analysis::{InferenceShape, ParallelLayout, VolumeModel};
use commsim::cluster::{Placement, Topology};
use commsim::model::ModelArch;
use commsim::perfmodel::SloSimulator;
use commsim::report::{fmt_bytes, render_table};

fn feasible_layouts(arch: &ModelArch, gpus: usize) -> Vec<ParallelLayout> {
    let mut out = Vec::new();
    for tp in [1usize, 2, 4, 8, 16] {
        if tp > gpus || !arch.supports_tp(tp) {
            continue;
        }
        for pp in [1usize, 2, 4, 8] {
            if tp * pp == gpus && arch.supports_pp(pp) {
                out.push(ParallelLayout::new(tp, pp));
            }
        }
    }
    out
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let arch = ModelArch::by_name(args.first().map(|s| s.as_str()).unwrap_or("13b"))
        .ok_or_else(|| anyhow::anyhow!("unknown model"))?;
    let gpus: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(8);
    let sp: usize = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(128);
    let sd: usize = args.get(3).map(|s| s.parse()).transpose()?.unwrap_or(128);
    let shape = InferenceShape::new(sp, sd, 2);
    let topology = Topology::cardinal(gpus.div_ceil(4).max(1));

    println!(
        "advisor: {} on {} GPUs ({} nodes x 4), Sp={sp} Sd={sd}\n",
        arch.name, gpus, topology.nodes
    );

    let mut rows = Vec::new();
    let mut results = Vec::new();
    for layout in feasible_layouts(&arch, gpus) {
        let placement = Placement::new(topology, layout)?;
        let sim = SloSimulator::new(arch.clone(), placement);
        let r = sim.simulate(shape);
        let vol = VolumeModel::new(arch.clone()).volume(layout, shape).total();
        results.push((layout, r, vol));
        rows.push(vec![
            layout.label(),
            format!("{:.1}", r.ttft_s * 1e3),
            format!("{:.2}", r.tpot_s * 1e3),
            format!("{:.2}", r.e2e_s),
            fmt_bytes(vol),
            format!("{:.0}%", r.comm_fraction(shape) * 100.0),
        ]);
    }
    print!(
        "{}",
        render_table(
            "Feasible layouts",
            &["Layout", "TTFT (ms)", "TPOT (ms)", "E2E (s)", "Comm volume", "Comm share"],
            &rows,
        )
    );

    let best_by = |f: &dyn Fn(&(ParallelLayout, commsim::perfmodel::SloReport, f64)) -> f64| {
        results
            .iter()
            .min_by(|a, b| f(a).partial_cmp(&f(b)).unwrap())
            .unwrap()
    };
    let ttft = best_by(&|x| x.1.ttft_s);
    let tpot = best_by(&|x| x.1.tpot_s);
    let e2e = best_by(&|x| x.1.e2e_s);
    let vol = best_by(&|x| x.2);
    println!("\nrecommendations (paper §V.C key takeaways):");
    println!("  interactive / TTFT-critical : {}", ttft.0.label());
    println!("  sustained decode (TPOT)     : {}", tpot.0.label());
    println!("  overall latency (E2E)       : {}", e2e.0.label());
    println!("  bandwidth-constrained fabric: {} ({} total)", vol.0.label(), fmt_bytes(vol.2));
    Ok(())
}
