//! End-to-end prefix-aware routing driver (the prefix analogue of
//! `fleet_e2e`, and the CI prefix-routing smoke test).
//!
//! Four checks on the model clock, all structural (no artifacts):
//!
//! 1. **Affinity win** — on shared-prefix traffic (multi-turn
//!    conversations) with per-replica prefix caches sized below the full
//!    conversation working set, the cache-affinity router strictly
//!    reduces modeled p95 TTFT vs round-robin: affinity pins each
//!    conversation to a warm replica, round-robin spreads every
//!    conversation across all replicas and thrashes their LRU caches.
//!    (Replicas run `max_batch = 1`, so a request's model TTFT is
//!    exactly its suffix's prefill price — the comparison isolates
//!    routing × caching, with no batching noise.)
//! 2. **Determinism** — re-running the affinity fleet with the same
//!    spec, workload, and seed reproduces the model summary and every
//!    per-request record bitwise.
//! 3. **Saved-prefill accounting** — the fleet's total saved prefill
//!    seconds equals the sum of per-request cached-token prefill prices,
//!    recomputed independently from `CostModel::prefill_price` (and the
//!    completion-order fold of the per-request records, bitwise).
//! 4. **Prefix-free equivalence** — on a prefix-free workload the
//!    affinity router produces the same assignment sequence (and the
//!    bitwise-identical summary) as least-outstanding-tokens, and the
//!    same TTFT percentiles as round-robin: the policy costs nothing
//!    when there is nothing to share.

use commsim::fleet::{FleetSpec, FleetSummary, RouterPolicy};
use commsim::plan::Deployment;
use commsim::report::fmt_bytes;
use commsim::server::{PrefixCacheConfig, SchedulerConfig};
use commsim::workload::{ArrivalProcess, LengthDist, PrefixProfile, WorkloadSpec};

fn print_summary(label: &str, s: &FleetSummary) {
    println!(
        "[{label}] {} requests ({} ok, {} failed) — TTFT p50/p95 {:.2}/{:.2} ms, \
         E2E p95 {:.3} s",
        s.requests,
        s.completed,
        s.failed,
        s.model.ttft.p50_s * 1e3,
        s.model.ttft.p95_s * 1e3,
        s.model.e2e.p95_s
    );
    println!(
        "  prefix hits: {} cached tokens, saved {:.1} ms prefill / {} comm",
        s.cached_prompt_tokens,
        s.saved_prefill_s * 1e3,
        fmt_bytes(s.saved_prefill_bytes)
    );
    for r in &s.replicas {
        println!(
            "  {:<24} assigned={:<3} tokens={:<5} cached={}",
            r.label, r.assigned, r.tokens, r.cached_tokens
        );
    }
}

fn main() -> anyhow::Result<()> {
    // 6 long-lived conversations sharing 112-token histories; prompts are
    // 127 tokens (7 full 16-token cache blocks — all shared — plus a
    // 15-token unique turn). Bursty arrivals keep both replicas busy
    // inside a burst, so cold conversations spread deterministically.
    let (sp, sd, requests) = (127usize, 4usize, 240usize);
    let (conversations, shared) = (6usize, 112usize);
    let seed = 0xF1EE7u64;
    let plan = Deployment::builder().model("3b").tp(2).workload(sp, sd).build()?;

    // Per-replica cache: 16-token blocks, budgeted at exactly 28 blocks
    // = 4 conversation prefixes (7 blocks each). Each replica can stay
    // warm for its share of the 6 conversations, but not for all of
    // them — round-robin's interleaved stream must thrash its LRU.
    let block_tokens = 16usize;
    let capacity_bytes = 28 * block_tokens * plan.arch().kv_bytes_per_token(2);
    let cache = PrefixCacheConfig { block_tokens, capacity_bytes };
    let scheduler = SchedulerConfig { max_batch: 1, ..SchedulerConfig::default() };
    let fleet = |router: RouterPolicy| -> anyhow::Result<FleetSpec> {
        Ok(plan
            .fleet(2)?
            .with_router(router)
            .with_scheduler(scheduler)
            .with_prefix_cache(cache)?)
    };

    let shared_wl = WorkloadSpec {
        arrivals: ArrivalProcess::bursty(1.0, 4),
        prompt: LengthDist::Fixed(sp),
        decode: LengthDist::Fixed(sd),
        prefix: Some(PrefixProfile::MultiTurn { conversations, shared }),
        requests,
    };
    println!(
        "prefix routing e2e: {} — {requests} requests, {conversations} conversations \
         sharing {shared}/{sp} tokens, seed {seed:#x}\n",
        plan.label()
    );

    // --- 1. affinity beats round-robin on shared-prefix traffic --------
    let rr = fleet(RouterPolicy::RoundRobin)?.simulate(&shared_wl, seed)?;
    let affinity = fleet(RouterPolicy::CacheAffinity)?.simulate(&shared_wl, seed)?;
    print_summary("round-robin", &rr);
    print_summary("affinity   ", &affinity);
    anyhow::ensure!(
        rr.completed == requests && affinity.completed == requests,
        "all requests must complete"
    );
    anyhow::ensure!(
        affinity.model.ttft.p95_s < rr.model.ttft.p95_s,
        "cache affinity must strictly reduce modeled p95 TTFT on shared-prefix \
         traffic ({:.3} vs {:.3} ms)",
        affinity.model.ttft.p95_s * 1e3,
        rr.model.ttft.p95_s * 1e3
    );
    anyhow::ensure!(
        affinity.cached_prompt_tokens > rr.cached_prompt_tokens,
        "affinity must hit more cached tokens than round-robin"
    );
    println!(
        "\naffinity win OK: p95 TTFT {:.2} ms -> {:.2} ms ({:.2}x)",
        rr.model.ttft.p95_s * 1e3,
        affinity.model.ttft.p95_s * 1e3,
        rr.model.ttft.p95_s / affinity.model.ttft.p95_s
    );

    // --- 2. bitwise determinism per seed -------------------------------
    let again = fleet(RouterPolicy::CacheAffinity)?.simulate(&shared_wl, seed)?;
    anyhow::ensure!(
        again.model == affinity.model,
        "same spec + workload + seed must reproduce the model summary bitwise"
    );
    anyhow::ensure!(again.per_request.len() == affinity.per_request.len());
    for (a, b) in affinity.per_request.iter().zip(again.per_request.iter()) {
        anyhow::ensure!(
            a.request_id == b.request_id
                && a.replica == b.replica
                && a.cached_prompt_tokens == b.cached_prompt_tokens
                && a.saved_prefill_s == b.saved_prefill_s
                && a.model == b.model,
            "per-request records must reproduce bitwise (request {})",
            a.request_id
        );
    }
    println!("determinism OK: identical summary and per-request records on re-run");

    // --- 3. saved prefill = sum of cached-token prefill prices ---------
    let cm = plan.cost_model();
    let mut recomputed = 0.0f64;
    let mut folded = 0.0f64;
    for m in &affinity.per_request {
        if m.cached_prompt_tokens > 0 {
            recomputed += cm.prefill_price(m.prompt_tokens)
                - cm.prefill_price(m.prompt_tokens - m.cached_prompt_tokens);
        }
        folded += m.saved_prefill_s;
    }
    anyhow::ensure!(
        affinity.saved_prefill_s == folded,
        "summary total must be the completion-order fold of per-request savings"
    );
    anyhow::ensure!(
        (affinity.saved_prefill_s - recomputed).abs()
            <= 1e-9 * recomputed.abs().max(f64::MIN_POSITIVE),
        "total saved prefill seconds {} must equal the sum of per-request \
         cached-token prefill prices {}",
        affinity.saved_prefill_s,
        recomputed
    );
    anyhow::ensure!(affinity.saved_prefill_s > 0.0 && affinity.saved_prefill_bytes > 0.0);
    println!(
        "saved-prefill accounting OK: {:.1} ms total = sum of per-request \
         cached-token prefill prices ({} saved comm)",
        affinity.saved_prefill_s * 1e3,
        fmt_bytes(affinity.saved_prefill_bytes)
    );

    // --- 4. prefix-free traffic: affinity costs nothing ----------------
    // (The equivalences are structural, so a shorter run suffices.)
    let free_wl = WorkloadSpec { prefix: None, requests: 60, ..shared_wl };
    let free_affinity = fleet(RouterPolicy::CacheAffinity)?.simulate(&free_wl, seed)?;
    let free_lot = fleet(RouterPolicy::LeastOutstandingTokens)?.simulate(&free_wl, seed)?;
    let free_rr = fleet(RouterPolicy::RoundRobin)?.simulate(&free_wl, seed)?;
    anyhow::ensure!(
        free_affinity.cached_prompt_tokens == 0 && free_lot.cached_prompt_tokens == 0,
        "unique-tokened prompts must never hit a prefix cache"
    );
    anyhow::ensure!(
        free_affinity.model == free_lot.model,
        "with zero hits, affinity must reproduce least-outstanding-tokens bitwise"
    );
    for (a, l) in free_affinity.per_request.iter().zip(free_lot.per_request.iter()) {
        anyhow::ensure!(
            a.request_id == l.request_id && a.replica == l.replica,
            "assignment sequences must match (request {})",
            a.request_id
        );
    }
    // With max_batch = 1, a request's model TTFT is its own prefill
    // price, so every policy reports the same TTFT percentiles on
    // prefix-free fixed-length traffic — up to last-ulp drift from each
    // replica's timeline accumulation (`(T + d) - T`), hence the 1e-9
    // band rather than bitwise equality across *different* schedules.
    let close = |a: f64, b: f64| (a - b).abs() <= 1e-9 * b.abs().max(f64::MIN_POSITIVE);
    anyhow::ensure!(
        close(free_affinity.model.ttft.p50_s, free_rr.model.ttft.p50_s)
            && close(free_affinity.model.ttft.p95_s, free_rr.model.ttft.p95_s),
        "prefix-free TTFT percentiles must match round-robin's ({:?} vs {:?})",
        free_affinity.model.ttft,
        free_rr.model.ttft
    );
    println!(
        "prefix-free equivalence OK: affinity == least-tokens bitwise, TTFT \
         percentiles match round-robin"
    );

    println!("\nprefix_routing_e2e OK");
    Ok(())
}
