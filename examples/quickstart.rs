//! Quickstart: the unified deployment-plan API in ~50 lines.
//!
//! One validated plan drives all three public surfaces:
//! 1. `analyze()`  — predict communication analytically (Eq. 1–7).
//! 2. `trace()`    — measure it by running the structural engine (no
//!    artifacts needed) and validate the trace against the prediction.
//! 3. `simulate()` — the SLO impact of a layout choice on the paper's
//!    testbed.
//!
//! Run: `cargo run --release --example quickstart`

use commsim::comm::{CollectiveKind, Stage};
use commsim::plan::Deployment;
use commsim::report::fmt_bytes;

fn main() -> anyhow::Result<()> {
    // One entry point: model x layout x workload, validated up front.
    let plan = Deployment::builder()
        .model("8b") // Llama-3.1-8B
        .tp(2)
        .workload(128, 128) // Sp = Sd = 128, BF16
        .build()?;

    // --- 1. analytical prediction -------------------------------------
    let vr = plan.analyze();
    println!(
        "[predict] {} under {}: {} total communication",
        plan.arch().name,
        plan.layout().label(),
        fmt_bytes(vr.total_bytes())
    );
    let decode_allreduce = vr.decode_ops.count(CollectiveKind::AllReduce);
    println!(
        "[predict] decode stage: {} AllReduce + {} Gather calls",
        decode_allreduce,
        vr.decode_ops.count(CollectiveKind::Gather),
    );

    // --- 2. measure by running the engine -----------------------------
    let summary = plan.trace()?;
    let measured = summary.paper_view(CollectiveKind::AllReduce, Stage::Decode);
    println!(
        "[measure] engine traced {} decode AllReduces (prediction: {})",
        measured.count, decode_allreduce,
    );
    assert_eq!(measured.count, decode_allreduce);

    // --- 3. simulate the SLO impact ------------------------------------
    for (tp, pp) in [(2usize, 1usize), (1, 2)] {
        let plan = Deployment::builder().model("8b").tp(tp).pp(pp).workload(128, 128).build()?;
        let r = plan.simulate();
        println!(
            "[simulate] {:<8} TTFT {:>7.1} ms   TPOT {:>6.2} ms   E2E {:>6.3} s",
            plan.layout().label(),
            r.ttft_s * 1e3,
            r.tpot_s * 1e3,
            r.e2e_s
        );
    }
    println!("quickstart OK");
    Ok(())
}
