//! Quickstart: the three public surfaces in ~60 lines.
//!
//! 1. Predict communication analytically (Eq. 1–7).
//! 2. Measure it by running the engine (structural mode — no artifacts
//!    needed) and validating the trace against the prediction.
//! 3. Simulate the SLO impact of a layout choice on the paper's testbed.
//!
//! Run: `cargo run --release --example quickstart`

use commsim::analysis::{InferenceShape, OpCountModel, ParallelLayout, VolumeModel};
use commsim::comm::{CollectiveKind, Stage};
use commsim::engine::{Engine, EngineConfig};
use commsim::model::ModelArch;
use commsim::perfmodel::SloSimulator;
use commsim::report::fmt_bytes;

fn main() -> anyhow::Result<()> {
    let arch = ModelArch::llama31_8b();
    let layout = ParallelLayout::new(2, 1); // TP=2
    let shape = InferenceShape::new(128, 128, 2); // Sp=Sd=128, BF16

    // --- 1. analytical prediction -------------------------------------
    let volume = VolumeModel::new(arch.clone()).volume(layout, shape);
    println!(
        "[predict] {} under {}: {} total communication",
        arch.name,
        layout.label(),
        fmt_bytes(volume.total())
    );
    let ops = OpCountModel::new(arch.clone(), layout, shape);
    let decode = ops.predict_paper_view(Stage::Decode);
    println!(
        "[predict] decode stage: {} AllReduce + {} Gather calls",
        decode.count(CollectiveKind::AllReduce),
        decode.count(CollectiveKind::Gather),
    );

    // --- 2. measure by running the engine -----------------------------
    let mut engine = Engine::new(EngineConfig::structural(arch.clone(), layout))?;
    engine.generate(&vec![0i32; 128], 128)?;
    let summary = engine.trace().summary();
    let measured = summary.paper_view(CollectiveKind::AllReduce, Stage::Decode);
    println!(
        "[measure] engine traced {} decode AllReduces (prediction: {})",
        measured.count,
        decode.count(CollectiveKind::AllReduce),
    );
    assert_eq!(measured.count, decode.count(CollectiveKind::AllReduce));

    // --- 3. simulate the SLO impact ------------------------------------
    for l in [ParallelLayout::new(2, 1), ParallelLayout::new(1, 2)] {
        let sim = SloSimulator::on_cardinal(arch.clone(), l)?;
        let r = sim.simulate(shape);
        println!(
            "[simulate] {:<8} TTFT {:>7.1} ms   TPOT {:>6.2} ms   E2E {:>6.3} s",
            l.label(),
            r.ttft_s * 1e3,
            r.tpot_s * 1e3,
            r.e2e_s
        );
    }
    println!("quickstart OK");
    Ok(())
}
