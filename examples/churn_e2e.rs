//! End-to-end serving-under-failure driver (the fault-injection
//! analogue of `fleet_e2e`, and the CI churn smoke test).
//!
//! Four checks on the model clock, all structural (no artifacts):
//!
//! 1. **Zero-fault identity** — attaching `FaultSpec::none()` reproduces
//!    the healthy fleet bitwise: same model summary, same per-request
//!    records, zero retries, zero wasted prefill. Fault injection costs
//!    nothing when it injects nothing.
//! 2. **Goodput under churn** — against an SLO the healthy fleet meets
//!    on every request, a mid-run blackout (both replicas down, scripted
//!    [`Outage`]s) strictly cuts goodput: stranded requests carry the
//!    downtime in their E2E.
//! 3. **Determinism** — re-running the faulty spec with the same seed
//!    reproduces the model summary and per-request retries bitwise.
//! 4. **Policy reordering** — there exists a seed (found by a small
//!    grid search and asserted) where the best router policy *under
//!    churn* differs from the best policy on the healthy fleet: failures
//!    change which router you should deploy, which is the point of
//!    modeling them.

use commsim::faults::FaultSpec;
use commsim::fleet::{FleetSpec, FleetSummary, RouterPolicy, SloTarget};
use commsim::plan::Deployment;
use commsim::workload::{ArrivalProcess, LengthDist, PrefixProfile, WorkloadSpec};

const POLICIES: [RouterPolicy; 4] = [
    RouterPolicy::RoundRobin,
    RouterPolicy::LeastOutstandingTokens,
    RouterPolicy::ShortestQueue,
    RouterPolicy::CacheAffinity,
];

/// Worst per-request model-time E2E of a run (the tightest SLO the run
/// meets on every request).
fn worst_e2e(s: &FleetSummary) -> f64 {
    s.per_request
        .iter()
        .filter_map(|m| m.model.as_ref().map(|t| t.e2e_s))
        .fold(0.0f64, f64::max)
}

/// Mid-decode instant of the run's last-finishing request: strictly
/// after its first token, with decode steps still to run — a blackout
/// here is guaranteed to kill it in flight.
fn mid_decode_of_last(s: &FleetSummary) -> f64 {
    let last = s
        .per_request
        .iter()
        .filter_map(|m| m.model.as_ref())
        .max_by(|a, b| a.finished_at_s.total_cmp(&b.finished_at_s))
        .expect("at least one priced request");
    let arrival = last.finished_at_s - last.e2e_s;
    let first_token = arrival + last.queue_s + last.ttft_s;
    0.5 * (first_token + last.finished_at_s)
}

/// Index of the best policy: highest goodput, ties to lower p99 E2E,
/// then to the earlier policy.
fn best(runs: &[(f64, f64)]) -> usize {
    let mut best = 0;
    for (i, &(gp, p99)) in runs.iter().enumerate().skip(1) {
        let (bgp, bp99) = runs[best];
        if gp > bgp || (gp == bgp && p99 < bp99) {
            best = i;
        }
    }
    best
}

fn main() -> anyhow::Result<()> {
    let (sp, sd) = (32usize, 16usize);
    let requests = 24usize;
    let seed = 0xF1EE7u64;
    let plan = Deployment::builder().model("8b").tp(2).workload(sp, sd).build()?;
    let workload = WorkloadSpec {
        arrivals: ArrivalProcess::poisson(150.0),
        prompt: LengthDist::Fixed(sp),
        decode: LengthDist::Fixed(sd),
        prefix: None,
        requests,
    };
    let fleet = || -> anyhow::Result<FleetSpec> {
        Ok(plan.fleet(2)?.with_router(RouterPolicy::LeastOutstandingTokens))
    };
    println!("churn e2e: {} x2 — {requests} requests, seed {seed:#x}\n", plan.label());

    // --- 1. zero-fault identity ----------------------------------------
    let healthy = fleet()?.simulate(&workload, seed)?;
    let nofault = fleet()?.with_faults(FaultSpec::none())?.simulate(&workload, seed)?;
    anyhow::ensure!(
        nofault.model == healthy.model,
        "FaultSpec::none() must reproduce the healthy model summary bitwise"
    );
    anyhow::ensure!(nofault.retries == 0 && nofault.wasted_prefill_s == 0.0);
    anyhow::ensure!(nofault.comm_bytes == healthy.comm_bytes);
    anyhow::ensure!(nofault.per_request.len() == healthy.per_request.len());
    for (a, b) in nofault.per_request.iter().zip(healthy.per_request.iter()) {
        anyhow::ensure!(
            a.request_id == b.request_id
                && a.replica == b.replica
                && a.model == b.model
                && a.retries == 0,
            "per-request records must match the healthy run"
        );
    }
    println!("zero-fault OK: FaultSpec::none() is the healthy fleet, bitwise");

    // --- 2. goodput strictly drops under churn -------------------------
    // SLO the healthy fleet meets on every request, by construction.
    let slo = SloTarget { e2e_p95_s: Some(worst_e2e(&healthy)), ..Default::default() };
    anyhow::ensure!(healthy.goodput(&slo) == 1.0, "healthy fleet meets its own worst E2E");
    // Blackout: both replicas down mid-run, for two healthy makespans.
    let t_fail = mid_decode_of_last(&healthy);
    let down_s = 2.0 * healthy.model.makespan_s;
    let blackout = FaultSpec::none()
        .with_outage(0, t_fail, down_s)
        .with_outage(1, t_fail, down_s);
    let churned = fleet()?.with_faults(blackout.clone())?.simulate(&workload, seed)?;
    anyhow::ensure!(churned.completed == requests, "the fleet recovers and serves everything");
    anyhow::ensure!(churned.retries > 0, "the blackout must kill in-flight requests");
    anyhow::ensure!(churned.wasted_prefill_s >= 0.0);
    let (gh, gc) = (healthy.goodput(&slo), churned.goodput(&slo));
    anyhow::ensure!(
        gc < gh,
        "goodput under churn must be strictly below healthy ({gc} vs {gh})"
    );
    println!(
        "goodput OK: blackout at {:.4}s for {:.4}s -> goodput {:.3} (healthy {:.3}), \
         {} retries, {:.4}s prefill wasted",
        t_fail, down_s, gc, gh, churned.retries, churned.wasted_prefill_s
    );

    // --- 3. faulty runs are bitwise-deterministic ----------------------
    let again = fleet()?.with_faults(blackout)?.simulate(&workload, seed)?;
    anyhow::ensure!(
        again.model == churned.model && again.retries == churned.retries,
        "same faults + seed must reproduce the run bitwise"
    );
    for (a, b) in again.per_request.iter().zip(churned.per_request.iter()) {
        anyhow::ensure!(a.model == b.model && a.retries == b.retries && a.replica == b.replica);
    }
    println!("determinism OK: identical faulty run on re-seed");

    // --- 4. churn reorders the router-policy ranking -------------------
    // A shared-prefix, mixed-length workload over 3 replicas separates
    // the policies; an outage then knocks one replica (and its cache
    // warmth) out mid-run. Search a small seed x outage grid for a case
    // where the churn-best policy differs from the healthy-best one.
    let tiny = Deployment::builder().model("tiny").tp(2).workload(48, 12).build()?;
    let wl = WorkloadSpec {
        arrivals: ArrivalProcess::poisson(600.0),
        prompt: LengthDist::Uniform { lo: 32, hi: 48 },
        decode: LengthDist::Uniform { lo: 4, hi: 12 },
        prefix: Some(PrefixProfile::MultiTurn { conversations: 6, shared: 24 }),
        requests: 32,
    };
    let mut reorder = None;
    'grid: for s in 0..16u64 {
        let seed = 0x5EED0 + s;
        // Healthy ranking, against the tightest healthy p95 across
        // policies (so the ranking has room to move).
        let mut runs = Vec::new();
        for p in POLICIES {
            runs.push(tiny.fleet(3)?.with_router(p).simulate(&wl, seed)?);
        }
        let slo = SloTarget {
            e2e_p95_s: Some(runs.iter().map(|r| r.model.e2e.p95_s).fold(f64::INFINITY, f64::min)),
            ..Default::default()
        };
        let scored: Vec<(f64, f64)> =
            runs.iter().map(|r| (r.goodput(&slo), r.model.e2e.p99_s)).collect();
        let healthy_best = best(&scored);
        let makespan = runs[healthy_best].model.makespan_s;
        for frac in [0.25, 0.45, 0.65] {
            for replica in 0..3usize {
                let faults =
                    FaultSpec::none().with_outage(replica, frac * makespan, 0.5 * makespan);
                let mut scored = Vec::new();
                for p in POLICIES {
                    let r = tiny
                        .fleet(3)?
                        .with_router(p)
                        .with_faults(faults.clone())?
                        .simulate(&wl, seed)?;
                    scored.push((r.goodput(&slo), r.model.e2e.p99_s));
                }
                let churn_best = best(&scored);
                if churn_best != healthy_best {
                    reorder = Some((seed, replica, frac, healthy_best, churn_best));
                    break 'grid;
                }
            }
        }
    }
    let (seed, replica, frac, hb, cb) =
        reorder.ok_or_else(|| anyhow::anyhow!("no seed reordered the policy ranking"))?;
    println!(
        "policy reordering OK: seed {seed:#x}, replica {replica} down at {frac} of the \
         makespan -> best policy shifts {} -> {}",
        POLICIES[hb].label(),
        POLICIES[cb].label()
    );

    println!("\nchurn_e2e OK");
    Ok(())
}
