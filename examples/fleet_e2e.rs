//! End-to-end fleet-serving driver (the fleet analogue of
//! `serve_e2e -- structural`, and the CI fleet smoke test).
//!
//! Three checks on the model clock, all structural (no artifacts):
//!
//! 1. **Horizontal scaling** — at a fixed Poisson arrival rate, a
//!    2-replica fleet must beat a single replica on model-time p95 E2E
//!    (queueing and decode-batch depth both halve).
//! 2. **Determinism** — re-running the same spec, workload, and seed
//!    reproduces the model-time summary bitwise.
//! 3. **Disaggregation** — a prefill-TP4 / decode-PP4 split serves the
//!    same workload; every request ships exactly the KV bytes
//!    `analysis::disagg::DisaggregationModel` predicts, priced through
//!    the α–β link model (the handoff wire time is on the request's
//!    timeline).

use commsim::analysis::{DisaggregationModel, InferenceShape, ParallelLayout};
use commsim::fleet::{FleetSpec, FleetSummary, RouterPolicy};
use commsim::plan::Deployment;
use commsim::report::fmt_bytes;
use commsim::workload::{ArrivalProcess, LengthDist, WorkloadSpec};

fn print_summary(label: &str, s: &FleetSummary) {
    println!(
        "[{label}] {} requests ({} ok, {} failed) — {:.1} tok/s over {:.3} s makespan",
        s.requests, s.completed, s.failed, s.model.tokens_per_s, s.model.makespan_s
    );
    println!(
        "  TTFT p50/p95 : {:.2} / {:.2} ms   TPOT p50/p95 : {:.3} / {:.3} ms",
        s.model.ttft.p50_s * 1e3,
        s.model.ttft.p95_s * 1e3,
        s.model.tpot.p50_s * 1e3,
        s.model.tpot.p95_s * 1e3
    );
    println!(
        "  E2E  p50/p95 : {:.4} / {:.4} s (mean {:.4} s, includes queueing)",
        s.model.e2e.p50_s, s.model.e2e.p95_s, s.model.e2e_mean_s
    );
    for r in &s.replicas {
        println!(
            "  {:<28} assigned={:<3} peak depth={:<3} tokens={}",
            r.label, r.assigned, r.max_depth, r.tokens
        );
    }
    if s.kv_transfer_bytes > 0.0 {
        println!(
            "  KV handoff   : {} total, {:.3} ms wire time",
            fmt_bytes(s.kv_transfer_bytes),
            s.kv_transfer_s * 1e3
        );
    }
}

fn main() -> anyhow::Result<()> {
    let (sp, sd) = (32usize, 16usize);
    let requests = 32usize;
    let rate = 150.0;
    let seed = 0xF1EE7u64;
    let plan = Deployment::builder().model("8b").tp(2).workload(sp, sd).build()?;
    let workload = WorkloadSpec {
        arrivals: ArrivalProcess::poisson(rate),
        prompt: LengthDist::Fixed(sp),
        decode: LengthDist::Fixed(sd),
        prefix: None,
        requests,
    };
    println!(
        "fleet e2e: {} — {requests} requests, Poisson {rate}/s, seed {seed:#x}\n",
        plan.label()
    );

    // --- 1. horizontal scaling: 2 replicas vs 1 at fixed load ----------
    let one = plan.fleet(1)?.simulate(&workload, seed)?;
    let two = plan
        .fleet(2)?
        .with_router(RouterPolicy::LeastOutstandingTokens)
        .simulate(&workload, seed)?;
    print_summary("1 replica ", &one);
    print_summary("2 replicas", &two);
    anyhow::ensure!(
        one.completed == requests && two.completed == requests,
        "all requests must complete"
    );
    anyhow::ensure!(
        two.model.e2e.p95_s < one.model.e2e.p95_s,
        "2 replicas must beat 1 on model-time p95 E2E at fixed arrival rate \
         ({:.4} vs {:.4} s)",
        two.model.e2e.p95_s,
        one.model.e2e.p95_s
    );
    println!(
        "\nscaling OK: p95 E2E {:.4} s -> {:.4} s ({:.2}x)",
        one.model.e2e.p95_s,
        two.model.e2e.p95_s,
        one.model.e2e.p95_s / two.model.e2e.p95_s
    );

    // --- 2. determinism ------------------------------------------------
    let again = plan
        .fleet(2)?
        .with_router(RouterPolicy::LeastOutstandingTokens)
        .simulate(&workload, seed)?;
    anyhow::ensure!(
        again.model == two.model,
        "same spec + workload + seed must reproduce the model summary bitwise"
    );
    println!("determinism OK: identical model-time summary on re-run");

    // --- 3. disaggregated prefill/decode pools -------------------------
    let prefill = Deployment::builder().model("8b").tp(4).workload(sp, sd).build()?;
    let decode = Deployment::builder().model("8b").pp(4).workload(sp, sd).build()?;
    let disagg = FleetSpec::disaggregated(&prefill, 1, &decode, 1)?
        .simulate(&workload, seed)?;
    println!();
    print_summary("disaggregated", &disagg);
    anyhow::ensure!(disagg.completed == requests, "disagg serves everything");
    let model = DisaggregationModel::new(
        plan.arch().clone(),
        ParallelLayout::new(4, 1),
        ParallelLayout::new(1, 4),
    );
    let expect = model.volume(InferenceShape::new(sp, sd, 2)).kv_transfer;
    for m in &disagg.per_request {
        anyhow::ensure!(
            m.kv_transfer_bytes == expect,
            "request {} shipped {} KV bytes, DisaggregationModel predicts {expect}",
            m.request_id,
            m.kv_transfer_bytes
        );
        anyhow::ensure!(m.kv_transfer_s > 0.0, "KV handoff wire time is priced");
    }
    anyhow::ensure!(
        disagg.total_tokens == requests * sd,
        "disaggregation serves the same token budget"
    );
    println!(
        "\ndisaggregation OK: {} KV bytes/request (= Sp x kv_bytes_per_token), \
         priced on the alpha-beta link model",
        fmt_bytes(expect)
    );

    println!("\nfleet_e2e OK");
    Ok(())
}
