//! End-to-end serving driver — the full three-layer stack on a real
//! workload (the system-prompt-mandated E2E validation; results recorded
//! in EXPERIMENTS.md).
//!
//! Two modes:
//!
//! - **numeric** (default; needs `make artifacts`): loads the tiny real
//!   model (Pallas kernels → JAX segments → AOT HLO → PJRT), verifies
//!   every layout against the pinned JAX reference, then serves a batch
//!   through the continuous-batching scheduler (numeric engines clamp to
//!   batch 1 — their PJRT executables hold single-sequence KV state).
//! - **structural** (`cargo run --release --example serve_e2e -- structural`):
//!   paper-scale continuous batching with no artifacts — serves the same
//!   request set at `max_batch` 4 and 1, demonstrates the throughput win,
//!   streams a few `TokenEvent`s, and prints the batch-tagged decode
//!   AllReduce accounting. This is the CI serving smoke test.

use commsim::comm::{CollectiveKind, Stage};
use commsim::engine::{SequenceInput, StepKind};
use commsim::plan::Deployment;
use commsim::runtime::ArtifactStore;
use commsim::server::{Request, SchedulerConfig, ServeSummary};

const EXPECTED_TOKENS: [i32; 12] = [95, 497, 497, 497, 109, 379, 109, 291, 497, 497, 109, 269];

fn requests(n: u64, sp: usize, vocab: i32, decode_len: usize) -> Vec<Request> {
    (0..n)
        .map(|id| Request {
            id,
            prompt: (0..sp as i32)
                .map(|i| (id as i32 * 131 + 7 * i) % vocab)
                .collect::<Vec<i32>>()
                .into(),
            decode_len,
        })
        .collect()
}

fn print_summary(label: &str, s: &ServeSummary) {
    println!(
        "[{label}] {} requests ({} ok, {} failed) — {:.1} tok/s ({:.2} req/s)",
        s.requests, s.completed, s.failed, s.tokens_per_s, s.requests_per_s
    );
    println!(
        "  TTFT p50/p95/p99 : {:.2} / {:.2} / {:.2} ms",
        s.ttft.p50_s * 1e3,
        s.ttft.p95_s * 1e3,
        s.ttft.p99_s * 1e3
    );
    println!(
        "  TPOT p50/p95/p99 : {:.3} / {:.3} / {:.3} ms",
        s.tpot.p50_s * 1e3,
        s.tpot.p95_s * 1e3,
        s.tpot.p99_s * 1e3
    );
    println!(
        "  E2E  p50/p99     : {:.4} / {:.4} s (mean {:.4} s)",
        s.e2e.p50_s, s.e2e.p99_s, s.e2e_mean_s
    );
    if let Some(mt) = &s.model {
        println!(
            "  model time       : TTFT p50 {:.1} ms, TPOT p50 {:.2} ms, E2E p50 {:.3} s \
             ({:.1} tok/s over {:.3} s makespan)",
            mt.ttft.p50_s * 1e3,
            mt.tpot.p50_s * 1e3,
            mt.e2e.p50_s,
            mt.tokens_per_s,
            mt.makespan_s
        );
    }
}

/// Paper-scale serving without artifacts: the continuous-batching path the
/// structural engine supports end-to-end.
fn structural_demo() -> anyhow::Result<()> {
    let plan = Deployment::builder().model("8b").tp(2).workload(32, 16).build()?;
    println!(
        "structural serving: {} (no artifacts; no-op compute, real collectives)\n",
        plan.label()
    );

    // --- streaming: drive a session by hand for two sequences -----------
    let mut engine = plan.engine()?;
    {
        let mut session = engine.session();
        session.admit(SequenceInput {
            id: 0,
            prompt: vec![0; 32].into(),
            start: 0,
            max_new_tokens: 4,
        })?;
        session.admit(SequenceInput {
            id: 1,
            prompt: vec![0; 32].into(),
            start: 0,
            max_new_tokens: 3,
        })?;
        println!("[stream] iteration-level token events:");
        while !session.is_idle() {
            let out = session.step()?;
            let kind = match out.kind {
                StepKind::Prefill => "prefill",
                StepKind::Decode => "decode ",
                StepKind::Idle => break,
            };
            let events: Vec<String> = out
                .events
                .iter()
                .map(|e| format!("seq{}#{}{}", e.seq, e.index, if e.is_last { "!" } else { "" }))
                .collect();
            println!(
                "  step {:<2} {kind} batch={} -> {}",
                out.step_index,
                out.batch,
                events.join(" ")
            );
        }
    }

    // --- throughput: continuous batching vs one-at-a-time ----------------
    let n = 8u64;
    let decode_len = 16usize;
    let serve = |max_batch: usize| -> anyhow::Result<(ServeSummary, usize)> {
        let cfg = SchedulerConfig { max_batch, ..SchedulerConfig::default() };
        let mut server = plan.server(cfg)?;
        let vocab = plan.arch().vocab as i32;
        let summary = server.serve_batch(requests(n, 32, vocab, decode_len))?;
        let trace = server.engine().trace().summary();
        let tagged = trace
            .batch_sizes()
            .into_iter()
            .filter(|&b| b > 1)
            .map(|b| trace.batch_view(b, CollectiveKind::AllReduce, Stage::Decode).count)
            .sum::<usize>();
        if max_batch > 1 {
            println!("\ndecode AllReduce by active batch size (max_batch={max_batch}):");
            for b in trace.batch_sizes() {
                let agg = trace.batch_view(b, CollectiveKind::AllReduce, Stage::Decode);
                if agg.count > 0 {
                    let per = agg.total_message_bytes / agg.count;
                    println!("  batch={b}: count={:<5} per-record={per} B", agg.count);
                }
            }
        }
        Ok((summary, tagged))
    };

    let (batched, tagged) = serve(4)?;
    let (fcfs, _) = serve(1)?;
    println!();
    print_summary("continuous batching, max_batch=4", &batched);
    print_summary("one-at-a-time, max_batch=1", &fcfs);
    anyhow::ensure!(
        batched.completed == n as usize && fcfs.completed == n as usize,
        "all requests must complete"
    );
    anyhow::ensure!(tagged > 0, "batched decode collectives must carry batch tags > 1");
    anyhow::ensure!(
        batched.tokens_per_s > fcfs.tokens_per_s,
        "continuous batching must beat FCFS aggregate throughput ({:.1} vs {:.1} tok/s)",
        batched.tokens_per_s,
        fcfs.tokens_per_s
    );
    println!(
        "\ncontinuous batching speedup: {:.2}x aggregate tokens/s",
        batched.tokens_per_s / fcfs.tokens_per_s
    );
    // Model time tells the same story on the priced virtual clock — and
    // being host-independent, it is the number structural serving stands
    // behind (wall clocks here time no-op compute).
    let bm = batched.model.as_ref().expect("structural serving is priced");
    let fm = fcfs.model.as_ref().expect("structural serving is priced");
    anyhow::ensure!(
        bm.tokens_per_s > fm.tokens_per_s,
        "continuous batching must also win in model time ({:.1} vs {:.1} tok/s)",
        bm.tokens_per_s,
        fm.tokens_per_s
    );
    println!(
        "model-time speedup: {:.2}x tokens per model second ({:.1} vs {:.1})",
        bm.tokens_per_s / fm.tokens_per_s,
        bm.tokens_per_s,
        fm.tokens_per_s
    );
    println!("\nserve_e2e OK (structural)");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let arg = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    if arg == "structural" {
        return structural_demo();
    }
    let store = ArtifactStore::open(arg)?;
    let sp = store.meta.prefill_len;
    let vocab = store.meta.vocab as i32;
    println!(
        "model: {} (h={}, L={}, v={}), prompt len {}",
        store.meta.model, store.meta.hidden, store.meta.layers, store.meta.vocab, sp
    );

    // --- correctness: every layout reproduces the JAX reference --------
    let pinned: Vec<i32> = (0..sp).map(|i| ((7 * i) as i32) % vocab).collect();
    for (tp, pp) in [(1usize, 1usize), (2, 1), (1, 2), (2, 2)] {
        let plan = Deployment::builder().artifacts(store.clone()).tp(tp).pp(pp).build()?;
        let mut engine = plan.engine()?;
        let r = engine.generate(&pinned, EXPECTED_TOKENS.len())?;
        anyhow::ensure!(
            r.tokens == EXPECTED_TOKENS,
            "{}: tokens diverge from JAX reference",
            plan.layout().label()
        );
        println!(
            "[verify] {:<10} tokens == JAX reference  (TTFT {:>6.1} ms, TPOT {:>6.2} ms)",
            plan.layout().label(),
            r.ttft.as_secs_f64() * 1e3,
            r.tpot.as_secs_f64() * 1e3,
        );
    }

    // --- serving: batch of requests through scheduler + session ---------
    let plan = Deployment::builder().artifacts(store.clone()).tp(2).pp(1).build()?;
    let mut server = plan.server(SchedulerConfig {
        kv_blocks: 256,
        kv_block_size: 16,
        max_queue: 256,
        max_batch: 8, // numeric engines clamp to 1 (single-sequence KV)
    })?;
    server.warmup()?; // exclude one-time PJRT first-execution setup from SLOs
    let n_requests = 16u64;
    let decode_len = 48usize;
    let summary = server.serve_batch(requests(n_requests, sp, vocab, decode_len))?;
    println!(
        "\n[serve] layout {} — {} requests x {} tokens",
        plan.layout().label(),
        n_requests,
        decode_len
    );
    print_summary("numeric serve", &summary);

    // --- the paper's object of study: the comm stream of that serving run
    let trace = server.engine().trace().summary();
    println!("\n[trace] collective stream of the serving run (per-worker view):");
    for stage in [Stage::Prefill, Stage::Decode] {
        for op in [CollectiveKind::AllReduce, CollectiveKind::Gather] {
            let v = trace.paper_view(op, stage);
            if v.count > 0 {
                println!(
                    "  {:<10} {:<8} count={:<6} bytes={}",
                    op.label(),
                    stage.label(),
                    v.count,
                    commsim::report::fmt_bytes(v.total_message_bytes as f64)
                );
            }
        }
    }
    println!("\nserve_e2e OK");
    Ok(())
}
