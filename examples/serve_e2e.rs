//! End-to-end serving driver — the full three-layer stack on a real
//! workload (the system-prompt-mandated E2E validation; results recorded
//! in EXPERIMENTS.md).
//!
//! Loads the tiny real model (Pallas kernels → JAX segments → AOT HLO →
//! PJRT), builds numeric deployment plans with real AllReduce/Gather
//! between worker threads, serves a batch of requests through the
//! router/scheduler, and reports latency/throughput. Also verifies the
//! served tokens against the pinned JAX reference and cross-checks TP=2
//! vs PP=2 vs hybrid 2×2.
//!
//! Run: `make artifacts && cargo run --release --example serve_e2e`

use commsim::plan::Deployment;
use commsim::runtime::ArtifactStore;
use commsim::server::{Request, SchedulerConfig};

const EXPECTED_TOKENS: [i32; 12] = [95, 497, 497, 497, 109, 379, 109, 291, 497, 497, 109, 269];

fn main() -> anyhow::Result<()> {
    let store = ArtifactStore::open(
        std::env::args().nth(1).unwrap_or_else(|| "artifacts".into()),
    )?;
    let sp = store.meta.prefill_len;
    let vocab = store.meta.vocab as i32;
    println!(
        "model: {} (h={}, L={}, v={}), prompt len {}",
        store.meta.model, store.meta.hidden, store.meta.layers, store.meta.vocab, sp
    );

    // --- correctness: every layout reproduces the JAX reference --------
    let pinned: Vec<i32> = (0..sp).map(|i| ((7 * i) as i32) % vocab).collect();
    for (tp, pp) in [(1usize, 1usize), (2, 1), (1, 2), (2, 2)] {
        let plan = Deployment::builder().artifacts(store.clone()).tp(tp).pp(pp).build()?;
        let mut engine = plan.engine()?;
        let r = engine.generate(&pinned, EXPECTED_TOKENS.len())?;
        anyhow::ensure!(
            r.tokens == EXPECTED_TOKENS,
            "{}: tokens diverge from JAX reference",
            plan.layout().label()
        );
        println!(
            "[verify] {:<10} tokens == JAX reference  (TTFT {:>6.1} ms, TPOT {:>6.2} ms)",
            plan.layout().label(),
            r.ttft.as_secs_f64() * 1e3,
            r.tpot.as_secs_f64() * 1e3,
        );
    }

    // --- serving: batch of requests through router + scheduler ---------
    let plan = Deployment::builder().artifacts(store.clone()).tp(2).pp(1).build()?;
    let mut server =
        plan.server(SchedulerConfig { kv_blocks: 256, kv_block_size: 16, max_queue: 256 })?;
    server.warmup()?; // exclude one-time PJRT first-execution setup from SLOs
    let n_requests = 16usize;
    let decode_len = 48usize;
    let requests: Vec<Request> = (0..n_requests as u64)
        .map(|id| Request {
            id,
            prompt: (0..sp as i32).map(|i| (id as i32 * 131 + 7 * i) % vocab).collect(),
            decode_len,
        })
        .collect();
    let summary = server.serve_batch(requests)?;
    println!(
        "\n[serve] layout {} — {} requests x {} tokens",
        plan.layout().label(),
        n_requests,
        decode_len
    );
    println!("  throughput : {:.1} tok/s ({:.2} req/s)", summary.tokens_per_s, summary.requests_per_s);
    println!("  TTFT p50/p99 : {:.1} / {:.1} ms", summary.ttft_p50_s * 1e3, summary.ttft_p99_s * 1e3);
    println!("  TPOT p50/p99 : {:.2} / {:.2} ms", summary.tpot_p50_s * 1e3, summary.tpot_p99_s * 1e3);
    println!("  E2E mean   : {:.3} s (includes queueing)", summary.e2e_mean_s);

    // --- the paper's object of study: the comm stream of that serving run
    let trace = server.engine().trace().summary();
    println!("\n[trace] collective stream of the serving run (per-worker view):");
    for stage in [commsim::comm::Stage::Prefill, commsim::comm::Stage::Decode] {
        for op in [
            commsim::comm::CollectiveKind::AllReduce,
            commsim::comm::CollectiveKind::Gather,
        ] {
            let v = trace.paper_view(op, stage);
            if v.count > 0 {
                println!(
                    "  {:<10} {:<8} count={:<6} bytes={}",
                    op.label(),
                    stage.label(),
                    v.count,
                    commsim::report::fmt_bytes(v.total_message_bytes as f64)
                );
            }
        }
    }
    println!("\nserve_e2e OK");
    Ok(())
}
