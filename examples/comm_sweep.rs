//! Communication sweep — regenerates the paper's full measurement campaign
//! in one run: every (model × layout × decode length) cell, engine-traced
//! and analytically cross-checked. The CSV on stdout is the input for
//! re-plotting Figs. 4–7.
//!
//! Run: `cargo run --release --example comm_sweep [--fast]`

use commsim::analysis::{InferenceShape, ParallelLayout, VolumeModel};
use commsim::comm::{CollectiveKind, Stage};
use commsim::engine::{Engine, EngineConfig};
use commsim::model::ModelArch;

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let sds: &[usize] = if fast { &[32] } else { &[128, 256, 512] };
    let layouts = [
        ParallelLayout::new(2, 1),
        ParallelLayout::new(4, 1),
        ParallelLayout::new(1, 2),
        ParallelLayout::new(1, 4),
        ParallelLayout::new(2, 2),
    ];

    println!("model,layout,sp,sd,op,stage,count,message_bytes,corrected_bytes,analytical_total");
    let mut cells = 0;
    for arch in ModelArch::paper_models() {
        for layout in layouts {
            for &sd in sds {
                let sp = 128;
                let shape = InferenceShape::new(sp, sd, 2);
                let analytical = VolumeModel::new(arch.clone()).volume(layout, shape).total();
                let mut engine =
                    Engine::new(EngineConfig::structural(arch.clone(), layout))?;
                engine.generate(&vec![0i32; sp], sd)?;
                let s = engine.trace().summary();
                for stage in [Stage::Prefill, Stage::Decode] {
                    for op in [
                        CollectiveKind::AllReduce,
                        CollectiveKind::AllGather,
                        CollectiveKind::Gather,
                        CollectiveKind::Send,
                    ] {
                        let v = s.paper_view(op, stage);
                        if v.count == 0 {
                            continue;
                        }
                        println!(
                            "{},{},{sp},{sd},{},{},{},{},{:.0},{analytical:.0}",
                            arch.name,
                            layout.label().replace(' ', "x"),
                            op.label(),
                            stage.label(),
                            v.count,
                            v.total_message_bytes,
                            v.corrected_volume_bytes,
                        );
                    }
                }
                cells += 1;
            }
        }
    }
    eprintln!("swept {cells} (model x layout x Sd) cells");
    Ok(())
}
