//! Communication sweep — regenerates the paper's full measurement campaign
//! in one run: every (model × layout × decode length) cell, engine-traced
//! and analytically cross-checked through the deployment-plan facade. The
//! CSV on stdout is the input for re-plotting Figs. 4–7.
//!
//! Run: `cargo run --release --example comm_sweep [--fast]`

use commsim::comm::{CollectiveKind, Stage};
use commsim::model::ModelArch;
use commsim::plan::Deployment;

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let sds: &[usize] = if fast { &[32] } else { &[128, 256, 512] };
    let layouts = [(2usize, 1usize), (4, 1), (1, 2), (1, 4), (2, 2)];

    println!("model,layout,sp,sd,op,stage,count,message_bytes,corrected_bytes,analytical_total");
    let mut cells = 0;
    for arch in ModelArch::paper_models() {
        for (tp, pp) in layouts {
            for &sd in sds {
                let sp = 128;
                let plan = Deployment::builder()
                    .arch(arch.clone())
                    .tp(tp)
                    .pp(pp)
                    .workload(sp, sd)
                    .build()?;
                let analytical = plan.analyze().total_bytes();
                let s = plan.trace()?;
                for stage in [Stage::Prefill, Stage::Decode] {
                    for op in [
                        CollectiveKind::AllReduce,
                        CollectiveKind::AllGather,
                        CollectiveKind::Gather,
                        CollectiveKind::Send,
                    ] {
                        let v = s.paper_view(op, stage);
                        if v.count == 0 {
                            continue;
                        }
                        println!(
                            "{},{},{sp},{sd},{},{},{},{},{:.0},{analytical:.0}",
                            arch.name,
                            plan.layout().label().replace(' ', "x"),
                            op.label(),
                            stage.label(),
                            v.count,
                            v.total_message_bytes,
                            v.corrected_volume_bytes,
                        );
                    }
                }
                cells += 1;
            }
        }
    }
    eprintln!("swept {cells} (model x layout x Sd) cells");
    Ok(())
}
