//! End-to-end elastic-fleet driver (the autoscale analogue of
//! `churn_e2e`, and the CI autoscale smoke test).
//!
//! Three checks on the model clock, all structural (no artifacts):
//!
//! 1. **Zero-action identity** — attaching a policy that can never act
//!    (`min == max`, unreachable thresholds, migration disabled)
//!    reproduces the static fleet bitwise: same model summary, same
//!    per-request records, same provisioned GPU·seconds, same traced
//!    bytes. Elasticity costs nothing when it does nothing.
//! 2. **The headline claim** — under a bursty trace, the elastic fleet
//!    (floor 1, ceiling 3) meets the same end-to-end SLO the static
//!    3-replica fleet meets, with *strictly fewer* provisioned
//!    GPU·seconds: capacity follows load instead of being held at peak.
//! 3. **Every action is priced** — the elastic run's scale-ups paid a
//!    weight cold-start (model seconds over the fleet wire) and any
//!    live KV migration paid α–β wire time; nothing is free, and the
//!    run stays bitwise-deterministic per seed.

use commsim::autoscale::AutoscalePolicy;
use commsim::fleet::{FleetSummary, RouterPolicy, SloTarget};
use commsim::plan::Deployment;
use commsim::workload::{ArrivalProcess, LengthDist, WorkloadSpec};

/// Worst per-request model-time E2E of a run (the tightest SLO the run
/// meets on every request).
fn worst_e2e(s: &FleetSummary) -> f64 {
    s.per_request
        .iter()
        .filter_map(|m| m.model.as_ref().map(|t| t.e2e_s))
        .fold(0.0f64, f64::max)
}

fn main() -> anyhow::Result<()> {
    let (sp, sd) = (32usize, 16usize);
    let requests = 48usize;
    let seed = 0xE1A57u64;
    let plan = Deployment::builder().model("8b").tp(2).workload(sp, sd).build()?;
    // Bursty offered load: epochs of 6 back-to-back arrivals with long
    // idle gaps (long-run rate 3 req/s), so the peak needs ~3 replicas
    // while the average needs ~1 — the gap elasticity exists to close.
    let workload = WorkloadSpec {
        arrivals: ArrivalProcess::bursty(3.0, 6),
        prompt: LengthDist::Fixed(sp),
        decode: LengthDist::Fixed(sd),
        prefix: None,
        requests,
    };
    println!("autoscale e2e: {} x1..3 — {requests} requests, seed {seed:#x}\n", plan.label());

    // Static baseline: provisioned for the peak, the whole run.
    let fixed = plan
        .fleet(3)?
        .with_router(RouterPolicy::LeastOutstandingTokens)
        .simulate(&workload, seed)?;
    anyhow::ensure!(fixed.completed == requests && fixed.failed == 0);

    // --- 1. zero-action identity ---------------------------------------
    // min == max keeps every replica active, the queue target is
    // unreachable, and migration is disabled: the controller ticks but
    // only ever Holds.
    let mut inert = AutoscalePolicy::target_queue(3, 3, 1e9, 1.0);
    inert.migrate_queue_gap = 0;
    let held = plan
        .fleet(3)?
        .with_router(RouterPolicy::LeastOutstandingTokens)
        .with_autoscale(inert)?
        .simulate(&workload, seed)?;
    anyhow::ensure!(
        held.model == fixed.model,
        "a never-acting policy must reproduce the static model summary bitwise"
    );
    anyhow::ensure!(held.per_request.len() == fixed.per_request.len());
    for (a, b) in held.per_request.iter().zip(fixed.per_request.iter()) {
        anyhow::ensure!(
            a.request_id == b.request_id && a.replica == b.replica && a.model == b.model,
            "per-request records must match the static run"
        );
    }
    anyhow::ensure!(held.provisioned_gpu_s == fixed.provisioned_gpu_s);
    anyhow::ensure!(held.comm_bytes == fixed.comm_bytes);
    anyhow::ensure!(held.cold_starts == 0 && held.migrations == 0);
    println!("zero-action OK: inert policy is the static fleet, bitwise");

    // --- 2. same SLO, strictly fewer provisioned GPU*s ------------------
    let policy = AutoscalePolicy::target_queue(1, 3, 1.5, 1.0);
    let elastic = || -> anyhow::Result<FleetSummary> {
        Ok(plan
            .fleet(3)?
            .with_router(RouterPolicy::LeastOutstandingTokens)
            .with_autoscale(policy.clone())?
            .simulate(&workload, seed)?)
    };
    let flexed = elastic()?;
    anyhow::ensure!(flexed.completed == requests, "elasticity never loses a request");
    anyhow::ensure!(flexed.failed == 0);
    // The operator's SLO: the tightest E2E bound both deployments meet
    // on every request.
    let slo = SloTarget {
        e2e_p95_s: Some(worst_e2e(&fixed).max(worst_e2e(&flexed))),
        ..Default::default()
    };
    let (gf, ge) = (fixed.goodput(&slo), flexed.goodput(&slo));
    anyhow::ensure!(gf == 1.0 && ge == 1.0, "both fleets meet the shared SLO ({gf}, {ge})");
    anyhow::ensure!(
        flexed.provisioned_gpu_s < fixed.provisioned_gpu_s,
        "elastic must provision strictly fewer GPU*s ({:.3} vs {:.3})",
        flexed.provisioned_gpu_s,
        fixed.provisioned_gpu_s
    );
    println!(
        "headline OK: goodput {ge:.3} at the static fleet's SLO with {:.1} GPU*s \
         provisioned vs {:.1} static ({:.0}% saved)",
        flexed.provisioned_gpu_s,
        fixed.provisioned_gpu_s,
        100.0 * (1.0 - flexed.provisioned_gpu_s / fixed.provisioned_gpu_s)
    );

    // --- 3. every elasticity action is priced ---------------------------
    anyhow::ensure!(flexed.cold_starts >= 1, "the bursts must trigger a scale-up");
    anyhow::ensure!(flexed.cold_start_s > 0.0, "scale-up is never free");
    if flexed.migrations > 0 {
        anyhow::ensure!(flexed.kv_migration_bytes > 0.0 && flexed.kv_migration_s > 0.0);
    }
    let again = elastic()?;
    anyhow::ensure!(
        again.model == flexed.model
            && again.cold_starts == flexed.cold_starts
            && again.migrations == flexed.migrations
            && again.provisioned_gpu_s == flexed.provisioned_gpu_s,
        "same policy + seed must reproduce the elastic run bitwise"
    );
    println!(
        "pricing OK: {} cold start(s) costing {:.3}s, {} migration(s) shipping {:.1} KiB \
         in {:.4}s — all on the model clock, reproducible per seed",
        flexed.cold_starts,
        flexed.cold_start_s,
        flexed.migrations,
        flexed.kv_migration_bytes / 1024.0,
        flexed.kv_migration_s
    );

    println!("\nautoscale_e2e OK");
    Ok(())
}
