//! End-to-end chunked-prefill driver (the CI smoke test for
//! `Deployment::chunked_prefill`).
//!
//! Setting: Llama-3.1-8B at TP=2 under a ShareGPT-like long-tail prompt
//! mix — mostly short chatty prompts with a heavy minority of 4096-token
//! documents — and short decode budgets, so every request spends its
//! decode phase as a potential *victim* of someone else's prefill. Four
//! checks on the model clock, all structural:
//!
//! 1. **Interference relief** — splitting the long prompts into
//!    128-token chunks fused with the running decode batch must strictly
//!    improve the decode-victim TPOT p95 of the colocated fleet: victims
//!    stream tokens through the chunk window (and escape it early)
//!    instead of stalling for the whole one-shot prefill.
//! 2. **Gap to disaggregation** — a prefill/decode split is the
//!    upper bound on interference relief (decode-pool victims only ever
//!    stall behind one-token intakes). Chunking must land the colocated
//!    fleet strictly between one-shot and disaggregated TPOT p95 —
//!    narrowing the gap the paper's comparison is usually shown with.
//! 3. **Identity** — a chunk budget no prompt exceeds reproduces the
//!    unchunked fleet summary bitwise: the knob is not "approximately
//!    off", it is the identical code path.
//! 4. **Determinism** — re-running the chunked fleet on the same seed
//!    reproduces the summary and the interference ledger bitwise.

use commsim::fleet::FleetSummary;
use commsim::plan::{Deployment, DeploymentPlan};
use commsim::server::SchedulerConfig;
use commsim::workload::{ArrivalProcess, LengthDist, WorkloadSpec};

fn print_summary(label: &str, s: &FleetSummary) {
    println!(
        "[{label}] {} requests ({} ok) — TPOT p50/p95 {:.2} / {:.2} ms, \
         {} chunked, {:.1} ms interference",
        s.requests,
        s.completed,
        s.model.tpot.p50_s * 1e3,
        s.model.tpot.p95_s * 1e3,
        s.chunked_requests,
        s.interference_s * 1e3
    );
}

fn main() -> anyhow::Result<()> {
    let requests = 96usize;
    let seed = 0xC11E5u64;
    let build = |chunk: Option<usize>| -> anyhow::Result<DeploymentPlan> {
        let mut b = Deployment::builder().model("8b").tp(2).workload(4096, 8);
        if let Some(tokens) = chunk {
            b = b.chunked_prefill(tokens);
        }
        Ok(b.build()?)
    };
    let plain = build(None)?;
    let chunked = build(Some(128))?;

    // Long-tail prompts over a short decode budget: a 4096-token prompt
    // splits into 32 chunks, while a victim has at most 7 decode gaps —
    // so under chunking every victim escapes the window early instead of
    // stalling for the full one-shot prefill. The rate oversubscribes
    // one replica so decode phases always overlap someone's prefill.
    let workload = WorkloadSpec {
        arrivals: ArrivalProcess::poisson(8.0),
        prompt: LengthDist::LongTail { short: 32, long: 4096, long_weight: 0.3 },
        decode: LengthDist::Fixed(8),
        prefix: None,
        requests,
    };
    let cfg =
        SchedulerConfig { kv_blocks: 4096, kv_block_size: 16, max_queue: 256, max_batch: 8 };
    let run = |spec: commsim::fleet::FleetSpec| -> anyhow::Result<FleetSummary> {
        Ok(spec.with_scheduler(cfg).simulate(&workload, seed)?)
    };

    // --- 1. chunking relieves decode-victim interference ----------------
    let one_shot = run(plain.fleet(1)?)?;
    let sarathi = run(chunked.fleet(1)?)?;
    print_summary("one-shot ", &one_shot);
    print_summary("chunk 128", &sarathi);
    for s in [&one_shot, &sarathi] {
        anyhow::ensure!(s.completed == requests, "all requests must complete");
    }
    anyhow::ensure!(
        one_shot.chunked_requests == 0 && sarathi.chunked_requests > 0,
        "the long-tail mix must exercise the chunk budget"
    );
    anyhow::ensure!(
        sarathi.model.tpot.p95_s < one_shot.model.tpot.p95_s,
        "chunked prefill must strictly improve decode-victim TPOT p95 \
         ({:.2} ms vs one-shot {:.2} ms)",
        sarathi.model.tpot.p95_s * 1e3,
        one_shot.model.tpot.p95_s * 1e3
    );
    anyhow::ensure!(
        sarathi.interference_s < one_shot.interference_s,
        "the chunked fleet must price strictly less total interference"
    );
    println!(
        "\ninterference OK: TPOT p95 {:.2} -> {:.2} ms under a 128-token budget",
        one_shot.model.tpot.p95_s * 1e3,
        sarathi.model.tpot.p95_s * 1e3
    );

    // --- 2. chunking narrows the gap to disaggregation ------------------
    let disagg = run(commsim::fleet::FleetSpec::disaggregated(&plain, 1, &plain, 1)?)?;
    print_summary("disagg   ", &disagg);
    anyhow::ensure!(disagg.completed == requests, "disagg must complete all requests");
    anyhow::ensure!(
        disagg.model.tpot.p95_s <= sarathi.model.tpot.p95_s,
        "decode-pool isolation bounds what chunking can recover"
    );
    let gap_one_shot = one_shot.model.tpot.p95_s - disagg.model.tpot.p95_s;
    let gap_chunked = sarathi.model.tpot.p95_s - disagg.model.tpot.p95_s;
    anyhow::ensure!(
        gap_chunked < gap_one_shot,
        "chunking must narrow the colocated-vs-disaggregated TPOT p95 gap \
         ({:.2} ms vs {:.2} ms)",
        gap_chunked * 1e3,
        gap_one_shot * 1e3
    );
    println!(
        "gap OK: colocated sits {:.2} ms over disaggregated one-shot, {:.2} ms chunked",
        gap_one_shot * 1e3,
        gap_chunked * 1e3
    );

    // --- 3. a budget no prompt exceeds is bitwise the unchunked path ----
    let slack = build(Some(8192))?;
    let slack_run = run(slack.fleet(1)?)?;
    anyhow::ensure!(
        slack_run.model == one_shot.model,
        "chunked_prefill(8192) over <= 4096-token prompts must reproduce \
         the unchunked fleet bitwise"
    );
    anyhow::ensure!(
        slack_run.chunked_requests == 0
            && slack_run.interference_s == one_shot.interference_s,
        "a never-exceeded budget splits nothing and re-prices nothing"
    );
    println!("identity OK: a slack budget is the unchunked code path, bit for bit");

    // --- 4. determinism of the chunked fleet ----------------------------
    let again = run(chunked.fleet(1)?)?;
    anyhow::ensure!(
        again.model == sarathi.model
            && again.chunked_requests == sarathi.chunked_requests
            && again.interference_s == sarathi.interference_s,
        "same spec + workload + seed must reproduce the chunked summary bitwise"
    );
    println!("determinism OK: identical chunked summary on re-run");

    println!("\nchunked_prefill_e2e OK");
    Ok(())
}
