//! End-to-end compressed-collectives driver (the CI smoke test for
//! `CollectiveTuning`).
//!
//! Setting: Llama-3.2-3B at TP=8 — the paper's cross-node layout where
//! decode is communication-bound (Fig. 8), so the wire precision is the
//! lever that matters. Three checks on the model clock, all structural:
//!
//! 1. **Capacity at fixed SLO** — at the same Poisson arrival rate, a
//!    2-replica int8-wire fleet (16 GPUs) must meet the E2E p95 SLO that
//!    a 4-replica fp16 fleet (32 GPUs) achieves: compressing AllReduce
//!    payloads buys back enough decode time to halve the fleet.
//! 2. **Default identity** — a plan built with an explicit
//!    `collective_tuning(16, 0.0)` reproduces the untuned fleet summary
//!    bitwise: the default tuning is not "approximately off", it is the
//!    identical code path.
//! 3. **Determinism** — re-running the int8 fleet on the same seed
//!    reproduces the model summary and the tuning accounting bitwise.

use commsim::fleet::{FleetSummary, SloTarget};
use commsim::plan::{Deployment, DeploymentPlan};
use commsim::report::fmt_bytes;
use commsim::server::SchedulerConfig;
use commsim::workload::{ArrivalProcess, LengthDist, WorkloadSpec};

fn print_summary(label: &str, s: &FleetSummary) {
    println!(
        "[{label}] {} requests ({} ok, {} failed) — E2E p50/p95 {:.3} / {:.3} s",
        s.requests, s.completed, s.failed, s.model.e2e.p50_s, s.model.e2e.p95_s
    );
    if s.wire_saved_bytes > 0.0 {
        println!(
            "  tuning: {} saved on the wire, {:.3} ms comm hidden",
            fmt_bytes(s.wire_saved_bytes),
            s.hidden_comm_s * 1e3
        );
    }
}

fn main() -> anyhow::Result<()> {
    let (sp, sd) = (64usize, 32usize);
    let requests = 96usize;
    let seed = 0x0DDB17u64;
    let build = |tuning: Option<(u32, f64)>| -> anyhow::Result<DeploymentPlan> {
        let mut b = Deployment::builder().model("3b").tp(8).workload(sp, sd);
        if let Some((bits, ov)) = tuning {
            b = b.collective_tuning(bits, ov);
        }
        Ok(b.build()?)
    };
    let fp16 = build(None)?;
    let int8 = build(Some((8, 0.0)))?;

    // Single-request service times set the arrival rate: 1.3x what two
    // fp16 replicas can serve sequentially, so the small fp16 fleet is
    // overloaded while the int8 wire keeps the same hardware stable.
    let s_fp16 = fp16.simulate().e2e_s;
    let s_int8 = int8.simulate().e2e_s;
    println!(
        "{} single-request E2E: fp16 {:.3} s, int8 wire {:.3} s ({:.0}% comm clawed back)\n",
        fp16.label(),
        s_fp16,
        s_int8,
        (1.0 - s_int8 / s_fp16) * 100.0
    );
    anyhow::ensure!(s_int8 < s_fp16, "int8 wire must shorten the comm-bound service time");
    let rate = 2.6 / s_fp16;
    let workload = WorkloadSpec {
        arrivals: ArrivalProcess::poisson(rate),
        prompt: LengthDist::Fixed(sp),
        decode: LengthDist::Fixed(sd),
        prefix: None,
        requests,
    };
    // max_batch 1 keeps each replica's capacity exactly 1/service-time, so
    // the capacity comparison below is about the wire, not batch dynamics.
    let cfg = SchedulerConfig { kv_blocks: 64, kv_block_size: 16, max_queue: 256, max_batch: 1 };
    let run = |plan: &DeploymentPlan, n: usize| -> anyhow::Result<FleetSummary> {
        Ok(plan.fleet(n)?.with_scheduler(cfg).simulate(&workload, seed)?)
    };

    // --- 1. capacity at fixed SLO --------------------------------------
    let fp16_large = run(&fp16, 4)?;
    let fp16_small = run(&fp16, 2)?;
    let int8_small = run(&int8, 2)?;
    print_summary("fp16 x4", &fp16_large);
    print_summary("fp16 x2", &fp16_small);
    print_summary("int8 x2", &int8_small);
    for s in [&fp16_large, &fp16_small, &int8_small] {
        anyhow::ensure!(s.completed == requests, "all requests must complete");
    }
    let slo = SloTarget { e2e_p95_s: Some(fp16_large.model.e2e.p95_s), ..Default::default() };
    anyhow::ensure!(
        slo.met_by(&int8_small.model),
        "2 int8 replicas (16 GPUs) must meet the E2E p95 SLO of 4 fp16 replicas \
         (32 GPUs): {:.3} s vs target {:.3} s",
        int8_small.model.e2e.p95_s,
        fp16_large.model.e2e.p95_s
    );
    anyhow::ensure!(
        !slo.met_by(&fp16_small.model),
        "2 fp16 replicas must miss that SLO ({:.3} s) — otherwise the rate is \
         too low for the capacity story",
        fp16_small.model.e2e.p95_s
    );
    anyhow::ensure!(
        int8_small.wire_saved_bytes > 0.0,
        "the int8 fleet must report its wire savings"
    );
    println!(
        "\ncapacity OK: int8 wire meets the {:.3} s SLO with half the GPUs \
         (fp16 needs 4 replicas; 2 fp16 replicas reach {:.3} s)",
        fp16_large.model.e2e.p95_s,
        fp16_small.model.e2e.p95_s
    );

    // --- 2. explicit default tuning is bitwise the untuned fleet -------
    let explicit = build(Some((16, 0.0)))?;
    let untuned = run(&fp16, 2)?;
    let defaulted = run(&explicit, 2)?;
    anyhow::ensure!(
        untuned.model == defaulted.model,
        "collective_tuning(16, 0.0) must reproduce the untuned fleet bitwise"
    );
    anyhow::ensure!(
        defaulted.wire_saved_bytes == 0.0 && defaulted.hidden_comm_s == 0.0,
        "the default tuning saves and hides exactly nothing"
    );
    println!("default identity OK: (16, 0.0) is the untuned code path, bit for bit");

    // --- 3. determinism of the tuned fleet -----------------------------
    let again = run(&int8, 2)?;
    anyhow::ensure!(
        again.model == int8_small.model
            && again.wire_saved_bytes == int8_small.wire_saved_bytes
            && again.hidden_comm_s == int8_small.hidden_comm_s,
        "same spec + workload + seed must reproduce the tuned summary bitwise"
    );
    println!("determinism OK: identical tuned summary on re-run");

    println!("\nquantized_comm_e2e OK");
    Ok(())
}
