//! Minimal, dependency-free stand-in for the `anyhow` crate.
//!
//! The vendored build environment has no network access, so this crate
//! provides exactly the API subset `commsim` uses — [`Error`], [`Result`],
//! [`anyhow!`], [`bail!`], [`ensure!`] — with the same semantics:
//!
//! - `Error` is an opaque, `Send + Sync` error value built from a message
//!   or converted from any `std::error::Error`;
//! - like real `anyhow`, `Error` deliberately does **not** implement
//!   `std::error::Error` itself, which is what makes the blanket
//!   `From<E: std::error::Error>` conversion (and therefore `?` on mixed
//!   error types) coherent.
//!
//! Swapping in the real crates.io `anyhow` is a one-line `Cargo.toml`
//! change; no source in `commsim` depends on anything beyond this subset.

use std::fmt;

/// An opaque error value carrying a rendered message.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(msg: M) -> Self {
        Self { msg: msg.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Self::msg(&e)
    }
}

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::concat!(
                "condition failed: ",
                ::std::stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_and_debug_show_message() {
        let e = anyhow!("bad value {}", 42);
        assert_eq!(e.to_string(), "bad value 42");
        assert_eq!(format!("{e:?}"), "bad value 42");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<usize> {
            Ok(s.parse::<usize>()?)
        }
        assert_eq!(parse("7").unwrap(), 7);
        assert!(parse("x").unwrap_err().to_string().contains("invalid digit"));
    }

    #[test]
    fn bail_and_ensure_return_errors() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x > 1, "too small: {x}");
            ensure!(x < 10);
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(f(0).unwrap_err().to_string(), "too small: 0");
        assert!(f(11).unwrap_err().to_string().contains("condition failed"));
        assert_eq!(f(5).unwrap_err().to_string(), "five is right out");
    }
}
