//! Offline stub of the `xla` crate (PJRT bindings to XLA).
//!
//! The build environment has neither network access nor an XLA
//! installation, so this crate mirrors the exact API surface `commsim`
//! uses with two behaviours:
//!
//! - **Host-side [`Literal`]s are real**: creation from untyped bytes,
//!   element readback and shape queries work, so every pure-Rust path
//!   (tensor marshalling, structural engine, tests) is fully functional.
//! - **Device paths report unavailable**: [`PjRtClient::cpu`] and
//!   everything that needs a PJRT runtime return a descriptive error.
//!   Numeric mode (the tiny AOT model) additionally requires built
//!   artifacts, so nothing that works today changes behaviour — the
//!   failure just becomes a clean `Result` instead of a missing crate.
//!
//! Replacing this stub with the real `xla` bindings is a `Cargo.toml`
//! path swap; signatures match `xla` 0.1.6 as used by `commsim::runtime`.

use std::fmt;

/// Error type mirroring `xla::Error`'s display behaviour.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT runtime unavailable (offline `xla` stub; numeric mode \
         needs the real xla bindings and `make artifacts`)"
    ))
}

/// Element types used by commsim (both 4 bytes wide).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Plain-old-data element types a [`Literal`] can be read back as.
pub trait NativeType: Copy {
    const ELEMENT_TYPE: ElementType;
    fn from_le_bytes(bytes: [u8; 4]) -> Self;
}

impl NativeType for f32 {
    const ELEMENT_TYPE: ElementType = ElementType::F32;
    fn from_le_bytes(bytes: [u8; 4]) -> Self {
        f32::from_le_bytes(bytes)
    }
}

impl NativeType for i32 {
    const ELEMENT_TYPE: ElementType = ElementType::S32;
    fn from_le_bytes(bytes: [u8; 4]) -> Self {
        i32::from_le_bytes(bytes)
    }
}

/// Host-side typed array (fully functional in the stub).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    bytes: Vec<u8>,
    tuple: Vec<Literal>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Self> {
        let expect = dims.iter().product::<usize>() * 4;
        if data.len() != expect {
            return Err(Error(format!(
                "literal data is {} bytes but shape {:?} needs {expect}",
                data.len(),
                dims
            )));
        }
        Ok(Self { ty, dims: dims.to_vec(), bytes: data.to_vec(), tuple: Vec::new() })
    }

    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if T::ELEMENT_TYPE != self.ty {
            return Err(Error(format!(
                "literal is {:?}, requested {:?}",
                self.ty,
                T::ELEMENT_TYPE
            )));
        }
        Ok(self
            .bytes
            .chunks_exact(4)
            .map(|c| T::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        if self.tuple.is_empty() {
            return Err(Error("not a tuple literal".to_string()));
        }
        Ok(self.tuple)
    }
}

/// Parsed HLO module text (never constructible in the stub).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<Self> {
        Err(unavailable(&format!("parsing HLO {path}")))
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self { _private: () }
    }
}

/// PJRT client (construction reports unavailable in the stub).
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("PjRtClient::buffer_from_host_buffer"))
    }
}

/// Device buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }

    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let data = [1.0f32, 2.0, 3.0, 4.0];
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 2], &bytes)
                .unwrap();
        assert_eq!(lit.element_count(), 4);
        assert_eq!(lit.to_vec::<f32>().unwrap(), data.to_vec());
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn literal_rejects_size_mismatch() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::S32, &[3], &[0u8; 4])
                .is_err()
        );
    }

    #[test]
    fn device_paths_report_unavailable() {
        let err = PjRtClient::cpu().unwrap_err().to_string();
        assert!(err.contains("unavailable"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
