//! End-to-end numeric integration: the Rust engine executing the AOT tiny
//! model via PJRT must produce the *same greedy token sequence* under every
//! parallel layout — and that sequence must match the JAX reference
//! (`python/compile/model.py::full_step`, pinned below).
//!
//! This is the proof that the three layers compose: Pallas kernels (L1)
//! lowered inside the JAX segments (L2), AOT'd to HLO, executed by PJRT
//! from the Rust coordinator (L3) with *real* AllReduce/AllGather/Gather/
//! Send/Recv between workers.
//!
//! Requires `make artifacts`.

use commsim::analysis::{InferenceShape, OpCountModel, ParallelLayout};
use commsim::comm::{CollectiveKind, Stage};
use commsim::engine::{Engine, EngineConfig};
use commsim::model::ModelArch;
use commsim::runtime::ArtifactStore;

/// Greedy continuation of the pinned prompt computed by the JAX reference
/// (see python/tests/test_numeric_pin.py, same constants).
const EXPECTED_TOKENS: [i32; 12] = [95, 497, 497, 497, 109, 379, 109, 291, 497, 497, 109, 269];

fn pinned_prompt(len: usize, vocab: usize) -> Vec<i32> {
    (0..len).map(|i| ((7 * i) % vocab) as i32).collect()
}

fn store() -> ArtifactStore {
    ArtifactStore::open(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
        .expect("artifacts present (run `make artifacts`)")
}

fn generate(layout: ParallelLayout, decode_len: usize) -> (Vec<i32>, Engine) {
    let store = store();
    let prompt = pinned_prompt(store.meta.prefill_len, store.meta.vocab);
    let mut engine = Engine::new(EngineConfig::numeric(store, layout)).expect("engine");
    let result = engine.generate(&prompt, decode_len).expect("generate");
    (result.tokens, engine)
}

#[test]
fn tp1_matches_jax_reference() {
    let (tokens, _) = generate(ParallelLayout::new(1, 1), EXPECTED_TOKENS.len());
    assert_eq!(tokens, EXPECTED_TOKENS, "single-worker segment composition");
}

#[test]
fn tp2_matches_jax_reference_with_real_allreduce() {
    let (tokens, engine) = generate(ParallelLayout::new(2, 1), EXPECTED_TOKENS.len());
    assert_eq!(tokens, EXPECTED_TOKENS, "TP=2 sharded inference");
    // And the communication stream matches the analytical model exactly.
    let summary = engine.trace().summary();
    let model = OpCountModel::new(
        ModelArch::tiny(),
        ParallelLayout::new(2, 1),
        InferenceShape::new(32, EXPECTED_TOKENS.len(), 4),
    );
    for stage in [Stage::Prefill, Stage::Decode] {
        let predicted = model.predict_paper_view(stage);
        for op in [CollectiveKind::AllReduce, CollectiveKind::Gather] {
            assert_eq!(
                summary.paper_view(op, stage).count,
                predicted.count(op),
                "{op:?} {stage:?}"
            );
        }
    }
}

#[test]
fn tp4_matches_jax_reference() {
    let (tokens, _) = generate(ParallelLayout::new(4, 1), EXPECTED_TOKENS.len());
    assert_eq!(tokens, EXPECTED_TOKENS, "TP=4 sharded inference");
}

#[test]
fn pp2_matches_jax_reference_with_real_p2p() {
    let (tokens, engine) = generate(ParallelLayout::new(1, 2), EXPECTED_TOKENS.len());
    assert_eq!(tokens, EXPECTED_TOKENS, "PP=2 staged inference");
    let summary = engine.trace().summary();
    // (p-1) * 2 tensors * steps: prefill 1 step, decode len-1 steps.
    assert_eq!(summary.global_count(CollectiveKind::Send, Stage::Prefill), 2);
    assert_eq!(
        summary.global_count(CollectiveKind::Send, Stage::Decode),
        2 * (EXPECTED_TOKENS.len() - 1)
    );
}

#[test]
fn pp4_matches_jax_reference() {
    let (tokens, _) = generate(ParallelLayout::new(1, 4), EXPECTED_TOKENS.len());
    assert_eq!(tokens, EXPECTED_TOKENS, "PP=4 staged inference");
}

#[test]
fn hybrid_tp2_pp2_matches_jax_reference() {
    let (tokens, engine) = generate(ParallelLayout::new(2, 2), EXPECTED_TOKENS.len());
    assert_eq!(tokens, EXPECTED_TOKENS, "hybrid TP=2 PP=2 inference");
    let summary = engine.trace().summary();
    // Hybrid adds stage-entry AllGathers (2 per step on stage-1 ranks).
    assert_eq!(summary.paper_view(CollectiveKind::AllGather, Stage::Prefill).count, 2);
    assert_eq!(
        summary.paper_view(CollectiveKind::AllGather, Stage::Decode).count,
        2 * (EXPECTED_TOKENS.len() - 1)
    );
    // p2p carries the TP-local slice [S, h/2].
    let shapes = summary.shapes(CollectiveKind::Send, Stage::Prefill);
    assert_eq!(shapes, vec![vec![32, ModelArch::tiny().hidden / 2]]);
}

#[test]
fn fused_engine_matches_segment_engine() {
    // The fused whole-model graphs (one dispatch per step) must produce
    // the same tokens as the segment-loop engine — the L2 §Perf fast path
    // is semantics-preserving.
    use commsim::engine::fused::FusedEngine;
    let store = store();
    let prompt = pinned_prompt(store.meta.prefill_len, store.meta.vocab);
    let mut fused = FusedEngine::new(store).expect("fused engine");
    let r = fused.generate(&prompt, EXPECTED_TOKENS.len()).expect("generate");
    assert_eq!(r.tokens, EXPECTED_TOKENS);
    // And again (KV reset path).
    let r2 = fused.generate(&prompt, 6).expect("generate");
    assert_eq!(r2.tokens, &EXPECTED_TOKENS[..6]);
}

#[test]
fn repeated_requests_reset_kv_state() {
    let store = store();
    let prompt = pinned_prompt(store.meta.prefill_len, store.meta.vocab);
    let mut engine =
        Engine::new(EngineConfig::numeric(store, ParallelLayout::new(2, 1))).unwrap();
    let a = engine.generate(&prompt, 6).unwrap();
    let b = engine.generate(&prompt, 6).unwrap();
    assert_eq!(a.tokens, b.tokens, "KV reset isolates requests");
    assert_eq!(a.tokens, &EXPECTED_TOKENS[..6]);
}

#[test]
fn numeric_mode_validates_prompt_length() {
    let store = store();
    let mut engine =
        Engine::new(EngineConfig::numeric(store, ParallelLayout::new(1, 1))).unwrap();
    assert!(engine.generate(&[1, 2, 3], 4).is_err(), "wrong prompt length");
}
