//! End-to-end numeric integration: the Rust engine executing the AOT tiny
//! model via PJRT must produce the *same greedy token sequence* under every
//! parallel layout — and that sequence must match the JAX reference
//! (`python/compile/model.py::full_step`, pinned below).
//!
//! This is the proof that the three layers compose: Pallas kernels (L1)
//! lowered inside the JAX segments (L2), AOT'd to HLO, executed by PJRT
//! from the Rust coordinator (L3) with *real* AllReduce/AllGather/Gather/
//! Send/Recv between workers — all assembled through the deployment-plan
//! facade.
//!
//! Requires `make artifacts`; every test self-skips (with a note on
//! stderr) when the artifacts have not been built, so the suite stays
//! green on machines without the JAX build path.

use commsim::analysis::{InferenceShape, OpCountModel, ParallelLayout};
use commsim::comm::{CollectiveKind, Stage};
use commsim::engine::Engine;
use commsim::model::ModelArch;
use commsim::plan::Deployment;
use commsim::runtime::ArtifactStore;

/// Greedy continuation of the pinned prompt computed by the JAX reference
/// (see python/tests/test_numeric_pin.py, same constants).
const EXPECTED_TOKENS: [i32; 12] = [95, 497, 497, 497, 109, 379, 109, 291, 497, 497, 109, 269];

fn pinned_prompt(len: usize, vocab: usize) -> Vec<i32> {
    (0..len).map(|i| ((7 * i) % vocab) as i32).collect()
}

/// The artifact store, or `None` (skip) when `make artifacts` has not run.
/// Only a genuinely absent store skips — artifacts that exist but fail to
/// load (truncated meta, interrupted build) still fail the test loudly.
fn store() -> Option<ArtifactStore> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !ArtifactStore::present(dir) {
        eprintln!(
            "skipping numeric integration test: no artifacts at {dir} (run `make artifacts`)"
        );
        return None;
    }
    Some(ArtifactStore::open(dir).expect("artifacts present but unreadable — rebuild them"))
}

fn numeric_engine(store: ArtifactStore, tp: usize, pp: usize) -> Engine {
    Deployment::builder()
        .artifacts(store)
        .tp(tp)
        .pp(pp)
        .build()
        .expect("numeric plan")
        .engine()
        .expect("engine")
}

fn generate(store: ArtifactStore, tp: usize, pp: usize, decode_len: usize) -> (Vec<i32>, Engine) {
    let prompt = pinned_prompt(store.meta.prefill_len, store.meta.vocab);
    let mut engine = numeric_engine(store, tp, pp);
    let result = engine.generate(&prompt, decode_len).expect("generate");
    (result.tokens, engine)
}

#[test]
fn tp1_matches_jax_reference() {
    let Some(store) = store() else { return };
    let (tokens, _) = generate(store, 1, 1, EXPECTED_TOKENS.len());
    assert_eq!(tokens, EXPECTED_TOKENS, "single-worker segment composition");
}

#[test]
fn tp2_matches_jax_reference_with_real_allreduce() {
    let Some(store) = store() else { return };
    let prefill_len = store.meta.prefill_len;
    let (tokens, engine) = generate(store, 2, 1, EXPECTED_TOKENS.len());
    assert_eq!(tokens, EXPECTED_TOKENS, "TP=2 sharded inference");
    // And the communication stream matches the analytical model exactly.
    let summary = engine.trace().summary();
    let model = OpCountModel::new(
        ModelArch::tiny(),
        ParallelLayout::new(2, 1),
        InferenceShape::new(prefill_len, EXPECTED_TOKENS.len(), 4),
    );
    for stage in [Stage::Prefill, Stage::Decode] {
        let predicted = model.predict_paper_view(stage);
        for op in [CollectiveKind::AllReduce, CollectiveKind::Gather] {
            assert_eq!(
                summary.paper_view(op, stage).count,
                predicted.count(op),
                "{op:?} {stage:?}"
            );
        }
    }
}

#[test]
fn tp4_matches_jax_reference() {
    let Some(store) = store() else { return };
    let (tokens, _) = generate(store, 4, 1, EXPECTED_TOKENS.len());
    assert_eq!(tokens, EXPECTED_TOKENS, "TP=4 sharded inference");
}

#[test]
fn pp2_matches_jax_reference_with_real_p2p() {
    let Some(store) = store() else { return };
    let (tokens, engine) = generate(store, 1, 2, EXPECTED_TOKENS.len());
    assert_eq!(tokens, EXPECTED_TOKENS, "PP=2 staged inference");
    let summary = engine.trace().summary();
    // (p-1) * 2 tensors * steps: prefill 1 step, decode len-1 steps.
    assert_eq!(summary.global_count(CollectiveKind::Send, Stage::Prefill), 2);
    assert_eq!(
        summary.global_count(CollectiveKind::Send, Stage::Decode),
        2 * (EXPECTED_TOKENS.len() - 1)
    );
}

#[test]
fn pp4_matches_jax_reference() {
    let Some(store) = store() else { return };
    let (tokens, _) = generate(store, 1, 4, EXPECTED_TOKENS.len());
    assert_eq!(tokens, EXPECTED_TOKENS, "PP=4 staged inference");
}

#[test]
fn hybrid_tp2_pp2_matches_jax_reference() {
    let Some(store) = store() else { return };
    let prefill_len = store.meta.prefill_len;
    let (tokens, engine) = generate(store, 2, 2, EXPECTED_TOKENS.len());
    assert_eq!(tokens, EXPECTED_TOKENS, "hybrid TP=2 PP=2 inference");
    let summary = engine.trace().summary();
    // Hybrid adds stage-entry AllGathers (2 per step on stage-1 ranks).
    assert_eq!(summary.paper_view(CollectiveKind::AllGather, Stage::Prefill).count, 2);
    assert_eq!(
        summary.paper_view(CollectiveKind::AllGather, Stage::Decode).count,
        2 * (EXPECTED_TOKENS.len() - 1)
    );
    // p2p carries the TP-local slice [S, h/2].
    let shapes = summary.shapes(CollectiveKind::Send, Stage::Prefill);
    assert_eq!(shapes, vec![vec![prefill_len, ModelArch::tiny().hidden / 2]]);
}

#[test]
fn fused_engine_matches_segment_engine() {
    // The fused whole-model graphs (one dispatch per step) must produce
    // the same tokens as the segment-loop engine — the L2 §Perf fast path
    // is semantics-preserving.
    use commsim::engine::fused::FusedEngine;
    let Some(store) = store() else { return };
    let prompt = pinned_prompt(store.meta.prefill_len, store.meta.vocab);
    let mut fused = FusedEngine::new(store).expect("fused engine");
    let r = fused.generate(&prompt, EXPECTED_TOKENS.len()).expect("generate");
    assert_eq!(r.tokens, EXPECTED_TOKENS);
    // And again (KV reset path).
    let r2 = fused.generate(&prompt, 6).expect("generate");
    assert_eq!(r2.tokens, &EXPECTED_TOKENS[..6]);
}

#[test]
fn repeated_requests_reset_kv_state() {
    let Some(store) = store() else { return };
    let prompt = pinned_prompt(store.meta.prefill_len, store.meta.vocab);
    let mut engine = numeric_engine(store, 2, 1);
    let a = engine.generate(&prompt, 6).unwrap();
    let b = engine.generate(&prompt, 6).unwrap();
    assert_eq!(a.tokens, b.tokens, "KV reset isolates requests");
    assert_eq!(a.tokens, &EXPECTED_TOKENS[..6]);
}

#[test]
fn numeric_mode_validates_prompt_length() {
    let Some(store) = store() else { return };
    let mut engine = numeric_engine(store, 1, 1);
    assert!(engine.generate(&[1, 2, 3], 4).is_err(), "wrong prompt length");
}
