//! Fleet-simulator integration: router-policy properties, bitwise
//! equivalence of a 1-replica fleet with the serving loop, and
//! disaggregated KV-handoff accounting against `analysis::disagg`.

use commsim::analysis::{DisaggregationModel, InferenceShape, ParallelLayout};
use commsim::fleet::{FleetSpec, FleetSummary, RouterPolicy};
use commsim::plan::{Deployment, DeploymentPlan};
use commsim::server::{Request, SchedulerConfig};
use commsim::workload::{ArrivalProcess, LengthDist, WorkloadSpec};

fn tiny(tp: usize, pp: usize) -> DeploymentPlan {
    Deployment::builder().model("tiny").tp(tp).pp(pp).workload(8, 4).build().unwrap()
}

fn fixed_workload(requests: usize, rate: f64, prompt: usize, decode: usize) -> WorkloadSpec {
    WorkloadSpec {
        arrivals: ArrivalProcess::poisson(rate),
        prompt: LengthDist::Fixed(prompt),
        decode: LengthDist::Fixed(decode),
        prefix: None,
        requests,
    }
}

/// (a) Every router policy is a pure function of (spec, workload, seed):
/// two runs agree bitwise per request, and a different seed diverges.
#[test]
fn every_policy_is_deterministic_per_seed() {
    let workload = WorkloadSpec {
        arrivals: ArrivalProcess::bursty(500.0, 4),
        prompt: LengthDist::LongTail { short: 8, long: 32, long_weight: 0.3 },
        decode: LengthDist::Uniform { lo: 2, hi: 6 },
        prefix: None,
        requests: 24,
    };
    for policy in [
        RouterPolicy::RoundRobin,
        RouterPolicy::LeastOutstandingTokens,
        RouterPolicy::ShortestQueue,
        RouterPolicy::CacheAffinity,
    ] {
        let run = |seed: u64| -> FleetSummary {
            tiny(2, 1)
                .fleet(2)
                .unwrap()
                .with_router(policy)
                .simulate(&workload, seed)
                .unwrap()
        };
        let a = run(11);
        let b = run(11);
        assert_eq!(a.model, b.model, "{policy:?}: same seed, same model summary");
        assert_eq!(a.per_request.len(), b.per_request.len());
        for (x, y) in a.per_request.iter().zip(b.per_request.iter()) {
            assert_eq!(x.request_id, y.request_id, "{policy:?}: completion order");
            assert_eq!(x.replica, y.replica, "{policy:?}: routing decisions");
            assert_eq!(x.model, y.model, "{policy:?}: per-request model times");
        }
        assert_eq!(a.completed, 24, "{policy:?} serves everything");
        let c = run(12);
        assert_ne!(a.model, c.model, "{policy:?}: different seed, different arrivals");
    }
}

/// (b) For uniform traffic on identical replicas, least-outstanding-tokens
/// never exceeds round-robin on the worst per-replica queue depth: the
/// load-aware policy can only balance better than the oblivious one.
#[test]
fn least_tokens_never_exceeds_round_robin_max_depth_on_uniform_traffic() {
    let workload = fixed_workload(48, 200.0, 8, 4);
    for seed in [1u64, 2, 3, 0xC0FFEE] {
        let max_depth = |policy: RouterPolicy| -> usize {
            let s = tiny(1, 1)
                .fleet(3)
                .unwrap()
                .with_router(policy)
                .simulate(&workload, seed)
                .unwrap();
            assert_eq!(s.completed, 48, "{policy:?} seed={seed}");
            s.replicas.iter().map(|r| r.max_depth).max().unwrap()
        };
        let rr = max_depth(RouterPolicy::RoundRobin);
        let lot = max_depth(RouterPolicy::LeastOutstandingTokens);
        assert!(
            lot <= rr,
            "seed={seed}: least-tokens max depth {lot} > round-robin {rr}"
        );
    }
}

/// (c) A colocated 1-replica fleet is the serving loop: it reproduces
/// `serve_poisson`'s model-time metrics bitwise — per request and in
/// aggregate — for the same scheduler config, arrival rate, and seed.
#[test]
fn single_replica_fleet_reproduces_serve_poisson_bitwise() {
    let plan = Deployment::builder().model("tiny").tp(2).workload(8, 6).build().unwrap();
    let cfg = SchedulerConfig { kv_blocks: 64, kv_block_size: 16, max_queue: 64, max_batch: 2 };
    let (rate, seed, n) = (2000.0, 42u64, 8usize);

    let mut server = plan.server(cfg).unwrap();
    let reqs: Vec<Request> = (0..n as u64)
        .map(|id| Request { id, prompt: vec![0; 8].into(), decode_len: 6 })
        .collect();
    let served = server.serve_poisson(reqs, rate, seed).unwrap();
    assert_eq!(served.completed, n);

    let fleet = plan
        .fleet(1)
        .unwrap()
        .with_scheduler(cfg)
        .simulate(&fixed_workload(n, rate, 8, 6), seed)
        .unwrap();
    assert_eq!(fleet.completed, n);

    // Aggregate: the model-time summary is bitwise identical.
    assert_eq!(fleet.model, served.model.expect("structural serving is priced"));

    // Per request: same completion order, same model clocks, bit for bit.
    let server_order: Vec<u64> = server.completed().iter().map(|m| m.request_id).collect();
    let fleet_order: Vec<u64> = fleet.per_request.iter().map(|m| m.request_id).collect();
    assert_eq!(server_order, fleet_order, "completion order matches");
    for (s, f) in server.completed().iter().zip(fleet.per_request.iter()) {
        assert_eq!(s.generated_tokens, f.generated_tokens);
        assert_eq!(s.model, f.model, "request {}", s.request_id);
    }
}

/// The hot path at scale: 100k requests through a 4-replica fleet, run
/// twice on one seed, must agree bitwise on everything — the replica-clock
/// index, the scratch-buffer routing, and summary-only trace folding are
/// pure optimizations, not approximations. Decode length 1 keeps each
/// request prefill-only so the debug-profile run stays fast while the DES
/// still churns through every arrival/advance/route decision.
#[test]
fn hundred_thousand_request_double_run_is_bitwise_identical() {
    let cfg = SchedulerConfig { max_queue: 100_000, ..SchedulerConfig::default() };
    let workload = fixed_workload(100_000, 20_000.0, 8, 1);
    let run = || -> FleetSummary {
        tiny(1, 1)
            .fleet(4)
            .unwrap()
            .with_scheduler(cfg)
            .with_router(RouterPolicy::LeastOutstandingTokens)
            .simulate(&workload, 0xBEEF)
            .unwrap()
    };
    let a = run();
    assert_eq!(a.completed, 100_000, "the fleet serves the whole trace");
    assert_eq!(a.failed, 0);
    let b = run();
    // Debug formatting renders every f64 exactly, so string equality over
    // the full summary (aggregate percentiles + 100k per-request records)
    // is a bitwise check.
    assert_eq!(format!("{a:?}"), format!("{b:?}"), "double run diverged");
}

/// KV-handoff accounting: every disaggregated request ships exactly the
/// bytes `DisaggregationModel::volume` predicts, and the wire pricing
/// follows the fleet's node grid (same node -> NVLink, across -> IB).
#[test]
fn disagg_kv_handoff_matches_disaggregation_model_and_link_class() {
    let prefill = tiny(2, 1);
    let decode = tiny(1, 2);
    let expect = DisaggregationModel::new(
        prefill.arch().clone(),
        ParallelLayout::new(2, 1),
        ParallelLayout::new(1, 2),
    )
    .volume(InferenceShape::new(8, 4, 2))
    .kv_transfer;

    let workload = fixed_workload(6, 1000.0, 8, 4);
    // Both 2-GPU pools fit one 4-GPU node: NVLink handoff.
    let nvlink = FleetSpec::disaggregated(&prefill, 1, &decode, 1)
        .unwrap()
        .simulate(&workload, 5)
        .unwrap();
    assert_eq!(nvlink.completed, 6);
    for m in &nvlink.per_request {
        assert_eq!(m.kv_transfer_bytes, expect, "request {}", m.request_id);
        assert!(m.kv_transfer_s > 0.0);
    }
    assert_eq!(nvlink.kv_transfer_bytes, expect * 6.0);

    // On 2-GPU nodes the pools land on different nodes: the same bytes
    // ride InfiniBand and the handoff gets strictly slower.
    let ib = FleetSpec::disaggregated(&prefill, 1, &decode, 1)
        .unwrap()
        .with_gpus_per_node(2)
        .unwrap()
        .simulate(&workload, 5)
        .unwrap();
    assert_eq!(ib.kv_transfer_bytes, nvlink.kv_transfer_bytes, "same bytes either way");
    assert!(
        ib.kv_transfer_s > nvlink.kv_transfer_s,
        "cross-node handoff ({}s) must outprice intra-node ({}s)",
        ib.kv_transfer_s,
        nvlink.kv_transfer_s
    );
}

/// The simulated disaggregation break-even (smallest decode length at
/// which the disaggregated fleet's total comm undercuts the colocated
/// one) agrees with the analytical `break_even_decode_len` within one
/// decode step. (The sim's decode pool generates Sd-1 tokens — the first
/// comes out of the prefill pool — so the crossing may land one step
/// early; never more.)
#[test]
fn simulated_break_even_matches_analytic_within_one_decode_step() {
    let sp = 128usize;
    let colo_plan = |sd: usize| {
        Deployment::builder().model("8b").tp(4).workload(sp, sd).build().unwrap()
    };
    let model = DisaggregationModel::new(
        colo_plan(1).arch().clone(),
        ParallelLayout::new(4, 1),
        ParallelLayout::new(1, 4),
    );
    let be = model
        .break_even_decode_len(ParallelLayout::new(4, 1), sp, 2, 4096)
        .expect("break-even exists for colocated TP");

    let comm = |sd: usize, disagg: bool| -> f64 {
        let workload = fixed_workload(1, 1000.0, sp, sd);
        let summary = if disagg {
            let prefill =
                Deployment::builder().model("8b").tp(4).workload(sp, sd).build().unwrap();
            let decode =
                Deployment::builder().model("8b").pp(4).workload(sp, sd).build().unwrap();
            FleetSpec::disaggregated(&prefill, 1, &decode, 1)
                .unwrap()
                .simulate(&workload, 9)
                .unwrap()
        } else {
            colo_plan(sd).fleet(1).unwrap().simulate(&workload, 9).unwrap()
        };
        assert_eq!(summary.completed, 1);
        summary.comm_bytes
    };

    let lo = be.saturating_sub(2).max(1);
    let hi = be + 2;
    let mut crossing = None;
    for sd in lo..=hi {
        if comm(sd, true) < comm(sd, false) {
            crossing = Some(sd);
            break;
        }
    }
    let crossing = crossing.unwrap_or_else(|| {
        panic!("no simulated break-even in {lo}..={hi} (analytic {be})")
    });
    assert!(
        crossing.abs_diff(be) <= 1,
        "simulated break-even {crossing} vs analytic {be}"
    );
}
