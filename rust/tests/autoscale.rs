//! Elastic-fleet integration — the migration contract's acceptance
//! suite. Live KV migration claims the moved sequence is *transparent*:
//! the remaining decode stream is bitwise-identical to the unmigrated
//! run (same tokens, same priced per-step latencies), and the shipped
//! bytes reconcile exactly with `(Sp + g − 1) · kv_bytes_per_token` at
//! the migration tick. Both halves are checked here: once at the
//! session level (the mechanism), once through the fleet DES (the
//! accounting).

use commsim::autoscale::AutoscalePolicy;
use commsim::engine::{SequenceInput, StepKind};
use commsim::fleet::RouterPolicy;
use commsim::plan::Deployment;
use commsim::workload::{ArrivalProcess, LengthDist, WorkloadSpec};

/// (a) Session-level replay of `migrate_out`'s contract: cut a sequence
/// after `g` tokens, restore it on a fresh engine as a 1-token prompt
/// (the last sampled token) over `Sp + g − 1` cached-KV tokens, and the
/// rest of the run is bitwise-identical to never migrating — token
/// values agree, and every post-intake decode step prices to the exact
/// same `model_latency_s` bits, because the restored decode positions
/// (hence per-iteration KV lengths) continue the original sequence
/// exactly. Only the intake prefill (the migration's priced cost) is
/// new.
#[test]
fn migrated_sequence_decode_stream_is_bitwise_identical() {
    const SP: usize = 8;
    const SD: usize = 12;
    let plan =
        Deployment::builder().model("tiny").tp(2).pp(1).workload(SP, SD).build().unwrap();

    // Reference: one unmigrated sequence; record every token and the
    // priced latency of the step that emitted it.
    let mut ref_engine = plan.engine().unwrap();
    let mut reference = ref_engine.session();
    reference
        .admit(SequenceInput { id: 7, prompt: vec![0; SP].into(), start: 0, max_new_tokens: SD })
        .unwrap();
    let mut ref_tokens: Vec<i32> = Vec::new();
    let mut ref_price: Vec<f64> = Vec::new();
    while !reference.is_idle() {
        let out = reference.step().unwrap();
        let price = out.model_latency_s.expect("structural plan engines are priced");
        assert!(price > 0.0, "every iteration costs model time");
        for ev in &out.events {
            ref_tokens.push(ev.token);
            ref_price.push(price);
        }
    }
    drop(reference);
    assert_eq!(ref_tokens.len(), SD, "prefill token + Sd - 1 decode tokens");

    for cut in [1usize, SD / 2, SD - 1] {
        // Source replica: prefill + (cut − 1) decode iterations, i.e.
        // exactly `cut` tokens out, then the sequence leaves.
        let mut src_engine = plan.engine().unwrap();
        let mut source = src_engine.session();
        source
            .admit(SequenceInput {
                id: 7,
                prompt: vec![0; SP].into(),
                start: 0,
                max_new_tokens: SD,
            })
            .unwrap();
        let mut tokens: Vec<i32> = Vec::new();
        let mut prices: Vec<f64> = Vec::new();
        while tokens.len() < cut {
            let out = source.step().unwrap();
            let price = out.model_latency_s.unwrap();
            for ev in &out.events {
                tokens.push(ev.token);
                prices.push(price);
            }
        }
        assert_eq!(&tokens[..], &ref_tokens[..cut], "pre-cut stream matches (cut={cut})");
        // What `migrate_out` ships: the last sampled token plus the
        // resident context `Sp + g − 1` (everything already written to
        // the source KV cache except the token about to be decoded).
        let last = *tokens.last().unwrap();
        let context = SP + cut - 1;
        drop(source);

        // Target replica: cached-context intake, remaining budget.
        let mut dst_engine = plan.engine().unwrap();
        let mut target = dst_engine.session();
        target
            .admit_with_context(
                SequenceInput {
                    id: 7,
                    prompt: vec![last].into(),
                    start: 0,
                    max_new_tokens: SD - cut,
                },
                context,
            )
            .unwrap();
        let mut intake_price = None;
        while !target.is_idle() {
            let out = target.step().unwrap();
            let price = out.model_latency_s.unwrap();
            if out.kind == StepKind::Prefill {
                intake_price = Some(price);
            }
            for ev in &out.events {
                tokens.push(ev.token);
                prices.push(price);
            }
        }
        assert_eq!(tokens, ref_tokens, "full stream matches after restore (cut={cut})");
        // The intake prefill is the migration's cost — present, priced,
        // and excluded from the identity below.
        let intake = intake_price.expect("restore runs an intake prefill");
        assert!(intake > 0.0, "the migration intake is never free");
        // Every decode step after the intake reprices to the exact
        // same bits as the unmigrated run.
        for i in (cut + 1)..SD {
            assert_eq!(
                prices[i].to_bits(),
                ref_price[i].to_bits(),
                "decode step for token {i} reprices bitwise (cut={cut})"
            );
        }
    }
}

/// (b) Fleet-level accounting under forced migration. A 2-replica
/// colocated fleet with scale-up unreachable (queue target 1e9) and
/// scale-down blocked (min == max) leaves Migrate as the only possible
/// decision; `migrate_queue_gap = 1` arms it on the standing
/// round-robin imbalance (9 requests over 2 replicas). The 3B model at
/// TP1/PP1 makes every prefill cost hundreds of model-milliseconds
/// against a ~10 ms tick interval, so ticks land mid-flight and
/// migrations must fire. Checked: bytes ship once per migrated request
/// at a whole-token multiple of `kv_bytes_per_token` inside
/// `[Sp, Sp + Sd − 2]`, land in the migration counters (per-request and
/// fleet) and never in the disaggregation handoff counters, no request
/// is lost, and the elastic DES stays a pure function of the seed.
#[test]
fn forced_migration_bytes_reconcile_with_kv_per_token() {
    const SP: usize = 8;
    const SD: usize = 32;
    let plan = Deployment::builder().model("3b").tp(1).pp(1).workload(SP, SD).build().unwrap();
    let kv = plan.arch().kv_bytes_per_token(plan.shape().dtype_bytes);
    let mut policy = AutoscalePolicy::target_queue(2, 2, 1e9, 0.04);
    policy.migrate_queue_gap = 1;
    policy.validate().unwrap();
    let workload = WorkloadSpec {
        arrivals: ArrivalProcess::poisson(2000.0),
        prompt: LengthDist::Fixed(SP),
        decode: LengthDist::Fixed(SD),
        prefix: None,
        requests: 9,
    };
    let run = || {
        plan.fleet(2)
            .unwrap()
            .with_router(RouterPolicy::RoundRobin)
            .with_autoscale(policy.clone())
            .unwrap()
            .simulate(&workload, 0xE1A5)
            .unwrap()
    };
    let s = run();
    assert_eq!(s.completed, 9, "migration never loses a request");
    assert_eq!(s.failed, 0);
    assert!(s.migrations >= 1, "forced-gap policy must migrate");
    assert!(s.kv_migration_bytes > 0.0, "migrated KV is accounted");
    assert!(s.kv_migration_s > 0.0, "migrated KV pays wire time");
    assert_eq!(s.kv_transfer_bytes, 0.0, "colocated fleet: no disagg handoff bytes");
    assert_eq!(s.kv_transfer_s, 0.0, "colocated fleet: no disagg handoff time");
    assert_eq!(s.cold_starts, 0, "scale-up was unreachable");

    // Per-request reconciliation: migration bytes ride the request's
    // kv_transfer_bytes channel, exactly once per migrated request, at
    // `(Sp + g − 1) · kv_bytes_per_token` for a cut g in [1, Sd − 1].
    let shipped: f64 = s.per_request.iter().map(|r| r.kv_transfer_bytes).sum();
    assert_eq!(shipped, s.kv_migration_bytes, "per-request bytes sum to the fleet counter");
    let migrated: Vec<_> =
        s.per_request.iter().filter(|r| r.kv_transfer_bytes > 0.0).collect();
    assert_eq!(migrated.len(), s.migrations, "one shipment per migrated request");
    for r in &migrated {
        let tokens = r.kv_transfer_bytes / kv as f64;
        assert_eq!(tokens.fract(), 0.0, "whole KV tokens ship (request {})", r.request_id);
        let t = tokens as usize;
        assert!(
            (SP..=SP + SD - 2).contains(&t),
            "request {} shipped {t} tokens outside [{SP}, {}]",
            r.request_id,
            SP + SD - 2
        );
        assert!(r.kv_transfer_s > 0.0, "request {} shipped for free", r.request_id);
    }

    // Same seed, same everything: elasticity does not break the DES's
    // determinism contract.
    let b = run();
    assert_eq!(s.model, b.model, "same seed, same model summary");
    assert_eq!(s.migrations, b.migrations);
    assert_eq!(s.kv_migration_bytes, b.kv_migration_bytes);
    assert_eq!(s.kv_migration_s, b.kv_migration_s);
}
