//! Structural-mode integration: paper-scale architectures through the full
//! engine (via the deployment-plan facade); every traced count, shape, and
//! corrected volume must equal both the analytical models (Eq. 1–7) and
//! the paper's published table values.

use commsim::analysis::{InferenceShape, OpCountModel, ParallelLayout, VolumeModel};
use commsim::comm::{CollectiveKind, Stage, TraceSummary};
use commsim::model::{ModelArch, DTYPE_BYTES_BF16};
use commsim::plan::Deployment;

fn run(arch: ModelArch, tp: usize, pp: usize, sp: usize, sd: usize) -> TraceSummary {
    Deployment::builder()
        .arch(arch)
        .tp(tp)
        .pp(pp)
        .workload(sp, sd)
        .build()
        .expect("feasible plan")
        .trace()
        .expect("structural trace")
}

/// Paper Table III — Llama-3.1-8B, Sp=Sd=128, TP∈{2,4}: counts AND shapes.
#[test]
fn table3_exact_reproduction() {
    for tp in [2usize, 4] {
        let s = run(ModelArch::llama31_8b(), tp, 1, 128, 128);
        let pre_ar = s.paper_view(CollectiveKind::AllReduce, Stage::Prefill);
        assert_eq!(pre_ar.count, 65, "tp={tp}");
        assert_eq!(
            s.shapes(CollectiveKind::AllReduce, Stage::Prefill),
            vec![vec![128, 4096]]
        );
        assert_eq!(s.paper_view(CollectiveKind::Gather, Stage::Prefill).count, 1);
        assert_eq!(
            s.shapes(CollectiveKind::Gather, Stage::Prefill),
            vec![vec![128_256 / tp]]
        );
        let dec_ar = s.paper_view(CollectiveKind::AllReduce, Stage::Decode);
        assert_eq!(dec_ar.count, 8255, "tp={tp}");
        assert_eq!(
            s.shapes(CollectiveKind::AllReduce, Stage::Decode),
            vec![vec![1, 4096]]
        );
        assert_eq!(s.paper_view(CollectiveKind::Gather, Stage::Decode).count, 127);
    }
}

/// Paper Table IV — AllReduce message sizes and counts across models.
#[test]
fn table4_exact_reproduction() {
    let cases = [
        (ModelArch::llama32_3b(), 786_432usize, 6_144usize, 57usize, 7_239usize),
        (ModelArch::llama31_8b(), 1_048_576, 8_192, 65, 8_255),
        (ModelArch::llama2_13b(), 1_310_720, 10_240, 81, 10_287),
    ];
    for (arch, pre_bytes, dec_bytes, pre_count, dec_count) in cases {
        let name = arch.name.clone();
        let s = run(arch, 4, 1, 128, 128);
        let pre = s.paper_view(CollectiveKind::AllReduce, Stage::Prefill);
        assert_eq!(pre.count, pre_count, "{name}");
        assert_eq!(pre.total_message_bytes / pre.count, pre_bytes, "{name}");
        let dec = s.paper_view(CollectiveKind::AllReduce, Stage::Decode);
        assert_eq!(dec.count, dec_count, "{name}");
        assert_eq!(dec.total_message_bytes / dec.count, dec_bytes, "{name}");
    }
}

/// Paper Table V — pipeline Send/Recv counts and shapes, PP∈{2,4}.
#[test]
fn table5_exact_reproduction() {
    for (pp, pre, dec) in [(2usize, 2usize, 254usize), (4, 6, 762)] {
        let s = run(ModelArch::llama31_8b(), 1, pp, 128, 128);
        assert_eq!(s.global_count(CollectiveKind::Send, Stage::Prefill), pre, "pp={pp}");
        assert_eq!(s.global_count(CollectiveKind::Recv, Stage::Prefill), pre);
        assert_eq!(s.global_count(CollectiveKind::Send, Stage::Decode), dec);
        assert_eq!(s.global_count(CollectiveKind::Recv, Stage::Decode), dec);
        assert_eq!(
            s.shapes(CollectiveKind::Send, Stage::Prefill),
            vec![vec![128, 4096]]
        );
        assert_eq!(s.shapes(CollectiveKind::Send, Stage::Decode), vec![vec![1, 4096]]);
    }
}

/// Paper Table VI — hybrid TP=2 PP=2 full breakdown.
#[test]
fn table6_exact_reproduction() {
    let s = run(ModelArch::llama31_8b(), 2, 2, 128, 128);
    // Prefill
    assert_eq!(s.paper_view(CollectiveKind::AllReduce, Stage::Prefill).count, 33);
    assert_eq!(s.paper_view(CollectiveKind::Gather, Stage::Prefill).count, 1);
    assert_eq!(
        s.shapes(CollectiveKind::Gather, Stage::Prefill),
        vec![vec![64_128]]
    );
    assert_eq!(s.paper_view(CollectiveKind::AllGather, Stage::Prefill).count, 2);
    assert_eq!(
        s.shapes(CollectiveKind::AllGather, Stage::Prefill),
        vec![vec![128, 4096]]
    );
    assert_eq!(s.paper_view(CollectiveKind::Send, Stage::Prefill).count, 2);
    assert_eq!(
        s.shapes(CollectiveKind::Send, Stage::Prefill),
        vec![vec![128, 2048]]
    );
    // Decode
    assert_eq!(s.paper_view(CollectiveKind::AllReduce, Stage::Decode).count, 4191);
    assert_eq!(s.paper_view(CollectiveKind::Gather, Stage::Decode).count, 127);
    assert_eq!(s.paper_view(CollectiveKind::AllGather, Stage::Decode).count, 254);
    assert_eq!(s.paper_view(CollectiveKind::Send, Stage::Decode).count, 254);
    assert_eq!(s.shapes(CollectiveKind::Send, Stage::Decode), vec![vec![1, 2048]]);
}

/// The traced corrected volume of one rank's stream integrates to Eq. 1
/// (per-worker NCCL accounting).
#[test]
fn traced_volume_matches_eq1() {
    let arch = ModelArch::llama32_3b();
    let shape = InferenceShape::new(128, 128, DTYPE_BYTES_BF16);
    let s = run(arch.clone(), 4, 1, 128, 128);
    // Sum one rank's corrected bytes (rank 1: non-driver, like the paper).
    let measured: f64 = s.per_rank[1].values().map(|v| v.corrected_volume_bytes).sum();
    let eq1 = VolumeModel::new(arch).tensor_parallel(4, shape).total();
    let rel = (measured - eq1).abs() / eq1;
    assert!(rel < 1e-12, "measured {measured}, Eq.1 {eq1}");
}

/// Pipeline: Send records only (each transfer once) integrate to Eq. 2.
#[test]
fn traced_volume_matches_eq2() {
    let arch = ModelArch::llama31_8b();
    let shape = InferenceShape::new(128, 128, DTYPE_BYTES_BF16);
    let s = run(arch.clone(), 1, 4, 128, 128);
    let measured = s.corrected_volume(CollectiveKind::Send);
    let eq2 = VolumeModel::new(arch).pipeline_parallel(4, shape).total();
    assert!((measured - eq2).abs() / eq2 < 1e-12);
}

/// Hybrid: full per-class decomposition matches Eq. 4–7.
#[test]
fn traced_volume_matches_eq4_to_7() {
    let arch = ModelArch::llama31_8b();
    let layout = ParallelLayout::new(2, 2);
    let shape = InferenceShape::new(128, 128, DTYPE_BYTES_BF16);
    let s = run(arch.clone(), 2, 2, 128, 128);
    let v = VolumeModel::new(arch).hybrid(layout, shape);
    // AllReduce: Eq. 4 is per TP-group-member-stream accounting — a
    // first-stage rank observes 2L/p layer AllReduces + 1 embedding
    // AllReduce per step ("additional embedding contribution", §III.C).
    let ar_measured: f64 = s.per_rank[0]
        .iter()
        .filter(|(k, _)| k.op == CollectiveKind::AllReduce)
        .map(|(_, v)| v.corrected_volume_bytes)
        .sum();
    let rel = (ar_measured - v.allreduce).abs() / v.allreduce;
    assert!(rel < 1e-12, "AR measured {ar_measured} vs Eq.4 {}", v.allreduce);

    // AllGather: one member per stage observes the stage's 2 gathers; the
    // formula counts (p-1) boundaries once.
    let ag_measured: f64 = s.per_rank[2]
        .iter()
        .filter(|(k, _)| k.op == CollectiveKind::AllGather)
        .map(|(_, v)| v.corrected_volume_bytes)
        .sum();
    assert!((ag_measured - v.allgather).abs() / v.allgather < 1e-12);

    // P2P: Eq. 7 is per-rank-pair accounting ([S, h/t] slices — Table VI);
    // rank 0's Send stream is exactly one pair's traffic across the single
    // boundary of p=2.
    let p2p_measured: f64 = s.per_rank[0]
        .iter()
        .filter(|(k, _)| k.op == CollectiveKind::Send)
        .map(|(_, v)| v.corrected_volume_bytes)
        .sum();
    assert!((p2p_measured - v.p2p).abs() / v.p2p < 1e-12);

    // Gather: Eq. 6.
    let g_measured: f64 = s.per_rank[2]
        .iter()
        .filter(|(k, _)| k.op == CollectiveKind::Gather)
        .map(|(_, v)| v.corrected_volume_bytes)
        .sum();
    assert!((g_measured - v.gather).abs() / v.gather < 1e-12);
}

/// Fig. 7's decode-length scaling measured end-to-end through the engine.
#[test]
fn decode_scaling_growth_factors_measured() {
    let arch = ModelArch::llama32_3b();
    let vol = |sd: usize| {
        let s = run(arch.clone(), 1, 4, 128, sd);
        s.corrected_volume(CollectiveKind::Send)
    };
    let v128 = vol(128);
    let v256 = vol(256);
    let v512 = vol(512);
    assert!((v256 / v128 - 383.0 / 255.0).abs() < 1e-9);
    assert!((v512 / v256 - 639.0 / 383.0).abs() < 1e-9);
}

/// Analytical op model agrees with the engine for every supported layout of
/// a 4-GPU box (exhaustive sweep, tiny arch for speed).
#[test]
fn op_model_engine_agreement_sweep() {
    let arch = ModelArch::tiny();
    for (tp, pp) in [(1, 1), (2, 1), (4, 1), (1, 2), (1, 4), (2, 2)] {
        let sp = 16;
        let sd = 6;
        let s = run(arch.clone(), tp, pp, sp, sd);
        let m = OpCountModel::new(
            arch.clone(),
            ParallelLayout::new(tp, pp),
            InferenceShape::new(sp, sd, DTYPE_BYTES_BF16),
        );
        for stage in [Stage::Prefill, Stage::Decode] {
            let predicted = m.predict_paper_view(stage);
            for op in [
                CollectiveKind::AllReduce,
                CollectiveKind::AllGather,
                CollectiveKind::Gather,
                CollectiveKind::Send,
                CollectiveKind::Recv,
            ] {
                assert_eq!(
                    s.paper_view(op, stage).count,
                    predicted.count(op),
                    "tp={tp} pp={pp} {op:?} {stage:?}"
                );
            }
        }
    }
}
