//! Fault-injection integration: scripted outages drive the retry path
//! end-to-end (re-route through the router, cache-warmth loss, wasted
//! first-attempt prefill priced against the cost model), degradation
//! windows slow the fleet wire, and churn stays bitwise-deterministic
//! per seed.
//!
//! Outage instants are *self-calibrated*: each test first runs the same
//! fleet healthy, reads the model-clock times of the requests it wants
//! to disturb, and places the outage relative to them. The simulation is
//! bitwise-deterministic and identical to the healthy run up to the
//! first fault event, so the calibrated instant lands exactly where the
//! healthy run says it will.

use commsim::faults::FaultSpec;
use commsim::fleet::{FleetSpec, SloTarget};
use commsim::plan::{Deployment, DeploymentPlan};
use commsim::server::PrefixCacheConfig;
use commsim::workload::{ArrivalProcess, LengthDist, PrefixProfile, WorkloadSpec};

fn tiny(tp: usize, pp: usize) -> DeploymentPlan {
    Deployment::builder().model("tiny").tp(tp).pp(pp).workload(8, 4).build().unwrap()
}

fn fixed_workload(requests: usize, rate: f64, prompt: usize, decode: usize) -> WorkloadSpec {
    WorkloadSpec {
        arrivals: ArrivalProcess::poisson(rate),
        prompt: LengthDist::Fixed(prompt),
        decode: LengthDist::Fixed(decode),
        prefix: None,
        requests,
    }
}

/// A request killed mid-decode re-enters the router, lands on the
/// surviving replica, and completes — with the retry counted, the
/// first attempt's prefill priced as waste (reconciling with
/// `CostModel::prefill_price`), and an E2E that spans both attempts.
#[test]
fn killed_request_retries_on_surviving_replica_and_pays_wasted_prefill() {
    let plan = tiny(2, 1);
    let spec = FleetSpec::colocated(&plan, 2).unwrap();
    let wl = fixed_workload(1, 1000.0, 8, 4);

    let healthy = spec.clone().simulate(&wl, 42).unwrap();
    assert_eq!(healthy.completed, 1);
    let h = healthy.per_request[0].model.expect("healthy request is priced");
    assert_eq!(healthy.per_request[0].replica, 0, "lone request takes the first replica");

    // Place the outage mid-decode: after the first token, with at least
    // two decode steps still to run (decode_len = 4), so the fail event
    // lands at an iteration boundary while the flight is live.
    let arrival = h.finished_at_s - h.e2e_s;
    let first_token = arrival + h.queue_s + h.ttft_s;
    let t_fail = 0.5 * (first_token + h.finished_at_s);
    assert!(first_token < t_fail && t_fail < h.finished_at_s);

    let faulty = spec
        .with_faults(FaultSpec::none().with_outage(0, t_fail, 1.0))
        .unwrap()
        .simulate(&wl, 42)
        .unwrap();
    assert_eq!(faulty.completed, 1, "the retry serves the request");
    assert_eq!(faulty.failed, 0);
    let m = &faulty.per_request[0];
    assert_eq!(m.retries, 1, "one failure, one retry");
    assert_eq!(m.replica, 1, "re-routed to the surviving replica");
    // The dead replica had prefilled the whole (uncached) prompt: that
    // work is priced as waste, exactly at the cost model's rate.
    let cm = plan.cost_model();
    assert_eq!(m.wasted_prefill_s, cm.prefill_price(8), "wasted = priced first prefill");
    assert_eq!(faulty.retries, 1);
    assert_eq!(faulty.wasted_prefill_s, m.wasted_prefill_s);
    let f = m.model.expect("retried request still priced");
    assert!(
        f.e2e_s > h.e2e_s,
        "E2E spans both attempts: {} vs healthy {}",
        f.e2e_s,
        h.e2e_s
    );
    assert!(f.e2e_s > m.wasted_prefill_s, "the waste sits inside the E2E span");
}

/// An outage empties the replica's prefix cache: post-recovery requests
/// prefill the shared prefix again (more cold misses than the healthy
/// run), and goodput against a healthy-calibrated SLO strictly drops —
/// stranded requests ride out the downtime inside their E2E.
#[test]
fn outage_loses_prefix_warmth_and_strictly_cuts_goodput() {
    let wl = WorkloadSpec {
        arrivals: ArrivalProcess::poisson(2000.0),
        prompt: LengthDist::Fixed(24),
        decode: LengthDist::Fixed(4),
        prefix: Some(PrefixProfile::SystemPrompt { shared: 16 }),
        requests: 8,
    };
    let cache = PrefixCacheConfig { block_tokens: 8, capacity_bytes: 64 << 20 };
    let spec = FleetSpec::colocated(&tiny(2, 1), 1).unwrap().with_prefix_cache(cache).unwrap();

    let healthy = spec.clone().simulate(&wl, 3).unwrap();
    assert_eq!(healthy.completed, 8);
    let misses = |s: &commsim::fleet::FleetSummary| {
        s.per_request.iter().filter(|m| m.cached_prompt_tokens == 0).count()
    };
    assert_eq!(misses(&healthy), 1, "healthy: only the first request is cold");

    // Drop the replica strictly inside the completion span: the cold
    // first request's miss is already frozen in its record, and at
    // least one request still has to (re-)admit after recovery — on a
    // freshly emptied cache.
    let finishes: Vec<f64> =
        healthy.per_request.iter().map(|m| m.model.expect("priced").finished_at_s).collect();
    let first_done = finishes.iter().copied().fold(f64::INFINITY, f64::min);
    let last_done = finishes.iter().copied().fold(0.0f64, f64::max);
    assert!(first_done < last_done, "completions are staggered");
    let t_fail = 0.5 * (first_done + last_done);
    let down_s = 2.0 * healthy.model.makespan_s;

    let faulty = spec
        .with_faults(FaultSpec::none().with_outage(0, t_fail, down_s))
        .unwrap()
        .simulate(&wl, 3)
        .unwrap();
    assert_eq!(faulty.completed, 8, "everything still serves, post-recovery");
    assert_eq!(faulty.failed, 0);
    assert!(
        misses(&faulty) > misses(&healthy),
        "cold restart forces fresh prefix misses: {} vs {}",
        misses(&faulty),
        misses(&healthy)
    );
    // Goodput against the healthy run's own worst E2E: the healthy
    // fleet scores a perfect 1.0 by construction; under the outage,
    // stranded requests carry the downtime in their E2E and miss it.
    let worst_e2e = healthy
        .per_request
        .iter()
        .map(|m| m.model.unwrap().e2e_s)
        .fold(0.0f64, f64::max);
    let slo = SloTarget { e2e_p95_s: Some(worst_e2e), ..Default::default() };
    assert_eq!(healthy.goodput(&slo), 1.0);
    assert!(
        faulty.goodput(&slo) < healthy.goodput(&slo),
        "goodput under churn must drop: {} vs {}",
        faulty.goodput(&slo),
        healthy.goodput(&slo)
    );
}

/// Losing the decode pool mid-request wastes the prefill work (twice:
/// the shipped attempt and the blocked re-prefill), strands the request
/// until recovery, and still serves it — two retries, two KV shipments.
#[test]
fn decode_pool_outage_wastes_prefill_and_reships_kv() {
    let prefill = tiny(2, 1);
    let decode = tiny(1, 2);
    let spec = FleetSpec::disaggregated(&prefill, 1, &decode, 1).unwrap();
    let wl = fixed_workload(1, 1000.0, 8, 4);

    let healthy = spec.clone().simulate(&wl, 5).unwrap();
    assert_eq!(healthy.completed, 1);
    let h = healthy.per_request[0].model.expect("priced");
    let kv_once = healthy.per_request[0].kv_transfer_bytes;
    assert!(kv_once > 0.0);

    // Fail the decode replica early in the decode phase — while the KV
    // is on the wire or the handed-off sequence has just started.
    let arrival = h.finished_at_s - h.e2e_s;
    let first_token = arrival + h.queue_s + h.ttft_s;
    let t_fail = first_token + 0.25 * (h.finished_at_s - first_token);
    let down_s = 1.0; // far past the healthy makespan: recovery gates completion

    let faulty = spec
        .with_faults(FaultSpec::none().with_outage(1, t_fail, down_s))
        .unwrap()
        .simulate(&wl, 5)
        .unwrap();
    assert_eq!(faulty.completed, 1);
    assert_eq!(faulty.failed, 0);
    let m = &faulty.per_request[0];
    // Retry #1: the decode-side loss (dead flight or dead handoff
    // target). Retry #2: the re-prefilled attempt finds the decode pool
    // still down and strands until recovery.
    assert_eq!(m.retries, 2, "decode loss + blocked re-prefill");
    let cm = prefill.cost_model();
    assert!(
        m.wasted_prefill_s >= 2.0 * cm.prefill_price(8),
        "both dead prefill passes are priced as waste: {} vs {}",
        m.wasted_prefill_s,
        2.0 * cm.prefill_price(8)
    );
    assert!(
        m.kv_transfer_bytes >= 2.0 * kv_once,
        "the KV ships once per attempt that reaches the wire"
    );
    let f = m.model.expect("priced");
    assert!(f.e2e_s > down_s, "the request rides out the decode-pool downtime");
    assert!(f.e2e_s > h.e2e_s);
}

/// A link-degradation window covering the run slows every KV handoff
/// (same bytes, strictly more wire seconds) and lengthens the run.
#[test]
fn degradation_window_slows_kv_handoffs_but_ships_the_same_bytes() {
    let spec = FleetSpec::disaggregated(&tiny(2, 1), 1, &tiny(1, 2), 1).unwrap();
    let wl = fixed_workload(6, 1000.0, 8, 4);
    let healthy = spec.clone().simulate(&wl, 5).unwrap();
    assert_eq!(healthy.completed, 6);
    let degraded = spec
        .with_faults(FaultSpec::none().with_degrade_window(0.0, 1.0e9, 4.0))
        .unwrap()
        .simulate(&wl, 5)
        .unwrap();
    assert_eq!(degraded.completed, 6);
    assert_eq!(
        degraded.kv_transfer_bytes, healthy.kv_transfer_bytes,
        "a slow wire moves the same bytes"
    );
    assert!(
        degraded.kv_transfer_s > healthy.kv_transfer_s,
        "4x-degraded handoffs must cost more wire time: {} vs {}",
        degraded.kv_transfer_s,
        healthy.kv_transfer_s
    );
    assert!(degraded.model.e2e.p95_s >= healthy.model.e2e.p95_s);
    assert_eq!(degraded.retries, 0, "windows slow links; they kill nothing");
}

/// Churn (MTBF/MTTR exponential processes) is a pure function of the
/// seed: two runs agree bitwise, including per-request retry counts.
#[test]
fn churn_is_bitwise_deterministic_per_seed() {
    let spec = FleetSpec::colocated(&tiny(1, 1), 3).unwrap();
    let wl = fixed_workload(24, 500.0, 8, 4);
    let healthy = spec.clone().simulate(&wl, 7).unwrap();
    let m = healthy.model.makespan_s;
    let churn = spec.with_faults(FaultSpec::none().with_churn(m, m / 5.0)).unwrap();

    let a = churn.simulate(&wl, 7).unwrap();
    let b = churn.simulate(&wl, 7).unwrap();
    assert_eq!(a.model, b.model, "same seed, same model summary under churn");
    assert_eq!(a.retries, b.retries);
    assert_eq!(a.wasted_prefill_s, b.wasted_prefill_s);
    assert_eq!(a.per_request.len(), b.per_request.len());
    for (x, y) in a.per_request.iter().zip(b.per_request.iter()) {
        assert_eq!(x.request_id, y.request_id);
        assert_eq!(x.replica, y.replica);
        assert_eq!(x.retries, y.retries);
        assert_eq!(x.model, y.model);
    }
    assert_eq!(a.requests, 24);
    assert_eq!(a.completed + a.failed, 24, "every request reaches a terminal state");
}
