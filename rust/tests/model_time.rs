//! Virtual-clock cost engine integration — the simtime redesign's
//! acceptance suite: one pricing core drives the SLO simulator's closed
//! forms, the priced trace, and structural model-time serving; model-time
//! serving percentiles are a pure function of (workload, seed).

use commsim::analysis::ParallelLayout;
use commsim::comm::{CollectiveKind, Stage};
use commsim::model::ModelArch;
use commsim::plan::Deployment;
use commsim::server::{Request, SchedulerConfig};
use commsim::simtime::{CostModel, Timeline};

fn plan(model: &str, tp: usize, pp: usize) -> commsim::plan::DeploymentPlan {
    Deployment::builder().model(model).tp(tp).pp(pp).workload(128, 128).build().unwrap()
}

/// The SLO simulator and the plan's cost model are the same arithmetic:
/// simulate() totals equal the closed-form breakdowns bit for bit, for
/// every paper layout.
#[test]
fn simulator_is_a_view_over_the_cost_model() {
    for (tp, pp) in [(2usize, 1usize), (4, 1), (8, 1), (1, 2), (1, 4), (1, 8), (2, 2), (4, 2)] {
        let plan = plan("8b", tp, pp);
        let cm = plan.cost_model();
        let shape = plan.shape();
        let r = plan.simulate();
        assert_eq!(r.prefill, cm.prefill_breakdown(shape), "tp={tp} pp={pp}");
        assert_eq!(r.decode_step, cm.decode_step_breakdown(shape), "tp={tp} pp={pp}");
        assert_eq!(r.ttft_s, r.prefill.total());
    }
}

/// A traced structural run carries modeled time on every collective
/// record, and the per-step modeled comm time of a decode iteration
/// matches the cost model's closed-form comm term.
#[test]
fn traced_records_are_priced_per_step_and_batch() {
    let plan = plan("3b", 4, 1);
    let summary = plan.trace().unwrap();
    // Every AllReduce row carries modeled seconds.
    let dec = summary.paper_view(CollectiveKind::AllReduce, Stage::Decode);
    assert!(dec.count > 0 && dec.modeled_time_s > 0.0);
    let pre = summary.paper_view(CollectiveKind::AllReduce, Stage::Prefill);
    assert!(pre.modeled_time_s > dec.modeled_time_s / dec.count as f64,
        "a prefill AllReduce outweighs one decode AllReduce");

    // Step 0 is the prefill iteration; its op-deduplicated modeled comm
    // time is the closed-form prefill comm term (single stage: every op
    // counted once is exactly the stage's serialized comm) within float
    // tolerance.
    let cm = plan.cost_model();
    let closed = cm.prefill_breakdown(plan.shape()).comm_s;
    let step0 = summary.step_modeled_comm_s(0);
    assert!(
        (step0 - closed).abs() <= 1e-9 * closed,
        "step 0 modeled comm {step0} vs closed form {closed}"
    );
    // Decode steps exist and are cheaper than the prefill step.
    let step1 = summary.step_modeled_comm_s(1);
    assert!(step1 > 0.0 && step1 < step0);
    assert!(summary.modeled_comm_total_s() > closed);
}

/// Structural serving reports model-time SLOs through the plan facade,
/// and a fixed Poisson seed reproduces them bitwise — on a fresh server
/// each time (host scheduling must not leak into model time).
#[test]
fn structural_poisson_serving_model_time_is_seed_deterministic() {
    let serve = |seed: u64| {
        let plan = plan("3b", 2, 1);
        let mut server = plan
            .server(SchedulerConfig { max_batch: 4, ..SchedulerConfig::default() })
            .unwrap();
        let reqs: Vec<Request> = (0..10u64)
            .map(|id| Request { id, prompt: vec![0; 64].into(), decode_len: 12 })
            .collect();
        let summary = server.serve_poisson(reqs, 20.0, seed).unwrap();
        assert_eq!(summary.completed, 10);
        summary.model.expect("structural serving is priced")
    };
    let a = serve(0xF00D);
    let b = serve(0xF00D);
    assert_eq!(a, b, "same seed, fresh server -> identical model-time summary");
    assert!(a.ttft.p50_s > 0.0 && a.tpot.p50_s > 0.0 && a.e2e.p99_s >= a.e2e.p50_s);
    let c = serve(0xBEEF);
    assert_ne!(a, c, "different arrival process -> different model time");
}

/// Numeric-style wall-clock metrics stay primary when no pricing exists:
/// an engine built without a cost model serves with `model: None`.
#[test]
fn unpriced_engines_serve_wall_clock_only() {
    use commsim::engine::{Engine, EngineConfig};
    use commsim::server::Server;
    let mut cfg = EngineConfig::structural(ModelArch::tiny(), ParallelLayout::new(2, 1));
    cfg.pricing = None;
    let mut server = Server::new(
        Engine::new(cfg).unwrap(),
        SchedulerConfig { kv_blocks: 64, kv_block_size: 16, max_queue: 16, max_batch: 2 },
    );
    let summary = server
        .serve_batch(vec![Request { id: 0, prompt: vec![0; 8].into(), decode_len: 4 }])
        .unwrap();
    assert_eq!(summary.completed, 1);
    assert!(summary.model.is_none(), "no pricing -> no model-time summary");
    assert!(server.completed()[0].model.is_none());
}

/// The timeline's event algebra composes as the serving path relies on:
/// iterations accumulate, idle jumps never rewind, and posting the same
/// workload twice doubles the clock.
#[test]
fn timeline_composes_iterations() {
    let cm = CostModel::on_cardinal(ModelArch::llama31_8b(), ParallelLayout::new(2, 2));
    let mut tl = Timeline::new(4);
    let (prefill, _) = cm.post_prefill(&mut tl, 128);
    let (d1, _) = cm.post_decode(&mut tl, &[129]);
    let (d2, _) = cm.post_decode(&mut tl, &[130]);
    assert!(prefill > d1, "prefill dominates a decode step");
    assert!(d1 > 0.0 && d2 >= d1, "KV growth never makes a step cheaper");
    let end = tl.max_time();
    assert!((end - (prefill + d1 + d2)).abs() <= 1e-9 * end);
    // Idle jump to a later arrival, then keep serving.
    tl.advance_all_to(end + 1.0);
    let (d3, _) = cm.post_decode(&mut tl, &[131]);
    assert!((tl.max_time() - (end + 1.0 + d3)).abs() <= 1e-9 * tl.max_time());
}
