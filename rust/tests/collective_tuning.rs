//! Collective-tuning integration: wire-precision savings stamped on serve
//! and fleet summaries must reconcile with the analytical `VolumeModel`
//! (Eq. 1–7) — the saved bytes are logical AllReduce/AllGather volume ×
//! (1 − wire factor), nothing more — and the default tuning must stamp
//! exact zeros everywhere.

use commsim::analysis::{InferenceShape, VolumeModel};
use commsim::model::DTYPE_BYTES_BF16;
use commsim::plan::{Deployment, DeploymentPlan};
use commsim::server::{Request, SchedulerConfig};
use commsim::workload::{ArrivalProcess, LengthDist, WorkloadSpec};

fn tuned_plan(
    model: &str,
    tp: usize,
    pp: usize,
    sp: usize,
    sd: usize,
    bits: u32,
) -> DeploymentPlan {
    Deployment::builder()
        .model(model)
        .tp(tp)
        .pp(pp)
        .workload(sp, sd)
        .collective_tuning(bits, 0.0)
        .build()
        .unwrap()
}

/// Analytic wire bytes saved for one (Sp, Sd) request under the plan's
/// tuning: the per-worker AllReduce + AllGather paper-view volume scaled
/// by (1 − wire factor). Gather and P2P ride the wire untouched.
fn analytic_saved(plan: &DeploymentPlan, sp: usize, sd: usize) -> f64 {
    let shape = InferenceShape::new(sp, sd, DTYPE_BYTES_BF16);
    let v = VolumeModel::new(plan.arch().clone()).volume(plan.layout(), shape);
    (v.allreduce + v.allgather) * (1.0 - plan.collective_tuning().wire_factor())
}

fn close(a: f64, b: f64, what: &str) {
    let denom = b.abs().max(1.0);
    assert!((a - b).abs() / denom < 1e-9, "{what}: {a} vs {b}");
}

/// One int8 request through the serving loop: the stamped savings are
/// exactly half of Eq. 1's AllReduce volume (wire factor 8/16 = 0.5).
#[test]
fn int8_serve_savings_reconcile_with_eq1() {
    let (sp, sd) = (32usize, 8usize);
    let plan = tuned_plan("3b", 2, 1, sp, sd, 8);
    let mut server = plan.server(SchedulerConfig::default()).unwrap();
    let summary = server
        .serve_batch(vec![Request { id: 0, prompt: vec![0; sp].into(), decode_len: sd }])
        .unwrap();
    assert_eq!(summary.completed, 1);
    close(summary.wire_saved_bytes, analytic_saved(&plan, sp, sd), "int8 serve vs Eq.1");
    // Zero overlap hides nothing, exactly.
    assert_eq!(summary.hidden_comm_s, 0.0);
}

/// Savings are additive across requests: N identical requests save N×
/// one request's analytic delta, batched decode included.
#[test]
fn savings_are_additive_across_requests() {
    let (sp, sd, n) = (16usize, 6usize, 3u64);
    let plan = tuned_plan("3b", 2, 1, sp, sd, 8);
    let mut server = plan
        .server(SchedulerConfig { max_batch: 4, ..SchedulerConfig::default() })
        .unwrap();
    let reqs: Vec<Request> = (0..n)
        .map(|id| Request { id, prompt: vec![0; sp].into(), decode_len: sd })
        .collect();
    let summary = server.serve_batch(reqs).unwrap();
    assert_eq!(summary.completed, n as usize);
    close(
        summary.wire_saved_bytes,
        n as f64 * analytic_saved(&plan, sp, sd),
        "N requests vs N × Eq.1 delta",
    );
}

/// Hybrid TP×PP at 4-bit wire: both tuned classes (AllReduce layer/embedding
/// traffic and stage-entry AllGathers) shrink by 1 − 4/16 = 3/4 of Eq. 4–5.
#[test]
fn int4_hybrid_savings_cover_allreduce_and_allgather() {
    let (sp, sd) = (16usize, 4usize);
    let plan = tuned_plan("8b", 2, 2, sp, sd, 4);
    assert_eq!(plan.collective_tuning().wire_factor(), 0.25);
    let mut server = plan.server(SchedulerConfig::default()).unwrap();
    let summary = server
        .serve_batch(vec![Request { id: 0, prompt: vec![0; sp].into(), decode_len: sd }])
        .unwrap();
    assert_eq!(summary.completed, 1);
    let expect = analytic_saved(&plan, sp, sd);
    assert!(expect > 0.0, "hybrid layout must have tunable volume");
    close(summary.wire_saved_bytes, expect, "int4 hybrid vs Eq.4+5 delta");
}

/// The default (16-bit, no-overlap) tuning stamps exact zeros — not small
/// numbers — on the serve summary.
#[test]
fn default_tuning_stamps_exact_zeros() {
    let plan = Deployment::builder().model("3b").tp(2).workload(32, 8).build().unwrap();
    assert!(plan.collective_tuning().is_default());
    let mut server = plan.server(SchedulerConfig::default()).unwrap();
    let summary = server
        .serve_batch(vec![Request { id: 0, prompt: vec![0; 32].into(), decode_len: 8 }])
        .unwrap();
    assert_eq!(summary.wire_saved_bytes, 0.0);
    assert_eq!(summary.hidden_comm_s, 0.0);
}

/// A 1-replica fleet inherits the plan's tuning through calibration and
/// reproduces the serving loop's tuning accounting bitwise.
#[test]
fn single_replica_fleet_matches_serve_tuning_accounting() {
    let plan = Deployment::builder()
        .model("tiny")
        .tp(2)
        .workload(8, 6)
        .collective_tuning(8, 0.25)
        .build()
        .unwrap();
    let cfg = SchedulerConfig { kv_blocks: 64, kv_block_size: 16, max_queue: 64, max_batch: 2 };
    let (rate, seed, n) = (2000.0, 42u64, 8usize);

    let mut server = plan.server(cfg).unwrap();
    let reqs: Vec<Request> = (0..n as u64)
        .map(|id| Request { id, prompt: vec![0; 8].into(), decode_len: 6 })
        .collect();
    let served = server.serve_poisson(reqs, rate, seed).unwrap();
    assert_eq!(served.completed, n);
    assert!(served.wire_saved_bytes > 0.0, "int8 serving saves wire bytes");
    assert!(served.hidden_comm_s > 0.0, "overlap hides some collective time");

    let workload = WorkloadSpec {
        arrivals: ArrivalProcess::poisson(rate),
        prompt: LengthDist::Fixed(8),
        decode: LengthDist::Fixed(6),
        prefix: None,
        requests: n,
    };
    let fleet = plan.fleet(1).unwrap().with_scheduler(cfg).simulate(&workload, seed).unwrap();
    assert_eq!(fleet.completed, n);
    assert_eq!(fleet.wire_saved_bytes, served.wire_saved_bytes, "bitwise saved bytes");
    assert_eq!(fleet.hidden_comm_s, served.hidden_comm_s, "bitwise hidden comm");
}
