//! Property-based invariants (PRNG-driven; proptest substitute — see
//! DESIGN.md §5). Each property runs across many randomized cases with a
//! deterministic seed, so failures are reproducible.

use std::thread;

use commsim::analysis::{InferenceShape, OpCountModel, ParallelLayout, VolumeModel};
use commsim::cluster::NetModel;
use commsim::comm::{CollectiveKind, Stage, TraceSink};
use commsim::comm::collectives::CommWorld;
use commsim::engine::kv::KvBlockManager;
use commsim::model::ModelArch;
use commsim::perfmodel::Calibration;
use commsim::runtime::tensor::HostTensor;
use commsim::server::{
    percentile, PrefixCache, PrefixCacheConfig, Request, Scheduler, SchedulerConfig,
};
use commsim::testutil::Rng;

/// AllReduce == elementwise sum of all contributions, for any group size,
/// message length, and op count.
#[test]
fn prop_allreduce_is_sum() {
    let mut rng = Rng::new(0xA11);
    for case in 0..40 {
        let size = rng.usize_in(2, 8);
        let len = rng.usize_in(1, 257);
        let rounds = rng.usize_in(1, 5);
        let sink = TraceSink::new();
        let world = CommWorld::new(size, 4, sink);
        let handles = world.create_group(&(0..size).collect::<Vec<_>>());
        // Deterministic per-rank inputs derived from (case, round, rank).
        let inputs: Vec<Vec<Vec<f32>>> = (0..size)
            .map(|r| {
                (0..rounds)
                    .map(|round| {
                        let mut g = Rng::new((case * 1000 + round * 10 + r) as u64);
                        g.f32_vec(len)
                    })
                    .collect()
            })
            .collect();
        let outs: Vec<Vec<Vec<f32>>> = thread::scope(|s| {
            let joins: Vec<_> = handles
                .into_iter()
                .zip(inputs.clone())
                .map(|(h, my_inputs)| {
                    s.spawn(move || {
                        my_inputs
                            .into_iter()
                            .map(|mut buf| {
                                let n = buf.len();
                                h.all_reduce(&mut buf, &[n], Stage::Decode);
                                buf
                            })
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            joins.into_iter().map(|j| j.join().unwrap()).collect()
        });
        for round in 0..rounds {
            let mut expect = vec![0.0f32; len];
            for r in 0..size {
                for (e, v) in expect.iter_mut().zip(&inputs[r][round]) {
                    *e += v;
                }
            }
            for r in 0..size {
                for (got, want) in outs[r][round].iter().zip(&expect) {
                    assert!((got - want).abs() < 1e-4, "case {case} round {round} rank {r}");
                }
            }
        }
    }
}

/// AllGather output is exactly the rank-ordered concatenation; Gather at
/// root equals it; non-roots get nothing.
#[test]
fn prop_gather_allgather_concatenation() {
    let mut rng = Rng::new(0xB22);
    for _case in 0..30 {
        let size = rng.usize_in(2, 6);
        let len = rng.usize_in(1, 64);
        let root = rng.usize_in(0, size - 1);
        let sink = TraceSink::new();
        let world = CommWorld::new(size, 4, sink);
        let handles = world.create_group(&(0..size).collect::<Vec<_>>());
        let inputs: Vec<Vec<f32>> = (0..size)
            .map(|r| (0..len).map(|i| (r * 1000 + i) as f32).collect())
            .collect();
        let expect: Vec<f32> = inputs.concat();
        let results: Vec<(Vec<f32>, Option<Vec<f32>>)> = thread::scope(|s| {
            let joins: Vec<_> = handles
                .into_iter()
                .zip(inputs)
                .map(|(h, input)| {
                    s.spawn(move || {
                        let total = input.len() * h.size();
                        let ag = h.all_gather(&input, &[total], Stage::Prefill);
                        let g = h.gather(&input, &[input.len()], root, Stage::Prefill);
                        (ag, g)
                    })
                })
                .collect();
            joins.into_iter().map(|j| j.join().unwrap()).collect()
        });
        for (r, (ag, g)) in results.into_iter().enumerate() {
            assert_eq!(ag, expect);
            if r == root {
                assert_eq!(g.unwrap(), expect);
            } else {
                assert!(g.is_none());
            }
        }
    }
}

/// Column slice / reassembly roundtrip for arbitrary [S, h] and divisor t.
#[test]
fn prop_column_slice_roundtrip() {
    let mut rng = Rng::new(0xC33);
    for _ in 0..100 {
        let s = rng.usize_in(1, 40);
        let t = *rng.choose(&[1usize, 2, 4, 8]);
        let h = t * rng.usize_in(1, 32);
        let x = HostTensor::from_vec(&[s, h], Rng::new(rng.next_u64()).f32_vec(s * h));
        let mut concat = Vec::new();
        for r in 0..t {
            concat.extend_from_slice(&x.column_slice(r, t).data);
        }
        let back = HostTensor::from_column_chunks(&concat, s, h, t);
        assert_eq!(back, x);
    }
}

/// The op-count model integrates exactly to the volume model for random
/// architectures, layouts and sequence shapes (they are one derivation).
#[test]
fn prop_ops_integrate_to_volume() {
    let mut rng = Rng::new(0xD44);
    for case in 0..200 {
        let t = *rng.choose(&[2usize, 4, 8]);
        let p = *rng.choose(&[1usize, 2]);
        // Eq. 4 assumes layers divide evenly across stages (true for every
        // architecture the paper evaluates) — generate accordingly.
        let arch = ModelArch {
            name: format!("rand-{case}"),
            hidden: 64 * rng.usize_in(1, 64),
            layers: p * rng.usize_in(1, 24),
            heads: 8,
            kv_heads: 8,
            head_dim: 64,
            intermediate: 256 * rng.usize_in(1, 40),
            vocab: 1024 * rng.usize_in(1, 100),
        };
        let layout = ParallelLayout::new(t, p);
        let shape =
            InferenceShape::new(rng.usize_in(1, 512), rng.usize_in(1, 512), 2);
        let ops = OpCountModel::new(arch.clone(), layout, shape);
        let vol = VolumeModel::new(arch).volume(layout, shape);

        // Integrate the per-worker paper-view stream (AllReduce, AllGather,
        // Gather) and global Sends (p2p) — the paper's per-class accounting.
        let b = shape.dtype_bytes as f64;
        let paper_view_bytes = |op: CollectiveKind| -> f64 {
            let mut total = 0.0;
            for stage in [Stage::Prefill, Stage::Decode] {
                for o in ops.predict_paper_view(stage).ops.iter().filter(|o| o.op == op) {
                    let elems: usize = o.shape.iter().product();
                    total += o.count as f64 * elems as f64 * b * op.correction_factor(t);
                }
            }
            total
        };
        let close = |a: f64, b: f64, what: &str| {
            let denom = b.abs().max(1.0);
            assert!((a - b).abs() / denom < 1e-9, "case {case} {what}: {a} vs {b}");
        };
        close(paper_view_bytes(CollectiveKind::AllReduce), vol.allreduce, "allreduce");
        close(paper_view_bytes(CollectiveKind::AllGather), vol.allgather, "allgather");
        close(paper_view_bytes(CollectiveKind::Gather), vol.gather, "gather");
        // Eq. 7 is per-rank-pair accounting (Table VI shows per-rank Send
        // streams of [S, h/t]); at p<=2 the paper view integrates exactly.
        close(paper_view_bytes(CollectiveKind::Send), vol.p2p, "p2p");
    }
}

/// KV block manager conservation: used + free == total at every step; a
/// random alloc/append/release workload never corrupts the pool.
#[test]
fn prop_kv_manager_conservation() {
    let mut rng = Rng::new(0xE55);
    for _case in 0..50 {
        let total = rng.usize_in(4, 64);
        let bs = rng.usize_in(1, 32);
        let mut m = KvBlockManager::new(total, bs);
        let mut live: Vec<u64> = Vec::new();
        let mut next_id = 0u64;
        for _op in 0..200 {
            assert_eq!(m.used_blocks() + m.free_blocks(), total, "conservation");
            match rng.usize_in(0, 2) {
                0 => {
                    let tokens = rng.usize_in(1, bs * 4);
                    if m.can_allocate(tokens) {
                        m.allocate(next_id, tokens).unwrap();
                        live.push(next_id);
                        next_id += 1;
                    } else {
                        assert!(m.allocate(next_id, tokens).is_err());
                        next_id += 1;
                    }
                }
                1 => {
                    if !live.is_empty() {
                        let idx = rng.usize_in(0, live.len() - 1);
                        let id = live[idx];
                        let _ = m.append_token(id); // may fail when exhausted; pool must stay sane
                    }
                }
                _ => {
                    if !live.is_empty() {
                        let idx = rng.usize_in(0, live.len() - 1);
                        let id = live.swap_remove(idx);
                        m.release(id).unwrap();
                    }
                }
            }
        }
        for id in live {
            m.release(id).unwrap();
        }
        assert_eq!(m.free_blocks(), total, "all blocks returned");
        assert_eq!(m.live_seqs(), 0);
    }
}

/// Scheduler: FCFS order is preserved, every submitted request is admitted
/// exactly once (given capacity), running never exceeds `max_batch`, and
/// KV drains to empty.
#[test]
fn prop_scheduler_fcfs_conservation() {
    let mut rng = Rng::new(0xF66);
    for _case in 0..30 {
        let blocks = rng.usize_in(8, 64);
        let bs = 16;
        let max_batch = rng.usize_in(1, 6);
        let mut s = Scheduler::new(SchedulerConfig {
            kv_blocks: blocks,
            kv_block_size: bs,
            max_queue: 1024,
            max_batch,
        });
        let n = rng.usize_in(1, 20);
        let mut submitted = Vec::new();
        for id in 0..n as u64 {
            let prompt = rng.usize_in(1, bs * 2);
            let decode = rng.usize_in(1, bs * 2);
            if prompt + decode <= blocks * bs {
                s.submit(Request { id, prompt: vec![0; prompt].into(), decode_len: decode })
                    .unwrap();
                submitted.push(id);
            }
        }
        let mut admitted = Vec::new();
        let mut running: Vec<u64> = Vec::new();
        loop {
            match s.admit_next().unwrap() {
                Some(a) => {
                    admitted.push(a.request.id);
                    running.push(a.request.id);
                    assert!(s.running_len() <= max_batch, "batch cap respected");
                    // Occasionally hold a few sequences in the batch before
                    // finishing, to exercise slot reuse.
                    if running.len() == max_batch {
                        let id = running.remove(0);
                        s.finish(id).unwrap();
                    }
                }
                None => {
                    let Some(id) = running.pop() else { break };
                    s.finish(id).unwrap();
                }
            }
        }
        assert_eq!(admitted, submitted, "FCFS, all admitted exactly once");
        assert_eq!(s.running_len(), 0);
        assert_eq!(s.kv().used_blocks(), 0, "KV drained");
    }
}

/// KvBlockManager under interleaved multi-sequence workloads: for any
/// alloc/append/release interleaving across >= 3 live sequences,
/// `used_blocks` equals the sum of live footprints exactly (no leaked and
/// no phantom blocks, even across failed appends), and `can_allocate`
/// agrees with `allocate`.
#[test]
fn prop_kv_interleaved_footprint_exact() {
    let mut rng = Rng::new(0x5EAF00D);
    for _case in 0..40 {
        let total = rng.usize_in(6, 48);
        let bs = rng.usize_in(1, 16);
        let mut m = KvBlockManager::new(total, bs);
        // Mirror of the manager's expected state: (id, tokens) per live seq.
        let mut live: Vec<(u64, usize)> = Vec::new();
        let mut next_id = 0u64;
        // Keep >= 3 sequences live from the start (1 token = 1 block each).
        for _ in 0..3 {
            assert!(m.can_allocate(1));
            m.allocate(next_id, 1).unwrap();
            live.push((next_id, 1));
            next_id += 1;
        }
        for _op in 0..300 {
            let expected: usize = live.iter().map(|&(_, t)| t.div_ceil(bs)).sum();
            assert_eq!(m.used_blocks(), expected, "used == sum of live footprints");
            assert_eq!(m.live_seqs(), live.len());
            match rng.usize_in(0, 3) {
                0 => {
                    let tokens = rng.usize_in(1, bs * 3);
                    let fits = m.can_allocate(tokens);
                    let res = m.allocate(next_id, tokens);
                    assert_eq!(
                        res.is_ok(),
                        fits,
                        "can_allocate({tokens}) must agree with allocate"
                    );
                    if res.is_ok() {
                        live.push((next_id, tokens));
                    }
                    next_id += 1;
                }
                3 => {
                    // Release, but never drop below 3 live sequences.
                    if live.len() > 3 {
                        let idx = rng.usize_in(0, live.len() - 1);
                        let (id, _) = live.swap_remove(idx);
                        m.release(id).unwrap();
                    }
                }
                _ => {
                    let idx = rng.usize_in(0, live.len() - 1);
                    let entry = &mut live[idx];
                    // A failed append (pool exhausted) must leave the
                    // footprint untouched; a successful one counts.
                    if m.append_token(entry.0).is_ok() {
                        entry.1 += 1;
                    }
                }
            }
        }
        for (id, _) in live {
            m.release(id).unwrap();
        }
        assert_eq!(m.free_blocks(), total, "all blocks returned");
        assert_eq!(m.live_seqs(), 0);
    }
}

/// Prefix-cache invariants under random observe/lookup workloads: a hit
/// never exceeds the prompt length (and is always block-aligned), the
/// resident bytes never exceed the capacity budget after any operation,
/// and identical seeds replay identical hit traces.
#[test]
fn prop_prefix_cache_hits_bounded_and_capacity_respected() {
    let mut rng = Rng::new(0x9F1E);
    for case in 0..40 {
        let block_tokens = rng.usize_in(1, 8);
        let kv_bytes_per_token = rng.usize_in(1, 64);
        // Small budgets (a handful of blocks) force constant eviction.
        let capacity_bytes = rng.usize_in(1, 24) * block_tokens * kv_bytes_per_token;
        let cfg = PrefixCacheConfig { block_tokens, capacity_bytes };
        let groups = rng.usize_in(1, 5) as u64;
        let run_seed = rng.next_u64();

        let run = |ops: usize| -> (Vec<usize>, usize) {
            let mut c = PrefixCache::new(cfg, kv_bytes_per_token);
            let mut g = Rng::new(run_seed);
            let mut trace = Vec::with_capacity(ops);
            for step in 0..ops {
                let group = g.next_u64() % groups;
                let shared = g.usize_in(0, 24);
                let unique = g.usize_in(1, 12);
                // Same-group prompts share their leading tokens; the tail
                // is unique to the (case, step) pair.
                let mut prompt: Vec<i32> =
                    (0..shared).map(|i| (group as i32) * 1000 + i as i32).collect();
                prompt.extend((0..unique).map(|i| {
                    0x40_0000 + (case as i32) * 10_000 + (step as i32) * 16 + i as i32
                }));
                let hit = if step % 3 == 0 {
                    let peek = c.lookup(&prompt);
                    let observed = c.observe(&prompt, step as f64);
                    assert_eq!(peek, observed, "lookup must predict observe");
                    observed
                } else {
                    c.observe(&prompt, step as f64)
                };
                assert!(hit <= prompt.len(), "hit {} > prompt {}", hit, prompt.len());
                assert_eq!(hit % block_tokens, 0, "hits are block-aligned");
                assert!(
                    c.resident_bytes() <= capacity_bytes,
                    "resident {} > capacity {capacity_bytes}",
                    c.resident_bytes()
                );
                trace.push(hit);
            }
            (trace, c.resident_blocks())
        };
        let (t1, r1) = run(120);
        let (t2, r2) = run(120);
        assert_eq!(t1, t2, "case {case}: identical seeds -> identical hit traces");
        assert_eq!(r1, r2);
    }
}

/// Collective time costs are monotone in message size and in group size,
/// for every op class, on both fabrics and on the calibrated constants —
/// a bigger message or a wider group can never get cheaper.
#[test]
fn prop_collective_costs_monotone_in_size_and_group() {
    let mut rng = Rng::new(0x51);
    let models = [NetModel::default(), Calibration::default().net];
    let ops = [
        CollectiveKind::AllReduce,
        CollectiveKind::AllGather,
        CollectiveKind::Gather,
        CollectiveKind::ReduceScatter,
        CollectiveKind::AllToAll,
        CollectiveKind::Send,
    ];
    for _case in 0..200 {
        let nm = models[rng.usize_in(0, 1)];
        let op = ops[rng.usize_in(0, ops.len() - 1)];
        let crosses = rng.usize_in(0, 1) == 1;
        let d = rng.usize_in(2, 16);
        let bytes = (rng.usize_in(1, 1 << 24)) as f64;
        let bigger = bytes * (1.0 + rng.f32_unit().abs() as f64 * 8.0) + 1.0;
        let base = nm.collective(op, bytes, d, crosses).total();
        // Monotone in message size.
        let grown = nm.collective(op, bigger, d, crosses).total();
        assert!(grown >= base, "{op:?} d={d}: {bytes}B -> {bigger}B shrank {base} -> {grown}");
        // Monotone in group size (p2p has no group dimension).
        if op != CollectiveKind::Send {
            let wider = nm.collective(op, bytes, d + rng.usize_in(1, 8), crosses).total();
            assert!(wider >= base, "{op:?}: wider group got cheaper");
        }
        // Degenerate group is free for collectives.
        if op != CollectiveKind::Send {
            assert_eq!(nm.collective(op, bytes, 1, crosses).total(), 0.0);
        }
    }
    // Two-level hierarchical: monotone in message size too.
    for _case in 0..100 {
        let nm = models[rng.usize_in(0, 1)];
        let g = [2usize, 4, 8][rng.usize_in(0, 2)];
        let nodes = rng.usize_in(2, 6);
        let bytes = (rng.usize_in(1, 1 << 24)) as f64;
        let bigger = bytes * 2.0 + 1.0;
        assert!(
            nm.allreduce_two_level(bigger, g, nodes).total()
                >= nm.allreduce_two_level(bytes, g, nodes).total()
        );
    }
}

/// The two-level hierarchical AllReduce is sandwiched by the pure
/// fabrics: it never beats the same group on pure NVLink and never loses
/// to the flat ring on pure IB — for any message size and node shape, on
/// both the default and the calibrated constants.
#[test]
fn prop_two_level_allreduce_between_nvlink_and_ib() {
    let mut rng = Rng::new(0x2FAB);
    let models = [NetModel::default(), Calibration::default().net];
    for _case in 0..300 {
        let nm = models[rng.usize_in(0, 1)];
        let g = [2usize, 4, 8][rng.usize_in(0, 2)];
        let nodes = rng.usize_in(2, 8);
        let d = g * nodes;
        let bytes = (rng.usize_in(1, 1 << 26)) as f64;
        let nv = nm.allreduce(bytes, d, false).total();
        let ib = nm.allreduce(bytes, d, true).total();
        let two = nm.allreduce_two_level(bytes, g, nodes).total();
        assert!(
            two >= nv,
            "g={g} nodes={nodes} bytes={bytes}: two-level {two} beat pure NVLink {nv}"
        );
        assert!(
            two <= ib,
            "g={g} nodes={nodes} bytes={bytes}: two-level {two} lost to pure IB {ib}"
        );
    }
}

/// Scale-down victim selection: for any fleet of drain candidates, the
/// victim always carries the minimum outstanding load, and within that
/// load class it is never the warmest cache while an equally-loaded
/// strictly colder replica exists — warm prefix caches survive drains.
#[test]
fn prop_drain_victim_never_warmest_among_equally_loaded() {
    use commsim::autoscale::{choose_victim, DrainCandidate};
    let mut rng = Rng::new(0xD12A1);
    for case in 0..300 {
        let n = rng.usize_in(2, 8);
        let candidates: Vec<DrainCandidate> = (0..n)
            .map(|replica| DrainCandidate {
                replica,
                // Coarse buckets force load ties; warmth varies freely.
                load: rng.usize_in(0, 3) * 100,
                warm_bytes: (rng.usize_in(0, 5) * 1000) as f64,
            })
            .collect();
        let victim = choose_victim(&candidates).unwrap();
        let v = candidates.iter().find(|c| c.replica == victim).unwrap();
        let min_load = candidates.iter().map(|c| c.load).min().unwrap();
        assert_eq!(v.load, min_load, "case {case}: victim must be least-loaded");
        // Nobody in the victim's load class is strictly colder.
        for c in candidates.iter().filter(|c| c.load == v.load) {
            assert!(
                c.warm_bytes >= v.warm_bytes,
                "case {case}: drained r{victim} (warm {}) over colder r{} (warm {})",
                v.warm_bytes,
                c.replica,
                c.warm_bytes
            );
        }
        // The headline property: the warmest equally-loaded replica is
        // never the victim while a colder peer exists.
        let warmest = candidates
            .iter()
            .filter(|c| c.load == min_load)
            .max_by(|a, b| a.warm_bytes.total_cmp(&b.warm_bytes))
            .unwrap();
        if candidates
            .iter()
            .any(|c| c.load == min_load && c.warm_bytes < warmest.warm_bytes)
        {
            assert_ne!(victim, warmest.replica, "case {case}");
        }
    }
}

/// Percentile is monotone in p and bounded by min/max.
#[test]
fn prop_percentile_monotone_bounded() {
    let mut rng = Rng::new(0x177);
    for _ in 0..50 {
        let n = rng.usize_in(1, 100);
        let samples: Vec<f64> = (0..n).map(|_| rng.f32_unit() as f64 * 100.0).collect();
        let lo = samples.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut last = f64::NEG_INFINITY;
        for p in [0.0, 10.0, 25.0, 50.0, 75.0, 90.0, 99.0, 100.0] {
            let v = percentile(&samples, p);
            assert!(v >= lo && v <= hi);
            assert!(v >= last, "monotone");
            last = v;
        }
    }
}

/// Structural engine conservation: every request completes with exactly the
/// requested number of tokens, under randomized layouts (engines built
/// through the deployment-plan facade).
#[test]
fn prop_engine_token_conservation() {
    use commsim::plan::Deployment;
    let mut rng = Rng::new(0x288);
    for _ in 0..6 {
        let (tp, pp) = *rng.choose(&[(1usize, 2usize), (2, 1), (2, 2), (4, 1), (1, 4)]);
        let sp = rng.usize_in(1, 64);
        let sd = rng.usize_in(1, 32);
        let mut e = Deployment::builder()
            .arch(ModelArch::tiny())
            .tp(tp)
            .pp(pp)
            .build()
            .unwrap()
            .engine()
            .unwrap();
        let r = e.generate(&vec![0i32; sp], sd).unwrap();
        assert_eq!(r.tokens.len(), sd, "tp={tp} pp={pp} sp={sp} sd={sd}");
        assert_eq!(r.step_latencies.len(), sd - 1);
        assert!(r.e2e >= r.ttft);
    }
}

/// Collective tuning over a seeded sweep of deployments: fewer wire bits
/// never increase modeled communication seconds (the quant/dequant
/// compute term is priced inside the comm figure, so this is the honest
/// end-to-end comparison), compute/overhead never move with the wire,
/// overlap only ever reduces the *exposed* comm, and the explicit
/// `(16, 0.0)` tuning is bitwise identical to untuned pricing.
#[test]
fn prop_wire_bits_monotone_and_explicit_default_bitwise() {
    use commsim::plan::Deployment;
    let mut rng = Rng::new(0x0B17);
    for case in 0..24 {
        let (tp, pp) = *rng.choose(&[(2usize, 1usize), (4, 1), (8, 1), (2, 2), (4, 2)]);
        let model = *rng.choose(&["3b", "8b", "13b"]);
        let sp = rng.usize_in(1, 512);
        let sd = rng.usize_in(1, 128);
        let build = |tuning: Option<(u32, f64)>| {
            let mut b = Deployment::builder().model(model).tp(tp).pp(pp).workload(sp, sd);
            if let Some((bits, ov)) = tuning {
                b = b.collective_tuning(bits, ov);
            }
            b.build().unwrap()
        };
        let shape = build(None).shape();
        let breakdowns = |tuning: Option<(u32, f64)>| {
            let cm = build(tuning).cost_model();
            (cm.prefill_breakdown(shape), cm.decode_step_breakdown(shape))
        };
        let (p16, d16) = breakdowns(None);
        let (pe, de) = breakdowns(Some((16, 0.0)));
        assert_eq!(p16, pe, "case {case}: explicit default must price bitwise-untuned");
        assert_eq!(d16, de, "case {case}");
        let (p8, d8) = breakdowns(Some((8, 0.0)));
        let (p4, d4) = breakdowns(Some((4, 0.0)));
        for (wide, narrow, what) in [
            (p16.comm_s, p8.comm_s, "prefill 16->8"),
            (p8.comm_s, p4.comm_s, "prefill 8->4"),
            (d16.comm_s, d8.comm_s, "decode 16->8"),
            (d8.comm_s, d4.comm_s, "decode 8->4"),
        ] {
            assert!(
                narrow <= wide,
                "case {case} {model} tp={tp} pp={pp} {what}: {narrow} > {wide}"
            );
        }
        assert_eq!(p8.compute_s, p16.compute_s, "case {case}: wire never touches compute");
        assert_eq!(p4.overhead_s, p16.overhead_s, "case {case}");
        assert_eq!(d4.compute_s, d16.compute_s, "case {case}");
        // Overlap alone: exposed comm shrinks (never grows), compute is
        // untouched, and totals never increase.
        let ov = (rng.f32_unit() as f64).abs().min(1.0);
        let (pov, dov) = breakdowns(Some((16, ov)));
        assert!(pov.comm_s <= p16.comm_s && dov.comm_s <= d16.comm_s, "case {case}");
        assert_eq!(pov.compute_s, p16.compute_s, "case {case}");
        assert!(pov.total() <= p16.total() && dov.total() <= d16.total(), "case {case}");
    }
}

/// Every plan yielded by `DeploymentPlan::sweep` is actually constructible:
/// the engine spawns its worker group and serves a request — the sweep's
/// feasibility filter and the engine's own layout checks must agree.
#[test]
fn prop_sweep_plans_construct_engines() {
    use commsim::plan::DeploymentPlan;
    let arch = ModelArch::tiny();
    let mut total = 0;
    for gpus in [1usize, 2, 4, 8] {
        let mut found = 0;
        for plan in DeploymentPlan::sweep(&arch, gpus) {
            assert_eq!(plan.layout().world_size(), gpus);
            let mut engine = plan.engine().expect("sweep yielded an infeasible plan");
            let r = engine.generate(&[0i32; 8], 4).unwrap();
            assert_eq!(r.tokens.len(), 4, "{}", plan.label());
            found += 1;
        }
        assert!(found >= 1, "no feasible layout found for {gpus} GPUs");
        total += found;
    }
    assert!(total >= 8, "tiny should admit most small power-of-two grids");
}
