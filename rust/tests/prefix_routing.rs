//! Prefix-aware routing integration: cache-affinity router equivalence
//! on prefix-free traffic, and saved-prefill accounting against the
//! `simtime::CostModel` closed forms.

use commsim::fleet::RouterPolicy;
use commsim::plan::{Deployment, DeploymentPlan};
use commsim::server::{PrefixCacheConfig, Request, SchedulerConfig, Server};
use commsim::workload::{ArrivalProcess, LengthDist, PrefixProfile, WorkloadSpec};

fn tiny(tp: usize, pp: usize) -> DeploymentPlan {
    Deployment::builder().model("tiny").tp(tp).pp(pp).workload(8, 4).build().unwrap()
}

fn cache() -> PrefixCacheConfig {
    PrefixCacheConfig { block_tokens: 4, capacity_bytes: 16 << 20 }
}

/// On a zero-shared-prefix workload (every prompt unique-tokened, so no
/// content-addressed cache can ever hit), `CacheAffinity` produces the
/// same assignment sequence — and the bitwise-identical simulation — as
/// `LeastOutstandingTokens`, with prefix caches attached to both runs.
#[test]
fn cache_affinity_matches_least_tokens_on_prefix_free_traffic() {
    let workload = WorkloadSpec {
        arrivals: ArrivalProcess::poisson(400.0),
        prompt: LengthDist::Uniform { lo: 8, hi: 24 },
        decode: LengthDist::Uniform { lo: 2, hi: 6 },
        prefix: None,
        requests: 32,
    };
    let run = |policy: RouterPolicy, seed: u64| {
        tiny(2, 1)
            .fleet(3)
            .unwrap()
            .with_router(policy)
            .with_prefix_cache(cache())
            .unwrap()
            .simulate(&workload, seed)
            .unwrap()
    };
    for seed in [5u64, 6, 0xC0FFEE] {
        let affinity = run(RouterPolicy::CacheAffinity, seed);
        let lot = run(RouterPolicy::LeastOutstandingTokens, seed);
        assert_eq!(affinity.completed, 32, "seed={seed}");
        assert_eq!(affinity.cached_prompt_tokens, 0, "unique prompts never hit");
        assert_eq!(affinity.saved_prefill_s, 0.0);
        assert_eq!(affinity.model, lot.model, "seed={seed}: bitwise-identical summary");
        assert_eq!(affinity.per_request.len(), lot.per_request.len());
        for (a, l) in affinity.per_request.iter().zip(lot.per_request.iter()) {
            assert_eq!(a.request_id, l.request_id, "seed={seed}: completion order");
            assert_eq!(
                a.replica, l.replica,
                "seed={seed} request {}: assignment sequence",
                a.request_id
            );
            assert_eq!(a.model, l.model);
        }
        // Per-replica dispatch statistics agree too.
        for (a, l) in affinity.replicas.iter().zip(lot.replicas.iter()) {
            assert_eq!((a.assigned, a.tokens), (l.assigned, l.tokens), "seed={seed}");
        }
    }
}

/// On shared-prefix traffic the affinity router concentrates each
/// group's requests on its warm replica, and every saved-prefill figure
/// matches `CostModel::prefill_breakdown` on the cached/suffix split.
#[test]
fn affinity_routes_groups_to_warm_replicas_and_prices_savings() {
    let plan = Deployment::builder().model("tiny").tp(2).workload(33, 4).build().unwrap();
    let workload = WorkloadSpec {
        arrivals: ArrivalProcess::bursty(50.0, 3),
        prompt: LengthDist::Fixed(33),
        decode: LengthDist::Fixed(4),
        prefix: Some(PrefixProfile::MultiTurn { conversations: 4, shared: 32 }),
        requests: 48,
    };
    let s = plan
        .fleet(2)
        .unwrap()
        .with_router(RouterPolicy::CacheAffinity)
        .with_prefix_cache(cache())
        .unwrap()
        .simulate(&workload, 0xF1EE7)
        .unwrap();
    assert_eq!(s.completed, 48);
    assert!(s.cached_prompt_tokens > 0, "groups repeat, so the cache must hit");
    // 4 conversations, generous capacity: at most one cold miss per
    // (conversation, replica) pair — affinity keeps that near one per
    // conversation.
    let misses = s.per_request.iter().filter(|m| m.cached_prompt_tokens == 0).count();
    assert!(misses <= 8, "at most |groups| x |replicas| cold misses, got {misses}");
    let cm = plan.cost_model();
    for m in &s.per_request {
        if m.cached_prompt_tokens == 0 {
            assert_eq!(m.saved_prefill_s, 0.0);
            assert_eq!(m.saved_prefill_bytes, 0.0);
            continue;
        }
        // Hits are block-aligned spans of the 32-token shared prefix.
        assert_eq!(m.cached_prompt_tokens % 4, 0);
        assert!(m.cached_prompt_tokens <= 32);
        // Saved seconds/bytes are exactly the closed-form full-vs-suffix
        // differences (prefill_breakdown under the hood).
        let suffix = m.prompt_tokens - m.cached_prompt_tokens;
        assert_eq!(
            m.saved_prefill_s,
            cm.prefill_price(m.prompt_tokens) - cm.prefill_price(suffix),
            "request {}",
            m.request_id
        );
        assert_eq!(
            m.saved_prefill_bytes,
            cm.prefill_comm_bytes(m.prompt_tokens) - cm.prefill_comm_bytes(suffix),
            "request {}",
            m.request_id
        );
    }
    let folded: f64 = s.per_request.iter().map(|m| m.saved_prefill_s).sum();
    assert_eq!(s.saved_prefill_s, folded, "summary = completion-order fold");
    assert_eq!(
        s.replicas.iter().map(|r| r.cached_tokens).sum::<usize>(),
        s.cached_prompt_tokens
    );
}

/// Single-replica serving stack: a full-prompt repeat's model TTFT is
/// the *suffix* prefill price — `CostModel::prefill_breakdown` on the
/// uncached tokens — and the engine's traced prefill shrinks to the
/// suffix too (the saved AllReduce volume never hits the wire).
#[test]
fn served_hit_ttft_is_the_suffix_prefill_breakdown() {
    use commsim::analysis::InferenceShape;
    use commsim::comm::{CollectiveKind, Stage};
    let plan = Deployment::builder().model("tiny").tp(2).workload(16, 2).build().unwrap();
    let mut srv = Server::new(
        plan.engine().unwrap(),
        SchedulerConfig { kv_blocks: 64, kv_block_size: 16, max_queue: 16, max_batch: 1 },
    )
    .with_prefix_cache(PrefixCacheConfig { block_tokens: 4, capacity_bytes: 1 << 20 })
    .unwrap();
    let prompt: commsim::server::PromptTokens = (100..116).collect::<Vec<i32>>().into();
    let summary = srv
        .serve_batch(vec![
            Request { id: 0, prompt: prompt.clone(), decode_len: 2 },
            Request { id: 1, prompt: prompt.clone(), decode_len: 2 },
        ])
        .unwrap();
    assert_eq!(summary.completed, 2);
    let hit = &srv.completed()[1];
    assert_eq!(hit.cached_prompt_tokens, 15, "full-block hit, clamped to leave 1");
    let cm = plan.cost_model();
    let suffix_ttft =
        cm.prefill_breakdown(InferenceShape::new(1, 1, plan.shape().dtype_bytes)).total();
    let got = hit.model.as_ref().unwrap().ttft_s;
    assert!(
        (got - suffix_ttft).abs() <= 1e-9 * suffix_ttft,
        "hit TTFT {got} vs suffix prefill breakdown {suffix_ttft}"
    );
    assert_eq!(hit.saved_prefill_s, cm.prefill_price(16) - cm.prefill_price(1));
    // The trace saw one 16-token prefill and one 1-token prefill, so the
    // prefill AllReduce stream must carry fewer bytes than two cold
    // 16-token prefills: the saved volume never hit the wire.
    let trace = srv.engine().trace().summary();
    let ar = trace.paper_view(CollectiveKind::AllReduce, Stage::Prefill);
    let mut cold = Server::new(
        plan.engine().unwrap(),
        SchedulerConfig { kv_blocks: 64, kv_block_size: 16, max_queue: 16, max_batch: 1 },
    );
    cold.serve_batch(vec![
        Request { id: 0, prompt: prompt.clone(), decode_len: 2 },
        Request { id: 1, prompt, decode_len: 2 },
    ])
    .unwrap();
    let cold_ar =
        cold.engine().trace().summary().paper_view(CollectiveKind::AllReduce, Stage::Prefill);
    assert!(
        ar.total_message_bytes < cold_ar.total_message_bytes,
        "cached suffix prefill must move fewer AllReduce bytes ({} vs {})",
        ar.total_message_bytes,
        cold_ar.total_message_bytes
    );
}
