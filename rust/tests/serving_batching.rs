//! Iteration-level serving integration — the session/step redesign's
//! acceptance suite: continuous batching is observable end-to-end
//! (throughput, streamed token events, batch-tagged decode collectives
//! with linear volume scaling), and the single-request `generate()` path
//! is byte-identical to serving one sequence through a session.

use commsim::comm::{CollectiveKind, Stage};
use commsim::engine::{SequenceInput, StepKind};
use commsim::model::ModelArch;
use commsim::plan::Deployment;
use commsim::server::{Request, SchedulerConfig, Server};

fn structural_plan(tp: usize, pp: usize) -> commsim::plan::DeploymentPlan {
    Deployment::builder().arch(ModelArch::tiny()).tp(tp).pp(pp).build().unwrap()
}

fn server(tp: usize, max_batch: usize) -> Server {
    structural_plan(tp, 1)
        .server(SchedulerConfig {
            kv_blocks: 256,
            kv_block_size: 4,
            max_queue: 64,
            max_batch,
        })
        .unwrap()
}

fn short_requests(lens: &[usize]) -> Vec<Request> {
    lens.iter()
        .enumerate()
        .map(|(id, &decode_len)| Request { id: id as u64, prompt: vec![0; 8].into(), decode_len })
        .collect()
}

/// Acceptance: 8 short structural requests at max_batch=4 beat the
/// one-at-a-time path's aggregate tokens/s on the same config, and the
/// batched trace carries decode AllReduce records tagged with batch > 1
/// whose payload scales linearly with the tag.
#[test]
fn continuous_batching_beats_fcfs_with_linear_batch_volume() {
    // Mixed decode lengths so the active batch shrinks mid-run (tags 4, 3,
    // 2, ... appear in one trace).
    let lens = [24usize, 24, 24, 16, 24, 24, 24, 16];

    let mut batched = server(2, 4);
    let sb = batched.serve_batch(short_requests(&lens)).unwrap();
    let tb = batched.engine().trace().summary();

    let mut fcfs = server(2, 1);
    let sf = fcfs.serve_batch(short_requests(&lens)).unwrap();
    let tf = fcfs.engine().trace().summary();

    let total: usize = lens.iter().sum();
    assert_eq!(sb.total_tokens, total);
    assert_eq!(sf.total_tokens, total);
    assert_eq!((sb.completed, sb.failed), (8, 0));
    assert_eq!((sf.completed, sf.failed), (8, 0));

    assert!(
        sb.tokens_per_s > sf.tokens_per_s,
        "continuous batching must raise aggregate throughput: {:.1} vs {:.1} tok/s",
        sb.tokens_per_s,
        sf.tokens_per_s
    );

    // The batched run's decode collectives are tagged with the active
    // batch size, including sizes > 1...
    let tagged_gt1: Vec<usize> = tb.batch_sizes().into_iter().filter(|&b| b > 1).collect();
    assert!(tagged_gt1.contains(&4), "full batches must appear: {tagged_gt1:?}");

    // ...and the payload per record is linear in the tag: B x the
    // single-sequence decode AllReduce ([B, h] vs [1, h]).
    let per_record = |s: &commsim::comm::TraceSummary, b: usize| -> usize {
        let agg = s.batch_view(b, CollectiveKind::AllReduce, Stage::Decode);
        assert!(agg.count > 0, "no decode AllReduce tagged batch={b}");
        assert_eq!(agg.total_message_bytes % agg.count, 0);
        agg.total_message_bytes / agg.count
    };
    let unit = per_record(&tf, 1); // FCFS run: every decode is batch 1
    for &b in &tagged_gt1 {
        assert_eq!(per_record(&tb, b), b * unit, "batch {b} must be {b}x the unit payload");
    }

    // The FCFS run on the same config never decodes more than one
    // sequence per iteration.
    assert_eq!(tf.batch_sizes(), vec![1]);
}

/// `Engine::generate` is a wrapper over the session: serving one request
/// through Server/Scheduler/Session produces the identical record stream
/// (ops, stages, shapes, ranks, tags) as the single-request API. Records
/// are canonically ordered first — within one collective round the worker
/// threads race into the shared sink.
#[test]
fn single_request_serving_is_byte_identical_to_generate() {
    fn canonical(mut recs: Vec<commsim::comm::CommRecord>) -> Vec<commsim::comm::CommRecord> {
        recs.sort_by(|a, b| {
            (a.step, a.rank, a.op, a.stage, &a.shape, a.peer, a.batch, a.elems).cmp(&(
                b.step, b.rank, b.op, b.stage, &b.shape, b.peer, b.batch, b.elems,
            ))
        });
        recs
    }

    let plan = structural_plan(2, 2);
    let mut e1 = plan.engine().unwrap();
    let r = e1.generate(&[0i32; 16], 8).unwrap();
    assert_eq!(r.tokens.len(), 8);
    let direct = canonical(e1.trace().snapshot());

    let mut srv = plan
        .server(SchedulerConfig { kv_blocks: 64, kv_block_size: 16, max_queue: 8, max_batch: 4 })
        .unwrap();
    srv.submit(Request { id: 0, prompt: vec![0; 16].into(), decode_len: 8 }).unwrap();
    let served = srv.run_to_completion().unwrap();
    assert_eq!(served.len(), 1);
    assert_eq!(served[0].generated_tokens, 8);
    assert!(served[0].error.is_none());
    let via_server = canonical(srv.engine().trace().snapshot());

    assert_eq!(direct, via_server, "single-request serving must not perturb the trace");
}

/// Per-sequence streaming: token events arrive iteration by iteration with
/// correct indices, and a sequence's completion frees its batch slot for a
/// queued request (continuous batching, not batch-synchronous).
#[test]
fn token_events_stream_and_slots_refill() {
    let plan = structural_plan(1, 1);
    let mut engine = plan.engine().unwrap();
    let mut session = engine.session();
    session
        .admit(SequenceInput { id: 0, prompt: vec![0; 4].into(), start: 0, max_new_tokens: 4 })
        .unwrap();
    session
        .admit(SequenceInput { id: 1, prompt: vec![0; 4].into(), start: 0, max_new_tokens: 2 })
        .unwrap();

    let mut events = Vec::new();
    let mut decode_batches = Vec::new();
    while !session.is_idle() {
        let out = session.step().unwrap();
        if out.kind == StepKind::Decode {
            decode_batches.push(out.batch);
        }
        events.extend(out.events);
    }
    // Prefill of 0, prefill of 1, then joint decode until 1 finishes.
    let summary: Vec<(u64, usize, bool)> =
        events.iter().map(|e| (e.seq, e.index, e.is_last)).collect();
    assert_eq!(
        summary,
        vec![
            (0, 0, false), // prefill seq 0
            (1, 0, false), // prefill seq 1
            (0, 1, false), // decode batch 2
            (1, 1, true),
            (0, 2, false), // decode batch 1
            (0, 3, true),
        ]
    );
    assert_eq!(decode_batches, vec![2, 1, 1]);
    drop(session);

    // Through the server: a short request finishing mid-run lets a queued
    // one enter the batch while the long request is still decoding.
    let mut srv = server(1, 2);
    let summary = srv
        .serve_batch(vec![
            Request { id: 0, prompt: vec![0; 8].into(), decode_len: 20 },
            Request { id: 1, prompt: vec![0; 8].into(), decode_len: 4 },
            Request { id: 2, prompt: vec![0; 8].into(), decode_len: 4 },
        ])
        .unwrap();
    assert_eq!(summary.completed, 3);
    let order: Vec<u64> = srv.completed().iter().map(|m| m.request_id).collect();
    assert_eq!(
        order,
        vec![1, 2, 0],
        "short requests drain through the freed slot before the long one finishes"
    );
}

/// Decode volume accounting against the analytical per-step expectation:
/// a batch-B decode AllReduce moves exactly B x h elements at the trace
/// dtype, for every observed batch size.
#[test]
fn batch_tagged_volume_matches_analytical_payload() {
    let arch = ModelArch::tiny();
    let plan = structural_plan(2, 1);
    let mut engine = plan.engine().unwrap();
    {
        let mut session = engine.session();
        for id in 0..5u64 {
            session
                .admit(SequenceInput { id, prompt: vec![0; 8].into(), start: 0, max_new_tokens: 6 })
                .unwrap();
        }
        while !session.is_idle() {
            session.step().unwrap();
        }
    }
    let s = engine.trace().summary();
    for b in s.batch_sizes() {
        let agg = s.batch_view(b, CollectiveKind::AllReduce, Stage::Decode);
        if agg.count == 0 {
            continue; // batch tag 1 comes from prefill iterations
        }
        assert_eq!(
            agg.total_message_bytes / agg.count,
            b * arch.hidden * 2,
            "batch {b}: decode AllReduce payload must be B x h x dtype"
        );
    }
    // The lockstep cohort of 5 must show up as batch-5 decode records.
    assert!(s.batch_view(5, CollectiveKind::AllReduce, Stage::Decode).count > 0);
}
