//! Seeded fault injection for the fleet simulator — replica churn,
//! straggler ranks, and link-degradation windows on the model clock.
//!
//! The paper characterizes communication on a *healthy* fabric; its
//! headline trade-off (TP buys latency with acute bandwidth sensitivity)
//! only sharpens when the fabric misbehaves: collectives run at the
//! slowest participant, so one slow rank taxes a whole replica, and a
//! failed replica costs every in-flight request its KV and prefix-cache
//! warmth. [`FaultSpec`] describes three injector families the fleet DES
//! ([`crate::fleet::FleetSpec::with_faults`]) executes deterministically:
//!
//! - **replica churn** — per-replica MTBF/MTTR exponential processes
//!   ([`ChurnSpec`], drawn from [`ChurnProcess`]) plus scripted
//!   [`Outage`]s for tests. On failure the replica drops its queue and
//!   every admitted request (retried through the router, warmth lost);
//!   recovery pays a model-time cold start — the weights ride
//!   [`NetModel::p2p`] ([`cold_start_s`]) and the prefix cache restarts
//!   cold.
//! - **straggler ranks** — a per-replica slowdown factor threaded through
//!   [`NetModel::degraded`]: every collective the replica prices inflates
//!   by the factor (α up, β bandwidth down), the slowest-member rule.
//! - **link-degradation windows** — time-boxed bandwidth cuts
//!   ([`DegradeWindow`]) on the fleet wire (KV handoffs, recovery
//!   reloads): [`FaultSpec::wire_factor`] maps a model time to the
//!   active factor.
//!
//! Fault randomness draws from its own seeded stream
//! ([`crate::workload::FAULT_STREAM_SALT`], one sub-stream per replica),
//! independent of the arrival/length/prefix streams — enabling churn
//! never moves an arrival, so healthy-vs-faulty comparisons stay paired.
//! [`FaultSpec::none`] is the exact healthy fleet: factor-1.0 degradation
//! is a bitwise f64 identity and no churn process is ever constructed.

use crate::cluster::NetModel;
use crate::model::ModelArch;
use crate::plan::PlanError;
use crate::workload::{splitmix64, Rng64, FAULT_STREAM_SALT};

/// Fleet-wide replica churn: every replica fails after an exponential
/// `mtbf_s` up-time and repairs after an exponential `mttr_s` down-time
/// (plus the deterministic cold start the fleet prices at recovery).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChurnSpec {
    /// Mean time between failures (seconds, model clock).
    pub mtbf_s: f64,
    /// Mean time to repair (seconds, model clock).
    pub mttr_s: f64,
}

/// One scripted outage: replica `replica` fails at `at_s` and repairs
/// `down_s` later. Deterministic by construction — the regression-test
/// (and incident-replay) counterpart of the stochastic [`ChurnSpec`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Outage {
    pub replica: usize,
    pub at_s: f64,
    pub down_s: f64,
}

/// One time-boxed degradation of the fleet wire: within `[t0_s, t1_s)`
/// inter-replica transfers (KV handoffs, recovery weight reloads) run on
/// links degraded by `factor` (α × factor, bandwidth ÷ factor).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DegradeWindow {
    pub t0_s: f64,
    pub t1_s: f64,
    pub factor: f64,
}

/// A validated-on-attach fault plan for one fleet simulation. The
/// default ([`FaultSpec::none`]) injects nothing and reproduces the
/// healthy fleet bitwise.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultSpec {
    /// Stochastic churn applied to every replica (None: no churn).
    pub churn: Option<ChurnSpec>,
    /// Scripted outages (composable with `churn`).
    pub outages: Vec<Outage>,
    /// Per-replica straggler slowdowns `(replica, factor >= 1.0)`;
    /// repeated entries for one replica compound multiplicatively.
    pub stragglers: Vec<(usize, f64)>,
    /// Fleet-wire degradation windows; overlapping windows apply the
    /// worst (largest) factor.
    pub degrade: Vec<DegradeWindow>,
}

fn positive_finite(what: &'static str, v: f64) -> Result<(), PlanError> {
    if !(v.is_finite() && v > 0.0) {
        return Err(PlanError::FaultValueInvalid { what, value: format!("{v}; must be > 0") });
    }
    Ok(())
}

fn factor_at_least_one(what: &'static str, v: f64) -> Result<(), PlanError> {
    if !(v.is_finite() && v >= 1.0) {
        return Err(PlanError::FaultValueInvalid {
            what,
            value: format!("{v}; must be a finite factor >= 1.0"),
        });
    }
    Ok(())
}

fn replica_in_range(replica: usize, replicas: usize) -> Result<(), PlanError> {
    if replica >= replicas {
        return Err(PlanError::FaultReplicaOutOfRange { replica, replicas });
    }
    Ok(())
}

impl FaultSpec {
    /// The empty fault plan — injects nothing, healthy fleet bitwise.
    pub fn none() -> Self {
        Self::default()
    }

    /// Whether this plan injects nothing at all.
    pub fn is_none(&self) -> bool {
        self.churn.is_none()
            && self.outages.is_empty()
            && self.stragglers.is_empty()
            && self.degrade.is_empty()
    }

    /// Fleet-wide exponential churn (builder form).
    pub fn with_churn(mut self, mtbf_s: f64, mttr_s: f64) -> Self {
        self.churn = Some(ChurnSpec { mtbf_s, mttr_s });
        self
    }

    /// One scripted outage (builder form).
    pub fn with_outage(mut self, replica: usize, at_s: f64, down_s: f64) -> Self {
        self.outages.push(Outage { replica, at_s, down_s });
        self
    }

    /// One straggler replica (builder form).
    pub fn with_straggler(mut self, replica: usize, factor: f64) -> Self {
        self.stragglers.push((replica, factor));
        self
    }

    /// One fleet-wire degradation window (builder form).
    pub fn with_degrade_window(mut self, t0_s: f64, t1_s: f64, factor: f64) -> Self {
        self.degrade.push(DegradeWindow { t0_s, t1_s, factor });
        self
    }

    /// Validate against a fleet of `replicas` members. Every numeric knob
    /// must be finite and in-domain; every named replica must exist.
    pub fn validate(&self, replicas: usize) -> Result<(), PlanError> {
        if let Some(c) = &self.churn {
            positive_finite("churn MTBF seconds", c.mtbf_s)?;
            positive_finite("churn MTTR seconds", c.mttr_s)?;
        }
        for o in &self.outages {
            replica_in_range(o.replica, replicas)?;
            if !(o.at_s.is_finite() && o.at_s >= 0.0) {
                return Err(PlanError::FaultValueInvalid {
                    what: "outage start time",
                    value: format!("{}; must be >= 0", o.at_s),
                });
            }
            positive_finite("outage down time", o.down_s)?;
        }
        for &(replica, factor) in &self.stragglers {
            replica_in_range(replica, replicas)?;
            factor_at_least_one("straggler factor", factor)?;
        }
        for w in &self.degrade {
            if !(w.t0_s.is_finite() && w.t0_s >= 0.0 && w.t1_s.is_finite() && w.t1_s > w.t0_s) {
                return Err(PlanError::FaultValueInvalid {
                    what: "degradation window",
                    value: format!("[{}, {}); needs 0 <= t0 < t1", w.t0_s, w.t1_s),
                });
            }
            factor_at_least_one("degradation factor", w.factor)?;
        }
        Ok(())
    }

    /// The straggler slowdown of one replica: the product of its entries
    /// (exactly 1.0 — the bitwise-identity factor — when it has none).
    pub fn straggler_factor(&self, replica: usize) -> f64 {
        self.stragglers
            .iter()
            .filter(|(r, _)| *r == replica)
            .map(|(_, f)| *f)
            .product()
    }

    /// The fleet-wire degradation factor at model time `t_s`: the worst
    /// factor among windows containing `t_s` (1.0 outside every window).
    pub fn wire_factor(&self, t_s: f64) -> f64 {
        self.degrade
            .iter()
            .filter(|w| w.t0_s <= t_s && t_s < w.t1_s)
            .map(|w| w.factor)
            .fold(1.0, f64::max)
    }
}

/// One replica's seeded failure/repair draw stream: exponential holding
/// times at the spec's MTBF/MTTR, on the replica's own sub-stream of the
/// fault stream — independent of every workload stream and of the other
/// replicas' churn.
#[derive(Debug, Clone)]
pub struct ChurnProcess {
    rng: Rng64,
    spec: ChurnSpec,
}

fn exp_draw(rng: &mut Rng64, mean_s: f64) -> f64 {
    // Inverse-CDF on [0, 1): ln(1 - u) is finite because u < 1.
    -(1.0 - rng.next_f64()).ln() * mean_s
}

impl ChurnProcess {
    pub fn new(seed: u64, replica: usize, spec: ChurnSpec) -> Self {
        // splitmix64 is a bijection: replica sub-streams never collide.
        let rng = Rng64::new(seed ^ FAULT_STREAM_SALT ^ splitmix64(replica as u64));
        Self { rng, spec }
    }

    /// Next up-time: seconds until the replica's next failure.
    pub fn time_to_failure(&mut self) -> f64 {
        exp_draw(&mut self.rng, self.spec.mtbf_s)
    }

    /// Next down-time: seconds until repair completes (the fleet adds
    /// the deterministic cold start on top).
    pub fn time_to_repair(&mut self) -> f64 {
        exp_draw(&mut self.rng, self.spec.mttr_s)
    }
}

/// Model-time cost of a recovered replica's cold start: the full weight
/// set (`param_count × dtype_bytes`) rides one inter-node [`NetModel::p2p`]
/// transfer (checkpoint storage is off-fabric, so the reload always
/// crosses nodes), on the possibly-degraded wire the caller passes in.
pub fn cold_start_s(arch: &ModelArch, dtype_bytes: usize, net: &NetModel) -> f64 {
    net.p2p((arch.param_count() * dtype_bytes) as f64, true).total()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_injects_nothing_and_validates_everywhere() {
        let f = FaultSpec::none();
        assert!(f.is_none());
        f.validate(0).unwrap();
        f.validate(8).unwrap();
        assert_eq!(f.straggler_factor(0), 1.0);
        assert_eq!(f.wire_factor(123.0), 1.0);
    }

    #[test]
    fn validation_rejects_out_of_domain_knobs() {
        let err = |f: FaultSpec| f.validate(2).unwrap_err();
        assert!(matches!(
            err(FaultSpec::none().with_churn(0.0, 1.0)),
            PlanError::FaultValueInvalid { what: "churn MTBF seconds", .. }
        ));
        assert!(matches!(
            err(FaultSpec::none().with_churn(1.0, f64::NAN)),
            PlanError::FaultValueInvalid { what: "churn MTTR seconds", .. }
        ));
        assert!(matches!(
            err(FaultSpec::none().with_straggler(2, 2.0)),
            PlanError::FaultReplicaOutOfRange { replica: 2, replicas: 2 }
        ));
        assert!(matches!(
            err(FaultSpec::none().with_straggler(0, 0.5)),
            PlanError::FaultValueInvalid { what: "straggler factor", .. }
        ));
        assert!(matches!(
            err(FaultSpec::none().with_outage(1, -1.0, 1.0)),
            PlanError::FaultValueInvalid { what: "outage start time", .. }
        ));
        assert!(matches!(
            err(FaultSpec::none().with_degrade_window(2.0, 1.0, 2.0)),
            PlanError::FaultValueInvalid { what: "degradation window", .. }
        ));
        assert!(matches!(
            err(FaultSpec::none().with_degrade_window(0.0, 1.0, 0.9)),
            PlanError::FaultValueInvalid { what: "degradation factor", .. }
        ));
        // Everything in-domain validates.
        FaultSpec::none()
            .with_churn(10.0, 1.0)
            .with_outage(0, 0.5, 0.25)
            .with_straggler(1, 4.0)
            .with_degrade_window(0.0, 2.0, 8.0)
            .validate(2)
            .unwrap();
    }

    #[test]
    fn straggler_factors_compound_and_windows_take_the_worst() {
        let f = FaultSpec::none()
            .with_straggler(1, 2.0)
            .with_straggler(1, 3.0)
            .with_degrade_window(0.0, 2.0, 2.0)
            .with_degrade_window(1.0, 3.0, 5.0);
        assert_eq!(f.straggler_factor(0), 1.0);
        assert_eq!(f.straggler_factor(1), 6.0);
        assert_eq!(f.wire_factor(0.5), 2.0);
        assert_eq!(f.wire_factor(1.5), 5.0, "overlap applies the worst factor");
        assert_eq!(f.wire_factor(2.5), 5.0);
        assert_eq!(f.wire_factor(3.0), 1.0, "windows are half-open");
    }

    #[test]
    fn churn_draws_are_seeded_per_replica_and_deterministic() {
        let spec = ChurnSpec { mtbf_s: 10.0, mttr_s: 1.0 };
        let draw = |seed: u64, replica: usize| -> Vec<f64> {
            let mut p = ChurnProcess::new(seed, replica, spec);
            (0..4).flat_map(|_| [p.time_to_failure(), p.time_to_repair()]).collect()
        };
        assert_eq!(draw(7, 0), draw(7, 0), "same seed+replica -> bitwise draws");
        assert_ne!(draw(7, 0), draw(7, 1), "replicas get independent sub-streams");
        assert_ne!(draw(7, 0), draw(8, 0), "seed moves the stream");
        for d in draw(7, 0) {
            assert!(d.is_finite() && d > 0.0);
        }
        // Exponential means land near the spec over many draws.
        let mut p = ChurnProcess::new(42, 3, spec);
        let n = 4000;
        let mean: f64 = (0..n).map(|_| p.time_to_failure()).sum::<f64>() / n as f64;
        assert!((mean - 10.0).abs() < 1.0, "empirical MTBF {mean} vs 10.0");
    }

    #[test]
    fn cold_start_prices_the_weights_over_the_wire() {
        let arch = ModelArch::tiny();
        let net = NetModel::default();
        let healthy = cold_start_s(&arch, 2, &net);
        let expect = net.p2p((arch.param_count() * 2) as f64, true).total();
        assert_eq!(healthy, expect);
        assert!(healthy > 0.0);
        // A degraded wire makes recovery strictly slower.
        assert!(cold_start_s(&arch, 2, &net.degraded(4.0)) > healthy);
    }
}
