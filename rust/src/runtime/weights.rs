//! Weight shard loading: parse the `weights_t{t}_rank{r}.{bin,manifest}`
//! pair written by `aot.py` (canonical tensor order, f32 little-endian;
//! line-based manifest: `total_bytes <n>` then `<name> <offset> <dims>`).

use std::collections::HashMap;
use std::path::Path;

use super::tensor::HostTensor;
use super::ArtifactStore;
use crate::Result;

#[derive(Debug)]
struct ManifestEntry {
    name: String,
    shape: Vec<usize>,
    offset: usize,
}

fn parse_manifest(text: &str) -> Result<(Vec<ManifestEntry>, usize)> {
    let mut lines = text.lines().filter(|l| !l.trim().is_empty());
    let header = lines.next().ok_or_else(|| anyhow::anyhow!("empty manifest"))?;
    let total_bytes: usize = header
        .strip_prefix("total_bytes ")
        .ok_or_else(|| anyhow::anyhow!("manifest missing total_bytes header"))?
        .trim()
        .parse()?;
    let mut entries = Vec::new();
    for (i, line) in lines.enumerate() {
        let mut parts = line.split_whitespace();
        let (name, offset, dims) = (
            parts.next().ok_or_else(|| anyhow::anyhow!("manifest line {}: name", i + 2))?,
            parts.next().ok_or_else(|| anyhow::anyhow!("manifest line {}: offset", i + 2))?,
            parts.next().ok_or_else(|| anyhow::anyhow!("manifest line {}: dims", i + 2))?,
        );
        let shape = dims
            .split(',')
            .map(|d| d.parse::<usize>())
            .collect::<std::result::Result<Vec<_>, _>>()
            .map_err(|e| anyhow::anyhow!("manifest line {}: {e}", i + 2))?;
        entries.push(ManifestEntry {
            name: name.to_string(),
            shape,
            offset: offset.parse()?,
        });
    }
    Ok((entries, total_bytes))
}

/// One TP rank's weight shard, loaded to host tensors by name.
#[derive(Debug, Clone)]
pub struct ShardWeights {
    pub tp: usize,
    pub rank: usize,
    tensors: HashMap<String, HostTensor>,
}

impl ShardWeights {
    /// Load rank `rank` of degree `tp` from an artifact store.
    pub fn load(store: &ArtifactStore, tp: usize, rank: usize) -> Result<Self> {
        let (bin_path, manifest_path) = store.shard_paths(tp, rank);
        Self::load_paths(&bin_path, &manifest_path, tp, rank)
    }

    fn load_paths(bin_path: &Path, manifest_path: &Path, tp: usize, rank: usize) -> Result<Self> {
        let text = std::fs::read_to_string(manifest_path).map_err(|e| {
            anyhow::anyhow!("reading {}: {e} (run `make artifacts`)", manifest_path.display())
        })?;
        let (entries, total_bytes) = parse_manifest(&text)?;
        let blob = std::fs::read(bin_path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", bin_path.display()))?;
        if blob.len() != total_bytes {
            anyhow::bail!(
                "{}: blob is {} bytes, manifest says {}",
                bin_path.display(),
                blob.len(),
                total_bytes
            );
        }
        let mut tensors = HashMap::with_capacity(entries.len());
        for e in &entries {
            let n_elems: usize = e.shape.iter().product();
            let n_bytes = n_elems * 4;
            let end = e.offset + n_bytes;
            if end > blob.len() {
                anyhow::bail!("{}: tensor {} overruns blob", bin_path.display(), e.name);
            }
            let mut data = vec![0.0f32; n_elems];
            // f32 little-endian, native on every supported target.
            for (i, chunk) in blob[e.offset..end].chunks_exact(4).enumerate() {
                data[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
            }
            tensors.insert(e.name.clone(), HostTensor::from_vec(&e.shape, data));
        }
        Ok(Self { tp, rank, tensors })
    }

    /// Fetch a tensor by canonical name (e.g. `"layer2.wq"`, `"embed"`).
    pub fn get(&self, name: &str) -> Result<&HostTensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("weight tensor {name} missing from shard"))
    }

    pub fn tensor_names(&self) -> Vec<&str> {
        self.tensors.keys().map(|s| s.as_str()).collect()
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::TempDir;

    fn write_test_shard(dir: &Path) {
        // Two tensors: a [2,2] and a [3].
        let t0: Vec<f32> = vec![1.0, 2.0, 3.0, 4.0];
        let t1: Vec<f32> = vec![5.0, 6.0, 7.0];
        let mut blob = Vec::new();
        for v in t0.iter().chain(t1.iter()) {
            blob.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(dir.join("weights_t2_rank0.bin"), &blob).unwrap();
        let manifest = "total_bytes 28\nembed 0 2,2\nfinal_norm 16 3\n";
        std::fs::write(dir.join("weights_t2_rank0.manifest"), manifest).unwrap();
    }

    #[test]
    fn loads_manifest_and_blob() {
        let dir = TempDir::new("commsim-weights");
        write_test_shard(dir.path());
        let w = ShardWeights::load_paths(
            &dir.path().join("weights_t2_rank0.bin"),
            &dir.path().join("weights_t2_rank0.manifest"),
            2,
            0,
        )
        .unwrap();
        assert_eq!(w.len(), 2);
        assert_eq!(w.get("embed").unwrap().shape, vec![2, 2]);
        assert_eq!(w.get("embed").unwrap().data, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(w.get("final_norm").unwrap().data, vec![5.0, 6.0, 7.0]);
        assert!(w.get("missing").is_err());
    }

    #[test]
    fn rejects_truncated_blob() {
        let dir = TempDir::new("commsim-weights-trunc");
        write_test_shard(dir.path());
        let path = dir.path().join("weights_t2_rank0.bin");
        let blob = std::fs::read(&path).unwrap();
        std::fs::write(&path, &blob[..20]).unwrap();
        let err = ShardWeights::load_paths(
            &path,
            &dir.path().join("weights_t2_rank0.manifest"),
            2,
            0,
        )
        .unwrap_err();
        assert!(err.to_string().contains("bytes"));
    }

    #[test]
    fn manifest_parse_errors() {
        assert!(parse_manifest("").is_err());
        assert!(parse_manifest("nonsense\n").is_err());
        assert!(parse_manifest("total_bytes 4\nfoo 0\n").is_err(), "missing dims");
        assert!(parse_manifest("total_bytes 4\nfoo 0 2,x\n").is_err(), "bad dim");
        let (e, total) = parse_manifest("total_bytes 8\nfoo 0 2\n").unwrap();
        assert_eq!(total, 8);
        assert_eq!(e[0].shape, vec![2]);
    }
}
