//! Minimal host-side f32 tensor used on the engine's data path, with the
//! layout helpers the TP/PP boundary exchanges need (column slicing for
//! `[S, h/t]` pipeline messages, rank-chunk reassembly after AllGather).

use crate::Result;

/// Dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn zeros(shape: &[usize]) -> Self {
        Self { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>(), "shape/data mismatch");
        Self { shape: shape.to_vec(), data }
    }

    pub fn elems(&self) -> usize {
        self.data.len()
    }

    /// Rows of a 2-D tensor.
    pub fn rows(&self) -> usize {
        assert_eq!(self.shape.len(), 2);
        self.shape[0]
    }

    /// Columns of a 2-D tensor.
    pub fn cols(&self) -> usize {
        assert_eq!(self.shape.len(), 2);
        self.shape[1]
    }

    /// Elementwise `self += other` (the residual adds the engine performs
    /// between AllReduced segment outputs).
    pub fn add_assign(&mut self, other: &HostTensor) {
        assert_eq!(self.shape, other.shape, "add_assign shape mismatch");
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += *b;
        }
    }

    /// Column slice `[S, h] -> [S, cols_per_rank]` for rank `r` of `t`
    /// (the `[S, h/t]` tensor a pipeline boundary ships per TP rank).
    pub fn column_slice(&self, rank: usize, t: usize) -> HostTensor {
        let (s, h) = (self.rows(), self.cols());
        assert!(h % t == 0 && rank < t);
        let w = h / t;
        let mut out = Vec::with_capacity(s * w);
        for row in 0..s {
            let base = row * h + rank * w;
            out.extend_from_slice(&self.data[base..base + w]);
        }
        HostTensor::from_vec(&[s, w], out)
    }

    /// Inverse of [`Self::column_slice`]: reassemble `[S, h]` from `t`
    /// rank-ordered column chunks of `[S, h/t]` (what our AllGather
    /// returns: chunks concatenated by rank).
    pub fn from_column_chunks(chunks_concat: &[f32], s: usize, h: usize, t: usize) -> HostTensor {
        assert_eq!(chunks_concat.len(), s * h);
        assert!(h % t == 0);
        let w = h / t;
        let mut out = vec![0.0f32; s * h];
        for rank in 0..t {
            let chunk = &chunks_concat[rank * s * w..(rank + 1) * s * w];
            for row in 0..s {
                out[row * h + rank * w..row * h + (rank + 1) * w]
                    .copy_from_slice(&chunk[row * w..(row + 1) * w]);
            }
        }
        HostTensor::from_vec(&[s, h], out)
    }

    /// Last row of a 2-D tensor as a new `[1, h]` tensor.
    pub fn last_row(&self) -> HostTensor {
        let (s, h) = (self.rows(), self.cols());
        HostTensor::from_vec(&[1, h], self.data[(s - 1) * h..].to_vec())
    }

    /// Convert to an XLA literal (f32).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let bytes: &[u8] = bytemuck_cast(&self.data);
        xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, &self.shape, bytes)
            .map_err(|e| anyhow::anyhow!("literal: {e}"))
    }

    /// Read back from an XLA literal of known shape.
    pub fn from_literal(lit: &xla::Literal, shape: &[usize]) -> Result<HostTensor> {
        let data = lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec: {e}"))?;
        Ok(HostTensor::from_vec(shape, data))
    }
}

/// i32 token ids to an XLA literal of shape `[n]`.
pub fn i32_literal(tokens: &[i32]) -> Result<xla::Literal> {
    let bytes: &[u8] = bytemuck_cast(tokens);
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32,
        &[tokens.len()],
        bytes,
    )
    .map_err(|e| anyhow::anyhow!("i32 literal: {e}"))
}

/// Greedy sampler over gathered logits.
pub fn argmax(logits: &[f32]) -> usize {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best
}

fn bytemuck_cast<T>(v: &[T]) -> &[u8] {
    // f32/i32 are plain-old-data; layout is the native little-endian the
    // AOT weight blobs use.
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v)) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn column_slice_roundtrip() {
        // [2, 4] with t=2 -> two [2, 2] slices -> reassembled.
        let x = HostTensor::from_vec(&[2, 4], (0..8).map(|i| i as f32).collect());
        let s0 = x.column_slice(0, 2);
        let s1 = x.column_slice(1, 2);
        assert_eq!(s0.data, vec![0.0, 1.0, 4.0, 5.0]);
        assert_eq!(s1.data, vec![2.0, 3.0, 6.0, 7.0]);
        let mut concat = s0.data.clone();
        concat.extend_from_slice(&s1.data);
        let back = HostTensor::from_column_chunks(&concat, 2, 4, 2);
        assert_eq!(back, x);
    }

    #[test]
    fn column_slice_identity_t1() {
        let x = HostTensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(x.column_slice(0, 1), x);
        let back = HostTensor::from_column_chunks(&x.data, 2, 3, 1);
        assert_eq!(back, x);
    }

    #[test]
    fn add_assign_and_last_row() {
        let mut a = HostTensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]);
        let b = HostTensor::from_vec(&[2, 2], vec![10., 20., 30., 40.]);
        a.add_assign(&b);
        assert_eq!(a.data, vec![11., 22., 33., 44.]);
        assert_eq!(a.last_row().data, vec![33., 44.]);
        assert_eq!(a.last_row().shape, vec![1, 2]);
    }

    #[test]
    fn argmax_picks_first_max() {
        assert_eq!(argmax(&[0.1, 3.0, 3.0, -1.0]), 1);
        assert_eq!(argmax(&[-5.0]), 0);
    }

    #[test]
    fn literal_roundtrip() {
        let x = HostTensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let lit = x.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit, &[2, 3]).unwrap();
        assert_eq!(back, x);
        let toks = i32_literal(&[7, 8, 9]).unwrap();
        assert_eq!(toks.to_vec::<i32>().unwrap(), vec![7, 8, 9]);
    }
}
