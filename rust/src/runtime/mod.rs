//! PJRT runtime: load AOT artifacts (HLO text) and execute them on the CPU
//! client — the Rust half of the AOT bridge (see python/compile/aot.py).
//!
//! PJRT objects from the `xla` crate are **not `Send`** (the client is an
//! `Rc`), so every engine worker thread owns its own [`xla::PjRtClient`] and
//! compiles its own executables; [`ArtifactStore`] is the shared, `Send`
//! description of what to load.

pub mod tensor;
pub mod weights;

pub use tensor::HostTensor;
pub use weights::ShardWeights;

use std::path::{Path, PathBuf};

use crate::Result;

/// Parsed `artifacts/meta.txt` — the contract between `aot.py` and the
/// engine (tiny-model dims, prefill length, available TP degrees). The
/// build also writes a `meta.json` twin for the Python tests; Rust parses
/// the line-based format (std-only, DESIGN.md §5 substitutions).
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub model: String,
    pub vocab: usize,
    pub hidden: usize,
    pub intermediate: usize,
    pub layers: usize,
    pub heads: usize,
    pub head_dim: usize,
    pub max_seq: usize,
    pub prefill_len: usize,
    pub tp_degrees: Vec<usize>,
    pub seed: u64,
    pub dtype: String,
}

impl ArtifactMeta {
    /// Parse the `key=value` meta format.
    pub fn parse(text: &str) -> Result<Self> {
        let mut map = std::collections::HashMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("meta line {}: missing '='", lineno + 1))?;
            map.insert(k.trim().to_string(), v.trim().to_string());
        }
        let get = |k: &str| -> Result<String> {
            map.get(k).cloned().ok_or_else(|| anyhow::anyhow!("meta missing key '{k}'"))
        };
        let num = |k: &str| -> Result<usize> {
            get(k)?.parse().map_err(|e| anyhow::anyhow!("meta key '{k}': {e}"))
        };
        let tp_degrees = get("tp_degrees")?
            .split(',')
            .map(|s| s.trim().parse::<usize>())
            .collect::<std::result::Result<Vec<_>, _>>()
            .map_err(|e| anyhow::anyhow!("meta tp_degrees: {e}"))?;
        Ok(Self {
            model: get("model")?,
            vocab: num("vocab")?,
            hidden: num("hidden")?,
            intermediate: num("intermediate")?,
            layers: num("layers")?,
            heads: num("heads")?,
            head_dim: num("head_dim")?,
            max_seq: num("max_seq")?,
            prefill_len: num("prefill_len")?,
            tp_degrees,
            seed: get("seed")?.parse()?,
            dtype: get("dtype")?,
        })
    }
}

/// Locator + metadata for a built artifact directory. Cheap to clone and
/// `Send` — workers use it to construct their thread-local runtimes.
#[derive(Debug, Clone)]
pub struct ArtifactStore {
    pub dir: PathBuf,
    pub meta: ArtifactMeta,
}

impl ArtifactStore {
    /// Open an artifact directory (reads `meta.txt`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let meta_path = dir.join("meta.txt");
        let text = std::fs::read_to_string(&meta_path).map_err(|e| {
            anyhow::anyhow!("reading {}: {e} (run `make artifacts`)", meta_path.display())
        })?;
        let meta = ArtifactMeta::parse(&text)?;
        Ok(Self { dir, meta })
    }

    /// Default location relative to the repo root / current directory.
    pub fn open_default() -> Result<Self> {
        Self::open("artifacts")
    }

    /// Whether a built artifact store exists at `dir` (its metadata file
    /// is present). The cheap probe for "artifacts were never built" —
    /// callers that find `present()` true should treat an `open()` failure
    /// as corruption, not absence.
    pub fn present(dir: impl AsRef<Path>) -> bool {
        dir.as_ref().join("meta.txt").exists()
    }

    /// Path of a segment HLO, e.g. `("attn", Phase::Decode, 2)`.
    pub fn hlo_path(&self, segment: &str, phase: Phase, tp: usize) -> PathBuf {
        self.dir.join(format!("{segment}_{}_t{tp}.hlo.txt", phase.suffix()))
    }

    /// Path of the fused whole-model graph (t=1 only).
    pub fn full_path(&self, phase: Phase) -> PathBuf {
        self.dir.join(format!("full_{}_t1.hlo.txt", phase.suffix()))
    }

    /// Weight shard blob + manifest paths for (t, rank).
    pub fn shard_paths(&self, tp: usize, rank: usize) -> (PathBuf, PathBuf) {
        (
            self.dir.join(format!("weights_t{tp}_rank{rank}.bin")),
            self.dir.join(format!("weights_t{tp}_rank{rank}.manifest")),
        )
    }

    /// Verify the store supports a TP degree.
    pub fn supports_tp(&self, tp: usize) -> bool {
        self.meta.tp_degrees.contains(&tp)
    }
}

/// Inference phase of a segment executable.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    Prefill,
    Decode,
}

impl Phase {
    fn suffix(&self) -> &'static str {
        match self {
            Phase::Prefill => "prefill",
            Phase::Decode => "decode",
        }
    }
}

/// Compile one HLO-text file on a client.
pub fn compile_hlo(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path.to_str().ok_or_else(|| {
        anyhow::anyhow!("non-utf8 path {}", path.display())
    })?)
    .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow::anyhow!("compiling {}: {e}", path.display()))
}

/// Execute with borrowed literal inputs; unwrap the
/// lowered-with-`return_tuple` output into its tuple elements.
///
/// NOTE: the `xla` 0.1.6 C++ shim *leaks the input device buffers* of
/// `execute()` (`BufferFromHostLiteral(...).release()` with no matching
/// free) — ~input-size bytes per call. Use [`execute_b_tuple`] with
/// caller-owned [`xla::PjRtBuffer`] inputs on any hot path; this variant is
/// kept for one-shot tooling and tests.
pub fn execute_tuple(
    exe: &xla::PjRtLoadedExecutable,
    inputs: &[&xla::Literal],
) -> Result<Vec<xla::Literal>> {
    let out = exe
        .execute::<&xla::Literal>(inputs)
        .map_err(|e| anyhow::anyhow!("execute: {e}"))?;
    let lit = out[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow::anyhow!("to_literal: {e}"))?;
    lit.to_tuple().map_err(|e| anyhow::anyhow!("to_tuple: {e}"))
}

/// Execute with caller-owned device buffers (leak-free, and skips the
/// host→device weight re-upload `execute()` performs on every call);
/// unwrap the tuple output.
pub fn execute_b_tuple(
    exe: &xla::PjRtLoadedExecutable,
    inputs: &[&xla::PjRtBuffer],
) -> Result<Vec<xla::Literal>> {
    let out = exe
        .execute_b::<&xla::PjRtBuffer>(inputs)
        .map_err(|e| anyhow::anyhow!("execute_b: {e}"))?;
    let lit = out[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow::anyhow!("to_literal: {e}"))?;
    lit.to_tuple().map_err(|e| anyhow::anyhow!("to_tuple: {e}"))
}

/// Upload an f32 host tensor to the device.
pub fn to_device(client: &xla::PjRtClient, t: &tensor::HostTensor) -> Result<xla::PjRtBuffer> {
    client
        .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)
        .map_err(|e| anyhow::anyhow!("to_device: {e}"))
}

/// Upload i32 data (token ids / positions) to the device.
pub fn i32_to_device(client: &xla::PjRtClient, data: &[i32]) -> Result<xla::PjRtBuffer> {
    client
        .buffer_from_host_buffer::<i32>(data, &[data.len()], None)
        .map_err(|e| anyhow::anyhow!("i32_to_device: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const META_TEXT: &str = "model=tiny-llama\nvocab=512\nhidden=256\nintermediate=768\n\
        layers=4\nheads=8\nhead_dim=32\nmax_seq=128\nprefill_len=32\nseed=0\n\
        dtype=f32\ntp_degrees=1,2,4\n";

    #[test]
    fn meta_parses_key_value_format() {
        let m = ArtifactMeta::parse(META_TEXT).unwrap();
        assert_eq!(m.model, "tiny-llama");
        assert_eq!(m.hidden, 256);
        assert_eq!(m.tp_degrees, vec![1, 2, 4]);
        assert_eq!(m.prefill_len, 32);
    }

    #[test]
    fn meta_rejects_missing_keys_and_garbage() {
        assert!(ArtifactMeta::parse("model=x\n").is_err());
        assert!(ArtifactMeta::parse(&META_TEXT.replace("vocab=512", "vocab=abc")).is_err());
        assert!(ArtifactMeta::parse(&META_TEXT.replace("hidden=256", "hidden")).is_err());
        // comments and blank lines are fine
        let ok = format!("# comment\n\n{META_TEXT}");
        assert!(ArtifactMeta::parse(&ok).is_ok());
    }

    #[test]
    fn artifact_paths() {
        let store = ArtifactStore {
            dir: PathBuf::from("/tmp/a"),
            meta: ArtifactMeta::parse(META_TEXT).unwrap(),
        };
        assert_eq!(
            store.hlo_path("attn", Phase::Decode, 2),
            PathBuf::from("/tmp/a/attn_decode_t2.hlo.txt")
        );
        assert_eq!(
            store.full_path(Phase::Prefill),
            PathBuf::from("/tmp/a/full_prefill_t1.hlo.txt")
        );
        let (bin, manifest) = store.shard_paths(4, 3);
        assert!(bin.ends_with("weights_t4_rank3.bin"));
        assert!(manifest.ends_with("weights_t4_rank3.manifest"));
        assert!(store.supports_tp(2));
        assert!(!store.supports_tp(8));
    }

    #[test]
    fn open_missing_dir_errors_helpfully() {
        let err = ArtifactStore::open("/nonexistent-dir").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
