//! `commsim` CLI — the leader entrypoint.
//!
//! Subcommands map onto the paper's workflow:
//! - `analyze` — analytical communication volume + op predictions (Eq. 1–7)
//! - `trace`   — run the structural engine and validate trace vs analytics
//! - `slo`     — simulate TTFT/TPOT/E2E for a layout (Figs. 8–10)
//! - `serve`   — serve the tiny real model end-to-end via PJRT (numeric)
//! - `tables`  — print all paper-table reproductions at once
//!
//! Flag parsing is hand-rolled (`--key value`); the vendored build
//! environment provides no CLI crate (DESIGN.md §5).

use std::collections::HashMap;

use commsim::analysis::{InferenceShape, OpCountModel, ParallelLayout, VolumeModel};
use commsim::cluster::{Placement, Topology};
use commsim::engine::{Engine, EngineConfig};
use commsim::model::ModelArch;
use commsim::perfmodel::SloSimulator;
use commsim::report;
use commsim::runtime::ArtifactStore;
use commsim::server::{Request, SchedulerConfig, Server};

const USAGE: &str = "\
commsim — communication patterns in distributed LLM inference (paper reproduction)

USAGE: commsim <COMMAND> [--flag value]...

COMMANDS:
  analyze   Analytical communication volume and op counts (Eq. 1-7)
            --model 3b|8b|13b|tiny  --tp N  --pp N  --sp N  --sd N
  trace     Run the structural engine; compare trace vs analytical model
            --model ...  --tp N  --pp N  --sp N  --sd N
  slo       Simulate TTFT/TPOT/E2E on the paper's testbed model
            --model ...  --tp N  --pp N  --sp N  --sd N  --gpus-per-node N
  serve     Serve the tiny real model via PJRT (requires `make artifacts`)
            --tp N  --pp N  --requests N  --decode-len N  --artifacts DIR
  tables    Print all paper-table reproductions (Tables III-VI)
";

/// Minimal `--key value` flag parser.
struct Flags(HashMap<String, String>);

impl Flags {
    fn parse(args: &[String]) -> anyhow::Result<Self> {
        let mut map = HashMap::new();
        let mut it = args.iter();
        while let Some(a) = it.next() {
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| anyhow::anyhow!("expected --flag, got '{a}'"))?;
            let val = it
                .next()
                .ok_or_else(|| anyhow::anyhow!("flag --{key} needs a value"))?;
            map.insert(key.replace('-', "_"), val.clone());
        }
        Ok(Self(map))
    }

    fn str(&self, key: &str, default: &str) -> String {
        self.0.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn num(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.0.get(key) {
            Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--{key}: {e}")),
            None => Ok(default),
        }
    }
}

fn arch(name: &str) -> anyhow::Result<ModelArch> {
    ModelArch::by_name(name)
        .ok_or_else(|| anyhow::anyhow!("unknown model '{name}' (3b|8b|13b|tiny)"))
}

fn cmd_analyze(f: &Flags) -> anyhow::Result<()> {
    let arch = arch(&f.str("model", "8b"))?;
    let layout = ParallelLayout::new(f.num("tp", 2)?, f.num("pp", 1)?);
    let (sp, sd) = (f.num("sp", 128)?, f.num("sd", 128)?);
    let shape = InferenceShape::new(sp, sd, 2);
    let v = VolumeModel::new(arch.clone()).volume(layout, shape);
    println!("model={} layout={} Sp={sp} Sd={sd} (BF16)", arch.name, layout.label());
    println!("{}", report::volume_line(&arch, layout, shape));
    let ops = OpCountModel::new(arch, layout, shape);
    for stage in [commsim::comm::Stage::Prefill, commsim::comm::Stage::Decode] {
        println!("\n{} ops (paper-table view):", stage.label());
        for o in ops.predict_paper_view(stage).ops {
            println!(
                "  {:<10} count={:<6} shape={}",
                o.op.label(),
                o.count,
                report::fmt_shape(&o.shape)
            );
        }
    }
    println!("\ntotal corrected volume: {}", report::fmt_bytes(v.total()));
    Ok(())
}

fn cmd_trace(f: &Flags) -> anyhow::Result<()> {
    let arch = arch(&f.str("model", "8b"))?;
    let layout = ParallelLayout::new(f.num("tp", 2)?, f.num("pp", 1)?);
    let (sp, sd) = (f.num("sp", 128)?, f.num("sd", 128)?);
    let shape = InferenceShape::new(sp, sd, 2);
    let mut engine = Engine::new(EngineConfig::structural(arch.clone(), layout))?;
    let r = engine.generate(&vec![0i32; sp], sd)?;
    eprintln!("generated {} tokens (structural)", r.tokens.len());
    let summary = engine.trace().summary();
    print!(
        "{}",
        report::comparison_table(
            &format!("{} {} Sp={sp} Sd={sd}", arch.name, layout.label()),
            &arch,
            layout,
            shape,
            &summary,
        )
    );
    Ok(())
}

fn cmd_slo(f: &Flags) -> anyhow::Result<()> {
    let arch = arch(&f.str("model", "3b"))?;
    let layout = ParallelLayout::new(f.num("tp", 2)?, f.num("pp", 1)?);
    let (sp, sd) = (f.num("sp", 128)?, f.num("sd", 128)?);
    let gpn = f.num("gpus_per_node", 4)?;
    let nodes = layout.world_size().div_ceil(gpn).max(1);
    let placement = Placement::new(Topology::new(nodes, gpn), layout)?;
    let sim = SloSimulator::new(arch.clone(), placement);
    let shape = InferenceShape::new(sp, sd, 2);
    let r = sim.simulate(shape);
    println!("model={} layout={} nodes={nodes}", arch.name, layout.label());
    println!("TTFT  {:>10.2} ms", r.ttft_s * 1e3);
    println!("TPOT  {:>10.2} ms", r.tpot_s * 1e3);
    println!("E2E   {:>10.2} s", r.e2e_s);
    println!("comm fraction {:>6.1}%", r.comm_fraction(shape) * 100.0);
    Ok(())
}

fn cmd_serve(f: &Flags) -> anyhow::Result<()> {
    let store = ArtifactStore::open(f.str("artifacts", "artifacts"))?;
    let sp = store.meta.prefill_len;
    let vocab = store.meta.vocab as i32;
    let layout = ParallelLayout::new(f.num("tp", 2)?, f.num("pp", 1)?);
    let requests = f.num("requests", 4)?;
    let decode_len = f.num("decode_len", 16)?;
    let engine = Engine::new(EngineConfig::numeric(store, layout))?;
    let mut server = Server::new(engine, SchedulerConfig::default());
    let reqs: Vec<Request> = (0..requests as u64)
        .map(|id| Request {
            id,
            prompt: (0..sp as i32).map(|i| (id as i32 * 31 + i) % vocab).collect(),
            decode_len,
        })
        .collect();
    let summary = server.serve_batch(reqs)?;
    println!("served {} requests, {} tokens", summary.requests, summary.total_tokens);
    println!(
        "throughput {:.1} tok/s, {:.2} req/s",
        summary.tokens_per_s, summary.requests_per_s
    );
    println!(
        "TTFT p50 {:.1} ms, TPOT p50 {:.2} ms, E2E mean {:.2} s",
        summary.ttft_p50_s * 1e3,
        summary.tpot_p50_s * 1e3,
        summary.e2e_mean_s
    );
    Ok(())
}

fn cmd_tables() -> anyhow::Result<()> {
    let shape = InferenceShape::new(128, 128, 2);
    let cases: Vec<(&str, ModelArch, Vec<ParallelLayout>)> = vec![
        (
            "Table III (TP)",
            ModelArch::llama31_8b(),
            vec![ParallelLayout::new(2, 1), ParallelLayout::new(4, 1)],
        ),
        (
            "Table V (PP)",
            ModelArch::llama31_8b(),
            vec![ParallelLayout::new(1, 2), ParallelLayout::new(1, 4)],
        ),
        ("Table VI (hybrid)", ModelArch::llama31_8b(), vec![ParallelLayout::new(2, 2)]),
    ];
    for (label, arch, layouts) in cases {
        for layout in layouts {
            let mut engine = Engine::new(EngineConfig::structural(arch.clone(), layout))?;
            engine.generate(&vec![0i32; 128], 128)?;
            let summary = engine.trace().summary();
            print!(
                "{}",
                report::comparison_table(
                    &format!("{label} {}", layout.label()),
                    &arch,
                    layout,
                    shape,
                    &summary,
                )
            );
            println!();
        }
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        eprint!("{USAGE}");
        std::process::exit(2);
    };
    let flags = Flags::parse(&args[1..])?;
    match cmd.as_str() {
        "analyze" => cmd_analyze(&flags),
        "trace" => cmd_trace(&flags),
        "slo" => cmd_slo(&flags),
        "serve" => cmd_serve(&flags),
        "tables" => cmd_tables(),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => {
            eprint!("unknown command '{other}'\n\n{USAGE}");
            std::process::exit(2);
        }
    }
}
