//! `commsim` CLI — the leader entrypoint.
//!
//! Subcommands map onto the paper's workflow, and every one is a thin
//! layer over the validated deployment-plan facade (`commsim::plan`):
//! - `analyze` — analytical communication volume + op predictions (Eq. 1–7)
//! - `trace`   — run the structural engine and validate trace vs analytics
//! - `slo`     — simulate TTFT/TPOT/E2E for a layout (Figs. 8–10)
//! - `serve`   — serve the tiny real model end-to-end via PJRT (numeric)
//! - `fleet`   — capacity-sweep a multi-replica fleet (colocated sizes +
//!   a disaggregated prefill/decode split) on the model clock
//! - `tables`  — print all paper-table reproductions at once
//!
//! Flag parsing is hand-rolled (`--key value`); the vendored build
//! environment provides no CLI crate (DESIGN.md §5). Each subcommand
//! declares its flag set and anything else is rejected with a
//! did-you-mean suggestion — a silent typo (`--ppp 2`) must not silently
//! produce numbers for the wrong layout.

use std::collections::HashMap;

use commsim::autoscale::AutoscalePolicy;
use commsim::comm::Stage;
use commsim::faults::FaultSpec;
use commsim::fleet::{self, FleetSpec, RouterPolicy, SloTarget};
use commsim::model::ModelArch;
use commsim::plan::Deployment;
use commsim::report;
use commsim::runtime::ArtifactStore;
use commsim::server::{PrefixCacheConfig, Request, SchedulerConfig};
use commsim::workload::{ArrivalProcess, LengthDist, PrefixProfile, WorkloadSpec};

const USAGE: &str = "\
commsim — communication patterns in distributed LLM inference (paper reproduction)

USAGE: commsim <COMMAND> [--flag value]...

COMMANDS:
  analyze   Analytical communication volume and op counts (Eq. 1-7)
            --model 3b|8b|13b|tiny  --tp N  --pp N  --sp N  --sd N
            --wire-bits 16|8|4  --overlap F (collective tuning, see below)
  trace     Run the structural engine; compare trace vs analytical model
            --model ...  --tp N  --pp N  --sp N  --sd N
            --wire-bits 16|8|4  --overlap F
  slo       Simulate TTFT/TPOT/E2E on the paper's testbed model
            --model ...  --tp N  --pp N  --sp N  --sd N  --gpus-per-node N
  serve     Serve requests through the continuous-batching scheduler
            numeric (default): --tp N  --pp N  --requests N  --decode-len N  --artifacts DIR
            structural (no artifacts needed): --model 3b|8b|13b|tiny  --sp N
            workload: --concurrency N (sequences per decode iteration)
                      --arrival-rate R (Poisson req/s; omit for all-at-once)
                      --seed N (arrival PRNG seed; --arrival-rate only)
            structural runs also report model-time SLOs (priced timeline)
            --wire-bits 16|8|4  --overlap F (structural only)
            --chunk-tokens N (Sarathi-style chunked prefill: prompts longer
                              than N prefill in N-token chunks interleaved
                              with running decodes; structural only)
  fleet     Capacity-sweep a multi-replica fleet on the model clock
            --model 3b|8b|13b|tiny  --tp N  --pp N  --sp N  --sd N
            --replicas-max N (colocated fleet sizes 1..=N; a disaggregated
                              prefill/decode configuration is always added)
            --router rr|least-tokens|shortest-queue|affinity
            --requests N  --arrival-rate R (Poisson req/s)  --seed N
            --burst N (group arrivals into bursts of N; default 1)
            --prefix-profile none|system|multi-turn|few-shot (shared-prefix
                              traffic; enables per-replica prefix caches)
            --prefix-shared N (shared prefix tokens; default Sp/2)
            --prefix-groups N (conversations/templates; default 8)
            --prefix-cache-mb N (per-replica prefix-cache budget; default 64)
            --slo-e2e-p95 S (report the cheapest fleet meeting E2E p95 <= S)
            --gpus-per-node N (fleet node grid; prices KV handoffs)
            --sweep threaded|sequential (candidate execution; default
                              threaded — one OS thread per candidate,
                              bitwise-identical output either way)
            elastic autoscaling (--autoscale switches to a static-vs-elastic
            comparison: cold-started scale-ups, warm-aware drains and live
            KV migration, all priced on the model clock):
            --autoscale Q (scale to hold mean queue depth near Q)
            --min-replicas N (elastic floor; the ceiling is --replicas-max)
            --scale-window S (controller sliding window, model seconds)
            fault injection (any of these switches to a per-policy churn
            table over a fixed fleet of --replicas-max replicas):
            --mtbf S (mean model-seconds between failures, per replica)
            --mttr S (mean repair seconds; needs --mtbf; default MTBF/10)
            --straggler R:F[,R:F...] (replica R prices collectives F x slower)
            --degrade T0:T1:F[,...] (fleet wire F x slower in [T0, T1) s)
            deterministic: the same --seed reproduces every number bitwise
            collective tuning (validated by the deployment plan, uniform
            across analyze/trace/serve/fleet):
            --wire-bits 16|8|4 (collective wire precision; 16 = untuned
                              fp16/bf16, 8|4 = Flash-Communication-style
                              quantized AllReduce/AllGather transports
                              that pay a quant/dequant compute term)
            --overlap F (fraction of each stage's compute that can hide
                              exposed collective time, in [0, 1])
            --chunk-tokens N (chunked prefill on the colocated replicas
                              and the disaggregated decode pool; the
                              prefill pool has no decodes to interleave
                              and always runs one-shot)
  bench-diff Compare two directories of BENCH_*.json perf artifacts
            --old DIR  --new DIR  --tolerance F (relative, default 0.05)
            exits non-zero when any modeled seconds/bytes grew past the
            tolerance (structural changes are reported, not failed on)
  tables    Print all paper-table reproductions (Tables III-VI)
";

/// Flags accepted by `analyze` (normalized: dashes become underscores).
const ANALYZE_FLAGS: &[&str] = &["model", "tp", "pp", "sp", "sd", "wire_bits", "overlap"];
/// `trace` takes the same set as `analyze`.
const TRACE_FLAGS: &[&str] = ANALYZE_FLAGS;
const SLO_FLAGS: &[&str] = &["model", "tp", "pp", "sp", "sd", "gpus_per_node"];
const SERVE_FLAGS: &[&str] = &[
    "tp",
    "pp",
    "requests",
    "decode_len",
    "artifacts",
    "model",
    "sp",
    "concurrency",
    "arrival_rate",
    "seed",
    "wire_bits",
    "overlap",
    "chunk_tokens",
];
const TABLES_FLAGS: &[&str] = &[];
const FLEET_FLAGS: &[&str] = &[
    "model",
    "tp",
    "pp",
    "sp",
    "sd",
    "replicas_max",
    "router",
    "requests",
    "arrival_rate",
    "seed",
    "burst",
    "prefix_profile",
    "prefix_shared",
    "prefix_groups",
    "prefix_cache_mb",
    "slo_e2e_p95",
    "gpus_per_node",
    "autoscale",
    "min_replicas",
    "scale_window",
    "mtbf",
    "mttr",
    "straggler",
    "degrade",
    "sweep",
    "wire_bits",
    "overlap",
    "chunk_tokens",
];
const BENCH_DIFF_FLAGS: &[&str] = &["old", "new", "tolerance"];

/// Minimal `--key value` flag parser with a per-subcommand allow-list.
struct Flags(HashMap<String, String>);

impl Flags {
    fn parse(cmd: &str, args: &[String], allowed: &[&str]) -> anyhow::Result<Self> {
        let mut map = HashMap::new();
        let mut it = args.iter();
        while let Some(a) = it.next() {
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| anyhow::anyhow!("expected --flag, got '{a}'"))?;
            let norm = key.replace('-', "_");
            if !allowed.contains(&norm.as_str()) {
                let mut msg = format!("unknown flag --{key} for '{cmd}'");
                if let Some(s) = closest_flag(&norm, allowed) {
                    msg.push_str(&format!(" (did you mean --{}?)", s.replace('_', "-")));
                }
                let valid: Vec<String> =
                    allowed.iter().map(|f| format!("--{}", f.replace('_', "-"))).collect();
                if valid.is_empty() {
                    anyhow::bail!("{msg}\n'{cmd}' takes no flags");
                }
                anyhow::bail!("{msg}\nvalid flags for '{cmd}': {}", valid.join(" "));
            }
            let val = it
                .next()
                .ok_or_else(|| anyhow::anyhow!("flag --{key} needs a value"))?;
            if map.insert(norm, val.clone()).is_some() {
                anyhow::bail!("flag --{key} given more than once");
            }
        }
        Ok(Self(map))
    }

    fn str(&self, key: &str, default: &str) -> String {
        self.0.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn opt(&self, key: &str) -> Option<&String> {
        self.0.get(key)
    }

    fn num(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.0.get(key) {
            Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--{key}: {e}")),
            None => Ok(default),
        }
    }

    fn float(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.0.get(key) {
            Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--{key}: {e}")),
            None => Ok(default),
        }
    }
}

/// Parse the collective-tuning flags shared by analyze/trace/serve/fleet.
/// `None` when neither flag was given: the plan builder is then never
/// touched and every output stays bitwise-identical to a run without the
/// flags. Domain validation ([16|8|4] bits, overlap in [0, 1]) lives in
/// the deployment plan — the CLI only parses numbers.
fn tuning_flags(f: &Flags) -> anyhow::Result<Option<(u32, f64)>> {
    if f.opt("wire_bits").is_none() && f.opt("overlap").is_none() {
        return Ok(None);
    }
    let bits = f.num("wire_bits", 16)? as u32;
    let overlap = f.float("overlap", 0.0)?;
    Ok(Some((bits, overlap)))
}

/// Header fragment for an explicitly tuned run (empty without the flags,
/// keeping seeded default stdout byte-identical across builds).
fn tuning_desc(tuning: Option<(u32, f64)>) -> String {
    match tuning {
        Some((bits, ov)) => format!(" wire-bits={bits} overlap={ov}"),
        None => String::new(),
    }
}

/// Parse `--chunk-tokens`. `None` without the flag: the plan builder is
/// never touched and every prefill stays one-shot, bitwise. Domain
/// validation (budget >= 1) lives in the deployment plan.
fn chunk_flag(f: &Flags) -> anyhow::Result<Option<usize>> {
    match f.opt("chunk_tokens") {
        Some(_) => Ok(Some(f.num("chunk_tokens", 0)?)),
        None => Ok(None),
    }
}

/// Header fragment for a chunked run (empty without the flag, keeping
/// seeded default stdout byte-identical across builds).
fn chunk_desc(chunk: Option<usize>) -> String {
    match chunk {
        Some(tokens) => format!(" chunk-tokens={tokens}"),
        None => String::new(),
    }
}

/// Nearest allowed flag within edit distance 2, for typo suggestions.
fn closest_flag<'a>(key: &str, allowed: &[&'a str]) -> Option<&'a str> {
    allowed
        .iter()
        .map(|a| (edit_distance(key, a), *a))
        .filter(|(d, _)| *d <= 2)
        .min_by_key(|(d, _)| *d)
        .map(|(_, a)| a)
}

/// Classic Levenshtein distance (flags are short; O(n·m) is fine).
fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    for (i, &ca) in a.iter().enumerate() {
        let mut cur = Vec::with_capacity(b.len() + 1);
        cur.push(i + 1);
        for (j, &cb) in b.iter().enumerate() {
            let cost = usize::from(ca != cb);
            cur.push((prev[j] + cost).min(prev[j + 1] + 1).min(cur[j] + 1));
        }
        prev = cur;
    }
    prev[b.len()]
}

fn cmd_analyze(f: &Flags) -> anyhow::Result<()> {
    let (sp, sd) = (f.num("sp", 128)?, f.num("sd", 128)?);
    let tuning = tuning_flags(f)?;
    let mut builder = Deployment::builder()
        .model(&f.str("model", "8b"))
        .tp(f.num("tp", 2)?)
        .pp(f.num("pp", 1)?)
        .workload(sp, sd);
    if let Some((bits, ov)) = tuning {
        builder = builder.collective_tuning(bits, ov);
    }
    let plan = builder.build()?;
    let vr = plan.analyze();
    println!(
        "model={} layout={} Sp={sp} Sd={sd} (BF16){}",
        plan.arch().name,
        plan.layout().label(),
        tuning_desc(tuning)
    );
    println!("{}", report::volume_line(plan.arch(), plan.layout(), plan.shape()));
    for stage in [Stage::Prefill, Stage::Decode] {
        println!("\n{} ops (paper-table view):", stage.label());
        for o in &vr.ops(stage).ops {
            println!(
                "  {:<10} count={:<6} shape={}",
                o.op.label(),
                o.count,
                report::fmt_shape(&o.shape)
            );
        }
    }
    println!("\ntotal corrected volume: {}", report::fmt_bytes(vr.total_bytes()));
    Ok(())
}

fn cmd_trace(f: &Flags) -> anyhow::Result<()> {
    let (sp, sd) = (f.num("sp", 128)?, f.num("sd", 128)?);
    let tuning = tuning_flags(f)?;
    let mut builder = Deployment::builder()
        .model(&f.str("model", "8b"))
        .tp(f.num("tp", 2)?)
        .pp(f.num("pp", 1)?)
        .workload(sp, sd);
    if let Some((bits, ov)) = tuning {
        builder = builder.collective_tuning(bits, ov);
    }
    let plan = builder.build()?;
    let summary = plan.trace()?;
    eprintln!("generated {sd} tokens (structural)");
    print!(
        "{}",
        report::comparison_table(
            &format!(
                "{} {} Sp={sp} Sd={sd}{}",
                plan.arch().name,
                plan.layout().label(),
                tuning_desc(tuning)
            ),
            plan.arch(),
            plan.layout(),
            plan.shape(),
            &summary,
        )
    );
    Ok(())
}

fn cmd_slo(f: &Flags) -> anyhow::Result<()> {
    let (sp, sd) = (f.num("sp", 128)?, f.num("sd", 128)?);
    let plan = Deployment::builder()
        .model(&f.str("model", "3b"))
        .tp(f.num("tp", 2)?)
        .pp(f.num("pp", 1)?)
        .workload(sp, sd)
        .gpus_per_node(f.num("gpus_per_node", 4)?)
        .build()?;
    let r = plan.simulate();
    println!(
        "model={} layout={} nodes={}",
        plan.arch().name,
        plan.layout().label(),
        plan.topology().nodes
    );
    println!("TTFT  {:>10.2} ms", r.ttft_s * 1e3);
    println!("TPOT  {:>10.2} ms", r.tpot_s * 1e3);
    println!("E2E   {:>10.2} s", r.e2e_s);
    println!("comm fraction {:>6.1}%", r.comm_fraction(plan.shape()) * 100.0);
    // When the TP group spans nodes, quantify how much of the decode
    // AllReduce cost is the flat-ring algorithm (what the paper's stack
    // runs) vs the two-level hierarchical what-if.
    if plan.layout().tp > 1 && plan.placement().tp_group_crosses_nodes(0) {
        let cm = plan.cost_model();
        let msg = plan.arch().hidden as f64 * plan.shape().dtype_bytes as f64;
        let flat = cm.cal.net.allreduce(msg, plan.layout().tp, true).total();
        let two = cm.tp_allreduce_two_level(0, msg).total();
        println!(
            "cross-node TP decode AllReduce: {:.1} us flat ring vs {:.1} us two-level \
             what-if ({:.1}x headroom for a topology-aware algorithm)",
            flat * 1e6,
            two * 1e6,
            flat / two
        );
    }
    Ok(())
}

fn cmd_serve(f: &Flags) -> anyhow::Result<()> {
    let requests = f.num("requests", 4)?;
    let decode_len = f.num("decode_len", 16)?;
    let concurrency = f.num("concurrency", SchedulerConfig::default().max_batch)?;
    let arrival_rate = f.float("arrival_rate", 0.0)?;
    let seed = f.num("seed", 0xC0FFEE)? as u64;
    if f.opt("seed").is_some() && arrival_rate <= 0.0 {
        anyhow::bail!(
            "--seed seeds the Poisson arrival process; it needs --arrival-rate \
             (all-at-once serving has no randomness to seed)"
        );
    }

    // --model selects structural serving at paper scale (continuous
    // batching with no artifacts); the default path serves the tiny real
    // model via PJRT over built artifacts. Flags foreign to the chosen
    // mode are rejected — a flag must never be silently ignored while
    // numbers come out (same rule as the per-subcommand allow-lists).
    let structural = f.opt("model").is_some();
    let tuning = tuning_flags(f)?;
    if !structural && tuning.is_some() {
        anyhow::bail!(
            "--wire-bits/--overlap tune the priced model timeline; they need \
             structural serving (--model ...) — numeric PJRT serving executes \
             real kernels and has no collective pricing to tune"
        );
    }
    let chunk = chunk_flag(f)?;
    if !structural && chunk.is_some() {
        anyhow::bail!(
            "--chunk-tokens splits prefills on the priced model timeline; it \
             needs structural serving (--model ...) — numeric PJRT prefill \
             graphs are fixed-length and cannot split a prompt"
        );
    }
    if structural && f.opt("artifacts").is_some() {
        anyhow::bail!(
            "--artifacts conflicts with --model: structural serving (--model) \
             uses no artifacts; drop one of the two flags"
        );
    }
    if !structural && f.opt("sp").is_some() {
        anyhow::bail!(
            "--sp applies to structural serving (--model ...); numeric prompts \
             are fixed by the artifacts' prefill length"
        );
    }
    if !structural && f.opt("concurrency").is_some() && concurrency > 1 {
        anyhow::bail!(
            "--concurrency > 1 needs structural serving (--model ...): numeric \
             PJRT backends hold single-sequence KV state and serve one request \
             at a time"
        );
    }
    let (plan, sp) = match f.opt("model") {
        Some(model) => {
            let sp = f.num("sp", 32)?;
            let mut builder = Deployment::builder()
                .model(model)
                .tp(f.num("tp", 2)?)
                .pp(f.num("pp", 1)?)
                .workload(sp, decode_len);
            if let Some((bits, ov)) = tuning {
                builder = builder.collective_tuning(bits, ov);
            }
            if let Some(tokens) = chunk {
                builder = builder.chunked_prefill(tokens);
            }
            let plan = builder.build()?;
            (plan, sp)
        }
        None => {
            let store = ArtifactStore::open(f.str("artifacts", "artifacts"))?;
            let sp = store.meta.prefill_len;
            let plan = Deployment::builder()
                .artifacts(store)
                .tp(f.num("tp", 2)?)
                .pp(f.num("pp", 1)?)
                // Validate the workload we are about to serve (prompt length
                // is fixed by the artifacts; --decode-len must fit max_seq).
                .workload(sp, decode_len)
                .build()?;
            (plan, sp)
        }
    };
    let vocab = plan.arch().vocab as i32;
    let cfg = SchedulerConfig { max_batch: concurrency.max(1), ..SchedulerConfig::default() };
    let mut server = plan.server(cfg)?;
    let reqs: Vec<Request> = (0..requests as u64)
        .map(|id| Request {
            id,
            prompt: (0..sp as i32)
                .map(|i| (id as i32 * 31 + i) % vocab)
                .collect::<Vec<i32>>()
                .into(),
            decode_len,
        })
        .collect();
    let summary = if arrival_rate > 0.0 {
        println!(
            "arrivals: Poisson rate={arrival_rate} req/s seed={seed:#x} ({seed}){}{}",
            tuning_desc(tuning),
            chunk_desc(chunk)
        );
        server.serve_poisson(reqs, arrival_rate, seed)?
    } else {
        println!("arrivals: all-at-once{}{}", tuning_desc(tuning), chunk_desc(chunk));
        server.serve_batch(reqs)?
    };
    println!(
        "served {} requests ({} completed, {} failed), {} tokens",
        summary.requests, summary.completed, summary.failed, summary.total_tokens
    );
    println!(
        "throughput {:.1} tok/s, {:.2} req/s (wall clock)",
        summary.tokens_per_s, summary.requests_per_s
    );
    println!(
        "TTFT p50/p95/p99 {:.1}/{:.1}/{:.1} ms",
        summary.ttft.p50_s * 1e3,
        summary.ttft.p95_s * 1e3,
        summary.ttft.p99_s * 1e3
    );
    println!(
        "TPOT p50/p95/p99 {:.2}/{:.2}/{:.2} ms",
        summary.tpot.p50_s * 1e3,
        summary.tpot.p95_s * 1e3,
        summary.tpot.p99_s * 1e3
    );
    println!(
        "E2E  p50/p99 {:.3}/{:.3} s (mean {:.3} s, includes queueing)",
        summary.e2e.p50_s, summary.e2e.p99_s, summary.e2e_mean_s
    );
    if let Some(mt) = &summary.model {
        println!(
            "\nmodel time (priced timeline — what the calibrated H100 testbed would take):"
        );
        println!(
            "  throughput {:.1} tok/s over {:.3} s makespan",
            mt.tokens_per_s, mt.makespan_s
        );
        println!(
            "  TTFT p50/p95/p99 {:.1}/{:.1}/{:.1} ms",
            mt.ttft.p50_s * 1e3,
            mt.ttft.p95_s * 1e3,
            mt.ttft.p99_s * 1e3
        );
        println!(
            "  TPOT p50/p95/p99 {:.2}/{:.2}/{:.2} ms",
            mt.tpot.p50_s * 1e3,
            mt.tpot.p95_s * 1e3,
            mt.tpot.p99_s * 1e3
        );
        println!(
            "  E2E  p50/p99 {:.3}/{:.3} s (mean {:.3} s, includes queueing)",
            mt.e2e.p50_s, mt.e2e.p99_s, mt.e2e_mean_s
        );
    }
    // Only explicitly tuned runs print the tuning accounting: default
    // stdout stays byte-identical for the seeded CI diffs.
    if tuning.is_some() {
        println!(
            "collective tuning: {} saved on the wire, {:.3} ms of comm hidden by overlap",
            report::fmt_bytes(summary.wire_saved_bytes),
            summary.hidden_comm_s * 1e3
        );
    }
    // Chunked runs report the interference ledger (absent without the
    // flag — seeded default stdout stays byte-identical).
    if chunk.is_some() {
        println!(
            "chunked prefill: {} of {} requests split; {:.3} ms of decode \
             interference priced onto victims",
            summary.chunked_requests,
            summary.requests,
            summary.interference_s * 1e3
        );
    }
    // Batched-decode comm accounting: AllReduce volume per active batch
    // size, straight off the step/batch-tagged trace.
    let trace = server.engine().trace().summary();
    let batches = trace.batch_sizes();
    if !batches.is_empty() {
        println!("\ndecode AllReduce by active batch size:");
        for b in batches {
            let agg = trace.batch_view(b, commsim::comm::CollectiveKind::AllReduce, Stage::Decode);
            if agg.count > 0 {
                println!(
                    "  batch={b}: count={:<6} total={} modeled={:.3} ms",
                    agg.count,
                    report::fmt_bytes(agg.total_message_bytes as f64),
                    agg.modeled_time_s * 1e3
                );
            }
        }
    }
    Ok(())
}

/// Parse `R:F[,R:F...]` straggler specs (`0:4.0,2:1.5`).
fn parse_stragglers(s: &str) -> anyhow::Result<Vec<(usize, f64)>> {
    s.split(',')
        .map(|part| {
            let (r, factor) = part
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("--straggler wants replica:factor, got '{part}'"))?;
            Ok((
                r.trim().parse().map_err(|e| anyhow::anyhow!("--straggler replica '{r}': {e}"))?,
                factor
                    .trim()
                    .parse()
                    .map_err(|e| anyhow::anyhow!("--straggler factor '{factor}': {e}"))?,
            ))
        })
        .collect()
}

/// Parse `T0:T1:F[,...]` degradation windows (`0.5:1.5:4`).
fn parse_degrade(s: &str) -> anyhow::Result<Vec<(f64, f64, f64)>> {
    s.split(',')
        .map(|part| {
            let fields: Vec<&str> = part.split(':').collect();
            anyhow::ensure!(
                fields.len() == 3,
                "--degrade wants t0:t1:factor, got '{part}'"
            );
            let num = |what: &str, v: &str| -> anyhow::Result<f64> {
                v.trim().parse().map_err(|e| anyhow::anyhow!("--degrade {what} '{v}': {e}"))
            };
            Ok((num("t0", fields[0])?, num("t1", fields[1])?, num("factor", fields[2])?))
        })
        .collect()
}

/// Assemble the fleet's fault plan from the CLI flags (empty when no
/// fault flag was given).
fn fleet_faults(f: &Flags) -> anyhow::Result<FaultSpec> {
    let mut faults = FaultSpec::none();
    match f.opt("mtbf") {
        Some(_) => {
            let mtbf = f.float("mtbf", 0.0)?;
            // MTTR defaults to a 91%-uptime replica (repair an order of
            // magnitude faster than failure).
            let mttr = f.float("mttr", mtbf / 10.0)?;
            faults = faults.with_churn(mtbf, mttr);
        }
        None => anyhow::ensure!(
            f.opt("mttr").is_none(),
            "--mttr sets the repair time of --mtbf churn; it needs --mtbf \
             (there is no failure process to repair from)"
        ),
    }
    if let Some(s) = f.opt("straggler") {
        for (replica, factor) in parse_stragglers(s)? {
            faults = faults.with_straggler(replica, factor);
        }
    }
    if let Some(s) = f.opt("degrade") {
        for (t0, t1, factor) in parse_degrade(s)? {
            faults = faults.with_degrade_window(t0, t1, factor);
        }
    }
    Ok(faults)
}

/// The serving-under-failure mode of `fleet`: a fixed fleet, every
/// router policy simulated healthy and faulty on the same seed, goodput
/// and tail latency side by side.
#[allow(clippy::too_many_arguments)]
fn fleet_churn_table(
    base: &commsim::plan::DeploymentPlan,
    replicas: usize,
    policies: &[RouterPolicy],
    faults: &FaultSpec,
    workload: &WorkloadSpec,
    seed: u64,
    target: SloTarget,
    gpn: usize,
    prefix_cache: Option<PrefixCacheConfig>,
) -> anyhow::Result<()> {
    let build = |policy: RouterPolicy, faulty: bool| -> anyhow::Result<FleetSpec> {
        let mut s = base.fleet(replicas)?.with_router(policy).with_gpus_per_node(gpn)?;
        if let Some(cache) = prefix_cache {
            s = s.with_prefix_cache(cache)?;
        }
        if faulty {
            s = s.with_faults(faults.clone())?;
        }
        Ok(s)
    };
    let fault_desc = {
        let mut parts = Vec::new();
        if let Some(c) = &faults.churn {
            parts.push(format!("churn MTBF={}s MTTR={}s", c.mtbf_s, c.mttr_s));
        }
        for &(r, x) in &faults.stragglers {
            parts.push(format!("straggler r{r} x{x}"));
        }
        for w in &faults.degrade {
            parts.push(format!("wire x{} in [{}, {})s", w.factor, w.t0_s, w.t1_s));
        }
        parts.join(", ")
    };
    println!(
        "fleet under failure: {} x{replicas}, seed {seed:#x} — {fault_desc}\n\
         goodput = error-free requests inside every set SLO target / offered \
         (no SLO flag: completion rate)\n",
        base.label()
    );
    let mut rows = Vec::new();
    for &policy in policies {
        let healthy = build(policy, false)?.simulate(workload, seed)?;
        let faulty = build(policy, true)?.simulate(workload, seed)?;
        rows.push(vec![
            policy.label().to_string(),
            format!("{:.3}", healthy.goodput(&target)),
            format!("{:.3}", faulty.goodput(&target)),
            format!("{:.4}", healthy.model.e2e.p99_s),
            format!("{:.4}", faulty.model.e2e.p99_s),
            faulty.retries.to_string(),
            format!("{:.4}", faulty.wasted_prefill_s),
            format!("{}/{}", faulty.completed, faulty.requests),
        ]);
    }
    print!(
        "{}",
        report::render_table(
            "router policies, healthy vs under faults (same seed: paired runs)",
            &[
                "Router",
                "goodput",
                "goodput (faults)",
                "E2E p99 (s)",
                "E2E p99 (faults)",
                "retries",
                "wasted prefill (s)",
                "served",
            ],
            &rows,
        )
    );
    Ok(())
}

/// The elastic mode of `fleet`: every static size in the elastic range
/// vs one autoscaled fleet on the same seed — elasticity actions
/// (cold starts, drains, live KV migrations) priced on the model clock.
#[allow(clippy::too_many_arguments)]
fn fleet_autoscale_table(
    base: &commsim::plan::DeploymentPlan,
    f: &Flags,
    workload: &WorkloadSpec,
    seed: u64,
    gpn: usize,
    prefix_cache: Option<PrefixCacheConfig>,
    router: RouterPolicy,
    max_replicas: usize,
    slo_e2e: Option<f64>,
) -> anyhow::Result<()> {
    let target_q = f.float("autoscale", 4.0)?;
    anyhow::ensure!(
        target_q > 0.0 && target_q.is_finite(),
        "--autoscale wants a positive target queue depth (got {target_q})"
    );
    let min = f.num("min_replicas", 1)?;
    let window = f.float("scale_window", 0.5)?;
    let mut policy = AutoscalePolicy::target_queue(min, max_replicas, target_q, window);
    if let Some(slo) = slo_e2e {
        // The SLO flag both judges goodput and arms the policy's
        // rolling-percentile scale-up trigger.
        policy = policy.with_slo_e2e_p95(slo);
    }
    let finish = |mut s: FleetSpec| -> anyhow::Result<FleetSpec> {
        s = s.with_router(router).with_gpus_per_node(gpn)?;
        if let Some(cache) = prefix_cache {
            s = s.with_prefix_cache(cache)?;
        }
        Ok(s)
    };
    let target = SloTarget { e2e_p95_s: slo_e2e, ..SloTarget::default() };
    println!(
        "elastic fleet: {} x[{min}..{max_replicas}], seed {seed:#x} — target \
         queue depth {target_q}, window {window}s{}\n\
         goodput = error-free requests inside every set SLO target / offered \
         (no SLO flag: completion rate)\n",
        base.label(),
        match slo_e2e {
            Some(s) => format!(", SLO trigger E2E p95 <= {s}s"),
            None => String::new(),
        }
    );
    let row = |label: String, s: &fleet::FleetSummary| -> Vec<String> {
        vec![
            label,
            format!("{:.3}", s.goodput(&target)),
            format!("{:.4}", s.model.e2e.p99_s),
            format!("{:.3}", s.provisioned_gpu_s),
            format!("{} ({:.1} ms)", s.cold_starts, s.cold_start_s * 1e3),
            s.migrations.to_string(),
            if s.kv_migration_bytes > 0.0 {
                format!(
                    "{} ({:.2} ms)",
                    report::fmt_bytes(s.kv_migration_bytes),
                    s.kv_migration_s * 1e3
                )
            } else {
                "-".to_string()
            },
            format!("{}/{}", s.completed, s.requests),
        ]
    };
    let mut rows = Vec::new();
    for n in min..=max_replicas {
        let summary = finish(base.fleet(n)?)?.simulate(workload, seed)?;
        rows.push(row(format!("static x{n}"), &summary));
    }
    let elastic = finish(base.fleet(max_replicas)?.with_autoscale(policy)?)?
        .simulate(workload, seed)?;
    rows.push(row(format!("elastic {min}..{max_replicas}"), &elastic));
    print!(
        "{}",
        report::render_table(
            "static sizes vs the elastic fleet (same seed: paired runs)",
            &[
                "Fleet",
                "goodput",
                "E2E p99 (s)",
                "GPU*s provisioned",
                "cold starts",
                "migrations",
                "KV migrated",
                "served",
            ],
            &rows,
        )
    );
    Ok(())
}

fn cmd_fleet(f: &Flags) -> anyhow::Result<()> {
    let (sp, sd) = (f.num("sp", 128)?, f.num("sd", 16)?);
    let requests = f.num("requests", 24)?;
    let rate = f.float("arrival_rate", 8.0)?;
    anyhow::ensure!(rate > 0.0, "--arrival-rate must be positive (req/s)");
    let seed = f.num("seed", 0xC0FFEE)? as u64;
    let burst = f.num("burst", 1)?;
    anyhow::ensure!(burst >= 1, "--burst must be >= 1");
    let router_name = f.str("router", "least-tokens");
    let router = RouterPolicy::parse(&router_name).ok_or_else(|| {
        anyhow::anyhow!("--router '{router_name}' unknown (rr|least-tokens|shortest-queue)")
    })?;
    let max_replicas = f.num("replicas_max", 3)?;
    anyhow::ensure!(max_replicas >= 1, "--replicas-max must be >= 1");
    // The SLO target is opt-in: without the flag the sweep reports
    // percentiles only, judging nothing the user never asked about.
    let slo_e2e = match f.opt("slo_e2e_p95") {
        Some(_) => Some(f.float("slo_e2e_p95", 1.0)?),
        None => None,
    };
    let gpn = f.num("gpus_per_node", 4)?;
    // Candidate execution strategy for the capacity sweep. Threaded and
    // sequential runs are bitwise-identical (asserted in-tree and
    // byte-diffed in CI), so the flag only trades wall-clock — the
    // chosen mode never appears in stdout.
    let sweep_mode = f.str("sweep", "threaded");
    anyhow::ensure!(
        matches!(sweep_mode.as_str(), "threaded" | "sequential"),
        "--sweep '{sweep_mode}' unknown (threaded|sequential)"
    );

    // Shared-prefix traffic: the profile shapes the workload's prompts
    // (and enables per-replica prefix caches on every candidate fleet).
    let shared = f.num("prefix_shared", sp / 2)?;
    let groups = f.num("prefix_groups", 8)?;
    let profile = match f.str("prefix_profile", "none").as_str() {
        "none" => None,
        "system" | "system-prompt" => Some(PrefixProfile::SystemPrompt { shared }),
        "multi-turn" | "multiturn" => {
            Some(PrefixProfile::MultiTurn { conversations: groups, shared })
        }
        "few-shot" | "fewshot" => Some(PrefixProfile::FewShot {
            templates: groups,
            shared,
            zero_shot_weight: 0.25,
        }),
        other => anyhow::bail!(
            "--prefix-profile '{other}' unknown (none|system|multi-turn|few-shot)"
        ),
    };
    // A flag must never be silently ignored while numbers come out (same
    // rule as the per-subcommand allow-lists): the prefix-shape knobs
    // only mean something under a profile.
    if profile.is_none() {
        for flag in ["prefix_shared", "prefix_groups"] {
            anyhow::ensure!(
                f.opt(flag).is_none(),
                "--{} needs --prefix-profile system|multi-turn|few-shot \
                 (prefix-free traffic has no shared prefix to shape)",
                flag.replace('_', "-")
            );
        }
    }
    let cache_mb = f.num("prefix_cache_mb", 64)?;
    anyhow::ensure!(cache_mb >= 1, "--prefix-cache-mb must be >= 1");
    let prefix_cache = (profile.is_some() || f.opt("prefix_cache_mb").is_some())
        .then_some(PrefixCacheConfig { block_tokens: 16, capacity_bytes: cache_mb << 20 });

    let tuning = tuning_flags(f)?;
    let tuned = |mut b: commsim::plan::Deployment| -> commsim::plan::Deployment {
        if let Some((bits, ov)) = tuning {
            b = b.collective_tuning(bits, ov);
        }
        b
    };
    // Chunked prefill applies to the colocated replicas and the
    // disaggregated *decode* pool (where intake prefills interleave with
    // running decodes); the prefill pool runs whole prompts back to back
    // with nothing to interleave, so it never takes the knob.
    let chunk = chunk_flag(f)?;
    let chunked = |b: commsim::plan::Deployment| -> commsim::plan::Deployment {
        match chunk {
            Some(tokens) => b.chunked_prefill(tokens),
            None => b,
        }
    };
    let (tp, pp) = (f.num("tp", 2)?, f.num("pp", 1)?);
    let base = chunked(tuned(
        Deployment::builder().model(&f.str("model", "8b")).tp(tp).pp(pp).workload(sp, sd),
    ))
    .build()?;
    let arch = base.arch().clone();
    let workload = WorkloadSpec {
        arrivals: if burst > 1 {
            ArrivalProcess::bursty(rate, burst)
        } else {
            ArrivalProcess::poisson(rate)
        },
        prompt: LengthDist::Fixed(sp),
        decode: LengthDist::Fixed(sd),
        prefix: profile,
        requests,
    };
    workload.validate()?;

    // Fault flags switch `fleet` into serving-under-failure mode: the
    // capacity sweep compares fleet shapes, the churn table compares
    // router policies on one fixed fleet, healthy vs faulty, same seed.
    let faults = fleet_faults(f)?;

    // --autoscale switches `fleet` into elastic mode: static fleets at
    // every size in the elastic range vs one autoscaled fleet, same seed.
    if f.opt("autoscale").is_some() {
        anyhow::ensure!(
            faults.is_none(),
            "--autoscale and fault injection are separate `fleet` modes — \
             drop one of them"
        );
        anyhow::ensure!(
            f.opt("sweep").is_none(),
            "--sweep picks the capacity sweep's execution; the autoscale \
             comparison runs its fleets one at a time"
        );
        if let Some((bits, ov)) = tuning {
            println!("collective tuning: wire-bits={bits} overlap={ov}");
        }
        if let Some(tokens) = chunk {
            println!("chunked prefill: budget={tokens} tokens");
        }
        return fleet_autoscale_table(
            &base,
            f,
            &workload,
            seed,
            gpn,
            prefix_cache,
            router,
            max_replicas,
            slo_e2e,
        );
    }
    // The policy-shape knobs only mean something under --autoscale (same
    // no-silent-ignore rule as the prefix knobs above).
    for flag in ["min_replicas", "scale_window"] {
        anyhow::ensure!(
            f.opt(flag).is_none(),
            "--{} shapes the --autoscale policy; it needs --autoscale Q",
            flag.replace('_', "-")
        );
    }

    if !faults.is_none() {
        anyhow::ensure!(
            f.opt("sweep").is_none(),
            "--sweep picks the capacity sweep's execution; the churn table \
             runs its fleets one at a time"
        );
        let policies = match f.opt("router") {
            // An explicit --router narrows the table to that policy.
            Some(_) => vec![router],
            None => vec![
                RouterPolicy::RoundRobin,
                RouterPolicy::LeastOutstandingTokens,
                RouterPolicy::ShortestQueue,
                RouterPolicy::CacheAffinity,
            ],
        };
        let target = SloTarget { e2e_p95_s: slo_e2e, ..SloTarget::default() };
        if let Some((bits, ov)) = tuning {
            println!("collective tuning: wire-bits={bits} overlap={ov}");
        }
        if let Some(tokens) = chunk {
            println!("chunked prefill: budget={tokens} tokens");
        }
        return fleet_churn_table(
            &base,
            max_replicas,
            &policies,
            &faults,
            &workload,
            seed,
            target,
            gpn,
            prefix_cache,
        );
    }

    // Candidates: colocated fleets of the base layout at every size, plus
    // one disaggregated configuration following the paper's per-stage
    // recommendation — a TP-heavy prefill pool (TTFT-optimal) feeding a
    // PP-heavy decode pool (volume-optimal), KV handoff priced on the α–β
    // link model.
    let mut specs = Vec::with_capacity(max_replicas + 1);
    let finish = |mut s: FleetSpec| -> anyhow::Result<FleetSpec> {
        s = s.with_router(router).with_gpus_per_node(gpn)?;
        if let Some(cache) = prefix_cache {
            s = s.with_prefix_cache(cache)?;
        }
        Ok(s)
    };
    for n in 1..=max_replicas {
        specs.push(finish(base.fleet(n)?)?);
    }
    let prefill_plan = if arch.supports_tp(4) {
        tuned(Deployment::builder().arch(arch.clone()).tp(4).pp(1).workload(sp, sd)).build()?
    } else if chunk.is_some() {
        // Chunk-free copy of the base layout (see above: the prefill
        // pool never chunks).
        tuned(Deployment::builder().arch(arch.clone()).tp(tp).pp(pp).workload(sp, sd))
            .build()?
    } else {
        base.clone()
    };
    let decode_plan = if arch.supports_pp(4) {
        chunked(tuned(Deployment::builder().arch(arch.clone()).tp(1).pp(4).workload(sp, sd)))
            .build()?
    } else {
        base.clone()
    };
    specs.push(finish(FleetSpec::disaggregated(&prefill_plan, 1, &decode_plan, 1)?)?);

    println!(
        "fleet capacity sweep: model={} workload={requests}x(Sp={sp}, Sd={sd}) \
         arrivals={} rate={rate}/s seed={seed:#x} router={}{}{}{}",
        arch.name,
        if burst > 1 {
            format!("bursty({burst})")
        } else {
            "Poisson".to_string()
        },
        router.label(),
        match &workload.prefix {
            Some(p) => format!(
                " prefix={}(shared={shared}, groups={groups}, cache={cache_mb}MiB)",
                p.label()
            ),
            None => String::new(),
        },
        tuning_desc(tuning),
        chunk_desc(chunk)
    );
    let target = SloTarget { e2e_p95_s: slo_e2e, ..SloTarget::default() };
    let sweep_start = std::time::Instant::now();
    let candidates = if sweep_mode == "sequential" {
        fleet::capacity_sweep_sequential(specs, &workload, seed, target)?
    } else {
        fleet::capacity_sweep(specs, &workload, seed, target)?
    };
    let sweep_wall = sweep_start.elapsed().as_secs_f64();
    let sim_events: u64 = candidates.iter().map(|c| c.summary.events).sum();
    // Advisory wall-clock rate on stderr only: seeded stdout stays
    // byte-identical across runs, machines, and --sweep modes.
    eprintln!(
        "sweep wall: {sweep_wall:.3} s, {sim_events} DES events ({:.0} events/s)",
        sim_events as f64 / sweep_wall.max(1e-9)
    );

    let mut rows = Vec::new();
    for c in &candidates {
        let m = &c.summary.model;
        rows.push(vec![
            c.spec.label(),
            c.spec.total_gpus().to_string(),
            format!("{:.1}", m.tokens_per_s),
            format!("{:.1} / {:.1}", m.ttft.p50_s * 1e3, m.ttft.p95_s * 1e3),
            format!("{:.2} / {:.2}", m.tpot.p50_s * 1e3, m.tpot.p95_s * 1e3),
            format!("{:.3} / {:.3}", m.e2e.p50_s, m.e2e.p95_s),
            if c.summary.kv_transfer_bytes > 0.0 {
                format!(
                    "{} ({:.2} ms)",
                    report::fmt_bytes(c.summary.kv_transfer_bytes),
                    c.summary.kv_transfer_s * 1e3
                )
            } else {
                "-".to_string()
            },
            if c.summary.cached_prompt_tokens > 0 {
                format!(
                    "{} tok ({:.1} ms)",
                    c.summary.cached_prompt_tokens,
                    c.summary.saved_prefill_s * 1e3
                )
            } else {
                "-".to_string()
            },
            match slo_e2e {
                Some(_) if c.meets_slo => "yes".to_string(),
                Some(_) => "no".to_string(),
                None => "-".to_string(),
            },
        ]);
    }
    print!(
        "{}",
        report::render_table(
            "fleet sweep — model-time SLOs per fleet configuration",
            &[
                "Fleet",
                "GPUs",
                "tok/s",
                "TTFT p50/p95 (ms)",
                "TPOT p50/p95 (ms)",
                "E2E p50/p95 (s)",
                "KV handoff",
                "Prefix hits (saved)",
                "SLO",
            ],
            &rows,
        )
    );
    // Tuned sweeps report what the quantized/overlapped collectives
    // bought, fleet-wide (absent without the flags — seeded default
    // stdout stays byte-identical).
    if tuning.is_some() {
        let saved: f64 = candidates.iter().map(|c| c.summary.wire_saved_bytes).sum();
        let hidden: f64 = candidates.iter().map(|c| c.summary.hidden_comm_s).sum();
        println!(
            "collective tuning across all candidates: {} saved on the wire, \
             {:.3} ms of comm hidden by overlap",
            report::fmt_bytes(saved),
            hidden * 1e3
        );
    }
    // Chunked sweeps report the interference ledger per candidate
    // (absent without the flag — seeded default stdout stays
    // byte-identical).
    if chunk.is_some() {
        println!("chunked prefill (requests split / decode interference priced):");
        for c in &candidates {
            println!(
                "  {}: {} split, {:.3} ms",
                c.spec.label(),
                c.summary.chunked_requests,
                c.summary.interference_s * 1e3
            );
        }
    }
    match slo_e2e {
        Some(slo) => match fleet::cheapest(&candidates) {
            Some(c) => println!(
                "\ncheapest fleet meeting E2E p95 <= {slo:.2} s: {} ({} GPUs, \
                 E2E p95 {:.3} s)",
                c.spec.label(),
                c.spec.total_gpus(),
                c.summary.model.e2e.p95_s
            ),
            None => println!(
                "\nno candidate meets E2E p95 <= {slo:.2} s — raise --replicas-max \
                 or relax the target"
            ),
        },
        None => println!(
            "\nset --slo-e2e-p95 <seconds> to report the cheapest fleet meeting \
             the target"
        ),
    }
    Ok(())
}

/// Compare two directories of `BENCH_*.json` artifacts (the bench-json
/// CI job's output from two runs) and fail on perf regressions.
fn cmd_bench_diff(f: &Flags) -> anyhow::Result<()> {
    let old_dir = f
        .opt("old")
        .ok_or_else(|| anyhow::anyhow!("bench-diff needs --old DIR (the baseline artifacts)"))?;
    let new_dir = f
        .opt("new")
        .ok_or_else(|| anyhow::anyhow!("bench-diff needs --new DIR (the current artifacts)"))?;
    let tolerance = f.float("tolerance", 0.05)?;
    let list = |dir: &str| -> anyhow::Result<Vec<String>> {
        let entries = std::fs::read_dir(dir)
            .map_err(|e| anyhow::anyhow!("reading bench dir '{dir}': {e}"))?;
        let mut names = Vec::new();
        for entry in entries {
            let entry = entry.map_err(|e| anyhow::anyhow!("reading bench dir '{dir}': {e}"))?;
            let name = entry.file_name().to_string_lossy().into_owned();
            if name.starts_with("BENCH_") && name.ends_with(".json") {
                names.push(name);
            }
        }
        names.sort();
        Ok(names)
    };
    let old_names = list(old_dir)?;
    let new_names = list(new_dir)?;
    anyhow::ensure!(
        !new_names.is_empty(),
        "no BENCH_*.json artifacts in '{new_dir}' — nothing to gate on"
    );
    println!(
        "bench-diff: {} baseline vs {} current artifacts, tolerance {:.1}%",
        old_names.len(),
        new_names.len(),
        tolerance * 100.0
    );
    for name in &old_names {
        if !new_names.contains(name) {
            println!("  {name}: only in baseline (bench removed?)");
        }
    }
    let mut regressions = 0usize;
    for name in &new_names {
        if !old_names.contains(name) {
            println!("  {name}: new bench, no baseline to diff against");
            continue;
        }
        let read = |dir: &str| -> anyhow::Result<report::BenchJson> {
            let path = format!("{dir}/{name}");
            let text = std::fs::read_to_string(&path)
                .map_err(|e| anyhow::anyhow!("reading '{path}': {e}"))?;
            report::parse_bench_json(&text)
                .map_err(|e| anyhow::anyhow!("parsing '{path}': {e}"))
        };
        let diff = report::bench_diff(&read(old_dir)?, &read(new_dir)?, tolerance)?;
        // Wall time is advisory: shown for trend-watching, never gated
        // on (host clocks are machine- and load-dependent).
        let wall = match &diff.wall {
            Some(w) => format!(" [wall {:.2}s -> {:.2}s, advisory]", w.old, w.new),
            None => String::new(),
        };
        if diff.is_clean() {
            println!("  {name}: OK{wall}");
            continue;
        }
        println!(
            "  {name}: {} regressions, {} improvements, {} notes{wall}",
            diff.regressions.len(),
            diff.improvements.len(),
            diff.notes.len()
        );
        for d in &diff.regressions {
            println!(
                "    REGRESSION row {} '{}': {} -> {} (+{:.1}%)",
                d.row.map(|r| r.to_string()).unwrap_or_else(|| "-".into()),
                d.field,
                d.old,
                d.new,
                d.ratio() * 100.0
            );
        }
        for d in &diff.improvements {
            println!(
                "    improvement row {} '{}': {} -> {} ({:.1}%)",
                d.row.map(|r| r.to_string()).unwrap_or_else(|| "-".into()),
                d.field,
                d.old,
                d.new,
                d.ratio() * 100.0
            );
        }
        for n in &diff.notes {
            println!("    note: {n}");
        }
        regressions += diff.regressions.len();
    }
    anyhow::ensure!(
        regressions == 0,
        "{regressions} perf regression(s) past the {:.1}% tolerance",
        tolerance * 100.0
    );
    println!("bench-diff OK: no regression past the tolerance");
    Ok(())
}

fn cmd_tables() -> anyhow::Result<()> {
    let cases: Vec<(&str, ModelArch, Vec<(usize, usize)>)> = vec![
        ("Table III (TP)", ModelArch::llama31_8b(), vec![(2, 1), (4, 1)]),
        ("Table V (PP)", ModelArch::llama31_8b(), vec![(1, 2), (1, 4)]),
        ("Table VI (hybrid)", ModelArch::llama31_8b(), vec![(2, 2)]),
    ];
    for (label, arch, layouts) in cases {
        for (tp, pp) in layouts {
            let plan = Deployment::builder()
                .arch(arch.clone())
                .tp(tp)
                .pp(pp)
                .workload(128, 128)
                .build()?;
            let summary = plan.trace()?;
            print!(
                "{}",
                report::comparison_table(
                    &format!("{label} {}", plan.layout().label()),
                    plan.arch(),
                    plan.layout(),
                    plan.shape(),
                    &summary,
                )
            );
            println!();
        }
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        eprint!("{USAGE}");
        std::process::exit(2);
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "analyze" => cmd_analyze(&Flags::parse("analyze", rest, ANALYZE_FLAGS)?),
        "trace" => cmd_trace(&Flags::parse("trace", rest, TRACE_FLAGS)?),
        "slo" => cmd_slo(&Flags::parse("slo", rest, SLO_FLAGS)?),
        "serve" => cmd_serve(&Flags::parse("serve", rest, SERVE_FLAGS)?),
        "fleet" => cmd_fleet(&Flags::parse("fleet", rest, FLEET_FLAGS)?),
        "bench-diff" => cmd_bench_diff(&Flags::parse("bench-diff", rest, BENCH_DIFF_FLAGS)?),
        "tables" => {
            Flags::parse("tables", rest, TABLES_FLAGS)?;
            cmd_tables()
        }
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => {
            eprint!("unknown command '{other}'\n\n{USAGE}");
            std::process::exit(2);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Vec<String> {
        s.iter().map(|a| a.to_string()).collect()
    }

    #[test]
    fn accepts_known_flags_and_applies_defaults() {
        let f = Flags::parse("slo", &args(&["--tp", "4", "--gpus-per-node", "8"]), SLO_FLAGS)
            .unwrap();
        assert_eq!(f.num("tp", 2).unwrap(), 4);
        assert_eq!(f.num("gpus_per_node", 4).unwrap(), 8);
        assert_eq!(f.num("pp", 1).unwrap(), 1);
        assert_eq!(f.str("model", "3b"), "3b");
    }

    #[test]
    fn rejects_unknown_flag_with_suggestion() {
        let err = Flags::parse("slo", &args(&["--ppp", "2"]), SLO_FLAGS).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("unknown flag --ppp"), "{msg}");
        assert!(msg.contains("did you mean --pp?"), "{msg}");
        assert!(msg.contains("--gpus-per-node"), "{msg}");
    }

    #[test]
    fn rejects_flags_foreign_to_the_subcommand() {
        // --gpus-per-node belongs to `slo`, not `analyze`.
        let err =
            Flags::parse("analyze", &args(&["--gpus-per-node", "4"]), ANALYZE_FLAGS).unwrap_err();
        assert!(err.to_string().contains("unknown flag --gpus-per-node"), "{err}");
        // `tables` takes nothing at all.
        let err = Flags::parse("tables", &args(&["--model", "8b"]), TABLES_FLAGS).unwrap_err();
        assert!(err.to_string().contains("takes no flags"), "{err}");
    }

    #[test]
    fn rejects_missing_values_and_bare_words() {
        assert!(Flags::parse("trace", &args(&["--tp"]), TRACE_FLAGS).is_err());
        assert!(Flags::parse("trace", &args(&["tp", "2"]), TRACE_FLAGS).is_err());
    }

    #[test]
    fn serve_accepts_seed_flag() {
        let f = Flags::parse(
            "serve",
            &args(&["--arrival-rate", "50", "--seed", "7"]),
            SERVE_FLAGS,
        )
        .unwrap();
        assert_eq!(f.num("seed", 0xC0FFEE).unwrap(), 7);
        assert_eq!(f.float("arrival_rate", 0.0).unwrap(), 50.0);
        // Default when omitted: the historical constant.
        let f = Flags::parse("serve", &args(&["--arrival-rate", "50"]), SERVE_FLAGS).unwrap();
        assert_eq!(f.num("seed", 0xC0FFEE).unwrap(), 0xC0FFEE);
    }

    #[test]
    fn fleet_flags_parse_with_defaults() {
        let f = Flags::parse(
            "fleet",
            &args(&["--replicas-max", "2", "--router", "rr", "--slo-e2e-p95", "0.5"]),
            FLEET_FLAGS,
        )
        .unwrap();
        assert_eq!(f.num("replicas_max", 3).unwrap(), 2);
        assert_eq!(f.str("router", "least-tokens"), "rr");
        assert_eq!(f.float("slo_e2e_p95", 1.0).unwrap(), 0.5);
        assert_eq!(f.num("burst", 1).unwrap(), 1);
        // Foreign flags are rejected with a suggestion, like every other
        // subcommand.
        let err = Flags::parse("fleet", &args(&["--concurrency", "4"]), FLEET_FLAGS).unwrap_err();
        assert!(err.to_string().contains("unknown flag --concurrency"), "{err}");
        // Prefix-routing flags parse (dashes normalize to underscores).
        let f = Flags::parse(
            "fleet",
            &args(&[
                "--router",
                "affinity",
                "--prefix-profile",
                "multi-turn",
                "--prefix-shared",
                "96",
                "--prefix-groups",
                "6",
                "--prefix-cache-mb",
                "32",
            ]),
            FLEET_FLAGS,
        )
        .unwrap();
        assert_eq!(f.str("router", "least-tokens"), "affinity");
        assert_eq!(f.str("prefix_profile", "none"), "multi-turn");
        assert_eq!(f.num("prefix_shared", 64).unwrap(), 96);
        assert_eq!(f.num("prefix_groups", 8).unwrap(), 6);
        assert_eq!(f.num("prefix_cache_mb", 64).unwrap(), 32);
    }

    #[test]
    fn fleet_fault_flags_parse_and_build_a_fault_spec() {
        let f = Flags::parse(
            "fleet",
            &args(&[
                "--mtbf",
                "2.5",
                "--mttr",
                "0.5",
                "--straggler",
                "0:4.0,2:1.5",
                "--degrade",
                "0.5:1.5:4",
                "--seed",
                "7",
            ]),
            FLEET_FLAGS,
        )
        .unwrap();
        let faults = fleet_faults(&f).unwrap();
        assert!(!faults.is_none());
        let churn = faults.churn.unwrap();
        assert_eq!(churn.mtbf_s, 2.5);
        assert_eq!(churn.mttr_s, 0.5);
        assert_eq!(faults.stragglers, vec![(0, 4.0), (2, 1.5)]);
        assert_eq!(faults.degrade.len(), 1);
        assert_eq!(faults.wire_factor(1.0), 4.0);
        // MTTR defaults to MTBF/10.
        let f = Flags::parse("fleet", &args(&["--mtbf", "10"]), FLEET_FLAGS).unwrap();
        assert_eq!(fleet_faults(&f).unwrap().churn.unwrap().mttr_s, 1.0);
        // No fault flags: the empty spec (sweep mode).
        let f = Flags::parse("fleet", &args(&[]), FLEET_FLAGS).unwrap();
        assert!(fleet_faults(&f).unwrap().is_none());
        // --mttr without --mtbf is never silently ignored.
        let f = Flags::parse("fleet", &args(&["--mttr", "0.5"]), FLEET_FLAGS).unwrap();
        let err = fleet_faults(&f).unwrap_err();
        assert!(err.to_string().contains("--mtbf"), "{err}");
    }

    #[test]
    fn fleet_sweep_flag_parses() {
        let f = Flags::parse("fleet", &args(&["--sweep", "sequential"]), FLEET_FLAGS).unwrap();
        assert_eq!(f.str("sweep", "threaded"), "sequential");
        // Default when the flag is omitted.
        let f = Flags::parse("fleet", &args(&[]), FLEET_FLAGS).unwrap();
        assert_eq!(f.str("sweep", "threaded"), "threaded");
    }

    #[test]
    fn fleet_autoscale_flags_parse_with_defaults() {
        let f = Flags::parse(
            "fleet",
            &args(&[
                "--autoscale",
                "2.5",
                "--min-replicas",
                "1",
                "--scale-window",
                "0.25",
                "--replicas-max",
                "4",
            ]),
            FLEET_FLAGS,
        )
        .unwrap();
        assert_eq!(f.float("autoscale", 4.0).unwrap(), 2.5);
        assert_eq!(f.num("min_replicas", 1).unwrap(), 1);
        assert_eq!(f.float("scale_window", 0.5).unwrap(), 0.25);
        assert_eq!(f.num("replicas_max", 3).unwrap(), 4);
        // Omitted knobs fall back to their documented defaults.
        let f = Flags::parse("fleet", &args(&["--autoscale", "4"]), FLEET_FLAGS).unwrap();
        assert_eq!(f.num("min_replicas", 1).unwrap(), 1);
        assert_eq!(f.float("scale_window", 0.5).unwrap(), 0.5);
        // The policy the flags assemble validates.
        AutoscalePolicy::target_queue(1, 4, 2.5, 0.25).validate().unwrap();
    }

    #[test]
    fn tuning_flags_parse_uniformly_across_subcommands() {
        for (cmd, flags) in [
            ("analyze", ANALYZE_FLAGS),
            ("trace", TRACE_FLAGS),
            ("serve", SERVE_FLAGS),
            ("fleet", FLEET_FLAGS),
        ] {
            let f = Flags::parse(cmd, &args(&["--wire-bits", "8", "--overlap", "0.5"]), flags)
                .unwrap();
            assert_eq!(tuning_flags(&f).unwrap(), Some((8, 0.5)), "{cmd}");
            // Without the flags: no tuning, so the builder is untouched
            // and the run stays bitwise-default.
            let f = Flags::parse(cmd, &args(&[]), flags).unwrap();
            assert_eq!(tuning_flags(&f).unwrap(), None, "{cmd}");
        }
        // One flag implies the other's default.
        let f = Flags::parse("analyze", &args(&["--wire-bits", "4"]), ANALYZE_FLAGS).unwrap();
        assert_eq!(tuning_flags(&f).unwrap(), Some((4, 0.0)));
        let f = Flags::parse("analyze", &args(&["--overlap", "0.25"]), ANALYZE_FLAGS).unwrap();
        assert_eq!(tuning_flags(&f).unwrap(), Some((16, 0.25)));
        // Headers describe tuned runs and stay byte-identical otherwise.
        assert_eq!(tuning_desc(Some((8, 0.5))), " wire-bits=8 overlap=0.5");
        assert_eq!(tuning_desc(None), "");
        // Domain validation is the plan's, not the CLI's: a width the
        // model doesn't price surfaces as the typed PlanError.
        let err = Deployment::builder()
            .model("8b")
            .tp(2)
            .workload(64, 8)
            .collective_tuning(12, 0.0)
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("wire precision"), "{err}");
        // `slo` keeps its strict flag set (uniformity is for the four
        // subcommands that price serving paths).
        let err = Flags::parse("slo", &args(&["--wire-bits", "8"]), SLO_FLAGS).unwrap_err();
        assert!(err.to_string().contains("unknown flag --wire-bits"), "{err}");
    }

    #[test]
    fn chunk_flag_parses_on_serve_and_fleet_only() {
        for (cmd, flags) in [("serve", SERVE_FLAGS), ("fleet", FLEET_FLAGS)] {
            let f = Flags::parse(cmd, &args(&["--chunk-tokens", "512"]), flags).unwrap();
            assert_eq!(chunk_flag(&f).unwrap(), Some(512), "{cmd}");
            // Without the flag: no chunking, the builder is untouched
            // and every prefill stays one-shot, bitwise.
            let f = Flags::parse(cmd, &args(&[]), flags).unwrap();
            assert_eq!(chunk_flag(&f).unwrap(), None, "{cmd}");
        }
        // Headers describe chunked runs and stay byte-identical otherwise.
        assert_eq!(chunk_desc(Some(512)), " chunk-tokens=512");
        assert_eq!(chunk_desc(None), "");
        // Domain validation is the plan's: a zero budget surfaces as the
        // typed PlanError, not a mid-DES panic.
        let err = Deployment::builder()
            .model("8b")
            .tp(2)
            .workload(64, 8)
            .chunked_prefill(0)
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("budget must be >= 1"), "{err}");
        // analyze/trace describe one-shot request shapes; they reject
        // the serving-schedule knob outright.
        let err =
            Flags::parse("analyze", &args(&["--chunk-tokens", "256"]), ANALYZE_FLAGS).unwrap_err();
        assert!(err.to_string().contains("unknown flag --chunk-tokens"), "{err}");
    }

    #[test]
    fn fault_spec_value_parsers_reject_malformed_input() {
        assert_eq!(parse_stragglers("1:2.0").unwrap(), vec![(1, 2.0)]);
        assert!(parse_stragglers("1").is_err(), "missing factor");
        assert!(parse_stragglers("a:2").is_err(), "non-numeric replica");
        assert!(parse_stragglers("1:x").is_err(), "non-numeric factor");
        assert_eq!(parse_degrade("0:2:8").unwrap(), vec![(0.0, 2.0, 8.0)]);
        assert_eq!(parse_degrade("0:1:2,3:4:5").unwrap().len(), 2);
        assert!(parse_degrade("0:2").is_err(), "missing factor");
        assert!(parse_degrade("0:2:8:9").is_err(), "too many fields");
        assert!(parse_degrade("x:2:8").is_err(), "non-numeric bound");
    }

    #[test]
    fn bench_diff_flags_parse() {
        let f = Flags::parse(
            "bench-diff",
            &args(&["--old", "a", "--new", "b", "--tolerance", "0.1"]),
            BENCH_DIFF_FLAGS,
        )
        .unwrap();
        assert_eq!(f.opt("old").unwrap(), "a");
        assert_eq!(f.opt("new").unwrap(), "b");
        assert_eq!(f.float("tolerance", 0.05).unwrap(), 0.1);
        let err =
            Flags::parse("bench-diff", &args(&["--model", "8b"]), BENCH_DIFF_FLAGS).unwrap_err();
        assert!(err.to_string().contains("unknown flag --model"), "{err}");
    }

    #[test]
    fn rejects_repeated_flags() {
        let err =
            Flags::parse("slo", &args(&["--tp", "2", "--tp", "4"]), SLO_FLAGS).unwrap_err();
        assert!(err.to_string().contains("more than once"), "{err}");
    }

    #[test]
    fn edit_distance_basics() {
        assert_eq!(edit_distance("pp", "pp"), 0);
        assert_eq!(edit_distance("ppp", "pp"), 1);
        assert_eq!(edit_distance("modle", "model"), 2);
        assert_eq!(edit_distance("", "sd"), 2);
        assert_eq!(closest_flag("ppp", SLO_FLAGS), Some("pp"));
        assert_eq!(closest_flag("zzzzz", SLO_FLAGS), None);
    }
}
