//! Transformer model descriptions: the paper's evaluation architectures and
//! the tiny real model the engine serves numerically.

pub mod arch;

pub use arch::{ModelArch, DTYPE_BYTES_BF16, DTYPE_BYTES_F32};
