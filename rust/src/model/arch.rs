//! Architecture registry (Table I variables: h, L, a, d_head, v, …).
//!
//! The paper evaluates three dense Llama-family models (§V); their
//! dimensions determine every communication count and message size, so the
//! registry is the ground truth the analytical models and the structural
//! engine share. Byte-exact cross-checks against the paper's Table IV live
//! in the unit tests below.


/// BF16 — the serving dtype used in all of the paper's experiments.
pub const DTYPE_BYTES_BF16: usize = 2;
/// F32 — the dtype of the tiny numeric-mode model (deterministic CPU PJRT).
pub const DTYPE_BYTES_F32: usize = 4;

/// Dense transformer architecture parameters (paper Table I).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelArch {
    /// Display name, e.g. "Llama-3.1-8B".
    pub name: String,
    /// Hidden dimension `h`.
    pub hidden: usize,
    /// Number of transformer layers `L`.
    pub layers: usize,
    /// Attention heads `a`.
    pub heads: usize,
    /// KV heads (GQA); equals `heads` for MHA. Does not change collective
    /// counts, only PP KV-transfer sizes in disaggregated setups.
    pub kv_heads: usize,
    /// Head dimension `d_head`.
    pub head_dim: usize,
    /// MLP intermediate (expanded) dimension.
    pub intermediate: usize,
    /// Vocabulary size `v`.
    pub vocab: usize,
}

impl ModelArch {
    /// Llama-3.2-3B (paper §V: L=28, h=3072, v=128256).
    pub fn llama32_3b() -> Self {
        Self {
            name: "Llama-3.2-3B".into(),
            hidden: 3072,
            layers: 28,
            heads: 24,
            kv_heads: 8,
            head_dim: 128,
            intermediate: 8192,
            vocab: 128_256,
        }
    }

    /// Llama-3.1-8B (paper §V: L=32, h=4096, v=128256).
    pub fn llama31_8b() -> Self {
        Self {
            name: "Llama-3.1-8B".into(),
            hidden: 4096,
            layers: 32,
            heads: 32,
            kv_heads: 8,
            head_dim: 128,
            intermediate: 14336,
            vocab: 128_256,
        }
    }

    /// Llama-2-13B (paper §V: L=40, h=5120, v=32000).
    pub fn llama2_13b() -> Self {
        Self {
            name: "Llama-2-13B".into(),
            hidden: 5120,
            layers: 40,
            heads: 40,
            kv_heads: 40,
            head_dim: 128,
            intermediate: 13824,
            vocab: 32_000,
        }
    }

    /// The tiny real model served numerically (mirrors python TINY config;
    /// dims must match artifacts/meta.json).
    pub fn tiny() -> Self {
        Self {
            name: "tiny-llama".into(),
            hidden: 256,
            layers: 4,
            heads: 8,
            kv_heads: 8,
            head_dim: 32,
            intermediate: 768,
            vocab: 512,
        }
    }

    /// Look up a registry model by (case-insensitive) short name.
    pub fn by_name(name: &str) -> Option<Self> {
        match name.to_ascii_lowercase().as_str() {
            "llama-3.2-3b" | "3b" => Some(Self::llama32_3b()),
            "llama-3.1-8b" | "8b" => Some(Self::llama31_8b()),
            "llama-2-13b" | "13b" => Some(Self::llama2_13b()),
            "tiny" | "tiny-llama" => Some(Self::tiny()),
            _ => None,
        }
    }

    /// The three paper evaluation models, in paper order (3B, 8B, 13B).
    pub fn paper_models() -> Vec<Self> {
        vec![Self::llama32_3b(), Self::llama31_8b(), Self::llama2_13b()]
    }

    /// Approximate parameter count (dense Llama layout, untied embeddings).
    pub fn param_count(&self) -> usize {
        let h = self.hidden;
        let qd = self.heads * self.head_dim;
        let kvd = self.kv_heads * self.head_dim;
        let attn = h * qd + 2 * h * kvd + qd * h;
        let mlp = 3 * h * self.intermediate;
        let norms = 2 * h;
        self.layers * (attn + mlp + norms) + 2 * self.vocab * h + h
    }

    /// Per-token KV cache bytes across all layers.
    pub fn kv_bytes_per_token(&self, dtype_bytes: usize) -> usize {
        2 * self.layers * self.kv_heads * self.head_dim * dtype_bytes
    }

    /// True iff the architecture divides evenly across `t` TP ranks.
    pub fn supports_tp(&self, t: usize) -> bool {
        t > 0
            && self.heads % t == 0
            && self.kv_heads % t == 0
            && self.intermediate % t == 0
            && self.vocab % t == 0
    }

    /// True iff layers split into `p` non-empty pipeline stages.
    pub fn supports_pp(&self, p: usize) -> bool {
        p > 0 && p <= self.layers
    }

    /// Layers owned by pipeline stage `s` of `p` (vLLM-style near-even
    /// split; earlier stages take the remainder).
    pub fn stage_layers(&self, p: usize, s: usize) -> usize {
        assert!(s < p, "stage {s} out of range for p={p}");
        let base = self.layers / p;
        let rem = self.layers % p;
        base + usize::from(s < rem)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table4_message_sizes_match_paper() {
        // Paper Table IV: AllReduce prefill message bytes at Sp=128, BF16.
        let cases = [
            (ModelArch::llama32_3b(), 786_432usize, 6_144usize, 57usize, 7_239usize),
            (ModelArch::llama31_8b(), 1_048_576, 8_192, 65, 8_255),
            (ModelArch::llama2_13b(), 1_310_720, 10_240, 81, 10_287),
        ];
        for (m, prefill_bytes, decode_bytes, prefill_count, decode_count) in cases {
            assert_eq!(128 * m.hidden * DTYPE_BYTES_BF16, prefill_bytes, "{}", m.name);
            assert_eq!(m.hidden * DTYPE_BYTES_BF16, decode_bytes, "{}", m.name);
            assert_eq!(2 * m.layers + 1, prefill_count, "{}", m.name);
            assert_eq!((2 * m.layers + 1) * 127, decode_count, "{}", m.name);
        }
    }

    #[test]
    fn table3_gather_slice_matches_paper() {
        // Paper Table III: Gather shape = v/t -> 64128 (TP=2), 32064 (TP=4).
        let m = ModelArch::llama31_8b();
        assert_eq!(m.vocab / 2, 64_128);
        assert_eq!(m.vocab / 4, 32_064);
    }

    #[test]
    fn registry_lookup() {
        assert_eq!(ModelArch::by_name("8b").unwrap().layers, 32);
        assert_eq!(ModelArch::by_name("LLAMA-2-13B").unwrap().hidden, 5120);
        assert!(ModelArch::by_name("70b").is_none());
        assert_eq!(ModelArch::paper_models().len(), 3);
    }

    #[test]
    fn param_counts_are_plausible() {
        let b = 1_000_000_000f64;
        let p3 = ModelArch::llama32_3b().param_count() as f64 / b;
        let p8 = ModelArch::llama31_8b().param_count() as f64 / b;
        let p13 = ModelArch::llama2_13b().param_count() as f64 / b;
        assert!((2.0..4.5).contains(&p3), "3B -> {p3}");
        assert!((6.5..9.5).contains(&p8), "8B -> {p8}");
        assert!((11.0..14.5).contains(&p13), "13B -> {p13}");
    }

    #[test]
    fn tp_divisibility() {
        let m = ModelArch::llama31_8b();
        for t in [1, 2, 4, 8] {
            assert!(m.supports_tp(t), "tp={t}");
        }
        assert!(!m.supports_tp(3));
        assert!(!m.supports_tp(0));
        let tiny = ModelArch::tiny();
        assert!(tiny.supports_tp(4));
        assert!(!tiny.supports_tp(16)); // vocab 512 / 16 = 32 ok, heads 8/16 no
    }

    #[test]
    fn stage_layers_partition_fully() {
        let m = ModelArch::llama32_3b(); // 28 layers
        for p in [1, 2, 4, 8] {
            let total: usize = (0..p).map(|s| m.stage_layers(p, s)).sum();
            assert_eq!(total, m.layers, "p={p}");
            // near-even: max-min <= 1
            let sizes: Vec<_> = (0..p).map(|s| m.stage_layers(p, s)).collect();
            assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
        }
    }

    #[test]
    fn kv_bytes_per_token() {
        let m = ModelArch::llama31_8b();
        // 2 * 32 layers * 8 kv heads * 128 dim * 2 bytes = 131072
        assert_eq!(m.kv_bytes_per_token(DTYPE_BYTES_BF16), 131_072);
    }
}
