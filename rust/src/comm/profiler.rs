//! Communication tracing — our analogue of the paper's PyTorch-profiler
//! methodology (§IV.B), but exact: every collective call site records one
//! [`CommRecord`] into a shared [`TraceSink`]; aggregation reproduces the
//! paper's table rows (per-op counts, shapes, total message sizes and
//! corrected volumes), with the paper's rank-selection conventions.
//!
//! When a [`crate::simtime::CostModel`] pricer is attached
//! ([`TraceSink::set_pricer`]), every record is priced *at record time*
//! ([`CommRecord::modeled_s`]): the trace then carries modeled α–β seconds
//! alongside bytes, aggregated per (op, stage, shape) row, per active
//! batch size, and per session step ([`TraceSummary::step_comm_s`]).

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::simtime::CostModel;

use super::CollectiveKind;

/// Inference stage a communication belongs to (paper splits every table
/// into Prefill / Decode columns).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Stage {
    Prefill,
    Decode,
}

impl Stage {
    pub fn label(&self) -> &'static str {
        match self {
            Stage::Prefill => "Prefill",
            Stage::Decode => "Decode",
        }
    }
}

/// One observed communication operation.
#[derive(Debug, Clone, PartialEq)]
pub struct CommRecord {
    pub op: CollectiveKind,
    pub stage: Stage,
    /// Global rank of the worker that issued the call.
    pub rank: usize,
    /// Participants in the group (collectives) or 2 (p2p).
    pub group_size: usize,
    /// Logical message shape as the profiler reports it (e.g. `[128, 4096]`
    /// for a prefill AllReduce; for AllGather the *gathered* output shape,
    /// matching Table VI).
    pub shape: Vec<usize>,
    /// Element count of `shape`.
    pub elems: usize,
    pub dtype_bytes: usize,
    /// Peer rank for Send/Recv.
    pub peer: Option<usize>,
    /// Iteration counter of the [`crate::engine::Session`] step that
    /// issued this op; `None` for collectives outside session-driven
    /// execution (raw library use, warmup).
    pub step: Option<u64>,
    /// Number of sequences in the forward pass that issued this op (the
    /// active batch size of the iteration — 1 for prefill and for the
    /// single-request `generate()` path); `None` outside sessions.
    pub batch: Option<usize>,
    /// Modeled α–β seconds of this operation, priced at record time by the
    /// sink's [`CostModel`] pricer; `0.0` when no pricer is attached.
    /// `Recv` records price to zero (the wire time lives on the `Send`).
    pub modeled_s: f64,
}

impl CommRecord {
    /// Raw message bytes (count × element size), the paper's
    /// "Total Message Size" axis in Figs. 4–5.
    pub fn message_bytes(&self) -> usize {
        self.elems * self.dtype_bytes
    }

    /// NCCL-corrected volume contribution (paper §V.B accounting).
    pub fn corrected_bytes(&self) -> f64 {
        self.message_bytes() as f64 * self.op.correction_factor(self.group_size)
    }
}

/// Thread-safe sink shared by all workers of an engine run.
#[derive(Debug, Default)]
pub struct TraceSink {
    records: Mutex<Vec<CommRecord>>,
    /// Summary-only mode: when `Some`, every record folds into this
    /// running [`TraceSummary`] at record time and the per-record `Vec`
    /// stays empty — consumers that only ever read [`Self::summary`]
    /// (the fleet DES) keep O(1) memory over million-record runs.
    /// Retained mode (`None`, the default) is unchanged and stays the
    /// path for trace/figure consumers that read [`Self::snapshot`].
    folded: Mutex<Option<TraceSummary>>,
    enabled: std::sync::atomic::AtomicBool,
    /// Iteration context stamped onto every record: the session step
    /// counter and the active batch size (0 = no context). The coordinator
    /// sets it before broadcasting a step command and all of the step's
    /// records land before its logits return, so a plain atomic pair is
    /// race-free.
    step: std::sync::atomic::AtomicU64,
    batch: std::sync::atomic::AtomicUsize,
    /// Prices every record at record time when attached. Set once by the
    /// engine before workers spawn — a `OnceLock` so the hot record path
    /// reads it without locking.
    pricer: std::sync::OnceLock<CostModel>,
}

impl TraceSink {
    pub fn new() -> Arc<Self> {
        Arc::new(Self {
            records: Mutex::new(Vec::new()),
            folded: Mutex::new(None),
            enabled: std::sync::atomic::AtomicBool::new(true),
            step: std::sync::atomic::AtomicU64::new(0),
            batch: std::sync::atomic::AtomicUsize::new(0),
            pricer: std::sync::OnceLock::new(),
        })
    }

    /// Disable recording (perf runs measure the engine without tracing).
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, std::sync::atomic::Ordering::Relaxed);
    }

    /// Switch between summary-only and retained tracing. In summary-only
    /// mode each record is folded into a running [`TraceSummary`] at
    /// record time via [`TraceSummary::fold`] — the same accumulation
    /// step [`TraceSummary::from_records`] runs, so [`Self::summary`] is
    /// bitwise-identical across modes — and the per-record `Vec` is
    /// never grown ([`Self::snapshot`] stays empty). Switching in either
    /// direction resets both stores so one summary never mixes streams.
    pub fn set_summary_only(&self, on: bool) {
        let mut folded = self.folded.lock().expect("sink poisoned");
        self.records.lock().expect("sink poisoned").clear();
        *folded = on.then(TraceSummary::default);
    }

    /// Attach the cost model that prices every subsequent record
    /// ([`CommRecord::modeled_s`]). First attachment wins; later calls
    /// are ignored (the sink is priced once, before workers spawn).
    pub fn set_pricer(&self, pricer: CostModel) {
        let _ = self.pricer.set(pricer);
    }

    /// Declare the iteration every subsequent record belongs to: session
    /// step counter and the batch that issued it (`batch >= 1`).
    pub fn set_iteration(&self, step: u64, batch: usize) {
        assert!(batch >= 1, "iteration batch must be >= 1");
        self.step.store(step, std::sync::atomic::Ordering::Relaxed);
        self.batch.store(batch, std::sync::atomic::Ordering::Relaxed);
    }

    /// Leave iteration context; subsequent records are untagged.
    pub fn clear_iteration(&self) {
        self.batch.store(0, std::sync::atomic::Ordering::Relaxed);
    }

    pub fn record(&self, mut rec: CommRecord) {
        if self.enabled.load(std::sync::atomic::Ordering::Relaxed) {
            let batch = self.batch.load(std::sync::atomic::Ordering::Relaxed);
            if batch > 0 {
                rec.step = Some(self.step.load(std::sync::atomic::Ordering::Relaxed));
                rec.batch = Some(batch);
            }
            if let Some(pricer) = self.pricer.get() {
                rec.modeled_s = pricer.price_record(&rec);
            }
            {
                let mut folded = self.folded.lock().expect("sink poisoned");
                if let Some(summary) = folded.as_mut() {
                    summary.fold(&rec);
                    return;
                }
            }
            self.records.lock().expect("sink poisoned").push(rec);
        }
    }

    pub fn len(&self) -> usize {
        self.records.lock().expect("sink poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.lock().expect("sink poisoned").is_empty()
    }

    pub fn clear(&self) {
        let mut folded = self.folded.lock().expect("sink poisoned");
        if let Some(summary) = folded.as_mut() {
            *summary = TraceSummary::default();
        }
        self.records.lock().expect("sink poisoned").clear();
    }

    /// Snapshot of all records (cloned; the engine keeps appending).
    pub fn snapshot(&self) -> Vec<CommRecord> {
        self.records.lock().expect("sink poisoned").clone()
    }

    pub fn summary(&self) -> TraceSummary {
        if let Some(summary) = self.folded.lock().expect("sink poisoned").as_ref() {
            return summary.clone();
        }
        TraceSummary::from_records(&self.snapshot())
    }
}

/// Aggregation key: (op, stage, shape) — one table row.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct AggKey {
    pub op: CollectiveKind,
    pub stage: Stage,
    pub shape: Vec<usize>,
}

/// Aggregated statistics for one (op, stage, shape) row.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct OpAggregate {
    pub count: usize,
    pub total_message_bytes: usize,
    pub corrected_volume_bytes: f64,
    /// Sum of the rows' modeled α–β seconds ([`CommRecord::modeled_s`]).
    /// Per-rank views give a rank's modeled communication time; the global
    /// view is an accounting sum (a d-member collective appears d times).
    pub modeled_time_s: f64,
}

/// Full aggregation of a trace, with the paper's viewing conventions.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TraceSummary {
    /// Global (all ranks) per-row aggregates.
    pub global: BTreeMap<AggKey, OpAggregate>,
    /// Per-rank aggregates: `per_rank[rank][key]`.
    pub per_rank: Vec<BTreeMap<AggKey, OpAggregate>>,
    /// Per-active-batch-size aggregates over the batch-tagged records
    /// (global across ranks): `per_batch[batch][key]`. Untagged records
    /// do not appear here.
    pub per_batch: BTreeMap<usize, BTreeMap<AggKey, OpAggregate>>,
    /// Modeled communication seconds per session step, with each
    /// operation counted once: a d-member collective's d records share
    /// its price, and a transfer's price lives on its `Send` record. For
    /// single-stage layouts (pp = 1) this equals the cost model's
    /// per-iteration comm term; with pipeline stages it sums every
    /// boundary link once (parallel TP links included) — an aggregate of
    /// serialized op time, not a critical path. Only step-tagged, priced
    /// records contribute.
    pub step_comm_s: BTreeMap<u64, f64>,
}

impl TraceSummary {
    pub fn from_records(records: &[CommRecord]) -> Self {
        let mut out = Self::default();
        for rec in records {
            out.fold(rec);
        }
        out
    }

    /// Fold one record into the aggregates — the single accumulation step
    /// shared by [`Self::from_records`] and the sink's summary-only mode
    /// ([`TraceSink::set_summary_only`]), so the two modes produce
    /// identical summaries by construction (same additions, same order).
    pub fn fold(&mut self, rec: &CommRecord) {
        if self.per_rank.len() <= rec.rank {
            self.per_rank.resize_with(rec.rank + 1, BTreeMap::new);
        }
        let key = AggKey {
            op: rec.op,
            stage: rec.stage,
            shape: rec.shape.clone(),
        };
        let add = |map: &mut BTreeMap<AggKey, OpAggregate>| {
            let agg = map.entry(key.clone()).or_default();
            agg.count += 1;
            agg.total_message_bytes += rec.message_bytes();
            agg.corrected_volume_bytes += rec.corrected_bytes();
            agg.modeled_time_s += rec.modeled_s;
        };
        add(&mut self.global);
        add(&mut self.per_rank[rec.rank]);
        if let Some(b) = rec.batch {
            add(self.per_batch.entry(b).or_default());
        }
        if let Some(step) = rec.step {
            if rec.modeled_s > 0.0 {
                // Count each op once: every member of a collective
                // records it at the same price, so the d records
                // share it; a Send is the transfer's single priced
                // record (Recv prices to zero).
                let share = match rec.op {
                    CollectiveKind::Send | CollectiveKind::Recv => rec.modeled_s,
                    _ => rec.modeled_s / rec.group_size.max(1) as f64,
                };
                *self.step_comm_s.entry(step).or_insert(0.0) += share;
            }
        }
    }

    /// Count for (op, stage) summed over shapes, global across ranks.
    pub fn global_count(&self, op: CollectiveKind, stage: Stage) -> usize {
        self.global
            .iter()
            .filter(|(k, _)| k.op == op && k.stage == stage)
            .map(|(_, v)| v.count)
            .sum()
    }

    /// Count for (op, stage) as observed by one rank.
    pub fn rank_count(&self, rank: usize, op: CollectiveKind, stage: Stage) -> usize {
        self.per_rank
            .get(rank)
            .map(|m| {
                m.iter()
                    .filter(|(k, _)| k.op == op && k.stage == stage)
                    .map(|(_, v)| v.count)
                    .sum()
            })
            .unwrap_or(0)
    }

    /// The paper's table convention for TP / hybrid (Tables III, VI):
    /// per-op statistics from the rank that observes the most of that op
    /// (profiles merge rank views; rank 0 is excluded in §IV.B, which the
    /// max over ranks reproduces since TP peers see identical streams).
    pub fn paper_view(&self, op: CollectiveKind, stage: Stage) -> OpAggregate {
        let mut best = OpAggregate::default();
        for m in &self.per_rank {
            let mut agg = OpAggregate::default();
            for (k, v) in m.iter().filter(|(k, _)| k.op == op && k.stage == stage) {
                let _ = k;
                agg.count += v.count;
                agg.total_message_bytes += v.total_message_bytes;
                agg.corrected_volume_bytes += v.corrected_volume_bytes;
                agg.modeled_time_s += v.modeled_time_s;
            }
            if agg.count > best.count {
                best = agg;
            }
        }
        best
    }

    /// Distinct active batch sizes observed in the trace (from
    /// session-tagged records), ordered.
    pub fn batch_sizes(&self) -> Vec<usize> {
        self.per_batch.keys().copied().collect()
    }

    /// Aggregate for (op, stage) over records tagged with active batch
    /// size `batch` (global across ranks, summed over shapes) — the
    /// comm-volume-vs-batch-size axis of batched decode accounting.
    pub fn batch_view(&self, batch: usize, op: CollectiveKind, stage: Stage) -> OpAggregate {
        let mut agg = OpAggregate::default();
        if let Some(m) = self.per_batch.get(&batch) {
            for v in m
                .iter()
                .filter(|(k, _)| k.op == op && k.stage == stage)
                .map(|(_, v)| v)
            {
                agg.count += v.count;
                agg.total_message_bytes += v.total_message_bytes;
                agg.corrected_volume_bytes += v.corrected_volume_bytes;
                agg.modeled_time_s += v.modeled_time_s;
            }
        }
        agg
    }

    /// Distinct shapes recorded for (op, stage), ordered.
    pub fn shapes(&self, op: CollectiveKind, stage: Stage) -> Vec<Vec<usize>> {
        self.global
            .keys()
            .filter(|k| k.op == op && k.stage == stage)
            .map(|k| k.shape.clone())
            .collect()
    }

    /// Total corrected communication volume (paper Figs. 6–7 y-axis).
    pub fn corrected_volume_total(&self) -> f64 {
        self.global.values().map(|v| v.corrected_volume_bytes).sum()
    }

    /// Modeled communication seconds of one session step, each op counted
    /// once (see [`Self::step_comm_s`]); `0.0` for unpriced or untagged
    /// traces.
    pub fn step_modeled_comm_s(&self, step: u64) -> f64 {
        self.step_comm_s.get(&step).copied().unwrap_or(0.0)
    }

    /// Sum of the per-step op-deduplicated modeled comm times over the
    /// whole traced run (iterations are serial).
    pub fn modeled_comm_total_s(&self) -> f64 {
        self.step_comm_s.values().sum()
    }

    /// Corrected volume for one op class.
    pub fn corrected_volume(&self, op: CollectiveKind) -> f64 {
        self.global
            .iter()
            .filter(|(k, _)| k.op == op)
            .map(|(_, v)| v.corrected_volume_bytes)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(op: CollectiveKind, stage: Stage, rank: usize, shape: &[usize]) -> CommRecord {
        CommRecord {
            op,
            stage,
            rank,
            group_size: 2,
            shape: shape.to_vec(),
            elems: shape.iter().product(),
            dtype_bytes: 2,
            peer: None,
            step: None,
            batch: None,
            modeled_s: 0.0,
        }
    }

    #[test]
    fn record_byte_math() {
        let r = rec(CollectiveKind::AllReduce, Stage::Prefill, 0, &[128, 4096]);
        assert_eq!(r.message_bytes(), 128 * 4096 * 2);
        // d=2 -> factor 1.0
        assert!((r.corrected_bytes() - r.message_bytes() as f64).abs() < 1e-9);
    }

    #[test]
    fn sink_records_and_clears() {
        let sink = TraceSink::new();
        sink.record(rec(CollectiveKind::Gather, Stage::Decode, 1, &[64128]));
        assert_eq!(sink.len(), 1);
        sink.set_enabled(false);
        sink.record(rec(CollectiveKind::Gather, Stage::Decode, 1, &[64128]));
        assert_eq!(sink.len(), 1, "disabled sink must not record");
        sink.set_enabled(true);
        sink.clear();
        assert!(sink.is_empty());
    }

    #[test]
    fn summary_global_and_per_rank() {
        let sink = TraceSink::new();
        for step in 0..3 {
            let _ = step;
            for rank in 0..2 {
                sink.record(rec(CollectiveKind::AllReduce, Stage::Decode, rank, &[1, 4096]));
            }
        }
        sink.record(rec(CollectiveKind::Gather, Stage::Decode, 0, &[64128]));
        let s = sink.summary();
        assert_eq!(s.global_count(CollectiveKind::AllReduce, Stage::Decode), 6);
        assert_eq!(s.rank_count(1, CollectiveKind::AllReduce, Stage::Decode), 3);
        assert_eq!(s.rank_count(1, CollectiveKind::Gather, Stage::Decode), 0);
        assert_eq!(s.paper_view(CollectiveKind::AllReduce, Stage::Decode).count, 3);
        let shapes = s.shapes(CollectiveKind::AllReduce, Stage::Decode);
        assert_eq!(shapes, vec![vec![1, 4096]]);
    }

    #[test]
    fn iteration_context_tags_records_and_batch_view_aggregates() {
        let sink = TraceSink::new();
        sink.record(rec(CollectiveKind::AllReduce, Stage::Prefill, 0, &[16, 8]));
        sink.set_iteration(3, 1);
        sink.record(rec(CollectiveKind::AllReduce, Stage::Decode, 0, &[1, 8]));
        sink.set_iteration(4, 4);
        sink.record(rec(CollectiveKind::AllReduce, Stage::Decode, 0, &[4, 8]));
        sink.record(rec(CollectiveKind::AllReduce, Stage::Decode, 1, &[4, 8]));
        sink.clear_iteration();
        sink.record(rec(CollectiveKind::AllReduce, Stage::Decode, 0, &[1, 8]));

        let snap = sink.snapshot();
        assert_eq!(snap[0].batch, None, "pre-context record untagged");
        assert_eq!((snap[1].step, snap[1].batch), (Some(3), Some(1)));
        assert_eq!((snap[2].step, snap[2].batch), (Some(4), Some(4)));
        assert_eq!(snap[4].batch, None, "post-clear record untagged");

        let s = sink.summary();
        assert_eq!(s.batch_sizes(), vec![1, 4]);
        let b4 = s.batch_view(4, CollectiveKind::AllReduce, Stage::Decode);
        assert_eq!(b4.count, 2);
        assert_eq!(b4.total_message_bytes, 2 * 4 * 8 * 2);
        let b1 = s.batch_view(1, CollectiveKind::AllReduce, Stage::Decode);
        assert_eq!(b1.count, 1);
        // Per-record payload scales linearly with the batch tag.
        assert_eq!(
            b4.total_message_bytes / b4.count,
            4 * (b1.total_message_bytes / b1.count)
        );
        // Untagged records still aggregate globally.
        assert_eq!(s.global_count(CollectiveKind::AllReduce, Stage::Decode), 4);
        assert_eq!(s.batch_view(2, CollectiveKind::AllReduce, Stage::Decode).count, 0);
    }

    #[test]
    fn pricer_stamps_modeled_time_and_summary_aggregates_it() {
        use crate::analysis::ParallelLayout;
        use crate::model::ModelArch;
        use crate::simtime::CostModel;

        let sink = TraceSink::new();
        let pricer = CostModel::on_cardinal(ModelArch::tiny(), ParallelLayout::new(2, 1));
        let expected = pricer
            .cal
            .net
            .allreduce((16usize * 8 * 2) as f64, 2, false)
            .total();
        sink.set_pricer(pricer);
        sink.set_iteration(0, 1);
        for rank in 0..2 {
            sink.record(rec(CollectiveKind::AllReduce, Stage::Prefill, rank, &[16, 8]));
        }
        sink.set_iteration(1, 1);
        sink.record(rec(CollectiveKind::AllReduce, Stage::Decode, 0, &[1, 8]));

        let snap = sink.snapshot();
        assert!((snap[0].modeled_s - expected).abs() < 1e-15, "priced at record time");
        let s = sink.summary();
        // Per-rank and paper views carry one record's price each; the
        // global view sums both members of the collective.
        let pv = s.paper_view(CollectiveKind::AllReduce, Stage::Prefill);
        assert!((pv.modeled_time_s - expected).abs() < 1e-15);
        // Step 0's op-deduplicated comm time is one AllReduce, not two:
        // both members' records share the op's price.
        assert!((s.step_modeled_comm_s(0) - expected).abs() < 1e-15);
        assert!(s.step_modeled_comm_s(1) > 0.0);
        assert_eq!(s.step_modeled_comm_s(7), 0.0, "unknown step prices to zero");
        assert!(
            (s.modeled_comm_total_s() - (s.step_modeled_comm_s(0) + s.step_modeled_comm_s(1)))
                .abs()
                < 1e-15
        );
        // Unpriced sinks keep modeled time at zero.
        let bare = TraceSink::new();
        bare.record(rec(CollectiveKind::AllReduce, Stage::Prefill, 0, &[16, 8]));
        assert_eq!(bare.snapshot()[0].modeled_s, 0.0);
    }

    #[test]
    fn summary_only_mode_folds_at_record_time_identically() {
        // The same record stream through a retained sink and a
        // summary-only sink must summarize identically (shared fold), and
        // the summary-only sink must retain nothing.
        let stream = |sink: &TraceSink| {
            sink.record(rec(CollectiveKind::AllReduce, Stage::Prefill, 0, &[16, 8]));
            sink.set_iteration(2, 3);
            for rank in 0..2 {
                sink.record(rec(CollectiveKind::AllReduce, Stage::Decode, rank, &[3, 8]));
            }
            sink.record(rec(CollectiveKind::Send, Stage::Decode, 1, &[1, 8]));
            sink.clear_iteration();
            sink.record(rec(CollectiveKind::Gather, Stage::Decode, 2, &[64128]));
        };
        let retained = TraceSink::new();
        stream(&retained);
        let folded = TraceSink::new();
        folded.set_summary_only(true);
        stream(&folded);
        assert_eq!(retained.summary(), folded.summary());
        assert_eq!(retained.len(), 5);
        assert!(folded.is_empty(), "summary-only mode must not retain records");
        // clear() resets the running summary, not just the record vec.
        folded.clear();
        assert_eq!(folded.summary(), TraceSummary::default());
        // Leaving summary-only mode returns to retained recording.
        folded.set_summary_only(false);
        stream(&folded);
        assert_eq!(folded.len(), 5);
        assert_eq!(folded.summary(), retained.summary());
    }

    #[test]
    fn corrected_volume_sums_by_op() {
        let sink = TraceSink::new();
        sink.record(rec(CollectiveKind::AllReduce, Stage::Prefill, 0, &[2, 8]));
        sink.record(rec(CollectiveKind::Send, Stage::Prefill, 0, &[2, 8]));
        let s = sink.summary();
        let ar = s.corrected_volume(CollectiveKind::AllReduce);
        let p2p = s.corrected_volume(CollectiveKind::Send);
        assert!((ar - 32.0).abs() < 1e-9); // 16 elems * 2B * factor 1.0 (d=2)
        assert!((p2p - 32.0).abs() < 1e-9); // factor 1.0
        assert!((s.corrected_volume_total() - 64.0).abs() < 1e-9);
    }
}
