//! Shared-memory collective implementations.
//!
//! One [`CommWorld`] per engine run owns the rendezvous state; workers hold
//! [`GroupHandle`]s (TP groups) and [`P2pEndpoint`]s (pipeline links). Data
//! is genuinely reduced/gathered/moved between worker threads — the numeric
//! engine's correctness depends on it — and every call is traced through the
//! shared [`TraceSink`].
//!
//! Collectives in a group are SPMD-ordered (every member issues the same
//! sequence), so a single generation-counted slot per group suffices; a
//! two-phase (fill → drain) protocol lets a fast worker block until the
//! previous operation fully drains before depositing into the next.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};

use super::profiler::{CommRecord, Stage, TraceSink};
use super::CollectiveKind;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    Filling,
    Draining,
}

struct SlotState {
    phase: Phase,
    contributions: Vec<Option<Vec<f32>>>,
    result: Option<Arc<Vec<f32>>>,
    /// Reused sum accumulator for the reduce fast path (no per-op allocs).
    acc: Vec<f32>,
    arrived: usize,
    departed: usize,
}

struct GroupShared {
    size: usize,
    state: Mutex<SlotState>,
    cv: Condvar,
}

impl GroupShared {
    fn new(size: usize) -> Self {
        Self {
            size,
            state: Mutex::new(SlotState {
                phase: Phase::Filling,
                contributions: vec![None; size],
                result: None,
                acc: Vec::new(),
                arrived: 0,
                departed: 0,
            }),
            cv: Condvar::new(),
        }
    }

    /// Run one collective round: deposit `input`, combine when everyone has
    /// arrived, hand the combined value to each member.
    fn round(
        &self,
        rank: usize,
        input: Vec<f32>,
        combine: impl FnOnce(&mut Vec<Option<Vec<f32>>>) -> Vec<f32>,
    ) -> Arc<Vec<f32>> {
        let mut st = self.state.lock().expect("group lock poisoned");
        // Wait out the previous operation's drain phase.
        while st.phase == Phase::Draining {
            st = self.cv.wait(st).expect("group lock poisoned");
        }
        debug_assert!(st.contributions[rank].is_none(), "double deposit rank {rank}");
        st.contributions[rank] = Some(input);
        st.arrived += 1;
        if st.arrived == self.size {
            let combined = combine(&mut st.contributions);
            st.result = Some(Arc::new(combined));
            st.phase = Phase::Draining;
            self.cv.notify_all();
        } else {
            // Measured alternative (EXPERIMENTS.md §Perf): spin-then-park
            // before the condvar wait improved p50 slightly but regressed
            // mean latency 2.4x on this (shared) testbed via lock thrash —
            // reverted; plain condvar parking is the keeper.
            while st.phase != Phase::Draining {
                st = self.cv.wait(st).expect("group lock poisoned");
            }
        }
        let res = st.result.as_ref().expect("result present in drain phase").clone();
        st.departed += 1;
        if st.departed == self.size {
            st.phase = Phase::Filling;
            st.arrived = 0;
            st.departed = 0;
            st.result = None;
            st.contributions.iter_mut().for_each(|c| *c = None);
            self.cv.notify_all();
        }
        res
    }

    /// Allocation-free sum round: ranks add into a shared accumulator under
    /// the slot lock and copy it out on drain — the AllReduce fast path
    /// (EXPERIMENTS.md §Perf: removes both the per-rank `to_vec` and the
    /// combine pass of the generic round).
    fn reduce_round(&self, buf: &mut [f32]) {
        let mut st = self.state.lock().expect("group lock poisoned");
        while st.phase == Phase::Draining {
            st = self.cv.wait(st).expect("group lock poisoned");
        }
        if st.arrived == 0 {
            st.acc.clear();
            st.acc.extend_from_slice(buf);
        } else {
            debug_assert_eq!(st.acc.len(), buf.len(), "mismatched reduce sizes");
            for (a, b) in st.acc.iter_mut().zip(buf.iter()) {
                *a += *b;
            }
        }
        st.arrived += 1;
        if st.arrived == self.size {
            st.phase = Phase::Draining;
            self.cv.notify_all();
        } else {
            while st.phase != Phase::Draining {
                st = self.cv.wait(st).expect("group lock poisoned");
            }
        }
        buf.copy_from_slice(&st.acc);
        st.departed += 1;
        if st.departed == self.size {
            st.phase = Phase::Filling;
            st.arrived = 0;
            st.departed = 0;
            self.cv.notify_all();
        }
    }
}

/// One worker's membership in a communication group.
#[derive(Clone)]
pub struct GroupHandle {
    shared: Arc<GroupShared>,
    /// Rank within the group (0-based).
    pub group_rank: usize,
    /// Global rank, used for trace attribution.
    pub global_rank: usize,
    sink: Arc<TraceSink>,
    /// Logical element width recorded in traces (BF16 in the paper's runs,
    /// F32 for the numeric tiny model).
    pub dtype_bytes: usize,
}

impl GroupHandle {
    /// Number of participants.
    pub fn size(&self) -> usize {
        self.shared.size
    }

    fn record(&self, op: CollectiveKind, stage: Stage, shape: &[usize]) {
        self.sink.record(CommRecord {
            op,
            stage,
            rank: self.global_rank,
            group_size: self.shared.size,
            shape: shape.to_vec(),
            elems: shape.iter().product(),
            dtype_bytes: self.dtype_bytes,
            peer: None,
            step: None,
            batch: None,
            modeled_s: 0.0,
        });
    }

    /// Sum-AllReduce `buf` in place across the group. `shape` is the
    /// logical tensor shape for the trace (e.g. `[S, h]`).
    pub fn all_reduce(&self, buf: &mut [f32], shape: &[usize], stage: Stage) {
        assert_eq!(buf.len(), shape.iter().product::<usize>(), "shape/len mismatch");
        if self.shared.size == 1 {
            return; // vLLM issues no NCCL call for single-member groups
        }
        self.record(CollectiveKind::AllReduce, stage, shape);
        self.shared.reduce_round(buf);
    }

    /// AllGather rank slices into the full tensor (concatenated by group
    /// rank along the leading memory order). `out_shape` is the gathered
    /// shape the trace reports (Table VI convention).
    pub fn all_gather(&self, local: &[f32], out_shape: &[usize], stage: Stage) -> Vec<f32> {
        if self.shared.size == 1 {
            return local.to_vec();
        }
        assert_eq!(
            local.len() * self.shared.size,
            out_shape.iter().product::<usize>(),
            "local slice size inconsistent with gathered shape"
        );
        self.record(CollectiveKind::AllGather, stage, out_shape);
        let res = self.shared.round(self.group_rank, local.to_vec(), |contribs| {
            let mut full = Vec::with_capacity(
                contribs.iter().map(|c| c.as_ref().map_or(0, |v| v.len())).sum(),
            );
            for c in contribs.iter_mut() {
                full.extend_from_slice(c.take().expect("contribution").as_slice());
            }
            full
        });
        res.as_ref().clone()
    }

    /// Gather rank slices to `root`; non-roots return `None`. The trace
    /// records the *slice* shape (Table III convention: `[v/t]`).
    pub fn gather(
        &self,
        local: &[f32],
        slice_shape: &[usize],
        root: usize,
        stage: Stage,
    ) -> Option<Vec<f32>> {
        assert_eq!(local.len(), slice_shape.iter().product::<usize>());
        if self.shared.size == 1 {
            return Some(local.to_vec());
        }
        self.record(CollectiveKind::Gather, stage, slice_shape);
        let res = self.shared.round(self.group_rank, local.to_vec(), |contribs| {
            let mut full = Vec::new();
            for c in contribs.iter_mut() {
                full.extend_from_slice(c.take().expect("contribution").as_slice());
            }
            full
        });
        (self.group_rank == root).then(|| res.as_ref().clone())
    }

    /// ReduceScatter: sum all contributions, return this rank's `1/d`
    /// slice (by leading order). Megatron-SP replaces each row-parallel
    /// AllReduce with ReduceScatter (+ AllGather at the region exit); the
    /// trace records the *input* shape like NCCL kernel profiles do.
    pub fn reduce_scatter(&self, buf: &[f32], in_shape: &[usize], stage: Stage) -> Vec<f32> {
        assert_eq!(buf.len(), in_shape.iter().product::<usize>());
        let d = self.shared.size;
        if d == 1 {
            return buf.to_vec();
        }
        assert!(buf.len() % d == 0, "message not divisible across group");
        self.record(CollectiveKind::ReduceScatter, stage, in_shape);
        let res = self.shared.round(self.group_rank, buf.to_vec(), |contribs| {
            let mut acc = contribs[0].take().expect("rank0 contribution");
            for c in contribs.iter_mut().skip(1) {
                let c = c.take().expect("contribution");
                for (a, b) in acc.iter_mut().zip(c.iter()) {
                    *a += *b;
                }
            }
            acc
        });
        let slice = buf.len() / d;
        res[self.group_rank * slice..(self.group_rank + 1) * slice].to_vec()
    }

    /// AllToAll: every rank contributes `d` equal chunks; rank `r` receives
    /// chunk `r` from every member, concatenated by source rank. This is
    /// the MoE dispatch/combine primitive (tokens routed to expert owners).
    pub fn all_to_all(&self, buf: &[f32], in_shape: &[usize], stage: Stage) -> Vec<f32> {
        assert_eq!(buf.len(), in_shape.iter().product::<usize>());
        let d = self.shared.size;
        if d == 1 {
            return buf.to_vec();
        }
        assert!(buf.len() % d == 0, "message not divisible across group");
        self.record(CollectiveKind::AllToAll, stage, in_shape);
        let chunk = buf.len() / d;
        let my_rank = self.group_rank;
        // Everyone deposits the full buffer; each departs with its column.
        let res = self.shared.round(my_rank, buf.to_vec(), |contribs| {
            // Flatten all contributions (rank-major) so every member can
            // extract its column on the way out.
            let mut all = Vec::with_capacity(chunk * d * d);
            for c in contribs.iter_mut() {
                all.extend_from_slice(c.take().expect("contribution").as_slice());
            }
            all
        });
        let mut out = Vec::with_capacity(chunk * d);
        for src in 0..d {
            let base = src * (chunk * d) + my_rank * chunk;
            out.extend_from_slice(&res[base..base + chunk]);
        }
        out
    }

    /// Barrier (no data) — engine lifecycle synchronization, untraced.
    pub fn barrier(&self) {
        if self.shared.size > 1 {
            self.shared.round(self.group_rank, Vec::new(), |_| Vec::new());
        }
    }
}

/// Directed point-to-point channel between two pipeline ranks. The sender
/// side records `Send`, the receiver side records `Recv` — matching the
/// per-rank NCCL kernels of Table V.
pub struct P2pEndpoint {
    pub global_rank: usize,
    pub peer: usize,
    tx: Option<Sender<Vec<f32>>>,
    rx: Option<Receiver<Vec<f32>>>,
    sink: Arc<TraceSink>,
    pub dtype_bytes: usize,
}

impl P2pEndpoint {
    /// Send a tensor to the peer.
    pub fn send(&self, data: Vec<f32>, shape: &[usize], stage: Stage) {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        self.sink.record(CommRecord {
            op: CollectiveKind::Send,
            stage,
            rank: self.global_rank,
            group_size: 2,
            shape: shape.to_vec(),
            elems: data.len(),
            dtype_bytes: self.dtype_bytes,
            peer: Some(self.peer),
            step: None,
            batch: None,
            modeled_s: 0.0,
        });
        self.tx
            .as_ref()
            .expect("endpoint is send-capable")
            .send(data)
            .expect("peer hung up");
    }

    /// Receive a tensor from the peer (blocking).
    pub fn recv(&self, shape: &[usize], stage: Stage) -> Vec<f32> {
        let data = self
            .rx
            .as_ref()
            .expect("endpoint is recv-capable")
            .recv()
            .expect("peer hung up");
        assert_eq!(data.len(), shape.iter().product::<usize>(), "recv shape mismatch");
        self.sink.record(CommRecord {
            op: CollectiveKind::Recv,
            stage,
            rank: self.global_rank,
            group_size: 2,
            shape: shape.to_vec(),
            elems: data.len(),
            dtype_bytes: self.dtype_bytes,
            peer: Some(self.peer),
            step: None,
            batch: None,
            modeled_s: 0.0,
        });
        data
    }
}

/// Factory for groups and p2p links of one engine run.
pub struct CommWorld {
    pub world_size: usize,
    pub sink: Arc<TraceSink>,
    pub dtype_bytes: usize,
    channels: Mutex<HashMap<(usize, usize), (Sender<Vec<f32>>, Option<Receiver<Vec<f32>>>)>>,
}

impl CommWorld {
    pub fn new(world_size: usize, dtype_bytes: usize, sink: Arc<TraceSink>) -> Arc<Self> {
        Arc::new(Self {
            world_size,
            sink,
            dtype_bytes,
            channels: Mutex::new(HashMap::new()),
        })
    }

    /// Create a collective group over `global_ranks`; returns one handle per
    /// member, in rank order.
    pub fn create_group(&self, global_ranks: &[usize]) -> Vec<GroupHandle> {
        assert!(!global_ranks.is_empty());
        let shared = Arc::new(GroupShared::new(global_ranks.len()));
        global_ranks
            .iter()
            .enumerate()
            .map(|(group_rank, &global_rank)| GroupHandle {
                shared: shared.clone(),
                group_rank,
                global_rank,
                sink: self.sink.clone(),
                dtype_bytes: self.dtype_bytes,
            })
            .collect()
    }

    /// Sender endpoint `src -> dst`.
    pub fn sender(&self, src: usize, dst: usize) -> P2pEndpoint {
        assert!(src < self.world_size && dst < self.world_size && src != dst);
        let mut map = self.channels.lock().expect("channel map poisoned");
        let (tx, _) = map.entry((src, dst)).or_insert_with(|| {
            let (tx, rx) = channel();
            (tx, Some(rx))
        });
        P2pEndpoint {
            global_rank: src,
            peer: dst,
            tx: Some(tx.clone()),
            rx: None,
            sink: self.sink.clone(),
            dtype_bytes: self.dtype_bytes,
        }
    }

    /// Receiver endpoint for messages `src -> dst` (single consumer: the
    /// receiving half can be claimed exactly once).
    pub fn receiver(&self, src: usize, dst: usize) -> P2pEndpoint {
        assert!(src < self.world_size && dst < self.world_size && src != dst);
        let mut map = self.channels.lock().expect("channel map poisoned");
        let entry = map.entry((src, dst)).or_insert_with(|| {
            let (tx, rx) = channel();
            (tx, Some(rx))
        });
        let rx = entry.1.take().expect("receiver endpoint already claimed");
        P2pEndpoint {
            global_rank: dst,
            peer: src,
            tx: None,
            rx: Some(rx),
            sink: self.sink.clone(),
            dtype_bytes: self.dtype_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn world(n: usize) -> (Arc<CommWorld>, Arc<TraceSink>) {
        let sink = TraceSink::new();
        (CommWorld::new(n, 4, sink.clone()), sink)
    }

    #[test]
    fn all_reduce_sums_across_ranks() {
        for size in [2usize, 3, 4, 8] {
            let (w, _) = world(size);
            let handles = w.create_group(&(0..size).collect::<Vec<_>>());
            let outs: Vec<Vec<f32>> = thread::scope(|s| {
                let joins: Vec<_> = handles
                    .into_iter()
                    .map(|h| {
                        s.spawn(move || {
                            let mut buf =
                                vec![(h.group_rank + 1) as f32, 10.0 * (h.group_rank + 1) as f32];
                            h.all_reduce(&mut buf, &[2], Stage::Prefill);
                            buf
                        })
                    })
                    .collect();
                joins.into_iter().map(|j| j.join().unwrap()).collect()
            });
            let expect: f32 = (1..=size).map(|r| r as f32).sum();
            for out in outs {
                assert_eq!(out, vec![expect, 10.0 * expect], "size={size}");
            }
        }
    }

    #[test]
    fn sequential_collectives_reuse_slot() {
        let (w, _) = world(2);
        let handles = w.create_group(&[0, 1]);
        let outs: Vec<f32> = thread::scope(|s| {
            let joins: Vec<_> = handles
                .into_iter()
                .map(|h| {
                    s.spawn(move || {
                        let mut total = 0.0f32;
                        for i in 0..100 {
                            let mut buf = vec![i as f32 + h.group_rank as f32];
                            h.all_reduce(&mut buf, &[1], Stage::Decode);
                            total += buf[0];
                        }
                        total
                    })
                })
                .collect();
            joins.into_iter().map(|j| j.join().unwrap()).collect()
        });
        // sum over i of (2i + 1) = 2*4950 + 100
        assert_eq!(outs, vec![10000.0, 10000.0]);
    }

    #[test]
    fn all_gather_concatenates_in_rank_order() {
        let (w, _) = world(4);
        let handles = w.create_group(&[0, 1, 2, 3]);
        let outs: Vec<Vec<f32>> = thread::scope(|s| {
            let joins: Vec<_> = handles
                .into_iter()
                .map(|h| {
                    s.spawn(move || {
                        let local = vec![h.group_rank as f32; 2];
                        h.all_gather(&local, &[8], Stage::Prefill)
                    })
                })
                .collect();
            joins.into_iter().map(|j| j.join().unwrap()).collect()
        });
        for out in outs {
            assert_eq!(out, vec![0., 0., 1., 1., 2., 2., 3., 3.]);
        }
    }

    #[test]
    fn gather_returns_only_at_root() {
        let (w, _) = world(2);
        let handles = w.create_group(&[0, 1]);
        let outs: Vec<Option<Vec<f32>>> = thread::scope(|s| {
            let joins: Vec<_> = handles
                .into_iter()
                .map(|h| {
                    s.spawn(move || {
                        let local = vec![h.group_rank as f32];
                        h.gather(&local, &[1], 0, Stage::Decode)
                    })
                })
                .collect();
            joins.into_iter().map(|j| j.join().unwrap()).collect()
        });
        assert_eq!(outs[0], Some(vec![0.0, 1.0]));
        assert_eq!(outs[1], None);
    }

    #[test]
    fn p2p_moves_data_and_traces_both_sides() {
        let (w, sink) = world(2);
        let tx = w.sender(0, 1);
        let rx = w.receiver(0, 1);
        let handle = thread::spawn(move || rx.recv(&[3], Stage::Prefill));
        tx.send(vec![1.0, 2.0, 3.0], &[3], Stage::Prefill);
        assert_eq!(handle.join().unwrap(), vec![1.0, 2.0, 3.0]);
        let s = sink.summary();
        assert_eq!(s.global_count(CollectiveKind::Send, Stage::Prefill), 1);
        assert_eq!(s.global_count(CollectiveKind::Recv, Stage::Prefill), 1);
        assert_eq!(s.rank_count(0, CollectiveKind::Send, Stage::Prefill), 1);
        assert_eq!(s.rank_count(1, CollectiveKind::Recv, Stage::Prefill), 1);
    }

    #[test]
    fn reduce_scatter_returns_summed_slice() {
        let (w, sink) = world(2);
        let handles = w.create_group(&[0, 1]);
        let outs: Vec<Vec<f32>> = thread::scope(|s| {
            let joins: Vec<_> = handles
                .into_iter()
                .map(|h| {
                    s.spawn(move || {
                        let buf = vec![
                            1.0 + h.group_rank as f32,
                            2.0 + h.group_rank as f32,
                            3.0 + h.group_rank as f32,
                            4.0 + h.group_rank as f32,
                        ];
                        h.reduce_scatter(&buf, &[4], Stage::Prefill)
                    })
                })
                .collect();
            joins.into_iter().map(|j| j.join().unwrap()).collect()
        });
        // sums: [3, 5, 7, 9]; rank0 gets [3,5], rank1 [7,9]
        assert_eq!(outs[0], vec![3.0, 5.0]);
        assert_eq!(outs[1], vec![7.0, 9.0]);
        let s = sink.summary();
        assert_eq!(s.global_count(CollectiveKind::ReduceScatter, Stage::Prefill), 2);
    }

    #[test]
    fn all_to_all_transposes_chunks() {
        let (w, sink) = world(2);
        let handles = w.create_group(&[0, 1]);
        let outs: Vec<Vec<f32>> = thread::scope(|s| {
            let joins: Vec<_> = handles
                .into_iter()
                .map(|h| {
                    s.spawn(move || {
                        let r = h.group_rank as f32;
                        // rank r contributes chunks [r*10+0..] for dst 0, 1
                        let buf = vec![r * 10.0, r * 10.0 + 1.0, r * 10.0 + 5.0, r * 10.0 + 6.0];
                        h.all_to_all(&buf, &[4], Stage::Decode)
                    })
                })
                .collect();
            joins.into_iter().map(|j| j.join().unwrap()).collect()
        });
        // rank0 receives chunk0 of rank0 + chunk0 of rank1
        assert_eq!(outs[0], vec![0.0, 1.0, 10.0, 11.0]);
        // rank1 receives chunk1 of each
        assert_eq!(outs[1], vec![5.0, 6.0, 15.0, 16.0]);
        let s = sink.summary();
        assert_eq!(s.global_count(CollectiveKind::AllToAll, Stage::Decode), 2);
    }

    #[test]
    fn reduce_scatter_plus_all_gather_equals_all_reduce() {
        // The Megatron-SP identity the analysis module relies on.
        for size in [2usize, 4] {
            let (w, _) = world(size);
            let handles = w.create_group(&(0..size).collect::<Vec<_>>());
            let outs: Vec<(Vec<f32>, Vec<f32>)> = thread::scope(|s| {
                let joins: Vec<_> = handles
                    .into_iter()
                    .map(|h| {
                        s.spawn(move || {
                            let n = 8;
                            let buf: Vec<f32> =
                                (0..n).map(|i| (i + h.group_rank) as f32).collect();
                            let slice = h.reduce_scatter(&buf, &[n], Stage::Prefill);
                            let gathered = h.all_gather(&slice, &[n], Stage::Prefill);
                            let mut ar = buf.clone();
                            h.all_reduce(&mut ar, &[n], Stage::Prefill);
                            (gathered, ar)
                        })
                    })
                    .collect();
                joins.into_iter().map(|j| j.join().unwrap()).collect()
            });
            for (rs_ag, ar) in outs {
                assert_eq!(rs_ag, ar, "size={size}");
            }
        }
    }

    #[test]
    fn single_member_group_is_silent() {
        let (w, sink) = world(1);
        let handles = w.create_group(&[0]);
        let mut buf = vec![5.0f32];
        handles[0].all_reduce(&mut buf, &[1], Stage::Prefill);
        assert_eq!(buf, vec![5.0]);
        let g = handles[0].gather(&buf, &[1], 0, Stage::Prefill);
        assert_eq!(g, Some(vec![5.0]));
        assert!(sink.is_empty(), "no NCCL calls for t=1");
    }

    #[test]
    fn traces_match_issued_ops() {
        let (w, sink) = world(2);
        let handles = w.create_group(&[0, 1]);
        thread::scope(|s| {
            for h in handles {
                s.spawn(move || {
                    let mut buf = vec![0.0f32; 8];
                    h.all_reduce(&mut buf, &[2, 4], Stage::Prefill);
                    let _ = h.all_gather(&buf[..4].to_vec(), &[8], Stage::Decode);
                });
            }
        });
        let s = sink.summary();
        assert_eq!(s.global_count(CollectiveKind::AllReduce, Stage::Prefill), 2);
        assert_eq!(s.global_count(CollectiveKind::AllGather, Stage::Decode), 2);
        assert_eq!(
            s.shapes(CollectiveKind::AllGather, Stage::Decode),
            vec![vec![8]]
        );
    }
}
