//! In-process NCCL-like communication substrate with first-class tracing.
//!
//! The paper's empirical side is a PyTorch-profiler trace of NCCL calls
//! inside vLLM; here every collective is implemented by [`collectives`] over
//! shared-memory rendezvous between worker threads (data is *actually*
//! reduced/gathered/moved), and every call emits a [`profiler::CommRecord`].
//! The profiler's aggregations regenerate the paper's Tables III–VI.

pub mod collectives;
pub mod profiler;

pub use collectives::{CommWorld, GroupHandle, P2pEndpoint};
pub use profiler::{AggKey, CommRecord, OpAggregate, Stage, TraceSink, TraceSummary};


/// Communication primitive classes observed in distributed LLM inference
/// (paper §V.A). `Send`/`Recv` are the pipeline point-to-point pair.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum CollectiveKind {
    AllReduce,
    AllGather,
    Gather,
    Send,
    Recv,
    /// Megatron-style sequence parallelism splits each AllReduce into a
    /// ReduceScatter + AllGather pair (paper §VIII future work).
    ReduceScatter,
    /// MoE expert-parallel token dispatch/combine (paper §VII future work).
    AllToAll,
}

impl CollectiveKind {
    pub fn label(&self) -> &'static str {
        match self {
            CollectiveKind::AllReduce => "Allreduce",
            CollectiveKind::AllGather => "Allgather",
            CollectiveKind::Gather => "Gather",
            CollectiveKind::Send => "Send",
            CollectiveKind::Recv => "Recv",
            CollectiveKind::ReduceScatter => "ReduceScatter",
            CollectiveKind::AllToAll => "AllToAll",
        }
    }

    /// NCCL volume correction factor for `d` participants (paper §V.B) —
    /// delegates to the shared collective algebra so trace accounting,
    /// the Eq. 1–7 closed forms and the α–β transfer terms agree by
    /// construction.
    pub fn correction_factor(&self, d: usize) -> f64 {
        match self {
            CollectiveKind::AllReduce => crate::simtime::algebra::allreduce_factor(d),
            CollectiveKind::AllGather
            | CollectiveKind::ReduceScatter
            | CollectiveKind::AllToAll => crate::simtime::algebra::allgather_factor(d),
            CollectiveKind::Gather | CollectiveKind::Send | CollectiveKind::Recv => 1.0,
        }
    }
}
