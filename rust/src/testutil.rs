//! Test & bench utilities (std-only substitutes for tempfile / proptest /
//! criterion, which the vendored environment does not provide —
//! DESIGN.md §5).

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

/// Self-cleaning temporary directory.
#[derive(Debug)]
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    /// Create a unique directory under the system temp dir.
    pub fn new(prefix: &str) -> Self {
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos();
        let path = std::env::temp_dir().join(format!(
            "{prefix}-{}-{nanos}",
            std::process::id()
        ));
        std::fs::create_dir_all(&path).expect("create temp dir");
        Self { path }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

/// SplitMix64 — deterministic PRNG for property-style tests (proptest
/// substitute). Good statistical quality for test-case generation.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[lo, hi]` (inclusive).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + (self.next_u64() as usize) % (hi - lo + 1)
    }

    /// Uniform f32 in `[-1, 1)`.
    pub fn f32_unit(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32 * 2.0 - 1.0
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize_in(0, items.len() - 1)]
    }

    pub fn f32_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.f32_unit()).collect()
    }
}

/// Timing statistics over repeated runs (criterion substitute for the
/// `cargo bench` harness binaries).
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl BenchStats {
    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>6} iters  mean {:>12?}  p50 {:>12?}  p95 {:>12?}  min {:>12?}",
            self.name, self.iters, self.mean, self.p50, self.p95, self.min
        )
    }
}

/// Run `f` repeatedly (after `warmup` runs) and report distribution stats.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    assert!(iters >= 1);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort();
    let total: Duration = samples.iter().sum();
    let p95_idx = ((iters * 95) / 100).min(iters - 1);
    BenchStats {
        name: name.to_string(),
        iters,
        mean: total / iters as u32,
        p50: samples[iters / 2],
        p95: samples[p95_idx],
        min: samples[0],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tempdir_creates_and_cleans() {
        let p;
        {
            let d = TempDir::new("commsim-test");
            p = d.path().to_path_buf();
            assert!(p.exists());
            std::fs::write(p.join("f"), b"x").unwrap();
        }
        assert!(!p.exists());
    }

    #[test]
    fn rng_is_deterministic_and_in_range() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        for _ in 0..1000 {
            let v = a.usize_in(3, 7);
            assert!((3..=7).contains(&v));
            let f = a.f32_unit();
            assert!((-1.0..1.0).contains(&f));
        }
        // different seeds diverge
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn bench_reports_sane_stats() {
        let s = bench("noop", 1, 16, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(s.iters, 16);
        assert!(s.min <= s.p50 && s.p50 <= s.p95);
        assert!(s.report().contains("noop"));
    }
}
