//! Typed construction-time errors for the deployment-plan facade.
//!
//! Every way a [`super::Deployment`] can be wired wrong is a named variant
//! rather than an ad-hoc string: callers (CLI, sweeps, tests) can match on
//! the failure class, and each message carries the numbers needed to fix
//! the configuration.

use std::fmt;

use crate::analysis::ParallelLayout;

/// Why a [`super::Deployment`] could not be validated into a
/// [`super::DeploymentPlan`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PlanError {
    /// Neither `.arch(..)` / `.model(..)` was called nor artifacts attached.
    MissingModel,
    /// The model name did not resolve in the architecture registry.
    UnknownModel { name: String },
    /// Both `.arch(..)` and `.model(name)` were set and disagree.
    ConflictingModel { arch: String, model: String },
    /// The plan's architecture does not match the attached artifact
    /// store's model (numeric serving always executes the artifacts).
    ArtifactModelMismatch { arch: String, artifact_model: String },
    /// A degree (or GPUs-per-node) of zero was requested for `axis`.
    ZeroDegree { axis: &'static str },
    /// Both `.topology(..)` and `.gpus_per_node(..)` were set — an explicit
    /// topology already fixes the node shape.
    ConflictingTopology,
    /// The architecture does not divide evenly across the TP degree.
    TpIndivisible {
        model: String,
        tp: usize,
        heads: usize,
        kv_heads: usize,
        intermediate: usize,
        vocab: usize,
    },
    /// More pipeline stages than the model has layers.
    PpExceedsLayers { model: String, pp: usize, layers: usize },
    /// The layout needs more GPUs than the topology provides.
    TopologyTooSmall { layout: ParallelLayout, needed: usize, available: usize },
    /// Zero-length prefill/decode or a zero-byte element width.
    InvalidWorkload { prefill_len: usize, decode_len: usize, dtype_bytes: usize },
    /// The attached artifact store was not built for this TP degree.
    ArtifactsMissingTp { tp: usize, available: Vec<usize> },
    /// The workload cannot be served by the attached artifacts (numeric
    /// mode serves fixed-length prompts within `max_seq`).
    ArtifactWorkloadMismatch {
        prefill_len: usize,
        decode_len: usize,
        artifact_prefill_len: usize,
        max_seq: usize,
    },
    /// A fleet member plan carries artifacts: numeric engines hold real
    /// single-sequence PJRT state and cannot be replicated into a fleet.
    FleetNumericUnsupported,
    /// Fleet members must serve one model; two plans disagree.
    FleetArchMismatch { base: String, other: String },
    /// Colocated replicas cannot be added to a disaggregated fleet (and
    /// vice versa): a fleet is either all-serve or prefill+decode pools.
    FleetMixedRoles,
    /// A disaggregated fleet needs at least one replica in each pool.
    DisaggPoolMissing { pool: &'static str },
    /// A fault-injection knob names a replica the fleet does not have.
    FaultReplicaOutOfRange { replica: usize, replicas: usize },
    /// A fault-injection value is out of its domain (`what` names the
    /// knob; `value` is the offending value, pre-formatted so the variant
    /// stays `Eq`).
    FaultValueInvalid { what: &'static str, value: String },
    /// Autoscale replica bounds are out of order (need 1 <= min <= max).
    AutoscaleBoundsInvalid { min: usize, max: usize },
    /// An autoscale knob is out of its domain (`what` names the knob;
    /// `value` is the offending value, pre-formatted so the variant
    /// stays `Eq`).
    AutoscaleValueInvalid { what: &'static str, value: String },
    /// The policy's ceiling disagrees with the spec's replica pool: a
    /// fleet spec lists its *maximum* replicas and the policy's
    /// `max_replicas` must equal that count.
    AutoscaleReplicaMismatch { max_replicas: usize, replicas: usize },
    /// Autoscaling drives colocated serve fleets; elastic disaggregated
    /// pools (scale-to-zero prefill) are a roadmap follow-on.
    AutoscaleDisaggUnsupported,
    /// The collective tuning's wire precision is not a modeled width
    /// (16 = untuned fp16/bf16, 8 and 4 = quantized variants).
    TuningBitsInvalid { bits: u32 },
    /// The collective tuning's compute–comm overlap factor is outside
    /// `[0, 1]` or not finite (`value` pre-formatted so the variant
    /// stays `Eq`).
    TuningOverlapInvalid { value: String },
    /// The chunked-prefill token budget is zero — a chunk must carry at
    /// least one prompt token per iteration.
    ChunkTokensInvalid { tokens: usize },
}

impl fmt::Display for PlanError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PlanError::MissingModel => write!(
                f,
                "no model selected: call .arch(..) or .model(\"3b|8b|13b|tiny\"), \
                 or attach artifacts for the tiny numeric model"
            ),
            PlanError::UnknownModel { name } => {
                write!(f, "unknown model '{name}' (known: 3b|8b|13b|tiny)")
            }
            PlanError::ConflictingModel { arch, model } => write!(
                f,
                "conflicting model selection: .arch() gave '{arch}' but \
                 .model(\"{model}\") resolves to a different architecture — \
                 set only one, or make them agree"
            ),
            PlanError::ArtifactModelMismatch { arch, artifact_model } => write!(
                f,
                "numeric serving executes the artifact model \
                 '{artifact_model}', but the plan's architecture is '{arch}' \
                 — drop .arch()/.model() or select the artifact model"
            ),
            PlanError::ZeroDegree { axis } => write!(f, "{axis} must be >= 1"),
            PlanError::ConflictingTopology => write!(
                f,
                "conflicting topology selection: .topology() already fixes \
                 the node shape — drop .gpus_per_node()"
            ),
            PlanError::TpIndivisible { model, tp, heads, kv_heads, intermediate, vocab } => {
                write!(
                    f,
                    "{model} does not divide across tp={tp}: heads={heads}, \
                     kv_heads={kv_heads}, intermediate={intermediate} and \
                     vocab={vocab} must all be divisible by the TP degree"
                )
            }
            PlanError::PpExceedsLayers { model, pp, layers } => write!(
                f,
                "{model} cannot split into pp={pp} stages: only {layers} layers"
            ),
            PlanError::TopologyTooSmall { layout, needed, available } => write!(
                f,
                "layout {} needs {needed} GPUs but the topology has {available}",
                layout.label()
            ),
            PlanError::InvalidWorkload { prefill_len, decode_len, dtype_bytes } => write!(
                f,
                "workload needs prefill >= 1, decode >= 1, dtype bytes >= 1 \
                 (got Sp={prefill_len}, Sd={decode_len}, b={dtype_bytes})"
            ),
            PlanError::ArtifactsMissingTp { tp, available } => write!(
                f,
                "artifacts were not built for tp={tp} (available TP degrees: {available:?})"
            ),
            PlanError::ArtifactWorkloadMismatch {
                prefill_len,
                decode_len,
                artifact_prefill_len,
                max_seq,
            } => write!(
                f,
                "artifacts serve fixed prompts of {artifact_prefill_len} \
                 tokens within max_seq {max_seq}; workload Sp={prefill_len} \
                 Sd={decode_len} cannot be served — drop .workload() to \
                 derive it from the artifacts"
            ),
            PlanError::FleetNumericUnsupported => write!(
                f,
                "fleet members must be structural plans: numeric engines \
                 hold real single-sequence PJRT state and cannot be \
                 replicated — drop .artifacts() from the member plan"
            ),
            PlanError::FleetArchMismatch { base, other } => write!(
                f,
                "fleet members must serve one model: fleet is '{base}' but \
                 the added replica plan is '{other}'"
            ),
            PlanError::FleetMixedRoles => write!(
                f,
                "a fleet is either all colocated replicas or disaggregated \
                 prefill+decode pools — colocated replicas cannot join a \
                 disaggregated fleet"
            ),
            PlanError::DisaggPoolMissing { pool } => write!(
                f,
                "a disaggregated fleet needs at least one {pool} replica"
            ),
            PlanError::FaultReplicaOutOfRange { replica, replicas } => write!(
                f,
                "fault injection names replica {replica}, but the fleet has \
                 only {replicas} replicas (indices 0..{replicas})"
            ),
            PlanError::FaultValueInvalid { what, value } => {
                write!(f, "fault injection: {what} is invalid ({value})")
            }
            PlanError::AutoscaleBoundsInvalid { min, max } => write!(
                f,
                "autoscale bounds need 1 <= min <= max replicas \
                 (got min={min}, max={max})"
            ),
            PlanError::AutoscaleValueInvalid { what, value } => {
                write!(f, "autoscale: {what} is invalid ({value})")
            }
            PlanError::AutoscaleReplicaMismatch { max_replicas, replicas } => write!(
                f,
                "autoscale max_replicas={max_replicas} but the fleet spec \
                 lists {replicas} replicas — the spec's replica list is the \
                 maximum pool, so the two must agree"
            ),
            PlanError::AutoscaleDisaggUnsupported => write!(
                f,
                "autoscaling drives colocated serve fleets only — elastic \
                 disaggregated prefill/decode pools are not supported yet"
            ),
            PlanError::TuningBitsInvalid { bits } => write!(
                f,
                "collective tuning: wire precision must be 16, 8 or 4 bits \
                 (got {bits})"
            ),
            PlanError::TuningOverlapInvalid { value } => write!(
                f,
                "collective tuning: overlap factor must be a finite value \
                 in [0, 1] (got {value})"
            ),
            PlanError::ChunkTokensInvalid { tokens } => write!(
                f,
                "chunked prefill: the token budget must be >= 1 (got \
                 {tokens}) — omit .chunked_prefill() for one-shot prefill"
            ),
        }
    }
}

impl std::error::Error for PlanError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn messages_carry_the_offending_numbers() {
        let e = PlanError::TpIndivisible {
            model: "Llama-3.1-8B".into(),
            tp: 3,
            heads: 32,
            kv_heads: 8,
            intermediate: 14336,
            vocab: 128_256,
        };
        let s = e.to_string();
        assert!(s.contains("tp=3") && s.contains("heads=32"), "{s}");

        let e = PlanError::PpExceedsLayers { model: "Llama-3.2-3B".into(), pp: 64, layers: 28 };
        assert!(e.to_string().contains("pp=64"));

        let e = PlanError::TopologyTooSmall {
            layout: ParallelLayout::new(4, 2),
            needed: 8,
            available: 4,
        };
        let s = e.to_string();
        assert!(s.contains("TP=4 PP=2") && s.contains("8 GPUs") && s.contains("has 4"), "{s}");

        let e = PlanError::TuningBitsInvalid { bits: 12 };
        assert!(e.to_string().contains("got 12"), "{e}");
        let e = PlanError::TuningOverlapInvalid { value: "1.5".into() };
        let s = e.to_string();
        assert!(s.contains("[0, 1]") && s.contains("1.5"), "{s}");

        let e = PlanError::ChunkTokensInvalid { tokens: 0 };
        let s = e.to_string();
        assert!(s.contains(">= 1") && s.contains("got 0"), "{s}");
    }

    #[test]
    fn converts_into_crate_error_via_question_mark() {
        fn f() -> crate::Result<()> {
            let r: Result<(), PlanError> = Err(PlanError::MissingModel);
            r?;
            Ok(())
        }
        assert!(f().unwrap_err().to_string().contains("no model selected"));
    }
}
