//! Feasible-layout enumeration — the parallelism advisor's search space as
//! a library primitive (paper §VII: "automated parallelism selection tools
//! that dynamically choose optimal configurations").

use crate::model::ModelArch;

use super::{Deployment, DeploymentPlan};

impl DeploymentPlan {
    /// Every feasible (TP, PP) plan of `arch` using exactly `gpus` GPUs,
    /// in ascending-TP order.
    ///
    /// A pair is feasible when `tp * pp == gpus`, the architecture divides
    /// across `tp` and splits into `pp` non-empty stages. Each yielded plan
    /// carries the paper-default workload (Sp = Sd = 128, BF16) and a
    /// just-big-enough 4-GPU-node topology; reshape with
    /// [`DeploymentPlan::with_workload`].
    pub fn sweep(arch: &ModelArch, gpus: usize) -> impl Iterator<Item = DeploymentPlan> {
        let mut plans = Vec::new();
        for tp in 1..=gpus {
            if gpus % tp != 0 {
                continue;
            }
            let pp = gpus / tp;
            if let Ok(plan) = Deployment::builder().arch(arch.clone()).tp(tp).pp(pp).build() {
                plans.push(plan);
            }
        }
        plans.into_iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn degrees(arch: &ModelArch, gpus: usize) -> Vec<(usize, usize)> {
        DeploymentPlan::sweep(arch, gpus)
            .map(|p| (p.layout().tp, p.layout().pp))
            .collect()
    }

    #[test]
    fn eight_gpus_covers_the_fig10_grid() {
        assert_eq!(
            degrees(&ModelArch::llama2_13b(), 8),
            vec![(1, 8), (2, 4), (4, 2), (8, 1)]
        );
    }

    #[test]
    fn infeasible_degrees_are_filtered() {
        // tiny: 8 heads, 4 layers. On 6 GPUs, tp=3 and tp=6 do not divide
        // the heads, pp=6 exceeds the layers — only TP=2 × PP=3 survives.
        assert_eq!(degrees(&ModelArch::tiny(), 6), vec![(2, 3)]);
    }

    #[test]
    fn zero_gpus_yields_nothing() {
        assert_eq!(degrees(&ModelArch::llama31_8b(), 0), vec![]);
    }

    #[test]
    fn every_swept_plan_uses_exactly_the_gpu_budget() {
        for gpus in [1usize, 2, 4, 8, 16] {
            for plan in DeploymentPlan::sweep(&ModelArch::llama31_8b(), gpus) {
                assert_eq!(plan.layout().world_size(), gpus);
                assert!(plan.topology().total_gpus() >= gpus);
            }
        }
    }
}
