//! The unified deployment-plan facade — one validated entry point for
//! everything the crate can do with a (model, layout, topology, workload)
//! tuple.
//!
//! Historically every consumer hand-assembled `ParallelLayout` +
//! `InferenceShape` + `Placement` + `EngineConfig` + `SloSimulator` with no
//! cross-validation; an infeasible combination surfaced as a worker panic
//! or a silent wrong answer. [`Deployment`] is the builder that validates
//! the whole tuple up front (typed [`PlanError`]s), and [`DeploymentPlan`]
//! is the resulting immutable plan exposing the unified verbs:
//!
//! - [`DeploymentPlan::analyze`] — the paper's analytical models (Eq. 1–7
//!   volumes + Tables III–VI op predictions) as a [`VolumeReport`];
//! - [`DeploymentPlan::trace`] — run the structural engine and return the
//!   measured collective stream ([`TraceSummary`]);
//! - [`DeploymentPlan::simulate`] — TTFT/TPOT/E2E on the calibrated
//!   testbed model ([`SloResult`], Figs. 1 and 8–10);
//! - [`DeploymentPlan::engine`] / [`DeploymentPlan::server`] — a live
//!   engine (numeric when artifacts are attached, structural otherwise)
//!   or a full serving stack;
//! - [`DeploymentPlan::sweep`] — iterator over every feasible (TP, PP)
//!   plan of a model on a GPU budget (the parallelism advisor's search
//!   space as a library primitive).

mod error;
mod sweep;

pub use error::PlanError;

use crate::analysis::{
    InferenceShape, OpCountModel, ParallelLayout, StageOps, VolumeBreakdown, VolumeModel,
};
use crate::cluster::{Placement, Topology};
use crate::comm::{Stage, TraceSummary};
use crate::engine::{Engine, EngineConfig, EngineMode};
use crate::model::{ModelArch, DTYPE_BYTES_BF16, DTYPE_BYTES_F32};
use crate::perfmodel::{Calibration, SloReport, SloSimulator};
use crate::runtime::ArtifactStore;
use crate::server::{SchedulerConfig, Server};
use crate::simtime::CostModel;

/// Simulated SLO metrics returned by [`DeploymentPlan::simulate`].
pub type SloResult = SloReport;

/// The invariant numeric artifacts impose on a workload: prompts are
/// fixed-length and the whole sequence must fit `max_seq`. Shared by
/// `build()` (explicit and artifact-derived workloads alike) and
/// [`DeploymentPlan::with_workload`].
fn check_artifact_workload(
    store: &ArtifactStore,
    prefill_len: usize,
    decode_len: usize,
) -> Result<(), PlanError> {
    if prefill_len != store.meta.prefill_len
        || prefill_len + decode_len > store.meta.max_seq
    {
        return Err(PlanError::ArtifactWorkloadMismatch {
            prefill_len,
            decode_len,
            artifact_prefill_len: store.meta.prefill_len,
            max_seq: store.meta.max_seq,
        });
    }
    Ok(())
}

/// Builder for a validated [`DeploymentPlan`].
///
/// Defaults mirror the paper's canonical setting: TP=1 × PP=1 on 4-GPU
/// nodes, Sp = Sd = 128 at BF16. `build()` rejects infeasible
/// combinations with a typed [`PlanError`].
#[derive(Debug, Clone)]
pub struct Deployment {
    arch: Option<ModelArch>,
    model_name: Option<String>,
    tp: usize,
    pp: usize,
    topology: Option<Topology>,
    gpus_per_node: Option<usize>,
    workload: Option<(usize, usize)>,
    dtype_bytes: Option<usize>,
    calibration: Option<Calibration>,
    tuning: Option<(u32, f64)>,
    chunk_tokens: Option<usize>,
    artifacts: Option<ArtifactStore>,
}

impl Default for Deployment {
    fn default() -> Self {
        Self {
            arch: None,
            model_name: None,
            tp: 1,
            pp: 1,
            topology: None,
            gpus_per_node: None,
            workload: None,
            dtype_bytes: None,
            calibration: None,
            tuning: None,
            chunk_tokens: None,
            artifacts: None,
        }
    }
}

impl Deployment {
    /// Start a new builder with the paper-default settings.
    pub fn builder() -> Self {
        Self::default()
    }

    /// Target architecture (a registry value or a custom `ModelArch`).
    pub fn arch(mut self, arch: ModelArch) -> Self {
        self.arch = Some(arch);
        self
    }

    /// Target architecture by registry short name (`3b|8b|13b|tiny`);
    /// resolution happens in `build()` so typos surface as
    /// [`PlanError::UnknownModel`].
    pub fn model(mut self, name: &str) -> Self {
        self.model_name = Some(name.to_string());
        self
    }

    /// Tensor-parallel degree `t`.
    pub fn tp(mut self, tp: usize) -> Self {
        self.tp = tp;
        self
    }

    /// Pipeline-parallel degree `p`.
    pub fn pp(mut self, pp: usize) -> Self {
        self.pp = pp;
        self
    }

    /// Explicit cluster topology. Without this, the plan gets just enough
    /// nodes of [`Self::gpus_per_node`] GPUs (the paper's testbed shape).
    pub fn topology(mut self, topology: Topology) -> Self {
        self.topology = Some(topology);
        self
    }

    /// GPUs per node for the implicit topology (default 4, Table II).
    /// Conflicts with [`Self::topology`], which fixes the node shape.
    pub fn gpus_per_node(mut self, gpus_per_node: usize) -> Self {
        self.gpus_per_node = Some(gpus_per_node);
        self
    }

    /// Sequence shape of one request: `S_p` prefill and `S_d` decode
    /// tokens (paper Table I). Defaults to Sp = Sd = 128; with artifacts
    /// attached and no explicit workload, the shape derives from the
    /// artifacts instead (their fixed prompt length).
    pub fn workload(mut self, prefill_len: usize, decode_len: usize) -> Self {
        self.workload = Some((prefill_len, decode_len));
        self
    }

    /// Element width `b` in bytes. Defaults to 2 (BF16, like the paper's
    /// runs) — or to the artifacts' dtype when attached, so analytics
    /// describe the bytes numeric serving actually moves. An explicit
    /// value always wins (e.g. a BF16 what-if on the f32 tiny model).
    pub fn dtype_bytes(mut self, dtype_bytes: usize) -> Self {
        self.dtype_bytes = Some(dtype_bytes);
        self
    }

    /// Override the SLO simulator's calibrated constants.
    pub fn calibration(mut self, calibration: Calibration) -> Self {
        self.calibration = Some(calibration);
        self
    }

    /// Collective variants for the plan's TP AllReduce/AllGather payloads:
    /// `wire_bits` is the on-wire precision (16 = the untuned fp16/bf16
    /// wire; 8 and 4 price the Flash-Communication-style quantized
    /// variants plus their quant/dequant compute), `overlap` is the
    /// fraction of per-stage compute that collective time can hide under
    /// (0.0 = fully exposed, the measured stack's eager mode). Validation
    /// happens in `build()` — out-of-domain values surface as
    /// [`PlanError::TuningBitsInvalid`] / [`PlanError::TuningOverlapInvalid`].
    /// This is the *only* way to construct a non-default
    /// [`crate::cluster::CollectiveTuning`]: the raw constructor is
    /// crate-private, and everything downstream of the plan (cost model,
    /// engines, servers, fleets — including `with_autoscale` /
    /// `with_faults` members) inherits the tuning through the plan's
    /// calibration.
    pub fn collective_tuning(mut self, wire_bits: u32, overlap: f64) -> Self {
        self.tuning = Some((wire_bits, overlap));
        self
    }

    /// Sarathi-style chunked-prefill budget for the plan's engines,
    /// servers and fleets: a prompt (suffix) longer than `tokens`
    /// prefills in `tokens`-sized chunks interleaved with decode
    /// iterations of already-admitted sequences, trading the owner's
    /// TTFT for the victims' TPOT instead of stalling decodes behind
    /// one monolithic prefill. Validation happens in `build()` — a zero
    /// budget surfaces as [`PlanError::ChunkTokensInvalid`]. Not
    /// calling this (or a budget at/above every prompt length) keeps
    /// the one-shot prefill path bitwise. Chunking is a serving-schedule
    /// knob: `analyze()`/`simulate()` still describe the one-shot
    /// request shape, and numeric plans reject the knob at `engine()`
    /// time (PJRT prefill graphs are fixed-length).
    pub fn chunked_prefill(mut self, tokens: usize) -> Self {
        self.chunk_tokens = Some(tokens);
        self
    }

    /// Attach built AOT artifacts: `engine()`/`server()` become numeric
    /// (real PJRT compute on the tiny model). Also defaults the
    /// architecture to `tiny` when no model was named.
    pub fn artifacts(mut self, store: ArtifactStore) -> Self {
        self.artifacts = Some(store);
        self
    }

    /// Validate the configuration into an immutable [`DeploymentPlan`].
    pub fn build(self) -> Result<DeploymentPlan, PlanError> {
        let arch = match (self.arch, self.model_name) {
            (Some(arch), Some(name)) => {
                let named = ModelArch::by_name(&name)
                    .ok_or_else(|| PlanError::UnknownModel { name: name.clone() })?;
                if named != arch {
                    return Err(PlanError::ConflictingModel {
                        arch: arch.name.clone(),
                        model: name,
                    });
                }
                arch
            }
            (Some(arch), None) => arch,
            (None, Some(name)) => {
                ModelArch::by_name(&name).ok_or(PlanError::UnknownModel { name })?
            }
            (None, None) => {
                if self.artifacts.is_some() {
                    ModelArch::tiny()
                } else {
                    return Err(PlanError::MissingModel);
                }
            }
        };
        if self.tp == 0 {
            return Err(PlanError::ZeroDegree { axis: "tensor-parallel degree" });
        }
        if self.pp == 0 {
            return Err(PlanError::ZeroDegree { axis: "pipeline-parallel degree" });
        }
        if !arch.supports_tp(self.tp) {
            return Err(PlanError::TpIndivisible {
                model: arch.name.clone(),
                tp: self.tp,
                heads: arch.heads,
                kv_heads: arch.kv_heads,
                intermediate: arch.intermediate,
                vocab: arch.vocab,
            });
        }
        if !arch.supports_pp(self.pp) {
            return Err(PlanError::PpExceedsLayers {
                model: arch.name.clone(),
                pp: self.pp,
                layers: arch.layers,
            });
        }
        let layout = ParallelLayout::new(self.tp, self.pp);
        let (prefill_len, decode_len) = match self.workload {
            Some(workload) => workload,
            // No explicit workload: numeric plans derive it from the
            // artifacts (fixed prompt length, decode within max_seq) so
            // analyze/simulate describe something engine() can serve.
            None => match &self.artifacts {
                Some(store) => {
                    let sp = store.meta.prefill_len;
                    (sp, store.meta.max_seq.saturating_sub(sp).clamp(1, 128))
                }
                None => (128, 128),
            },
        };
        let dtype_bytes = self.dtype_bytes.unwrap_or_else(|| match &self.artifacts {
            Some(store) if store.meta.dtype == "f32" => DTYPE_BYTES_F32,
            _ => DTYPE_BYTES_BF16,
        });
        if prefill_len == 0 || decode_len == 0 || dtype_bytes == 0 {
            return Err(PlanError::InvalidWorkload {
                prefill_len,
                decode_len,
                dtype_bytes,
            });
        }
        // Applies to derived workloads too: a degenerate store (e.g.
        // max_seq <= prefill_len) must fail here, not at the first
        // decode step inside engine().
        if let Some(store) = &self.artifacts {
            check_artifact_workload(store, prefill_len, decode_len)?;
        }
        let shape = InferenceShape::new(prefill_len, decode_len, dtype_bytes);
        if self.topology.is_some() && self.gpus_per_node.is_some() {
            return Err(PlanError::ConflictingTopology);
        }
        let gpus_per_node = self.gpus_per_node.unwrap_or(4);
        if self.topology.is_none() && gpus_per_node == 0 {
            return Err(PlanError::ZeroDegree { axis: "GPUs per node" });
        }
        let topology = self.topology.unwrap_or_else(|| {
            let nodes = layout.world_size().div_ceil(gpus_per_node).max(1);
            Topology::new(nodes, gpus_per_node)
        });
        if layout.world_size() > topology.total_gpus() {
            return Err(PlanError::TopologyTooSmall {
                layout,
                needed: layout.world_size(),
                available: topology.total_gpus(),
            });
        }
        if let Some(store) = &self.artifacts {
            if !store.supports_tp(self.tp) {
                return Err(PlanError::ArtifactsMissingTp {
                    tp: self.tp,
                    available: store.meta.tp_degrees.clone(),
                });
            }
            // engine() executes the artifacts — the analytical side must
            // describe the same model, or analyze/simulate silently lie.
            if store.meta.model != arch.name {
                return Err(PlanError::ArtifactModelMismatch {
                    arch: arch.name.clone(),
                    artifact_model: store.meta.model.clone(),
                });
            }
        }
        let placement =
            Placement::new(topology, layout).expect("layout validated against topology");
        let mut calibration = self.calibration.unwrap_or_default();
        if let Some((wire_bits, overlap)) = self.tuning {
            if !matches!(wire_bits, 4 | 8 | 16) {
                return Err(PlanError::TuningBitsInvalid { bits: wire_bits });
            }
            if !overlap.is_finite() || !(0.0..=1.0).contains(&overlap) {
                return Err(PlanError::TuningOverlapInvalid { value: overlap.to_string() });
            }
            calibration.tuning = crate::cluster::CollectiveTuning::new(wire_bits, overlap);
        }
        if self.chunk_tokens == Some(0) {
            return Err(PlanError::ChunkTokensInvalid { tokens: 0 });
        }
        Ok(DeploymentPlan {
            arch,
            placement,
            shape,
            calibration,
            chunk_tokens: self.chunk_tokens,
            artifacts: self.artifacts,
        })
    }
}

/// Analytical communication prediction for one plan (Eq. 1–7 volumes plus
/// the per-stage op counts/shapes of Tables III–VI).
#[derive(Debug, Clone)]
pub struct VolumeReport {
    pub arch: ModelArch,
    pub layout: ParallelLayout,
    pub shape: InferenceShape,
    /// Per-collective-class corrected volume (bytes).
    pub volume: VolumeBreakdown,
    /// Paper-table-view op predictions for the prefill stage.
    pub prefill_ops: StageOps,
    /// Paper-table-view op predictions for the decode stage.
    pub decode_ops: StageOps,
    /// Global-view predictions (all ranks, each transfer counted once —
    /// the Table V / Fig. 5 convention) for the prefill stage.
    pub prefill_global_ops: StageOps,
    /// Global-view predictions for the decode stage.
    pub decode_global_ops: StageOps,
}

impl VolumeReport {
    /// Total corrected communication volume in bytes (the paper's headline
    /// number per layout).
    pub fn total_bytes(&self) -> f64 {
        self.volume.total()
    }

    /// The predicted op stream of one stage (per-worker paper view).
    pub fn ops(&self, stage: Stage) -> &StageOps {
        match stage {
            Stage::Prefill => &self.prefill_ops,
            Stage::Decode => &self.decode_ops,
        }
    }

    /// The predicted op stream of one stage in the global view (all
    /// ranks, each transfer counted once).
    pub fn global_ops(&self, stage: Stage) -> &StageOps {
        match stage {
            Stage::Prefill => &self.prefill_global_ops,
            Stage::Decode => &self.decode_global_ops,
        }
    }
}

/// A validated deployment: model × layout × placement × workload (plus
/// optional artifacts and calibration overrides). Cheap to clone; every
/// verb can be called any number of times.
#[derive(Debug, Clone)]
pub struct DeploymentPlan {
    arch: ModelArch,
    placement: Placement,
    shape: InferenceShape,
    calibration: Calibration,
    chunk_tokens: Option<usize>,
    artifacts: Option<ArtifactStore>,
}

impl DeploymentPlan {
    /// The plan's architecture.
    pub fn arch(&self) -> &ModelArch {
        &self.arch
    }

    /// The plan's parallel layout.
    pub fn layout(&self) -> ParallelLayout {
        self.placement.layout
    }

    /// The plan's placement onto the cluster topology.
    pub fn placement(&self) -> &Placement {
        &self.placement
    }

    /// The plan's cluster topology.
    pub fn topology(&self) -> Topology {
        self.placement.topology
    }

    /// The plan's sequence shape.
    pub fn shape(&self) -> InferenceShape {
        self.shape
    }

    /// Whether `engine()`/`server()` will execute real numeric compute.
    pub fn is_numeric(&self) -> bool {
        self.artifacts.is_some()
    }

    /// The plan's collective tuning (wire precision + overlap factor),
    /// as validated by the builder.
    pub fn collective_tuning(&self) -> crate::cluster::CollectiveTuning {
        self.calibration.tuning
    }

    /// The plan's chunked-prefill token budget (`None` = one-shot
    /// prefill), as validated by the builder.
    pub fn chunk_tokens(&self) -> Option<usize> {
        self.chunk_tokens
    }

    /// Human-readable identity, e.g. `Llama-3.1-8B TP=2 PP=2`.
    pub fn label(&self) -> String {
        format!("{} {}", self.arch.name, self.layout().label())
    }

    /// Same plan, different sequence shape (re-validated, including
    /// against attached artifacts).
    pub fn with_workload(
        mut self,
        prefill_len: usize,
        decode_len: usize,
    ) -> Result<Self, PlanError> {
        if prefill_len == 0 || decode_len == 0 {
            return Err(PlanError::InvalidWorkload {
                prefill_len,
                decode_len,
                dtype_bytes: self.shape.dtype_bytes,
            });
        }
        if let Some(store) = &self.artifacts {
            check_artifact_workload(store, prefill_len, decode_len)?;
        }
        self.shape = InferenceShape::new(prefill_len, decode_len, self.shape.dtype_bytes);
        Ok(self)
    }

    /// Same plan, different calibration — the fault-injection hook: a
    /// straggler replica is this plan with
    /// [`crate::cluster::NetModel::degraded`] applied to its calibration's
    /// network, so its engine pricing, cost model, and wire all slow down
    /// together. `Calibration` is unconstrained (any finite constants
    /// describe *some* testbed), so no re-validation is needed.
    pub fn with_calibration(mut self, calibration: Calibration) -> Self {
        self.calibration = calibration;
        self
    }

    /// Analytical communication prediction (Eq. 1–7 + Tables III–VI).
    pub fn analyze(&self) -> VolumeReport {
        let volume = VolumeModel::new(self.arch.clone()).volume(self.layout(), self.shape);
        let ops = OpCountModel::new(self.arch.clone(), self.layout(), self.shape);
        VolumeReport {
            arch: self.arch.clone(),
            layout: self.layout(),
            shape: self.shape,
            volume,
            prefill_ops: ops.predict_paper_view(Stage::Prefill),
            decode_ops: ops.predict_paper_view(Stage::Decode),
            prefill_global_ops: ops.predict_global(Stage::Prefill),
            decode_global_ops: ops.predict_global(Stage::Decode),
        }
    }

    /// The plan's pricing core: the α–β/compute cost model over this
    /// placement and calibration — what `simulate()` reads closed forms
    /// from and what `trace()`/`engine()`/`server()` price records and
    /// model-time clocks with.
    pub fn cost_model(&self) -> CostModel {
        CostModel::new(self.arch.clone(), self.placement.clone(), self.calibration)
    }

    /// Run the structural engine over the plan's workload and return the
    /// measured collective stream (priced: every record carries modeled
    /// α–β seconds). Always structural (the paper's measurement mode)
    /// regardless of attached artifacts.
    pub fn trace(&self) -> crate::Result<TraceSummary> {
        let mut engine = Engine::new(self.structural_config())?;
        engine.generate(&vec![0i32; self.shape.prefill_len], self.shape.decode_len)?;
        Ok(engine.trace().summary())
    }

    /// Simulate TTFT / TPOT / E2E on the calibrated testbed model.
    pub fn simulate(&self) -> SloResult {
        SloSimulator::new(self.arch.clone(), self.placement.clone())
            .with_calibration(self.calibration)
            .simulate(self.shape)
    }

    /// Build a live engine: numeric (PJRT, tiny model) when artifacts are
    /// attached, structural (paper-scale, no-op compute) otherwise. Both
    /// carry the plan's cost model, pricing every traced collective;
    /// structural engines additionally drive a model-time session clock
    /// (numeric serving keeps wall clocks as its primary latency).
    pub fn engine(&self) -> crate::Result<Engine> {
        let cfg = match &self.artifacts {
            // Numeric configs keep the chunk knob too: Engine::new owns
            // the "PJRT prefill graphs are fixed-length" rejection, so a
            // chunked numeric plan fails loudly instead of silently
            // serving one-shot.
            Some(store) => EngineConfig::numeric(store.clone(), self.layout())
                .with_pricing(self.cost_model())
                .with_chunk_tokens(self.chunk_tokens),
            None => self.structural_config(),
        };
        Engine::new(cfg)
    }

    /// Structural engine config priced with this plan's own cost model
    /// (not the on-cardinal default `EngineConfig::structural` would
    /// build and immediately discard).
    fn structural_config(&self) -> EngineConfig {
        EngineConfig {
            arch: self.arch.clone(),
            layout: self.layout(),
            mode: EngineMode::Structural,
            trace_dtype_bytes: DTYPE_BYTES_BF16,
            pricing: Some(self.cost_model()),
            chunk_tokens: self.chunk_tokens,
        }
    }

    /// A colocated fleet of `replicas` copies of this plan — the entry
    /// point to the fleet simulator ([`crate::fleet`]). The returned
    /// [`crate::fleet::FleetSpec`] composes further: heterogeneous
    /// replicas via [`crate::fleet::FleetSpec::add_replicas`],
    /// disaggregated prefill/decode pools via
    /// [`crate::fleet::FleetSpec::disaggregated`], router/scheduler/node
    /// knobs via its `with_*` methods, then
    /// [`crate::fleet::FleetSpec::simulate`] runs a workload on the model
    /// clock. Requires a structural plan (numeric engines cannot be
    /// replicated).
    pub fn fleet(&self, replicas: usize) -> Result<crate::fleet::FleetSpec, PlanError> {
        crate::fleet::FleetSpec::colocated(self, replicas)
    }

    /// Build a full serving stack — iteration-level continuous-batching
    /// scheduler + engine session — over [`Self::engine`].
    ///
    /// `cfg.max_batch` is the serving concurrency knob (how many sequences
    /// share each decode iteration); it is clamped to 1 on numeric plans,
    /// whose PJRT backends hold single-sequence KV state. Arrival-process
    /// knobs live on the server itself: `serve_batch` is open-loop
    /// all-at-once, `serve_poisson` replays Poisson arrivals at a
    /// configurable rate (the `serve` CLI exposes both as
    /// `--concurrency` / `--arrival-rate`).
    pub fn server(&self, cfg: SchedulerConfig) -> crate::Result<Server> {
        Ok(Server::new(self.engine()?, cfg))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::CollectiveKind;
    use crate::runtime::ArtifactMeta;

    #[test]
    fn rejects_indivisible_tp() {
        let err = Deployment::builder().model("8b").tp(3).build().unwrap_err();
        assert!(matches!(err, PlanError::TpIndivisible { tp: 3, .. }), "{err}");
    }

    #[test]
    fn rejects_pp_exceeding_layers() {
        let err = Deployment::builder().model("3b").pp(64).build().unwrap_err();
        assert!(
            matches!(err, PlanError::PpExceedsLayers { pp: 64, layers: 28, .. }),
            "{err}"
        );
    }

    #[test]
    fn rejects_layout_exceeding_topology() {
        let err = Deployment::builder()
            .model("8b")
            .tp(4)
            .pp(2)
            .topology(Topology::new(1, 4))
            .build()
            .unwrap_err();
        assert_eq!(
            err,
            PlanError::TopologyTooSmall {
                layout: ParallelLayout::new(4, 2),
                needed: 8,
                available: 4,
            }
        );
    }

    #[test]
    fn rejects_unknown_and_missing_models() {
        let err = Deployment::builder().model("70b").build().unwrap_err();
        assert_eq!(err, PlanError::UnknownModel { name: "70b".into() });
        let err = Deployment::builder().tp(2).build().unwrap_err();
        assert_eq!(err, PlanError::MissingModel);
    }

    #[test]
    fn rejects_conflicting_topology_selection() {
        let err = Deployment::builder()
            .model("8b")
            .tp(4)
            .topology(Topology::new(2, 2))
            .gpus_per_node(8)
            .build()
            .unwrap_err();
        assert_eq!(err, PlanError::ConflictingTopology);
    }

    #[test]
    fn rejects_conflicting_model_selection() {
        let err = Deployment::builder()
            .arch(ModelArch::tiny())
            .model("13b")
            .build()
            .unwrap_err();
        assert!(matches!(err, PlanError::ConflictingModel { .. }), "{err}");
        // Agreeing selections coexist fine.
        let both = Deployment::builder().arch(ModelArch::llama2_13b()).model("13b");
        assert!(both.build().is_ok());
    }

    #[test]
    fn rejects_zero_degrees_and_workloads() {
        assert!(matches!(
            Deployment::builder().model("8b").tp(0).build().unwrap_err(),
            PlanError::ZeroDegree { .. }
        ));
        assert!(matches!(
            Deployment::builder().model("8b").pp(0).build().unwrap_err(),
            PlanError::ZeroDegree { .. }
        ));
        assert!(matches!(
            Deployment::builder().model("8b").workload(0, 128).build().unwrap_err(),
            PlanError::InvalidWorkload { .. }
        ));
        assert!(matches!(
            Deployment::builder().model("8b").dtype_bytes(0).build().unwrap_err(),
            PlanError::InvalidWorkload { .. }
        ));
        assert!(matches!(
            Deployment::builder().model("8b").gpus_per_node(0).build().unwrap_err(),
            PlanError::ZeroDegree { .. }
        ));
    }

    #[test]
    fn collective_tuning_validates_and_threads_into_the_calibration() {
        // Out-of-domain knobs surface as typed errors.
        let err = Deployment::builder().model("8b").collective_tuning(12, 0.0).build();
        assert_eq!(err.unwrap_err(), PlanError::TuningBitsInvalid { bits: 12 });
        for bad in [-0.1, 1.5, f64::NAN, f64::INFINITY] {
            let err =
                Deployment::builder().model("8b").collective_tuning(8, bad).build().unwrap_err();
            assert!(matches!(err, PlanError::TuningOverlapInvalid { .. }), "{bad}: {err}");
        }
        // No tuning call -> the identity default, bitwise.
        let plain = Deployment::builder().model("8b").tp(2).build().unwrap();
        assert!(plain.collective_tuning().is_default());
        // An explicit identity tuning is the same default.
        let explicit =
            Deployment::builder().model("8b").tp(2).collective_tuning(16, 0.0).build().unwrap();
        assert_eq!(explicit.collective_tuning(), plain.collective_tuning());
        assert_eq!(explicit.simulate(), plain.simulate(), "identity tuning reprices nothing");
        // A quantized wire reaches the cost model and cheapens comm.
        let int8 =
            Deployment::builder().model("8b").tp(2).collective_tuning(8, 0.0).build().unwrap();
        assert_eq!(int8.collective_tuning().wire_bits(), 8);
        assert!(int8.collective_tuning().quantizes());
        let shape = int8.shape();
        let tuned = int8.cost_model().prefill_breakdown(shape);
        let untuned = plain.cost_model().prefill_breakdown(shape);
        assert!(tuned.comm_s < untuned.comm_s);
        assert_eq!(tuned.compute_s, untuned.compute_s);
    }

    #[test]
    fn chunked_prefill_validates_and_threads_into_the_engine() {
        // A zero budget is a typed construction error, not a DES panic.
        let err = Deployment::builder().model("8b").chunked_prefill(0).build().unwrap_err();
        assert_eq!(err, PlanError::ChunkTokensInvalid { tokens: 0 });
        // No call -> one-shot prefill, and the engine config agrees.
        let plain = Deployment::builder().model("3b").tp(2).build().unwrap();
        assert_eq!(plain.chunk_tokens(), None);
        assert_eq!(plain.engine().unwrap().config().chunk_tokens, None);
        // A positive budget survives into the plan and its engines.
        let chunked =
            Deployment::builder().model("3b").tp(2).chunked_prefill(256).build().unwrap();
        assert_eq!(chunked.chunk_tokens(), Some(256));
        assert_eq!(chunked.engine().unwrap().config().chunk_tokens, Some(256));
        // The knob reschedules serving; it does not change the request
        // shape the analytical models describe.
        assert_eq!(chunked.analyze().volume, plain.analyze().volume);
        assert_eq!(chunked.simulate(), plain.simulate());
        // Numeric plans reject the knob at engine() time: PJRT prefill
        // graphs are fixed-length, so chunking cannot be served.
        const META: &str = "model=tiny-llama\nvocab=512\nhidden=256\nintermediate=768\n\
            layers=4\nheads=8\nhead_dim=32\nmax_seq=128\nprefill_len=32\nseed=0\n\
            dtype=f32\ntp_degrees=1,2,4\n";
        let store = ArtifactStore {
            dir: std::path::PathBuf::from("/nonexistent"),
            meta: ArtifactMeta::parse(META).unwrap(),
        };
        let numeric =
            Deployment::builder().artifacts(store).chunked_prefill(16).build().unwrap();
        let err = numeric.engine().unwrap_err().to_string();
        assert!(err.contains("chunked prefill"), "{err}");
    }

    #[test]
    fn implicit_topology_uses_just_enough_cardinal_nodes() {
        let plan = Deployment::builder().model("3b").tp(2).pp(4).build().unwrap();
        assert_eq!(plan.topology(), Topology::new(2, 4));
        assert_eq!(plan.layout(), ParallelLayout::new(2, 4));
        assert_eq!(plan.label(), "Llama-3.2-3B TP=2 PP=4");
        let single = Deployment::builder().model("3b").build().unwrap();
        assert_eq!(single.topology(), Topology::new(1, 4));
    }

    #[test]
    fn analyze_matches_direct_volume_model() {
        let plan =
            Deployment::builder().model("8b").tp(2).pp(2).workload(128, 128).build().unwrap();
        let vr = plan.analyze();
        let direct = VolumeModel::new(ModelArch::llama31_8b())
            .volume(ParallelLayout::new(2, 2), InferenceShape::new(128, 128, 2));
        assert_eq!(vr.volume, direct);
        assert!(vr.total_bytes() > 0.0);
        // Table VI's headline counts surface through the report.
        assert_eq!(vr.ops(Stage::Prefill).count(CollectiveKind::AllReduce), 33);
        assert_eq!(vr.decode_ops.count(CollectiveKind::AllReduce), 4191);
    }

    #[test]
    fn simulate_matches_direct_simulator() {
        let plan = Deployment::builder().model("3b").tp(4).build().unwrap();
        let direct = SloSimulator::on_cardinal(ModelArch::llama32_3b(), ParallelLayout::new(4, 1))
            .unwrap()
            .simulate(InferenceShape::new(128, 128, 2));
        assert_eq!(plan.simulate(), direct);
    }

    #[test]
    fn trace_agrees_with_analyze_counts() {
        let plan =
            Deployment::builder().arch(ModelArch::tiny()).tp(2).workload(16, 8).build().unwrap();
        let summary = plan.trace().unwrap();
        let vr = plan.analyze();
        for stage in [Stage::Prefill, Stage::Decode] {
            for op in [CollectiveKind::AllReduce, CollectiveKind::Gather] {
                assert_eq!(
                    summary.paper_view(op, stage).count,
                    vr.ops(stage).count(op),
                    "{op:?} {stage:?}"
                );
            }
        }
    }

    #[test]
    fn with_workload_revalidates() {
        let plan = Deployment::builder().model("8b").build().unwrap();
        let plan = plan.with_workload(64, 32).unwrap();
        assert_eq!(plan.shape().prefill_len, 64);
        assert_eq!(plan.shape().decode_len, 32);
        let plan = Deployment::builder().model("8b").build().unwrap();
        assert!(matches!(
            plan.with_workload(0, 32).unwrap_err(),
            PlanError::InvalidWorkload { .. }
        ));
    }

    #[test]
    fn artifacts_must_cover_the_tp_degree() {
        const META: &str = "model=tiny-llama\nvocab=512\nhidden=256\nintermediate=768\n\
            layers=4\nheads=8\nhead_dim=32\nmax_seq=128\nprefill_len=32\nseed=0\n\
            dtype=f32\ntp_degrees=1,2,4\n";
        let store = ArtifactStore {
            dir: std::path::PathBuf::from("/nonexistent"),
            meta: ArtifactMeta::parse(META).unwrap(),
        };
        // tiny supports tp=8 architecturally, but the store was not built
        // for it — the plan must reject before any worker spawns.
        let err =
            Deployment::builder().artifacts(store.clone()).tp(8).build().unwrap_err();
        assert_eq!(err, PlanError::ArtifactsMissingTp { tp: 8, available: vec![1, 2, 4] });
        // The analytical arch must be the artifact model: a plan that
        // analyzes 8B but serves tiny artifacts is rejected.
        let err = Deployment::builder()
            .model("8b")
            .artifacts(store.clone())
            .tp(2)
            .build()
            .unwrap_err();
        assert!(matches!(err, PlanError::ArtifactModelMismatch { .. }), "{err}");
        // A workload the artifacts cannot serve is rejected up front...
        let err = Deployment::builder()
            .artifacts(store.clone())
            .tp(2)
            .workload(128, 128)
            .build()
            .unwrap_err();
        assert!(matches!(err, PlanError::ArtifactWorkloadMismatch { .. }), "{err}");
        // ...as is reshaping an already-built numeric plan.
        let plan = Deployment::builder().artifacts(store.clone()).tp(2).build().unwrap();
        assert!(matches!(
            plan.with_workload(128, 128).unwrap_err(),
            PlanError::ArtifactWorkloadMismatch { .. }
        ));
        // A servable explicit workload is fine.
        assert!(Deployment::builder()
            .artifacts(store.clone())
            .tp(2)
            .workload(32, 16)
            .build()
            .is_ok());
        // A degenerate store (no decode room at all: max_seq == prefill)
        // cannot produce a "valid" plan via the derived workload either.
        let degenerate = ArtifactStore {
            dir: std::path::PathBuf::from("/nonexistent"),
            meta: ArtifactMeta::parse(&META.replace("max_seq=128", "max_seq=32")).unwrap(),
        };
        let err = Deployment::builder().artifacts(degenerate).tp(2).build().unwrap_err();
        assert!(matches!(err, PlanError::ArtifactWorkloadMismatch { .. }), "{err}");
        // A covered degree builds (numeric), defaults the arch to tiny and
        // derives the workload from the artifacts (Sp=32, Sd within max_seq).
        let plan = Deployment::builder().artifacts(store).tp(2).build().unwrap();
        assert!(plan.is_numeric());
        assert_eq!(plan.arch().name, "tiny-llama");
        assert_eq!(plan.shape().prefill_len, 32);
        assert_eq!(plan.shape().decode_len, 96);
        // ...including the dtype: the tiny model serves f32, so analytics
        // must count 4 bytes per element, not the BF16 default.
        assert_eq!(plan.shape().dtype_bytes, 4);
    }
}
