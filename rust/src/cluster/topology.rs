//! Node/GPU topology and worker placement.
//!
//! The paper's testbed (Table II): nodes of 4× H100 with NVLink inside and
//! InfiniBand NDR400 between. Parallelism placement follows vLLM: global
//! rank `r` = `pp_stage * tp + tp_rank`, ranks filled onto GPUs in order,
//! TP groups packed within a node first (§II.B: "TP within compute nodes,
//! PP across").


use crate::analysis::ParallelLayout;

/// Physical cluster shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Topology {
    pub nodes: usize,
    pub gpus_per_node: usize,
}

impl Topology {
    pub fn new(nodes: usize, gpus_per_node: usize) -> Self {
        assert!(nodes >= 1 && gpus_per_node >= 1);
        Self { nodes, gpus_per_node }
    }

    /// The paper's testbed: 4×H100 per node.
    pub fn cardinal(nodes: usize) -> Self {
        Self::new(nodes, 4)
    }

    pub fn total_gpus(&self) -> usize {
        self.nodes * self.gpus_per_node
    }

    /// Node hosting a global rank (ranks fill nodes in order).
    pub fn node_of(&self, rank: usize) -> usize {
        assert!(rank < self.total_gpus(), "rank {rank} out of range");
        rank / self.gpus_per_node
    }

    /// Whether two ranks share a node (NVLink) or cross nodes (IB).
    pub fn same_node(&self, a: usize, b: usize) -> bool {
        self.node_of(a) == self.node_of(b)
    }
}

/// Mapping of a parallel layout onto a topology.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Placement {
    pub topology: Topology,
    pub layout: ParallelLayout,
}

impl Placement {
    pub fn new(topology: Topology, layout: ParallelLayout) -> crate::Result<Self> {
        if layout.world_size() > topology.total_gpus() {
            anyhow::bail!(
                "layout {} needs {} GPUs but topology has {}",
                layout.label(),
                layout.world_size(),
                topology.total_gpus()
            );
        }
        Ok(Self { topology, layout })
    }

    /// Global rank of (pp_stage, tp_rank) — vLLM placement.
    pub fn global_rank(&self, pp_stage: usize, tp_rank: usize) -> usize {
        assert!(pp_stage < self.layout.pp && tp_rank < self.layout.tp);
        pp_stage * self.layout.tp + tp_rank
    }

    /// Ranks of one TP group (a pipeline stage's workers).
    pub fn tp_group(&self, pp_stage: usize) -> Vec<usize> {
        (0..self.layout.tp).map(|t| self.global_rank(pp_stage, t)).collect()
    }

    /// Whether the TP group of `pp_stage` spans nodes (forces its
    /// AllReduces onto the inter-node fabric).
    pub fn tp_group_crosses_nodes(&self, pp_stage: usize) -> bool {
        let ranks = self.tp_group(pp_stage);
        let first = self.topology.node_of(ranks[0]);
        ranks.iter().any(|&r| self.topology.node_of(r) != first)
    }

    /// Whether the pipeline boundary `stage -> stage+1` crosses nodes
    /// (checked pairwise on the slice-exchanging rank pairs).
    pub fn pp_boundary_crosses_nodes(&self, stage: usize) -> bool {
        assert!(stage + 1 < self.layout.pp);
        (0..self.layout.tp).any(|t| {
            !self.topology.same_node(
                self.global_rank(stage, t),
                self.global_rank(stage + 1, t),
            )
        })
    }

    /// Number of pipeline boundaries that cross nodes.
    pub fn internode_boundaries(&self) -> usize {
        (0..self.layout.pp.saturating_sub(1))
            .filter(|&s| self.pp_boundary_crosses_nodes(s))
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_assignment() {
        let t = Topology::cardinal(2);
        assert_eq!(t.total_gpus(), 8);
        assert_eq!(t.node_of(0), 0);
        assert_eq!(t.node_of(3), 0);
        assert_eq!(t.node_of(4), 1);
        assert!(t.same_node(0, 3));
        assert!(!t.same_node(3, 4));
    }

    #[test]
    #[should_panic]
    fn node_of_out_of_range_panics() {
        Topology::cardinal(1).node_of(4);
    }

    #[test]
    fn placement_rejects_oversubscription() {
        let t = Topology::cardinal(1);
        assert!(Placement::new(t, ParallelLayout::new(8, 1)).is_err());
        assert!(Placement::new(t, ParallelLayout::new(4, 1)).is_ok());
    }

    #[test]
    fn tp8_on_two_nodes_crosses() {
        // Paper Fig. 8: TP=8 spans two 4-GPU nodes -> inter-node AllReduce.
        let p = Placement::new(Topology::cardinal(2), ParallelLayout::new(8, 1)).unwrap();
        assert!(p.tp_group_crosses_nodes(0));
        // TP=4 on one node does not.
        let p4 = Placement::new(Topology::cardinal(1), ParallelLayout::new(4, 1)).unwrap();
        assert!(!p4.tp_group_crosses_nodes(0));
    }

    #[test]
    fn pp8_has_one_internode_boundary() {
        // Paper Fig. 9: PP=8 on two nodes -> the 3->4 boundary crosses.
        let p = Placement::new(Topology::cardinal(2), ParallelLayout::new(1, 8)).unwrap();
        assert_eq!(p.internode_boundaries(), 1);
        assert!(p.pp_boundary_crosses_nodes(3));
        assert!(!p.pp_boundary_crosses_nodes(2));
    }

    #[test]
    fn hybrid_placements_fig10() {
        let topo = Topology::cardinal(2);
        // TP=2 PP=4: stages {0,1} node0, {2,3} node1 -> TP intra-node,
        // one inter-node pp boundary.
        let p = Placement::new(topo, ParallelLayout::new(2, 4)).unwrap();
        assert!(!p.tp_group_crosses_nodes(0));
        assert!(!p.tp_group_crosses_nodes(3));
        assert_eq!(p.internode_boundaries(), 1);
        // TP=4 PP=2: each stage's TP group fills one node.
        let p = Placement::new(topo, ParallelLayout::new(4, 2)).unwrap();
        assert!(!p.tp_group_crosses_nodes(0));
        assert_eq!(p.internode_boundaries(), 1);
    }

    #[test]
    fn rank_numbering_is_tp_major() {
        let p = Placement::new(Topology::cardinal(2), ParallelLayout::new(2, 2)).unwrap();
        assert_eq!(p.global_rank(0, 0), 0);
        assert_eq!(p.global_rank(0, 1), 1);
        assert_eq!(p.global_rank(1, 0), 2);
        assert_eq!(p.tp_group(1), vec![2, 3]);
    }
}
