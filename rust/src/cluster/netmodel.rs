//! α–β network cost model for the paper's interconnects.
//!
//! We cannot measure NVLink/InfiniBand on this testbed, so collective costs
//! are modeled with the standard latency–bandwidth (α–β) form the NCCL
//! performance guide uses ([16] in the paper): a ring AllReduce over `d`
//! workers moves `2(d−1)/d · n` bytes per GPU in `2(d−1)` steps, etc. Byte
//! factors and step counts come from the shared collective algebra
//! ([`crate::simtime::algebra`]) so they can never drift from the volume
//! accounting. Constants are calibrated in
//! [`crate::perfmodel::calibration`]; the *ratios* (NVLink ≫ IB in
//! bandwidth, IB ≫ NVLink in latency) are what the paper's SLO shapes
//! depend on.
//!
//! Two algorithms are modeled for node-spanning AllReduce:
//! - **flat ring** at the slowest member link ([`NetModel::allreduce`]
//!   with `crosses_nodes`) — what the paper's measured stack runs (vLLM
//!   0.8.5, custom-allreduce disabled), and what the SLO calibration was
//!   fitted against;
//! - **two-level hierarchical** ([`NetModel::allreduce_two_level`]) —
//!   intra-node ReduceScatter, inter-node AllReduce over one leader per
//!   node, intra-node AllGather: the NCCL-tree-style what-if, exposed
//!   placement-aware through
//!   [`crate::simtime::CostModel::tp_allreduce_two_level`] to bound how
//!   much a topology-aware algorithm could save over the measured flat
//!   ring.

use super::topology::Placement;
use crate::comm::CollectiveKind;
use crate::simtime::algebra;

/// Link class between two workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LinkClass {
    /// Intra-node NVLink (NVLink4 on H100).
    NvLink,
    /// Inter-node InfiniBand NDR400 (4 NICs/node on the paper's testbed).
    InfiniBand,
}

/// α–β parameters of one link class.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkParams {
    /// Per-operation launch + wire latency (seconds).
    pub alpha_s: f64,
    /// Effective per-GPU bus bandwidth (bytes/second).
    pub bus_bw: f64,
}

/// Network model over a placement.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetModel {
    pub nvlink: LinkParams,
    pub ib: LinkParams,
}

/// Cost decomposition of one collective (seconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CollectiveCost {
    pub latency_s: f64,
    pub transfer_s: f64,
}

impl CollectiveCost {
    pub fn total(&self) -> f64 {
        self.latency_s + self.transfer_s
    }
}

/// Collective tuning — wire precision and compute–comm overlap for TP
/// AllReduce/AllGather payloads (Flash Communication, arXiv:2412.04964).
///
/// The default (16-bit wire, zero overlap) prices every collective exactly
/// as the untuned model — bitwise, with no branch taken on the quantized
/// formulas. Non-default tunings are only constructible through the
/// validated plan builder
/// ([`Deployment::builder().collective_tuning(..)`](crate::plan::Deployment::collective_tuning))
/// or the CLI's `--wire-bits`/`--overlap` flags: the constructor is
/// crate-private, so no caller can bypass the `PlanError` validation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CollectiveTuning {
    wire_bits: u32,
    overlap: f64,
}

impl Default for CollectiveTuning {
    fn default() -> Self {
        Self { wire_bits: 16, overlap: 0.0 }
    }
}

impl CollectiveTuning {
    /// Crate-private: validation lives in `plan::Deployment::build` — the
    /// only producers of non-default tunings are the plan builder and the
    /// CLI flags layered on it.
    pub(crate) fn new(wire_bits: u32, overlap: f64) -> Self {
        debug_assert!(matches!(wire_bits, 4 | 8 | 16), "plan validation owns the gate");
        debug_assert!((0.0..=1.0).contains(&overlap));
        Self { wire_bits, overlap }
    }

    /// Wire precision of AllReduce/AllGather payloads, in bits (16 = the
    /// untuned fp16/bf16 wire; 8 and 4 quantize).
    pub fn wire_bits(&self) -> u32 {
        self.wire_bits
    }

    /// Fraction of per-stage compute that exposed collective time can hide
    /// under (0.0 = fully exposed, the eager-mode default).
    pub fn overlap(&self) -> f64 {
        self.overlap
    }

    /// Wire-byte scale `wire_bits / 16` (exactly 1.0 at the default).
    pub fn wire_factor(&self) -> f64 {
        f64::from(self.wire_bits) / 16.0
    }

    /// Whether the quantized collective variants are in play.
    pub fn quantizes(&self) -> bool {
        self.wire_bits < 16
    }

    /// Whether any knob departs from the untuned default.
    pub fn is_default(&self) -> bool {
        self.wire_bits == 16 && self.overlap == 0.0
    }
}

impl Default for NetModel {
    fn default() -> Self {
        Self {
            // NVLink4: ~450 GB/s/dir peak; NCCL ring busbw on 2-4 GPUs
            // measured around 300 GB/s effective; small-message launch ~4 µs.
            nvlink: LinkParams { alpha_s: 4.0e-6, bus_bw: 300.0e9 },
            // NDR400: 50 GB/s/NIC raw; NCCL cross-node small-message launch
            // ~14 µs, effective per-GPU busbw ~40 GB/s.
            ib: LinkParams { alpha_s: 14.0e-6, bus_bw: 40.0e9 },
        }
    }
}

impl LinkParams {
    /// This link degraded by `factor >= 1.0`: launch latency inflates by
    /// the factor and effective bandwidth shrinks by it — the α–β form of
    /// a slow rank or a throttled link ([`crate::faults`]). `x * 1.0` and
    /// `x / 1.0` are bitwise f64 identities, so `degraded(1.0)` is
    /// bit-for-bit the healthy link with no branch.
    pub fn degraded(&self, factor: f64) -> LinkParams {
        LinkParams { alpha_s: self.alpha_s * factor, bus_bw: self.bus_bw / factor }
    }
}

impl NetModel {
    /// Link parameters governing a group: the slowest member link.
    pub fn group_params(&self, crosses_nodes: bool) -> LinkParams {
        if crosses_nodes { self.ib } else { self.nvlink }
    }

    /// Both fabrics degraded by `factor >= 1.0` (straggler rank: every
    /// collective touching the replica runs at the slowest member's
    /// speed, so one slow rank degrades the whole group — the α–β analog
    /// of the paper's slowest-participant observation). `degraded(1.0)`
    /// is bitwise the healthy model.
    pub fn degraded(&self, factor: f64) -> NetModel {
        NetModel { nvlink: self.nvlink.degraded(factor), ib: self.ib.degraded(factor) }
    }

    /// Ring AllReduce over `d` workers, message `n` bytes:
    /// `2(d−1) α + 2(d−1)/d · n / busbw`.
    pub fn allreduce(&self, n_bytes: f64, d: usize, crosses_nodes: bool) -> CollectiveCost {
        if d <= 1 {
            return CollectiveCost { latency_s: 0.0, transfer_s: 0.0 };
        }
        let p = self.group_params(crosses_nodes);
        CollectiveCost {
            latency_s: algebra::allreduce_steps(d) * p.alpha_s,
            transfer_s: CollectiveKind::AllReduce.correction_factor(d) * n_bytes / p.bus_bw,
        }
    }

    /// Two-level hierarchical AllReduce over `nodes × gpus_per_node`
    /// workers: intra-node ReduceScatter over NVLink, inter-node AllReduce
    /// of the per-node shard (`n / g` bytes) over IB between one leader
    /// per node, intra-node AllGather over NVLink.
    ///
    /// The formula is floored at the flat all-NVLink ring of the same
    /// group: a node-spanning collective can never beat the same group on
    /// pure NVLink, and the raw two-phase sum ignores the cross-phase
    /// synchronization that makes tiny hierarchical messages pay at least
    /// the single-fabric launch train. A single-node group degenerates to
    /// the flat NVLink ring.
    pub fn allreduce_two_level(
        &self,
        n_bytes: f64,
        gpus_per_node: usize,
        nodes: usize,
    ) -> CollectiveCost {
        let g = gpus_per_node.max(1);
        let d = g * nodes.max(1);
        if d <= 1 {
            return CollectiveCost { latency_s: 0.0, transfer_s: 0.0 };
        }
        let flat_nv = self.allreduce(n_bytes, d, false);
        if nodes <= 1 {
            return flat_nv;
        }
        // Intra-node ReduceScatter + AllGather: 2(g−1) NVLink steps moving
        // 2(g−1)/g · n bytes; inter-node ring AllReduce of the n/g shard.
        let two_level = CollectiveCost {
            latency_s: 2.0 * algebra::allgather_steps(g) * self.nvlink.alpha_s
                + algebra::allreduce_steps(nodes) * self.ib.alpha_s,
            transfer_s: 2.0 * algebra::allgather_factor(g) * n_bytes / self.nvlink.bus_bw
                + algebra::allreduce_factor(nodes) * (n_bytes / g as f64) / self.ib.bus_bw,
        };
        if two_level.total() < flat_nv.total() {
            flat_nv
        } else {
            two_level
        }
    }

    /// Ring AllGather to `n_out` gathered bytes over `d` workers:
    /// `(d−1) α + (d−1)/d · n_out / busbw`.
    pub fn allgather(&self, n_out_bytes: f64, d: usize, crosses_nodes: bool) -> CollectiveCost {
        if d <= 1 {
            return CollectiveCost { latency_s: 0.0, transfer_s: 0.0 };
        }
        let p = self.group_params(crosses_nodes);
        CollectiveCost {
            latency_s: algebra::allgather_steps(d) * p.alpha_s,
            transfer_s: CollectiveKind::AllGather.correction_factor(d) * n_out_bytes / p.bus_bw,
        }
    }

    /// [`Self::allreduce`] under a [`CollectiveTuning`]: with a quantized
    /// wire the ring's `2(d−1)` launches collapse to the Flash
    /// Communication all-to-all + all-gather pair and the transfer term
    /// carries `wire_bits/16` of the bytes. An untuned wire (16 bits)
    /// takes the untuned path — bitwise.
    pub fn allreduce_tuned(
        &self,
        n_bytes: f64,
        d: usize,
        crosses_nodes: bool,
        tuning: CollectiveTuning,
    ) -> CollectiveCost {
        if !tuning.quantizes() {
            return self.allreduce(n_bytes, d, crosses_nodes);
        }
        if d <= 1 {
            return CollectiveCost { latency_s: 0.0, transfer_s: 0.0 };
        }
        let p = self.group_params(crosses_nodes);
        CollectiveCost {
            latency_s: algebra::quantized_allreduce_steps(d) * p.alpha_s,
            transfer_s: CollectiveKind::AllReduce.correction_factor(d)
                * n_bytes
                * tuning.wire_factor()
                / p.bus_bw,
        }
    }

    /// [`Self::allgather`] under a [`CollectiveTuning`]: the two-step
    /// quantized all-gather pays at most two launches and ships
    /// `wire_bits/16` of the gathered bytes. Untuned wires take the
    /// untuned path — bitwise.
    pub fn allgather_tuned(
        &self,
        n_out_bytes: f64,
        d: usize,
        crosses_nodes: bool,
        tuning: CollectiveTuning,
    ) -> CollectiveCost {
        if !tuning.quantizes() {
            return self.allgather(n_out_bytes, d, crosses_nodes);
        }
        if d <= 1 {
            return CollectiveCost { latency_s: 0.0, transfer_s: 0.0 };
        }
        let p = self.group_params(crosses_nodes);
        CollectiveCost {
            latency_s: algebra::two_step_allgather_steps(d) * p.alpha_s,
            transfer_s: CollectiveKind::AllGather.correction_factor(d)
                * n_out_bytes
                * tuning.wire_factor()
                / p.bus_bw,
        }
    }

    /// Gather of `d` slices of `n_slice` bytes to a root: the root drains
    /// `(d−1)` slices at link bandwidth after one launch.
    pub fn gather(&self, n_slice_bytes: f64, d: usize, crosses_nodes: bool) -> CollectiveCost {
        if d <= 1 {
            return CollectiveCost { latency_s: 0.0, transfer_s: 0.0 };
        }
        let p = self.group_params(crosses_nodes);
        CollectiveCost {
            latency_s: p.alpha_s,
            transfer_s: (d as f64 - 1.0) * n_slice_bytes / p.bus_bw,
        }
    }

    /// Point-to-point transfer of `n` bytes across one link.
    pub fn p2p(&self, n_bytes: f64, crosses_nodes: bool) -> CollectiveCost {
        let p = self.group_params(crosses_nodes);
        CollectiveCost { latency_s: p.alpha_s, transfer_s: n_bytes / p.bus_bw }
    }

    /// Price any collective class with one entry point (the record-pricing
    /// dispatch). `n_bytes` follows each op's trace convention: message
    /// bytes for AllReduce/ReduceScatter/AllToAll, *gathered* bytes for
    /// AllGather, *slice* bytes for Gather, wire bytes for Send/Recv.
    pub fn collective(
        &self,
        op: CollectiveKind,
        n_bytes: f64,
        d: usize,
        crosses_nodes: bool,
    ) -> CollectiveCost {
        match op {
            CollectiveKind::AllReduce => self.allreduce(n_bytes, d, crosses_nodes),
            CollectiveKind::AllGather => self.allgather(n_bytes, d, crosses_nodes),
            // ReduceScatter and AllToAll share AllGather's ring shape:
            // (d−1) steps, (d−1)/d corrected bytes.
            CollectiveKind::ReduceScatter | CollectiveKind::AllToAll => {
                self.allgather(n_bytes, d, crosses_nodes)
            }
            CollectiveKind::Gather => self.gather(n_bytes, d, crosses_nodes),
            CollectiveKind::Send | CollectiveKind::Recv => self.p2p(n_bytes, crosses_nodes),
        }
    }

    /// AllReduce cost for a TP group of a placement's stage.
    pub fn tp_allreduce(
        &self,
        placement: &Placement,
        pp_stage: usize,
        n_bytes: f64,
    ) -> CollectiveCost {
        self.allreduce(
            n_bytes,
            placement.layout.tp,
            placement.tp_group_crosses_nodes(pp_stage),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::ParallelLayout;
    use crate::cluster::Topology;

    #[test]
    fn allreduce_cost_formula() {
        let nm = NetModel::default();
        let c = nm.allreduce(1.0e6, 4, false);
        assert!((c.latency_s - 6.0 * 4.0e-6).abs() < 1e-12);
        assert!((c.transfer_s - 1.5e6 / 300.0e9).abs() < 1e-15);
        // degenerate group
        assert_eq!(nm.allreduce(1.0e6, 1, false).total(), 0.0);
    }

    #[test]
    fn internode_is_slower_for_small_and_large_messages() {
        let nm = NetModel::default();
        for bytes in [8.0e3, 1.0e6, 1.0e9] {
            let intra = nm.allreduce(bytes, 4, false).total();
            let inter = nm.allreduce(bytes, 4, true).total();
            assert!(inter > intra, "bytes={bytes}");
        }
    }

    #[test]
    fn small_message_allreduce_is_latency_dominated() {
        // Paper §V.C: decode-stage [1, h] AllReduces (8 KB) are dominated
        // by launch latency, which is why cross-node TP wrecks TPOT.
        let nm = NetModel::default();
        let c = nm.allreduce(8192.0, 8, true);
        assert!(c.latency_s > 10.0 * c.transfer_s);
    }

    #[test]
    fn p2p_and_gather_scale_with_bytes() {
        let nm = NetModel::default();
        assert!(nm.p2p(2.0e6, true).total() > nm.p2p(1.0e6, true).total());
        assert!(nm.gather(1.0e6, 4, false).total() > nm.gather(1.0e5, 4, false).total());
    }

    #[test]
    fn two_level_allreduce_sits_between_the_pure_fabrics() {
        let nm = NetModel::default();
        for bytes in [1.0, 8.0e3, 1.0e6, 1.0e9] {
            for (g, nodes) in [(2usize, 2usize), (4, 2), (4, 4), (8, 2)] {
                let d = g * nodes;
                let nv = nm.allreduce(bytes, d, false).total();
                let ib = nm.allreduce(bytes, d, true).total();
                let two = nm.allreduce_two_level(bytes, g, nodes).total();
                assert!(two >= nv, "bytes={bytes} g={g} n={nodes}: {two} < nvlink {nv}");
                assert!(two <= ib, "bytes={bytes} g={g} n={nodes}: {two} > ib {ib}");
            }
        }
    }

    #[test]
    fn two_level_allreduce_degenerates_cleanly() {
        let nm = NetModel::default();
        // Single node: exactly the flat NVLink ring.
        assert_eq!(nm.allreduce_two_level(1.0e6, 4, 1), nm.allreduce(1.0e6, 4, false));
        // Single worker: free.
        assert_eq!(nm.allreduce_two_level(1.0e6, 1, 1).total(), 0.0);
        // Large messages beat the flat IB ring by a wide margin (the
        // intra-node phases run at NVLink bandwidth).
        let two = nm.allreduce_two_level(1.0e9, 4, 2).total();
        let ib = nm.allreduce(1.0e9, 8, true).total();
        assert!(two < 0.5 * ib, "two-level {two} vs flat IB {ib}");
    }

    #[test]
    fn collective_dispatch_matches_direct_formulas() {
        let nm = NetModel::default();
        for crosses in [false, true] {
            assert_eq!(
                nm.collective(CollectiveKind::AllReduce, 1.0e6, 4, crosses),
                nm.allreduce(1.0e6, 4, crosses)
            );
            assert_eq!(
                nm.collective(CollectiveKind::AllGather, 1.0e6, 4, crosses),
                nm.allgather(1.0e6, 4, crosses)
            );
            assert_eq!(
                nm.collective(CollectiveKind::Gather, 1.0e6, 4, crosses),
                nm.gather(1.0e6, 4, crosses)
            );
            assert_eq!(
                nm.collective(CollectiveKind::Send, 1.0e6, 2, crosses),
                nm.p2p(1.0e6, crosses)
            );
            // ReduceScatter: (d−1) launches, (d−1)/d bytes.
            let rs = nm.collective(CollectiveKind::ReduceScatter, 1.0e6, 4, crosses);
            let p = nm.group_params(crosses);
            assert!((rs.latency_s - 3.0 * p.alpha_s).abs() < 1e-15);
            assert!((rs.transfer_s - 0.75 * 1.0e6 / p.bus_bw).abs() < 1e-18);
        }
    }

    #[test]
    fn degraded_collectives_never_undercut_healthy_for_any_kind() {
        // Fault-injection invariant: a degraded fabric is monotonically
        // slower (>=) than the healthy one for every collective class, on
        // both link fabrics, for small and large messages.
        let nm = NetModel::default();
        let ops = [
            CollectiveKind::AllReduce,
            CollectiveKind::AllGather,
            CollectiveKind::ReduceScatter,
            CollectiveKind::AllToAll,
            CollectiveKind::Gather,
            CollectiveKind::Send,
            CollectiveKind::Recv,
        ];
        for factor in [1.5, 2.0, 8.0] {
            let slow = nm.degraded(factor);
            for op in ops {
                for crosses in [false, true] {
                    for bytes in [1.0, 8192.0, 1.0e6, 1.0e9] {
                        for d in [2usize, 4, 8] {
                            let h = nm.collective(op, bytes, d, crosses);
                            let s = slow.collective(op, bytes, d, crosses);
                            assert!(
                                s.latency_s >= h.latency_s && s.transfer_s >= h.transfer_s,
                                "{op:?} x{factor} crosses={crosses} bytes={bytes} d={d}: \
                                 degraded {s:?} < healthy {h:?}"
                            );
                            assert!(
                                s.total() >= h.total(),
                                "{op:?} x{factor}: total went down under degradation"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn degradation_factor_one_is_bitwise_identity() {
        // FaultSpec::none() must not perturb a single bit: factor 1.0 maps
        // every α and β through exact f64 identities.
        let nm = NetModel::default();
        assert_eq!(nm.degraded(1.0), nm);
        assert_eq!(nm.nvlink.degraded(1.0), nm.nvlink);
        let ops = [
            CollectiveKind::AllReduce,
            CollectiveKind::AllGather,
            CollectiveKind::Gather,
            CollectiveKind::Send,
        ];
        let unit = nm.degraded(1.0);
        for op in ops {
            for crosses in [false, true] {
                assert_eq!(
                    unit.collective(op, 8192.0, 4, crosses),
                    nm.collective(op, 8192.0, 4, crosses),
                    "{op:?} crosses={crosses}: factor 1.0 perturbed the cost"
                );
            }
        }
    }

    #[test]
    fn default_tuning_is_bitwise_the_untuned_collective() {
        let nm = NetModel::default();
        let t = CollectiveTuning::default();
        assert!(t.is_default() && !t.quantizes());
        assert_eq!(t.wire_factor(), 1.0);
        for crosses in [false, true] {
            for bytes in [1.0, 8192.0, 1.0e6, 1.0e9] {
                for d in [1usize, 2, 4, 8] {
                    assert_eq!(
                        nm.allreduce_tuned(bytes, d, crosses, t),
                        nm.allreduce(bytes, d, crosses)
                    );
                    assert_eq!(
                        nm.allgather_tuned(bytes, d, crosses, t),
                        nm.allgather(bytes, d, crosses)
                    );
                }
            }
        }
    }

    #[test]
    fn quantized_wires_never_undercut_on_neither_term() {
        // Monotonicity the property suite leans on: fewer wire bits never
        // increase either α–β term, on both fabrics, at every group size.
        let nm = NetModel::default();
        let tunings = [
            CollectiveTuning::default(),
            CollectiveTuning::new(8, 0.0),
            CollectiveTuning::new(4, 0.0),
        ];
        for crosses in [false, true] {
            for bytes in [1.0, 8192.0, 1.0e6, 1.0e9] {
                for d in [2usize, 3, 4, 8, 16] {
                    for pair in tunings.windows(2) {
                        let (hi, lo) = (pair[0], pair[1]);
                        let ar_hi = nm.allreduce_tuned(bytes, d, crosses, hi);
                        let ar_lo = nm.allreduce_tuned(bytes, d, crosses, lo);
                        assert!(
                            ar_lo.latency_s <= ar_hi.latency_s
                                && ar_lo.transfer_s <= ar_hi.transfer_s,
                            "AllReduce {}b -> {}b crosses={crosses} bytes={bytes} d={d}",
                            hi.wire_bits(),
                            lo.wire_bits()
                        );
                        let ag_hi = nm.allgather_tuned(bytes, d, crosses, hi);
                        let ag_lo = nm.allgather_tuned(bytes, d, crosses, lo);
                        assert!(
                            ag_lo.latency_s <= ag_hi.latency_s
                                && ag_lo.transfer_s <= ag_hi.transfer_s,
                            "AllGather {}b -> {}b crosses={crosses} bytes={bytes} d={d}",
                            hi.wire_bits(),
                            lo.wire_bits()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn placement_aware_allreduce_uses_slow_fabric_when_spanning() {
        let nm = NetModel::default();
        let p8 = Placement::new(Topology::cardinal(2), ParallelLayout::new(8, 1)).unwrap();
        let p4 = Placement::new(Topology::cardinal(1), ParallelLayout::new(4, 1)).unwrap();
        let cross = nm.tp_allreduce(&p8, 0, 8192.0).total();
        let local = nm.tp_allreduce(&p4, 0, 8192.0).total();
        assert!(cross > 3.0 * local);
    }
}
