//! α–β network cost model for the paper's interconnects.
//!
//! We cannot measure NVLink/InfiniBand on this testbed, so collective costs
//! are modeled with the standard latency–bandwidth (α–β) form the NCCL
//! performance guide uses ([16] in the paper): a ring AllReduce over `d`
//! workers moves `2(d−1)/d · n` bytes per GPU in `2(d−1)` steps, etc.
//! Constants are calibrated in [`crate::perfmodel::calibration`]; the
//! *ratios* (NVLink ≫ IB in bandwidth, IB ≫ NVLink in latency) are what the
//! paper's SLO shapes depend on.


use super::topology::Placement;
use crate::comm::CollectiveKind;

/// Link class between two workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LinkClass {
    /// Intra-node NVLink (NVLink4 on H100).
    NvLink,
    /// Inter-node InfiniBand NDR400 (4 NICs/node on the paper's testbed).
    InfiniBand,
}

/// α–β parameters of one link class.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkParams {
    /// Per-operation launch + wire latency (seconds).
    pub alpha_s: f64,
    /// Effective per-GPU bus bandwidth (bytes/second).
    pub bus_bw: f64,
}

/// Network model over a placement.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetModel {
    pub nvlink: LinkParams,
    pub ib: LinkParams,
}

/// Cost decomposition of one collective (seconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CollectiveCost {
    pub latency_s: f64,
    pub transfer_s: f64,
}

impl CollectiveCost {
    pub fn total(&self) -> f64 {
        self.latency_s + self.transfer_s
    }
}

impl Default for NetModel {
    fn default() -> Self {
        Self {
            // NVLink4: ~450 GB/s/dir peak; NCCL ring busbw on 2-4 GPUs
            // measured around 300 GB/s effective; small-message launch ~4 µs.
            nvlink: LinkParams { alpha_s: 4.0e-6, bus_bw: 300.0e9 },
            // NDR400: 50 GB/s/NIC raw; NCCL cross-node small-message launch
            // ~14 µs, effective per-GPU busbw ~40 GB/s.
            ib: LinkParams { alpha_s: 14.0e-6, bus_bw: 40.0e9 },
        }
    }
}

impl NetModel {
    /// Link parameters governing a group: the slowest member link.
    pub fn group_params(&self, crosses_nodes: bool) -> LinkParams {
        if crosses_nodes { self.ib } else { self.nvlink }
    }

    /// Ring AllReduce over `d` workers, message `n` bytes:
    /// `2(d−1) α + 2(d−1)/d · n / busbw`.
    pub fn allreduce(&self, n_bytes: f64, d: usize, crosses_nodes: bool) -> CollectiveCost {
        if d <= 1 {
            return CollectiveCost { latency_s: 0.0, transfer_s: 0.0 };
        }
        let p = self.group_params(crosses_nodes);
        CollectiveCost {
            latency_s: 2.0 * (d as f64 - 1.0) * p.alpha_s,
            transfer_s: CollectiveKind::AllReduce.correction_factor(d) * n_bytes / p.bus_bw,
        }
    }

    /// Ring AllGather to `n_out` gathered bytes over `d` workers:
    /// `(d−1) α + (d−1)/d · n_out / busbw`.
    pub fn allgather(&self, n_out_bytes: f64, d: usize, crosses_nodes: bool) -> CollectiveCost {
        if d <= 1 {
            return CollectiveCost { latency_s: 0.0, transfer_s: 0.0 };
        }
        let p = self.group_params(crosses_nodes);
        CollectiveCost {
            latency_s: (d as f64 - 1.0) * p.alpha_s,
            transfer_s: CollectiveKind::AllGather.correction_factor(d) * n_out_bytes / p.bus_bw,
        }
    }

    /// Gather of `d` slices of `n_slice` bytes to a root: the root drains
    /// `(d−1)` slices at link bandwidth after one launch.
    pub fn gather(&self, n_slice_bytes: f64, d: usize, crosses_nodes: bool) -> CollectiveCost {
        if d <= 1 {
            return CollectiveCost { latency_s: 0.0, transfer_s: 0.0 };
        }
        let p = self.group_params(crosses_nodes);
        CollectiveCost {
            latency_s: p.alpha_s,
            transfer_s: (d as f64 - 1.0) * n_slice_bytes / p.bus_bw,
        }
    }

    /// Point-to-point transfer of `n` bytes across one link.
    pub fn p2p(&self, n_bytes: f64, crosses_nodes: bool) -> CollectiveCost {
        let p = self.group_params(crosses_nodes);
        CollectiveCost { latency_s: p.alpha_s, transfer_s: n_bytes / p.bus_bw }
    }

    /// AllReduce cost for a TP group of a placement's stage.
    pub fn tp_allreduce(
        &self,
        placement: &Placement,
        pp_stage: usize,
        n_bytes: f64,
    ) -> CollectiveCost {
        self.allreduce(
            n_bytes,
            placement.layout.tp,
            placement.tp_group_crosses_nodes(pp_stage),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::ParallelLayout;
    use crate::cluster::Topology;

    #[test]
    fn allreduce_cost_formula() {
        let nm = NetModel::default();
        let c = nm.allreduce(1.0e6, 4, false);
        assert!((c.latency_s - 6.0 * 4.0e-6).abs() < 1e-12);
        assert!((c.transfer_s - 1.5e6 / 300.0e9).abs() < 1e-15);
        // degenerate group
        assert_eq!(nm.allreduce(1.0e6, 1, false).total(), 0.0);
    }

    #[test]
    fn internode_is_slower_for_small_and_large_messages() {
        let nm = NetModel::default();
        for bytes in [8.0e3, 1.0e6, 1.0e9] {
            let intra = nm.allreduce(bytes, 4, false).total();
            let inter = nm.allreduce(bytes, 4, true).total();
            assert!(inter > intra, "bytes={bytes}");
        }
    }

    #[test]
    fn small_message_allreduce_is_latency_dominated() {
        // Paper §V.C: decode-stage [1, h] AllReduces (8 KB) are dominated
        // by launch latency, which is why cross-node TP wrecks TPOT.
        let nm = NetModel::default();
        let c = nm.allreduce(8192.0, 8, true);
        assert!(c.latency_s > 10.0 * c.transfer_s);
    }

    #[test]
    fn p2p_and_gather_scale_with_bytes() {
        let nm = NetModel::default();
        assert!(nm.p2p(2.0e6, true).total() > nm.p2p(1.0e6, true).total());
        assert!(nm.gather(1.0e6, 4, false).total() > nm.gather(1.0e5, 4, false).total());
    }

    #[test]
    fn placement_aware_allreduce_uses_slow_fabric_when_spanning() {
        let nm = NetModel::default();
        let p8 = Placement::new(Topology::cardinal(2), ParallelLayout::new(8, 1)).unwrap();
        let p4 = Placement::new(Topology::cardinal(1), ParallelLayout::new(4, 1)).unwrap();
        let cross = nm.tp_allreduce(&p8, 0, 8192.0).total();
        let local = nm.tp_allreduce(&p4, 0, 8192.0).total();
        assert!(cross > 3.0 * local);
    }
}
