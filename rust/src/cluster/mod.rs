//! Cluster substrate: node/GPU topology, worker placement, and the α–β
//! link model standing in for the paper's NVLink + InfiniBand NDR400
//! testbed (DESIGN.md §5 Substitutions).

pub mod netmodel;
pub mod topology;

pub use netmodel::{CollectiveCost, CollectiveTuning, LinkClass, LinkParams, NetModel};
pub use topology::{Placement, Topology};
