//! Report rendering: paper tables/figures side-by-side with analytical
//! predictions and engine-measured values. Used by the benches and the CLI.
//!
//! Also home to the benches' machine-readable output: every fig/table
//! bench accepts `--json <path>` and writes one `BENCH_<name>.json` file
//! ([`BenchJson`]) with its scenario parameters and modeled
//! seconds/bytes, so CI can accumulate a perf trajectory as workflow
//! artifacts. The writer is hand-rolled (the vendored build environment
//! has no serde): flat string/number fields only.

use crate::analysis::{InferenceShape, OpCountModel, ParallelLayout, VolumeModel};
use crate::comm::{CollectiveKind, Stage, TraceSummary};
use crate::model::ModelArch;

/// Fixed-width text table.
pub fn render_table(title: &str, headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    out.push_str(&format!("## {title}\n"));
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        let mut line = String::from("| ");
        for (i, c) in cells.iter().enumerate() {
            line.push_str(&format!("{:<w$} | ", c, w = widths[i]));
        }
        line.trim_end().to_string()
    };
    let head: Vec<String> = headers.iter().map(|s| s.to_string()).collect();
    out.push_str(&fmt_row(&head, &widths));
    out.push('\n');
    let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
    out.push_str(&fmt_row(&sep, &widths));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

/// Human-readable bytes.
pub fn fmt_bytes(b: f64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    format!("{v:.2} {}", UNITS[u])
}

pub fn fmt_shape(shape: &[usize]) -> String {
    let inner: Vec<String> = shape.iter().map(|d| d.to_string()).collect();
    format!("[{}]", inner.join(","))
}

/// One (op, stage) row comparing analytical prediction vs engine trace
/// under the paper's table-view convention.
pub fn compare_row(
    op: CollectiveKind,
    stage: Stage,
    model: &OpCountModel,
    trace: &TraceSummary,
) -> Vec<String> {
    let predicted = model.predict_paper_view(stage);
    let observed = trace.paper_view(op, stage);
    let pred_count = predicted.count(op);
    let pred_shape = predicted.shape(op).map(fmt_shape).unwrap_or_else(|| "-".into());
    let obs_shapes = trace.shapes(op, stage);
    let obs_shape = obs_shapes.first().map(|s| fmt_shape(s)).unwrap_or_else(|| "-".into());
    let status = if pred_count == observed.count && (pred_count == 0 || pred_shape == obs_shape) {
        "OK"
    } else {
        "MISMATCH"
    };
    vec![
        format!("{} ({})", op.label(), stage.label()),
        pred_count.to_string(),
        pred_shape,
        observed.count.to_string(),
        obs_shape,
        status.to_string(),
    ]
}

/// Render a full measured-vs-analytical comparison for a layout run.
pub fn comparison_table(
    title: &str,
    arch: &ModelArch,
    layout: ParallelLayout,
    shape: InferenceShape,
    trace: &TraceSummary,
) -> String {
    let model = OpCountModel::new(arch.clone(), layout, shape);
    let ops = [
        CollectiveKind::AllReduce,
        CollectiveKind::AllGather,
        CollectiveKind::Gather,
        CollectiveKind::Send,
        CollectiveKind::Recv,
    ];
    let mut rows = Vec::new();
    for stage in [Stage::Prefill, Stage::Decode] {
        for op in ops {
            let predicted = model.predict_paper_view(stage).count(op);
            let observed = trace.paper_view(op, stage).count;
            if predicted == 0 && observed == 0 {
                continue;
            }
            rows.push(compare_row(op, stage, &model, trace));
        }
    }
    render_table(
        title,
        &[
            "Operation",
            "Count (analytical)",
            "Shape (analytical)",
            "Count (measured)",
            "Shape (measured)",
            "Status",
        ],
        &rows,
    )
}

/// Volume summary line for a layout (Figs. 6–7 series points).
pub fn volume_line(arch: &ModelArch, layout: ParallelLayout, shape: InferenceShape) -> String {
    let v = VolumeModel::new(arch.clone()).volume(layout, shape);
    format!(
        "{:<14} {:>12} total  (AR {:>12} | AG {:>12} | G {:>12} | P2P {:>12})",
        layout.label(),
        fmt_bytes(v.total()),
        fmt_bytes(v.allreduce),
        fmt_bytes(v.allgather),
        fmt_bytes(v.gather),
        fmt_bytes(v.p2p),
    )
}

/// One JSON scalar a bench result row can carry.
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    Num(f64),
    Int(i64),
    Str(String),
    Bool(bool),
}

impl From<f64> for JsonValue {
    fn from(v: f64) -> Self {
        Self::Num(v)
    }
}

impl From<usize> for JsonValue {
    fn from(v: usize) -> Self {
        Self::Int(v as i64)
    }
}

impl From<i64> for JsonValue {
    fn from(v: i64) -> Self {
        Self::Int(v)
    }
}

impl From<bool> for JsonValue {
    fn from(v: bool) -> Self {
        Self::Bool(v)
    }
}

impl From<&str> for JsonValue {
    fn from(v: &str) -> Self {
        Self::Str(v.to_string())
    }
}

impl From<String> for JsonValue {
    fn from(v: String) -> Self {
        Self::Str(v)
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn json_value(v: &JsonValue) -> String {
    match v {
        // Non-finite floats have no JSON spelling; degrade to null.
        JsonValue::Num(x) if !x.is_finite() => "null".to_string(),
        JsonValue::Num(x) => format!("{x}"),
        JsonValue::Int(x) => format!("{x}"),
        JsonValue::Str(s) => format!("\"{}\"", json_escape(s)),
        JsonValue::Bool(b) => format!("{b}"),
    }
}

fn json_object(fields: &[(String, JsonValue)]) -> String {
    let inner: Vec<String> = fields
        .iter()
        .map(|(k, v)| format!("\"{}\": {}", json_escape(k), json_value(v)))
        .collect();
    format!("{{{}}}", inner.join(", "))
}

/// Machine-readable bench result: scenario parameters plus one flat
/// object per result row, rendered as stable, diffable JSON.
#[derive(Debug, Clone, Default)]
pub struct BenchJson {
    name: String,
    params: Vec<(String, JsonValue)>,
    rows: Vec<Vec<(String, JsonValue)>>,
}

impl BenchJson {
    pub fn new(name: &str) -> Self {
        Self { name: name.to_string(), params: Vec::new(), rows: Vec::new() }
    }

    /// Record one scenario parameter (model, Sp, Sd, ...).
    pub fn param(&mut self, key: &str, value: impl Into<JsonValue>) -> &mut Self {
        self.params.push((key.to_string(), value.into()));
        self
    }

    /// Record one result row (a series point: layout, modeled seconds,
    /// bytes, ...).
    pub fn row(&mut self, fields: &[(&str, JsonValue)]) -> &mut Self {
        self.rows
            .push(fields.iter().map(|(k, v)| (k.to_string(), v.clone())).collect());
        self
    }

    /// Render the document.
    pub fn render(&self) -> String {
        let rows: Vec<String> =
            self.rows.iter().map(|r| format!("    {}", json_object(r))).collect();
        format!(
            "{{\n  \"bench\": \"{}\",\n  \"params\": {},\n  \"results\": [\n{}\n  ]\n}}\n",
            json_escape(&self.name),
            json_object(&self.params),
            rows.join(",\n")
        )
    }

    /// Write the document to `path`.
    pub fn write(&self, path: &str) -> crate::Result<()> {
        std::fs::write(path, self.render())
            .map_err(|e| anyhow::anyhow!("writing bench JSON '{path}': {e}"))
    }
}

/// Parse the shared `--json <path>` flag from a bench binary's argument
/// list (other arguments — e.g. cargo's own bench flags — are ignored).
pub fn bench_json_path() -> crate::Result<Option<String>> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.iter().position(|a| a == "--json") {
        Some(i) => match args.get(i + 1) {
            Some(p) => Ok(Some(p.clone())),
            None => anyhow::bail!("--json needs a file path"),
        },
        None => Ok(None),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::DTYPE_BYTES_BF16;

    #[test]
    fn render_basic_table() {
        let s = render_table(
            "T",
            &["a", "bb"],
            &[vec!["1".into(), "2".into()], vec!["333".into(), "4".into()]],
        );
        assert!(s.contains("## T"));
        assert!(s.contains("| a   | bb |"));
        assert!(s.contains("| 333 | 4  |"));
    }

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512.0), "512.00 B");
        assert_eq!(fmt_bytes(2048.0), "2.00 KiB");
        assert_eq!(fmt_bytes(3.0 * 1024.0 * 1024.0 * 1024.0), "3.00 GiB");
    }

    #[test]
    fn shape_formatting() {
        assert_eq!(fmt_shape(&[128, 4096]), "[128,4096]");
        assert_eq!(fmt_shape(&[64128]), "[64128]");
    }

    #[test]
    fn bench_json_renders_valid_flat_documents() {
        let mut j = BenchJson::new("fig8_tp_slo");
        j.param("model", "Llama-3.2-3B").param("sp", 128usize);
        j.row(&[("tp", JsonValue::from(2usize)), ("e2e_s", JsonValue::from(0.31))]);
        j.row(&[("tp", JsonValue::from(8usize)), ("note", JsonValue::from("2 \"nodes\""))]);
        let s = j.render();
        assert!(s.contains("\"bench\": \"fig8_tp_slo\""), "{s}");
        assert!(s.contains("\"model\": \"Llama-3.2-3B\""), "{s}");
        assert!(s.contains("\"sp\": 128"), "{s}");
        assert!(s.contains("\"e2e_s\": 0.31"), "{s}");
        assert!(s.contains("\"note\": \"2 \\\"nodes\\\"\""), "{s}");
        // Balanced braces/brackets (cheap well-formedness check).
        assert_eq!(s.matches('{').count(), s.matches('}').count());
        assert_eq!(s.matches('[').count(), s.matches(']').count());
        // Non-finite floats degrade to null instead of invalid JSON.
        let mut j = BenchJson::new("x");
        j.row(&[("v", JsonValue::from(f64::NAN))]);
        assert!(j.render().contains("\"v\": null"));
    }

    #[test]
    fn volume_line_contains_layout() {
        let line = volume_line(
            &ModelArch::llama31_8b(),
            ParallelLayout::new(4, 1),
            InferenceShape::new(128, 128, DTYPE_BYTES_BF16),
        );
        assert!(line.contains("TP=4"));
        assert!(line.contains("total"));
    }
}
