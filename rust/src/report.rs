//! Report rendering: paper tables/figures side-by-side with analytical
//! predictions and engine-measured values. Used by the benches and the CLI.
//!
//! Also home to the benches' machine-readable output: every fig/table
//! bench accepts `--json <path>` and writes one `BENCH_<name>.json` file
//! ([`BenchJson`]) with its scenario parameters and modeled
//! seconds/bytes, so CI can accumulate a perf trajectory as workflow
//! artifacts. The writer is hand-rolled (the vendored build environment
//! has no serde): flat string/number fields only. [`parse_bench_json`]
//! reads those documents back and [`bench_diff`] compares two runs of
//! one bench, flagging numeric fields that grew past a tolerance — the
//! CI perf-trajectory gate (`commsim bench-diff`). Each artifact also
//! carries an advisory `wall_s` stamp (host seconds the bench ran);
//! wall time is diffed on its own channel and never gates.

use crate::analysis::{InferenceShape, OpCountModel, ParallelLayout, VolumeModel};
use crate::comm::{CollectiveKind, Stage, TraceSummary};
use crate::model::ModelArch;

/// Fixed-width text table.
pub fn render_table(title: &str, headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    out.push_str(&format!("## {title}\n"));
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        let mut line = String::from("| ");
        for (i, c) in cells.iter().enumerate() {
            line.push_str(&format!("{:<w$} | ", c, w = widths[i]));
        }
        line.trim_end().to_string()
    };
    let head: Vec<String> = headers.iter().map(|s| s.to_string()).collect();
    out.push_str(&fmt_row(&head, &widths));
    out.push('\n');
    let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
    out.push_str(&fmt_row(&sep, &widths));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

/// Human-readable bytes.
pub fn fmt_bytes(b: f64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    format!("{v:.2} {}", UNITS[u])
}

pub fn fmt_shape(shape: &[usize]) -> String {
    let inner: Vec<String> = shape.iter().map(|d| d.to_string()).collect();
    format!("[{}]", inner.join(","))
}

/// One (op, stage) row comparing analytical prediction vs engine trace
/// under the paper's table-view convention.
pub fn compare_row(
    op: CollectiveKind,
    stage: Stage,
    model: &OpCountModel,
    trace: &TraceSummary,
) -> Vec<String> {
    let predicted = model.predict_paper_view(stage);
    let observed = trace.paper_view(op, stage);
    let pred_count = predicted.count(op);
    let pred_shape = predicted.shape(op).map(fmt_shape).unwrap_or_else(|| "-".into());
    let obs_shapes = trace.shapes(op, stage);
    let obs_shape = obs_shapes.first().map(|s| fmt_shape(s)).unwrap_or_else(|| "-".into());
    let status = if pred_count == observed.count && (pred_count == 0 || pred_shape == obs_shape) {
        "OK"
    } else {
        "MISMATCH"
    };
    vec![
        format!("{} ({})", op.label(), stage.label()),
        pred_count.to_string(),
        pred_shape,
        observed.count.to_string(),
        obs_shape,
        status.to_string(),
    ]
}

/// Render a full measured-vs-analytical comparison for a layout run.
pub fn comparison_table(
    title: &str,
    arch: &ModelArch,
    layout: ParallelLayout,
    shape: InferenceShape,
    trace: &TraceSummary,
) -> String {
    let model = OpCountModel::new(arch.clone(), layout, shape);
    let ops = [
        CollectiveKind::AllReduce,
        CollectiveKind::AllGather,
        CollectiveKind::Gather,
        CollectiveKind::Send,
        CollectiveKind::Recv,
    ];
    let mut rows = Vec::new();
    for stage in [Stage::Prefill, Stage::Decode] {
        for op in ops {
            let predicted = model.predict_paper_view(stage).count(op);
            let observed = trace.paper_view(op, stage).count;
            if predicted == 0 && observed == 0 {
                continue;
            }
            rows.push(compare_row(op, stage, &model, trace));
        }
    }
    render_table(
        title,
        &[
            "Operation",
            "Count (analytical)",
            "Shape (analytical)",
            "Count (measured)",
            "Shape (measured)",
            "Status",
        ],
        &rows,
    )
}

/// Volume summary line for a layout (Figs. 6–7 series points).
pub fn volume_line(arch: &ModelArch, layout: ParallelLayout, shape: InferenceShape) -> String {
    let v = VolumeModel::new(arch.clone()).volume(layout, shape);
    format!(
        "{:<14} {:>12} total  (AR {:>12} | AG {:>12} | G {:>12} | P2P {:>12})",
        layout.label(),
        fmt_bytes(v.total()),
        fmt_bytes(v.allreduce),
        fmt_bytes(v.allgather),
        fmt_bytes(v.gather),
        fmt_bytes(v.p2p),
    )
}

/// One JSON scalar a bench result row can carry.
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    Num(f64),
    Int(i64),
    Str(String),
    Bool(bool),
    /// What a non-finite float renders as; read back by the parser.
    Null,
}

impl From<f64> for JsonValue {
    fn from(v: f64) -> Self {
        Self::Num(v)
    }
}

impl From<usize> for JsonValue {
    fn from(v: usize) -> Self {
        Self::Int(v as i64)
    }
}

impl From<i64> for JsonValue {
    fn from(v: i64) -> Self {
        Self::Int(v)
    }
}

impl From<bool> for JsonValue {
    fn from(v: bool) -> Self {
        Self::Bool(v)
    }
}

impl From<&str> for JsonValue {
    fn from(v: &str) -> Self {
        Self::Str(v.to_string())
    }
}

impl From<String> for JsonValue {
    fn from(v: String) -> Self {
        Self::Str(v)
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn json_value(v: &JsonValue) -> String {
    match v {
        // Non-finite floats have no JSON spelling; degrade to null.
        JsonValue::Num(x) if !x.is_finite() => "null".to_string(),
        JsonValue::Num(x) => format!("{x}"),
        JsonValue::Int(x) => format!("{x}"),
        JsonValue::Str(s) => format!("\"{}\"", json_escape(s)),
        JsonValue::Bool(b) => format!("{b}"),
        JsonValue::Null => "null".to_string(),
    }
}

fn json_object(fields: &[(String, JsonValue)]) -> String {
    let inner: Vec<String> = fields
        .iter()
        .map(|(k, v)| format!("\"{}\": {}", json_escape(k), json_value(v)))
        .collect();
    format!("{{{}}}", inner.join(", "))
}

/// The advisory wall-clock param [`BenchJson::write`] stamps on every
/// artifact: how many host seconds the bench ran for, measured from
/// construction to write. Host timing is noisy (machine, load,
/// codegen), so [`bench_diff`] reports its movement separately
/// ([`BenchDiff::wall`]) and never fails on it — the gate stays on
/// modeled numbers only.
const WALL_FIELD: &str = "wall_s";

/// Machine-readable bench result: scenario parameters plus one flat
/// object per result row, rendered as stable, diffable JSON.
#[derive(Debug, Clone)]
pub struct BenchJson {
    name: String,
    params: Vec<(String, JsonValue)>,
    rows: Vec<Vec<(String, JsonValue)>>,
    /// When this document was started; [`Self::write`] turns the
    /// elapsed span into the advisory [`WALL_FIELD`] param.
    created: std::time::Instant,
}

impl Default for BenchJson {
    fn default() -> Self {
        Self {
            name: String::new(),
            params: Vec::new(),
            rows: Vec::new(),
            created: std::time::Instant::now(),
        }
    }
}

impl BenchJson {
    pub fn new(name: &str) -> Self {
        Self { name: name.to_string(), ..Self::default() }
    }

    /// Record one scenario parameter (model, Sp, Sd, ...).
    pub fn param(&mut self, key: &str, value: impl Into<JsonValue>) -> &mut Self {
        self.params.push((key.to_string(), value.into()));
        self
    }

    /// Record one result row (a series point: layout, modeled seconds,
    /// bytes, ...).
    pub fn row(&mut self, fields: &[(&str, JsonValue)]) -> &mut Self {
        self.rows
            .push(fields.iter().map(|(k, v)| (k.to_string(), v.clone())).collect());
        self
    }

    /// Render the document.
    pub fn render(&self) -> String {
        let rows: Vec<String> =
            self.rows.iter().map(|r| format!("    {}", json_object(r))).collect();
        format!(
            "{{\n  \"bench\": \"{}\",\n  \"params\": {},\n  \"results\": [\n{}\n  ]\n}}\n",
            json_escape(&self.name),
            json_object(&self.params),
            rows.join(",\n")
        )
    }

    /// Write the document to `path`, stamping the advisory wall-clock
    /// param first (elapsed host seconds since construction — see
    /// [`WALL_FIELD`]). Idempotent: a re-write replaces the stamp.
    pub fn write(&mut self, path: &str) -> crate::Result<()> {
        let wall = self.created.elapsed().as_secs_f64();
        self.params.retain(|(k, _)| k != WALL_FIELD);
        self.param(WALL_FIELD, wall);
        std::fs::write(path, self.render())
            .map_err(|e| anyhow::anyhow!("writing bench JSON '{path}': {e}"))
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn params(&self) -> &[(String, JsonValue)] {
        &self.params
    }

    pub fn rows(&self) -> &[Vec<(String, JsonValue)>] {
        &self.rows
    }
}

/// Parse a `BENCH_*.json` document produced by [`BenchJson::render`]
/// back into a [`BenchJson`] — the reader half of the perf-trajectory
/// pipeline, hand-rolled like the writer (no serde in the vendored
/// build environment). Strict to the writer's shape: a top-level object
/// with `bench` (string), `params` (flat object), and `results` (array
/// of flat objects); scalar values only.
pub fn parse_bench_json(text: &str) -> crate::Result<BenchJson> {
    let mut p = Parser { s: text.as_bytes(), i: 0 };
    let doc = p.document()?;
    p.skip_ws();
    anyhow::ensure!(p.i == p.s.len(), "trailing content at byte {} in bench JSON", p.i);
    Ok(doc)
}

struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while self.i < self.s.len() && self.s[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&mut self) -> crate::Result<u8> {
        self.skip_ws();
        self.s
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of bench JSON"))
    }

    fn expect(&mut self, c: u8) -> crate::Result<()> {
        let got = self.peek()?;
        anyhow::ensure!(
            got == c,
            "expected '{}' at byte {}, found '{}'",
            c as char,
            self.i,
            got as char
        );
        self.i += 1;
        Ok(())
    }

    fn string(&mut self) -> crate::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = *self
                .s
                .get(self.i)
                .ok_or_else(|| anyhow::anyhow!("unterminated string in bench JSON"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = *self
                        .s
                        .get(self.i)
                        .ok_or_else(|| anyhow::anyhow!("unterminated escape in bench JSON"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            anyhow::ensure!(
                                self.i + 4 <= self.s.len(),
                                "truncated \\u escape in bench JSON"
                            );
                            let hex = std::str::from_utf8(&self.s[self.i..self.i + 4])
                                .map_err(|_| anyhow::anyhow!("non-UTF8 \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| anyhow::anyhow!("bad \\u escape '{hex}'"))?;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow::anyhow!("bad codepoint {code}"))?,
                            );
                            self.i += 4;
                        }
                        _ => anyhow::bail!("unknown escape '\\{}' in bench JSON", e as char),
                    }
                }
                // The writer only emits ASCII control codes escaped, but
                // plain multi-byte UTF-8 passes through byte-for-byte.
                c => {
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    anyhow::ensure!(start + len <= self.s.len(), "truncated UTF-8 sequence");
                    out.push_str(
                        std::str::from_utf8(&self.s[start..start + len])
                            .map_err(|_| anyhow::anyhow!("invalid UTF-8 in bench JSON"))?,
                    );
                    self.i = start + len;
                }
            }
        }
    }

    fn scalar(&mut self) -> crate::Result<JsonValue> {
        match self.peek()? {
            b'"' => Ok(JsonValue::Str(self.string()?)),
            b't' => self.literal("true", JsonValue::Bool(true)),
            b'f' => self.literal("false", JsonValue::Bool(false)),
            b'n' => self.literal("null", JsonValue::Null),
            b'{' | b'[' => anyhow::bail!(
                "nested containers are not valid bench-JSON scalars (byte {})",
                self.i
            ),
            _ => self.number(),
        }
    }

    fn literal(&mut self, word: &str, v: JsonValue) -> crate::Result<JsonValue> {
        anyhow::ensure!(
            self.s[self.i..].starts_with(word.as_bytes()),
            "expected '{word}' at byte {}",
            self.i
        );
        self.i += word.len();
        Ok(v)
    }

    fn number(&mut self) -> crate::Result<JsonValue> {
        let start = self.i;
        while self
            .s
            .get(self.i)
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E'))
        {
            self.i += 1;
        }
        let lit = std::str::from_utf8(&self.s[start..self.i]).expect("ASCII number literal");
        anyhow::ensure!(!lit.is_empty(), "expected a JSON value at byte {start}");
        if !lit.contains(['.', 'e', 'E']) {
            if let Ok(v) = lit.parse::<i64>() {
                return Ok(JsonValue::Int(v));
            }
        }
        lit.parse::<f64>()
            .map(JsonValue::Num)
            .map_err(|_| anyhow::anyhow!("bad number '{lit}' at byte {start}"))
    }

    /// `{ "k": scalar, ... }`
    fn flat_object(&mut self) -> crate::Result<Vec<(String, JsonValue)>> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(fields);
        }
        loop {
            let key = self.string()?;
            self.expect(b':')?;
            fields.push((key, self.scalar()?));
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(fields);
                }
                c => anyhow::bail!("expected ',' or '}}', found '{}'", c as char),
            }
        }
    }

    fn document(&mut self) -> crate::Result<BenchJson> {
        self.expect(b'{')?;
        let mut doc = BenchJson::default();
        let mut seen_bench = false;
        loop {
            let key = self.string()?;
            self.expect(b':')?;
            match key.as_str() {
                "bench" => {
                    doc.name = self.string()?;
                    seen_bench = true;
                }
                "params" => doc.params = self.flat_object()?,
                "results" => {
                    self.expect(b'[')?;
                    if self.peek()? == b']' {
                        self.i += 1;
                    } else {
                        loop {
                            doc.rows.push(self.flat_object()?);
                            match self.peek()? {
                                b',' => self.i += 1,
                                b']' => {
                                    self.i += 1;
                                    break;
                                }
                                c => anyhow::bail!("expected ',' or ']', found '{}'", c as char),
                            }
                        }
                    }
                }
                k => anyhow::bail!("unknown top-level bench-JSON key '{k}'"),
            }
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    anyhow::ensure!(seen_bench, "bench JSON is missing its \"bench\" name");
                    return Ok(doc);
                }
                c => anyhow::bail!("expected ',' or '}}', found '{}'", c as char),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// One numeric field that moved between two runs of the same bench.
#[derive(Debug, Clone)]
pub struct BenchDelta {
    /// Result-row index (position in `results`), or `None` for a param.
    pub row: Option<usize>,
    pub field: String,
    pub old: f64,
    pub new: f64,
}

impl BenchDelta {
    /// Relative change, `new/old - 1` (positive = grew).
    pub fn ratio(&self) -> f64 {
        self.new / self.old - 1.0
    }
}

/// Outcome of diffing one bench's JSON between two runs.
#[derive(Debug, Clone, Default)]
pub struct BenchDiff {
    pub bench: String,
    /// Numeric fields that grew by more than the tolerance — modeled
    /// seconds/bytes going up is a perf regression.
    pub regressions: Vec<BenchDelta>,
    /// Numeric fields that shrank by more than the tolerance (reported,
    /// never failed on).
    pub improvements: Vec<BenchDelta>,
    /// Structural differences (row counts, renamed/retyped fields,
    /// changed labels): the trajectory broke, so the numeric diff is
    /// not meaningful for the affected rows. Reported, not failed on —
    /// benches legitimately evolve.
    pub notes: Vec<String>,
    /// Movement of the advisory `wall_s` param (host seconds the bench
    /// ran for). Wall clocks are machine- and load-dependent, so this
    /// is informational only: never a regression, never considered by
    /// [`Self::is_clean`]. `None` when either run lacks the stamp.
    pub wall: Option<BenchDelta>,
}

impl BenchDiff {
    /// Nothing moved past the tolerance and nothing changed shape.
    /// Deliberately ignores [`Self::wall`] — wall time is advisory.
    pub fn is_clean(&self) -> bool {
        self.regressions.is_empty() && self.improvements.is_empty() && self.notes.is_empty()
    }
}

fn numeric(v: &JsonValue) -> Option<f64> {
    match v {
        JsonValue::Num(x) => Some(*x),
        JsonValue::Int(x) => Some(*x as f64),
        _ => None,
    }
}

fn diff_fields(
    at: &str,
    row: Option<usize>,
    old: &[(String, JsonValue)],
    new: &[(String, JsonValue)],
    tolerance: f64,
    out: &mut BenchDiff,
) {
    for (key, ov) in old {
        let Some((_, nv)) = new.iter().find(|(k, _)| k == key) else {
            out.notes.push(format!("{at}: field '{key}' disappeared"));
            continue;
        };
        match (numeric(ov), numeric(nv)) {
            (Some(o), Some(n)) => {
                if !(o.is_finite() && n.is_finite()) || o == n {
                    continue;
                }
                if o == 0.0 {
                    out.notes.push(format!("{at}: '{key}' moved off zero to {n}"));
                } else if n > o * (1.0 + tolerance) {
                    out.regressions.push(BenchDelta {
                        row,
                        field: key.clone(),
                        old: o,
                        new: n,
                    });
                } else if n < o * (1.0 - tolerance) {
                    out.improvements.push(BenchDelta {
                        row,
                        field: key.clone(),
                        old: o,
                        new: n,
                    });
                }
            }
            _ => {
                if ov != nv {
                    out.notes.push(format!(
                        "{at}: '{key}' changed from {} to {}",
                        json_value(ov),
                        json_value(nv)
                    ));
                }
            }
        }
    }
    for (key, _) in new {
        if !old.iter().any(|(k, _)| k == key) {
            out.notes.push(format!("{at}: new field '{key}'"));
        }
    }
}

/// Diff two runs of the same bench: rows match by position (the benches
/// emit a deterministic row order), numeric fields that grew past
/// `tolerance` (relative, e.g. `0.05` = 5%) are regressions. Changed
/// params or reshaped results are structural notes, not regressions.
pub fn bench_diff(old: &BenchJson, new: &BenchJson, tolerance: f64) -> crate::Result<BenchDiff> {
    anyhow::ensure!(
        old.name == new.name,
        "diffing different benches: '{}' vs '{}'",
        old.name,
        new.name
    );
    anyhow::ensure!(
        tolerance.is_finite() && tolerance >= 0.0,
        "tolerance must be a finite fraction >= 0"
    );
    let mut out = BenchDiff { bench: old.name.clone(), ..Default::default() };
    // Changed params mean the scenarios differ — numbers aren't
    // comparable, so everything param-side is a note. The one
    // exception is the writer's advisory wall-clock stamp, which moves
    // on every run by construction: it gets its own side channel.
    for (key, ov) in &old.params {
        if key == WALL_FIELD {
            let nv = new.params.iter().find(|(k, _)| k == key).map(|(_, v)| v);
            if let (Some(o), Some(n)) = (numeric(ov), nv.and_then(numeric)) {
                if o.is_finite() && n.is_finite() {
                    out.wall = Some(BenchDelta { row: None, field: key.clone(), old: o, new: n });
                }
            }
            continue;
        }
        match new.params.iter().find(|(k, _)| k == key) {
            Some((_, nv)) if nv == ov => {}
            Some((_, nv)) => out.notes.push(format!(
                "param '{key}' changed from {} to {}",
                json_value(ov),
                json_value(nv)
            )),
            None => out.notes.push(format!("param '{key}' disappeared")),
        }
    }
    if old.rows.len() != new.rows.len() {
        out.notes.push(format!(
            "result rows changed: {} -> {}",
            old.rows.len(),
            new.rows.len()
        ));
    }
    for (i, (o, n)) in old.rows.iter().zip(new.rows.iter()).enumerate() {
        diff_fields(&format!("row {i}"), Some(i), o, n, tolerance, &mut out);
    }
    Ok(out)
}

/// Parse the shared `--json <path>` flag from a bench binary's argument
/// list (other arguments — e.g. cargo's own bench flags — are ignored).
pub fn bench_json_path() -> crate::Result<Option<String>> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.iter().position(|a| a == "--json") {
        Some(i) => match args.get(i + 1) {
            Some(p) => Ok(Some(p.clone())),
            None => anyhow::bail!("--json needs a file path"),
        },
        None => Ok(None),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::DTYPE_BYTES_BF16;

    #[test]
    fn render_basic_table() {
        let s = render_table(
            "T",
            &["a", "bb"],
            &[vec!["1".into(), "2".into()], vec!["333".into(), "4".into()]],
        );
        assert!(s.contains("## T"));
        assert!(s.contains("| a   | bb |"));
        assert!(s.contains("| 333 | 4  |"));
    }

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512.0), "512.00 B");
        assert_eq!(fmt_bytes(2048.0), "2.00 KiB");
        assert_eq!(fmt_bytes(3.0 * 1024.0 * 1024.0 * 1024.0), "3.00 GiB");
    }

    #[test]
    fn shape_formatting() {
        assert_eq!(fmt_shape(&[128, 4096]), "[128,4096]");
        assert_eq!(fmt_shape(&[64128]), "[64128]");
    }

    #[test]
    fn bench_json_renders_valid_flat_documents() {
        let mut j = BenchJson::new("fig8_tp_slo");
        j.param("model", "Llama-3.2-3B").param("sp", 128usize);
        j.row(&[("tp", JsonValue::from(2usize)), ("e2e_s", JsonValue::from(0.31))]);
        j.row(&[("tp", JsonValue::from(8usize)), ("note", JsonValue::from("2 \"nodes\""))]);
        let s = j.render();
        assert!(s.contains("\"bench\": \"fig8_tp_slo\""), "{s}");
        assert!(s.contains("\"model\": \"Llama-3.2-3B\""), "{s}");
        assert!(s.contains("\"sp\": 128"), "{s}");
        assert!(s.contains("\"e2e_s\": 0.31"), "{s}");
        assert!(s.contains("\"note\": \"2 \\\"nodes\\\"\""), "{s}");
        // Balanced braces/brackets (cheap well-formedness check).
        assert_eq!(s.matches('{').count(), s.matches('}').count());
        assert_eq!(s.matches('[').count(), s.matches(']').count());
        // Non-finite floats degrade to null instead of invalid JSON.
        let mut j = BenchJson::new("x");
        j.row(&[("v", JsonValue::from(f64::NAN))]);
        assert!(j.render().contains("\"v\": null"));
    }

    #[test]
    fn bench_json_round_trips_through_the_parser() {
        let mut j = BenchJson::new("fig7_decode_scaling");
        j.param("model", "Llama-3.1-8B").param("sd", 256usize).param("numeric", false);
        j.row(&[
            ("layout", JsonValue::from("TP=4")),
            ("modeled_s", JsonValue::from(0.125)),
            ("bytes", JsonValue::from(3221225472.5)),
            ("ranks", JsonValue::from(4usize)),
        ]);
        j.row(&[("layout", JsonValue::from("PP=4")), ("nan", JsonValue::from(f64::NAN))]);
        let text = j.render();
        let parsed = parse_bench_json(&text).unwrap();
        assert_eq!(parsed.name(), "fig7_decode_scaling");
        assert_eq!(parsed.params(), j.params());
        assert_eq!(parsed.rows().len(), 2);
        assert_eq!(parsed.rows()[0], j.rows()[0]);
        // NaN rendered as null and reads back as Null.
        assert_eq!(parsed.rows()[1][1], ("nan".to_string(), JsonValue::Null));
        // The re-render is byte-identical: parse is a true inverse on
        // everything the writer emits (modulo the one NaN -> null hop).
        assert_eq!(parse_bench_json(&parsed.render()).unwrap().render(), parsed.render());
        // Escapes survive the round trip.
        let mut esc = BenchJson::new("x");
        esc.row(&[("s", JsonValue::from("a\"b\\c\nd\te"))]);
        let back = parse_bench_json(&esc.render()).unwrap();
        assert_eq!(back.rows()[0][0].1, JsonValue::Str("a\"b\\c\nd\te".to_string()));
        // Garbage is rejected, not misread.
        assert!(parse_bench_json("{\"bench\": [1]}").is_err());
        assert!(parse_bench_json("{\"params\": {}}").is_err(), "missing bench name");
        assert!(parse_bench_json("not json").is_err());
    }

    #[test]
    fn bench_diff_flags_regressions_past_tolerance_only() {
        let doc = |s: f64, b: f64| {
            let mut j = BenchJson::new("fig8_tp_slo");
            j.param("model", "8b");
            j.row(&[
                ("layout", JsonValue::from("TP=2")),
                ("modeled_s", JsonValue::from(s)),
                ("bytes", JsonValue::from(b)),
            ]);
            j
        };
        let old = doc(1.0, 1.0e9);
        // Inside the 5% band: clean.
        let d = bench_diff(&old, &doc(1.04, 1.0e9), 0.05).unwrap();
        assert!(d.is_clean(), "{d:?}");
        // 6% slower: one regression, attributed to its row and field.
        let d = bench_diff(&old, &doc(1.06, 1.0e9), 0.05).unwrap();
        assert_eq!(d.regressions.len(), 1);
        assert_eq!(d.regressions[0].field, "modeled_s");
        assert_eq!(d.regressions[0].row, Some(0));
        assert!(d.regressions[0].ratio() > 0.05);
        assert!(d.improvements.is_empty());
        // 50% faster: an improvement, never a failure.
        let d = bench_diff(&old, &doc(0.5, 1.0e9), 0.05).unwrap();
        assert_eq!(d.improvements.len(), 1);
        assert!(d.regressions.is_empty());
        // Changed label or row count: structural notes, no regression.
        let mut reshaped = doc(1.0, 1.0e9);
        reshaped.row(&[("layout", JsonValue::from("TP=4"))]);
        let d = bench_diff(&old, &reshaped, 0.05).unwrap();
        assert!(d.regressions.is_empty());
        assert!(!d.notes.is_empty());
        // Different benches refuse to diff.
        assert!(bench_diff(&old, &BenchJson::new("other"), 0.05).is_err());
        // Params moving is a note (scenario changed), not a regression.
        let mut p = doc(1.0, 1.0e9);
        p.param("sd", 64usize);
        let mut q = doc(1.0, 1.0e9);
        q.param("sd", 128usize);
        let d = bench_diff(&p, &q, 0.05).unwrap();
        assert!(d.regressions.is_empty());
        assert_eq!(d.notes.len(), 1);
    }

    #[test]
    fn wall_time_stamp_is_written_once_and_diffs_as_advisory_only() {
        // write() stamps wall_s; a re-write replaces the stamp instead
        // of duplicating it.
        let path = std::env::temp_dir().join("BENCH_commsim_wall_test.json");
        let path = path.to_str().unwrap();
        let mut j = BenchJson::new("wall");
        j.param("model", "8b");
        j.write(path).unwrap();
        j.write(path).unwrap();
        let back = parse_bench_json(&std::fs::read_to_string(path).unwrap()).unwrap();
        std::fs::remove_file(path).ok();
        let walls: Vec<_> = back.params().iter().filter(|(k, _)| k == "wall_s").collect();
        assert_eq!(walls.len(), 1, "{:?}", back.params());
        assert!(matches!(&walls[0].1, JsonValue::Num(s) if *s >= 0.0), "{:?}", walls[0]);

        // The differ routes wall_s to the advisory channel: a run 10x
        // slower in wall time is still clean, but the movement is kept.
        let doc = |wall: Option<f64>| {
            let mut j = BenchJson::new("w");
            j.param("model", "8b");
            if let Some(w) = wall {
                j.param("wall_s", w);
            }
            j.row(&[("modeled_s", JsonValue::from(1.0))]);
            j
        };
        let d = bench_diff(&doc(Some(1.0)), &doc(Some(10.0)), 0.05).unwrap();
        assert!(d.is_clean(), "{d:?}");
        let w = d.wall.as_ref().unwrap();
        assert_eq!((w.old, w.new), (1.0, 10.0));
        // Stamp appearing (first run after the writer gained it) or
        // disappearing never dirties the diff.
        let d = bench_diff(&doc(None), &doc(Some(1.0)), 0.05).unwrap();
        assert!(d.is_clean() && d.wall.is_none(), "{d:?}");
        let d = bench_diff(&doc(Some(1.0)), &doc(None), 0.05).unwrap();
        assert!(d.is_clean() && d.wall.is_none(), "{d:?}");
    }

    #[test]
    fn volume_line_contains_layout() {
        let line = volume_line(
            &ModelArch::llama31_8b(),
            ParallelLayout::new(4, 1),
            InferenceShape::new(128, 128, DTYPE_BYTES_BF16),
        );
        assert!(line.contains("TP=4"));
        assert!(line.contains("total"));
    }
}
