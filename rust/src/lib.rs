//! # commsim — Communication Patterns in Distributed LLM Inference
//!
//! Full-system reproduction of *"Characterizing Communication Patterns in
//! Distributed Large Language Model Inference"* (Xu et al., CS.DC 2025).
//!
//! The crate is a vLLM-like serving stack whose every inter-worker
//! communication is a first-class, traced operation:
//!
//! - [`model`] — transformer architecture registry (paper models + the tiny
//!   real model served end-to-end).
//! - [`analysis`] — the paper's analytical models (Eq. 1–7): communication
//!   volume, operation counts and message shapes for TP / PP / hybrid.
//! - [`comm`] — an in-process NCCL-like collective library (AllReduce,
//!   AllGather, Gather, Send/Recv) with built-in tracing.
//! - [`cluster`] — node/GPU topology and the α–β link model (NVLink vs
//!   InfiniBand NDR400).
//! - [`perfmodel`] — H100 roofline compute model + SLO simulator that
//!   regenerates the paper's latency figures (TTFT / TPOT / E2E).
//! - [`runtime`] — PJRT artifact loading and execution (`xla` crate); the
//!   AOT bridge from the JAX/Pallas build path.
//! - [`engine`] — the distributed inference engine: TP/PP/hybrid worker
//!   groups, paged KV cache, prefill/decode loop.
//! - [`server`] — request router, continuous-batching scheduler, SLO
//!   metrics.
//! - [`report`] — renders paper tables/figures side-by-side with our
//!   measured + analytical values.
//!
//! Python (JAX + Pallas) runs only at build time (`make artifacts`); the
//! serving path is pure Rust.

pub mod analysis;
pub mod cluster;
pub mod comm;
pub mod engine;
pub mod model;
pub mod perfmodel;
pub mod report;
pub mod runtime;
pub mod server;
pub mod testutil;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
