//! # commsim — Communication Patterns in Distributed LLM Inference
//!
//! Full-system reproduction of *"Characterizing Communication Patterns in
//! Distributed Large Language Model Inference"* (Xu et al., CS.DC 2025):
//! a vLLM-like serving stack whose every inter-worker communication is a
//! first-class, traced operation.
//!
//! ## Entry point: the deployment-plan facade
//!
//! Everything starts at [`plan::Deployment`] — one validated builder for
//! the (model, layout, topology, workload) tuple, with typed
//! [`plan::PlanError`]s for every infeasible combination (TP not dividing
//! the heads, PP exceeding the layers, layouts that overflow the cluster):
//!
//! ```
//! use commsim::plan::Deployment;
//!
//! let plan = Deployment::builder()
//!     .model("8b")          // Llama-3.1-8B from the registry
//!     .tp(2)
//!     .pp(2)
//!     .workload(128, 128)   // Sp, Sd (paper Table I)
//!     .build()?;
//!
//! let report = plan.analyze();          // Eq. 1-7 volumes + op predictions
//! assert!(report.total_bytes() > 0.0);
//! # Ok::<(), commsim::plan::PlanError>(())
//! ```
//!
//! The validated [`plan::DeploymentPlan`] exposes the unified verbs —
//! `analyze()` (analytical models), `trace()` (run the structural engine,
//! measure the collective stream), `simulate()` (TTFT/TPOT/E2E on the
//! calibrated testbed), `engine()`/`server()` (live serving, numeric when
//! AOT artifacts are attached) — and
//! [`plan::DeploymentPlan::sweep`] enumerates every feasible (TP, PP)
//! plan of a model on a GPU budget. The CLI (`commsim
//! analyze|trace|slo|serve|tables`), the examples and the figure/table
//! benches are all thin layers over this facade.
//!
//! ## Layers underneath
//!
//! - [`model`] — transformer architecture registry (paper models + the tiny
//!   real model served end-to-end).
//! - [`analysis`] — the paper's analytical models (Eq. 1–7): communication
//!   volume, operation counts and message shapes for TP / PP / hybrid.
//! - [`comm`] — an in-process NCCL-like collective library (AllReduce,
//!   AllGather, Gather, Send/Recv) with built-in tracing.
//! - [`cluster`] — node/GPU topology and the α–β link model (NVLink vs
//!   InfiniBand NDR400), including a two-level hierarchical AllReduce for
//!   node-spanning groups.
//! - [`simtime`] — the virtual-clock cost engine: one shared collective
//!   algebra ([`simtime::algebra`]), the [`simtime::CostModel`] pricing
//!   core (closed-form phase breakdowns, per-record trace pricing,
//!   per-iteration timeline posting) and per-rank [`simtime::Timeline`]
//!   clocks. The SLO simulator, the priced trace, and model-time serving
//!   are all views over this one core.
//! - [`perfmodel`] — H100 roofline compute model + SLO simulator (a thin
//!   closed-form view over `simtime`) that regenerates the paper's
//!   latency figures (TTFT / TPOT / E2E).
//! - [`runtime`] — PJRT artifact loading and execution (`xla` crate); the
//!   AOT bridge from the JAX/Pallas build path.
//! - [`engine`] — the distributed inference engine: TP/PP/hybrid worker
//!   groups, paged KV cache, and the iteration-level session API
//!   ([`engine::Session`]): `step()` runs one prefill-or-decode iteration
//!   over the active batch, streams per-sequence [`engine::TokenEvent`]s,
//!   and tags every traced collective with its step and batch size
//!   (`Engine::generate` is a single-sequence wrapper over it).
//! - [`server`] — request router, iteration-level continuous-batching
//!   scheduler (prompt-footprint admission, on-demand KV growth,
//!   `max_batch` concurrency, Poisson arrivals), a per-replica
//!   block-granular [`server::PrefixCache`] (admissions prefill only the
//!   uncached suffix and record saved prefill seconds/bytes), and SLO
//!   metrics with p50/p95/p99 TTFT/TPOT/E2E — in *wall time* (host
//!   clocks; the real latency of numeric PJRT serving) and, on priced
//!   structural engines, *model time* (the virtual-clock seconds the
//!   calibrated testbed would take — deterministic for a fixed workload
//!   and arrival seed).
//! - [`workload`] — seeded open-loop workload generation: Poisson/bursty
//!   arrival processes × fixed/uniform/long-tail request-length
//!   distributions × shared-prefix profiles
//!   ([`workload::PrefixProfile`]: system-prompt, multi-turn, few-shot),
//!   all drawing from independent streams of one deterministic PRNG.
//! - [`fleet`] — the fleet-scale simulator: N priced replicas (each its
//!   own plan — heterogeneous fleets allowed) behind a pluggable router
//!   (round-robin, least-outstanding-tokens, shortest-queue, and
//!   prefix-cache-aware cache-affinity), colocated
//!   or split into disaggregated prefill/decode pools with per-request
//!   KV-cache handoffs priced through the α–β link model; plus the
//!   capacity sweep that finds the cheapest fleet meeting an SLO target
//!   (`commsim fleet` on the CLI).
//! - [`autoscale`] — model-clock elasticity over the fleet: an
//!   [`autoscale::AutoscalePolicy`] (target queue depth and/or rolling
//!   SLO percentile over a sliding window) drives a controller that
//!   spawns replicas with α–β-priced weight cold-starts, drains victims
//!   chosen by warm prefix-cache value, and live-migrates a hot
//!   replica's sequences (resident KV shipped via `NetModel::p2p`) —
//!   every elasticity action is paid for in model time.
//! - [`faults`] — seeded fault injection over the fleet: replica churn
//!   (MTBF/MTTR exponential processes and scripted outages; failed
//!   replicas drop their queues, retried requests lose cache warmth,
//!   recovery pays a weight-reload cold start), straggler replicas
//!   (per-replica α–β degradation of every collective), and time-boxed
//!   link-degradation windows on the fleet wire — reporting goodput,
//!   retries, and wasted prefill per router policy.
//! - [`report`] — renders paper tables/figures side-by-side with our
//!   measured + analytical values.
//!
//! Python (JAX + Pallas) runs only at build time (`make artifacts`); the
//! serving path is pure Rust.

pub mod analysis;
pub mod autoscale;
pub mod cluster;
pub mod comm;
pub mod engine;
pub mod faults;
pub mod fleet;
pub mod model;
pub mod perfmodel;
pub mod plan;
pub mod report;
pub mod runtime;
pub mod server;
pub mod simtime;
pub mod testutil;
pub mod workload;

pub use plan::{Deployment, DeploymentPlan, PlanError, SloResult, VolumeReport};

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
