//! Extensions beyond the paper's evaluated configurations — the two
//! parallelism schemes its Conclusions/Future-Work sections name:
//! **sequence parallelism** (Megatron-SP) and **expert parallelism** (MoE).
//! Both reuse Table I's variables and the NCCL accounting of §V.B, so they
//! compose with [`super::volume`] directly.

use crate::comm::CollectiveKind;
use crate::model::ModelArch;

use super::volume::{InferenceShape, VolumeBreakdown, VolumeModel};

/// Megatron-style sequence parallelism layered on TP.
///
/// SP splits the activations of the norm/dropout regions along the
/// sequence dimension and replaces each of the layer's two AllReduces with
/// a ReduceScatter (region entry) + AllGather (region exit). Per-GPU bytes
/// are *identical* — `2(t−1)/t·n = (t−1)/t·n + (t−1)/t·n` — but the op
/// count doubles and each op moves half the corrected volume, shifting the
/// workload toward the latency (α) term for short sequences. That is the
/// quantitative reason vLLM does not enable SP for decode (window = 1
/// token): 2× the per-layer launch latency for zero byte savings.
#[derive(Debug, Clone)]
pub struct SequenceParallelModel {
    pub arch: ModelArch,
}

impl SequenceParallelModel {
    pub fn new(arch: ModelArch) -> Self {
        Self { arch }
    }

    /// Corrected communication volume under TP+SP (bytes). Equal to Eq. 1's
    /// AllReduce term, redistributed over ReduceScatter + AllGather.
    pub fn volume(&self, t: usize, shape: InferenceShape) -> VolumeBreakdown {
        let base = VolumeModel::new(self.arch.clone()).tensor_parallel(t, shape);
        VolumeBreakdown {
            allreduce: 0.0,
            // Half of each former AllReduce's corrected bytes lands in each
            // half of the RS+AG pair; we report the AG half under
            // `allgather` and fold the RS half there too (the breakdown
            // struct predates the extension; total is what matters).
            allgather: base.allreduce,
            gather: base.gather,
            p2p: 0.0,
        }
    }

    /// Collective *launches* per forward step over one token window —
    /// the latency-term comparison against plain TP.
    pub fn ops_per_step(&self, t: usize) -> Vec<(CollectiveKind, usize)> {
        if t <= 1 {
            return vec![];
        }
        let l = self.arch.layers;
        vec![
            (CollectiveKind::ReduceScatter, 2 * l),
            (CollectiveKind::AllGather, 2 * l),
            // embedding AllReduce is unchanged by SP
            (CollectiveKind::AllReduce, 1),
        ]
    }

    /// Plain-TP launches per step, for comparison.
    pub fn tp_ops_per_step(&self, t: usize) -> usize {
        if t <= 1 { 0 } else { 2 * self.arch.layers + 1 }
    }
}

/// Mixture-of-Experts expert parallelism (EP): each MoE layer dispatches
/// every token's hidden state to its expert's owner rank and combines the
/// expert outputs back — two AllToAll operations per MoE layer per step
/// (Switch/GShard dispatch-combine).
#[derive(Debug, Clone)]
pub struct ExpertParallelModel {
    pub arch: ModelArch,
    /// Number of experts activated per token (top-k routing).
    pub top_k: usize,
    /// Fraction of layers that are MoE (1.0 = every layer, 0.5 = alternating).
    pub moe_layer_fraction: f64,
}

impl ExpertParallelModel {
    pub fn new(arch: ModelArch, top_k: usize, moe_layer_fraction: f64) -> Self {
        assert!(top_k >= 1 && (0.0..=1.0).contains(&moe_layer_fraction));
        Self { arch, top_k, moe_layer_fraction }
    }

    /// Corrected AllToAll volume over a full request (bytes) for an EP
    /// group of `e` ranks: per MoE layer per token-position, dispatch +
    /// combine each move `top_k · h · b` with correction `(e−1)/e`.
    pub fn volume(&self, e: usize, shape: InferenceShape) -> VolumeBreakdown {
        let tokens = shape.total_steps_tokens() as f64;
        let moe_layers = self.arch.layers as f64 * self.moe_layer_fraction;
        let bytes_per_layer_token = (self.top_k * self.arch.hidden) as f64
            * shape.dtype_bytes as f64;
        let factor = CollectiveKind::AllToAll.correction_factor(e);
        let all_to_all = 2.0 * moe_layers * tokens * bytes_per_layer_token * factor;
        VolumeBreakdown {
            // Reported under allgather slot? No — extend semantics: use p2p
            // slot for dispatch/combine traffic to keep AR/AG reserved for
            // the dense components.
            p2p: all_to_all,
            ..Default::default()
        }
    }

    /// AllToAll launches per forward step.
    pub fn ops_per_step(&self, e: usize) -> usize {
        if e <= 1 {
            0
        } else {
            (2.0 * self.arch.layers as f64 * self.moe_layer_fraction).round() as usize
        }
    }

    /// Decode-stage comparison against dense TP (Eq. 1): EP moves
    /// `2·k·h` per MoE layer vs TP's `2·2h` per dense layer — EP's volume
    /// advantage holds while `top_k <= 2` and its ops are α-bound like
    /// TP's, which is the deployment-relevant takeaway.
    pub fn decode_volume_vs_tp(&self, e: usize, t: usize, shape: InferenceShape) -> (f64, f64) {
        let decode_shape = InferenceShape::new(1, shape.decode_len, shape.dtype_bytes);
        let ep = self.volume(e, decode_shape).total();
        let tp = VolumeModel::new(self.arch.clone())
            .tensor_parallel(t, decode_shape)
            .total();
        (ep, tp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelArch, DTYPE_BYTES_BF16};

    fn shape128() -> InferenceShape {
        InferenceShape::new(128, 128, DTYPE_BYTES_BF16)
    }

    #[test]
    fn sp_total_volume_equals_tp() {
        // RS+AG moves exactly the bytes AllReduce moved.
        let arch = ModelArch::llama31_8b();
        for t in [2usize, 4, 8] {
            let tp = VolumeModel::new(arch.clone()).tensor_parallel(t, shape128());
            let sp = SequenceParallelModel::new(arch.clone()).volume(t, shape128());
            assert!((tp.total() - sp.total()).abs() < 1e-6, "t={t}");
        }
    }

    #[test]
    fn sp_doubles_layer_collective_launches() {
        let m = SequenceParallelModel::new(ModelArch::llama31_8b());
        let sp_layer_ops: usize = m
            .ops_per_step(4)
            .iter()
            .filter(|(k, _)| *k != CollectiveKind::AllReduce)
            .map(|(_, c)| c)
            .sum();
        assert_eq!(sp_layer_ops, 2 * (m.tp_ops_per_step(4) - 1));
        assert!(m.ops_per_step(1).is_empty());
    }

    #[test]
    fn ep_volume_hand_computed() {
        // 8B-like dense arch, every layer MoE, top-2, e=4, decode-only.
        let arch = ModelArch::llama31_8b();
        let m = ExpertParallelModel::new(arch.clone(), 2, 1.0);
        let shape = InferenceShape::new(1, 128, DTYPE_BYTES_BF16);
        let v = m.volume(4, shape).total();
        // 2 (dispatch+combine) * 32 layers * 128 tokens * 2k * 4096 h * 2B * 3/4
        let expect = 2.0 * 32.0 * 128.0 * (2.0 * 4096.0) * 2.0 * 0.75;
        assert!((v - expect).abs() < 1e-6, "{v} vs {expect}");
        assert_eq!(m.ops_per_step(4), 64);
        assert_eq!(m.ops_per_step(1), 0);
    }

    #[test]
    fn ep_beats_dense_tp_volume_at_top1() {
        // top-1 MoE decode moves 2·h/layer vs TP's ~2·2h(t−1)/t/layer.
        let arch = ModelArch::llama31_8b();
        let m = ExpertParallelModel::new(arch.clone(), 1, 1.0);
        let (ep, tp) = m.decode_volume_vs_tp(4, 4, shape128());
        assert!(ep < tp, "ep={ep} tp={tp}");
    }

    #[test]
    fn ep_volume_scales_with_top_k_and_fraction() {
        let arch = ModelArch::llama32_3b();
        let s = shape128();
        let v1 = ExpertParallelModel::new(arch.clone(), 1, 1.0).volume(4, s).total();
        let v2 = ExpertParallelModel::new(arch.clone(), 2, 1.0).volume(4, s).total();
        let vh = ExpertParallelModel::new(arch.clone(), 2, 0.5).volume(4, s).total();
        assert!((v2 / v1 - 2.0).abs() < 1e-9);
        assert!((v2 / vh - 2.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn ep_rejects_zero_top_k() {
        ExpertParallelModel::new(ModelArch::tiny(), 0, 1.0);
    }
}
