//! The paper's analytical models (§III).
//!
//! [`volume`] implements Eq. 1–7: predicted communication *bytes* for TP,
//! PP and hybrid parallelism. [`ops`] predicts the *operation counts and
//! message shapes* that the PyTorch profiler observed (Tables III–VI) —
//! the per-stage breakdown the volume formulas integrate over.

pub mod disagg;
pub mod extensions;
pub mod ops;
pub mod volume;

pub use disagg::{DisaggVolume, DisaggregationModel};
pub use extensions::{ExpertParallelModel, SequenceParallelModel};
pub use ops::{OpCountModel, PredictedOps, StageOps};
pub use volume::{InferenceShape, ParallelLayout, VolumeBreakdown, VolumeModel};
