//! Communication-volume models — paper §III, Equations 1–7.
//!
//! Volumes follow the NCCL accounting the paper adopts ([16]): message size
//! multiplied by the algorithm's correction factor — `2(d−1)/d` for
//! AllReduce, `(d−1)/d` for AllGather, `1` for point-to-point and Gather,
//! where `d` is the number of participating workers.


use crate::model::ModelArch;

/// A parallelism layout: `t` tensor-parallel × `p` pipeline-parallel ranks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ParallelLayout {
    /// Tensor-parallel size `t`.
    pub tp: usize,
    /// Pipeline-parallel size `p`.
    pub pp: usize,
}

impl ParallelLayout {
    pub fn new(tp: usize, pp: usize) -> Self {
        assert!(tp >= 1 && pp >= 1, "degrees must be >= 1");
        Self { tp, pp }
    }

    /// Total number of GPU workers.
    pub fn world_size(&self) -> usize {
        self.tp * self.pp
    }

    pub fn label(&self) -> String {
        match (self.tp, self.pp) {
            (t, 1) => format!("TP={t}"),
            (1, p) => format!("PP={p}"),
            (t, p) => format!("TP={t} PP={p}"),
        }
    }
}

/// Sequence-length setting of one inference request (paper Table I:
/// `S_p` prefill tokens, `S_d` decode tokens, `b` bytes per element).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InferenceShape {
    pub prefill_len: usize,
    pub decode_len: usize,
    pub dtype_bytes: usize,
}

impl InferenceShape {
    pub fn new(prefill_len: usize, decode_len: usize, dtype_bytes: usize) -> Self {
        assert!(prefill_len >= 1 && decode_len >= 1);
        Self { prefill_len, decode_len, dtype_bytes }
    }

    /// The `(S_p + S_d − 1)` term: total forward steps' token-positions —
    /// the final sampled token never re-enters the network.
    pub fn total_steps_tokens(&self) -> usize {
        self.prefill_len + self.decode_len - 1
    }
}

/// Per-collective-class volume decomposition (bytes). `total()` is the
/// paper's reported communication volume.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct VolumeBreakdown {
    pub allreduce: f64,
    pub allgather: f64,
    pub gather: f64,
    pub p2p: f64,
}

impl VolumeBreakdown {
    pub fn total(&self) -> f64 {
        self.allreduce + self.allgather + self.gather + self.p2p
    }
}

/// Analytical volume model over (architecture, layout, sequence shape).
#[derive(Debug, Clone)]
pub struct VolumeModel {
    pub arch: ModelArch,
}

impl VolumeModel {
    pub fn new(arch: ModelArch) -> Self {
        Self { arch }
    }

    /// AllReduce correction factor `2(d−1)/d` (ring algorithm bytes/GPU)
    /// — one source of truth in [`crate::simtime::algebra`].
    pub fn allreduce_factor(d: usize) -> f64 {
        crate::simtime::algebra::allreduce_factor(d)
    }

    /// AllGather correction factor `(d−1)/d` — shared collective algebra.
    pub fn allgather_factor(d: usize) -> f64 {
        crate::simtime::algebra::allgather_factor(d)
    }

    /// Eq. 1 — pure tensor parallelism:
    /// `V_tp = (2L+1)(S_p+S_d−1) h b · 2(t−1)/t + S_d (v/t) b`.
    pub fn tensor_parallel(&self, t: usize, shape: InferenceShape) -> VolumeBreakdown {
        assert!(t >= 1);
        let a = &self.arch;
        let b = shape.dtype_bytes as f64;
        let tokens = shape.total_steps_tokens() as f64;
        let allreduce = (2 * a.layers + 1) as f64
            * tokens
            * a.hidden as f64
            * b
            * Self::allreduce_factor(t);
        let gather = if t > 1 {
            shape.decode_len as f64 * (a.vocab as f64 / t as f64) * b
        } else {
            0.0
        };
        VolumeBreakdown { allreduce, gather, ..Default::default() }
    }

    /// Eq. 2 — pure pipeline parallelism:
    /// `V_pp = (p−1) · 2 · (S_p+S_d−1) h b`.
    ///
    /// The factor 2 is the two tensors vLLM ships per stage boundary
    /// (hidden states + deferred residual; §V.A "separate transmission").
    pub fn pipeline_parallel(&self, p: usize, shape: InferenceShape) -> VolumeBreakdown {
        assert!(p >= 1);
        let a = &self.arch;
        let p2p = (p.saturating_sub(1)) as f64
            * 2.0
            * shape.total_steps_tokens() as f64
            * a.hidden as f64
            * shape.dtype_bytes as f64;
        VolumeBreakdown { p2p, ..Default::default() }
    }

    /// Eq. 3–7 — hybrid: `V = V_ar + V_ag + V_gather + V_p2p`, with the
    /// rank-0-stage embedding AllReduce correction (§III.C final note).
    pub fn hybrid(&self, layout: ParallelLayout, shape: InferenceShape) -> VolumeBreakdown {
        let (t, p) = (layout.tp, layout.pp);
        if p == 1 {
            return self.tensor_parallel(t, shape);
        }
        if t == 1 {
            return self.pipeline_parallel(p, shape);
        }
        let a = &self.arch;
        let b = shape.dtype_bytes as f64;
        let tokens = shape.total_steps_tokens() as f64;
        let h = a.hidden as f64;

        // Eq. 4 + embedding contribution on the first pipeline rank.
        let layer_ar = (2 * a.layers) as f64 / p as f64;
        let allreduce =
            (layer_ar + 1.0) * tokens * h * b * Self::allreduce_factor(t);

        // Eq. 5 — stage-entry redistribution among TP workers.
        let allgather = 2.0
            * (p - 1) as f64
            * tokens
            * h
            * b
            * Self::allgather_factor(t);

        // Eq. 6 — logits gather.
        let gather = shape.decode_len as f64 * (a.vocab as f64 / t as f64) * b;

        // Eq. 7 — p2p carries the TP-local slice h/t (×2 tensors).
        let p2p = (p - 1) as f64 * 2.0 * tokens * (h / t as f64) * b;

        VolumeBreakdown { allreduce, allgather, gather, p2p }
    }

    /// Dispatch on layout shape (the benches' single entry point).
    pub fn volume(&self, layout: ParallelLayout, shape: InferenceShape) -> VolumeBreakdown {
        self.hybrid(layout, shape)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelArch, DTYPE_BYTES_BF16};

    fn shape128() -> InferenceShape {
        InferenceShape::new(128, 128, DTYPE_BYTES_BF16)
    }

    #[test]
    fn eq1_tensor_parallel_hand_computed() {
        let m = VolumeModel::new(ModelArch::llama31_8b());
        let v = m.tensor_parallel(4, shape128());
        // (2*32+1) * 255 * 4096 * 2 * 2*(3/4)
        let expect_ar = 65.0 * 255.0 * 4096.0 * 2.0 * 1.5;
        assert!((v.allreduce - expect_ar).abs() < 1e-6);
        let expect_gather = 128.0 * (128_256.0 / 4.0) * 2.0;
        assert!((v.gather - expect_gather).abs() < 1e-6);
        assert_eq!(v.p2p, 0.0);
        assert_eq!(v.allgather, 0.0);
    }

    #[test]
    fn eq2_pipeline_parallel_hand_computed() {
        let m = VolumeModel::new(ModelArch::llama31_8b());
        let v = m.pipeline_parallel(4, shape128());
        let expect = 3.0 * 2.0 * 255.0 * 4096.0 * 2.0;
        assert!((v.p2p - expect).abs() < 1e-6);
        assert_eq!(v.total(), v.p2p);
    }

    #[test]
    fn eq4_to_7_hybrid_hand_computed() {
        let m = VolumeModel::new(ModelArch::llama31_8b());
        let v = m.hybrid(ParallelLayout::new(2, 2), shape128());
        let b = 2.0;
        let tokens = 255.0;
        let h = 4096.0;
        let ar = (32.0 + 1.0) * tokens * h * b * 1.0; // 2L/p=32, +1 embed; factor 2*(1/2)=1
        let ag = 2.0 * 1.0 * tokens * h * b * 0.5;
        let g = 128.0 * (128_256.0 / 2.0) * b;
        let p2p = 1.0 * 2.0 * tokens * (h / 2.0) * b;
        assert!((v.allreduce - ar).abs() < 1e-6, "{} vs {}", v.allreduce, ar);
        assert!((v.allgather - ag).abs() < 1e-6);
        assert!((v.gather - g).abs() < 1e-6);
        assert!((v.p2p - p2p).abs() < 1e-6);
    }

    #[test]
    fn hybrid_degenerates_to_pure_forms() {
        let m = VolumeModel::new(ModelArch::llama32_3b());
        let s = shape128();
        assert_eq!(
            m.hybrid(ParallelLayout::new(4, 1), s),
            m.tensor_parallel(4, s)
        );
        assert_eq!(
            m.hybrid(ParallelLayout::new(1, 4), s),
            m.pipeline_parallel(4, s)
        );
    }

    #[test]
    fn single_gpu_volume_is_zero() {
        let m = VolumeModel::new(ModelArch::llama32_3b());
        let v = m.volume(ParallelLayout::new(1, 1), shape128());
        assert_eq!(v.total(), 0.0);
    }

    #[test]
    fn fig6_ordering_tp_highest_pp_lowest() {
        // Paper Fig. 6: TP=4 highest volume, PP=4 lowest, hybrid between —
        // for every evaluation model.
        let s = shape128();
        for arch in ModelArch::paper_models() {
            let m = VolumeModel::new(arch.clone());
            let tp = m.volume(ParallelLayout::new(4, 1), s).total();
            let pp = m.volume(ParallelLayout::new(1, 4), s).total();
            let hy = m.volume(ParallelLayout::new(2, 2), s).total();
            assert!(tp > hy && hy > pp, "{}: tp={tp} hy={hy} pp={pp}", arch.name);
        }
    }

    #[test]
    fn fig7_sublinear_decode_scaling_ratios() {
        // Paper §V.B: growth factors 1.50x (128->256) and 1.67x (256->512)
        // from the (S_p + S_d − 1) term.
        let m = VolumeModel::new(ModelArch::llama31_8b());
        let v = |layout: ParallelLayout, sd: usize| {
            m.volume(layout, InferenceShape::new(128, sd, DTYPE_BYTES_BF16)).total()
        };
        // Pure (S_p + S_d − 1) scaling (PP volume): exactly 383/255, 639/383.
        let pp = ParallelLayout::new(1, 4);
        assert!((v(pp, 256) / v(pp, 128) - 383.0 / 255.0).abs() < 1e-12);
        assert!((v(pp, 512) / v(pp, 256) - 639.0 / 383.0).abs() < 1e-12);
        // TP adds the Gather term (∝ S_d), shifting ratios by ~1-2%.
        let tp = ParallelLayout::new(4, 1);
        let g1 = v(tp, 256) / v(tp, 128);
        let g2 = v(tp, 512) / v(tp, 256);
        assert!((g1 - 1.50).abs() < 0.03, "g1={g1}");
        assert!((g2 - 1.67).abs() < 0.03, "g2={g2}");
    }

    #[test]
    fn volume_scales_with_model_size() {
        // Fig. 6 note: volume increases 3B -> 8B -> 13B for every strategy.
        let s = shape128();
        for layout in [
            ParallelLayout::new(4, 1),
            ParallelLayout::new(1, 4),
            ParallelLayout::new(2, 2),
        ] {
            let v3 = VolumeModel::new(ModelArch::llama32_3b()).volume(layout, s).total();
            let v8 = VolumeModel::new(ModelArch::llama31_8b()).volume(layout, s).total();
            let v13 = VolumeModel::new(ModelArch::llama2_13b()).volume(layout, s).total();
            assert!(v3 < v8 && v8 < v13, "{}", layout.label());
        }
    }

    #[test]
    fn layout_helpers() {
        assert_eq!(ParallelLayout::new(2, 4).world_size(), 8);
        assert_eq!(ParallelLayout::new(8, 1).label(), "TP=8");
        assert_eq!(ParallelLayout::new(1, 8).label(), "PP=8");
        assert_eq!(ParallelLayout::new(2, 4).label(), "TP=2 PP=4");
    }

    #[test]
    fn correction_factors() {
        assert_eq!(VolumeModel::allreduce_factor(1), 0.0);
        assert!((VolumeModel::allreduce_factor(2) - 1.0).abs() < 1e-12);
        assert!((VolumeModel::allreduce_factor(4) - 1.5).abs() < 1e-12);
        assert!((VolumeModel::allgather_factor(4) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn correction_factors_pin_the_shared_algebra() {
        // One source of truth: the volume model's factors, the trace
        // accounting's correction_factor, and the algebra module must be
        // bitwise-identical for every group size.
        use crate::comm::CollectiveKind;
        for d in 1..=64usize {
            assert_eq!(
                VolumeModel::allreduce_factor(d),
                CollectiveKind::AllReduce.correction_factor(d),
                "allreduce d={d}"
            );
            assert_eq!(
                VolumeModel::allreduce_factor(d),
                crate::simtime::algebra::allreduce_factor(d),
            );
            assert_eq!(
                VolumeModel::allgather_factor(d),
                CollectiveKind::AllGather.correction_factor(d),
                "allgather d={d}"
            );
            assert_eq!(
                VolumeModel::allgather_factor(d),
                crate::simtime::algebra::allgather_factor(d),
            );
        }
    }
}
