//! Operation-count and message-shape predictions (paper Tables III–VI).
//!
//! The volume formulas of [`super::volume`] integrate over a concrete
//! per-rank operation stream; this module predicts that stream — per rank
//! and in the paper's table-view conventions — so engine traces can be
//! validated op-for-op.
//!
//! Derivation (paper §III + §V.A, DESIGN.md §6), per forward step over a
//! token window `S`:
//! - TP group (t>1): 1 embedding AllReduce `[S,h]` on the first pipeline
//!   stage, 2 AllReduce `[S,h]` per local layer, 1 logits Gather `[v/t]`
//!   on the last stage per *sampled* token;
//! - PP boundary: 2 tensors (hidden + deferred residual) per link per step
//!   (`[S, h/t]` each — `[S,h]` when t=1);
//! - hybrid stage entry (t>1, stage>0): 2 AllGathers to `[S,h]`.
//!
//! Prefill is 1 step over `S_p` tokens; decode is `S_d − 1` steps over 1
//! token (the last sampled token never re-enters the network).


use super::volume::{InferenceShape, ParallelLayout};
use crate::comm::{CollectiveKind, Stage};
use crate::model::ModelArch;

/// One predicted table row: op class, count, message shape.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PredictedOps {
    pub op: CollectiveKind,
    pub count: usize,
    pub shape: Vec<usize>,
}

/// Predictions for one stage (prefill or decode).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StageOps {
    pub ops: Vec<PredictedOps>,
}

impl StageOps {
    pub fn count(&self, op: CollectiveKind) -> usize {
        self.ops.iter().filter(|o| o.op == op).map(|o| o.count).sum()
    }

    pub fn shape(&self, op: CollectiveKind) -> Option<&[usize]> {
        self.ops.iter().find(|o| o.op == op).map(|o| o.shape.as_slice())
    }

    fn push(&mut self, op: CollectiveKind, count: usize, shape: Vec<usize>) {
        if count > 0 {
            self.ops.push(PredictedOps { op, count, shape });
        }
    }
}

/// Analytical op-count model over (architecture, layout, sequence shape).
#[derive(Debug, Clone)]
pub struct OpCountModel {
    pub arch: ModelArch,
    pub layout: ParallelLayout,
    pub shape: InferenceShape,
}

impl OpCountModel {
    pub fn new(arch: ModelArch, layout: ParallelLayout, shape: InferenceShape) -> Self {
        assert!(arch.supports_tp(layout.tp), "arch does not divide by tp");
        assert!(arch.supports_pp(layout.pp), "arch does not divide by pp");
        Self { arch, layout, shape }
    }

    fn steps(&self, stage: Stage) -> (usize, usize) {
        // (number of forward steps, token window per step)
        match stage {
            Stage::Prefill => (1, self.shape.prefill_len),
            Stage::Decode => (self.shape.decode_len - 1, 1),
        }
    }

    /// Per-rank predicted ops for `stage`. Global rank = `pp_stage * tp +
    /// tp_rank` (TP-major placement, vLLM convention).
    pub fn predict_rank(&self, pp_stage: usize, stage: Stage) -> StageOps {
        let (t, p) = (self.layout.tp, self.layout.pp);
        let (steps, window) = self.steps(stage);
        let h = self.arch.hidden;
        let local_layers = self.arch.stage_layers(p, pp_stage);
        let mut out = StageOps::default();
        if steps == 0 {
            return out;
        }

        if t > 1 {
            let mut ar = 2 * local_layers;
            if pp_stage == 0 {
                ar += 1; // vocab-parallel embedding
            }
            out.push(CollectiveKind::AllReduce, ar * steps, vec![window, h]);
            if p > 1 && pp_stage > 0 {
                // Stage-entry redistribution of (hidden, residual).
                out.push(CollectiveKind::AllGather, 2 * steps, vec![window, h]);
            }
            if pp_stage == p - 1 {
                out.push(CollectiveKind::Gather, steps, vec![self.arch.vocab / t]);
            }
        }
        if p > 1 {
            let slice = vec![window, h / t];
            if pp_stage < p - 1 {
                out.push(CollectiveKind::Send, 2 * steps, slice.clone());
            }
            if pp_stage > 0 {
                out.push(CollectiveKind::Recv, 2 * steps, slice);
            }
        }
        out
    }

    /// Global totals (sum over all ranks) — the Table V convention for
    /// pipeline Send/Recv counts.
    pub fn predict_global(&self, stage: Stage) -> StageOps {
        let (t, p) = (self.layout.tp, self.layout.pp);
        let mut total = StageOps::default();
        for s in 0..p {
            let per_rank = self.predict_rank(s, stage);
            for o in per_rank.ops {
                // Collectives are issued by every TP member of the stage;
                // p2p by exactly one rank pair per boundary slice... in our
                // engine each TP rank sends its own slice, so multiply all
                // ops by the t members.
                let copies = t;
                if let Some(existing) = total
                    .ops
                    .iter_mut()
                    .find(|e| e.op == o.op && e.shape == o.shape)
                {
                    existing.count += o.count * copies;
                } else {
                    total.push(o.op, o.count * copies, o.shape);
                }
            }
        }
        total
    }

    /// The paper's table view: per-op stats from the rank observing the
    /// most of that op (Tables III and VI; reproduces "exclude rank 0, read
    /// one worker's profile").
    pub fn predict_paper_view(&self, stage: Stage) -> StageOps {
        let p = self.layout.pp;
        let mut best: Vec<PredictedOps> = Vec::new();
        for s in 0..p {
            for o in self.predict_rank(s, stage).ops {
                match best.iter_mut().find(|b| b.op == o.op) {
                    Some(b) if b.count >= o.count => {}
                    Some(b) => *b = o,
                    None => best.push(o),
                }
            }
        }
        StageOps { ops: best }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelArch, DTYPE_BYTES_BF16};

    fn shape128() -> InferenceShape {
        InferenceShape::new(128, 128, DTYPE_BYTES_BF16)
    }

    fn model(tp: usize, pp: usize) -> OpCountModel {
        OpCountModel::new(
            ModelArch::llama31_8b(),
            ParallelLayout::new(tp, pp),
            shape128(),
        )
    }

    #[test]
    fn table3_tp_counts_and_shapes() {
        // Paper Table III, Llama-3.1-8B, Sp=Sd=128, TP in {2,4}.
        for t in [2, 4] {
            let m = model(t, 1);
            let pre = m.predict_paper_view(Stage::Prefill);
            assert_eq!(pre.count(CollectiveKind::AllReduce), 65, "tp={t}");
            assert_eq!(pre.shape(CollectiveKind::AllReduce).unwrap(), &[128, 4096]);
            assert_eq!(pre.count(CollectiveKind::Gather), 1);
            assert_eq!(pre.shape(CollectiveKind::Gather).unwrap(), &[128_256 / t]);

            let dec = m.predict_paper_view(Stage::Decode);
            assert_eq!(dec.count(CollectiveKind::AllReduce), 8255, "tp={t}");
            assert_eq!(dec.shape(CollectiveKind::AllReduce).unwrap(), &[1, 4096]);
            assert_eq!(dec.count(CollectiveKind::Gather), 127);
        }
    }

    #[test]
    fn table4_allreduce_counts_across_models() {
        // Paper Table IV: E2E Allreduce counts 57/65/81 prefill, 7239/8255/10287 decode.
        let cases = [
            (ModelArch::llama32_3b(), 57, 7239),
            (ModelArch::llama31_8b(), 65, 8255),
            (ModelArch::llama2_13b(), 81, 10287),
        ];
        for (arch, pre_count, dec_count) in cases {
            let m = OpCountModel::new(arch.clone(), ParallelLayout::new(4, 1), shape128());
            assert_eq!(
                m.predict_paper_view(Stage::Prefill).count(CollectiveKind::AllReduce),
                pre_count,
                "{}",
                arch.name
            );
            assert_eq!(
                m.predict_paper_view(Stage::Decode).count(CollectiveKind::AllReduce),
                dec_count,
                "{}",
                arch.name
            );
        }
    }

    #[test]
    fn table5_pp_global_send_recv() {
        // Paper Table V: PP=2 -> 2/2 prefill, 254/254 decode;
        //                PP=4 -> 6/6 prefill, 762/762 decode.
        for (p, pre, dec) in [(2usize, 2usize, 254usize), (4, 6, 762)] {
            let m = model(1, p);
            let g_pre = m.predict_global(Stage::Prefill);
            assert_eq!(g_pre.count(CollectiveKind::Send), pre, "p={p}");
            assert_eq!(g_pre.count(CollectiveKind::Recv), pre, "p={p}");
            assert_eq!(g_pre.shape(CollectiveKind::Send).unwrap(), &[128, 4096]);
            let g_dec = m.predict_global(Stage::Decode);
            assert_eq!(g_dec.count(CollectiveKind::Send), dec, "p={p}");
            assert_eq!(g_dec.count(CollectiveKind::Recv), dec, "p={p}");
            assert_eq!(g_dec.shape(CollectiveKind::Send).unwrap(), &[1, 4096]);
        }
    }

    #[test]
    fn table6_hybrid_tp2_pp2() {
        // Paper Table VI: TP=2 x PP=2, Llama-3.1-8B.
        let m = model(2, 2);
        let pre = m.predict_paper_view(Stage::Prefill);
        assert_eq!(pre.count(CollectiveKind::AllReduce), 33);
        assert_eq!(pre.shape(CollectiveKind::AllReduce).unwrap(), &[128, 4096]);
        assert_eq!(pre.count(CollectiveKind::Gather), 1);
        assert_eq!(pre.shape(CollectiveKind::Gather).unwrap(), &[64128]);
        assert_eq!(pre.count(CollectiveKind::AllGather), 2);
        assert_eq!(pre.shape(CollectiveKind::AllGather).unwrap(), &[128, 4096]);
        assert_eq!(pre.count(CollectiveKind::Send), 2);
        assert_eq!(pre.shape(CollectiveKind::Send).unwrap(), &[128, 2048]);

        let dec = m.predict_paper_view(Stage::Decode);
        assert_eq!(dec.count(CollectiveKind::AllReduce), 4191);
        assert_eq!(dec.count(CollectiveKind::Gather), 127);
        assert_eq!(dec.count(CollectiveKind::AllGather), 254);
        assert_eq!(dec.count(CollectiveKind::Send), 254);
        assert_eq!(dec.shape(CollectiveKind::Send).unwrap(), &[1, 2048]);
    }

    #[test]
    fn per_rank_stage_roles() {
        let m = model(2, 2);
        // Stage 0: embedding AR but no gather/allgather/recv.
        let s0 = m.predict_rank(0, Stage::Prefill);
        assert_eq!(s0.count(CollectiveKind::AllReduce), 33);
        assert_eq!(s0.count(CollectiveKind::Gather), 0);
        assert_eq!(s0.count(CollectiveKind::AllGather), 0);
        assert_eq!(s0.count(CollectiveKind::Send), 2);
        assert_eq!(s0.count(CollectiveKind::Recv), 0);
        // Stage 1: no embedding; gather + allgather + recv.
        let s1 = m.predict_rank(1, Stage::Prefill);
        assert_eq!(s1.count(CollectiveKind::AllReduce), 32);
        assert_eq!(s1.count(CollectiveKind::Gather), 1);
        assert_eq!(s1.count(CollectiveKind::AllGather), 2);
        assert_eq!(s1.count(CollectiveKind::Send), 0);
        assert_eq!(s1.count(CollectiveKind::Recv), 2);
    }

    #[test]
    fn pure_tp_has_no_p2p_and_pure_pp_no_collectives() {
        let tp = model(4, 1);
        let v = tp.predict_global(Stage::Decode);
        assert_eq!(v.count(CollectiveKind::Send), 0);
        assert_eq!(v.count(CollectiveKind::Recv), 0);
        assert_eq!(v.count(CollectiveKind::AllGather), 0);

        let pp = model(1, 4);
        let v = pp.predict_global(Stage::Decode);
        assert_eq!(v.count(CollectiveKind::AllReduce), 0);
        assert_eq!(v.count(CollectiveKind::Gather), 0);
    }

    #[test]
    fn single_gpu_is_silent() {
        let m = model(1, 1);
        for stage in [Stage::Prefill, Stage::Decode] {
            assert!(m.predict_global(stage).ops.is_empty());
        }
    }

    #[test]
    fn counts_integrate_to_eq1_volume() {
        // Σ (count × message bytes × correction) over predicted ops must
        // equal Eq. 1 exactly — the two models are one derivation.
        use crate::analysis::volume::VolumeModel;
        let arch = ModelArch::llama31_8b();
        let shape = shape128();
        let t = 4;
        let m = OpCountModel::new(arch.clone(), ParallelLayout::new(t, 1), shape);
        let vm = VolumeModel::new(arch);
        let b = shape.dtype_bytes as f64;
        let mut total = 0.0;
        for stage in [Stage::Prefill, Stage::Decode] {
            for o in m.predict_paper_view(stage).ops {
                let elems: usize = o.shape.iter().product();
                total += o.count as f64 * elems as f64 * b * o.op.correction_factor(t);
            }
        }
        let eq1 = vm.tensor_parallel(t, shape).total();
        assert!(
            (total - eq1).abs() / eq1 < 1e-12,
            "ops integrate to {total}, Eq.1 gives {eq1}"
        );
    }
}
