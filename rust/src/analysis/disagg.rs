//! Prefill/decode disaggregation (DistServe [25], discussed in the paper's
//! Related Work): prefill and decode run on *separate* worker pools, so
//! after prefill the whole KV cache must cross the network once per
//! request. This module quantifies that trade against colocated serving
//! with the same accounting as Eq. 1–7 — the natural next question after
//! the paper's Fig. 6/7 analysis ("what if the stages don't share GPUs?").

use crate::model::ModelArch;

use super::volume::{InferenceShape, ParallelLayout, VolumeModel};

/// Disaggregated deployment: a prefill pool and a decode pool, each with
/// its own parallel layout, connected by the inter-node fabric.
#[derive(Debug, Clone)]
pub struct DisaggregationModel {
    pub arch: ModelArch,
    pub prefill_layout: ParallelLayout,
    pub decode_layout: ParallelLayout,
}

/// Volume decomposition of one disaggregated request (bytes).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DisaggVolume {
    /// Collective traffic inside the prefill pool (Eq. 1–7 over Sp only).
    pub prefill_internal: f64,
    /// Collective traffic inside the decode pool (Eq. 1–7 over Sd steps).
    pub decode_internal: f64,
    /// One-shot KV-cache migration: `Sp · 2 · L · kv_heads · d_head · b`.
    pub kv_transfer: f64,
}

impl DisaggVolume {
    pub fn total(&self) -> f64 {
        self.prefill_internal + self.decode_internal + self.kv_transfer
    }
}

impl DisaggregationModel {
    pub fn new(
        arch: ModelArch,
        prefill_layout: ParallelLayout,
        decode_layout: ParallelLayout,
    ) -> Self {
        assert!(arch.supports_tp(prefill_layout.tp) && arch.supports_pp(prefill_layout.pp));
        assert!(arch.supports_tp(decode_layout.tp) && arch.supports_pp(decode_layout.pp));
        Self { arch, prefill_layout, decode_layout }
    }

    /// Per-request volume under disaggregation.
    ///
    /// Prefill-pool internal traffic is Eq. 1–7 with `S_d = 1` (the pool
    /// produces exactly the first token); decode-pool traffic is Eq. 1–7
    /// with a 1-token prompt (it never sees the prefill window); the KV
    /// migration ships every layer's K and V for the `S_p` cached tokens.
    pub fn volume(&self, shape: InferenceShape) -> DisaggVolume {
        let vm = VolumeModel::new(self.arch.clone());
        let prefill_shape = InferenceShape::new(shape.prefill_len, 1, shape.dtype_bytes);
        let decode_shape = InferenceShape::new(1, shape.decode_len, shape.dtype_bytes);
        let kv_transfer = (shape.prefill_len
            * self.arch.kv_bytes_per_token(shape.dtype_bytes)) as f64;
        DisaggVolume {
            prefill_internal: vm.volume(self.prefill_layout, prefill_shape).total(),
            decode_internal: vm.volume(self.decode_layout, decode_shape).total(),
            kv_transfer,
        }
    }

    /// Colocated baseline (same total GPUs in one pool, the paper's
    /// setting) for comparison.
    pub fn colocated_volume(&self, layout: ParallelLayout, shape: InferenceShape) -> f64 {
        VolumeModel::new(self.arch.clone()).volume(layout, shape).total()
    }

    /// The decode-length break-even: disaggregation amortizes its KV
    /// migration over generated tokens; returns the smallest `S_d` at which
    /// the disaggregated total undercuts the colocated baseline, if any
    /// (searching `1..=max_sd`).
    pub fn break_even_decode_len(
        &self,
        colocated: ParallelLayout,
        sp: usize,
        dtype_bytes: usize,
        max_sd: usize,
    ) -> Option<usize> {
        (1..=max_sd).find(|&sd| {
            let shape = InferenceShape::new(sp, sd, dtype_bytes);
            self.volume(shape).total() < self.colocated_volume(colocated, shape)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelArch, DTYPE_BYTES_BF16};

    fn model() -> DisaggregationModel {
        DisaggregationModel::new(
            ModelArch::llama31_8b(),
            ParallelLayout::new(4, 1), // prefill pool: TP4 (TTFT-optimal)
            ParallelLayout::new(1, 4), // decode pool: PP4 (volume-optimal)
        )
    }

    #[test]
    fn kv_transfer_hand_computed() {
        // 8B GQA: 2 * 32 layers * 8 kv heads * 128 dim * 2 B = 131072 B/token.
        let v = model().volume(InferenceShape::new(128, 128, DTYPE_BYTES_BF16));
        assert_eq!(v.kv_transfer, (128 * 131_072) as f64);
    }

    #[test]
    fn pools_see_only_their_stage() {
        let m = model();
        let shape = InferenceShape::new(128, 128, DTYPE_BYTES_BF16);
        let v = m.volume(shape);
        // Prefill pool: Eq. 1 over (Sp, Sd=1) — the (2L+1)·Sp·h·b·f term.
        let expect_prefill = VolumeModel::new(m.arch.clone())
            .tensor_parallel(4, InferenceShape::new(128, 1, DTYPE_BYTES_BF16))
            .total();
        assert!((v.prefill_internal - expect_prefill).abs() < 1e-9);
        // Decode pool: pure-PP p2p over the decode steps only.
        let expect_decode = VolumeModel::new(m.arch.clone())
            .pipeline_parallel(4, InferenceShape::new(1, 128, DTYPE_BYTES_BF16))
            .total();
        assert!((v.decode_internal - expect_decode).abs() < 1e-9);
    }

    #[test]
    fn disagg_beats_colocated_tp_for_long_generation() {
        // Colocated TP=4 pays (2L+1)·h AllReduces for *every* decode token;
        // the disaggregated decode pool (PP4) pays only p2p. Past some Sd
        // the one-shot KV migration is amortized.
        let m = model();
        let be = m.break_even_decode_len(ParallelLayout::new(4, 1), 128, 2, 4096);
        assert!(be.is_some(), "break-even must exist");
        let be = be.unwrap();
        assert!(be < 64, "KV migration amortizes quickly, got {be}");
        // And before break-even, colocation wins.
        if be > 1 {
            let shape = InferenceShape::new(128, be - 1, DTYPE_BYTES_BF16);
            assert!(
                m.volume(shape).total()
                    >= m.colocated_volume(ParallelLayout::new(4, 1), shape)
            );
        }
    }

    #[test]
    fn disagg_never_beats_colocated_pp_on_volume() {
        // Colocated PP is already volume-minimal; disaggregation adds the
        // KV migration on top of the same decode-pool traffic.
        let arch = ModelArch::llama32_3b();
        let m = DisaggregationModel::new(
            arch.clone(),
            ParallelLayout::new(4, 1),
            ParallelLayout::new(1, 4),
        );
        for sd in [32usize, 128, 512] {
            let shape = InferenceShape::new(128, sd, DTYPE_BYTES_BF16);
            assert!(
                m.volume(shape).total() > m.colocated_volume(ParallelLayout::new(1, 4), shape),
                "sd={sd}"
            );
        }
    }

    #[test]
    fn kv_transfer_scales_with_prompt_only() {
        let m = model();
        let v1 = m.volume(InferenceShape::new(128, 128, 2));
        let v2 = m.volume(InferenceShape::new(256, 128, 2));
        let v3 = m.volume(InferenceShape::new(128, 512, 2));
        assert!((v2.kv_transfer / v1.kv_transfer - 2.0).abs() < 1e-12);
        assert_eq!(v1.kv_transfer, v3.kv_transfer);
    }
}
