//! Model-clock autoscaling — the control loop that makes fleet topology
//! an *output* of the simulation instead of an input.
//!
//! The paper's recommendation space (TP for short sequences, PP for
//! volume, hybrid needs tuning) is static; production load is not. This
//! module closes the loop inside one [`crate::fleet`] simulation: an
//! [`AutoscalePolicy`] (target queue depth and/or a rolling model-time
//! SLO percentile over a sliding window) is watched by a [`Controller`]
//! whose scale-check ticks ride the fleet's discrete-event heap and
//! emit [`ScaleDecision`]s:
//!
//! - **ScaleUp** activates a parked replica after a weight cold-start
//!   priced as per-GPU shard bytes over the interconnect
//!   ([`crate::faults::cold_start_s`] over the possibly-degraded fleet
//!   wire) — elasticity is never free;
//! - **ScaleDown** drains a replica gracefully (no new admissions,
//!   in-flight requests finish), choosing the victim with
//!   [`choose_victim`]: least loaded first, and at equal load the one
//!   whose prefix cache holds the least [`warm_prefix_value`] — a warm
//!   cache is capacity the fleet would otherwise re-prefill;
//! - **Migrate** rebalances a hot replica by shipping one live
//!   sequence's resident KV (`Sp·kv_bytes_per_token` at the migration
//!   tick) to the coolest replica via [`crate::cluster::NetModel::p2p`]
//!   — the same α–β pricing as the disaggregated prefill→decode
//!   handoff — instead of queueing behind the hot spot.
//!
//! Tick jitter draws from its own salted RNG stream
//! ([`crate::workload::AUTOSCALE_STREAM_SALT`]), so attaching a policy
//! never perturbs the arrival/length/prefix/fault streams; a policy
//! that never acts (`min_replicas == max_replicas`, unreachable
//! thresholds) leaves every simulation output bitwise-identical to the
//! static fleet.

use std::collections::VecDeque;

use crate::plan::PlanError;
use crate::server::PrefixCacheStats;
use crate::workload::{Rng64, AUTOSCALE_STREAM_SALT};

/// When and how far a fleet may change shape. Attached to a fleet with
/// [`crate::fleet::FleetSpec::with_autoscale`]; the spec's replica list
/// is the *maximum* pool (`max_replicas` must equal it), of which
/// `min_replicas` are active from t = 0 and the rest start parked.
#[derive(Debug, Clone, PartialEq)]
pub struct AutoscalePolicy {
    /// Replicas that are always provisioned (the standing fleet).
    pub min_replicas: usize,
    /// Hard ceiling — must equal the fleet spec's replica count.
    pub max_replicas: usize,
    /// Sliding-window span (model seconds) over which queue-depth and
    /// SLO signals are aggregated.
    pub window_s: f64,
    /// Scale-check cadence (model seconds); each tick lands at
    /// `interval_s` times a jitter in [0.9, 1.1) from the autoscale RNG
    /// stream, desynchronizing the control loop from the workload.
    pub interval_s: f64,
    /// Scale up when the window's mean queue depth per active replica
    /// exceeds this.
    pub scale_up_queue: f64,
    /// Scale down when the window's mean queue depth per active replica
    /// falls below this (must be `< scale_up_queue` — the deadband
    /// between them prevents flapping).
    pub scale_down_queue: f64,
    /// Optional rolling SLO trigger: scale up whenever the p95 of
    /// model-time E2E latencies completing inside the window exceeds
    /// this, regardless of queue depth (and never scale down while it
    /// does).
    pub slo_e2e_p95_s: Option<f64>,
    /// Rebalance trigger: when the spread between the hottest and
    /// coolest active replica's queue depth reaches this many requests,
    /// migrate one live sequence instead of scaling (0 disables
    /// migration).
    pub migrate_queue_gap: usize,
}

impl AutoscalePolicy {
    /// A target-queue-depth policy between `min` and `max` replicas:
    /// scale up above `target_queue` mean depth per replica, down below
    /// a quarter of it, check every `window_s / 4`, and migrate when
    /// two replicas diverge by twice the target. Refine with the struct
    /// fields or [`Self::with_slo_e2e_p95`].
    pub fn target_queue(min: usize, max: usize, target_queue: f64, window_s: f64) -> Self {
        Self {
            min_replicas: min,
            max_replicas: max,
            window_s,
            interval_s: window_s / 4.0,
            scale_up_queue: target_queue,
            scale_down_queue: target_queue / 4.0,
            slo_e2e_p95_s: None,
            migrate_queue_gap: (target_queue * 2.0).ceil() as usize,
        }
    }

    /// Add a rolling p95 E2E SLO trigger (model seconds).
    pub fn with_slo_e2e_p95(mut self, s: f64) -> Self {
        self.slo_e2e_p95_s = Some(s);
        self
    }

    /// Disable live KV migration (scale decisions only).
    pub fn without_migration(mut self) -> Self {
        self.migrate_queue_gap = 0;
        self
    }

    pub fn validate(&self) -> Result<(), PlanError> {
        if self.min_replicas < 1 || self.min_replicas > self.max_replicas {
            return Err(PlanError::AutoscaleBoundsInvalid {
                min: self.min_replicas,
                max: self.max_replicas,
            });
        }
        check_positive_finite("window seconds", self.window_s)?;
        check_positive_finite("check interval seconds", self.interval_s)?;
        check_positive_finite("scale-up queue depth", self.scale_up_queue)?;
        if !self.scale_down_queue.is_finite() || self.scale_down_queue < 0.0 {
            return Err(PlanError::AutoscaleValueInvalid {
                what: "scale-down queue depth",
                value: format!("{} (need finite, >= 0)", self.scale_down_queue),
            });
        }
        if self.scale_down_queue >= self.scale_up_queue {
            return Err(PlanError::AutoscaleValueInvalid {
                what: "scale-down queue depth",
                value: format!(
                    "{} (must be < scale-up depth {} — the deadband prevents flapping)",
                    self.scale_down_queue, self.scale_up_queue
                ),
            });
        }
        if let Some(s) = self.slo_e2e_p95_s {
            check_positive_finite("E2E p95 SLO seconds", s)?;
        }
        Ok(())
    }
}

fn check_positive_finite(what: &'static str, v: f64) -> Result<(), PlanError> {
    if v.is_finite() && v > 0.0 {
        Ok(())
    } else {
        Err(PlanError::AutoscaleValueInvalid {
            what,
            value: format!("{v} (need finite, > 0)"),
        })
    }
}

/// What the controller tells the fleet loop to do at a scale-check
/// tick. The controller decides *direction*; the fleet owns mechanism
/// (which replica spawns, which drains via [`choose_victim`], which
/// sequence ships).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleDecision {
    Hold,
    /// Activate one parked replica (after its priced cold start).
    ScaleUp,
    /// Drain one active replica (no new admissions; park when empty).
    ScaleDown,
    /// Ship one live sequence from the hottest to the coolest replica.
    Migrate,
}

/// The fleet state a scale-check tick observes (all in model time).
#[derive(Debug, Clone)]
pub struct FleetSnapshot<'a> {
    pub now_s: f64,
    /// Replicas currently routable (alive, active, not draining).
    pub active: usize,
    /// Replicas mid-cold-start (count toward capacity so one burst does
    /// not trigger a spawn per tick).
    pub pending_up: usize,
    /// Total queue depth (queued + in-flight requests) over active
    /// replicas.
    pub queue_depth_total: usize,
    /// Hottest minus coolest active replica's queue depth.
    pub hottest_gap: usize,
    /// Model-time E2E latencies of requests that finished inside the
    /// sliding window.
    pub recent_e2e_s: &'a [f64],
}

/// The autoscale control loop: owns the policy, the sliding window of
/// queue-depth samples, and the jitter RNG stream. One per simulation;
/// deterministic per (policy, seed).
#[derive(Debug, Clone)]
pub struct Controller {
    policy: AutoscalePolicy,
    rng: Rng64,
    /// (tick time, mean queue depth per active replica) samples, pruned
    /// to the sliding window.
    depth_samples: VecDeque<(f64, f64)>,
}

impl Controller {
    pub fn new(policy: AutoscalePolicy, seed: u64) -> Self {
        Self {
            policy,
            rng: Rng64::new(seed ^ AUTOSCALE_STREAM_SALT),
            depth_samples: VecDeque::new(),
        }
    }

    pub fn policy(&self) -> &AutoscalePolicy {
        &self.policy
    }

    /// Model time of the next scale-check tick: `interval_s` from `now`
    /// times a jitter in [0.9, 1.1) drawn from the autoscale stream.
    pub fn next_tick_after(&mut self, now_s: f64) -> f64 {
        now_s + self.policy.interval_s * (0.9 + 0.2 * self.rng.next_f64())
    }

    /// Mean queue depth per active replica over the current window
    /// (the signal the thresholds compare against; 0 with no samples).
    pub fn rolling_queue_depth(&self) -> f64 {
        if self.depth_samples.is_empty() {
            return 0.0;
        }
        let sum: f64 = self.depth_samples.iter().map(|&(_, d)| d).sum();
        sum / self.depth_samples.len() as f64
    }

    /// Record one tick's snapshot into the sliding window and decide.
    /// Scale-up wins over everything (an overloaded fleet rebalances by
    /// growing); migration rebalances when capacity is right but skewed;
    /// scale-down needs the window calm on *both* signals.
    pub fn tick(&mut self, snap: &FleetSnapshot<'_>) -> ScaleDecision {
        let per_replica = snap.queue_depth_total as f64 / (snap.active.max(1)) as f64;
        self.depth_samples.push_back((snap.now_s, per_replica));
        let horizon = snap.now_s - self.policy.window_s;
        while self.depth_samples.front().is_some_and(|&(t, _)| t < horizon) {
            self.depth_samples.pop_front();
        }
        let mean_depth = self.rolling_queue_depth();
        let slo_hot = match self.policy.slo_e2e_p95_s {
            Some(target) => rolling_p95(snap.recent_e2e_s) > Some(target),
            None => false,
        };
        let provisioned = snap.active + snap.pending_up;
        if (mean_depth > self.policy.scale_up_queue || slo_hot)
            && provisioned < self.policy.max_replicas
        {
            return ScaleDecision::ScaleUp;
        }
        if self.policy.migrate_queue_gap > 0
            && snap.hottest_gap >= self.policy.migrate_queue_gap
            && snap.active >= 2
        {
            return ScaleDecision::Migrate;
        }
        if mean_depth < self.policy.scale_down_queue
            && !slo_hot
            && snap.pending_up == 0
            && snap.active > self.policy.min_replicas
        {
            return ScaleDecision::ScaleDown;
        }
        ScaleDecision::Hold
    }
}

/// Nearest-rank p95 of a sample set (None when empty) — the rolling SLO
/// signal, also behind `ReplicaStats::rolling_ttft_p95_s`. A copy is
/// sorted; the windows involved are small.
pub fn rolling_p95(samples: &[f64]) -> Option<f64> {
    if samples.is_empty() {
        return None;
    }
    let mut s = samples.to_vec();
    s.sort_by(f64::total_cmp);
    let idx = ((0.95 * s.len() as f64).ceil() as usize).clamp(1, s.len()) - 1;
    Some(s[idx])
}

/// Ranking score of a replica's warm prefix cache: resident KV bytes ×
/// the cache's observed mean hit tokens per prompt. Draining a replica
/// throws this away — every future hit it would have served gets
/// re-prefilled somewhere cold — so scale-down prefers victims with the
/// least of it.
pub fn warm_prefix_value(resident_bytes: usize, stats: &PrefixCacheStats) -> f64 {
    if stats.observed == 0 {
        return 0.0;
    }
    resident_bytes as f64 * (stats.hit_tokens as f64 / stats.observed as f64)
}

/// One replica's claim to survive a scale-down.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DrainCandidate {
    pub replica: usize,
    /// Outstanding work (prompt + decode tokens still owed).
    pub load: usize,
    /// [`warm_prefix_value`] of its prefix cache (0 without one).
    pub warm_bytes: f64,
}

/// Pick the scale-down victim: least loaded first; at equal load the
/// *coldest* cache drains (never the replica whose warm prefix value is
/// highest while an equally-loaded colder one exists); index breaks
/// exact ties for determinism.
pub fn choose_victim(candidates: &[DrainCandidate]) -> Option<usize> {
    candidates
        .iter()
        .min_by(|a, b| {
            a.load
                .cmp(&b.load)
                .then(a.warm_bytes.total_cmp(&b.warm_bytes))
                .then(a.replica.cmp(&b.replica))
        })
        .map(|c| c.replica)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> AutoscalePolicy {
        AutoscalePolicy::target_queue(1, 4, 4.0, 1.0)
    }

    #[test]
    fn policy_validation_rejects_degenerate_knobs() {
        assert!(policy().validate().is_ok());
        let e = AutoscalePolicy { min_replicas: 0, ..policy() }.validate().unwrap_err();
        assert!(matches!(e, PlanError::AutoscaleBoundsInvalid { min: 0, max: 4 }));
        let e = AutoscalePolicy { min_replicas: 5, ..policy() }.validate().unwrap_err();
        assert!(matches!(e, PlanError::AutoscaleBoundsInvalid { min: 5, max: 4 }));
        assert!(AutoscalePolicy { window_s: 0.0, ..policy() }.validate().is_err());
        assert!(AutoscalePolicy { interval_s: f64::NAN, ..policy() }.validate().is_err());
        assert!(AutoscalePolicy { scale_up_queue: -1.0, ..policy() }.validate().is_err());
        // The deadband: down threshold must sit strictly below up.
        let e = AutoscalePolicy { scale_down_queue: 4.0, ..policy() }.validate().unwrap_err();
        assert!(e.to_string().contains("deadband"), "{e}");
        assert!(policy().with_slo_e2e_p95(0.0).validate().is_err());
        assert!(policy().with_slo_e2e_p95(0.5).validate().is_ok());
    }

    #[test]
    fn ticks_jitter_inside_their_band_and_are_seed_deterministic() {
        let mut a = Controller::new(policy(), 7);
        let mut b = Controller::new(policy(), 7);
        let mut c = Controller::new(policy(), 8);
        let mut differs = false;
        let mut t = 0.0;
        for _ in 0..64 {
            let (na, nb, nc) = (a.next_tick_after(t), b.next_tick_after(t), c.next_tick_after(t));
            assert_eq!(na, nb, "same seed, same jitter stream");
            differs |= na != nc;
            let interval = policy().interval_s;
            assert!(na - t >= 0.9 * interval && na - t < 1.1 * interval);
            t = na;
        }
        assert!(differs, "different seeds draw different jitter");
    }

    fn snap(now_s: f64, active: usize, depth: usize) -> FleetSnapshot<'static> {
        FleetSnapshot {
            now_s,
            active,
            pending_up: 0,
            queue_depth_total: depth,
            hottest_gap: 0,
            recent_e2e_s: &[],
        }
    }

    #[test]
    fn controller_scales_on_queue_depth_with_a_deadband() {
        let mut c = Controller::new(policy(), 1);
        // Sustained depth above target → grow, until the pool is full.
        assert_eq!(c.tick(&snap(0.25, 1, 10)), ScaleDecision::ScaleUp);
        assert_eq!(c.tick(&snap(0.50, 2, 20)), ScaleDecision::ScaleUp);
        let full = FleetSnapshot { pending_up: 2, ..snap(0.75, 2, 20) };
        assert_eq!(c.tick(&full), ScaleDecision::Hold, "cold-starting counts as capacity");
        // A calm window (old hot samples pruned) → drain back down.
        for i in 0..8 {
            let d = c.tick(&snap(2.0 + 0.25 * i as f64, 4, 0));
            if i >= 4 {
                assert_eq!(d, ScaleDecision::ScaleDown, "tick {i}");
            }
        }
        // Never below the floor.
        let mut c = Controller::new(policy(), 1);
        assert_eq!(c.tick(&snap(0.25, 1, 0)), ScaleDecision::Hold);
    }

    #[test]
    fn slo_trigger_scales_up_and_blocks_scale_down() {
        let p = policy().with_slo_e2e_p95(0.1);
        let mut c = Controller::new(p, 1);
        let slow = [0.5f64; 8];
        let hot = FleetSnapshot { recent_e2e_s: &slow, ..snap(0.25, 1, 0) };
        assert_eq!(c.tick(&hot), ScaleDecision::ScaleUp, "SLO breach grows an idle fleet");
        let hot2 = FleetSnapshot { recent_e2e_s: &slow, ..snap(0.5, 4, 0) };
        assert_eq!(c.tick(&hot2), ScaleDecision::Hold, "full pool, still hot: hold");
        let calm = FleetSnapshot { recent_e2e_s: &[0.01], ..snap(3.0, 4, 0) };
        let mut last = ScaleDecision::Hold;
        for i in 0..6 {
            last = c.tick(&FleetSnapshot { now_s: 3.0 + 0.25 * i as f64, ..calm.clone() });
        }
        assert_eq!(last, ScaleDecision::ScaleDown, "calm window drains");
    }

    #[test]
    fn migration_fires_on_queue_skew_when_capacity_is_right() {
        let mut c = Controller::new(policy(), 1);
        let skew = FleetSnapshot { hottest_gap: 8, ..snap(0.25, 2, 4) };
        assert_eq!(c.tick(&skew), ScaleDecision::Migrate);
        // Disabled migration never fires.
        let mut c = Controller::new(policy().without_migration(), 1);
        let skew = FleetSnapshot { hottest_gap: 8, ..snap(0.25, 2, 4) };
        assert_ne!(c.tick(&skew), ScaleDecision::Migrate);
        // One replica cannot rebalance with itself.
        let mut c = Controller::new(policy(), 1);
        let solo = FleetSnapshot { hottest_gap: 8, ..snap(0.25, 1, 4) };
        assert_ne!(c.tick(&solo), ScaleDecision::Migrate);
    }

    #[test]
    fn victim_selection_spares_warm_caches_at_equal_load() {
        let c = |replica, load, warm_bytes| DrainCandidate { replica, load, warm_bytes };
        assert_eq!(choose_victim(&[]), None);
        // Load dominates: the near-idle replica drains even if cold.
        assert_eq!(choose_victim(&[c(0, 100, 0.0), c(1, 2, 1e9)]), Some(1));
        // Equal load: the cold replica drains, never the warm one.
        assert_eq!(choose_victim(&[c(0, 5, 8e6), c(1, 5, 0.0)]), Some(1));
        assert_eq!(choose_victim(&[c(0, 5, 0.0), c(1, 5, 8e6)]), Some(0));
        // Exact ties resolve by index, deterministically.
        assert_eq!(choose_victim(&[c(2, 5, 1.0), c(1, 5, 1.0)]), Some(1));
    }

    #[test]
    fn warm_value_is_resident_bytes_times_hit_rate() {
        let cold = PrefixCacheStats::default();
        assert_eq!(warm_prefix_value(1 << 20, &cold), 0.0);
        let s = PrefixCacheStats { observed: 10, hit_tokens: 40, ..Default::default() };
        assert_eq!(warm_prefix_value(1000, &s), 1000.0 * 4.0);
    }

    #[test]
    fn rolling_p95_is_nearest_rank() {
        assert_eq!(rolling_p95(&[]), None);
        assert_eq!(rolling_p95(&[3.0]), Some(3.0));
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(rolling_p95(&v), Some(95.0));
    }
}
