//! One fleet replica: a priced structural engine session plus its own
//! continuous-batching scheduler (and, optionally, a prefix-cache
//! model), advanced one engine iteration at a time by the fleet's
//! discrete-event loop.
//!
//! The per-iteration logic (admission, per-token KV growth with mid-decode
//! bail-out, one `Session::step`, model-clock bookkeeping) mirrors
//! [`crate::server::Server`]'s serving loop exactly — a single-replica
//! colocated fleet reproduces `serve_poisson`'s model-time metrics
//! bitwise — but is factored so the fleet can interleave many replicas on
//! one global model clock and inject handoff arrivals mid-simulation.
//!
//! With a [`PrefixCache`] attached, admission consumes the cached-prefix
//! hint: the session prefills (and the cost model prices) only the
//! uncached suffix, the KV pool is charged only the suffix's blocks, and
//! the replica records the saved prefill seconds/bytes per request. The
//! router reads [`Replica::load_for_chain`] (a hit estimate over a
//! once-hashed prompt chain) to steer same-prefix requests back to warm
//! replicas.

use std::collections::HashMap;

use crate::engine::kv::SeqId;
use crate::engine::{Session, SequenceInput};
use crate::server::prefix_cache::chain_hashes;
use crate::server::{PrefixCache, Request, Scheduler, SchedulerConfig};
use crate::simtime::CostModel;
use crate::Result;

use super::router::ReplicaLoad;

/// Model-clock record of one request's pass through a replica. For a
/// colocated fleet this is the whole request; under disaggregation a
/// request produces one of these per pool (prefill, then decode).
#[derive(Debug, Clone)]
pub(crate) struct ReplicaDone {
    pub id: SeqId,
    pub prompt_tokens: usize,
    /// Leading prompt tokens served from the replica's prefix cache at
    /// admission (0 without a cache or on a miss).
    pub cached_tokens: usize,
    /// Model-time prefill seconds the cached prefix saved this pass.
    pub saved_prefill_s: f64,
    /// Corrected prefill communication bytes the cached prefix saved.
    pub saved_prefill_bytes: f64,
    /// Tokens this replica generated for the sequence.
    pub generated: usize,
    /// Last sampled token (the decode pool's 1-token prompt under
    /// disaggregation).
    pub last_token: i32,
    pub arrival_s: f64,
    pub admitted_s: f64,
    pub first_token_s: Option<f64>,
    pub last_token_s: f64,
    /// True when the request never entered the engine (queue overflow or
    /// session admission rejection) — such requests carry no model times,
    /// matching the serving loop's convention.
    pub rejected: bool,
    /// Prefill iterations the prompt took on this replica (1 one-shot,
    /// more under chunked prefill, 0 when rejected).
    pub prefill_chunks: usize,
    /// Model-time seconds other prompts' prefill work stole from this
    /// sequence's decode stream on this replica.
    pub interference_s: f64,
    pub error: Option<String>,
}

/// One request lost to a replica failure ([`Replica::fail`]): the fleet
/// retries it through the router, charging the first attempt's sunk
/// prefill as waste.
#[derive(Debug, Clone)]
pub(crate) struct LostRequest {
    pub id: SeqId,
    /// Model-time prefill seconds the dead replica had sunk into the
    /// request — the priced uncached suffix for admitted flights, 0 for
    /// requests that were still queued.
    pub wasted_prefill_s: f64,
}

/// A live sequence checkpointed off a replica by
/// [`Replica::migrate_out`]: the partial pass's model-clock record plus
/// everything the target replica needs to restore it mid-decode via
/// cached-context admission.
#[derive(Debug, Clone)]
pub(crate) struct MigratedSeq {
    /// The source pass (tokens generated so far, TTFT, last token) —
    /// merged with the target pass at completion, exactly like a
    /// disaggregated prefill record.
    pub done: ReplicaDone,
    /// Decode tokens still owed after the migration.
    pub remaining: usize,
    /// Cached-KV token count to ship and resubmit with: every token
    /// below the re-prefilled last one (`context + Sp + generated - 1`),
    /// so the target's decode positions continue the source's sequence
    /// bitwise.
    pub context: usize,
}

/// In-flight model-clock bookkeeping (mirror of the serving loop's
/// `ModelFlight`).
struct Flight {
    arrival_s: f64,
    admitted_s: f64,
    /// Cached-KV tokens shipped with the submission (a disaggregated
    /// handoff or a live migration; 0 on first service) — a second
    /// migration stacks on top of it.
    context: usize,
    prompt_tokens: usize,
    cached_tokens: usize,
    saved_prefill_s: f64,
    saved_prefill_bytes: f64,
    /// Tokens this replica was asked to generate (outstanding-token
    /// accounting on bail-out).
    decode_budget: usize,
    first_token_s: Option<f64>,
    last_token_s: f64,
    last_token: i32,
    generated: usize,
    /// Prefill iterations the prompt took (1 one-shot; chunked counts).
    prefill_chunks: usize,
    /// Interference seconds absorbed while decoding on this replica.
    interference_s: f64,
}

pub(crate) struct Replica<'e> {
    label: String,
    session: Session<'e>,
    scheduler: Scheduler,
    /// Prefix-cache model (shared-prefix serving) and the pricing core
    /// that values its hits.
    prefix: Option<PrefixCache>,
    cost: CostModel,
    /// Model-time arrival offset and cached-context token count of
    /// submitted-but-not-admitted requests.
    arrivals: HashMap<SeqId, (f64, usize)>,
    /// Block chain hashes of queued prompts, computed once at submission
    /// — every admission pass probes (and the eventual admit observes)
    /// this instead of rehashing the prompt. Only populated with a prefix
    /// cache attached; entries leave with their request.
    chains: HashMap<SeqId, Vec<u64>>,
    flights: HashMap<SeqId, Flight>,
    outstanding_tokens: usize,
    tokens_served: usize,
    cached_tokens_total: usize,
}

impl<'e> Replica<'e> {
    pub fn new(
        label: String,
        session: Session<'e>,
        cfg: SchedulerConfig,
        prefix: Option<PrefixCache>,
        cost: CostModel,
    ) -> Self {
        Self {
            label,
            session,
            scheduler: Scheduler::new(cfg),
            prefix,
            cost,
            arrivals: HashMap::new(),
            chains: HashMap::new(),
            flights: HashMap::new(),
            outstanding_tokens: 0,
            tokens_served: 0,
            cached_tokens_total: 0,
        }
    }

    /// The replica's model clock.
    pub fn now(&self) -> f64 {
        self.session.model_now().expect("fleet replicas run priced structural engines")
    }

    /// Whether [`Self::advance`] has work to do.
    pub fn runnable(&self) -> bool {
        !self.session.is_idle() || self.scheduler.queue_len() > 0
    }

    /// Queued + admitted requests (the router's queue-depth signal).
    pub fn queue_depth(&self) -> usize {
        self.scheduler.queue_len() + self.session.live()
    }

    pub fn load(&self) -> ReplicaLoad {
        ReplicaLoad {
            queue_depth: self.queue_depth(),
            outstanding_tokens: self.outstanding_tokens,
            prefix_hit_tokens: 0,
        }
    }

    /// Load snapshot for routing one specific request: [`Self::load`]
    /// plus the prefix cache's hit estimate for its prompt — the
    /// cache-affinity router's signal. Takes the prompt's precomputed
    /// [`crate::server::prefix_cache::chain_hashes`] chain so the router
    /// hashes each prompt once, not once per replica; the estimate is
    /// clamped like admission (never the whole prompt — one token always
    /// prefills). Read-only: routing must not mutate.
    pub fn load_for_chain(&self, chain: &[u64], prompt_len: usize) -> ReplicaLoad {
        let hit = match &self.prefix {
            Some(cache) => cache.lookup_chain(chain).min(prompt_len.saturating_sub(1)),
            None => 0,
        };
        ReplicaLoad { prefix_hit_tokens: hit, ..self.load() }
    }

    pub fn tokens_served(&self) -> usize {
        self.tokens_served
    }

    /// Total prompt tokens this replica served out of its prefix cache.
    pub fn cached_tokens_total(&self) -> usize {
        self.cached_tokens_total
    }

    /// Route a request to this replica at model time `at_s`. An idle
    /// replica's clock jumps to the arrival (the discrete-event idle
    /// skip); a busy one will pick the request up at its next iteration
    /// boundary. `context` is the cached-KV token count shipped with the
    /// request (a disaggregated decode-pool handoff; 0 otherwise) —
    /// decode iterations are priced against it. `Err` means the
    /// scheduler rejected the submission (queue full / oversized
    /// request) — the caller fails that request, not the simulation.
    pub fn submit(&mut self, req: Request, at_s: f64, context: usize) -> Result<()> {
        if self.session.is_idle() && self.scheduler.queue_len() == 0 {
            self.session.advance_model_time_to(at_s);
        }
        let id = req.id;
        // Outstanding work is prompt tokens still to prefill plus decode
        // tokens still to generate — so a prefill-pool request (decode
        // budget 1) still weighs its whole prompt with the
        // least-outstanding-tokens router.
        let tokens = req.prompt.len() + req.decode_len;
        let chain = self
            .prefix
            .as_ref()
            .map(|cache| chain_hashes(cache.config().block_tokens, &req.prompt));
        self.scheduler.submit(req)?;
        if let Some(chain) = chain {
            self.chains.insert(id, chain);
        }
        self.arrivals.insert(id, (at_s, context));
        self.outstanding_tokens += tokens;
        Ok(())
    }

    /// One scheduling-loop pass: admit whatever fits, grow/bail KV before
    /// a decode iteration, then run exactly one engine iteration. Returns
    /// every request that left the replica during the pass.
    pub fn advance(&mut self) -> Result<Vec<ReplicaDone>> {
        let mut done = Vec::new();
        // Admission (mirror of the serving loop's step 2, with the
        // prefix-cache hint shrinking the KV charge and the prefill).
        loop {
            // Raw lookup over the chain hashed once at submission:
            // `admit_next_with_cached` owns the clamp that keeps at least
            // one token prefilling.
            let cached_hint = match (&self.prefix, self.scheduler.peek()) {
                (Some(cache), Some(head)) => match self.chains.get(&head.id) {
                    Some(chain) => cache.lookup_chain(chain),
                    None => cache.lookup(&head.prompt),
                },
                _ => 0,
            };
            let Some(admitted) = self.scheduler.admit_next_with_cached(cached_hint)? else {
                break;
            };
            let req = admitted.request;
            let cached = admitted.cached_tokens;
            let id = req.id;
            let prompt_tokens = req.prompt.len();
            let decode_len = req.decode_len;
            let (arrival_s, context) = self.arrivals.remove(&id).unwrap_or((0.0, 0));
            // Range admission off the shared prompt tokens — no suffix
            // copy per admission.
            let input = SequenceInput {
                id,
                prompt: req.prompt.clone(),
                start: cached,
                max_new_tokens: decode_len,
            };
            // The cached prefix sits below the request's own context (a
            // disaggregated decode-pool handoff ships `context` tokens;
            // colocated serving has context 0): decode positions start
            // past both.
            if let Err(e) = self.session.admit_with_context(input, context + cached) {
                self.chains.remove(&id);
                self.scheduler.finish(id)?;
                self.outstanding_tokens =
                    self.outstanding_tokens.saturating_sub(prompt_tokens + decode_len);
                done.push(ReplicaDone {
                    id,
                    prompt_tokens,
                    cached_tokens: 0,
                    saved_prefill_s: 0.0,
                    saved_prefill_bytes: 0.0,
                    generated: 0,
                    last_token: 0,
                    arrival_s,
                    admitted_s: arrival_s,
                    first_token_s: None,
                    last_token_s: arrival_s,
                    rejected: true,
                    prefill_chunks: 0,
                    interference_s: 0.0,
                    error: Some(e.to_string()),
                });
                continue;
            }
            if let Some(cache) = &mut self.prefix {
                // Only admitted prompts enter the cache — a rejected
                // admission computes no KV.
                let now_s = self.session.model_now().unwrap_or(0.0);
                match self.chains.remove(&id) {
                    Some(chain) => {
                        cache.observe_chain(&chain, now_s);
                    }
                    None => {
                        cache.observe(&req.prompt, now_s);
                    }
                }
            }
            let (saved_prefill_s, saved_prefill_bytes) = if cached > 0 {
                (
                    self.cost.prefill_price(prompt_tokens)
                        - self.cost.prefill_price(prompt_tokens - cached),
                    self.cost.prefill_comm_bytes(prompt_tokens)
                        - self.cost.prefill_comm_bytes(prompt_tokens - cached),
                )
            } else {
                (0.0, 0.0)
            };
            self.cached_tokens_total += cached;
            let admitted_s = self.now().max(arrival_s);
            self.flights.insert(
                id,
                Flight {
                    arrival_s,
                    admitted_s,
                    context,
                    prompt_tokens,
                    cached_tokens: cached,
                    saved_prefill_s,
                    saved_prefill_bytes,
                    decode_budget: decode_len,
                    first_token_s: None,
                    last_token_s: admitted_s,
                    last_token: 0,
                    generated: 0,
                    prefill_chunks: 1,
                    interference_s: 0.0,
                },
            );
        }

        if self.session.is_idle() {
            if self.scheduler.queue_len() > 0 {
                // Same invariant as the serving loop: submit() already
                // rejected never-fitting requests, so an idle session with
                // a blocked head of line is a sizing bug, not load.
                anyhow::bail!(
                    "head-of-line request cannot fit replica '{}'s KV pool",
                    self.label
                );
            }
            return Ok(done);
        }

        // Pre-decode KV growth with mid-decode bail-out (step 4) — also
        // ahead of a mixed chunk+decode iteration, where the active
        // batch writes a token alongside the chunk.
        if self.session.decode_in_next_step() {
            for id in self.session.active_ids() {
                if self.scheduler.grow(id).is_ok() {
                    continue;
                }
                self.session.cancel(id);
                self.scheduler.finish(id)?;
                let f = self.flights.remove(&id).expect("active seq tracked");
                self.outstanding_tokens = self
                    .outstanding_tokens
                    .saturating_sub(f.decode_budget.saturating_sub(f.generated));
                done.push(Self::finish_flight(
                    id,
                    &f,
                    Some("KV pool exhausted mid-decode; sequence bailed out".to_string()),
                ));
            }
            if self.session.is_idle() {
                return Ok(done); // every active sequence bailed; re-admit
            }
        }

        // One engine iteration (prefill, chunk, mixed, or batched
        // decode; step 5).
        let outcome = self.session.step()?;
        let now = self.now();
        for &(victim, stretch) in &outcome.interference {
            if let Some(f) = self.flights.get_mut(&victim) {
                f.interference_s += stretch;
            }
        }
        if let Some((owner, chunks)) = outcome.chunk_owner {
            if let Some(f) = self.flights.get_mut(&owner) {
                f.prefill_chunks = chunks as usize;
            }
        }
        for e in &outcome.events {
            if let Some(f) = self.flights.get_mut(&e.seq) {
                f.generated += 1;
                f.last_token = e.token;
                if f.first_token_s.is_none() {
                    f.first_token_s = Some(now);
                    // First token = prefill done: the prompt's share of
                    // the outstanding work retires with it.
                    self.outstanding_tokens =
                        self.outstanding_tokens.saturating_sub(f.prompt_tokens);
                }
                f.last_token_s = now;
                self.tokens_served += 1;
                self.outstanding_tokens = self.outstanding_tokens.saturating_sub(1);
            }
        }
        for id in &outcome.finished {
            self.scheduler.finish(*id)?;
            let f = self.flights.remove(id).expect("finished seq tracked");
            done.push(Self::finish_flight(*id, &f, None));
        }
        Ok(done)
    }

    /// Kill the replica: cancel every admitted sequence, drop the whole
    /// queue, and restart the prefix cache cold (a recovered replica has
    /// lost its KV pool's contents along with its weights). Returns the
    /// lost requests — admitted flights first (by id, for determinism:
    /// the flight map's iteration order is not), then the queue in FCFS
    /// order — for the fleet to retry through the router. The session
    /// itself survives with its model clock intact; the fleet gates
    /// re-use on the recovery event.
    pub fn fail(&mut self, kv_bytes_per_token: usize) -> Result<Vec<LostRequest>> {
        let mut lost = Vec::new();
        let mut ids: Vec<SeqId> = self.flights.keys().copied().collect();
        ids.sort_unstable();
        for id in ids {
            let f = self.flights.remove(&id).expect("listed flight exists");
            self.session.cancel(id);
            self.scheduler.finish(id)?;
            // The sunk cost is the suffix this replica actually
            // prefilled (the cached prefix cost nothing to skip); a
            // flight still waiting on its prefill step has sunk nothing.
            let wasted = if f.first_token_s.is_some() {
                self.cost.prefill_price(f.prompt_tokens - f.cached_tokens)
            } else {
                0.0
            };
            lost.push(LostRequest { id, wasted_prefill_s: wasted });
        }
        // Queued requests sank no prefill; their enqueue instants are
        // dropped here because the fleet anchors E2E/goodput on the
        // model-clock arrival the router preserved (`Pending.arrival_s`),
        // not on host instants.
        for (req, _enqueued_at) in self.scheduler.drain_waiting() {
            lost.push(LostRequest { id: req.id, wasted_prefill_s: 0.0 });
        }
        self.arrivals.clear();
        self.chains.clear();
        self.outstanding_tokens = 0;
        if let Some(cache) = self.prefix.take() {
            self.prefix = Some(PrefixCache::new(cache.config(), kv_bytes_per_token));
        }
        Ok(lost)
    }

    /// Live sequences eligible for KV migration — admitted, mid-decode
    /// (first token out, budget not exhausted) — most-remaining-work
    /// first (those benefit most from moving), ids breaking ties for
    /// determinism over the flight map's arbitrary order.
    pub fn migration_candidates(&self) -> Vec<SeqId> {
        let mut c: Vec<(usize, SeqId)> = self
            .flights
            .iter()
            .filter(|(_, f)| {
                f.first_token_s.is_some() && f.generated >= 1 && f.generated < f.decode_budget
            })
            .map(|(&id, f)| (f.decode_budget - f.generated, id))
            .collect();
        c.sort_unstable_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        c.into_iter().map(|(_, id)| id).collect()
    }

    /// Checkpoint a live mid-decode sequence off this replica: cancel it
    /// in the session, free its scheduler blocks, and return everything
    /// the fleet needs to restore it elsewhere ([`MigratedSeq`]). The
    /// target resubmits a 1-token prompt (the last sampled token) over
    /// `context` cached-KV tokens, so its decode positions — and hence
    /// every remaining structural token — continue the unmigrated
    /// sequence bitwise. `None` when the sequence is not migratable
    /// (unknown, still prefilling, or already finished).
    pub fn migrate_out(&mut self, id: SeqId) -> Result<Option<MigratedSeq>> {
        let migratable = self.flights.get(&id).is_some_and(|f| {
            f.first_token_s.is_some() && f.generated >= 1 && f.generated < f.decode_budget
        });
        if !migratable {
            return Ok(None);
        }
        let f = self.flights.remove(&id).expect("checked above");
        self.session.cancel(id);
        self.scheduler.finish(id)?;
        let remaining = f.decode_budget - f.generated;
        // The prompt's share retired at first token; only the unproduced
        // decode tail leaves with the sequence.
        self.outstanding_tokens = self.outstanding_tokens.saturating_sub(remaining);
        let context = f.context + f.prompt_tokens + f.generated - 1;
        Ok(Some(MigratedSeq {
            done: Self::finish_flight(id, &f, None),
            remaining,
            context,
        }))
    }

    /// Warm prefix-cache value of this replica
    /// ([`crate::autoscale::warm_prefix_value`]: resident KV bytes ×
    /// observed hit rate) — the capacity a scale-down would throw away.
    /// 0 without a cache.
    pub fn warm_prefix_value(&self) -> f64 {
        match &self.prefix {
            Some(cache) => {
                crate::autoscale::warm_prefix_value(cache.resident_bytes(), &cache.stats())
            }
            None => 0.0,
        }
    }

    /// Re-activate a parked (previously drained) replica: the weight
    /// reload behind the scale-up cold start also means its prefix
    /// cache comes back empty. Flights and queue are empty by
    /// construction (a replica only parks once drained).
    pub fn reset_cold(&mut self, kv_bytes_per_token: usize) {
        debug_assert!(!self.runnable(), "only a drained replica re-activates");
        if let Some(cache) = self.prefix.take() {
            self.prefix = Some(PrefixCache::new(cache.config(), kv_bytes_per_token));
        }
    }

    fn finish_flight(id: SeqId, f: &Flight, error: Option<String>) -> ReplicaDone {
        ReplicaDone {
            id,
            prompt_tokens: f.prompt_tokens,
            cached_tokens: f.cached_tokens,
            saved_prefill_s: f.saved_prefill_s,
            saved_prefill_bytes: f.saved_prefill_bytes,
            generated: f.generated,
            last_token: f.last_token,
            arrival_s: f.arrival_s,
            admitted_s: f.admitted_s,
            first_token_s: f.first_token_s,
            last_token_s: f.last_token_s,
            rejected: false,
            prefill_chunks: f.prefill_chunks,
            interference_s: f.interference_s,
            error,
        }
    }
}
