//! Fleet-scale serving simulator — N priced engine replicas behind a
//! router, on one deterministic model clock.
//!
//! The paper's recommendations (TP for short sequences, PP for volume,
//! hybrid needs tuning) are per-replica; a production service asks the
//! *fleet-level* question: how many replicas, in which layouts, behind
//! which router, serve a traffic mix within SLO. [`FleetSpec`] composes
//! validated [`DeploymentPlan`]s into a fleet — homogeneous or
//! heterogeneous colocated replicas ([`FleetSpec::colocated`] /
//! [`FleetSpec::add_replicas`]), or disaggregated prefill/decode pools
//! ([`FleetSpec::disaggregated`], the DistServe-style split
//! `analysis::disagg` prices statically) — and
//! [`FleetSpec::simulate`] runs a discrete-event simulation of an
//! open-loop [`WorkloadSpec`] against it:
//!
//! - every replica is a priced structural engine ([`crate::simtime`]
//!   model clock), advanced one engine iteration at a time; the fleet
//!   loop interleaves replicas in global model-time order, so metrics are
//!   bitwise-deterministic per workload seed;
//! - a single-replica colocated fleet reproduces
//!   [`crate::server::Server::serve_poisson`]'s model-time metrics
//!   bitwise (same arrival stream, same iteration loop, same formulas);
//! - under disaggregation, each request prefills in the prefill pool,
//!   ships its KV cache once (`Sp · kv_bytes_per_token`, priced through
//!   [`NetModel::p2p`] over NVLink or InfiniBand depending on whether the
//!   pools share a node on the fleet's node grid), then decodes in the
//!   decode pool with every decode iteration priced against the shipped
//!   `Sp`-token context (cached-context admission,
//!   [`crate::engine::Session::admit_with_context`]) — so disaggregated
//!   vs colocated TTFT/TPOT/E2E percentiles come from the same
//!   simulation. (The decode pool's KV *block* accounting still charges
//!   only the 1-token handoff prompt plus growth — modeling shipped
//!   blocks in the scheduler is the "KV migration under load" roadmap
//!   item.);
//! - [`capacity_sweep`] runs a list of candidate fleets over one workload
//!   and [`cheapest`] picks the fewest-GPU fleet meeting an [`SloTarget`]
//!   — the capacity-planning loop as a library primitive;
//! - [`FleetSpec::with_faults`] attaches a seeded [`crate::faults`]
//!   injection spec — replica churn (failed replicas drop their queues
//!   and in-flight requests, which retry through the router with their
//!   cache warmth lost; recovery pays a weight-reload cold start),
//!   scripted outages, straggler replicas (a degraded per-replica α–β
//!   calibration), and time-boxed link-degradation windows on the fleet
//!   wire — and [`FleetSummary::goodput`] scores the result as
//!   completed-within-SLO ÷ offered. [`crate::faults::FaultSpec::none`]
//!   (the default) leaves every output bitwise-identical to a fault-free
//!   fleet.

mod replica;
mod router;

pub use router::{ReplicaLoad, Router, RouterPolicy, CACHE_AFFINITY_HIT_WEIGHT};

use std::cmp::{Ordering, Reverse};
use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::time::Duration;

use crate::autoscale::{
    choose_victim, AutoscalePolicy, Controller, DrainCandidate, FleetSnapshot, ScaleDecision,
};
use crate::cluster::NetModel;
use crate::comm::{CollectiveKind, Stage, TraceSummary};
use crate::engine::Engine;
use crate::faults::{cold_start_s, ChurnProcess, FaultSpec};
use crate::model::ModelArch;
use crate::perfmodel::Calibration;
use crate::plan::{DeploymentPlan, PlanError};
use crate::server::prefix_cache::chain_hashes;
use crate::server::{
    ModelRequestTimes, ModelServeSummary, PrefixCache, PrefixCacheConfig, PromptTokens,
    Request, RequestMetrics, SchedulerConfig, ServeSummary,
};
use crate::workload::WorkloadSpec;

use replica::{Replica, ReplicaDone};

/// What a replica does in the fleet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaRole {
    /// Colocated serving: prefill and decode on the same replica.
    Serve,
    /// Disaggregated prefill pool member (produces the first token, then
    /// hands the KV cache off).
    Prefill,
    /// Disaggregated decode pool member (receives the KV cache, produces
    /// the remaining tokens).
    Decode,
}

impl ReplicaRole {
    pub fn label(&self) -> &'static str {
        match self {
            Self::Serve => "serve",
            Self::Prefill => "prefill",
            Self::Decode => "decode",
        }
    }
}

#[derive(Debug, Clone)]
struct ReplicaSpec {
    plan: DeploymentPlan,
    role: ReplicaRole,
}

/// A validated fleet: replicas (each its own [`DeploymentPlan`] layout)
/// plus router policy, per-replica scheduler config, and the node grid
/// replicas pack onto (for KV-handoff link classification).
#[derive(Debug, Clone)]
pub struct FleetSpec {
    replicas: Vec<ReplicaSpec>,
    router: RouterPolicy,
    scheduler: SchedulerConfig,
    gpus_per_node: usize,
    /// Per-replica prefix-cache model (None: no caching, every prompt
    /// prefills in full and [`RouterPolicy::CacheAffinity`] degenerates
    /// to least-outstanding-tokens).
    prefix_cache: Option<PrefixCacheConfig>,
    /// Fault-injection spec ([`FaultSpec::none`] by default — a healthy
    /// fleet, bitwise-identical to a spec without the field).
    faults: FaultSpec,
    /// Elasticity policy (`None`: a static fleet, every replica active
    /// for the whole run). With a policy, the replica list above is the
    /// *maximum* pool; `min_replicas` of it are active at t = 0.
    autoscale: Option<AutoscalePolicy>,
}

/// Fleet members must serve the same model structurally; numeric plans
/// hold real single-sequence PJRT state and cannot be replicated.
fn check_member(base: Option<&ModelArch>, plan: &DeploymentPlan) -> Result<(), PlanError> {
    if plan.is_numeric() {
        return Err(PlanError::FleetNumericUnsupported);
    }
    if let Some(b) = base {
        if b.name != plan.arch().name {
            return Err(PlanError::FleetArchMismatch {
                base: b.name.clone(),
                other: plan.arch().name.clone(),
            });
        }
    }
    Ok(())
}

impl FleetSpec {
    /// A colocated fleet of `n` identical replicas of `plan`
    /// (the [`DeploymentPlan::fleet`] verb).
    pub fn colocated(plan: &DeploymentPlan, n: usize) -> Result<Self, PlanError> {
        if n == 0 {
            return Err(PlanError::ZeroDegree { axis: "fleet replica count" });
        }
        check_member(None, plan)?;
        Ok(Self {
            replicas: (0..n)
                .map(|_| ReplicaSpec { plan: plan.clone(), role: ReplicaRole::Serve })
                .collect(),
            router: RouterPolicy::RoundRobin,
            scheduler: SchedulerConfig::default(),
            gpus_per_node: 4,
            prefix_cache: None,
            faults: FaultSpec::none(),
            autoscale: None,
        })
    }

    /// A disaggregated fleet: `n_prefill` replicas of `prefill` feeding
    /// `n_decode` replicas of `decode` through per-request KV-cache
    /// handoffs.
    pub fn disaggregated(
        prefill: &DeploymentPlan,
        n_prefill: usize,
        decode: &DeploymentPlan,
        n_decode: usize,
    ) -> Result<Self, PlanError> {
        if n_prefill == 0 {
            return Err(PlanError::DisaggPoolMissing { pool: "prefill" });
        }
        if n_decode == 0 {
            return Err(PlanError::DisaggPoolMissing { pool: "decode" });
        }
        check_member(None, prefill)?;
        check_member(Some(prefill.arch()), decode)?;
        let mut replicas = Vec::with_capacity(n_prefill + n_decode);
        replicas.extend((0..n_prefill).map(|_| ReplicaSpec {
            plan: prefill.clone(),
            role: ReplicaRole::Prefill,
        }));
        replicas.extend(
            (0..n_decode)
                .map(|_| ReplicaSpec { plan: decode.clone(), role: ReplicaRole::Decode }),
        );
        Ok(Self {
            replicas,
            router: RouterPolicy::RoundRobin,
            scheduler: SchedulerConfig::default(),
            gpus_per_node: 4,
            prefix_cache: None,
            faults: FaultSpec::none(),
            autoscale: None,
        })
    }

    /// Grow a colocated fleet with `n` replicas of another (same-model)
    /// layout — heterogeneous fleets.
    pub fn add_replicas(mut self, plan: &DeploymentPlan, n: usize) -> Result<Self, PlanError> {
        if self.is_disaggregated() {
            return Err(PlanError::FleetMixedRoles);
        }
        if n == 0 {
            return Err(PlanError::ZeroDegree { axis: "fleet replica count" });
        }
        check_member(Some(self.arch()), plan)?;
        self.replicas.extend(
            (0..n).map(|_| ReplicaSpec { plan: plan.clone(), role: ReplicaRole::Serve }),
        );
        Ok(self)
    }

    /// Select the router policy (default round-robin).
    pub fn with_router(mut self, policy: RouterPolicy) -> Self {
        self.router = policy;
        self
    }

    /// Per-replica scheduler configuration (KV pool, queue, batch).
    pub fn with_scheduler(mut self, cfg: SchedulerConfig) -> Self {
        self.scheduler = cfg;
        self
    }

    /// Node grid the replicas pack onto, in spec order (default 4 GPUs
    /// per node, the paper's testbed shape). Determines whether a
    /// prefill→decode KV handoff rides NVLink or InfiniBand.
    pub fn with_gpus_per_node(mut self, gpus_per_node: usize) -> Result<Self, PlanError> {
        if gpus_per_node == 0 {
            return Err(PlanError::ZeroDegree { axis: "GPUs per node" });
        }
        self.gpus_per_node = gpus_per_node;
        Ok(self)
    }

    /// Attach a prefix-cache model to every replica (block-granular LRU
    /// with a byte budget — see [`crate::server::PrefixCache`]). Requests
    /// whose leading tokens are resident on their replica prefill only
    /// the uncached suffix; pair with [`RouterPolicy::CacheAffinity`] to
    /// steer same-prefix traffic back to warm replicas.
    pub fn with_prefix_cache(mut self, cfg: PrefixCacheConfig) -> Result<Self, PlanError> {
        if cfg.block_tokens == 0 {
            return Err(PlanError::ZeroDegree { axis: "prefix-cache block tokens" });
        }
        if cfg.capacity_bytes == 0 {
            return Err(PlanError::ZeroDegree { axis: "prefix-cache capacity bytes" });
        }
        self.prefix_cache = Some(cfg);
        Ok(self)
    }

    /// Attach a fault-injection spec — replica churn (MTBF/MTTR),
    /// scripted outages, straggler replicas, and link-degradation
    /// windows (see [`crate::faults::FaultSpec`]). Validated against the
    /// current replica count; [`FaultSpec::none`] (the default) leaves
    /// every simulation output bitwise-identical to a fault-free fleet.
    pub fn with_faults(mut self, faults: FaultSpec) -> Result<Self, PlanError> {
        faults.validate(self.replicas.len())?;
        self.faults = faults;
        Ok(self)
    }

    /// Attach a model-clock autoscale policy ([`crate::autoscale`]).
    /// The spec's replica list becomes the *maximum* pool — the policy's
    /// `max_replicas` must equal it — of which `min_replicas` are active
    /// from t = 0; the rest park until the controller spawns them
    /// (paying the weight cold-start). Colocated fleets only: elastic
    /// disaggregated pools are a roadmap follow-on. A policy that never
    /// acts leaves every output bitwise-identical to the static fleet.
    pub fn with_autoscale(mut self, policy: AutoscalePolicy) -> Result<Self, PlanError> {
        if self.is_disaggregated() {
            return Err(PlanError::AutoscaleDisaggUnsupported);
        }
        policy.validate()?;
        if policy.max_replicas != self.replicas.len() {
            return Err(PlanError::AutoscaleReplicaMismatch {
                max_replicas: policy.max_replicas,
                replicas: self.replicas.len(),
            });
        }
        self.autoscale = Some(policy);
        Ok(self)
    }

    pub fn autoscale(&self) -> Option<&AutoscalePolicy> {
        self.autoscale.as_ref()
    }

    pub fn faults(&self) -> &FaultSpec {
        &self.faults
    }

    pub fn prefix_cache(&self) -> Option<PrefixCacheConfig> {
        self.prefix_cache
    }

    pub fn router(&self) -> RouterPolicy {
        self.router
    }

    pub fn scheduler(&self) -> SchedulerConfig {
        self.scheduler
    }

    pub fn gpus_per_node(&self) -> usize {
        self.gpus_per_node
    }

    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    pub fn is_disaggregated(&self) -> bool {
        self.replicas.iter().any(|r| r.role != ReplicaRole::Serve)
    }

    /// The fleet's model (all members agree by construction).
    pub fn arch(&self) -> &ModelArch {
        self.replicas[0].plan.arch()
    }

    /// Total GPUs across every replica.
    pub fn total_gpus(&self) -> usize {
        self.replicas.iter().map(|r| r.plan.layout().world_size()).sum()
    }

    /// Human-readable identity, e.g.
    /// `2x Llama-3.1-8B TP=2 PP=1 [round-robin]` or
    /// `prefill 1x ... TP=4 PP=1 + decode 1x ... TP=1 PP=4 [least-tokens]`.
    pub fn label(&self) -> String {
        let mut parts: Vec<String> = Vec::new();
        let mut i = 0;
        while i < self.replicas.len() {
            let cur = &self.replicas[i];
            let mut j = i;
            while j < self.replicas.len()
                && self.replicas[j].role == cur.role
                && self.replicas[j].plan.label() == cur.plan.label()
            {
                j += 1;
            }
            let prefix = match cur.role {
                ReplicaRole::Serve => String::new(),
                ReplicaRole::Prefill => "prefill ".to_string(),
                ReplicaRole::Decode => "decode ".to_string(),
            };
            parts.push(format!("{prefix}{}x {}", j - i, cur.plan.label()));
            i = j;
        }
        let pfx = if self.prefix_cache.is_some() { " +pfx" } else { "" };
        let flt = if self.faults.is_none() { "" } else { " +faults" };
        let aut = match &self.autoscale {
            Some(p) => format!(" +auto[{}..{}]", p.min_replicas, p.max_replicas),
            None => String::new(),
        };
        format!("{} [{}{pfx}{flt}{aut}]", parts.join(" + "), self.router.label())
    }

    /// Run the fleet against an open-loop workload. Deterministic per
    /// `seed`: the same spec, workload, and seed reproduce every metric
    /// bitwise.
    pub fn simulate(&self, workload: &WorkloadSpec, seed: u64) -> crate::Result<FleetSummary> {
        self.faults.validate(self.replicas.len())?;
        let timed = workload.generate(seed)?;
        let total_requests = timed.len();
        let n = self.replicas.len();
        let roles: Vec<ReplicaRole> = self.replicas.iter().map(|r| r.role).collect();
        let serve_pool: Vec<usize> =
            (0..n).filter(|&i| roles[i] != ReplicaRole::Decode).collect();
        let decode_pool: Vec<usize> =
            (0..n).filter(|&i| roles[i] == ReplicaRole::Decode).collect();
        let disagg = !decode_pool.is_empty();

        // Replicas pack onto the fleet node grid in spec order; a KV
        // handoff crosses nodes when the pools' lead GPUs land on
        // different nodes.
        let mut offsets = Vec::with_capacity(n);
        let mut off = 0usize;
        for r in &self.replicas {
            offsets.push(off);
            off += r.plan.layout().world_size();
        }
        let nodes: Vec<usize> = offsets.iter().map(|&o| o / self.gpus_per_node).collect();
        // A straggler replica serves through a degraded calibration — its
        // plan rebuilt with `NetModel::degraded(factor)` — so engine
        // pricing, the replica's cost model, and its KV-handoff wire all
        // slow down together. Factor 1.0 (the default) keeps the
        // original plan, bitwise.
        let plans: Vec<DeploymentPlan> = self
            .replicas
            .iter()
            .enumerate()
            .map(|(i, r)| {
                let f = self.faults.straggler_factor(i);
                if f == 1.0 {
                    r.plan.clone()
                } else {
                    let cal = r.plan.cost_model().cal;
                    r.plan
                        .clone()
                        .with_calibration(Calibration { net: cal.net.degraded(f), ..cal })
                }
            })
            .collect();
        let nets: Vec<NetModel> = plans.iter().map(|p| p.cost_model().cal.net).collect();
        let kv_per_token: Vec<usize> = plans
            .iter()
            .map(|p| p.arch().kv_bytes_per_token(p.shape().dtype_bytes))
            .collect();

        let mut engines: Vec<Engine> =
            plans.iter().map(|p| p.engine()).collect::<crate::Result<Vec<_>>>()?;
        // Fleet accounting only ever reads the folded trace summary
        // (`traced_comm_bytes` below), so fold each `CommRecord` at
        // record time instead of retaining a per-record Vec that grows
        // with every priced iteration of every replica.
        for e in &engines {
            e.trace().set_summary_only(true);
        }

        let mut heap: BinaryHeap<Reverse<Event>> = BinaryHeap::with_capacity(timed.len());
        let mut next_seq = 0u64;
        for t in timed {
            heap.push(Reverse(Event {
                at: t.at_s,
                seq: next_seq,
                kind: EventKind::Arrival(t.request),
            }));
            next_seq += 1;
        }

        // Fault machinery. Churn draws come from a per-replica stream of
        // the fault RNG (salted off the workload seed), consumed in event
        // order — deterministic per seed, and independent of the
        // arrival/length/prefix streams. Scripted outages pre-schedule
        // their Fail/Recover pairs; recovery always pays the weight
        // cold-start over the (possibly degraded) fleet wire.
        let mut alive = vec![true; n];
        let mut down_until = vec![0.0f64; n];
        let mut stranded: Vec<u64> = Vec::new();
        let mut churn_procs: Vec<Option<ChurnProcess>> =
            (0..n).map(|i| self.faults.churn.map(|c| ChurnProcess::new(seed, i, c))).collect();
        for (i, proc) in churn_procs.iter_mut().enumerate() {
            if let Some(p) = proc {
                heap.push(Reverse(Event {
                    at: p.time_to_failure(),
                    seq: next_seq,
                    kind: EventKind::Fail { replica: i, churned: true },
                }));
                next_seq += 1;
            }
        }
        for o in &self.faults.outages {
            heap.push(Reverse(Event {
                at: o.at_s,
                seq: next_seq,
                kind: EventKind::Fail { replica: o.replica, churned: false },
            }));
            next_seq += 1;
            let repair_at = o.at_s + o.down_s;
            let wire = nets[o.replica].degraded(self.faults.wire_factor(repair_at));
            let recover_at = repair_at
                + cold_start_s(self.arch(), plans[o.replica].shape().dtype_bytes, &wire);
            down_until[o.replica] = down_until[o.replica].max(recover_at);
            heap.push(Reverse(Event {
                at: recover_at,
                seq: next_seq,
                kind: EventKind::Recover { replica: o.replica, churned: false },
            }));
            next_seq += 1;
        }

        // Elasticity machinery. With an autoscale policy, replicas
        // `0..min` start active and the rest park; controller
        // scale-check ticks ride the event heap (jitter from the
        // autoscale RNG stream — arrivals/lengths/prefixes/faults are
        // unperturbed) and every action is priced in model time: a
        // scale-up pays the weight cold-start over the fleet wire, a
        // migration ships live KV through `NetModel::p2p`. Without a
        // policy no state ever changes and no tick is scheduled —
        // bitwise-identical to the pre-autoscale loop.
        let mut states: Vec<ReplState> = match &self.autoscale {
            Some(p) => (0..n)
                .map(|i| if i < p.min_replicas { ReplState::Active } else { ReplState::Parked })
                .collect(),
            None => vec![ReplState::Active; n],
        };
        // The serve-pool routing mask: alive AND active (draining
        // replicas finish their work but admit nothing new).
        let mut routable: Vec<bool> =
            (0..n).map(|i| alive[i] && states[i] == ReplState::Active).collect();
        let mut controller = self.autoscale.clone().map(|p| Controller::new(p, seed));
        if let Some(ctl) = controller.as_mut() {
            heap.push(Reverse(Event {
                at: ctl.next_tick_after(0.0),
                seq: next_seq,
                kind: EventKind::ScaleTick,
            }));
            next_seq += 1;
        }
        // Provisioned-capacity accounting: a replica's clock runs from
        // activation (the scale-up decision — GPUs are held while the
        // weights stream in) to park or end-of-run.
        let mut prov_start: Vec<Option<f64>> = states
            .iter()
            .map(|s| if *s == ReplState::Parked { None } else { Some(0.0) })
            .collect();
        let mut provisioned_s = vec![0.0f64; n];
        let mut cold_starts = 0usize;
        let mut cold_start_total_s = 0.0f64;
        let mut migrations = 0usize;
        let mut kv_migration_bytes = 0.0f64;
        let mut kv_migration_s = 0.0f64;
        // Per-replica (tick time, queue depth) samples behind the
        // rolling-window signals reported in `ReplicaStats`.
        let mut depth_samples: Vec<Vec<(f64, usize)>> = vec![Vec::new(); n];

        let mut pending: HashMap<u64, Pending> = HashMap::new();
        let mut completed: Vec<FleetRequestMetrics> = Vec::new();
        // Rolling E2E window behind the controller's SLO signal,
        // maintained incrementally: each tick folds in only the
        // completions recorded since the previous tick and retires the
        // aged-out head, instead of rescanning every completed request
        // (which made tick cost grow linearly over a long run). Entries
        // are (finished_at_s, e2e_s) in completion order.
        let mut e2e_window: VecDeque<(f64, f64)> = VecDeque::new();
        let mut e2e_scanned = 0usize;
        let mut stats: Vec<ReplicaStats> = self
            .replicas
            .iter()
            .enumerate()
            .map(|(i, r)| ReplicaStats {
                label: format!("{}#{i} {}", r.role.label(), r.plan.label()),
                role: r.role,
                gpus: r.plan.layout().world_size(),
                assigned: 0,
                max_depth: 0,
                tokens: 0,
                cached_tokens: 0,
                provisioned_s: 0.0,
                rolling_queue_depth: 0.0,
                rolling_ttft_p95_s: 0.0,
            })
            .collect();
        let mut kv_total_bytes = 0.0f64;
        let mut kv_total_s = 0.0f64;
        // DES loop iterations (event deliveries + replica advances): a
        // deterministic measure of simulation work, and the numerator
        // the CLI's advisory events/sec rate is computed from.
        let mut events: u64 = 0;

        {
            let mut replicas: Vec<Replica<'_>> = engines
                .iter_mut()
                .enumerate()
                .map(|(i, e)| {
                    Replica::new(
                        stats[i].label.clone(),
                        e.session(),
                        self.scheduler,
                        self.prefix_cache.map(|cfg| PrefixCache::new(cfg, kv_per_token[i])),
                        plans[i].cost_model(),
                    )
                })
                .collect();
            let mut arrival_router = Router::new(self.router);
            let mut handoff_router = Router::new(self.router);
            // Cache-affinity needs a per-(replica, request) hit estimate;
            // the other policies route on the plain load snapshot.
            let estimate_hits = self.router.wants_prefix_estimates();
            // The clock index replaces the per-iteration `min_by` rescan
            // over all replicas; it is re-synced at every point a
            // replica's clock or runnability can change. All replicas
            // start idle, so the index starts empty.
            let mut clocks = ClockIndex::new(n);
            let mut scratch = RouteScratch::default();

            loop {
                // Earliest replica with work, by (model clock, index).
                let busy: Option<(usize, f64)> = clocks.min();
                // Deliver the next event iff it precedes every pending
                // iteration; otherwise run the earliest iteration (events
                // are delivered at iteration boundaries, exactly like the
                // single-replica serving loop's arrival feed).
                let deliver = match (heap.peek(), busy) {
                    (Some(Reverse(ev)), Some((_, now))) => ev.at <= now,
                    (Some(_), None) => true,
                    (None, Some(_)) => false,
                    (None, None) => break,
                };
                events += 1;
                if deliver {
                    let Reverse(ev) = heap.pop().expect("deliver branch peeked an event");
                    match ev.kind {
                        EventKind::Arrival(req) => {
                            // Hash the prompt's block chain once per
                            // arrival; every replica probe reuses it.
                            let chain = match (estimate_hits, self.prefix_cache) {
                                (true, Some(c)) => Some(chain_hashes(c.block_tokens, &req.prompt)),
                                _ => None,
                            };
                            scratch.snapshot(&serve_pool, &routable, |i| match &chain {
                                Some(c) => replicas[i].load_for_chain(c, req.prompt.len()),
                                None => replicas[i].load(),
                            });
                            let pick = arrival_router
                                .route_masked(&scratch.loads, &scratch.live)
                                .map(|slot| serve_pool[slot]);
                            let id = req.id;
                            pending.insert(
                                id,
                                Pending {
                                    // An `Arc` bump, not a token copy: a
                                    // fault-injection retry rebuilds the
                                    // Request from these shared tokens.
                                    prompt: req.prompt.clone(),
                                    arrival_s: ev.at,
                                    chain,
                                    attempt: 0,
                                    retries: 0,
                                    wasted_prefill_s: 0.0,
                                    prompt_tokens: req.prompt.len(),
                                    decode_len: req.decode_len,
                                    replica: pick.unwrap_or(0),
                                    decode_replica: None,
                                    prefill: None,
                                    kv_bytes: 0.0,
                                    kv_s: 0.0,
                                },
                            );
                            let Some(pick) = pick else {
                                // Every serve replica is down: park the
                                // request until a recovery re-routes it.
                                stranded.push(id);
                                continue;
                            };
                            // Under disaggregation the prefill pool only
                            // produces the first token.
                            let sub = if disagg {
                                Request { id, prompt: req.prompt, decode_len: 1 }
                            } else {
                                req
                            };
                            let submitted = replicas[pick].submit(sub, ev.at, 0);
                            refresh_clock(&mut clocks, &replicas, pick);
                            if let Err(e) = submitted {
                                let p = pending.remove(&id).expect("just inserted");
                                completed.push(FleetRequestMetrics {
                                    request_id: id,
                                    replica: pick,
                                    decode_replica: None,
                                    prompt_tokens: p.prompt_tokens,
                                    generated_tokens: 0,
                                    cached_prompt_tokens: 0,
                                    saved_prefill_s: 0.0,
                                    saved_prefill_bytes: 0.0,
                                    kv_transfer_bytes: 0.0,
                                    kv_transfer_s: 0.0,
                                    retries: p.retries,
                                    wasted_prefill_s: p.wasted_prefill_s,
                                    prefill_chunks: 0,
                                    interference_s: 0.0,
                                    model: None,
                                    error: Some(e.to_string()),
                                });
                            } else {
                                stats[pick].assigned += 1;
                                stats[pick].max_depth =
                                    stats[pick].max_depth.max(replicas[pick].queue_depth());
                            }
                        }
                        EventKind::Handoff { id, token, remaining, context, replica, attempt } => {
                            // A retry bumped the attempt epoch: this KV
                            // shipment belongs to a dead attempt — drop it.
                            if pending.get(&id).map(|p| p.attempt) != Some(attempt) {
                                continue;
                            }
                            if !alive[replica] {
                                // The decode target died while the KV was
                                // on the wire: the shipped blocks are gone
                                // with it. Retry the request from scratch.
                                let p = pending.get_mut(&id).expect("attempt matched");
                                p.attempt += 1;
                                p.retries += 1;
                                if let Some(pf) = p.prefill.take() {
                                    p.wasted_prefill_s += plans[p.replica]
                                        .cost_model()
                                        .prefill_price(pf.prompt_tokens - pf.cached_tokens);
                                }
                                p.decode_replica = None;
                                route_retry(
                                    id,
                                    ev.at,
                                    &mut replicas,
                                    &mut clocks,
                                    &mut scratch,
                                    &serve_pool,
                                    &routable,
                                    &mut arrival_router,
                                    &mut pending,
                                    &mut stats,
                                    &mut completed,
                                    &mut stranded,
                                    disagg,
                                );
                                continue;
                            }
                            let req =
                                Request { id, prompt: vec![token].into(), decode_len: remaining };
                            let submitted = replicas[replica].submit(req, ev.at, context);
                            refresh_clock(&mut clocks, &replicas, replica);
                            if let Err(e) = submitted {
                                let p = pending.remove(&id).expect("handoff tracked");
                                let pf = p.prefill.as_ref().expect("prefill preceded handoff");
                                completed.push(FleetRequestMetrics {
                                    request_id: id,
                                    replica: p.replica,
                                    decode_replica: p.decode_replica,
                                    prompt_tokens: p.prompt_tokens,
                                    generated_tokens: pf.generated,
                                    cached_prompt_tokens: pf.cached_tokens,
                                    saved_prefill_s: pf.saved_prefill_s,
                                    saved_prefill_bytes: pf.saved_prefill_bytes,
                                    kv_transfer_bytes: p.kv_bytes,
                                    kv_transfer_s: p.kv_s,
                                    retries: p.retries,
                                    wasted_prefill_s: p.wasted_prefill_s,
                                    prefill_chunks: pf.prefill_chunks,
                                    interference_s: pf.interference_s,
                                    model: Some(anchored(&p, pf)),
                                    error: Some(e.to_string()),
                                });
                            } else {
                                stats[replica].assigned += 1;
                                stats[replica].max_depth = stats[replica]
                                    .max_depth
                                    .max(replicas[replica].queue_depth());
                            }
                        }
                        EventKind::Fail { replica, churned } => {
                            // Draw this failure's repair first (churn
                            // draws are consumed in event order, keeping
                            // the stream deterministic). Recovery pays
                            // the weight-reload cold start over the fleet
                            // wire — degraded if a link window covers the
                            // repair time — before taking traffic again.
                            if churned {
                                if let Some(proc) = churn_procs[replica].as_mut() {
                                    let repair_at = ev.at + proc.time_to_repair();
                                    let wire = nets[replica]
                                        .degraded(self.faults.wire_factor(repair_at));
                                    let recover_at = repair_at
                                        + cold_start_s(
                                            self.arch(),
                                            plans[replica].shape().dtype_bytes,
                                            &wire,
                                        );
                                    down_until[replica] = down_until[replica].max(recover_at);
                                    heap.push(Reverse(Event {
                                        at: recover_at,
                                        seq: next_seq,
                                        kind: EventKind::Recover { replica, churned: true },
                                    }));
                                    next_seq += 1;
                                }
                            }
                            if alive[replica] {
                                alive[replica] = false;
                                routable[replica] = false;
                                // A draining replica that dies parks
                                // immediately: its GPUs release now, not
                                // at drain completion.
                                if states[replica] == ReplState::Draining {
                                    states[replica] = ReplState::Parked;
                                    if let Some(s) = prov_start[replica].take() {
                                        provisioned_s[replica] += (ev.at - s).max(0.0);
                                    }
                                }
                                let lost = replicas[replica].fail(kv_per_token[replica])?;
                                refresh_clock(&mut clocks, &replicas, replica);
                                for l in &lost {
                                    let p = pending
                                        .get_mut(&l.id)
                                        .expect("lost request tracked");
                                    p.attempt += 1;
                                    p.retries += 1;
                                    p.wasted_prefill_s += l.wasted_prefill_s;
                                    if let Some(pf) = p.prefill.take() {
                                        // A decode-pool loss wastes the
                                        // first attempt's prefill-pool
                                        // work as well.
                                        p.wasted_prefill_s += plans[p.replica]
                                            .cost_model()
                                            .prefill_price(
                                                pf.prompt_tokens - pf.cached_tokens,
                                            );
                                    }
                                    p.decode_replica = None;
                                }
                                for l in lost {
                                    route_retry(
                                        l.id,
                                        ev.at,
                                        &mut replicas,
                                        &mut clocks,
                                        &mut scratch,
                                        &serve_pool,
                                        &routable,
                                        &mut arrival_router,
                                        &mut pending,
                                        &mut stats,
                                        &mut completed,
                                        &mut stranded,
                                        disagg,
                                    );
                                }
                            }
                        }
                        EventKind::Recover { replica, churned } => {
                            // Schedule the next churn failure only while
                            // the run still has work left — otherwise the
                            // event heap would never drain.
                            if churned && completed.len() < total_requests {
                                if let Some(proc) = churn_procs[replica].as_mut() {
                                    heap.push(Reverse(Event {
                                        at: ev.at + proc.time_to_failure(),
                                        seq: next_seq,
                                        kind: EventKind::Fail { replica, churned: true },
                                    }));
                                    next_seq += 1;
                                }
                            }
                            // Overlapping outages extend the downtime:
                            // only the recovery that clears `down_until`
                            // revives the replica.
                            if !alive[replica] && ev.at >= down_until[replica] {
                                alive[replica] = true;
                                // A replica the controller parked (or is
                                // still cold-starting) recovers its
                                // health but not a routing slot.
                                routable[replica] = states[replica] == ReplState::Active;
                                for id in std::mem::take(&mut stranded) {
                                    route_retry(
                                        id,
                                        ev.at,
                                        &mut replicas,
                                        &mut clocks,
                                        &mut scratch,
                                        &serve_pool,
                                        &routable,
                                        &mut arrival_router,
                                        &mut pending,
                                        &mut stats,
                                        &mut completed,
                                        &mut stranded,
                                        disagg,
                                    );
                                }
                            }
                        }
                        EventKind::ScaleTick => {
                            // Stop ticking once the offered load is fully
                            // served — otherwise the heap never drains.
                            if completed.len() >= total_requests {
                                continue;
                            }
                            let ctl = controller
                                .as_mut()
                                .expect("ScaleTick only scheduled with a policy");
                            let active_idx: Vec<usize> =
                                (0..n).filter(|&i| routable[i]).collect();
                            let mut depth_total = 0usize;
                            let mut hot_depth = 0usize;
                            let mut cool_depth = usize::MAX;
                            for &i in &active_idx {
                                let d = replicas[i].queue_depth();
                                depth_samples[i].push((ev.at, d));
                                depth_total += d;
                                hot_depth = hot_depth.max(d);
                                cool_depth = cool_depth.min(d);
                            }
                            let hottest_gap = if active_idx.is_empty() {
                                0
                            } else {
                                hot_depth - cool_depth
                            };
                            let pending_up = states
                                .iter()
                                .filter(|&&s| s == ReplState::ColdStarting)
                                .count();
                            let horizon = ev.at - ctl.policy().window_s;
                            // ScaleTick times are strictly increasing, so
                            // the horizon is monotone and the head can
                            // retire for good. Completion order is not
                            // finished-at order, though, so mid-queue
                            // entries that aged out stay put and are
                            // filtered on read — keeping `recent` bitwise
                            // what a full rescan of `completed` produced.
                            for m in &completed[e2e_scanned..] {
                                if let Some(t) = m.model.as_ref() {
                                    e2e_window.push_back((t.finished_at_s, t.e2e_s));
                                }
                            }
                            e2e_scanned = completed.len();
                            while e2e_window
                                .front()
                                .is_some_and(|&(f, _)| f < horizon)
                            {
                                e2e_window.pop_front();
                            }
                            let recent: Vec<f64> = e2e_window
                                .iter()
                                .filter(|&&(f, _)| f >= horizon)
                                .map(|&(_, e)| e)
                                .collect();
                            let decision = ctl.tick(&FleetSnapshot {
                                now_s: ev.at,
                                active: active_idx.len(),
                                pending_up,
                                queue_depth_total: depth_total,
                                hottest_gap,
                                recent_e2e_s: &recent,
                            });
                            match decision {
                                ScaleDecision::Hold => {}
                                ScaleDecision::ScaleUp => {
                                    // Lowest-index healthy parked replica
                                    // spawns; GPUs are held from the
                                    // decision while the weights stream
                                    // in over the (possibly degraded)
                                    // fleet wire.
                                    if let Some(r) = (0..n).find(|&i| {
                                        alive[i] && states[i] == ReplState::Parked
                                    }) {
                                        states[r] = ReplState::ColdStarting;
                                        prov_start[r] = Some(ev.at);
                                        let wire = nets[r]
                                            .degraded(self.faults.wire_factor(ev.at));
                                        let cost = cold_start_s(
                                            self.arch(),
                                            plans[r].shape().dtype_bytes,
                                            &wire,
                                        );
                                        cold_starts += 1;
                                        cold_start_total_s += cost;
                                        heap.push(Reverse(Event {
                                            at: ev.at + cost,
                                            seq: next_seq,
                                            kind: EventKind::ScaleUpDone { replica: r },
                                        }));
                                        next_seq += 1;
                                    }
                                }
                                ScaleDecision::ScaleDown => {
                                    if active_idx.len() > ctl.policy().min_replicas {
                                        let candidates: Vec<DrainCandidate> = active_idx
                                            .iter()
                                            .map(|&i| DrainCandidate {
                                                replica: i,
                                                load: replicas[i]
                                                    .load()
                                                    .outstanding_tokens,
                                                warm_bytes: replicas[i]
                                                    .warm_prefix_value(),
                                            })
                                            .collect();
                                        if let Some(v) = choose_victim(&candidates) {
                                            states[v] = ReplState::Draining;
                                            routable[v] = false;
                                            if !replicas[v].runnable() {
                                                // Already idle: park (and
                                                // release GPUs) now.
                                                states[v] = ReplState::Parked;
                                                if let Some(s) = prov_start[v].take() {
                                                    provisioned_s[v] +=
                                                        (ev.at - s).max(0.0);
                                                }
                                            }
                                        }
                                    }
                                }
                                ScaleDecision::Migrate => {
                                    // Hottest → coolest active replica by
                                    // queue depth, first index winning
                                    // ties; ship the live sequence with
                                    // the most remaining decode work.
                                    let mut hot = active_idx[0];
                                    let mut cool = active_idx[0];
                                    for &i in &active_idx[1..] {
                                        if replicas[i].queue_depth()
                                            > replicas[hot].queue_depth()
                                        {
                                            hot = i;
                                        }
                                        if replicas[i].queue_depth()
                                            < replicas[cool].queue_depth()
                                        {
                                            cool = i;
                                        }
                                    }
                                    // One migration per request: a
                                    // sequence that already carries a
                                    // merged source pass stays put.
                                    let pick = replicas[hot]
                                        .migration_candidates()
                                        .into_iter()
                                        .find(|id| {
                                            pending
                                                .get(id)
                                                .is_some_and(|p| p.prefill.is_none())
                                        });
                                    if hot != cool {
                                        if let Some(id) = pick {
                                            if let Some(m) = replicas[hot].migrate_out(id)?
                                            {
                                                // The source may have gone
                                                // idle when its flight left.
                                                refresh_clock(
                                                    &mut clocks,
                                                    &replicas,
                                                    hot,
                                                );
                                                // Resident KV below the
                                                // re-prefilled token ships
                                                // through the same α–β p2p
                                                // path as a disagg handoff.
                                                let bytes =
                                                    (m.context * kv_per_token[hot]) as f64;
                                                let crosses = nodes[hot] != nodes[cool];
                                                let wire = nets[hot].degraded(
                                                    self.faults.wire_factor(ev.at),
                                                );
                                                let cost =
                                                    wire.p2p(bytes, crosses).total();
                                                migrations += 1;
                                                kv_migration_bytes += bytes;
                                                kv_migration_s += cost;
                                                let p = pending
                                                    .get_mut(&id)
                                                    .expect("candidate filter checked");
                                                p.kv_bytes += bytes;
                                                p.kv_s += cost;
                                                let token = m.done.last_token;
                                                p.prefill = Some(m.done);
                                                heap.push(Reverse(Event {
                                                    at: ev.at + cost,
                                                    seq: next_seq,
                                                    kind: EventKind::Migrate {
                                                        id,
                                                        token,
                                                        remaining: m.remaining,
                                                        context: m.context,
                                                        replica: cool,
                                                        attempt: p.attempt,
                                                    },
                                                }));
                                                next_seq += 1;
                                            }
                                        }
                                    }
                                }
                            }
                            heap.push(Reverse(Event {
                                at: ctl.next_tick_after(ev.at),
                                seq: next_seq,
                                kind: EventKind::ScaleTick,
                            }));
                            next_seq += 1;
                        }
                        EventKind::ScaleUpDone { replica } => {
                            // A fault can fell the replica mid-load; it
                            // then joins the pool through the Recover
                            // path instead.
                            if states[replica] == ReplState::ColdStarting {
                                states[replica] = ReplState::Active;
                                // The weight reload behind the cold start
                                // means the prefix cache comes back empty.
                                replicas[replica].reset_cold(kv_per_token[replica]);
                                routable[replica] = alive[replica];
                                if routable[replica] {
                                    for id in std::mem::take(&mut stranded) {
                                        route_retry(
                                            id,
                                            ev.at,
                                            &mut replicas,
                                            &mut clocks,
                                            &mut scratch,
                                            &serve_pool,
                                            &routable,
                                            &mut arrival_router,
                                            &mut pending,
                                            &mut stats,
                                            &mut completed,
                                            &mut stranded,
                                            disagg,
                                        );
                                    }
                                }
                            }
                        }
                        EventKind::Migrate { id, token, remaining, context, replica, attempt } => {
                            // A fault retried the request while its KV
                            // was on the wire: the shipment belongs to a
                            // dead attempt — drop it.
                            if pending.get(&id).map(|p| p.attempt) != Some(attempt) {
                                continue;
                            }
                            if !routable[replica] {
                                // The target left the pool (fault or
                                // drain) mid-shipment: the source pass is
                                // sunk; the request retries from scratch.
                                let p = pending.get_mut(&id).expect("attempt matched");
                                p.attempt += 1;
                                p.retries += 1;
                                if let Some(pf) = p.prefill.take() {
                                    p.wasted_prefill_s += plans[p.replica]
                                        .cost_model()
                                        .prefill_price(pf.prompt_tokens - pf.cached_tokens);
                                }
                                route_retry(
                                    id,
                                    ev.at,
                                    &mut replicas,
                                    &mut clocks,
                                    &mut scratch,
                                    &serve_pool,
                                    &routable,
                                    &mut arrival_router,
                                    &mut pending,
                                    &mut stats,
                                    &mut completed,
                                    &mut stranded,
                                    disagg,
                                );
                                continue;
                            }
                            // Restore the sequence mid-decode: 1-token
                            // prompt (the last sampled token) over the
                            // shipped cached-KV context — exactly the
                            // disaggregated handoff's admission shape, so
                            // the remaining decode positions (and tokens)
                            // continue the source bitwise.
                            let req =
                                Request { id, prompt: vec![token].into(), decode_len: remaining };
                            let submitted = replicas[replica].submit(req, ev.at, context);
                            refresh_clock(&mut clocks, &replicas, replica);
                            if let Err(e) = submitted {
                                let p = pending.remove(&id).expect("migration tracked");
                                let pf =
                                    p.prefill.as_ref().expect("source pass preceded migration");
                                completed.push(FleetRequestMetrics {
                                    request_id: id,
                                    replica: p.replica,
                                    decode_replica: None,
                                    prompt_tokens: p.prompt_tokens,
                                    generated_tokens: pf.generated,
                                    cached_prompt_tokens: pf.cached_tokens,
                                    saved_prefill_s: pf.saved_prefill_s,
                                    saved_prefill_bytes: pf.saved_prefill_bytes,
                                    kv_transfer_bytes: p.kv_bytes,
                                    kv_transfer_s: p.kv_s,
                                    retries: p.retries,
                                    wasted_prefill_s: p.wasted_prefill_s,
                                    prefill_chunks: pf.prefill_chunks,
                                    interference_s: pf.interference_s,
                                    model: Some(anchored(&p, pf)),
                                    error: Some(e.to_string()),
                                });
                            } else {
                                let p = pending.get_mut(&id).expect("attempt matched");
                                p.replica = replica;
                                stats[replica].assigned += 1;
                                stats[replica].max_depth = stats[replica]
                                    .max_depth
                                    .max(replicas[replica].queue_depth());
                            }
                        }
                    }
                    continue;
                }

                let (bi, _) = busy.expect("non-deliver branch has a runnable replica");
                let done = replicas[bi].advance()?;
                refresh_clock(&mut clocks, &replicas, bi);
                for d in done {
                    match roles[bi] {
                        ReplicaRole::Serve => {
                            let p = pending.remove(&d.id).expect("routed request tracked");
                            if let Some(pf) = p.prefill.as_ref() {
                                // Migrated mid-decode: merge the source
                                // pass with this (target) pass, exactly
                                // like a disaggregated prefill + decode
                                // pair — TTFT from the source, the tail
                                // (with the KV shipment inside the
                                // inter-token gap) from the target.
                                let (model, generated) = if d.rejected {
                                    (Some(anchored(&p, pf)), pf.generated)
                                } else {
                                    let mut t = merge_times(pf, &d);
                                    t.queue_s = pf.admitted_s - p.arrival_s;
                                    t.e2e_s = d.last_token_s - p.arrival_s;
                                    (Some(t), pf.generated + d.generated)
                                };
                                completed.push(FleetRequestMetrics {
                                    request_id: d.id,
                                    replica: p.replica,
                                    decode_replica: None,
                                    prompt_tokens: p.prompt_tokens,
                                    generated_tokens: generated,
                                    // Cache hits happened on the source
                                    // replica; the 1-token restore prompt
                                    // never hits.
                                    cached_prompt_tokens: pf.cached_tokens,
                                    saved_prefill_s: pf.saved_prefill_s,
                                    saved_prefill_bytes: pf.saved_prefill_bytes,
                                    kv_transfer_bytes: p.kv_bytes,
                                    kv_transfer_s: p.kv_s,
                                    retries: p.retries,
                                    wasted_prefill_s: p.wasted_prefill_s,
                                    // A rejected target pass carries zero
                                    // chunk/interference totals, so the
                                    // sums stay the source pass's.
                                    prefill_chunks: pf.prefill_chunks,
                                    interference_s: pf.interference_s + d.interference_s,
                                    model,
                                    error: d.error.clone(),
                                });
                            } else {
                                completed.push(FleetRequestMetrics {
                                    request_id: d.id,
                                    replica: p.replica,
                                    decode_replica: None,
                                    prompt_tokens: d.prompt_tokens,
                                    generated_tokens: d.generated,
                                    cached_prompt_tokens: d.cached_tokens,
                                    saved_prefill_s: d.saved_prefill_s,
                                    saved_prefill_bytes: d.saved_prefill_bytes,
                                    kv_transfer_bytes: 0.0,
                                    kv_transfer_s: 0.0,
                                    retries: p.retries,
                                    wasted_prefill_s: p.wasted_prefill_s,
                                    prefill_chunks: d.prefill_chunks,
                                    interference_s: d.interference_s,
                                    model: if d.rejected {
                                        None
                                    } else {
                                        Some(anchored(&p, &d))
                                    },
                                    error: d.error.clone(),
                                });
                            }
                        }
                        ReplicaRole::Prefill => {
                            if d.rejected || d.error.is_some() {
                                let p = pending.remove(&d.id).expect("routed request tracked");
                                completed.push(FleetRequestMetrics {
                                    request_id: d.id,
                                    replica: p.replica,
                                    decode_replica: None,
                                    prompt_tokens: d.prompt_tokens,
                                    generated_tokens: d.generated,
                                    cached_prompt_tokens: d.cached_tokens,
                                    saved_prefill_s: d.saved_prefill_s,
                                    saved_prefill_bytes: d.saved_prefill_bytes,
                                    kv_transfer_bytes: 0.0,
                                    kv_transfer_s: 0.0,
                                    retries: p.retries,
                                    wasted_prefill_s: p.wasted_prefill_s,
                                    prefill_chunks: d.prefill_chunks,
                                    interference_s: d.interference_s,
                                    model: if d.rejected {
                                        None
                                    } else {
                                        Some(anchored(&p, &d))
                                    },
                                    error: d.error.clone(),
                                });
                                continue;
                            }
                            let p = pending.get_mut(&d.id).expect("routed request tracked");
                            let remaining = p.decode_len.saturating_sub(d.generated);
                            if remaining == 0 {
                                // Single-token request: prefill is the
                                // whole generation; no handoff.
                                let done = FleetRequestMetrics {
                                    request_id: d.id,
                                    replica: p.replica,
                                    decode_replica: None,
                                    prompt_tokens: d.prompt_tokens,
                                    generated_tokens: d.generated,
                                    cached_prompt_tokens: d.cached_tokens,
                                    saved_prefill_s: d.saved_prefill_s,
                                    saved_prefill_bytes: d.saved_prefill_bytes,
                                    kv_transfer_bytes: 0.0,
                                    kv_transfer_s: 0.0,
                                    retries: p.retries,
                                    wasted_prefill_s: p.wasted_prefill_s,
                                    prefill_chunks: d.prefill_chunks,
                                    interference_s: d.interference_s,
                                    model: Some(anchored(p, &d)),
                                    error: None,
                                };
                                pending.remove(&d.id);
                                completed.push(done);
                                continue;
                            }
                            // Route the decode replica now, price the KV
                            // migration, and deliver the request to the
                            // decode pool once the wire drains.
                            scratch.snapshot(&decode_pool, &alive, |i| replicas[i].load());
                            let Some(slot) =
                                handoff_router.route_masked(&scratch.loads, &scratch.live)
                            else {
                                // The whole decode pool is down: the
                                // prefill work is wasted; the request
                                // retries from scratch once a replica
                                // recovers.
                                let wasted = plans[bi]
                                    .cost_model()
                                    .prefill_price(d.prompt_tokens - d.cached_tokens);
                                let p =
                                    pending.get_mut(&d.id).expect("routed request tracked");
                                p.attempt += 1;
                                p.retries += 1;
                                p.wasted_prefill_s += wasted;
                                p.decode_replica = None;
                                stranded.push(d.id);
                                continue;
                            };
                            let pick = decode_pool[slot];
                            let bytes = (d.prompt_tokens * kv_per_token[bi]) as f64;
                            let crosses = nodes[bi] != nodes[pick];
                            // Link-degradation windows slow the handoff
                            // wire (factor 1.0 outside any window — a
                            // bitwise no-op).
                            let wire =
                                nets[bi].degraded(self.faults.wire_factor(d.last_token_s));
                            let cost = wire.p2p(bytes, crosses).total();
                            kv_total_bytes += bytes;
                            kv_total_s += cost;
                            p.decode_replica = Some(pick);
                            // Accumulated, not assigned: a retried
                            // request ships (and pays for) its KV once
                            // per attempt.
                            p.kv_bytes += bytes;
                            p.kv_s += cost;
                            heap.push(Reverse(Event {
                                at: d.last_token_s + cost,
                                seq: next_seq,
                                kind: EventKind::Handoff {
                                    id: d.id,
                                    token: d.last_token,
                                    remaining,
                                    // The decode pool prices its decode
                                    // iterations against the shipped
                                    // Sp-token prefill KV (its own 1-token
                                    // prompt — the handed-off first token —
                                    // sits on top of it, matching the
                                    // colocated position sequence exactly).
                                    context: d.prompt_tokens,
                                    replica: pick,
                                    attempt: p.attempt,
                                },
                            }));
                            next_seq += 1;
                            p.prefill = Some(d);
                        }
                        ReplicaRole::Decode => {
                            let p = pending.remove(&d.id).expect("handoff tracked");
                            let pf = p.prefill.as_ref().expect("prefill preceded decode");
                            let (model, generated) = if d.rejected {
                                // The decode pool refused the session: the
                                // request keeps its prefill-phase times.
                                (Some(anchored(&p, pf)), pf.generated)
                            } else {
                                // Anchor queue/E2E at the *first* arrival so
                                // failed attempts and stranded-while-down
                                // waits stay inside the span (bitwise no-op
                                // on a healthy fleet, where the serving
                                // attempt's arrival is the first arrival).
                                let mut t = merge_times(pf, &d);
                                t.queue_s = pf.admitted_s - p.arrival_s;
                                t.e2e_s = d.last_token_s - p.arrival_s;
                                (Some(t), pf.generated + d.generated)
                            };
                            completed.push(FleetRequestMetrics {
                                request_id: d.id,
                                replica: p.replica,
                                decode_replica: p.decode_replica,
                                prompt_tokens: p.prompt_tokens,
                                generated_tokens: generated,
                                // Prefix-cache savings happen in the
                                // prefill pool; the decode pool's 1-token
                                // intake never hits.
                                cached_prompt_tokens: pf.cached_tokens,
                                saved_prefill_s: pf.saved_prefill_s,
                                saved_prefill_bytes: pf.saved_prefill_bytes,
                                kv_transfer_bytes: p.kv_bytes,
                                kv_transfer_s: p.kv_s,
                                retries: p.retries,
                                wasted_prefill_s: p.wasted_prefill_s,
                                // Chunking lives in the prefill pool; the
                                // decode pool's 1-token intake is always
                                // one-shot, but its victims' stalls behind
                                // intake prefills still accumulate.
                                prefill_chunks: pf.prefill_chunks,
                                interference_s: pf.interference_s + d.interference_s,
                                model,
                                error: d.error.clone(),
                            });
                        }
                    }
                }
                // A draining replica parks (releasing its GPUs) the
                // moment its last in-flight request leaves.
                if states[bi] == ReplState::Draining && !replicas[bi].runnable() {
                    states[bi] = ReplState::Parked;
                    if let Some(s) = prov_start[bi].take() {
                        provisioned_s[bi] += (replicas[bi].now() - s).max(0.0);
                    }
                }
            }

            for (i, r) in replicas.iter().enumerate() {
                stats[i].tokens = r.tokens_served();
                stats[i].cached_tokens = r.cached_tokens_total();
            }
        }

        // Close every still-open provisioned interval at the model-time
        // end of the run (static replicas run the whole span; a drained
        // one already closed at its park).
        let end_s = completed
            .iter()
            .filter_map(|m| m.model.as_ref())
            .map(|t| t.finished_at_s)
            .fold(0.0f64, f64::max);
        for i in 0..n {
            if let Some(s) = prov_start[i].take() {
                provisioned_s[i] += (end_s - s).max(0.0);
            }
            stats[i].provisioned_s = provisioned_s[i];
        }
        // Rolling-window signals as of end-of-run (what the controller's
        // last tick saw, for the CLI table and post-mortems).
        if let Some(p) = &self.autoscale {
            let horizon = end_s - p.window_s;
            for i in 0..n {
                let tail: Vec<f64> = depth_samples[i]
                    .iter()
                    .filter(|&&(t, _)| t >= horizon)
                    .map(|&(_, d)| d as f64)
                    .collect();
                if !tail.is_empty() {
                    stats[i].rolling_queue_depth =
                        tail.iter().sum::<f64>() / tail.len() as f64;
                }
                let ttfts: Vec<f64> = completed
                    .iter()
                    .filter(|m| m.replica == i)
                    .filter_map(|m| m.model.as_ref())
                    .filter(|t| t.finished_at_s >= horizon)
                    .map(|t| t.ttft_s)
                    .collect();
                stats[i].rolling_ttft_p95_s =
                    crate::autoscale::rolling_p95(&ttfts).unwrap_or(0.0);
            }
        }
        let provisioned_gpu_s: f64 =
            stats.iter().map(|s| s.gpus as f64 * s.provisioned_s).sum();

        // Aggregate through the serving stack's own summary path so the
        // model-time percentiles share one implementation (and a
        // 1-replica fleet matches `serve_poisson` bitwise).
        let wall: Vec<RequestMetrics> = completed
            .iter()
            .map(|m| RequestMetrics {
                request_id: m.request_id,
                prompt_tokens: m.prompt_tokens,
                generated_tokens: m.generated_tokens,
                cached_prompt_tokens: m.cached_prompt_tokens,
                saved_prefill_s: m.saved_prefill_s,
                saved_prefill_bytes: m.saved_prefill_bytes,
                queue_s: 0.0,
                ttft_s: 0.0,
                tpot_s: 0.0,
                e2e_s: 0.0,
                retries: m.retries,
                wasted_prefill_s: m.wasted_prefill_s,
                prefill_chunks: m.prefill_chunks,
                interference_s: m.interference_s,
                model: m.model,
                error: m.error.clone(),
            })
            .collect();
        let agg = ServeSummary::from_metrics(&wall, Duration::ZERO);

        let mut comm_bytes = kv_total_bytes + kv_migration_bytes;
        let mut wire_saved_bytes = 0.0f64;
        let mut hidden_comm_s = 0.0f64;
        for (i, e) in engines.iter().enumerate() {
            let summary = e.trace().summary();
            comm_bytes += traced_comm_bytes(&summary, self.replicas[i].plan.layout().pp);
            hidden_comm_s += e.hidden_comm_s();
            if let Some(cm) = e.cost_model() {
                wire_saved_bytes += cm.wire_saved_bytes(&summary);
            }
        }

        Ok(FleetSummary {
            requests: agg.requests,
            completed: agg.completed,
            failed: agg.failed,
            total_tokens: agg.total_tokens,
            model: agg.model.unwrap_or_default(),
            per_request: completed,
            replicas: stats,
            cached_prompt_tokens: agg.cached_prompt_tokens,
            saved_prefill_s: agg.saved_prefill_s,
            saved_prefill_bytes: agg.saved_prefill_bytes,
            retries: agg.retries,
            wasted_prefill_s: agg.wasted_prefill_s,
            chunked_requests: agg.chunked_requests,
            interference_s: agg.interference_s,
            kv_transfer_bytes: kv_total_bytes,
            kv_transfer_s: kv_total_s,
            kv_migration_bytes,
            kv_migration_s,
            cold_starts,
            cold_start_s: cold_start_total_s,
            migrations,
            provisioned_gpu_s,
            comm_bytes,
            wire_saved_bytes,
            hidden_comm_s,
            events,
        })
    }
}

/// Model-clock latencies of one replica pass (the serving loop's
/// `request_metrics` formulas, verbatim).
fn times_from(d: &ReplicaDone) -> ModelRequestTimes {
    let first = d.first_token_s.unwrap_or(d.admitted_s);
    ModelRequestTimes {
        queue_s: d.admitted_s - d.arrival_s,
        ttft_s: if d.first_token_s.is_some() {
            first - d.admitted_s
        } else {
            0.0
        },
        tpot_s: if d.generated > 1 {
            (d.last_token_s - first) / (d.generated - 1) as f64
        } else {
            0.0
        },
        e2e_s: d.last_token_s - d.arrival_s,
        finished_at_s: d.last_token_s,
    }
}

/// [`times_from`] anchored at the request's *first* arrival: queue time
/// and E2E span failed attempts and stranded-while-down waits too (the
/// wasted first-attempt prefill is inside that span), while TTFT/TPOT
/// describe the attempt that actually served. On a healthy fleet the
/// serving attempt's arrival *is* the first arrival, so this is exactly
/// [`times_from`], bitwise.
fn anchored(p: &Pending, d: &ReplicaDone) -> ModelRequestTimes {
    let mut t = times_from(d);
    t.queue_s = d.admitted_s - p.arrival_s;
    t.e2e_s = d.last_token_s - p.arrival_s;
    t
}

/// Re-route one request after a fault (its replica failed, its handoff
/// target died, or a recovery revived a fully-down pool). The request
/// re-enters the arrival router over the live serve pool; with no live
/// replica it parks on `stranded` until a recovery event. A rejected
/// resubmission fails the request, exactly as on first arrival.
#[allow(clippy::too_many_arguments)]
fn route_retry(
    id: u64,
    at: f64,
    replicas: &mut [Replica<'_>],
    clocks: &mut ClockIndex,
    scratch: &mut RouteScratch,
    serve_pool: &[usize],
    routable: &[bool],
    router: &mut Router,
    pending: &mut HashMap<u64, Pending>,
    stats: &mut [ReplicaStats],
    completed: &mut Vec<FleetRequestMetrics>,
    stranded: &mut Vec<u64>,
    disagg: bool,
) {
    let Some(p) = pending.get(&id) else { return };
    scratch.snapshot(serve_pool, routable, |i| match &p.chain {
        Some(c) => replicas[i].load_for_chain(c, p.prompt.len()),
        None => replicas[i].load(),
    });
    let Some(slot) = router.route_masked(&scratch.loads, &scratch.live) else {
        stranded.push(id);
        return;
    };
    let pick = serve_pool[slot];
    let sub = Request {
        id,
        prompt: p.prompt.clone(),
        decode_len: if disagg { 1 } else { p.decode_len },
    };
    let pm = pending.get_mut(&id).expect("present above");
    pm.replica = pick;
    let submitted = replicas[pick].submit(sub, at, 0);
    refresh_clock(clocks, replicas, pick);
    match submitted {
        Ok(()) => {
            stats[pick].assigned += 1;
            stats[pick].max_depth = stats[pick].max_depth.max(replicas[pick].queue_depth());
        }
        Err(e) => {
            let p = pending.remove(&id).expect("present above");
            completed.push(FleetRequestMetrics {
                request_id: id,
                replica: pick,
                decode_replica: None,
                prompt_tokens: p.prompt_tokens,
                generated_tokens: 0,
                cached_prompt_tokens: 0,
                saved_prefill_s: 0.0,
                saved_prefill_bytes: 0.0,
                kv_transfer_bytes: p.kv_bytes,
                kv_transfer_s: p.kv_s,
                retries: p.retries,
                wasted_prefill_s: p.wasted_prefill_s,
                prefill_chunks: 0,
                interference_s: 0.0,
                model: None,
                error: Some(e.to_string()),
            });
        }
    }
}

/// Merge a disaggregated request's prefill-pool and decode-pool passes:
/// TTFT comes from the prefill pool, the token train (and E2E tail) from
/// the decode pool, with the KV-handoff gap inside the inter-token time.
fn merge_times(prefill: &ReplicaDone, decode: &ReplicaDone) -> ModelRequestTimes {
    let total = prefill.generated + decode.generated;
    let first = prefill.first_token_s.unwrap_or(prefill.admitted_s);
    ModelRequestTimes {
        queue_s: prefill.admitted_s - prefill.arrival_s,
        ttft_s: if prefill.first_token_s.is_some() {
            first - prefill.admitted_s
        } else {
            0.0
        },
        tpot_s: if total > 1 {
            (decode.last_token_s - first) / (total - 1) as f64
        } else {
            0.0
        },
        e2e_s: decode.last_token_s - prefill.arrival_s,
        finished_at_s: decode.last_token_s,
    }
}

/// Traced corrected collective volume of one replica's run, under the
/// paper's accounting (one worker stream for collectives; each pipeline
/// boundary transfer counted once via rank 0's Send stream × (p−1) links
/// — the Fig. 6 convention).
fn traced_comm_bytes(summary: &TraceSummary, pp: usize) -> f64 {
    let mut total = 0.0;
    for op in [CollectiveKind::AllReduce, CollectiveKind::AllGather, CollectiveKind::Gather] {
        for stage in [Stage::Prefill, Stage::Decode] {
            total += summary.paper_view(op, stage).corrected_volume_bytes;
        }
    }
    if pp > 1 && !summary.per_rank.is_empty() {
        total += summary.per_rank[0]
            .iter()
            .filter(|(k, _)| k.op == CollectiveKind::Send)
            .map(|(_, v)| v.corrected_volume_bytes)
            .sum::<f64>()
            * (pp - 1) as f64;
    }
    total
}

/// Fleet-level bookkeeping of one in-flight request.
struct Pending {
    /// The original prompt tokens (an `Arc` bump, shared with every
    /// attempt's Request), so a fault-injection retry can resubmit the
    /// request verbatim without the DES cloning token vectors.
    prompt: PromptTokens,
    /// First arrival time — a retried request anchors queue/E2E here,
    /// not at its resubmission.
    arrival_s: f64,
    /// Precomputed prompt block-hash chain (cache-affinity routing),
    /// reused when a retry re-routes the request.
    chain: Option<Vec<u64>>,
    /// Attempt epoch, bumped on every retry: a KV-handoff event carrying
    /// a stale epoch belongs to a dead attempt and is dropped.
    attempt: u32,
    retries: usize,
    wasted_prefill_s: f64,
    prompt_tokens: usize,
    decode_len: usize,
    replica: usize,
    decode_replica: Option<usize>,
    prefill: Option<ReplicaDone>,
    kv_bytes: f64,
    kv_s: f64,
}

/// Replica model-clock key ordered by [`f64::total_cmp`] — the exact
/// ordering the DES's old brute-force `min_by` scan used, so the index
/// reproduces its choices bitwise.
#[derive(Debug, Clone, Copy)]
struct ClockKey(f64);

impl PartialEq for ClockKey {
    fn eq(&self, other: &Self) -> bool {
        self.0.total_cmp(&other.0) == Ordering::Equal
    }
}

impl Eq for ClockKey {}

impl PartialOrd for ClockKey {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for ClockKey {
    fn cmp(&self, other: &Self) -> Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// Incrementally-maintained index of runnable replicas' model clocks.
///
/// The fleet DES needs "earliest runnable replica, ties to the lowest
/// index" on *every* loop iteration; rescanning all replicas makes each
/// iteration O(n). This index is updated only at the points where a
/// replica's clock or runnability can change (submit, advance, fail,
/// migrate), so the per-iteration delivery choice is `min()` over a
/// `BTreeSet` — O(log n) maintenance, O(1) reads — and, because the set
/// is ordered by `(total_cmp clock, index)`, it agrees with the
/// brute-force scan on every input, NaNs and negative zeros included.
#[derive(Debug, Default)]
pub struct ClockIndex {
    /// Runnable replicas, ordered by (clock, index).
    set: std::collections::BTreeSet<(ClockKey, usize)>,
    /// Per-replica mirror of what the set holds (`None`: not runnable),
    /// so updates can remove the stale entry without a scan.
    entries: Vec<Option<f64>>,
}

impl ClockIndex {
    pub fn new(n: usize) -> Self {
        Self { set: std::collections::BTreeSet::new(), entries: vec![None; n] }
    }

    /// Record replica `i`'s state: `Some(clock)` while it has work,
    /// `None` once it goes idle.
    pub fn set(&mut self, i: usize, clock: Option<f64>) {
        if let Some(old) = self.entries[i] {
            self.set.remove(&(ClockKey(old), i));
        }
        self.entries[i] = clock;
        if let Some(c) = clock {
            self.set.insert((ClockKey(c), i));
        }
    }

    /// Earliest runnable replica and its clock — `(index, clock)`, ties
    /// on the clock resolving to the lowest index.
    pub fn min(&self) -> Option<(usize, f64)> {
        self.set.iter().next().map(|&(k, i)| (i, k.0))
    }
}

/// Re-sync one replica's entry in the clock index. Called after every
/// operation that can change the replica's clock or runnability.
fn refresh_clock(idx: &mut ClockIndex, replicas: &[Replica<'_>], i: usize) {
    idx.set(i, replicas[i].runnable().then(|| replicas[i].now()));
}

/// Reusable routing buffers: the DES routes on every arrival and retry,
/// and the load/liveness snapshots would otherwise allocate two fresh
/// vectors per request.
#[derive(Default)]
struct RouteScratch {
    loads: Vec<ReplicaLoad>,
    live: Vec<bool>,
}

impl RouteScratch {
    /// Fill the buffers for `pool`, then route: loads via `load_of`,
    /// liveness from `routable`.
    fn snapshot(
        &mut self,
        pool: &[usize],
        routable: &[bool],
        mut load_of: impl FnMut(usize) -> ReplicaLoad,
    ) {
        self.loads.clear();
        self.loads.extend(pool.iter().map(|&i| load_of(i)));
        self.live.clear();
        self.live.extend(pool.iter().map(|&i| routable[i]));
    }
}

#[derive(Debug)]
struct Event {
    at: f64,
    seq: u64,
    kind: EventKind,
}

#[derive(Debug)]
enum EventKind {
    Arrival(Request),
    Handoff {
        id: u64,
        token: i32,
        remaining: usize,
        context: usize,
        replica: usize,
        /// [`Pending::attempt`] at shipment time (stale handoffs from a
        /// retried attempt are dropped on delivery).
        attempt: u32,
    },
    /// A replica goes down (churn draw or scripted outage): it loses its
    /// queue, flights, KV, and prefix-cache warmth.
    Fail { replica: usize, churned: bool },
    /// A replica comes back (MTTR draw or outage end, plus the weight
    /// cold-start) and takes traffic again.
    Recover { replica: usize, churned: bool },
    /// Autoscale controller scale-check (scheduled only with a policy
    /// attached; jittered by the autoscale RNG stream).
    ScaleTick,
    /// A scale-up's weight cold-start finished: the replica joins the
    /// routable pool (unless a fault felled it mid-load).
    ScaleUpDone { replica: usize },
    /// A live KV migration's shipment arrives at the target replica —
    /// the elasticity analogue of `Handoff`, carrying the same restore
    /// payload (1-token prompt over `context` cached-KV tokens).
    Migrate {
        id: u64,
        token: i32,
        remaining: usize,
        context: usize,
        replica: usize,
        /// [`Pending::attempt`] at shipment time (stale migrations from a
        /// retried attempt are dropped on delivery).
        attempt: u32,
    },
}

/// Lifecycle of a replica under autoscaling. Static fleets (no policy)
/// hold every replica at `Active` forever — the mask the router sees is
/// then exactly the fault-injection `alive` mask.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ReplState {
    /// In the routing pool (when alive).
    Active,
    /// Scale-up issued; weights streaming in. Counts toward provisioned
    /// capacity but takes no traffic until `ScaleUpDone`.
    ColdStarting,
    /// Scale-down issued: admits nothing new, finishes its in-flight
    /// work, then parks.
    Draining,
    /// Deprovisioned (or never provisioned): holds no GPUs.
    Parked,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        self.at.total_cmp(&other.at).then_with(|| self.seq.cmp(&other.seq))
    }
}

/// SLO record of one fleet-served request (model time).
#[derive(Debug, Clone)]
pub struct FleetRequestMetrics {
    pub request_id: u64,
    /// Serving replica (the prefill-pool member under disaggregation).
    pub replica: usize,
    /// Decode-pool replica the request was handed off to, if any.
    pub decode_replica: Option<usize>,
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    /// Leading prompt tokens served from the replica's prefix cache
    /// (0 without caches or on a miss).
    pub cached_prompt_tokens: usize,
    /// Model-time prefill seconds the cached prefix saved this request
    /// (`CostModel::prefill_price(full) - prefill_price(suffix)`).
    pub saved_prefill_s: f64,
    /// Corrected prefill communication bytes the cached prefix saved.
    pub saved_prefill_bytes: f64,
    /// KV-cache bytes shipped on the request's behalf: the prefill →
    /// decode handoff under disaggregation, or a live autoscale
    /// migration's resident context (0 when the request never moved).
    pub kv_transfer_bytes: f64,
    /// Modeled wire time of those shipments (stamped into the request's
    /// timeline: the receiving replica sees the sequence only after it).
    pub kv_transfer_s: f64,
    /// Times the request was re-routed after losing its replica to a
    /// fault (0 on a healthy fleet).
    pub retries: usize,
    /// Model-time prefill seconds sunk into attempts that died with
    /// their replica — work done, paid for in the request's E2E span,
    /// and thrown away.
    pub wasted_prefill_s: f64,
    /// Prefill iterations the serving attempt used: 1 for a one-shot
    /// prefill, `ceil(suffix / chunk_tokens)` when the chunked-prefill
    /// budget split the prompt, 0 when the request never prefilled.
    pub prefill_chunks: usize,
    /// Model seconds this request lost as a decode *victim* to other
    /// requests' prefill work on its replica: full stalls behind
    /// one-shot prefills plus the per-iteration stretch of sharing
    /// mixed chunk+decode batches. Summed across disaggregated passes.
    pub interference_s: f64,
    /// Model-clock latencies; `None` when the request never entered an
    /// engine (queue overflow / admission rejection).
    pub model: Option<ModelRequestTimes>,
    pub error: Option<String>,
}

/// Per-replica dispatch statistics of one simulation.
#[derive(Debug, Clone)]
pub struct ReplicaStats {
    pub label: String,
    pub role: ReplicaRole,
    pub gpus: usize,
    /// Requests routed to this replica.
    pub assigned: usize,
    /// Peak queued + in-flight requests observed at assignment time.
    pub max_depth: usize,
    /// Tokens the replica generated.
    pub tokens: usize,
    /// Prompt tokens the replica served out of its prefix cache.
    pub cached_tokens: usize,
    /// Model seconds this replica was provisioned (activation — GPUs
    /// held from the scale-up decision, weights streaming — to park or
    /// end-of-run). Equals the run's makespan on a static fleet.
    pub provisioned_s: f64,
    /// Mean queue depth over the controller's last sliding window
    /// (0 without an autoscale policy or samples).
    pub rolling_queue_depth: f64,
    /// Nearest-rank p95 TTFT of this replica's completions inside the
    /// last sliding window (0 without a policy or completions).
    pub rolling_ttft_p95_s: f64,
}

/// Aggregate of one fleet simulation.
#[derive(Debug, Clone)]
pub struct FleetSummary {
    pub requests: usize,
    pub completed: usize,
    pub failed: usize,
    pub total_tokens: usize,
    /// Model-time makespan/throughput/percentiles (same aggregation as
    /// [`crate::server::ServeSummary`]'s model side).
    pub model: ModelServeSummary,
    /// Per-request metrics in completion order.
    pub per_request: Vec<FleetRequestMetrics>,
    pub replicas: Vec<ReplicaStats>,
    /// Total prompt tokens served out of prefix caches.
    pub cached_prompt_tokens: usize,
    /// Total model-time prefill seconds saved by prefix-cache hits
    /// (summed over `per_request` in completion order).
    pub saved_prefill_s: f64,
    /// Total corrected prefill communication bytes saved by prefix-cache
    /// hits.
    pub saved_prefill_bytes: f64,
    /// Total fault-injection retries across every request (0 on a
    /// healthy fleet).
    pub retries: usize,
    /// Total model-time prefill seconds lost to replica failures.
    pub wasted_prefill_s: f64,
    /// Requests whose prefill was split into more than one chunk by a
    /// chunked-prefill budget (0 with the knob unset).
    pub chunked_requests: usize,
    /// Total model seconds requests lost as decode victims to other
    /// requests' prefill work (one-shot stalls + mixed-batch stretch).
    pub interference_s: f64,
    /// Total KV-cache bytes shipped prefill → decode.
    pub kv_transfer_bytes: f64,
    /// Total modeled KV-handoff wire seconds.
    pub kv_transfer_s: f64,
    /// Total live-KV bytes shipped by autoscale migrations (0 without a
    /// policy).
    pub kv_migration_bytes: f64,
    /// Total modeled wire seconds of those migrations.
    pub kv_migration_s: f64,
    /// Autoscale cold starts paid (scale-up weight loads; fault-recovery
    /// reloads are accounted inside the churn timeline instead).
    pub cold_starts: usize,
    /// Total model seconds spent streaming weights for those scale-ups.
    pub cold_start_s: f64,
    /// Live KV migrations performed.
    pub migrations: usize,
    /// GPU·seconds provisioned: Σ over replicas of GPUs × provisioned
    /// model time. A static fleet pays `total_gpus × makespan`; an
    /// elastic one pays only for what it kept active — the headline
    /// cost axis autoscaling trades against latency.
    pub provisioned_gpu_s: f64,
    /// Traced corrected collective volume across all replicas plus KV
    /// handoffs and autoscale migrations (the fleet-level analogue of
    /// Eq. 1–7 totals). Traces record logical fp16 payloads, so this is
    /// independent of the wire precision; the quantized transports'
    /// saving is `wire_saved_bytes`.
    pub comm_bytes: f64,
    /// Collective wire bytes the plans' [`crate::cluster::CollectiveTuning`]
    /// saved across all replicas — logical AllReduce/AllGather volume ×
    /// (1 − wire factor). Exactly 0.0 at the default 16-bit tuning.
    pub wire_saved_bytes: f64,
    /// Modeled collective seconds hidden behind compute by the tuning's
    /// overlap factor, summed over every replica's engine. Exactly 0.0
    /// at the default (no-overlap) tuning.
    pub hidden_comm_s: f64,
    /// DES loop iterations executed (event deliveries + replica
    /// advances): a deterministic measure of simulation work, the
    /// numerator behind the CLI's advisory events/sec rate.
    pub events: u64,
}

impl FleetSummary {
    /// Goodput under `slo`: the fraction of *offered* requests that
    /// completed without error with per-request model-time latencies
    /// inside every set target (the p95 targets double as per-request
    /// bounds). Failed, rejected, and SLO-busting requests all count
    /// against it — the serving-under-churn headline number: a fleet
    /// that technically completes everything but blows its latency
    /// budget on every retried request gets the score it deserves.
    pub fn goodput(&self, slo: &SloTarget) -> f64 {
        if self.requests == 0 {
            return 0.0;
        }
        let good = self
            .per_request
            .iter()
            .filter(|m| {
                m.error.is_none()
                    && m.model.as_ref().is_some_and(|t| slo.met_by_request(t))
            })
            .count();
        good as f64 / self.requests as f64
    }
}

/// SLO targets for capacity planning (each axis optional; p95s).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SloTarget {
    pub ttft_p95_s: Option<f64>,
    pub tpot_p95_s: Option<f64>,
    pub e2e_p95_s: Option<f64>,
}

fn within(target: Option<f64>, got: f64) -> bool {
    match target {
        Some(t) => got <= t,
        None => true,
    }
}

impl SloTarget {
    /// Whether a run's model-time percentiles meet every set target.
    pub fn met_by(&self, m: &ModelServeSummary) -> bool {
        within(self.ttft_p95_s, m.ttft.p95_s)
            && within(self.tpot_p95_s, m.tpot.p95_s)
            && within(self.e2e_p95_s, m.e2e.p95_s)
    }

    /// Whether one request's model-time latencies meet every set target
    /// — the per-request criterion behind [`FleetSummary::goodput`].
    pub fn met_by_request(&self, t: &ModelRequestTimes) -> bool {
        within(self.ttft_p95_s, t.ttft_s)
            && within(self.tpot_p95_s, t.tpot_s)
            && within(self.e2e_p95_s, t.e2e_s)
    }
}

/// One candidate of a capacity sweep.
#[derive(Debug, Clone)]
pub struct FleetCandidate {
    pub spec: FleetSpec,
    pub summary: FleetSummary,
    /// Every request completed and every set SLO target is met.
    pub meets_slo: bool,
}

/// Simulate every candidate fleet against one workload (same seed — the
/// comparisons are paired), one OS thread per candidate.
///
/// Candidate simulations share no mutable state and each is
/// deterministic per `(spec, workload, seed)`, so running them
/// concurrently changes nothing observable: results come back in spec
/// order with every modeled number bitwise-identical to
/// [`capacity_sweep_sequential`] (a test and a CI byte-diff hold the two
/// paths to that).
pub fn capacity_sweep(
    specs: Vec<FleetSpec>,
    workload: &WorkloadSpec,
    seed: u64,
    target: SloTarget,
) -> crate::Result<Vec<FleetCandidate>> {
    std::thread::scope(|s| {
        let handles: Vec<_> = specs
            .into_iter()
            .map(|spec| s.spawn(move || sweep_one(spec, workload, seed, target)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("sweep thread panicked"))
            .collect()
    })
}

/// [`capacity_sweep`] on the calling thread. Kept alongside the threaded
/// path so byte-identity between the two stays checkable (the CLI's
/// `--sweep sequential` escape hatch routes here).
pub fn capacity_sweep_sequential(
    specs: Vec<FleetSpec>,
    workload: &WorkloadSpec,
    seed: u64,
    target: SloTarget,
) -> crate::Result<Vec<FleetCandidate>> {
    specs.into_iter().map(|spec| sweep_one(spec, workload, seed, target)).collect()
}

fn sweep_one(
    spec: FleetSpec,
    workload: &WorkloadSpec,
    seed: u64,
    target: SloTarget,
) -> crate::Result<FleetCandidate> {
    let summary = spec.simulate(workload, seed)?;
    let meets_slo = summary.failed == 0
        && summary.completed == summary.requests
        && target.met_by(&summary.model);
    Ok(FleetCandidate { spec, summary, meets_slo })
}

/// The cheapest (fewest GPUs) candidate meeting its SLO, if any; ties
/// resolve to the earliest candidate.
pub fn cheapest(candidates: &[FleetCandidate]) -> Option<&FleetCandidate> {
    candidates.iter().filter(|c| c.meets_slo).min_by_key(|c| c.spec.total_gpus())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::Deployment;
    use crate::workload::{ArrivalProcess, LengthDist};

    fn tiny_plan(tp: usize, pp: usize) -> DeploymentPlan {
        Deployment::builder().model("tiny").tp(tp).pp(pp).workload(8, 4).build().unwrap()
    }

    fn workload(requests: usize, rate: f64) -> WorkloadSpec {
        WorkloadSpec {
            arrivals: ArrivalProcess::poisson(rate),
            prompt: LengthDist::Fixed(8),
            decode: LengthDist::Fixed(4),
            prefix: None,
            requests,
        }
    }

    #[test]
    fn spec_validation() {
        let plan = tiny_plan(2, 1);
        assert!(matches!(
            FleetSpec::colocated(&plan, 0).unwrap_err(),
            PlanError::ZeroDegree { .. }
        ));
        assert!(matches!(
            FleetSpec::disaggregated(&plan, 0, &plan, 1).unwrap_err(),
            PlanError::DisaggPoolMissing { pool: "prefill" }
        ));
        assert!(matches!(
            FleetSpec::disaggregated(&plan, 1, &plan, 0).unwrap_err(),
            PlanError::DisaggPoolMissing { pool: "decode" }
        ));
        // Heterogeneous layouts of one model compose; different models
        // do not.
        let spec = FleetSpec::colocated(&plan, 2).unwrap();
        let spec = spec.add_replicas(&tiny_plan(1, 2), 1).unwrap();
        assert_eq!(spec.replica_count(), 3);
        assert_eq!(spec.total_gpus(), 2 + 2 + 2);
        let other = Deployment::builder().model("8b").tp(2).build().unwrap();
        assert!(matches!(
            FleetSpec::colocated(&plan, 1).unwrap().add_replicas(&other, 1).unwrap_err(),
            PlanError::FleetArchMismatch { .. }
        ));
        // Disaggregated specs cannot also take colocated replicas.
        let d = FleetSpec::disaggregated(&plan, 1, &tiny_plan(1, 2), 1).unwrap();
        assert!(d.is_disaggregated());
        assert!(matches!(
            d.add_replicas(&plan, 1).unwrap_err(),
            PlanError::FleetMixedRoles
        ));
        assert!(matches!(
            FleetSpec::colocated(&plan, 1).unwrap().with_gpus_per_node(0).unwrap_err(),
            PlanError::ZeroDegree { .. }
        ));
        // Degenerate prefix-cache configs are rejected.
        let cache0 = PrefixCacheConfig { block_tokens: 0, capacity_bytes: 1 << 20 };
        assert!(matches!(
            FleetSpec::colocated(&plan, 1).unwrap().with_prefix_cache(cache0).unwrap_err(),
            PlanError::ZeroDegree { .. }
        ));
        let cap0 = PrefixCacheConfig { block_tokens: 16, capacity_bytes: 0 };
        assert!(matches!(
            FleetSpec::colocated(&plan, 1).unwrap().with_prefix_cache(cap0).unwrap_err(),
            PlanError::ZeroDegree { .. }
        ));
    }

    #[test]
    fn fault_spec_validates_against_replica_count_and_marks_the_label() {
        let plan = tiny_plan(2, 1);
        let spec = FleetSpec::colocated(&plan, 2).unwrap();
        assert!(matches!(
            spec.clone().with_faults(FaultSpec::none().with_straggler(5, 2.0)).unwrap_err(),
            PlanError::FaultReplicaOutOfRange { replica: 5, replicas: 2 }
        ));
        let spec = spec.with_faults(FaultSpec::none().with_straggler(1, 2.0)).unwrap();
        assert!(spec.label().ends_with("[round-robin +faults]"), "{}", spec.label());
    }

    #[test]
    fn zero_fault_spec_is_bitwise_identical_and_stragglers_slow_the_fleet() {
        let spec = FleetSpec::colocated(&tiny_plan(2, 1), 2).unwrap();
        let wl = workload(12, 2000.0);
        let healthy = spec.clone().simulate(&wl, 7).unwrap();
        // An explicit all-healthy FaultSpec is a bitwise no-op.
        let none =
            spec.clone().with_faults(FaultSpec::none()).unwrap().simulate(&wl, 7).unwrap();
        assert_eq!(healthy.model, none.model);
        assert_eq!(none.retries, 0);
        assert_eq!(none.wasted_prefill_s, 0.0);
        // Slowing every replica's fabric 4x strictly lengthens the run
        // (tiny TP=2 pays AllReduces every layer).
        let slow = spec
            .with_faults(FaultSpec::none().with_straggler(0, 4.0).with_straggler(1, 4.0))
            .unwrap()
            .simulate(&wl, 7)
            .unwrap();
        assert!(
            slow.model.makespan_s > healthy.model.makespan_s,
            "straggler fleet must be slower: {} vs {}",
            slow.model.makespan_s,
            healthy.model.makespan_s
        );
    }

    #[test]
    fn shared_prefix_workload_hits_caches_and_saves_priced_prefill() {
        use crate::workload::PrefixProfile;
        let wl = WorkloadSpec {
            arrivals: ArrivalProcess::poisson(2000.0),
            prompt: LengthDist::Fixed(24),
            decode: LengthDist::Fixed(4),
            prefix: Some(PrefixProfile::SystemPrompt { shared: 16 }),
            requests: 8,
        };
        let cache = PrefixCacheConfig { block_tokens: 8, capacity_bytes: 64 << 20 };
        let spec = FleetSpec::colocated(&tiny_plan(2, 1), 1)
            .unwrap()
            .with_prefix_cache(cache)
            .unwrap()
            .with_router(RouterPolicy::CacheAffinity);
        assert!(spec.label().ends_with("[affinity +pfx]"), "{}", spec.label());
        let s = spec.simulate(&wl, 3).unwrap();
        assert_eq!(s.completed, 8);
        // First request is cold; every later one hits the 16-token system
        // prompt (both full blocks of it).
        let misses = s.per_request.iter().filter(|m| m.cached_prompt_tokens == 0).count();
        assert_eq!(misses, 1, "only the first request prefills the system prompt");
        let cm = tiny_plan(2, 1).cost_model();
        for m in &s.per_request {
            if m.cached_prompt_tokens > 0 {
                assert_eq!(m.cached_prompt_tokens, 16);
                assert_eq!(
                    m.saved_prefill_s,
                    cm.prefill_price(24) - cm.prefill_price(8),
                    "request {}",
                    m.request_id
                );
                assert!(m.saved_prefill_bytes > 0.0);
            }
        }
        assert_eq!(s.cached_prompt_tokens, 7 * 16);
        assert_eq!(s.replicas[0].cached_tokens, 7 * 16);
        let per_request_sum: f64 = s.per_request.iter().map(|m| m.saved_prefill_s).sum();
        assert_eq!(s.saved_prefill_s, per_request_sum, "summary = completion-order sum");
        // Without caches the same workload saves nothing and runs
        // strictly slower on makespan (the prefills are all paid).
        let cold = FleetSpec::colocated(&tiny_plan(2, 1), 1)
            .unwrap()
            .simulate(&wl, 3)
            .unwrap();
        assert_eq!(cold.cached_prompt_tokens, 0);
        assert_eq!(cold.saved_prefill_s, 0.0);
        assert!(s.model.makespan_s < cold.model.makespan_s, "hits shorten the run");
    }

    #[test]
    fn labels_group_replicas() {
        let spec = FleetSpec::colocated(&tiny_plan(2, 1), 2).unwrap();
        assert_eq!(spec.label(), "2x tiny-llama TP=2 PP=1 [round-robin]");
        let spec = FleetSpec::disaggregated(&tiny_plan(2, 1), 1, &tiny_plan(1, 2), 2)
            .unwrap()
            .with_router(RouterPolicy::LeastOutstandingTokens);
        assert_eq!(
            spec.label(),
            "prefill 1x tiny-llama TP=2 PP=1 + decode 2x tiny-llama TP=1 PP=2 [least-tokens]"
        );
    }

    #[test]
    fn colocated_fleet_serves_everything_deterministically() {
        let spec = FleetSpec::colocated(&tiny_plan(2, 1), 2)
            .unwrap()
            .with_router(RouterPolicy::RoundRobin);
        let wl = workload(12, 2000.0);
        let a = spec.simulate(&wl, 7).unwrap();
        assert_eq!(a.requests, 12);
        assert_eq!(a.completed, 12);
        assert_eq!(a.failed, 0);
        assert_eq!(a.total_tokens, 12 * 4);
        assert!(a.model.makespan_s > 0.0 && a.model.tokens_per_s > 0.0);
        assert_eq!(a.kv_transfer_bytes, 0.0, "colocated fleets ship no KV");
        assert!(a.comm_bytes > 0.0);
        // Round-robin splits 12 arrivals 6/6.
        assert_eq!(a.replicas[0].assigned, 6);
        assert_eq!(a.replicas[1].assigned, 6);
        assert_eq!(a.replicas.iter().map(|r| r.tokens).sum::<usize>(), 48);
        let b = spec.simulate(&wl, 7).unwrap();
        assert_eq!(a.model, b.model, "same seed -> bitwise-identical model summary");
        let c = spec.simulate(&wl, 8).unwrap();
        assert_ne!(a.model, c.model, "different seed shifts the arrival process");
    }

    #[test]
    fn disaggregated_fleet_prices_kv_handoffs() {
        let spec = FleetSpec::disaggregated(&tiny_plan(2, 1), 1, &tiny_plan(1, 2), 1).unwrap();
        let wl = workload(6, 1000.0);
        let s = spec.simulate(&wl, 3).unwrap();
        assert_eq!(s.completed, 6);
        assert_eq!(s.total_tokens, 6 * 4, "disagg serves the same token budget");
        assert!(s.kv_transfer_bytes > 0.0);
        assert!(s.kv_transfer_s > 0.0);
        for m in &s.per_request {
            assert!(m.kv_transfer_bytes > 0.0, "every request ships its KV once");
            assert_eq!(m.decode_replica, Some(1));
            let t = m.model.as_ref().unwrap();
            assert!(t.ttft_s > 0.0 && t.e2e_s >= t.ttft_s);
        }
        // Prefill pool generated exactly one token per request.
        assert_eq!(s.replicas[0].tokens, 6);
        assert_eq!(s.replicas[1].tokens, 6 * 3);
    }

    #[test]
    fn autoscale_spec_validation_and_label() {
        use crate::autoscale::AutoscalePolicy;
        let plan = tiny_plan(2, 1);
        // The policy ceiling must equal the spec's (maximum) pool.
        let spec = FleetSpec::colocated(&plan, 2).unwrap();
        assert!(matches!(
            spec.clone()
                .with_autoscale(AutoscalePolicy::target_queue(1, 4, 4.0, 0.1))
                .unwrap_err(),
            PlanError::AutoscaleReplicaMismatch { max_replicas: 4, replicas: 2 }
        ));
        // Degenerate policies are rejected through the same validator.
        assert!(matches!(
            spec.clone()
                .with_autoscale(AutoscalePolicy::target_queue(0, 2, 4.0, 0.1))
                .unwrap_err(),
            PlanError::AutoscaleBoundsInvalid { .. }
        ));
        // Elastic disaggregated pools are a roadmap follow-on.
        let d = FleetSpec::disaggregated(&plan, 1, &tiny_plan(1, 2), 1).unwrap();
        assert!(matches!(
            d.with_autoscale(AutoscalePolicy::target_queue(1, 2, 4.0, 0.1)).unwrap_err(),
            PlanError::AutoscaleDisaggUnsupported
        ));
        let spec = spec
            .with_autoscale(AutoscalePolicy::target_queue(1, 2, 4.0, 0.1))
            .unwrap();
        assert!(spec.label().ends_with("[round-robin +auto[1..2]]"), "{}", spec.label());
        assert_eq!(spec.autoscale().unwrap().max_replicas, 2);
    }

    #[test]
    fn never_acting_policy_is_bitwise_identical_to_the_static_fleet() {
        let spec = FleetSpec::colocated(&tiny_plan(2, 1), 2).unwrap();
        let wl = workload(12, 2000.0);
        let stat = spec.clone().simulate(&wl, 7).unwrap();
        // min == max (no parked pool to grow into, no floor to drain
        // toward) and unreachable thresholds: the controller ticks but
        // every decision is Hold.
        let policy = crate::autoscale::AutoscalePolicy::target_queue(2, 2, 1e9, 0.05);
        let auto = spec.with_autoscale(policy).unwrap().simulate(&wl, 7).unwrap();
        assert_eq!(stat.model, auto.model, "no-op ticks must not perturb the DES");
        assert_eq!(
            stat.replicas.iter().map(|r| r.assigned).collect::<Vec<_>>(),
            auto.replicas.iter().map(|r| r.assigned).collect::<Vec<_>>(),
        );
        assert_eq!(auto.cold_starts, 0);
        assert_eq!(auto.migrations, 0);
        assert_eq!(auto.kv_migration_bytes, 0.0);
        // Both fleets pay full static provisioning: every GPU from t=0
        // to the end of the run.
        let end = stat
            .per_request
            .iter()
            .filter_map(|m| m.model.as_ref())
            .map(|t| t.finished_at_s)
            .fold(0.0f64, f64::max);
        assert_eq!(stat.provisioned_gpu_s, 4.0 * end);
        assert_eq!(auto.provisioned_gpu_s, stat.provisioned_gpu_s);
    }

    #[test]
    fn elastic_fleet_pays_cold_starts_and_provisions_the_second_replica_late() {
        // One standing replica, one parked; a hot open loop forces a
        // scale-up whose cold start and late provisioning both show up
        // in the summary.
        let policy = crate::autoscale::AutoscalePolicy::target_queue(1, 2, 0.5, 0.02)
            .without_migration();
        let spec = FleetSpec::colocated(&tiny_plan(2, 1), 2)
            .unwrap()
            .with_router(RouterPolicy::LeastOutstandingTokens)
            .with_autoscale(policy)
            .unwrap();
        let wl = workload(24, 3000.0);
        let s = spec.simulate(&wl, 11).unwrap();
        assert_eq!(s.completed, 24);
        assert_eq!(s.failed, 0);
        assert!(s.cold_starts >= 1, "hot loop must trigger a scale-up");
        assert!(s.cold_start_s > 0.0);
        assert!(
            s.replicas[1].provisioned_s > 0.0,
            "the spawned replica holds GPUs from its activation"
        );
        assert!(
            s.replicas[1].provisioned_s < s.replicas[0].provisioned_s,
            "the second replica was provisioned strictly later: {} vs {}",
            s.replicas[1].provisioned_s,
            s.replicas[0].provisioned_s
        );
        let end = s
            .per_request
            .iter()
            .filter_map(|m| m.model.as_ref())
            .map(|t| t.finished_at_s)
            .fold(0.0f64, f64::max);
        assert!(
            s.provisioned_gpu_s < 4.0 * end,
            "elastic provisioning undercuts static max-N over the same span"
        );
        // Elasticity is deterministic per seed like everything else.
        let t = spec.simulate(&wl, 11).unwrap();
        assert_eq!(s.model, t.model);
        assert_eq!(s.cold_starts, t.cold_starts);
        assert_eq!(s.provisioned_gpu_s, t.provisioned_gpu_s);
    }

    #[test]
    fn retried_requests_anchor_e2e_at_the_first_arrival() {
        // Regression for the retry path's timekeeping: queued requests
        // drained off a dead replica lose their scheduler enqueue
        // instants, so the fleet must anchor a retry's queue/E2E on
        // `Pending.arrival_s` — never on resubmission time.
        //
        // The DES is bitwise-deterministic up to the first fault event,
        // so a healthy baseline run tells us exactly when replica 0 is
        // mid-service: kill it halfway through its last request's
        // lifetime and that request is guaranteed to be displaced.
        let wl = workload(12, 2000.0);
        let healthy =
            FleetSpec::colocated(&tiny_plan(2, 1), 2).unwrap().simulate(&wl, 7).unwrap();
        let target = healthy
            .per_request
            .iter()
            .filter(|m| m.replica == 0)
            .filter_map(|m| m.model.as_ref())
            .max_by(|a, b| a.finished_at_s.total_cmp(&b.finished_at_s))
            .expect("round-robin routes half the requests to replica 0");
        let arrival = target.finished_at_s - target.e2e_s;
        let outage_at = (arrival + target.finished_at_s) / 2.0;
        let spec = FleetSpec::colocated(&tiny_plan(2, 1), 2)
            .unwrap()
            .with_faults(FaultSpec::none().with_outage(0, outage_at, 1e3))
            .unwrap();
        let s = spec.simulate(&wl, 7).unwrap();
        assert_eq!(s.requests, 12);
        assert!(s.retries >= 1, "the outage must displace at least one request");
        let retried: Vec<_> = s
            .per_request
            .iter()
            .filter(|m| m.retries > 0 && m.error.is_none())
            .collect();
        assert!(!retried.is_empty(), "a displaced request must complete on replica 1");
        for m in &retried {
            let t = m.model.as_ref().unwrap();
            let derived_arrival = t.finished_at_s - t.e2e_s;
            assert!(
                derived_arrival < outage_at + 1e-9,
                "request {}: E2E must span from the pre-outage arrival \
                 (derived arrival {derived_arrival}, outage at {outage_at})",
                m.request_id
            );
            assert!(t.queue_s > 0.0 && t.e2e_s >= t.queue_s);
        }
    }

    #[test]
    fn chunk_budget_at_or_above_every_prompt_is_bitwise_identical() {
        // A budget no prompt exceeds must branch onto the one-shot
        // prefill code path everywhere — same modeled clocks, bitwise.
        let plain = FleetSpec::colocated(&tiny_plan(2, 1), 2).unwrap();
        let roomy_plan = Deployment::builder()
            .model("tiny")
            .tp(2)
            .workload(8, 4)
            .chunked_prefill(64)
            .build()
            .unwrap();
        let roomy = FleetSpec::colocated(&roomy_plan, 2).unwrap();
        let wl = workload(12, 2000.0);
        let a = plain.simulate(&wl, 7).unwrap();
        let b = roomy.simulate(&wl, 7).unwrap();
        assert_eq!(a.model, b.model, "an idle chunk budget must not reprice anything");
        assert_eq!(b.chunked_requests, 0);
        assert!(b.per_request.iter().all(|m| m.prefill_chunks == 1));
        assert_eq!(a.interference_s, b.interference_s, "same stalls either way");
    }

    #[test]
    fn chunked_fleet_splits_prefills_and_stays_deterministic() {
        let plan = Deployment::builder()
            .model("tiny")
            .tp(2)
            .workload(48, 4)
            .chunked_prefill(16)
            .build()
            .unwrap();
        let spec = FleetSpec::colocated(&plan, 2).unwrap();
        let wl = WorkloadSpec {
            arrivals: ArrivalProcess::poisson(2000.0),
            prompt: LengthDist::Fixed(48),
            decode: LengthDist::Fixed(4),
            prefix: None,
            requests: 12,
        };
        let a = spec.simulate(&wl, 7).unwrap();
        assert_eq!(a.completed, 12);
        assert_eq!(a.chunked_requests, 12, "every 48-token prompt splits on a 16-token budget");
        for m in &a.per_request {
            assert_eq!(m.prefill_chunks, 3, "ceil(48 / 16) chunks, request {}", m.request_id);
            assert!(m.interference_s >= 0.0);
        }
        // Chunking on is as deterministic per seed as chunking off.
        let b = spec.simulate(&wl, 7).unwrap();
        assert_eq!(a.model, b.model, "same seed, same chunked schedule, bitwise");
        assert_eq!(a.interference_s, b.interference_s);
        assert_eq!(a.chunked_requests, b.chunked_requests);
    }

    #[test]
    fn clock_index_min_matches_the_brute_force_scan() {
        // Drive the index with a deterministic pseudo-random update
        // stream (splitmix64) and check `min()` against a rescan of the
        // mirror after every step — including ties, +0.0/-0.0, and
        // re-idling entries.
        let mut x = 0x9E3779B97F4A7C15u64;
        let mut next = move || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let n = 9;
        let mut idx = ClockIndex::new(n);
        let mut mirror: Vec<Option<f64>> = vec![None; n];
        for _ in 0..4000 {
            let i = (next() % n as u64) as usize;
            let clock = match next() % 4 {
                0 => None,
                1 => Some(0.0 * if next() % 2 == 0 { 1.0 } else { -1.0 }),
                // Coarse quantization to force plenty of exact ties.
                _ => Some((next() % 16) as f64 * 0.125),
            };
            idx.set(i, clock);
            mirror[i] = clock;
            let brute = mirror
                .iter()
                .enumerate()
                .filter_map(|(j, c)| c.map(|c| (j, c)))
                .min_by(|a, b| a.1.total_cmp(&b.1).then_with(|| a.0.cmp(&b.0)));
            let got = idx.min();
            assert_eq!(
                got.map(|(j, c)| (j, c.to_bits())),
                brute.map(|(j, c)| (j, c.to_bits())),
                "index diverged from the brute-force scan"
            );
        }
    }

    #[test]
    fn threaded_capacity_sweep_matches_sequential_bitwise() {
        let specs = || {
            vec![
                FleetSpec::colocated(&tiny_plan(2, 1), 1).unwrap(),
                FleetSpec::colocated(&tiny_plan(2, 1), 2)
                    .unwrap()
                    .with_router(RouterPolicy::LeastOutstandingTokens),
                FleetSpec::disaggregated(&tiny_plan(2, 1), 1, &tiny_plan(1, 2), 1).unwrap(),
            ]
        };
        let wl = workload(10, 1500.0);
        let target = SloTarget { e2e_p95_s: Some(10.0), ..Default::default() };
        let seq = capacity_sweep_sequential(specs(), &wl, 7, target).unwrap();
        let thr = capacity_sweep(specs(), &wl, 7, target).unwrap();
        assert_eq!(seq.len(), thr.len());
        for (a, b) in seq.iter().zip(&thr) {
            assert_eq!(
                format!("{a:?}"),
                format!("{b:?}"),
                "threaded sweep must match the sequential path bitwise"
            );
        }
    }
}
