//! Request routing across fleet replicas.
//!
//! The router is the fleet's only stateful dispatch decision, so every
//! policy is deliberately deterministic: ties break toward the lowest
//! replica index, and round-robin keeps a single cursor. Given the same
//! replica-load snapshots, the same policy always produces the same
//! assignment sequence — a precondition for the fleet simulator's
//! bitwise per-seed reproducibility.

/// How many outstanding tokens of load one estimated prefix-hit token
/// offsets under [`RouterPolicy::CacheAffinity`]. A hit token saves the
/// whole prefill work of that token *plus* its TP AllReduce share, while
/// an outstanding token is mostly cheap decode work — so cache affinity
/// is worth trading several queued tokens for, but not a collapsed
/// replica: past this ratio the policy falls back to load balancing.
/// (At 8, a typical shared prefix outweighs a handful of queued
/// requests, which keeps conversation→replica pinning stable through
/// transient imbalance without ever overriding real overload.)
pub const CACHE_AFFINITY_HIT_WEIGHT: i64 = 8;

/// Dispatch policy over a pool of replicas.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterPolicy {
    /// Cycle through replicas regardless of their load.
    RoundRobin,
    /// Pick the replica with the fewest outstanding tokens (un-prefilled
    /// prompt + still-to-generate decode) — a work-aware least-loaded
    /// policy.
    LeastOutstandingTokens,
    /// Pick the replica with the fewest queued + in-flight requests.
    ShortestQueue,
    /// Cache-affinity: blend the replica's estimated prefix-hit tokens
    /// for *this* request ([`ReplicaLoad::prefix_hit_tokens`]) with its
    /// outstanding-token load — minimize
    /// `outstanding − HIT_WEIGHT · hit`. With no hits anywhere (a
    /// prefix-free workload, or no prefix caches configured) this is
    /// exactly [`RouterPolicy::LeastOutstandingTokens`], assignment for
    /// assignment.
    CacheAffinity,
}

impl RouterPolicy {
    pub fn label(&self) -> &'static str {
        match self {
            Self::RoundRobin => "round-robin",
            Self::LeastOutstandingTokens => "least-tokens",
            Self::ShortestQueue => "shortest-queue",
            Self::CacheAffinity => "affinity",
        }
    }

    /// Parse a CLI spelling (`rr`, `least-tokens`, `sq`, `affinity`, ...).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "rr" | "round-robin" | "roundrobin" => Some(Self::RoundRobin),
            "lot" | "least-tokens" | "least-outstanding-tokens" => {
                Some(Self::LeastOutstandingTokens)
            }
            "sq" | "shortest-queue" => Some(Self::ShortestQueue),
            "ca" | "affinity" | "cache-affinity" => Some(Self::CacheAffinity),
            _ => None,
        }
    }

    /// Whether the policy reads [`ReplicaLoad::prefix_hit_tokens`] — the
    /// fleet loop only computes per-request hit estimates when asked.
    pub fn wants_prefix_estimates(&self) -> bool {
        matches!(self, Self::CacheAffinity)
    }
}

/// Load snapshot of one replica at routing time.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ReplicaLoad {
    /// Queued + admitted requests on the replica.
    pub queue_depth: usize,
    /// Tokens accepted but not yet processed: un-prefilled prompt tokens
    /// plus still-to-generate decode tokens.
    pub outstanding_tokens: usize,
    /// Estimated prompt tokens of the request *being routed* that this
    /// replica's prefix cache already holds (0 without a cache). Unlike
    /// the other fields this is per-(replica, request), not per-replica.
    pub prefix_hit_tokens: usize,
}

/// A policy plus its dispatch state (the round-robin cursor).
#[derive(Debug, Clone)]
pub struct Router {
    policy: RouterPolicy,
    next_rr: usize,
}

impl Router {
    pub fn new(policy: RouterPolicy) -> Self {
        Self { policy, next_rr: 0 }
    }

    pub fn policy(&self) -> RouterPolicy {
        self.policy
    }

    /// Pick a replica index within `loads`. Ties break toward the lowest
    /// index; round-robin ignores the loads entirely.
    pub fn route(&mut self, loads: &[ReplicaLoad]) -> usize {
        assert!(!loads.is_empty(), "router needs at least one replica");
        match self.policy {
            RouterPolicy::RoundRobin => {
                let i = self.next_rr % loads.len();
                self.next_rr = self.next_rr.wrapping_add(1);
                i
            }
            RouterPolicy::LeastOutstandingTokens => {
                argmin_by(loads, |l| l.outstanding_tokens as i64)
            }
            RouterPolicy::ShortestQueue => argmin_by(loads, |l| l.queue_depth as i64),
            RouterPolicy::CacheAffinity => argmin_by(loads, |l| {
                l.outstanding_tokens as i64
                    - CACHE_AFFINITY_HIT_WEIGHT * l.prefix_hit_tokens as i64
            }),
        }
    }

    /// [`Self::route`] with down replicas masked out: `alive[i]` gates
    /// `loads[i]`, and the pick is an index into `loads` (never a dead
    /// replica). Returns `None` when every replica is down — the fleet
    /// strands the request until a recovery event. With all replicas
    /// alive this is exactly [`Self::route`], pick for pick, cursor for
    /// cursor — the fault-free path stays bitwise identical. Round-robin
    /// cycles over the *live* pool, so a down replica's turns fall to its
    /// successors instead of queueing behind a dead socket.
    pub fn route_masked(&mut self, loads: &[ReplicaLoad], alive: &[bool]) -> Option<usize> {
        assert_eq!(loads.len(), alive.len(), "one alive flag per replica load");
        // Allocation-free masking (this runs once per routed request):
        // the policies walk the mask in place instead of densifying the
        // live pool into temporary vectors. Because the dense copy
        // enumerated live replicas in ascending index order, "k-th live
        // index" and "(key, index)-argmin over live entries" reproduce
        // the old picks exactly.
        let n_live = alive.iter().filter(|&&a| a).count();
        if n_live == 0 {
            return None;
        }
        match self.policy {
            RouterPolicy::RoundRobin => {
                let k = self.next_rr % n_live;
                self.next_rr = self.next_rr.wrapping_add(1);
                (0..loads.len()).filter(|&i| alive[i]).nth(k)
            }
            RouterPolicy::LeastOutstandingTokens => {
                argmin_masked(loads, alive, |l| l.outstanding_tokens as i64)
            }
            RouterPolicy::ShortestQueue => {
                argmin_masked(loads, alive, |l| l.queue_depth as i64)
            }
            RouterPolicy::CacheAffinity => argmin_masked(loads, alive, |l| {
                l.outstanding_tokens as i64
                    - CACHE_AFFINITY_HIT_WEIGHT * l.prefix_hit_tokens as i64
            }),
        }
    }
}

/// Index of the smallest key; ties resolve to the lowest index.
fn argmin_by(loads: &[ReplicaLoad], key: impl Fn(&ReplicaLoad) -> i64) -> usize {
    loads
        .iter()
        .enumerate()
        .min_by_key(|(i, l)| (key(l), *i))
        .map(|(i, _)| i)
        .expect("non-empty pool")
}

/// [`argmin_by`] over the live entries only; `None` with none alive.
fn argmin_masked(
    loads: &[ReplicaLoad],
    alive: &[bool],
    key: impl Fn(&ReplicaLoad) -> i64,
) -> Option<usize> {
    loads
        .iter()
        .enumerate()
        .filter(|&(i, _)| alive[i])
        .min_by_key(|(i, l)| (key(l), *i))
        .map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn load(queue_depth: usize, outstanding_tokens: usize) -> ReplicaLoad {
        ReplicaLoad { queue_depth, outstanding_tokens, prefix_hit_tokens: 0 }
    }

    fn hit(outstanding_tokens: usize, prefix_hit_tokens: usize) -> ReplicaLoad {
        ReplicaLoad { queue_depth: 0, outstanding_tokens, prefix_hit_tokens }
    }

    #[test]
    fn round_robin_cycles_independent_of_load() {
        let mut r = Router::new(RouterPolicy::RoundRobin);
        let loads = [load(9, 900), load(0, 0), load(5, 50)];
        let picks: Vec<usize> = (0..7).map(|_| r.route(&loads)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2, 0]);
    }

    #[test]
    fn least_tokens_and_shortest_queue_pick_minima_with_low_index_ties() {
        let mut lot = Router::new(RouterPolicy::LeastOutstandingTokens);
        assert_eq!(lot.route(&[load(0, 30), load(9, 10), load(0, 20)]), 1);
        assert_eq!(lot.route(&[load(0, 10), load(0, 10)]), 0, "tie -> lowest index");
        let mut sq = Router::new(RouterPolicy::ShortestQueue);
        assert_eq!(sq.route(&[load(3, 0), load(1, 999), load(2, 0)]), 1);
        assert_eq!(sq.route(&[load(2, 0), load(2, 0), load(2, 0)]), 0);
    }

    #[test]
    fn cache_affinity_blends_hits_with_load() {
        let mut ca = Router::new(RouterPolicy::CacheAffinity);
        // Zero hits everywhere: exactly least-outstanding-tokens,
        // including the low-index tie-break.
        assert_eq!(ca.route(&[load(0, 30), load(9, 10), load(0, 20)]), 1);
        assert_eq!(ca.route(&[load(0, 10), load(0, 10)]), 0);
        // A warm replica wins despite a moderately deeper queue: 64 hit
        // tokens offset up to 8*64 = 512 outstanding tokens.
        assert_eq!(ca.route(&[hit(0, 0), hit(400, 64)]), 1);
        // ...but not a collapsed one.
        assert_eq!(ca.route(&[hit(0, 0), hit(600, 64)]), 0);
        // Among equally-loaded replicas the biggest hit wins.
        assert_eq!(ca.route(&[hit(50, 16), hit(50, 48), hit(50, 32)]), 1);
        // Hit ties break toward the lowest index.
        assert_eq!(ca.route(&[hit(50, 32), hit(50, 32)]), 0);
    }

    #[test]
    fn masked_routing_skips_down_replicas_and_matches_unmasked_when_healthy() {
        let loads = [load(0, 30), load(9, 10), load(0, 20)];
        for policy in [
            RouterPolicy::RoundRobin,
            RouterPolicy::LeastOutstandingTokens,
            RouterPolicy::ShortestQueue,
            RouterPolicy::CacheAffinity,
        ] {
            // All-alive masking is the identity — same picks, same cursor.
            let mut plain = Router::new(policy);
            let mut masked = Router::new(policy);
            for _ in 0..5 {
                assert_eq!(
                    masked.route_masked(&loads, &[true, true, true]),
                    Some(plain.route(&loads)),
                    "{policy:?} diverged under an all-alive mask"
                );
            }
            // Everything down: the request has nowhere to go.
            assert_eq!(masked.route_masked(&loads, &[false, false, false]), None);
        }
        // The load minimum is down: the pick skips to the live runner-up.
        let mut lot = Router::new(RouterPolicy::LeastOutstandingTokens);
        assert_eq!(lot.route_masked(&loads, &[true, false, true]), Some(2));
        // Round-robin cycles over the live pool only.
        let mut rr = Router::new(RouterPolicy::RoundRobin);
        let picks: Vec<_> =
            (0..4).map(|_| rr.route_masked(&loads, &[true, false, true]).unwrap()).collect();
        assert_eq!(picks, vec![0, 2, 0, 2]);
    }

    #[test]
    fn parse_accepts_cli_spellings() {
        assert_eq!(RouterPolicy::parse("rr"), Some(RouterPolicy::RoundRobin));
        assert_eq!(
            RouterPolicy::parse("least-tokens"),
            Some(RouterPolicy::LeastOutstandingTokens)
        );
        assert_eq!(RouterPolicy::parse("shortest-queue"), Some(RouterPolicy::ShortestQueue));
        assert_eq!(RouterPolicy::parse("sq"), Some(RouterPolicy::ShortestQueue));
        assert_eq!(RouterPolicy::parse("affinity"), Some(RouterPolicy::CacheAffinity));
        assert_eq!(RouterPolicy::parse("cache-affinity"), Some(RouterPolicy::CacheAffinity));
        assert_eq!(RouterPolicy::parse("ca"), Some(RouterPolicy::CacheAffinity));
        assert_eq!(RouterPolicy::parse("bogus"), None);
        assert!(RouterPolicy::CacheAffinity.wants_prefix_estimates());
        assert!(!RouterPolicy::RoundRobin.wants_prefix_estimates());
    }
}
