//! H100 roofline compute model.
//!
//! Prefill processes `S_p` tokens in parallel → large GEMMs → FLOP-bound:
//! `time = flops / (peak · eff_prefill)`. Decode processes one token →
//! GEMV-shaped → bound by streaming the weights from HBM:
//! `time = weight_bytes / (hbm_bw · eff_decode)`. Both are per-GPU after
//! tensor-parallel sharding by `t`.


use crate::model::ModelArch;

/// Accelerator + efficiency constants (defaults: H100 SXM, BF16).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ComputeModel {
    /// Peak dense BF16 throughput (FLOP/s).
    pub peak_flops: f64,
    /// HBM bandwidth (bytes/s).
    pub hbm_bw: f64,
    /// Achieved fraction of peak for prefill GEMMs.
    pub eff_prefill: f64,
    /// Achieved fraction of HBM bandwidth for decode weight streaming.
    pub eff_decode: f64,
    /// Serving dtype width (bytes).
    pub dtype_bytes: f64,
}

impl Default for ComputeModel {
    fn default() -> Self {
        Self {
            peak_flops: 989e12, // H100 SXM dense BF16
            hbm_bw: 3.35e12,    // HBM3
            eff_prefill: 0.45,
            eff_decode: 0.90,
            dtype_bytes: 2.0,
        }
    }
}

impl ComputeModel {
    /// Weight parameters in one transformer layer.
    pub fn layer_params(arch: &ModelArch) -> f64 {
        let h = arch.hidden as f64;
        let qd = (arch.heads * arch.head_dim) as f64;
        let kvd = (arch.kv_heads * arch.head_dim) as f64;
        h * qd + 2.0 * h * kvd + qd * h + 3.0 * h * arch.intermediate as f64
    }

    /// FLOPs to prefill `s_p` tokens through `layers` layers (GEMM 2·params
    /// per token + quadratic attention term).
    pub fn prefill_flops(&self, arch: &ModelArch, layers: usize, s_p: usize) -> f64 {
        let per_token_gemm = 2.0 * Self::layer_params(arch);
        let attn_quad =
            4.0 * (s_p as f64) * (arch.heads * arch.head_dim) as f64; // per token per layer
        layers as f64 * s_p as f64 * (per_token_gemm + attn_quad)
    }

    /// Prefill wall time of `layers` layers sharded over `t` GPUs (seconds).
    pub fn prefill_time(&self, arch: &ModelArch, layers: usize, s_p: usize, t: usize) -> f64 {
        self.prefill_flops(arch, layers, s_p) / (t as f64 * self.peak_flops * self.eff_prefill)
    }

    /// FLOPs to prefill one chunk of `len` tokens at offset `start` of a
    /// prompt (Sarathi-style chunked prefill): the GEMM term covers only
    /// the chunk's tokens, while each chunk token attends over everything
    /// before it — the quadratic term telescopes as
    /// `(start+len)² − start²`, so summing chunk FLOPs over a full split
    /// reproduces [`Self::prefill_flops`] exactly.
    pub fn prefill_chunk_flops(
        &self,
        arch: &ModelArch,
        layers: usize,
        start: usize,
        len: usize,
    ) -> f64 {
        let per_token_gemm = 2.0 * Self::layer_params(arch);
        let qd = (arch.heads * arch.head_dim) as f64;
        let end = (start + len) as f64;
        let attn = qd * (end * end - (start as f64) * (start as f64));
        layers as f64 * (len as f64 * per_token_gemm + 4.0 * attn)
    }

    /// Wall time of one prefill chunk sharded over `t` GPUs (seconds).
    pub fn prefill_chunk_time(
        &self,
        arch: &ModelArch,
        layers: usize,
        start: usize,
        len: usize,
        t: usize,
    ) -> f64 {
        self.prefill_chunk_flops(arch, layers, start, len)
            / (t as f64 * self.peak_flops * self.eff_prefill)
    }

    /// Decode-step wall time of `layers` layers sharded over `t` GPUs:
    /// stream the local weight shard + the KV cache once from HBM.
    pub fn decode_time(
        &self,
        arch: &ModelArch,
        layers: usize,
        kv_len: usize,
        t: usize,
    ) -> f64 {
        let weight_bytes = Self::layer_params(arch) * layers as f64 * self.dtype_bytes;
        let kv_bytes = (arch.kv_bytes_per_token(self.dtype_bytes as usize) as f64)
            * (layers as f64 / arch.layers as f64)
            * kv_len as f64;
        (weight_bytes + kv_bytes) / (t as f64 * self.hbm_bw * self.eff_decode)
    }

    /// Whole-model decode step on `t` GPUs (all layers).
    pub fn full_decode_time(&self, arch: &ModelArch, kv_len: usize, t: usize) -> f64 {
        self.decode_time(arch, arch.layers, kv_len, t)
    }

    /// Quant + dequant cost of moving `n_bytes` (logical BF16 payload)
    /// through a low-bit wire: both casts stream the tensor through HBM
    /// once, so the pair is priced as two memory-bound passes. Charged per
    /// collective launch when a [`crate::cluster::CollectiveTuning`]
    /// narrows the wire below 16 bits (Flash Communication §3 models the
    /// same fused quantization as bandwidth-bound, arXiv:2412.04964).
    pub fn quant_dequant_time(&self, n_bytes: f64) -> f64 {
        2.0 * n_bytes / (self.hbm_bw * self.eff_decode)
    }

    /// One *batched* decode iteration of `layers` layers sharded over `t`
    /// GPUs: the weight shard streams from HBM once (shared by every
    /// sequence in the batch), each sequence's KV cache streams at its own
    /// context length. A singleton batch `[k]` is exactly
    /// [`Self::decode_time`] at `kv_len = k`.
    pub fn decode_batch_time(
        &self,
        arch: &ModelArch,
        layers: usize,
        kv_lens: &[usize],
        t: usize,
    ) -> f64 {
        let weight_bytes = Self::layer_params(arch) * layers as f64 * self.dtype_bytes;
        let per_token = (arch.kv_bytes_per_token(self.dtype_bytes as usize) as f64)
            * (layers as f64 / arch.layers as f64);
        let kv_bytes: f64 = kv_lens.iter().map(|&k| per_token * k as f64).sum();
        (weight_bytes + kv_bytes) / (t as f64 * self.hbm_bw * self.eff_decode)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_params_match_arch_totals() {
        let arch = ModelArch::llama31_8b();
        let per_layer = ComputeModel::layer_params(&arch);
        let embeddings = 2.0 * (arch.vocab * arch.hidden) as f64;
        let total = per_layer * arch.layers as f64 + embeddings;
        let counted = arch.param_count() as f64;
        assert!((total - counted).abs() / counted < 0.01);
    }

    #[test]
    fn decode_is_memory_bound_sane() {
        // 3B over 2 GPUs: ~3.2 GB/GPU over 3.35 TB/s * 0.9 ≈ 1.05 ms —
        // the right magnitude for the paper's 1.17 ms TPOT at TP=2.
        let cm = ComputeModel::default();
        let t = cm.full_decode_time(&ModelArch::llama32_3b(), 128, 2);
        assert!((0.8e-3..1.4e-3).contains(&t), "decode {t}");
    }

    #[test]
    fn prefill_ms_scale() {
        // 3B, Sp=128 on 2 GPUs at 45% of peak: ~1 ms — prefill compute is
        // NOT the 150 ms TTFT the paper reports; framework overhead is
        // (see calibration.rs).
        let cm = ComputeModel::default();
        let t = cm.prefill_time(&ModelArch::llama32_3b(), 28, 128, 2);
        assert!((0.2e-3..4e-3).contains(&t), "prefill {t}");
    }

    #[test]
    fn sharding_speeds_up_both_phases() {
        let cm = ComputeModel::default();
        let arch = ModelArch::llama2_13b();
        assert!(
            cm.prefill_time(&arch, arch.layers, 128, 8)
                < cm.prefill_time(&arch, arch.layers, 128, 2)
        );
        assert!(cm.full_decode_time(&arch, 128, 8) < cm.full_decode_time(&arch, 128, 2));
    }

    #[test]
    fn decode_time_grows_with_kv_len() {
        let cm = ComputeModel::default();
        let arch = ModelArch::llama31_8b();
        assert!(cm.full_decode_time(&arch, 4096, 1) > cm.full_decode_time(&arch, 1, 1));
    }

    #[test]
    fn quant_dequant_is_two_hbm_passes() {
        let cm = ComputeModel::default();
        let n = 1.0e6;
        let expect = 2.0 * n / (cm.hbm_bw * cm.eff_decode);
        assert_eq!(cm.quant_dequant_time(n), expect);
        assert_eq!(cm.quant_dequant_time(0.0), 0.0);
        // Linear in bytes: doubling the payload doubles the cast cost.
        assert!((cm.quant_dequant_time(2.0 * n) - 2.0 * expect).abs() < 1e-18);
    }

    #[test]
    fn chunk_flops_telescope_to_the_one_shot_prefill() {
        let cm = ComputeModel::default();
        let arch = ModelArch::llama32_3b();
        for (s_p, chunk) in [(128usize, 32usize), (100, 48), (257, 64), (64, 64), (64, 128)] {
            let one_shot = cm.prefill_flops(&arch, arch.layers, s_p);
            let mut sum = 0.0;
            let mut start = 0usize;
            while start < s_p {
                let len = chunk.min(s_p - start);
                sum += cm.prefill_chunk_flops(&arch, arch.layers, start, len);
                start += len;
            }
            // The quadratic attention term telescopes exactly; float
            // summation noise is the only slack.
            assert!(
                (sum - one_shot).abs() / one_shot < 1e-12,
                "Sp={s_p} chunk={chunk}: {sum} vs {one_shot}"
            );
        }
        // A chunk covering the whole prompt is the one-shot formula (up
        // to float association — the serving path never relies on this:
        // an unchunked prompt takes the one-shot code path by branch).
        let whole = cm.prefill_chunk_flops(&arch, arch.layers, 0, 128);
        let one = cm.prefill_flops(&arch, arch.layers, 128);
        assert!((whole - one).abs() / one < 1e-12);
        // Later chunks cost more than earlier equal-length chunks (they
        // attend over more context).
        assert!(
            cm.prefill_chunk_flops(&arch, arch.layers, 96, 32)
                > cm.prefill_chunk_flops(&arch, arch.layers, 0, 32)
        );
        assert!(
            cm.prefill_chunk_time(&arch, arch.layers, 96, 32, 2)
                < cm.prefill_chunk_time(&arch, arch.layers, 96, 32, 1)
        );
    }

    #[test]
    fn batched_decode_time_shares_the_weight_stream() {
        let cm = ComputeModel::default();
        let arch = ModelArch::llama31_8b();
        // Singleton batch is bitwise the single-sequence decode time.
        assert_eq!(
            cm.decode_batch_time(&arch, arch.layers, &[300], 2),
            cm.decode_time(&arch, arch.layers, 300, 2)
        );
        // Four sequences cost more than one but far less than four
        // independent steps (weights stream once).
        let one = cm.decode_batch_time(&arch, arch.layers, &[256], 2);
        let four = cm.decode_batch_time(&arch, arch.layers, &[256; 4], 2);
        assert!(four > one && four < 4.0 * one);
    }
}
