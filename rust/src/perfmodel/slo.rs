//! SLO simulator: per-request TTFT / TPOT / E2E for any (model, layout,
//! placement, sequence shape) — regenerates Figs. 1 and 8–10.
//!
//! Single-request semantics (the paper isolates batching effects, §IV.B):
//! the pipeline processes one microbatch, so stages execute strictly
//! serially; a decode step flows through all stages then returns the
//! sampled token to the first stage.
//!
//! The simulator is a thin closed-form view over the shared pricing core
//! ([`crate::simtime::CostModel`]) — the same α–β/compute arithmetic that
//! prices traced records and drives model-time serving, so the figures
//! here and the serving SLOs can never diverge.

use crate::analysis::{InferenceShape, ParallelLayout};
use crate::cluster::Placement;
use crate::model::ModelArch;
use crate::simtime::CostModel;

use super::calibration::Calibration;

pub use crate::simtime::PhaseBreakdown;

/// Simulated SLO metrics for one request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloReport {
    pub ttft_s: f64,
    /// Mean time per output token after the first.
    pub tpot_s: f64,
    pub e2e_s: f64,
    pub prefill: PhaseBreakdown,
    /// Per-decode-step breakdown (multiply by `S_d − 1` for phase totals).
    pub decode_step: PhaseBreakdown,
}

impl SloReport {
    /// Whole-request communication fraction (Fig. 1).
    pub fn comm_fraction(&self, shape: InferenceShape) -> f64 {
        let steps = (shape.decode_len - 1) as f64;
        let comm = self.prefill.comm_s + steps * self.decode_step.comm_s;
        let total = self.prefill.total() + steps * self.decode_step.total();
        if total == 0.0 { 0.0 } else { comm / total }
    }
}

/// The simulator: composes roofline compute, α–β collectives and calibrated
/// framework overheads over a placement — stored as the one shared
/// [`CostModel`] its closed forms read from.
#[derive(Debug, Clone)]
pub struct SloSimulator {
    cost: CostModel,
}

impl SloSimulator {
    pub fn new(arch: ModelArch, placement: Placement) -> Self {
        Self { cost: CostModel::new(arch, placement, Calibration::default()) }
    }

    pub fn with_calibration(mut self, cal: Calibration) -> Self {
        self.cost.cal = cal;
        self
    }

    /// Convenience: place a layout on the paper's 4-GPU-node topology with
    /// just enough nodes — the same placement rule every structural
    /// engine's default pricer uses ([`CostModel::on_cardinal`]).
    pub fn on_cardinal(arch: ModelArch, layout: ParallelLayout) -> crate::Result<Self> {
        Ok(Self { cost: CostModel::on_cardinal(arch, layout) })
    }

    /// The shared pricing core this simulator is a view over.
    pub fn cost_model(&self) -> &CostModel {
        &self.cost
    }

    /// Prefill phase breakdown → TTFT.
    pub fn prefill(&self, shape: InferenceShape) -> PhaseBreakdown {
        self.cost.prefill_breakdown(shape)
    }

    /// One decode step breakdown → TPOT.
    pub fn decode_step(&self, shape: InferenceShape) -> PhaseBreakdown {
        self.cost.decode_step_breakdown(shape)
    }

    /// Full-request SLO metrics.
    pub fn simulate(&self, shape: InferenceShape) -> SloReport {
        let prefill = self.cost.prefill_breakdown(shape);
        let decode_step = self.cost.decode_step_breakdown(shape);
        let steps = (shape.decode_len - 1) as f64;
        let ttft = prefill.total();
        let tpot = decode_step.total();
        SloReport {
            ttft_s: ttft,
            tpot_s: tpot,
            e2e_s: ttft + steps * tpot,
            prefill,
            decode_step,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::DTYPE_BYTES_BF16;

    fn shape128() -> InferenceShape {
        InferenceShape::new(128, 128, DTYPE_BYTES_BF16)
    }

    fn sim(arch: ModelArch, tp: usize, pp: usize) -> SloSimulator {
        SloSimulator::on_cardinal(arch, ParallelLayout::new(tp, pp)).unwrap()
    }

    fn ms(x: f64) -> f64 {
        x * 1e3
    }

    #[test]
    fn fig8_tp_scaling_shape() {
        // Paper Fig. 8 (3B): TP=2 {e2e 310, ttft 150, tpot 1.17};
        // TP=4 {210, 90, 0.86}; TP=8 cross-node {1520, 30, 11.56}.
        let a = ModelArch::llama32_3b;
        let r2 = sim(a(), 2, 1).simulate(shape128());
        let r4 = sim(a(), 4, 1).simulate(shape128());
        let r8 = sim(a(), 8, 1).simulate(shape128());

        // orderings
        assert!(r4.ttft_s < r2.ttft_s && r8.ttft_s < r4.ttft_s, "TTFT monotone in t");
        assert!(r4.tpot_s < r2.tpot_s, "TP4 improves TPOT intra-node");
        assert!(r8.tpot_s > 5.0 * r4.tpot_s, "cross-node TP wrecks TPOT");
        assert!(r8.e2e_s > r2.e2e_s && r4.e2e_s < r2.e2e_s);

        // magnitudes within 25% of the paper
        let close = |got: f64, want: f64, tol: f64| {
            assert!((got - want).abs() / want < tol, "got {got}, want {want}");
        };
        close(ms(r2.ttft_s), 150.0, 0.25);
        close(ms(r4.ttft_s), 90.0, 0.25);
        close(ms(r8.ttft_s), 30.0, 0.60); // paper 30ms; comm-heavy tail
        close(ms(r2.tpot_s), 1.17, 0.25);
        close(ms(r4.tpot_s), 0.86, 0.25);
        close(ms(r8.tpot_s), 11.56, 0.25);
        close(r8.e2e_s, 1.52, 0.25);
    }

    #[test]
    fn fig9_pp_scaling_shape() {
        // Paper Fig. 9 (3B): PP=2 {e2e 0.69s, ttft 430ms, tpot ~2ms};
        // PP=4 {1.36s, 1110ms, ~2ms}; PP=8 {4.98s, 2520ms, 19.22ms}.
        let a = ModelArch::llama32_3b;
        let r2 = sim(a(), 1, 2).simulate(shape128());
        let r4 = sim(a(), 1, 4).simulate(shape128());
        let r8 = sim(a(), 1, 8).simulate(shape128());

        assert!(r4.ttft_s > r2.ttft_s && r8.ttft_s > r4.ttft_s, "TTFT grows with depth");
        assert!((r2.tpot_s - r4.tpot_s).abs() < 0.5e-3, "TPOT stable intra-node");
        assert!(r8.tpot_s > 8.0 * r4.tpot_s, "cross-node handoffs dominate PP=8");

        let close = |got: f64, want: f64, tol: f64| {
            assert!((got - want).abs() / want < tol, "got {got}, want {want}");
        };
        close(ms(r2.ttft_s), 430.0, 0.25);
        close(ms(r4.ttft_s), 1110.0, 0.25);
        close(ms(r8.ttft_s), 2520.0, 0.25);
        close(ms(r8.tpot_s), 19.22, 0.25);
        close(r2.e2e_s, 0.69, 0.25);
        close(r4.e2e_s, 1.36, 0.25);
        close(r8.e2e_s, 4.98, 0.25);
    }

    #[test]
    fn fig10_hybrid_13b_shape() {
        // Paper Fig. 10 (13B, 8 GPUs/2 nodes): TP8 best {2.37s, 70ms, 18ms};
        // TP4 PP2 catastrophic {15.15s, ~103ms tpot}; TP2 PP4 intermediate;
        // PP8 moderate {ttft 2430ms}.
        let a = ModelArch::llama2_13b;
        let tp8 = sim(a(), 8, 1).simulate(shape128());
        let tp4pp2 = sim(a(), 4, 2).simulate(shape128());
        let tp2pp4 = sim(a(), 2, 4).simulate(shape128());
        let pp8 = sim(a(), 1, 8).simulate(shape128());

        // The paper's headline ordering.
        assert!(tp8.e2e_s < tp2pp4.e2e_s && tp8.e2e_s < pp8.e2e_s);
        assert!(tp4pp2.e2e_s > tp2pp4.e2e_s, "unbalanced hybrid is worst");
        assert!(tp4pp2.e2e_s > pp8.e2e_s);
        assert!(tp8.ttft_s < 0.2 * pp8.ttft_s, "TP8 TTFT advantage");

        let close = |got: f64, want: f64, tol: f64| {
            assert!((got - want).abs() / want < tol, "got {got}, want {want}");
        };
        close(tp8.e2e_s, 2.37, 0.30);
        close(ms(tp8.tpot_s), 18.0, 0.30);
        close(ms(tp4pp2.tpot_s), 103.0, 0.35);
        close(ms(pp8.ttft_s), 2430.0, 0.25);
    }

    #[test]
    fn fig1_comm_fraction_ordering() {
        // Fig. 1: TP layouts are the most communication-bound for 8B.
        let a = ModelArch::llama31_8b;
        let s = shape128();
        let f_tp4 = sim(a(), 4, 1).simulate(s).comm_fraction(s);
        let f_pp4 = sim(a(), 1, 4).simulate(s).comm_fraction(s);
        let f_tp2 = sim(a(), 2, 1).simulate(s).comm_fraction(s);
        assert!(f_tp4 > f_pp4, "tp4 {f_tp4} vs pp4 {f_pp4}");
        assert!(f_tp4 > 0.05 && f_tp4 < 0.95);
        assert!(f_tp2 > 0.0);
    }

    #[test]
    fn breakdown_totals_are_consistent() {
        let s = shape128();
        let r = sim(ModelArch::llama31_8b(), 2, 2).simulate(s);
        let manual =
            r.prefill.total() + (s.decode_len as f64 - 1.0) * r.decode_step.total();
        assert!((r.e2e_s - manual).abs() < 1e-12);
        assert!(r.ttft_s > 0.0 && r.tpot_s > 0.0);
    }

    #[test]
    fn single_gpu_has_no_comm() {
        let s = shape128();
        let r = sim(ModelArch::llama32_3b(), 1, 1).simulate(s);
        assert_eq!(r.prefill.comm_s, 0.0);
        assert_eq!(r.decode_step.comm_s, 0.0);
        assert_eq!(r.comm_fraction(s), 0.0);
    }
}
