//! SLO simulator: per-request TTFT / TPOT / E2E for any (model, layout,
//! placement, sequence shape) — regenerates Figs. 1 and 8–10.
//!
//! Single-request semantics (the paper isolates batching effects, §IV.B):
//! the pipeline processes one microbatch, so stages execute strictly
//! serially; a decode step flows through all stages then returns the
//! sampled token to the first stage.


use crate::analysis::{InferenceShape, ParallelLayout};
use crate::cluster::{Placement, Topology};
use crate::comm::Stage;
use crate::model::ModelArch;

use super::calibration::Calibration;

/// Time decomposition of one phase (seconds).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseBreakdown {
    pub compute_s: f64,
    pub comm_s: f64,
    pub overhead_s: f64,
}

impl PhaseBreakdown {
    pub fn total(&self) -> f64 {
        self.compute_s + self.comm_s + self.overhead_s
    }

    /// Communication fraction of total phase time (Fig. 1 y-axis).
    pub fn comm_fraction(&self) -> f64 {
        let t = self.total();
        if t == 0.0 { 0.0 } else { self.comm_s / t }
    }
}

/// Simulated SLO metrics for one request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloReport {
    pub ttft_s: f64,
    /// Mean time per output token after the first.
    pub tpot_s: f64,
    pub e2e_s: f64,
    pub prefill: PhaseBreakdown,
    /// Per-decode-step breakdown (multiply by `S_d − 1` for phase totals).
    pub decode_step: PhaseBreakdown,
}

impl SloReport {
    /// Whole-request communication fraction (Fig. 1).
    pub fn comm_fraction(&self, shape: InferenceShape) -> f64 {
        let steps = (shape.decode_len - 1) as f64;
        let comm = self.prefill.comm_s + steps * self.decode_step.comm_s;
        let total = self.prefill.total() + steps * self.decode_step.total();
        if total == 0.0 { 0.0 } else { comm / total }
    }
}

/// The simulator: composes roofline compute, α–β collectives and calibrated
/// framework overheads over a placement.
#[derive(Debug, Clone)]
pub struct SloSimulator {
    pub arch: ModelArch,
    pub placement: Placement,
    pub cal: Calibration,
}

impl SloSimulator {
    pub fn new(arch: ModelArch, placement: Placement) -> Self {
        Self { arch, placement, cal: Calibration::default() }
    }

    pub fn with_calibration(mut self, cal: Calibration) -> Self {
        self.cal = cal;
        self
    }

    /// Convenience: place a layout on the paper's 4-GPU-node topology with
    /// just enough nodes.
    pub fn on_cardinal(arch: ModelArch, layout: ParallelLayout) -> crate::Result<Self> {
        let nodes = layout.world_size().div_ceil(4).max(1);
        let placement = Placement::new(Topology::cardinal(nodes), layout)?;
        Ok(Self::new(arch, placement))
    }

    fn layout(&self) -> ParallelLayout {
        self.placement.layout
    }

    /// Per-step communication time of stage `s` over a `window`-token
    /// message (TP collectives + boundary p2p wire time).
    fn stage_comm(&self, s: usize, window: usize, stage: Stage) -> f64 {
        let (t, p) = (self.layout().tp, self.layout().pp);
        let b = self.cal.compute.dtype_bytes;
        let h = self.arch.hidden as f64;
        let msg = window as f64 * h * b;
        let crosses = self.placement.tp_group_crosses_nodes(s);
        let net = &self.cal.net;
        let mut time = 0.0;

        if t > 1 {
            let mut ars = 2 * self.arch.stage_layers(p, s);
            if s == 0 {
                ars += 1; // vocab-parallel embedding
            }
            time += ars as f64 * net.allreduce(msg, t, crosses).total();
            if p > 1 && s > 0 {
                time += 2.0 * net.allgather(msg, t, crosses).total();
            }
            if s == p - 1 {
                // Logits gather of v/t slices, once per sampled token; the
                // prefill step samples exactly one token too.
                let slice = (self.arch.vocab / t) as f64 * b;
                let _ = stage;
                time += net.gather(slice, t, crosses).total();
            }
        }
        if p > 1 && s < p - 1 {
            let cross = self.placement.pp_boundary_crosses_nodes(s);
            let slice = msg / t as f64;
            time += 2.0 * net.p2p(slice, cross).total();
        }
        time
    }

    /// Framework handoff overhead (per step) for pipeline boundaries,
    /// including the sampled-token return hop to stage 0.
    fn decode_handoff_overhead(&self) -> f64 {
        let p = self.layout().pp;
        if p <= 1 {
            return 0.0;
        }
        let t = self.layout().tp;
        let mut crossings = self.placement.internode_boundaries();
        // Return hop: last stage -> first stage.
        let last = self.placement.global_rank(p - 1, 0);
        let first = self.placement.global_rank(0, 0);
        if !self.placement.topology.same_node(last, first) {
            crossings += 1;
        }
        crossings as f64 * self.cal.internode_handoff(t)
    }

    /// Prefill phase breakdown → TTFT.
    pub fn prefill(&self, shape: InferenceShape) -> PhaseBreakdown {
        let (t, p) = (self.layout().tp, self.layout().pp);
        let sp = shape.prefill_len;
        let mut compute = 0.0;
        let mut comm = 0.0;
        for s in 0..p {
            let layers = self.arch.stage_layers(p, s);
            compute += self.cal.compute.prefill_time(&self.arch, layers, sp, t);
            comm += self.stage_comm(s, sp, Stage::Prefill);
        }
        let mut overhead = self.cal.ttft_framework_overhead(self.layout().world_size());
        overhead += (p - 1) as f64 * self.cal.pp_boundary_prefill_s * (t as f64).powf(
            if p > 1 { self.cal.handoff_tp_exp } else { 0.0 },
        );
        PhaseBreakdown { compute_s: compute, comm_s: comm, overhead_s: overhead }
    }

    /// One decode step breakdown → TPOT.
    pub fn decode_step(&self, shape: InferenceShape) -> PhaseBreakdown {
        let (t, p) = (self.layout().tp, self.layout().pp);
        // Mid-generation context length for KV streaming cost.
        let kv_len = shape.prefill_len + shape.decode_len / 2;
        let mut compute = 0.0;
        let mut comm = 0.0;
        for s in 0..p {
            let layers = self.arch.stage_layers(p, s);
            compute += self.cal.compute.decode_time(&self.arch, layers, kv_len, t);
            comm += self.stage_comm(s, 1, Stage::Decode);
        }
        let overhead = self.cal.step_overhead_s + self.decode_handoff_overhead();
        PhaseBreakdown { compute_s: compute, comm_s: comm, overhead_s: overhead }
    }

    /// Full-request SLO metrics.
    pub fn simulate(&self, shape: InferenceShape) -> SloReport {
        let prefill = self.prefill(shape);
        let decode_step = self.decode_step(shape);
        let steps = (shape.decode_len - 1) as f64;
        let ttft = prefill.total();
        let tpot = decode_step.total();
        SloReport {
            ttft_s: ttft,
            tpot_s: tpot,
            e2e_s: ttft + steps * tpot,
            prefill,
            decode_step,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::DTYPE_BYTES_BF16;

    fn shape128() -> InferenceShape {
        InferenceShape::new(128, 128, DTYPE_BYTES_BF16)
    }

    fn sim(arch: ModelArch, tp: usize, pp: usize) -> SloSimulator {
        SloSimulator::on_cardinal(arch, ParallelLayout::new(tp, pp)).unwrap()
    }

    fn ms(x: f64) -> f64 {
        x * 1e3
    }

    #[test]
    fn fig8_tp_scaling_shape() {
        // Paper Fig. 8 (3B): TP=2 {e2e 310, ttft 150, tpot 1.17};
        // TP=4 {210, 90, 0.86}; TP=8 cross-node {1520, 30, 11.56}.
        let a = ModelArch::llama32_3b;
        let r2 = sim(a(), 2, 1).simulate(shape128());
        let r4 = sim(a(), 4, 1).simulate(shape128());
        let r8 = sim(a(), 8, 1).simulate(shape128());

        // orderings
        assert!(r4.ttft_s < r2.ttft_s && r8.ttft_s < r4.ttft_s, "TTFT monotone in t");
        assert!(r4.tpot_s < r2.tpot_s, "TP4 improves TPOT intra-node");
        assert!(r8.tpot_s > 5.0 * r4.tpot_s, "cross-node TP wrecks TPOT");
        assert!(r8.e2e_s > r2.e2e_s && r4.e2e_s < r2.e2e_s);

        // magnitudes within 25% of the paper
        let close = |got: f64, want: f64, tol: f64| {
            assert!((got - want).abs() / want < tol, "got {got}, want {want}");
        };
        close(ms(r2.ttft_s), 150.0, 0.25);
        close(ms(r4.ttft_s), 90.0, 0.25);
        close(ms(r8.ttft_s), 30.0, 0.60); // paper 30ms; comm-heavy tail
        close(ms(r2.tpot_s), 1.17, 0.25);
        close(ms(r4.tpot_s), 0.86, 0.25);
        close(ms(r8.tpot_s), 11.56, 0.25);
        close(r8.e2e_s, 1.52, 0.25);
    }

    #[test]
    fn fig9_pp_scaling_shape() {
        // Paper Fig. 9 (3B): PP=2 {e2e 0.69s, ttft 430ms, tpot ~2ms};
        // PP=4 {1.36s, 1110ms, ~2ms}; PP=8 {4.98s, 2520ms, 19.22ms}.
        let a = ModelArch::llama32_3b;
        let r2 = sim(a(), 1, 2).simulate(shape128());
        let r4 = sim(a(), 1, 4).simulate(shape128());
        let r8 = sim(a(), 1, 8).simulate(shape128());

        assert!(r4.ttft_s > r2.ttft_s && r8.ttft_s > r4.ttft_s, "TTFT grows with depth");
        assert!((r2.tpot_s - r4.tpot_s).abs() < 0.5e-3, "TPOT stable intra-node");
        assert!(r8.tpot_s > 8.0 * r4.tpot_s, "cross-node handoffs dominate PP=8");

        let close = |got: f64, want: f64, tol: f64| {
            assert!((got - want).abs() / want < tol, "got {got}, want {want}");
        };
        close(ms(r2.ttft_s), 430.0, 0.25);
        close(ms(r4.ttft_s), 1110.0, 0.25);
        close(ms(r8.ttft_s), 2520.0, 0.25);
        close(ms(r8.tpot_s), 19.22, 0.25);
        close(r2.e2e_s, 0.69, 0.25);
        close(r4.e2e_s, 1.36, 0.25);
        close(r8.e2e_s, 4.98, 0.25);
    }

    #[test]
    fn fig10_hybrid_13b_shape() {
        // Paper Fig. 10 (13B, 8 GPUs/2 nodes): TP8 best {2.37s, 70ms, 18ms};
        // TP4 PP2 catastrophic {15.15s, ~103ms tpot}; TP2 PP4 intermediate;
        // PP8 moderate {ttft 2430ms}.
        let a = ModelArch::llama2_13b;
        let tp8 = sim(a(), 8, 1).simulate(shape128());
        let tp4pp2 = sim(a(), 4, 2).simulate(shape128());
        let tp2pp4 = sim(a(), 2, 4).simulate(shape128());
        let pp8 = sim(a(), 1, 8).simulate(shape128());

        // The paper's headline ordering.
        assert!(tp8.e2e_s < tp2pp4.e2e_s && tp8.e2e_s < pp8.e2e_s);
        assert!(tp4pp2.e2e_s > tp2pp4.e2e_s, "unbalanced hybrid is worst");
        assert!(tp4pp2.e2e_s > pp8.e2e_s);
        assert!(tp8.ttft_s < 0.2 * pp8.ttft_s, "TP8 TTFT advantage");

        let close = |got: f64, want: f64, tol: f64| {
            assert!((got - want).abs() / want < tol, "got {got}, want {want}");
        };
        close(tp8.e2e_s, 2.37, 0.30);
        close(ms(tp8.tpot_s), 18.0, 0.30);
        close(ms(tp4pp2.tpot_s), 103.0, 0.35);
        close(ms(pp8.ttft_s), 2430.0, 0.25);
    }

    #[test]
    fn fig1_comm_fraction_ordering() {
        // Fig. 1: TP layouts are the most communication-bound for 8B.
        let a = ModelArch::llama31_8b;
        let s = shape128();
        let f_tp4 = sim(a(), 4, 1).simulate(s).comm_fraction(s);
        let f_pp4 = sim(a(), 1, 4).simulate(s).comm_fraction(s);
        let f_tp2 = sim(a(), 2, 1).simulate(s).comm_fraction(s);
        assert!(f_tp4 > f_pp4, "tp4 {f_tp4} vs pp4 {f_pp4}");
        assert!(f_tp4 > 0.05 && f_tp4 < 0.95);
        assert!(f_tp2 > 0.0);
    }

    #[test]
    fn breakdown_totals_are_consistent() {
        let s = shape128();
        let r = sim(ModelArch::llama31_8b(), 2, 2).simulate(s);
        let manual =
            r.prefill.total() + (s.decode_len as f64 - 1.0) * r.decode_step.total();
        assert!((r.e2e_s - manual).abs() < 1e-12);
        assert!(r.ttft_s > 0.0 && r.tpot_s > 0.0);
    }

    #[test]
    fn single_gpu_has_no_comm() {
        let s = shape128();
        let r = sim(ModelArch::llama32_3b(), 1, 1).simulate(s);
        assert_eq!(r.prefill.comm_s, 0.0);
        assert_eq!(r.decode_step.comm_s, 0.0);
        assert_eq!(r.comm_fraction(s), 0.0);
    }
}
