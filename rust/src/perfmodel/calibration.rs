//! Calibrated constants for the SLO simulator.
//!
//! The paper's latency figures contain two kinds of time: physics (compute
//! roofline + wire) and *framework* overhead of the measured stack (vLLM
//! 0.8.5 V0 engine, eager mode, custom-allreduce disabled — §IV.A). The
//! physics constants below are standard H100/NVLink/NDR numbers; the
//! framework constants were fitted once against the nine SLO data points of
//! Figs. 8–10 (see EXPERIMENTS.md §Calibration for the fit):
//!
//! - `alpha_nvlink`: 1 µs small-message NCCL launch over NVLink — fitted
//!   from the TP=2→TP=4 TPOT delta of Fig. 8 (0.31 ms over 57 extra ring
//!   hops × 4).
//! - `alpha_ib`: 14 µs cross-node — fitted from Fig. 8's TP=8 TPOT
//!   (11.56 ms ≈ 57 AllReduce × 14 hops × α).
//! - `ttft_base/ttft_per_log2_tp`: vLLM's prefill-path overhead falls
//!   log-linearly with TP degree in Fig. 8 (150/90/30 ms at t=2/4/8);
//!   210 − 60·log₂t ms reproduces all three exactly.
//! - `pp_boundary_prefill`: 340 ms per pipeline boundary during prefill —
//!   the V0 engine runs stages as serialized virtual engines
//!   (Fig. 9: 430/1110/2520 ms ≈ 90 + 340·(p−1)).
//! - `internode_handoff`: 8.6 ms per cross-node stage handoff per decode
//!   step (Ray object transfer, not the wire) — Fig. 9's PP=8 TPOT jump
//!   (19.2 ≈ decode compute + 2 crossings × 8.6). Scales ~t^1.2 when a
//!   stage has multiple TP workers to synchronize (Fig. 10's catastrophic
//!   TP=4 PP=2).


use crate::cluster::netmodel::{CollectiveTuning, LinkParams, NetModel};
use crate::perfmodel::compute::ComputeModel;

/// Full constant set used by [`super::slo::SloSimulator`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Calibration {
    pub compute: ComputeModel,
    pub net: NetModel,
    /// Collective variants in play — wire precision + overlap factor for
    /// TP AllReduce/AllGather payloads. The default (16-bit, 0.0) prices
    /// bitwise-identically to the untuned model; non-default values only
    /// enter through the validated plan builder.
    pub tuning: CollectiveTuning,
    /// Fixed request-intake cost included in TTFT (seconds).
    pub ttft_base_s: f64,
    /// vLLM prefill-path overhead: `max(0, a − b·log2(t))` (seconds).
    pub ttft_tp_fit_a_s: f64,
    pub ttft_tp_fit_b_s: f64,
    /// Per-pipeline-boundary prefill serialization overhead (seconds).
    pub pp_boundary_prefill_s: f64,
    /// Per-decode-step fixed engine overhead (seconds).
    pub step_overhead_s: f64,
    /// Cross-node stage-handoff framework cost per decode step (seconds),
    /// before the `t^handoff_tp_exp` multiplier.
    pub internode_handoff_s: f64,
    /// Exponent of the TP-width multiplier on cross-node handoffs.
    pub handoff_tp_exp: f64,
}

impl Default for Calibration {
    fn default() -> Self {
        Self {
            compute: ComputeModel::default(),
            net: NetModel {
                nvlink: LinkParams { alpha_s: 1.0e-6, bus_bw: 300.0e9 },
                ib: LinkParams { alpha_s: 14.0e-6, bus_bw: 40.0e9 },
            },
            tuning: CollectiveTuning::default(),
            ttft_base_s: 0.0,
            ttft_tp_fit_a_s: 0.210,
            ttft_tp_fit_b_s: 0.060,
            pp_boundary_prefill_s: 0.340,
            step_overhead_s: 0.0,
            internode_handoff_s: 8.6e-3,
            handoff_tp_exp: 1.2,
        }
    }
}

impl Calibration {
    /// vLLM prefill-path framework overhead, falling log-linearly with the
    /// number of workers: `max(0, a − b·log2(world))`. Fitted on Fig. 8's
    /// TP sweep (150/90/30 ms at 2/4/8 GPUs) and consistent with Fig. 9's
    /// PP intercepts (§EXPERIMENTS.md Calibration).
    pub fn ttft_framework_overhead(&self, world_size: usize) -> f64 {
        let fit = self.ttft_tp_fit_a_s - self.ttft_tp_fit_b_s * (world_size as f64).log2();
        self.ttft_base_s + fit.max(0.0)
    }

    /// Cross-node handoff cost for a stage with `t` TP workers.
    pub fn internode_handoff(&self, t: usize) -> f64 {
        self.internode_handoff_s * (t as f64).powf(self.handoff_tp_exp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ttft_fit_reproduces_fig8_overheads() {
        let c = Calibration::default();
        assert!((c.ttft_framework_overhead(2) - 0.150).abs() < 1e-9);
        assert!((c.ttft_framework_overhead(4) - 0.090).abs() < 1e-9);
        assert!((c.ttft_framework_overhead(8) - 0.030).abs() < 1e-9);
        // never negative, even for absurd degrees
        assert!(c.ttft_framework_overhead(1024) >= 0.0);
    }

    #[test]
    fn handoff_grows_with_tp_width() {
        let c = Calibration::default();
        assert!((c.internode_handoff(1) - 8.6e-3).abs() < 1e-12);
        assert!(c.internode_handoff(4) > 4.0 * 8.6e-3);
        assert!(c.internode_handoff(4) < 6.0 * 8.6e-3);
    }
}
