//! Predictive performance models — regenerate the paper's SLO figures.
//!
//! The paper measures TTFT / TPOT / E2E on 4×H100 nodes (Figs. 1, 8–10);
//! this testbed has neither H100s nor InfiniBand, so latency is *simulated*
//! from three calibrated components (DESIGN.md §5):
//!
//! 1. [`compute`] — H100 roofline: prefill is FLOP-bound on the tensor
//!    cores, decode is weight-streaming-bound on HBM3;
//! 2. [`crate::cluster::netmodel`] — α–β collective costs over the
//!    placement's link classes;
//! 3. [`calibration`] — fitted vLLM-V0 framework overheads (per-step
//!    scheduling, pipeline-stage handoffs), the constants the paper's
//!    anomalously large PP latencies are made of.
//!
//! [`slo`] composes the three into per-request TTFT/TPOT/E2E and the
//! comm/compute fraction breakdown of Fig. 1 — as a thin closed-form view
//! over the shared pricing core in [`crate::simtime`], the same
//! `CostModel` that prices traced records and drives model-time serving.

pub mod calibration;
pub mod compute;
pub mod slo;

pub use calibration::Calibration;
pub use compute::ComputeModel;
pub use slo::{PhaseBreakdown, SloReport, SloSimulator};
