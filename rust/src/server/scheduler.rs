//! Request scheduler: FCFS admission with paged-KV backpressure.
//!
//! vLLM's continuous-batching scheduler admits requests while KV blocks are
//! available and returns them to the pool on completion. Our engine serves
//! one request at a time (the paper's single-request methodology isolates
//! communication from batching, §IV.B), so the scheduler's role is the
//! admission/queueing discipline in front of the engine plus KV lifecycle.

use std::collections::VecDeque;
use std::time::Instant;

use crate::engine::kv::{KvBlockManager, SeqId};
use crate::Result;

/// One queued generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: SeqId,
    pub prompt: Vec<i32>,
    pub decode_len: usize,
}

/// A request popped for execution (queue timing attached).
#[derive(Debug)]
pub struct Admitted {
    pub request: Request,
    pub enqueued_at: Instant,
}

/// Scheduler configuration.
#[derive(Debug, Clone, Copy)]
pub struct SchedulerConfig {
    pub kv_blocks: usize,
    pub kv_block_size: usize,
    pub max_queue: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        Self { kv_blocks: 512, kv_block_size: 16, max_queue: 1024 }
    }
}

/// FCFS scheduler with KV admission control.
pub struct Scheduler {
    cfg: SchedulerConfig,
    kv: KvBlockManager,
    queue: VecDeque<(Request, Instant)>,
}

impl Scheduler {
    pub fn new(cfg: SchedulerConfig) -> Self {
        Self { cfg, kv: KvBlockManager::new(cfg.kv_blocks, cfg.kv_block_size), queue: VecDeque::new() }
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn kv(&self) -> &KvBlockManager {
        &self.kv
    }

    /// Enqueue a request (rejects when the queue is full — backpressure to
    /// the router).
    pub fn submit(&mut self, request: Request) -> Result<()> {
        if self.queue.len() >= self.cfg.max_queue {
            anyhow::bail!("queue full ({} requests)", self.cfg.max_queue);
        }
        if request.prompt.is_empty() {
            anyhow::bail!("empty prompt");
        }
        let total = request.prompt.len() + request.decode_len;
        if total > self.cfg.kv_blocks * self.cfg.kv_block_size {
            anyhow::bail!("request of {total} tokens can never fit the KV pool");
        }
        self.queue.push_back((request, Instant::now()));
        Ok(())
    }

    /// Pop the next request iff its *full* KV footprint fits now (FCFS:
    /// head-of-line blocks — vLLM V0 default behaviour).
    pub fn admit_next(&mut self) -> Result<Option<Admitted>> {
        let Some((front, _)) = self.queue.front() else {
            return Ok(None);
        };
        let tokens = front.prompt.len() + front.decode_len;
        if !self.kv.can_allocate(tokens) {
            return Ok(None);
        }
        let (request, enqueued_at) = self.queue.pop_front().expect("non-empty");
        self.kv.allocate(request.id, request.prompt.len())?;
        // Reserve decode growth eagerly (admission checked the full span).
        for _ in 0..request.decode_len {
            self.kv.append_token(request.id)?;
        }
        Ok(Some(Admitted { request, enqueued_at }))
    }

    /// Release a finished request's KV blocks.
    pub fn complete(&mut self, id: SeqId) -> Result<()> {
        self.kv.release(id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, prompt: usize, decode: usize) -> Request {
        Request { id, prompt: vec![0; prompt], decode_len: decode }
    }

    #[test]
    fn fcfs_order_and_completion() {
        let mut s = Scheduler::new(SchedulerConfig {
            kv_blocks: 16,
            kv_block_size: 16,
            max_queue: 8,
        });
        s.submit(req(1, 16, 16)).unwrap();
        s.submit(req(2, 16, 16)).unwrap();
        let a = s.admit_next().unwrap().unwrap();
        assert_eq!(a.request.id, 1);
        let b = s.admit_next().unwrap().unwrap();
        assert_eq!(b.request.id, 2);
        assert!(s.admit_next().unwrap().is_none());
        s.complete(1).unwrap();
        s.complete(2).unwrap();
        assert_eq!(s.kv().used_blocks(), 0);
    }

    #[test]
    fn kv_backpressure_blocks_admission() {
        let mut s = Scheduler::new(SchedulerConfig {
            kv_blocks: 4,
            kv_block_size: 16,
            max_queue: 8,
        });
        s.submit(req(1, 32, 32)).unwrap(); // 4 blocks
        s.submit(req(2, 16, 16)).unwrap();
        assert!(s.admit_next().unwrap().is_some());
        assert!(s.admit_next().unwrap().is_none(), "no blocks left");
        s.complete(1).unwrap();
        assert_eq!(s.admit_next().unwrap().unwrap().request.id, 2, "FCFS after release");
    }

    #[test]
    fn rejects_oversized_and_overflow() {
        let mut s = Scheduler::new(SchedulerConfig {
            kv_blocks: 2,
            kv_block_size: 4,
            max_queue: 1,
        });
        assert!(s.submit(req(1, 64, 64)).is_err(), "can never fit");
        assert!(s.submit(req(2, 0, 4)).is_err(), "empty prompt");
        s.submit(req(3, 4, 2)).unwrap();
        assert!(s.submit(req(4, 4, 2)).is_err(), "queue full");
    }
}
