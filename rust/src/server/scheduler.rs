//! Iteration-level continuous-batching scheduler (vLLM-style).
//!
//! Requests move waiting → running → finished. Admission charges only the
//! *prompt* KV footprint ([`Scheduler::admit_next`]); decode growth is
//! allocated one token at a time ([`Scheduler::grow`]) exactly when an
//! iteration is about to write it — vLLM's on-demand block allocation.
//! The old scheduler reserved a request's entire decode span eagerly, so a
//! pool that could interleave requests rejected feasible concurrency; now
//! up to [`SchedulerConfig::max_batch`] sequences share every decode
//! iteration and a sequence whose growth exhausts the pool is bailed out
//! cleanly by the serving loop (blocks released, error surfaced in its
//! `RequestMetrics`).

use std::collections::VecDeque;
use std::time::Instant;

use crate::engine::kv::{KvBlockManager, SeqId};
use crate::Result;

pub use crate::engine::session::PromptTokens;

/// One queued generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: SeqId,
    pub prompt: PromptTokens,
    pub decode_len: usize,
}

/// A request popped for execution (queue timing attached).
#[derive(Debug)]
pub struct Admitted {
    pub request: Request,
    pub enqueued_at: Instant,
    /// Prefix-cache hint consumed at admission: leading prompt tokens
    /// whose KV is already resident on this replica. The serving loop
    /// prefills (and prices) only the remaining suffix; KV-pool
    /// admission charged only the suffix's blocks. 0 without a cache.
    pub cached_tokens: usize,
}

/// Scheduler configuration.
#[derive(Debug, Clone, Copy)]
pub struct SchedulerConfig {
    pub kv_blocks: usize,
    pub kv_block_size: usize,
    pub max_queue: usize,
    /// Maximum sequences decoding concurrently in one engine iteration
    /// (vLLM's `max_num_seqs`) — the serving concurrency knob.
    pub max_batch: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        Self { kv_blocks: 512, kv_block_size: 16, max_queue: 1024, max_batch: 8 }
    }
}

/// FCFS continuous-batching scheduler with prompt-footprint KV admission.
pub struct Scheduler {
    cfg: SchedulerConfig,
    kv: KvBlockManager,
    waiting: VecDeque<(Request, Instant)>,
    running: Vec<SeqId>,
}

impl Scheduler {
    pub fn new(cfg: SchedulerConfig) -> Self {
        assert!(cfg.max_batch >= 1, "max_batch must be >= 1");
        Self {
            cfg,
            kv: KvBlockManager::new(cfg.kv_blocks, cfg.kv_block_size),
            waiting: VecDeque::new(),
            running: Vec::new(),
        }
    }

    pub fn queue_len(&self) -> usize {
        self.waiting.len()
    }

    /// Sequences currently admitted and holding KV blocks.
    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    pub fn kv(&self) -> &KvBlockManager {
        &self.kv
    }

    pub fn config(&self) -> SchedulerConfig {
        self.cfg
    }

    /// Enqueue a request (rejects when the queue is full — backpressure to
    /// the router). A request whose full span can never fit the pool even
    /// alone is rejected up front; pool *contention* is handled later by
    /// the mid-decode bail-out path instead.
    pub fn submit(&mut self, request: Request) -> Result<()> {
        if self.waiting.len() >= self.cfg.max_queue {
            anyhow::bail!("queue full ({} requests)", self.cfg.max_queue);
        }
        if request.prompt.is_empty() {
            anyhow::bail!("empty prompt");
        }
        if request.decode_len == 0 {
            // Catch it at the front door: downstream the session would
            // only trip an assert mid-iteration, deep in a DES run.
            anyhow::bail!("decode_len must be >= 1 (a request generates at least one token)");
        }
        let total = request.prompt.len() + request.decode_len;
        if total > self.cfg.kv_blocks * self.cfg.kv_block_size {
            anyhow::bail!("request of {total} tokens can never fit the KV pool");
        }
        self.waiting.push_back((request, Instant::now()));
        Ok(())
    }

    /// The queue head, if any — so a prefix-cache owner can compute the
    /// cached-prefix hint for exactly the request [`Self::admit_next_with_cached`]
    /// would pop.
    pub fn peek(&self) -> Option<&Request> {
        self.waiting.front().map(|(r, _)| r)
    }

    /// Pop the queue head iff a batch slot is free and its *prompt* blocks
    /// fit now (FCFS: head-of-line blocks — vLLM V0 default behaviour).
    /// Decode growth is not reserved here; see [`Self::grow`].
    pub fn admit_next(&mut self) -> Result<Option<Admitted>> {
        self.admit_next_with_cached(0)
    }

    /// [`Self::admit_next`] with a prefix-cache hint: the head request's
    /// leading `cached` tokens are already resident, so KV admission
    /// charges only the uncached suffix (the cached blocks live in the
    /// prefix cache's own byte budget, shared across requests, not in
    /// this pool). The hint is clamped so at least one token is always
    /// prefilled — an admission never treats the whole prompt as cached.
    pub fn admit_next_with_cached(&mut self, cached: usize) -> Result<Option<Admitted>> {
        if self.running.len() >= self.cfg.max_batch {
            return Ok(None);
        }
        let Some((front, _)) = self.waiting.front() else {
            return Ok(None);
        };
        let cached = cached.min(front.prompt.len().saturating_sub(1));
        if !self.kv.can_allocate(front.prompt.len() - cached) {
            return Ok(None);
        }
        let (request, enqueued_at) = self.waiting.pop_front().expect("non-empty");
        self.kv.allocate(request.id, request.prompt.len() - cached)?;
        self.running.push(request.id);
        Ok(Some(Admitted { request, enqueued_at, cached_tokens: cached }))
    }

    /// Reserve KV for one more decoded token of a running sequence, on the
    /// iteration that writes it. `Err` means the pool is exhausted: the
    /// caller bails the sequence out (cancel + [`Self::finish`]); the
    /// failed call leaves its footprint untouched.
    pub fn grow(&mut self, id: SeqId) -> Result<bool> {
        self.kv.append_token(id)
    }

    /// Retire a running sequence — completed or bailed out — releasing
    /// all of its KV blocks.
    pub fn finish(&mut self, id: SeqId) -> Result<()> {
        self.running.retain(|&r| r != id);
        self.kv.release(id)
    }

    /// Empty the waiting queue and return the still-unadmitted requests
    /// with their original enqueue instants, in FCFS order — the
    /// replica-failure path ([`crate::faults`]): a dead replica's queue
    /// is handed back to the router. Keeping `enqueued_at` lets the
    /// retry path count queueing — and therefore E2E/goodput — from the
    /// request's first arrival instead of silently restarting its
    /// clock. Queued requests hold no KV, so there is nothing else to
    /// release.
    pub fn drain_waiting(&mut self) -> Vec<(Request, Instant)> {
        self.waiting.drain(..).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, prompt: usize, decode: usize) -> Request {
        Request { id, prompt: vec![0; prompt].into(), decode_len: decode }
    }

    fn cfg(kv_blocks: usize, kv_block_size: usize, max_batch: usize) -> SchedulerConfig {
        SchedulerConfig { kv_blocks, kv_block_size, max_queue: 8, max_batch }
    }

    #[test]
    fn fcfs_order_and_finish_releases_kv() {
        let mut s = Scheduler::new(cfg(16, 16, 4));
        s.submit(req(1, 16, 16)).unwrap();
        s.submit(req(2, 16, 16)).unwrap();
        let a = s.admit_next().unwrap().unwrap();
        assert_eq!(a.request.id, 1);
        let b = s.admit_next().unwrap().unwrap();
        assert_eq!(b.request.id, 2);
        assert_eq!(s.running_len(), 2);
        assert!(s.admit_next().unwrap().is_none(), "queue drained");
        s.finish(1).unwrap();
        s.finish(2).unwrap();
        assert_eq!(s.running_len(), 0);
        assert_eq!(s.kv().used_blocks(), 0);
    }

    #[test]
    fn prompt_only_admission_raises_concurrency() {
        // Pool: 4 blocks x 16 tokens. The old full-span reservation charged
        // req 1 all 4 blocks (16 + 48 tokens) at admission, so req 2 could
        // only run after it finished. Prompt-footprint admission runs both
        // concurrently: prompts take 1 block each, growth is on demand.
        let mut s = Scheduler::new(cfg(4, 16, 4));
        s.submit(req(1, 16, 48)).unwrap();
        s.submit(req(2, 16, 16)).unwrap();
        assert!(s.admit_next().unwrap().is_some());
        assert!(
            s.admit_next().unwrap().is_some(),
            "feasible concurrency must not be rejected"
        );
        assert_eq!(s.running_len(), 2);
        assert_eq!(s.kv().used_blocks(), 2, "prompt blocks only");
    }

    #[test]
    fn drain_waiting_returns_fcfs_and_leaves_running_alone() {
        let mut s = Scheduler::new(cfg(16, 16, 1));
        s.submit(req(1, 16, 4)).unwrap();
        s.submit(req(2, 16, 4)).unwrap();
        s.submit(req(3, 16, 4)).unwrap();
        assert_eq!(s.admit_next().unwrap().unwrap().request.id, 1);
        let before_drain = Instant::now();
        let drained = s.drain_waiting();
        assert_eq!(drained.iter().map(|(r, _)| r.id).collect::<Vec<_>>(), vec![2, 3]);
        for (_, enqueued_at) in &drained {
            assert!(
                *enqueued_at <= before_drain,
                "drained requests keep their original enqueue instant"
            );
        }
        assert_eq!(s.queue_len(), 0);
        assert_eq!(s.running_len(), 1, "admitted sequences are the caller's to cancel");
        assert!(s.drain_waiting().is_empty());
    }

    #[test]
    fn max_batch_caps_admission() {
        let mut s = Scheduler::new(cfg(64, 16, 2));
        for id in 0..4 {
            s.submit(req(id, 16, 8)).unwrap();
        }
        assert!(s.admit_next().unwrap().is_some());
        assert!(s.admit_next().unwrap().is_some());
        assert!(s.admit_next().unwrap().is_none(), "batch full");
        s.finish(0).unwrap();
        assert_eq!(s.admit_next().unwrap().unwrap().request.id, 2, "FCFS after a slot frees");
    }

    #[test]
    fn kv_backpressure_blocks_admission_on_prompt() {
        let mut s = Scheduler::new(cfg(4, 16, 8));
        s.submit(req(1, 64, 1)).unwrap(); // prompt takes the whole pool
        s.submit(req(2, 16, 16)).unwrap();
        assert!(s.admit_next().unwrap().is_some());
        assert!(s.admit_next().unwrap().is_none(), "no blocks for the next prompt");
        s.finish(1).unwrap();
        assert_eq!(s.admit_next().unwrap().unwrap().request.id, 2);
        s.finish(2).unwrap();
    }

    #[test]
    fn grow_exhaustion_surfaces_and_finish_recovers() {
        let mut s = Scheduler::new(cfg(2, 4, 8));
        s.submit(req(1, 4, 4)).unwrap();
        s.submit(req(2, 4, 4)).unwrap();
        assert!(s.admit_next().unwrap().is_some());
        assert!(s.admit_next().unwrap().is_some());
        // Both prompts fill the pool; the first boundary crossing fails.
        assert!(s.grow(1).is_err(), "pool exhausted mid-decode");
        s.finish(1).unwrap(); // bail-out releases the blocks
        assert!(s.grow(2).is_ok(), "survivor grows into the freed blocks");
        s.finish(2).unwrap();
        assert_eq!(s.kv().used_blocks(), 0);
    }

    #[test]
    fn cached_hint_charges_only_the_suffix() {
        // Pool: 3 blocks x 16 tokens. A 32-token prompt takes 2 blocks
        // uncached — but with 16 tokens cached, admission charges one
        // block, so a second hinted request fits alongside.
        let mut s = Scheduler::new(cfg(3, 16, 4));
        s.submit(req(1, 32, 1)).unwrap();
        s.submit(req(2, 32, 1)).unwrap();
        assert_eq!(s.peek().unwrap().id, 1);
        let a = s.admit_next_with_cached(16).unwrap().unwrap();
        assert_eq!((a.request.id, a.cached_tokens), (1, 16));
        assert_eq!(s.kv().used_blocks(), 1, "suffix block only");
        assert_eq!(s.peek().unwrap().id, 2);
        let b = s.admit_next_with_cached(16).unwrap().unwrap();
        assert_eq!(b.cached_tokens, 16);
        assert_eq!(s.kv().used_blocks(), 2);
        s.finish(1).unwrap();
        s.finish(2).unwrap();
        // The hint is clamped: a fully-cached prompt still prefills (and
        // charges) at least one token.
        s.submit(req(3, 16, 1)).unwrap();
        let c = s.admit_next_with_cached(999).unwrap().unwrap();
        assert_eq!(c.cached_tokens, 15, "at least one token stays uncached");
        assert_eq!(s.kv().used_blocks(), 1);
        s.finish(3).unwrap();
        // admit_next is exactly the zero-hint path.
        s.submit(req(4, 16, 1)).unwrap();
        let d = s.admit_next().unwrap().unwrap();
        assert_eq!(d.cached_tokens, 0);
        assert_eq!(s.kv().used_blocks(), 1, "full prompt charged");
        s.finish(4).unwrap();
        assert!(s.peek().is_none());
    }

    #[test]
    fn rejects_oversized_and_overflow() {
        let mut s = Scheduler::new(cfg(2, 4, 8));
        assert!(s.submit(req(1, 64, 64)).is_err(), "can never fit");
        assert!(s.submit(req(2, 0, 4)).is_err(), "empty prompt");
        let zero_decode = s.submit(req(12, 4, 0));
        assert!(zero_decode.is_err(), "zero decode span caught at submit, not mid-DES");
        assert!(zero_decode.unwrap_err().to_string().contains("decode_len"));
        for id in 3..11 {
            s.submit(req(id, 4, 2)).unwrap();
        }
        assert!(s.submit(req(11, 4, 2)).is_err(), "queue full");
    }
}
