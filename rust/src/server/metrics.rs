//! Per-request SLO metrics and aggregation (paper §II.A: TTFT, TPOT,
//! throughput; §V.C evaluates these across parallelism layouts).

use std::time::Duration;

/// SLO record of one served request.
#[derive(Debug, Clone)]
pub struct RequestMetrics {
    pub request_id: u64,
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    /// Queue wait before admission into the engine's batch.
    pub queue_s: f64,
    /// Time to first token, excluding queueing.
    pub ttft_s: f64,
    /// Mean time per output token after the first.
    pub tpot_s: f64,
    /// End-to-end latency including queueing.
    pub e2e_s: f64,
    /// Set when the request did not complete its decode span — e.g. the
    /// KV pool was exhausted mid-decode and the sequence was bailed out
    /// (`generated_tokens` counts what it produced before that).
    pub error: Option<String>,
}

/// p50 / p95 / p99 of one latency metric, in seconds.
#[derive(Debug, Clone, Copy, Default)]
pub struct LatencyPercentiles {
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
}

impl LatencyPercentiles {
    /// One NaN-filter + sort, three nearest-rank lookups.
    pub fn from_samples(samples: &[f64]) -> Self {
        let mut v: Vec<f64> = samples.iter().copied().filter(|x| !x.is_nan()).collect();
        if v.is_empty() {
            return Self::default();
        }
        v.sort_by(|a, b| a.total_cmp(b));
        let rank = |p: f64| v[nearest_rank(p, v.len())];
        Self { p50_s: rank(50.0), p95_s: rank(95.0), p99_s: rank(99.0) }
    }
}

/// Nearest-rank index for percentile `p` over `len` sorted samples.
fn nearest_rank(p: f64, len: usize) -> usize {
    let rank = ((p / 100.0) * (len as f64 - 1.0)).round() as usize;
    rank.min(len - 1)
}

/// Aggregate over a batch of served requests.
#[derive(Debug, Clone, Default)]
pub struct ServeSummary {
    pub requests: usize,
    /// Requests that completed their full decode span.
    pub completed: usize,
    /// Requests bailed out with an error in their metrics.
    pub failed: usize,
    pub total_tokens: usize,
    pub wall_s: f64,
    pub tokens_per_s: f64,
    pub requests_per_s: f64,
    pub ttft: LatencyPercentiles,
    pub tpot: LatencyPercentiles,
    pub e2e: LatencyPercentiles,
    pub e2e_mean_s: f64,
}

/// Percentile over unsorted samples (nearest-rank). NaN-safe: NaN samples
/// are ignored, and an empty (or all-NaN) input yields `0.0`.
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p));
    let mut v: Vec<f64> = samples.iter().copied().filter(|x| !x.is_nan()).collect();
    if v.is_empty() {
        return 0.0;
    }
    v.sort_by(|a, b| a.total_cmp(b));
    v[nearest_rank(p, v.len())]
}

impl ServeSummary {
    pub fn from_metrics(metrics: &[RequestMetrics], wall: Duration) -> Self {
        let wall_s = wall.as_secs_f64();
        let total_tokens: usize = metrics.iter().map(|m| m.generated_tokens).sum();
        let failed = metrics.iter().filter(|m| m.error.is_some()).count();
        // Latency bands come from requests that actually produced the
        // measured quantity — a request rejected before any token has
        // placeholder 0.0 samples that would drag p50 toward a fictitious
        // perfect SLO. E2E covers every token-producing request (a
        // mid-decode bail consumed real wall time); requests_per_s counts
        // completed requests only, never rejected ones.
        let ttfts: Vec<f64> =
            metrics.iter().filter(|m| m.generated_tokens >= 1).map(|m| m.ttft_s).collect();
        let tpots: Vec<f64> =
            metrics.iter().filter(|m| m.generated_tokens >= 2).map(|m| m.tpot_s).collect();
        let e2es: Vec<f64> =
            metrics.iter().filter(|m| m.generated_tokens >= 1).map(|m| m.e2e_s).collect();
        let completed = metrics.len() - failed;
        Self {
            requests: metrics.len(),
            completed,
            failed,
            total_tokens,
            wall_s,
            tokens_per_s: if wall_s > 0.0 { total_tokens as f64 / wall_s } else { 0.0 },
            requests_per_s: if wall_s > 0.0 { completed as f64 / wall_s } else { 0.0 },
            ttft: LatencyPercentiles::from_samples(&ttfts),
            tpot: LatencyPercentiles::from_samples(&tpots),
            e2e: LatencyPercentiles::from_samples(&e2es),
            e2e_mean_s: if e2es.is_empty() {
                0.0
            } else {
                e2es.iter().sum::<f64>() / e2es.len() as f64
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(id: u64, ttft_s: f64, tpot_s: f64, e2e_s: f64, error: Option<String>) -> RequestMetrics {
        RequestMetrics {
            request_id: id,
            prompt_tokens: 8,
            generated_tokens: 10,
            queue_s: 0.0,
            ttft_s,
            tpot_s,
            e2e_s,
            error,
        }
    }

    #[test]
    fn percentile_nearest_rank() {
        let v = vec![4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 4.0);
        assert_eq!(percentile(&v, 50.0), 3.0); // rank round(0.5*3)=2 -> 3.0
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn percentile_empty_and_nan_are_safe() {
        assert_eq!(percentile(&[], 50.0), 0.0, "empty input is a defined 0.0");
        assert_eq!(percentile(&[], 0.0), 0.0);
        assert_eq!(percentile(&[], 100.0), 0.0);
        // NaN samples are dropped rather than poisoning the sort...
        assert_eq!(percentile(&[f64::NAN, 2.0, 1.0], 100.0), 2.0);
        // ...and an all-NaN input degrades to the empty case.
        assert_eq!(percentile(&[f64::NAN, f64::NAN], 50.0), 0.0);
    }

    #[test]
    fn summary_aggregates_with_percentile_bands() {
        let metrics: Vec<RequestMetrics> = (0..10)
            .map(|i| m(i, 0.1 * (i + 1) as f64, 0.01, 0.2 * (i + 1) as f64, None))
            .collect();
        let s = ServeSummary::from_metrics(&metrics, Duration::from_secs(1));
        assert_eq!(s.requests, 10);
        assert_eq!(s.completed, 10);
        assert_eq!(s.failed, 0);
        assert_eq!(s.total_tokens, 100);
        assert!((s.tokens_per_s - 100.0).abs() < 1e-9);
        assert!((s.e2e_mean_s - 1.1).abs() < 1e-9);
        // Bands are ordered and hit the nearest-rank values.
        assert!(s.ttft.p50_s <= s.ttft.p95_s && s.ttft.p95_s <= s.ttft.p99_s);
        assert!((s.ttft.p50_s - 0.6).abs() < 1e-9); // rank round(0.5*9)=5 -> 6th
        assert!((s.ttft.p99_s - 1.0).abs() < 1e-9);
        assert!(s.e2e.p50_s <= s.e2e.p99_s);
    }

    #[test]
    fn summary_counts_failures_without_skewing_latency_bands() {
        let mut failed = m(1, 0.0, 0.0, 0.05, Some("queue full".into()));
        failed.generated_tokens = 0; // rejected before any token
        let metrics = vec![
            m(0, 0.1, 0.01, 0.2, None),
            m(2, 0.3, 0.02, 0.4, None),
            failed,
        ];
        let s = ServeSummary::from_metrics(&metrics, Duration::from_secs(1));
        assert_eq!((s.requests, s.completed, s.failed), (3, 2, 1));
        // The zero-token failure's placeholder 0.0 samples stay out of the
        // TTFT/TPOT bands; E2E still covers every request.
        // Two samples [0.1, 0.3]: nearest rank round(0.5*1)=1 -> 0.3; with
        // the failure's 0.0 included it would be 0.1.
        assert!((s.ttft.p50_s - 0.3).abs() < 1e-9, "p50 over token-producing requests");
        assert!(s.tpot.p50_s > 0.0);
        assert!((s.e2e.p50_s - 0.4).abs() < 1e-9, "rejected request's 0.05s stays out");
        // Throughput counts completed requests, not rejected ones.
        assert!((s.requests_per_s - 2.0).abs() < 1e-9);
    }
}
