//! Per-request SLO metrics and aggregation (paper §II.A: TTFT, TPOT,
//! throughput; §V.C evaluates these across parallelism layouts).
//!
//! Every latency appears in up to two clocks: **wall time** (what the host
//! actually took — the meaningful number for numeric PJRT serving) and
//! **model time** (the priced-timeline seconds the calibrated testbed
//! would take — the meaningful number for structural serving, where
//! wall clocks only measure thread scheduling). Model-time fields are
//! `Option`s populated when the engine carries a pricing cost model.

use std::time::Duration;

/// Model-time (priced virtual clock) latencies of one served request —
/// present when the serving engine runs with a pricing cost model
/// (structural plans). Deterministic for a fixed workload and seed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelRequestTimes {
    /// Model-time queue wait before admission.
    pub queue_s: f64,
    /// Model-time to first token, excluding queueing.
    pub ttft_s: f64,
    /// Mean model time per output token after the first.
    pub tpot_s: f64,
    /// Model-time end-to-end latency including queueing.
    pub e2e_s: f64,
    /// Model clock at the request's last token (for makespan accounting).
    pub finished_at_s: f64,
}

/// SLO record of one served request.
#[derive(Debug, Clone)]
pub struct RequestMetrics {
    pub request_id: u64,
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    /// Leading prompt tokens served out of the replica's prefix cache at
    /// admission (0 without a cache or on a miss). Prefill ran — and was
    /// priced — only for the remaining suffix.
    pub cached_prompt_tokens: usize,
    /// Model-time prefill seconds the cached prefix saved this request:
    /// `CostModel::prefill_price(full) - prefill_price(suffix)`. 0 on a
    /// miss or without a pricing cost model.
    pub saved_prefill_s: f64,
    /// Corrected prefill communication bytes (TP AllReduce et al.) the
    /// cached prefix saved this request.
    pub saved_prefill_bytes: f64,
    /// Queue wait before admission into the engine's batch.
    pub queue_s: f64,
    /// Time to first token, excluding queueing.
    pub ttft_s: f64,
    /// Mean time per output token after the first.
    pub tpot_s: f64,
    /// End-to-end latency including queueing.
    pub e2e_s: f64,
    /// Times the request was re-routed after a replica failure
    /// ([`crate::faults`]; 0 on a fault-free path). Each retry restarts
    /// the request from scratch on another replica.
    pub retries: usize,
    /// Model-time prefill seconds burned on failed attempts (prefill ran
    /// on a replica that died before the request finished; priced at
    /// `CostModel::prefill_price` of the prefilled suffix). 0 on a
    /// fault-free path.
    pub wasted_prefill_s: f64,
    /// Prefill iterations this request's prompt took: 1 on the one-shot
    /// path, `ceil(suffix / chunk_tokens)` under chunked prefill, 0 for
    /// requests rejected before prefilling.
    pub prefill_chunks: usize,
    /// Model-time seconds other requests' prefill work added to this
    /// request's decode stream: full stalls under one-shot prefills
    /// landing mid-decode, fused-minus-decode-alone stretch in mixed
    /// chunked iterations. The per-request face of prefill/decode
    /// interference — what disaggregation removes and chunking
    /// amortizes. 0 without a pricing cost model.
    pub interference_s: f64,
    /// Model-time latencies from the priced timeline (structural serving);
    /// `None` on unpriced engines and on requests rejected before
    /// admission.
    pub model: Option<ModelRequestTimes>,
    /// Set when the request did not complete its decode span — e.g. the
    /// KV pool was exhausted mid-decode and the sequence was bailed out
    /// (`generated_tokens` counts what it produced before that).
    pub error: Option<String>,
}

/// p50 / p95 / p99 of one latency metric, in seconds.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LatencyPercentiles {
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
}

impl LatencyPercentiles {
    /// One NaN-filter + sort, three nearest-rank lookups.
    pub fn from_samples(samples: &[f64]) -> Self {
        Self::from_sorted(&sorted_clean(samples))
    }

    /// p50/p95/p99 straight off an already-sorted, NaN-free buffer — the
    /// single-sort contract every percentile path shares: each series is
    /// sorted exactly once and all three ranks read the same buffer.
    pub fn from_sorted(v: &[f64]) -> Self {
        if v.is_empty() {
            return Self::default();
        }
        debug_assert!(
            v.windows(2).all(|w| w[0] <= w[1]),
            "from_sorted wants a sorted, NaN-free buffer"
        );
        let rank = |p: f64| v[nearest_rank(p, v.len())];
        Self { p50_s: rank(50.0), p95_s: rank(95.0), p99_s: rank(99.0) }
    }
}

/// NaN-filtered, total-order-sorted copy of `samples` — the shared
/// preprocessing of every percentile path.
fn sorted_clean(samples: &[f64]) -> Vec<f64> {
    let mut v: Vec<f64> = samples.iter().copied().filter(|x| !x.is_nan()).collect();
    v.sort_by(|a, b| a.total_cmp(b));
    v
}

/// Nearest-rank index for percentile `p` over `len` sorted samples.
fn nearest_rank(p: f64, len: usize) -> usize {
    let rank = ((p / 100.0) * (len as f64 - 1.0)).round() as usize;
    rank.min(len - 1)
}

/// Model-time aggregate of a serving run (the structural analogue of the
/// wall-clock fields of [`ServeSummary`]).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ModelServeSummary {
    /// Model-clock span of the run: session epoch (t = 0) to the last
    /// token's clock. Open-loop arrival offsets are inside the span —
    /// matching the wall-clock side — so low-rate Poisson runs include
    /// their pre-arrival idle time here and in `tokens_per_s`.
    pub makespan_s: f64,
    /// Generated tokens per model-time second.
    pub tokens_per_s: f64,
    pub ttft: LatencyPercentiles,
    pub tpot: LatencyPercentiles,
    pub e2e: LatencyPercentiles,
    pub e2e_mean_s: f64,
}

/// Aggregate over a batch of served requests.
#[derive(Debug, Clone, Default)]
pub struct ServeSummary {
    pub requests: usize,
    /// Requests that completed their full decode span.
    pub completed: usize,
    /// Requests bailed out with an error in their metrics.
    pub failed: usize,
    pub total_tokens: usize,
    pub wall_s: f64,
    pub tokens_per_s: f64,
    pub requests_per_s: f64,
    pub ttft: LatencyPercentiles,
    pub tpot: LatencyPercentiles,
    pub e2e: LatencyPercentiles,
    pub e2e_mean_s: f64,
    /// Total prompt tokens served out of prefix caches across the run
    /// (0 when no cache is configured).
    pub cached_prompt_tokens: usize,
    /// Total model-time prefill seconds saved by prefix-cache hits,
    /// summed over requests in completion order.
    pub saved_prefill_s: f64,
    /// Total corrected prefill communication bytes saved by prefix-cache
    /// hits.
    pub saved_prefill_bytes: f64,
    /// Total replica-failure retries across the run (0 without fault
    /// injection).
    pub retries: usize,
    /// Total model-time prefill seconds burned on failed attempts.
    pub wasted_prefill_s: f64,
    /// Wire bytes the plan's quantized collectives kept off the fabric:
    /// traced AllReduce/AllGather corrected volume × `(1 − wire_bits/16)`.
    /// Exactly 0.0 at the default 16-bit tuning. Stamped by the serving
    /// layer after the run (it needs the engine's trace, which
    /// `from_metrics` does not see).
    pub wire_saved_bytes: f64,
    /// Collective seconds the tuning's overlap factor hid behind compute
    /// across the run (0.0 at the default zero overlap). Stamped by the
    /// serving layer after the run.
    pub hidden_comm_s: f64,
    /// Requests whose prompt prefilled in more than one chunk (0 with
    /// chunked prefill off).
    pub chunked_requests: usize,
    /// Total model-time seconds prefill work stole from decoding
    /// requests across the run (Σ per-request `interference_s`).
    pub interference_s: f64,
    /// Model-time percentiles from the priced timeline — present when the
    /// run served through a pricing engine (structural plans), absent on
    /// wall-clock-only (numeric) serving.
    pub model: Option<ModelServeSummary>,
}

/// Band filtering shared by the wall- and model-clock summaries: samples
/// of one latency metric over *error-free* requests that generated at
/// least `min_tokens` tokens. Errored requests stamp placeholder `0.0`
/// latencies (a bailed sequence never finished its span; a rejected one
/// never started), and a zero sample deflates p50 toward a fictitious
/// perfect SLO — failures are counted in `failed`/goodput, never in the
/// latency bands. The accessor returns `None` for requests without the
/// clock in question.
fn banded_samples(
    metrics: &[RequestMetrics],
    min_tokens: usize,
    value: impl Fn(&RequestMetrics) -> Option<f64>,
) -> Vec<f64> {
    metrics
        .iter()
        .filter(|m| m.error.is_none() && m.generated_tokens >= min_tokens)
        .filter_map(value)
        .collect()
}

/// Mean with the empty-input convention the summaries share.
fn mean_or_zero(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        0.0
    } else {
        samples.iter().sum::<f64>() / samples.len() as f64
    }
}

/// Percentile over unsorted samples (nearest-rank). NaN-safe: NaN samples
/// are ignored, and an empty (or all-NaN) input yields `0.0`.
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p));
    let v = sorted_clean(samples);
    if v.is_empty() {
        return 0.0;
    }
    v[nearest_rank(p, v.len())]
}

impl ServeSummary {
    pub fn from_metrics(metrics: &[RequestMetrics], wall: Duration) -> Self {
        let wall_s = wall.as_secs_f64();
        // Every scalar total comes out of one pass over the metrics (the
        // in-order f64 sums are bitwise what the per-field `sum()` chains
        // computed). The latency series are collected separately because
        // each one band-filters differently (see `banded_samples`).
        let mut total_tokens = 0usize;
        let mut failed = 0usize;
        let mut cached_prompt_tokens = 0usize;
        let mut saved_prefill_s = 0.0;
        let mut saved_prefill_bytes = 0.0;
        let mut retries = 0usize;
        let mut wasted_prefill_s = 0.0;
        let mut chunked_requests = 0usize;
        let mut interference_s = 0.0;
        for m in metrics {
            total_tokens += m.generated_tokens;
            failed += usize::from(m.error.is_some());
            cached_prompt_tokens += m.cached_prompt_tokens;
            saved_prefill_s += m.saved_prefill_s;
            saved_prefill_bytes += m.saved_prefill_bytes;
            retries += m.retries;
            wasted_prefill_s += m.wasted_prefill_s;
            chunked_requests += usize::from(m.prefill_chunks > 1);
            interference_s += m.interference_s;
        }
        // Latency bands come from error-free requests that actually
        // produced the measured quantity (see `banded_samples`);
        // requests_per_s counts completed requests only, never rejected
        // or bailed ones.
        let ttfts = banded_samples(metrics, 1, |m| Some(m.ttft_s));
        let tpots = banded_samples(metrics, 2, |m| Some(m.tpot_s));
        let e2es = banded_samples(metrics, 1, |m| Some(m.e2e_s));
        let completed = metrics.len() - failed;
        Self {
            requests: metrics.len(),
            completed,
            failed,
            total_tokens,
            wall_s,
            tokens_per_s: if wall_s > 0.0 { total_tokens as f64 / wall_s } else { 0.0 },
            requests_per_s: if wall_s > 0.0 { completed as f64 / wall_s } else { 0.0 },
            ttft: LatencyPercentiles::from_samples(&ttfts),
            tpot: LatencyPercentiles::from_samples(&tpots),
            e2e: LatencyPercentiles::from_samples(&e2es),
            e2e_mean_s: mean_or_zero(&e2es),
            cached_prompt_tokens,
            saved_prefill_s,
            saved_prefill_bytes,
            retries,
            wasted_prefill_s,
            wire_saved_bytes: 0.0,
            hidden_comm_s: 0.0,
            chunked_requests,
            interference_s,
            model: Self::model_summary(metrics, total_tokens),
        }
    }

    /// Model-time aggregate over the requests that carry priced-timeline
    /// latencies (same band-filtering rules as the wall-clock side).
    fn model_summary(metrics: &[RequestMetrics], total_tokens: usize) -> Option<ModelServeSummary> {
        if !metrics.iter().any(|m| m.model.is_some()) {
            return None;
        }
        let model = |f: fn(&ModelRequestTimes) -> f64| {
            move |m: &RequestMetrics| m.model.as_ref().map(f)
        };
        let ttfts = banded_samples(metrics, 1, model(|t| t.ttft_s));
        let tpots = banded_samples(metrics, 2, model(|t| t.tpot_s));
        let e2es = banded_samples(metrics, 1, model(|t| t.e2e_s));
        let makespan_s = banded_samples(metrics, 1, model(|t| t.finished_at_s))
            .into_iter()
            .fold(0.0, f64::max);
        Some(ModelServeSummary {
            makespan_s,
            tokens_per_s: if makespan_s > 0.0 {
                total_tokens as f64 / makespan_s
            } else {
                0.0
            },
            ttft: LatencyPercentiles::from_samples(&ttfts),
            tpot: LatencyPercentiles::from_samples(&tpots),
            e2e: LatencyPercentiles::from_samples(&e2es),
            e2e_mean_s: mean_or_zero(&e2es),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(id: u64, ttft_s: f64, tpot_s: f64, e2e_s: f64, error: Option<String>) -> RequestMetrics {
        RequestMetrics {
            request_id: id,
            prompt_tokens: 8,
            generated_tokens: 10,
            cached_prompt_tokens: 0,
            saved_prefill_s: 0.0,
            saved_prefill_bytes: 0.0,
            queue_s: 0.0,
            ttft_s,
            tpot_s,
            e2e_s,
            retries: 0,
            wasted_prefill_s: 0.0,
            prefill_chunks: 1,
            interference_s: 0.0,
            model: None,
            error,
        }
    }

    #[test]
    fn percentile_nearest_rank() {
        let v = vec![4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 4.0);
        assert_eq!(percentile(&v, 50.0), 3.0); // rank round(0.5*3)=2 -> 3.0
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn summary_percentiles_match_single_call_percentile_bitwise() {
        // The summary now sorts each latency series exactly once and reads
        // p50/p95/p99 off the same sorted buffer. That restructuring must be
        // invisible: every percentile stays bitwise equal to the one-shot
        // `percentile()` helper over the raw series.
        let metrics: Vec<RequestMetrics> = (0..37u64)
            .map(|i| {
                let x = ((i.wrapping_mul(2654435761) % 97) as f64) * 0.013 + 0.001;
                m(i, x, x * 0.1, x * 2.0, None)
            })
            .collect();
        let s = ServeSummary::from_metrics(&metrics, Duration::from_secs_f64(1.0));
        let ttfts: Vec<f64> = metrics.iter().map(|m| m.ttft_s).collect();
        let tpots: Vec<f64> = metrics.iter().map(|m| m.tpot_s).collect();
        let e2es: Vec<f64> = metrics.iter().map(|m| m.e2e_s).collect();
        for (band, series) in [(&s.ttft, &ttfts), (&s.tpot, &tpots), (&s.e2e, &e2es)] {
            assert_eq!(band.p50_s.to_bits(), percentile(series, 50.0).to_bits());
            assert_eq!(band.p95_s.to_bits(), percentile(series, 95.0).to_bits());
            assert_eq!(band.p99_s.to_bits(), percentile(series, 99.0).to_bits());
        }
        // from_sorted over a pre-sorted buffer is the same as from_samples.
        assert_eq!(
            LatencyPercentiles::from_sorted(&sorted_clean(&ttfts)),
            LatencyPercentiles::from_samples(&ttfts)
        );
    }

    #[test]
    fn percentile_empty_and_nan_are_safe() {
        assert_eq!(percentile(&[], 50.0), 0.0, "empty input is a defined 0.0");
        assert_eq!(percentile(&[], 0.0), 0.0);
        assert_eq!(percentile(&[], 100.0), 0.0);
        // NaN samples are dropped rather than poisoning the sort...
        assert_eq!(percentile(&[f64::NAN, 2.0, 1.0], 100.0), 2.0);
        // ...and an all-NaN input degrades to the empty case.
        assert_eq!(percentile(&[f64::NAN, f64::NAN], 50.0), 0.0);
    }

    #[test]
    fn summary_aggregates_with_percentile_bands() {
        let metrics: Vec<RequestMetrics> = (0..10)
            .map(|i| m(i, 0.1 * (i + 1) as f64, 0.01, 0.2 * (i + 1) as f64, None))
            .collect();
        let s = ServeSummary::from_metrics(&metrics, Duration::from_secs(1));
        assert_eq!(s.requests, 10);
        assert_eq!(s.completed, 10);
        assert_eq!(s.failed, 0);
        assert_eq!(s.total_tokens, 100);
        assert!((s.tokens_per_s - 100.0).abs() < 1e-9);
        assert!((s.e2e_mean_s - 1.1).abs() < 1e-9);
        // Bands are ordered and hit the nearest-rank values.
        assert!(s.ttft.p50_s <= s.ttft.p95_s && s.ttft.p95_s <= s.ttft.p99_s);
        assert!((s.ttft.p50_s - 0.6).abs() < 1e-9); // rank round(0.5*9)=5 -> 6th
        assert!((s.ttft.p99_s - 1.0).abs() < 1e-9);
        assert!(s.e2e.p50_s <= s.e2e.p99_s);
    }

    #[test]
    fn prefix_savings_sum_across_requests() {
        let mut a = m(0, 0.1, 0.01, 0.2, None);
        a.cached_prompt_tokens = 24;
        a.saved_prefill_s = 0.5;
        a.saved_prefill_bytes = 1024.0;
        let mut b = m(1, 0.1, 0.01, 0.2, None);
        b.cached_prompt_tokens = 8;
        b.saved_prefill_s = 0.25;
        b.saved_prefill_bytes = 512.0;
        let s = ServeSummary::from_metrics(&[a, b], Duration::from_secs(1));
        assert_eq!(s.cached_prompt_tokens, 32);
        assert_eq!(s.saved_prefill_s, 0.5 + 0.25);
        assert_eq!(s.saved_prefill_bytes, 1536.0);
    }

    #[test]
    fn model_time_summary_aggregates_when_present() {
        // Wall-only metrics: no model summary at all.
        let wall_only = vec![m(0, 0.1, 0.01, 0.2, None)];
        assert!(ServeSummary::from_metrics(&wall_only, Duration::from_secs(1)).model.is_none());

        // Mixed: model percentiles come from the model clocks, wall
        // percentiles stay on the wall clocks.
        let metrics: Vec<RequestMetrics> = (0..4)
            .map(|i| {
                let mut r = m(i, 0.001, 0.0001, 0.002, None);
                let e2e = 0.25 * (i + 1) as f64;
                r.model = Some(ModelRequestTimes {
                    queue_s: 0.0,
                    ttft_s: 0.1 * (i + 1) as f64,
                    tpot_s: 0.01,
                    e2e_s: e2e,
                    finished_at_s: e2e,
                });
                r
            })
            .collect();
        let s = ServeSummary::from_metrics(&metrics, Duration::from_secs(1));
        let mt = s.model.expect("model summary present");
        assert!((mt.makespan_s - 1.0).abs() < 1e-12, "makespan is the last finish");
        assert!((mt.tokens_per_s - 40.0).abs() < 1e-9, "40 tokens over 1.0 model-seconds");
        assert!((mt.ttft.p99_s - 0.4).abs() < 1e-12);
        assert!(mt.e2e.p50_s > s.e2e.p50_s, "model clocks dominate these wall clocks");
        // A request with no model times (rejected at submit) does not
        // poison the aggregation.
        let mut metrics = metrics;
        let mut rejected = m(9, 0.0, 0.0, 0.0, Some("queue full".into()));
        rejected.generated_tokens = 0;
        metrics.push(rejected);
        let s = ServeSummary::from_metrics(&metrics, Duration::from_secs(1));
        assert!((s.model.unwrap().ttft.p99_s - 0.4).abs() < 1e-12);
    }

    #[test]
    fn retries_and_wasted_prefill_sum_across_requests() {
        let mut a = m(0, 0.1, 0.01, 0.2, None);
        a.retries = 2;
        a.wasted_prefill_s = 0.03;
        let mut b = m(1, 0.1, 0.01, 0.2, None);
        b.retries = 1;
        b.wasted_prefill_s = 0.01;
        let s = ServeSummary::from_metrics(&[a, b], Duration::from_secs(1));
        assert_eq!(s.retries, 3);
        assert!((s.wasted_prefill_s - 0.04).abs() < 1e-12);
        // The fault-free path stays all-zero.
        let s = ServeSummary::from_metrics(&[m(0, 0.1, 0.01, 0.2, None)], Duration::ZERO);
        assert_eq!(s.retries, 0);
        assert_eq!(s.wasted_prefill_s, 0.0);
    }

    #[test]
    fn summary_counts_failures_without_skewing_latency_bands() {
        let mut failed = m(1, 0.0, 0.0, 0.05, Some("queue full".into()));
        failed.generated_tokens = 0; // rejected before any token
        let metrics = vec![
            m(0, 0.1, 0.01, 0.2, None),
            m(2, 0.3, 0.02, 0.4, None),
            failed,
        ];
        let s = ServeSummary::from_metrics(&metrics, Duration::from_secs(1));
        assert_eq!((s.requests, s.completed, s.failed), (3, 2, 1));
        // The zero-token failure's placeholder 0.0 samples stay out of the
        // TTFT/TPOT bands; E2E still covers every request.
        // Two samples [0.1, 0.3]: nearest rank round(0.5*1)=1 -> 0.3; with
        // the failure's 0.0 included it would be 0.1.
        assert!((s.ttft.p50_s - 0.3).abs() < 1e-9, "p50 over token-producing requests");
        assert!(s.tpot.p50_s > 0.0);
        assert!((s.e2e.p50_s - 0.4).abs() < 1e-9, "rejected request's 0.05s stays out");
        // Throughput counts completed requests, not rejected ones.
        assert!((s.requests_per_s - 2.0).abs() < 1e-9);
    }

    #[test]
    fn errored_requests_with_partial_tokens_stay_out_of_latency_bands() {
        // A mid-decode bail-out produced real tokens but stamps a
        // placeholder tpot_s of 0.0 (its span never finished). That zero
        // must not deflate p50: the band filter keys on `error`, not
        // just token counts.
        let mut bailed = m(1, 0.05, 0.0, 0.1, Some("KV pool exhausted".into()));
        bailed.generated_tokens = 5; // partial progress, still errored
        let mut bailed_model = bailed.clone();
        bailed_model.request_id = 3;
        bailed_model.model = Some(ModelRequestTimes {
            queue_s: 0.0,
            ttft_s: 0.0,
            tpot_s: 0.0,
            e2e_s: 0.0,
            finished_at_s: 0.0,
        });
        let mut ok = m(0, 0.2, 0.03, 0.5, None);
        ok.model = Some(ModelRequestTimes {
            queue_s: 0.0,
            ttft_s: 0.2,
            tpot_s: 0.03,
            e2e_s: 0.5,
            finished_at_s: 0.5,
        });
        let s = ServeSummary::from_metrics(
            &[ok, bailed, bailed_model],
            Duration::from_secs(1),
        );
        assert_eq!((s.completed, s.failed), (1, 2));
        // Without the error filter these would read 0.0 (two zero
        // samples out of three put the median on a placeholder).
        assert!((s.tpot.p50_s - 0.03).abs() < 1e-12, "wall tpot band excludes failures");
        assert!((s.ttft.p50_s - 0.2).abs() < 1e-12);
        assert!((s.e2e.p50_s - 0.5).abs() < 1e-12);
        let mt = s.model.expect("one priced request");
        assert!((mt.tpot.p50_s - 0.03).abs() < 1e-12, "model tpot band excludes failures");
        assert!((mt.ttft.p50_s - 0.2).abs() < 1e-12);
    }

    #[test]
    fn chunk_and_interference_totals_sum_across_requests() {
        let mut a = m(0, 0.1, 0.01, 0.2, None);
        a.prefill_chunks = 4;
        a.interference_s = 0.002;
        let mut b = m(1, 0.1, 0.01, 0.2, None);
        b.prefill_chunks = 1; // one-shot prompt: not a chunked request
        b.interference_s = 0.001;
        let s = ServeSummary::from_metrics(&[a, b], Duration::from_secs(1));
        assert_eq!(s.chunked_requests, 1);
        assert!((s.interference_s - 0.003).abs() < 1e-15);
        // The unchunked path stays all-zero.
        let s = ServeSummary::from_metrics(&[m(0, 0.1, 0.01, 0.2, None)], Duration::ZERO);
        assert_eq!(s.chunked_requests, 0);
        assert_eq!(s.interference_s, 0.0);
    }
}
