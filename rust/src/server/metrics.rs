//! Per-request SLO metrics and aggregation (paper §II.A: TTFT, TPOT,
//! throughput; §V.C evaluates these across parallelism layouts).

use std::time::Duration;


/// SLO record of one served request.
#[derive(Debug, Clone)]
pub struct RequestMetrics {
    pub request_id: u64,
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    /// Queue wait before the engine started prefill.
    pub queue_s: f64,
    /// Time to first token, excluding queueing.
    pub ttft_s: f64,
    /// Mean time per output token after the first.
    pub tpot_s: f64,
    /// End-to-end latency including queueing.
    pub e2e_s: f64,
}

/// Aggregate over a batch of served requests.
#[derive(Debug, Clone, Default)]
pub struct ServeSummary {
    pub requests: usize,
    pub total_tokens: usize,
    pub wall_s: f64,
    pub tokens_per_s: f64,
    pub requests_per_s: f64,
    pub ttft_p50_s: f64,
    pub ttft_p99_s: f64,
    pub tpot_p50_s: f64,
    pub tpot_p99_s: f64,
    pub e2e_mean_s: f64,
}

/// Percentile over unsorted samples (nearest-rank).
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p));
    if samples.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

impl ServeSummary {
    pub fn from_metrics(metrics: &[RequestMetrics], wall: Duration) -> Self {
        let wall_s = wall.as_secs_f64();
        let total_tokens: usize = metrics.iter().map(|m| m.generated_tokens).sum();
        let ttfts: Vec<f64> = metrics.iter().map(|m| m.ttft_s).collect();
        let tpots: Vec<f64> = metrics.iter().map(|m| m.tpot_s).collect();
        let e2es: Vec<f64> = metrics.iter().map(|m| m.e2e_s).collect();
        Self {
            requests: metrics.len(),
            total_tokens,
            wall_s,
            tokens_per_s: if wall_s > 0.0 { total_tokens as f64 / wall_s } else { 0.0 },
            requests_per_s: if wall_s > 0.0 { metrics.len() as f64 / wall_s } else { 0.0 },
            ttft_p50_s: percentile(&ttfts, 50.0),
            ttft_p99_s: percentile(&ttfts, 99.0),
            tpot_p50_s: percentile(&tpots, 50.0),
            tpot_p99_s: percentile(&tpots, 99.0),
            e2e_mean_s: if e2es.is_empty() {
                0.0
            } else {
                e2es.iter().sum::<f64>() / e2es.len() as f64
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let v = vec![4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 4.0);
        assert_eq!(percentile(&v, 50.0), 3.0); // rank round(0.5*3)=2 -> 3.0
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn summary_aggregates() {
        let metrics = vec![
            RequestMetrics {
                request_id: 0,
                prompt_tokens: 8,
                generated_tokens: 10,
                queue_s: 0.0,
                ttft_s: 0.1,
                tpot_s: 0.01,
                e2e_s: 0.2,
            },
            RequestMetrics {
                request_id: 1,
                prompt_tokens: 8,
                generated_tokens: 10,
                queue_s: 0.05,
                ttft_s: 0.3,
                tpot_s: 0.02,
                e2e_s: 0.5,
            },
        ];
        let s = ServeSummary::from_metrics(&metrics, Duration::from_secs(1));
        assert_eq!(s.requests, 2);
        assert_eq!(s.total_tokens, 20);
        assert!((s.tokens_per_s - 20.0).abs() < 1e-9);
        assert!((s.e2e_mean_s - 0.35).abs() < 1e-9);
        assert!(s.ttft_p99_s >= s.ttft_p50_s);
    }
}
