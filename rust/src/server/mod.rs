//! Serving front-end: request router + continuous-batching scheduler +
//! engine session + SLO metrics.
//!
//! [`Server`] is the synchronous core (the engine's collectives block);
//! async intake wraps it via a channel in `main.rs`/examples. The serving
//! loop is iteration-level: every pass admits whatever the scheduler's
//! batch slots and prompt-footprint KV check allow, grows each active
//! sequence's KV by the token the iteration is about to write (bailing a
//! sequence out cleanly when the pool is exhausted), then runs exactly one
//! [`crate::engine::Session::step`] — so requests join and leave the
//! decode batch between iterations, vLLM-style, and per-request
//! [`RequestMetrics`] come from the streamed token events.
//!
//! Workload knobs: [`SchedulerConfig::max_batch`] is the concurrency
//! limit (clamped to 1 on numeric engines, whose PJRT backends hold
//! single-sequence KV state), and [`Server::serve_poisson`] replays an
//! open-loop Poisson arrival process at a configurable rate.

pub mod metrics;
pub mod prefix_cache;
pub mod scheduler;

pub use metrics::{
    percentile, LatencyPercentiles, ModelRequestTimes, ModelServeSummary, RequestMetrics,
    ServeSummary,
};
pub use prefix_cache::{PrefixCache, PrefixCacheConfig, PrefixCacheStats};
pub use scheduler::{PromptTokens, Request, Scheduler, SchedulerConfig};

use std::collections::{HashMap, VecDeque};
use std::time::{Duration, Instant};

use crate::engine::kv::SeqId;
use crate::engine::{Engine, SequenceInput};
use crate::Result;

/// Model-clock bookkeeping of one in-flight request (priced engines).
struct ModelFlight {
    arrival_s: f64,
    admitted_s: f64,
    first_token_s: Option<f64>,
    last_token_s: f64,
}

/// Per-request bookkeeping while a sequence is in the engine.
struct InFlight {
    prompt_tokens: usize,
    cached_tokens: usize,
    saved_prefill_s: f64,
    saved_prefill_bytes: f64,
    enqueued_at: Instant,
    admitted_at: Instant,
    first_token_at: Option<Instant>,
    last_token_at: Instant,
    generated: usize,
    /// Prefill iterations the prompt took (1 one-shot; chunked counts).
    prefill_chunks: usize,
    /// Model-time seconds other prompts' prefill work stole from this
    /// request's decode stream.
    interference_s: f64,
    model: Option<ModelFlight>,
}

/// The serving loop: continuous-batching scheduler in front of an engine.
pub struct Server {
    engine: Engine,
    scheduler: Scheduler,
    /// Prefix-cache model ([`Self::with_prefix_cache`]): admissions
    /// consume a cached-prefix hint, prefill only the uncached suffix,
    /// and record saved prefill seconds/bytes.
    prefix: Option<PrefixCache>,
    completed: Vec<RequestMetrics>,
}

impl Server {
    /// Build the serving stack. `cfg.max_batch` is clamped to 1 when the
    /// engine cannot decode batches (numeric mode's fixed-shape PJRT
    /// executables hold single-sequence KV state).
    pub fn new(engine: Engine, mut cfg: SchedulerConfig) -> Self {
        if !engine.supports_batched_decode() {
            cfg.max_batch = 1;
        }
        Self { engine, scheduler: Scheduler::new(cfg), prefix: None, completed: Vec::new() }
    }

    /// Attach a prefix-cache model: requests whose leading tokens are
    /// resident prefill only their uncached suffix (priced accordingly —
    /// structural engines only; numeric backends hold real KV state and
    /// cannot fake a warm cache, so the cache is rejected there).
    pub fn with_prefix_cache(mut self, cfg: PrefixCacheConfig) -> crate::Result<Self> {
        cfg.validate()?;
        anyhow::ensure!(
            self.engine.supports_batched_decode(),
            "prefix caching needs a structural engine: numeric backends hold \
             real KV state and cannot fake a warm cache"
        );
        let ecfg = self.engine.config();
        let kv = ecfg.arch.kv_bytes_per_token(ecfg.trace_dtype_bytes);
        self.prefix = Some(PrefixCache::new(cfg, kv));
        Ok(self)
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    pub fn scheduler(&self) -> &Scheduler {
        &self.scheduler
    }

    /// The prefix cache, when one is attached.
    pub fn prefix_cache(&self) -> Option<&PrefixCache> {
        self.prefix.as_ref()
    }

    /// Run the engine's warmup request (excluded from traces) so the first
    /// served request's TTFT is not inflated by lazy one-time setup.
    pub fn warmup(&mut self) -> Result<()> {
        self.engine.warmup()
    }

    /// Enqueue a request.
    pub fn submit(&mut self, request: Request) -> Result<()> {
        self.scheduler.submit(request)
    }

    /// Drain the queue through the iteration loop; returns the metrics of
    /// everything served by this call, in completion order.
    pub fn run_to_completion(&mut self) -> Result<&[RequestMetrics]> {
        let first = self.completed.len();
        self.drive(VecDeque::new())?;
        Ok(&self.completed[first..])
    }

    /// Wire bytes the engine's quantized collectives have kept off the
    /// fabric so far, plus its overlap-hidden collective seconds — both
    /// exactly 0.0 at the default tuning. Sampled before/after a serve
    /// call so each summary reports its own run's deltas even when one
    /// server serves several batches.
    fn tuning_totals(&self) -> (f64, f64) {
        let hidden = self.engine.hidden_comm_s();
        let saved = match self.engine.cost_model() {
            Some(cm) if cm.cal.tuning.quantizes() => {
                cm.wire_saved_bytes(&self.engine.trace().summary())
            }
            _ => 0.0,
        };
        (saved, hidden)
    }

    /// Serve a batch of requests arriving all at once and summarize.
    pub fn serve_batch(&mut self, requests: Vec<Request>) -> Result<ServeSummary> {
        let wall_start = Instant::now();
        let first = self.completed.len();
        let (saved0, hidden0) = self.tuning_totals();
        for r in requests {
            self.submit(r)?;
        }
        self.drive(VecDeque::new())?;
        let mut summary =
            ServeSummary::from_metrics(&self.completed[first..], wall_start.elapsed());
        let (saved1, hidden1) = self.tuning_totals();
        summary.wire_saved_bytes = saved1 - saved0;
        summary.hidden_comm_s = hidden1 - hidden0;
        Ok(summary)
    }

    /// Serve with open-loop Poisson arrivals at `rate_per_s`: request `i`
    /// arrives after the i-th exponential inter-arrival gap (deterministic
    /// for a given `seed` — the arrival stream is
    /// [`crate::workload::ArrivalProcess::Poisson`], so a single-replica
    /// fleet simulation replays the exact same offsets). Queueing shows up
    /// in `queue_s`/`e2e_s`.
    pub fn serve_poisson(
        &mut self,
        requests: Vec<Request>,
        rate_per_s: f64,
        seed: u64,
    ) -> Result<ServeSummary> {
        anyhow::ensure!(rate_per_s > 0.0, "arrival rate must be positive (req/s)");
        let wall_start = Instant::now();
        let first = self.completed.len();
        let (saved0, hidden0) = self.tuning_totals();
        let offsets =
            crate::workload::ArrivalProcess::poisson(rate_per_s).offsets(requests.len(), seed);
        let arrivals: VecDeque<(f64, Request)> = offsets.into_iter().zip(requests).collect();
        self.drive(arrivals)?;
        let mut summary =
            ServeSummary::from_metrics(&self.completed[first..], wall_start.elapsed());
        let (saved1, hidden1) = self.tuning_totals();
        summary.wire_saved_bytes = saved1 - saved0;
        summary.hidden_comm_s = hidden1 - hidden0;
        Ok(summary)
    }

    pub fn completed(&self) -> &[RequestMetrics] {
        &self.completed
    }

    /// The iteration loop. `arrivals` are (offset-from-now seconds,
    /// request) pairs submitted once their time comes; an empty deque
    /// serves whatever is already queued.
    ///
    /// On a priced structural engine the loop is a discrete-event
    /// simulation: arrivals gate on the session's *model* clock (idle gaps
    /// jump the clock instead of sleeping), so the model-time metrics are
    /// a pure function of the workload — deterministic for a fixed
    /// arrival seed, independent of host scheduling. Unpriced (numeric)
    /// engines keep the wall-clock behaviour: arrivals gate on host time
    /// and idle gaps really sleep.
    fn drive(&mut self, mut arrivals: VecDeque<(f64, Request)>) -> Result<()> {
        let t0 = Instant::now();
        let mut in_flight: HashMap<SeqId, InFlight> = HashMap::new();
        // Saved-prefill pricing for prefix-cache hits (cloned up front:
        // the session mutably borrows the engine for the whole loop).
        let pricer = self.engine.cost_model().cloned();
        let mut session = self.engine.session();
        // Model-time arrival offsets of open-loop requests (everything
        // submitted before drive() arrived at model t = 0).
        let mut model_arrivals: HashMap<SeqId, f64> = HashMap::new();
        let model_mode = session.model_now().is_some();
        loop {
            // 1. Feed arrivals whose time has come. A rejected submission
            //    (queue full under open-loop load, oversized request) fails
            //    that request, not the serving loop — everything already
            //    in flight keeps its KV and completes normally.
            let arrived = |at: f64| {
                if model_mode {
                    session.model_now().expect("model mode") >= at
                } else {
                    t0.elapsed().as_secs_f64() >= at
                }
            };
            while arrivals.front().is_some_and(|(at, _)| arrived(*at)) {
                let (at, req) = arrivals.pop_front().expect("non-empty");
                let (id, prompt_tokens) = (req.id, req.prompt.len());
                if let Err(e) = self.scheduler.submit(req) {
                    self.completed.push(RequestMetrics {
                        request_id: id,
                        prompt_tokens,
                        generated_tokens: 0,
                        cached_prompt_tokens: 0,
                        saved_prefill_s: 0.0,
                        saved_prefill_bytes: 0.0,
                        queue_s: 0.0,
                        ttft_s: 0.0,
                        tpot_s: 0.0,
                        e2e_s: 0.0,
                        retries: 0,
                        wasted_prefill_s: 0.0,
                        prefill_chunks: 0,
                        interference_s: 0.0,
                        model: None,
                        error: Some(e.to_string()),
                    });
                } else if model_mode {
                    model_arrivals.insert(id, at);
                }
            }

            // 2. Admit while batch slots and prompt KV allow. With a
            //    prefix cache, the head-of-line request's cached-prefix
            //    hint shrinks both its KV charge and the prefill the
            //    session will run (suffix-only, priced accordingly).
            loop {
                // Raw lookup: `admit_next_with_cached` owns the clamp
                // that keeps at least one token prefilling.
                let cached_hint = match (&self.prefix, self.scheduler.peek()) {
                    (Some(cache), Some(head)) => cache.lookup(&head.prompt),
                    _ => 0,
                };
                let Some(admitted) = self.scheduler.admit_next_with_cached(cached_hint)? else {
                    break;
                };
                let now = Instant::now();
                let req = admitted.request;
                let cached = admitted.cached_tokens;
                let id = req.id;
                let prompt_tokens = req.prompt.len();
                // Range admission: the session prefills `prompt[cached..]`
                // off the shared tokens — no suffix copy per admission.
                let input = SequenceInput {
                    id,
                    prompt: req.prompt.clone(),
                    start: cached,
                    max_new_tokens: req.decode_len,
                };
                if let Err(e) = session.admit_with_context(input, cached) {
                    // The scheduler admitted something the session rejects
                    // (e.g. a wrong-length prompt for numeric artifacts):
                    // fail the request, not the serving loop.
                    self.scheduler.finish(id)?;
                    let queue_s = (now - admitted.enqueued_at).as_secs_f64();
                    self.completed.push(RequestMetrics {
                        request_id: id,
                        prompt_tokens,
                        generated_tokens: 0,
                        cached_prompt_tokens: 0,
                        saved_prefill_s: 0.0,
                        saved_prefill_bytes: 0.0,
                        queue_s,
                        ttft_s: 0.0,
                        tpot_s: 0.0,
                        e2e_s: queue_s,
                        retries: 0,
                        wasted_prefill_s: 0.0,
                        prefill_chunks: 0,
                        interference_s: 0.0,
                        model: None,
                        error: Some(e.to_string()),
                    });
                    continue;
                }
                if let Some(cache) = &mut self.prefix {
                    // Record the admitted prompt: touch its hit blocks,
                    // insert the rest (LRU on the model clock). Only
                    // prompts the session accepted enter the cache — a
                    // rejected admission computes no KV.
                    let now_s = session
                        .model_now()
                        .unwrap_or_else(|| t0.elapsed().as_secs_f64());
                    cache.observe(&req.prompt, now_s);
                }
                let (saved_prefill_s, saved_prefill_bytes) = match (&pricer, cached) {
                    (Some(cm), c) if c > 0 => (
                        cm.prefill_price(prompt_tokens) - cm.prefill_price(prompt_tokens - c),
                        cm.prefill_comm_bytes(prompt_tokens)
                            - cm.prefill_comm_bytes(prompt_tokens - c),
                    ),
                    _ => (0.0, 0.0),
                };
                let model = session.model_now().map(|now_m| {
                    let arrival_s = model_arrivals.remove(&id).unwrap_or(0.0);
                    let admitted_s = now_m.max(arrival_s);
                    ModelFlight {
                        arrival_s,
                        admitted_s,
                        first_token_s: None,
                        last_token_s: admitted_s,
                    }
                });
                in_flight.insert(
                    id,
                    InFlight {
                        prompt_tokens,
                        cached_tokens: cached,
                        saved_prefill_s,
                        saved_prefill_bytes,
                        enqueued_at: admitted.enqueued_at,
                        admitted_at: now,
                        first_token_at: None,
                        last_token_at: now,
                        generated: 0,
                        prefill_chunks: 1,
                        interference_s: 0.0,
                        model,
                    },
                );
            }

            // 3. Nothing running: either done, blocked, or between arrivals.
            if session.is_idle() {
                if self.scheduler.queue_len() > 0 {
                    // Safety net: with an idle session every block is free,
                    // and submit() already rejected never-fitting requests.
                    anyhow::bail!("head-of-line request cannot fit the KV pool");
                }
                match arrivals.front() {
                    Some(&(at, _)) => {
                        if model_mode {
                            // Discrete-event jump to the next arrival.
                            session.advance_model_time_to(at);
                        } else {
                            let now = t0.elapsed().as_secs_f64();
                            if at > now {
                                std::thread::sleep(Duration::from_secs_f64(at - now));
                            }
                        }
                        continue;
                    }
                    None => break,
                }
            }

            // 4. Before an iteration that decodes the active batch (a
            //    pure decode, or a mixed chunk+decode step), reserve KV
            //    for the token each active sequence is about to write;
            //    bail out the ones the pool cannot hold (blocks
            //    released, error in the metrics).
            if session.decode_in_next_step() {
                for id in session.active_ids() {
                    if self.scheduler.grow(id).is_ok() {
                        continue;
                    }
                    session.cancel(id);
                    let info = in_flight.remove(&id).expect("active seq tracked");
                    self.scheduler.finish(id)?;
                    self.completed.push(Self::request_metrics(
                        id,
                        &info,
                        Some("KV pool exhausted mid-decode; sequence bailed out".to_string()),
                    ));
                }
                if session.is_idle() {
                    continue; // every active sequence bailed; re-admit
                }
            }

            // 5. One engine iteration (prefill, chunk, mixed, or
            //    batched decode).
            let outcome = session.step()?;
            let now = Instant::now();
            let now_model = session.model_now();
            // Interference bookkeeping: seconds this iteration's prefill
            // work added to each mid-decode victim, and the chunk count
            // of a prompt that just finished prefilling.
            for &(victim, stretch) in &outcome.interference {
                if let Some(info) = in_flight.get_mut(&victim) {
                    info.interference_s += stretch;
                }
            }
            if let Some((owner, chunks)) = outcome.chunk_owner {
                if let Some(info) = in_flight.get_mut(&owner) {
                    info.prefill_chunks = chunks as usize;
                }
            }
            for e in &outcome.events {
                if let Some(info) = in_flight.get_mut(&e.seq) {
                    info.generated += 1;
                    if info.first_token_at.is_none() {
                        info.first_token_at = Some(now);
                    }
                    info.last_token_at = now;
                    if let (Some(mf), Some(tm)) = (info.model.as_mut(), now_model) {
                        if mf.first_token_s.is_none() {
                            mf.first_token_s = Some(tm);
                        }
                        mf.last_token_s = tm;
                    }
                }
            }
            for id in &outcome.finished {
                let info = in_flight.remove(id).expect("finished seq tracked");
                self.scheduler.finish(*id)?;
                self.completed.push(Self::request_metrics(*id, &info, None));
            }
        }
        Ok(())
    }

    fn request_metrics(id: SeqId, info: &InFlight, error: Option<String>) -> RequestMetrics {
        let first = info.first_token_at.unwrap_or(info.admitted_at);
        let tpot_s = if info.generated > 1 {
            (info.last_token_at - first).as_secs_f64() / (info.generated - 1) as f64
        } else {
            0.0
        };
        let model = info.model.as_ref().map(|mf| {
            let first_s = mf.first_token_s.unwrap_or(mf.admitted_s);
            ModelRequestTimes {
                queue_s: mf.admitted_s - mf.arrival_s,
                ttft_s: if mf.first_token_s.is_some() {
                    first_s - mf.admitted_s
                } else {
                    0.0
                },
                tpot_s: if info.generated > 1 {
                    (mf.last_token_s - first_s) / (info.generated - 1) as f64
                } else {
                    0.0
                },
                e2e_s: mf.last_token_s - mf.arrival_s,
                finished_at_s: mf.last_token_s,
            }
        });
        RequestMetrics {
            request_id: id,
            prompt_tokens: info.prompt_tokens,
            generated_tokens: info.generated,
            cached_prompt_tokens: info.cached_tokens,
            saved_prefill_s: info.saved_prefill_s,
            saved_prefill_bytes: info.saved_prefill_bytes,
            queue_s: (info.admitted_at - info.enqueued_at).as_secs_f64(),
            ttft_s: if info.first_token_at.is_some() {
                (first - info.admitted_at).as_secs_f64()
            } else {
                0.0
            },
            tpot_s,
            e2e_s: (info.last_token_at - info.enqueued_at).as_secs_f64(),
            // Single-replica serving has no router to retry through; the
            // fleet's fault-injection path stamps these.
            retries: 0,
            wasted_prefill_s: 0.0,
            prefill_chunks: info.prefill_chunks,
            interference_s: info.interference_s,
            model,
            error,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::ParallelLayout;
    use crate::engine::EngineConfig;
    use crate::model::ModelArch;

    fn tiny_server(tp: usize, pp: usize, max_batch: usize) -> Server {
        let cfg = EngineConfig::structural(ModelArch::tiny(), ParallelLayout::new(tp, pp));
        Server::new(
            Engine::new(cfg).unwrap(),
            SchedulerConfig { kv_blocks: 64, kv_block_size: 16, max_queue: 64, max_batch },
        )
    }

    fn reqs(n: u64, prompt: usize, decode: usize) -> Vec<Request> {
        (0..n)
            .map(|id| Request { id, prompt: vec![0; prompt].into(), decode_len: decode })
            .collect()
    }

    #[test]
    fn serves_batch_and_releases_kv() {
        let mut srv = tiny_server(2, 2, 4);
        let summary = srv.serve_batch(reqs(4, 16, 8)).unwrap();
        assert_eq!(summary.requests, 4);
        assert_eq!(summary.completed, 4);
        assert_eq!(summary.failed, 0);
        assert_eq!(summary.total_tokens, 32);
        assert!(summary.tokens_per_s > 0.0);
        assert_eq!(srv.completed().len(), 4);
        assert_eq!(srv.scheduler().kv().used_blocks(), 0, "all KV released");
        assert_eq!(srv.scheduler().running_len(), 0);
        for m in srv.completed() {
            assert_eq!(m.generated_tokens, 8);
            assert!(m.error.is_none());
        }
    }

    #[test]
    fn fcfs_when_batch_is_one() {
        let mut srv = tiny_server(1, 2, 1);
        srv.serve_batch(reqs(3, 8, 4)).unwrap();
        let ids: Vec<u64> = srv.completed().iter().map(|m| m.request_id).collect();
        assert_eq!(ids, vec![0, 1, 2], "one-at-a-time completes in submission order");
        let m = srv.completed();
        assert!(m[2].queue_s >= m[0].queue_s, "FCFS queueing accumulates");
    }

    #[test]
    fn batched_requests_interleave_completions() {
        let mut srv = tiny_server(2, 1, 4);
        // Equal-length requests decode in lockstep and finish on the same
        // iteration; completion order is batch order, all with small queue
        // delay (no one waits for a predecessor's full decode).
        let summary = srv.serve_batch(reqs(4, 8, 6)).unwrap();
        assert_eq!(summary.completed, 4);
        let max_queue = srv.completed().iter().map(|m| m.queue_s).fold(0.0, f64::max);
        let max_e2e = srv.completed().iter().map(|m| m.e2e_s).fold(0.0, f64::max);
        assert!(
            max_queue < max_e2e,
            "admission happens up front under continuous batching"
        );
    }

    #[test]
    fn kv_exhaustion_bails_one_sequence_and_completes_the_rest() {
        // Pool: 8 blocks x 4 tokens = 32. Two requests of prompt 12 (3
        // blocks each) + decode 12 peak at 6 blocks each = 12 > 8: the
        // old full-span admission would have serialized them; here both
        // run, the pool runs dry mid-decode, one bails with an error and
        // the survivor finishes into the freed blocks.
        let plan_cfg = EngineConfig::structural(ModelArch::tiny(), ParallelLayout::new(2, 1));
        let mut srv = Server::new(
            Engine::new(plan_cfg).unwrap(),
            SchedulerConfig { kv_blocks: 8, kv_block_size: 4, max_queue: 8, max_batch: 4 },
        );
        let summary = srv.serve_batch(reqs(2, 12, 12)).unwrap();
        assert_eq!(summary.requests, 2);
        assert_eq!(summary.failed, 1, "exactly one sequence bails");
        assert_eq!(summary.completed, 1);
        let failed: Vec<&RequestMetrics> =
            srv.completed().iter().filter(|m| m.error.is_some()).collect();
        assert_eq!(failed.len(), 1);
        assert!(failed[0].error.as_ref().unwrap().contains("KV pool exhausted"));
        assert!(failed[0].generated_tokens >= 1, "partial progress is reported");
        let ok: Vec<&RequestMetrics> =
            srv.completed().iter().filter(|m| m.error.is_none()).collect();
        assert_eq!(ok[0].generated_tokens, 12, "survivor completes its span");
        assert_eq!(srv.scheduler().kv().used_blocks(), 0, "bail-out released KV");
    }

    #[test]
    fn poisson_arrivals_serve_everything() {
        let mut srv = tiny_server(2, 1, 4);
        let summary = srv.serve_poisson(reqs(6, 8, 4), 500.0, 0xC0FFEE).unwrap();
        assert_eq!(summary.requests, 6);
        assert_eq!(summary.completed, 6);
        assert_eq!(summary.total_tokens, 24);
        assert!(summary.wall_s > 0.0);
        for m in srv.completed() {
            assert!(m.queue_s >= 0.0 && m.e2e_s >= m.ttft_s);
        }
    }

    #[test]
    fn structural_serving_reports_model_time_next_to_wall_time() {
        let mut srv = tiny_server(2, 1, 4);
        let summary = srv.serve_batch(reqs(4, 16, 8)).unwrap();
        let mt = summary.model.as_ref().expect("priced structural serving");
        assert!(mt.makespan_s > 0.0);
        assert!(mt.tokens_per_s > 0.0);
        assert!(mt.ttft.p50_s > 0.0 && mt.tpot.p50_s > 0.0);
        for m in srv.completed() {
            let t = m.model.as_ref().expect("every served request carries model times");
            assert!(t.ttft_s > 0.0, "prefill is never free in model time");
            assert!(t.e2e_s >= t.ttft_s + t.queue_s);
            assert!(t.finished_at_s <= mt.makespan_s + 1e-12);
        }
        // Single-request model TTFT with an idle server is the SLO
        // simulator's prefill total — one pricing core end to end.
        let mut srv = tiny_server(2, 1, 1);
        let summary = srv.serve_batch(reqs(1, 16, 4)).unwrap();
        let sim = crate::perfmodel::SloSimulator::on_cardinal(
            ModelArch::tiny(),
            ParallelLayout::new(2, 1),
        )
        .unwrap();
        let ttft = sim.prefill(crate::analysis::InferenceShape::new(16, 4, 2)).total();
        let got = summary.model.unwrap().ttft.p50_s;
        assert!(
            (got - ttft).abs() <= 1e-9 * ttft,
            "served model TTFT {got} vs simulated {ttft}"
        );
    }

    #[test]
    fn prefix_cache_prices_only_the_uncached_suffix() {
        let plan_cfg = EngineConfig::structural(ModelArch::tiny(), ParallelLayout::new(2, 1));
        let mut srv = Server::new(
            Engine::new(plan_cfg).unwrap(),
            SchedulerConfig { kv_blocks: 64, kv_block_size: 16, max_queue: 64, max_batch: 1 },
        )
        .with_prefix_cache(PrefixCacheConfig { block_tokens: 4, capacity_bytes: 1 << 20 })
        .unwrap();
        // Two requests with an identical 16-token prompt, served one at a
        // time: the second hits the whole prompt (clamped to 15 so one
        // token still prefills).
        let prompt: PromptTokens = (0..16).collect::<Vec<i32>>().into();
        let reqs = vec![
            Request { id: 0, prompt: prompt.clone(), decode_len: 4 },
            Request { id: 1, prompt: prompt.clone(), decode_len: 4 },
        ];
        let summary = srv.serve_batch(reqs).unwrap();
        assert_eq!(summary.completed, 2);
        let m0 = &srv.completed()[0];
        let m1 = &srv.completed()[1];
        assert_eq!(m0.cached_prompt_tokens, 0, "cold cache");
        assert_eq!(m0.saved_prefill_s, 0.0);
        assert_eq!(m1.cached_prompt_tokens, 15, "full hit, one token prefills");
        assert_eq!(m1.prompt_tokens, 16, "metrics keep the full prompt length");
        // The hit's model TTFT is the suffix's prefill price; the saved
        // seconds are the full-vs-suffix closed-form difference.
        let cm = crate::simtime::CostModel::on_cardinal(
            ModelArch::tiny(),
            ParallelLayout::new(2, 1),
        );
        let t1 = m1.model.as_ref().unwrap();
        let suffix_ttft = cm.prefill_price(1);
        assert!(
            (t1.ttft_s - suffix_ttft).abs() <= 1e-9 * suffix_ttft,
            "hit TTFT {} vs suffix prefill {}",
            t1.ttft_s,
            suffix_ttft
        );
        assert_eq!(m1.saved_prefill_s, cm.prefill_price(16) - cm.prefill_price(1));
        assert!(m1.saved_prefill_bytes > 0.0);
        let t0m = m0.model.as_ref().unwrap();
        assert!(t1.ttft_s < t0m.ttft_s, "the hit beats the cold prefill");
        // Aggregates carry the totals.
        assert_eq!(summary.cached_prompt_tokens, 15);
        assert_eq!(summary.saved_prefill_s, m1.saved_prefill_s);
        // The cache is observable and bounded.
        let cache = srv.prefix_cache().unwrap();
        assert_eq!(cache.stats().observed, 2);
        assert!(cache.resident_bytes() <= cache.config().capacity_bytes);
    }

    #[test]
    fn model_time_poisson_serving_is_deterministic_for_a_seed() {
        let run = |seed: u64| {
            let mut srv = tiny_server(2, 1, 2);
            let summary = srv.serve_poisson(reqs(8, 8, 6), 2000.0, seed).unwrap();
            assert_eq!(summary.completed, 8);
            let mt = summary.model.expect("structural poisson serving is priced");
            let per_req: Vec<(f64, f64, f64)> = srv
                .completed()
                .iter()
                .map(|m| {
                    let t = m.model.as_ref().unwrap();
                    (t.queue_s, t.ttft_s, t.e2e_s)
                })
                .collect();
            (mt, per_req)
        };
        let (s1, r1) = run(42);
        let (s2, r2) = run(42);
        assert_eq!(s1, s2, "same seed -> bitwise-identical model summary");
        assert_eq!(r1, r2, "same seed -> bitwise-identical per-request model times");
        let (s3, _) = run(43);
        assert_ne!(s1, s3, "a different seed shifts the arrival process");
        // Seed 0 is a valid seed like any other (the scramble keeps the
        // PRNG off its absorbing state) and serves deterministically.
        let (z1, _) = run(0);
        let (z2, _) = run(0);
        assert_eq!(z1, z2);
        assert_ne!(s1, z1, "0 and 42 are distinct arrival streams");
    }
}
