//! Serving front-end: request router + scheduler + engine + SLO metrics.
//!
//! [`Server`] is the synchronous core (the engine's collectives block);
//! async intake wraps it via a channel in `main.rs`/examples. Requests flow
//! FCFS through KV admission, execute on the engine one at a time (the
//! paper's single-request methodology), and produce [`RequestMetrics`].

pub mod metrics;
pub mod scheduler;

pub use metrics::{percentile, RequestMetrics, ServeSummary};
pub use scheduler::{Request, Scheduler, SchedulerConfig};

use std::time::Instant;

use crate::engine::Engine;
use crate::Result;

/// The serving loop: scheduler in front of an engine.
pub struct Server {
    engine: Engine,
    scheduler: Scheduler,
    completed: Vec<RequestMetrics>,
}

impl Server {
    pub fn new(engine: Engine, cfg: SchedulerConfig) -> Self {
        Self { engine, scheduler: Scheduler::new(cfg), completed: Vec::new() }
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Run the engine's warmup request (excluded from traces) so the first
    /// served request's TTFT is not inflated by lazy one-time setup.
    pub fn warmup(&mut self) -> Result<()> {
        self.engine.warmup()
    }

    /// Enqueue a request.
    pub fn submit(&mut self, request: Request) -> Result<()> {
        self.scheduler.submit(request)
    }

    /// Drain the queue, serving every admissible request; returns metrics
    /// in completion order.
    pub fn run_to_completion(&mut self) -> Result<&[RequestMetrics]> {
        let first = self.completed.len();
        loop {
            let Some(admitted) = self.scheduler.admit_next()? else {
                if self.scheduler.queue_len() > 0 {
                    anyhow::bail!("head-of-line request cannot fit the KV pool");
                }
                break;
            };
            let queue_s = admitted.enqueued_at.elapsed().as_secs_f64();
            let req = admitted.request;
            let start = Instant::now();
            let result = self.engine.generate(&req.prompt, req.decode_len)?;
            let e2e_s = start.elapsed().as_secs_f64() + queue_s;
            self.scheduler.complete(req.id)?;
            self.completed.push(RequestMetrics {
                request_id: req.id,
                prompt_tokens: req.prompt.len(),
                generated_tokens: result.tokens.len(),
                queue_s,
                ttft_s: result.ttft.as_secs_f64(),
                tpot_s: result.tpot.as_secs_f64(),
                e2e_s,
            });
        }
        Ok(&self.completed[first..])
    }

    /// Serve a batch and summarize (the end-to-end example's entry point).
    pub fn serve_batch(&mut self, requests: Vec<Request>) -> Result<ServeSummary> {
        let wall_start = Instant::now();
        for r in requests {
            self.submit(r)?;
        }
        let served = self.run_to_completion()?.to_vec();
        Ok(ServeSummary::from_metrics(&served, wall_start.elapsed()))
    }

    pub fn completed(&self) -> &[RequestMetrics] {
        &self.completed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::ParallelLayout;
    use crate::engine::{EngineConfig, EngineMode};
    use crate::model::ModelArch;

    fn tiny_server(tp: usize, pp: usize) -> Server {
        let cfg = EngineConfig {
            arch: ModelArch::tiny(),
            layout: ParallelLayout::new(tp, pp),
            mode: EngineMode::Structural,
            trace_dtype_bytes: 2,
        };
        Server::new(
            Engine::new(cfg).unwrap(),
            SchedulerConfig { kv_blocks: 64, kv_block_size: 16, max_queue: 64 },
        )
    }

    #[test]
    fn serves_batch_fcfs_and_releases_kv() {
        let mut srv = tiny_server(2, 2);
        let reqs: Vec<Request> = (0..4)
            .map(|i| Request { id: i, prompt: vec![0; 16], decode_len: 8 })
            .collect();
        let summary = srv.serve_batch(reqs).unwrap();
        assert_eq!(summary.requests, 4);
        assert_eq!(summary.total_tokens, 32);
        assert!(summary.tokens_per_s > 0.0);
        assert_eq!(srv.completed().len(), 4);
        // completion order is submission order (FCFS, single-engine)
        let ids: Vec<u64> = srv.completed().iter().map(|m| m.request_id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn later_requests_wait_in_queue() {
        let mut srv = tiny_server(1, 2);
        let reqs: Vec<Request> = (0..3)
            .map(|i| Request { id: i, prompt: vec![0; 8], decode_len: 4 })
            .collect();
        srv.serve_batch(reqs).unwrap();
        let m = srv.completed();
        assert!(m[2].queue_s >= m[0].queue_s, "FCFS queueing accumulates");
    }
}
