//! Per-replica prefix-cache model — vLLM-style automatic prefix caching
//! on the model clock.
//!
//! Production prompts share long prefixes (system prompts, multi-turn
//! chat history, few-shot templates), and a replica that already holds a
//! prefix's KV cache skips that prefix's prefill — compute *and* its TP
//! AllReduce volume. This module models that cache so the serving loop
//! can price prefill only for the uncached suffix and the fleet router
//! can steer same-prefix requests back to the replica that is warm for
//! them ([`crate::fleet::RouterPolicy::CacheAffinity`]).
//!
//! The model follows vLLM's hash-chain design at token-*block*
//! granularity: block `i` of a prompt is identified by
//! `hash(parent_chain_hash, tokens[i*B .. (i+1)*B])`, so a lookup walks
//! the prompt's chain from the root and a hit is always a *leading*
//! block-aligned span — two prompts share cache entries exactly as far
//! as their token content agrees. Only full blocks are cached (a partial
//! tail block is never hit-able), and an admission never treats the
//! whole prompt as cached: at least one token is always prefilled, like
//! vLLM, so every request still produces its first token through the
//! engine.
//!
//! Residency is bounded by a byte budget (`capacity_bytes`, charged at
//! `kv_bytes_per_token` per token) with LRU eviction on the replica's
//! *model* clock. Eviction order is deepest-least-recent first: when a
//! prompt is observed, its blocks are touched leaf→root so the root —
//! the part shared by the most requests — is always the youngest and
//! dies last. Everything is deterministic: hashes come from the
//! splitmix64 chain, LRU order is a strictly monotone touch counter, and
//! no operation ever iterates a `HashMap`, so two runs with the same
//! inputs produce bitwise-identical hit traces.

use std::collections::{BTreeMap, HashMap};

use crate::workload::splitmix64;

/// Configuration of one replica's prefix cache.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefixCacheConfig {
    /// Tokens per cached block (the hash granularity; vLLM default 16).
    pub block_tokens: usize,
    /// Byte budget for resident prefix KV on this replica.
    pub capacity_bytes: usize,
}

impl Default for PrefixCacheConfig {
    fn default() -> Self {
        Self { block_tokens: 16, capacity_bytes: 64 << 20 }
    }
}

impl PrefixCacheConfig {
    pub fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(self.block_tokens >= 1, "prefix-cache block must hold >= 1 token");
        anyhow::ensure!(self.capacity_bytes >= 1, "prefix-cache capacity must be >= 1 byte");
        Ok(())
    }
}

/// Lifetime counters of one cache (all token counts are prompt tokens).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PrefixCacheStats {
    /// Prompts observed (admissions).
    pub observed: u64,
    /// Total cached-prefix tokens served across observations.
    pub hit_tokens: u64,
    /// Blocks inserted.
    pub inserted_blocks: u64,
    /// Blocks evicted by the capacity budget.
    pub evicted_blocks: u64,
}

/// One resident block: its LRU touch tick and the model time it was last
/// used (the tick orders eviction; the time is reporting).
#[derive(Debug, Clone, Copy)]
struct Block {
    tick: u64,
    last_used_s: f64,
}

/// Deterministic block-granular prefix cache with a byte budget and
/// model-time LRU eviction.
#[derive(Debug, Clone)]
pub struct PrefixCache {
    cfg: PrefixCacheConfig,
    kv_bytes_per_token: usize,
    /// chain-hash → resident block.
    blocks: HashMap<u64, Block>,
    /// LRU index: touch tick → chain-hash (ticks are unique).
    lru: BTreeMap<u64, u64>,
    tick: u64,
    stats: PrefixCacheStats,
}

/// Chain hash of one block given its parent's chain hash (splitmix64
/// sponge over the block's tokens; the root parent is a fixed tag).
fn chain_hash(parent: u64, tokens: &[i32]) -> u64 {
    let mut h = splitmix64(parent ^ 0x9E3A_11CE_5EED_B10C);
    for &t in tokens {
        h = splitmix64(h ^ (t as u32 as u64));
    }
    h
}

/// Chain hashes of the prompt's *full* `block_tokens`-sized blocks, root
/// first. Free-standing so a router can hash a prompt once and probe
/// many replicas' caches with [`PrefixCache::lookup_chain`].
pub fn chain_hashes(block_tokens: usize, prompt: &[i32]) -> Vec<u64> {
    assert!(block_tokens >= 1);
    let mut parent = 0u64;
    prompt
        .chunks_exact(block_tokens)
        .map(|chunk| {
            parent = chain_hash(parent, chunk);
            parent
        })
        .collect()
}

impl PrefixCache {
    /// `kv_bytes_per_token` is the replica's KV footprint per cached
    /// token ([`crate::model::ModelArch::kv_bytes_per_token`]).
    pub fn new(cfg: PrefixCacheConfig, kv_bytes_per_token: usize) -> Self {
        assert!(cfg.block_tokens >= 1, "prefix-cache block must hold >= 1 token");
        assert!(kv_bytes_per_token >= 1, "kv_bytes_per_token must be >= 1");
        Self {
            cfg,
            kv_bytes_per_token,
            blocks: HashMap::new(),
            lru: BTreeMap::new(),
            tick: 0,
            stats: PrefixCacheStats::default(),
        }
    }

    pub fn config(&self) -> PrefixCacheConfig {
        self.cfg
    }

    pub fn stats(&self) -> PrefixCacheStats {
        self.stats
    }

    /// Bytes one resident block accounts for.
    fn block_bytes(&self) -> usize {
        self.cfg.block_tokens * self.kv_bytes_per_token
    }

    /// Bytes currently resident. Never exceeds the capacity budget after
    /// an observation returns.
    pub fn resident_bytes(&self) -> usize {
        self.blocks.len() * self.block_bytes()
    }

    pub fn resident_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Chain hashes of the prompt's *full* blocks, root first.
    fn chain(&self, prompt: &[i32]) -> Vec<u64> {
        chain_hashes(self.cfg.block_tokens, prompt)
    }

    /// Cached-prefix length of `prompt` in tokens, without touching the
    /// cache (the router's estimate). Always a multiple of the block
    /// size and ≤ the prompt length; the *admission* clamp (never the
    /// whole prompt) is the caller's, because only the caller knows it
    /// is about to prefill.
    pub fn lookup(&self, prompt: &[i32]) -> usize {
        self.lookup_chain(&self.chain(prompt))
    }

    /// [`Self::lookup`] over a precomputed [`chain_hashes`] chain (must
    /// have been built with this cache's block size).
    pub fn lookup_chain(&self, chain: &[u64]) -> usize {
        let mut hit = 0usize;
        for h in chain {
            if !self.blocks.contains_key(h) {
                break;
            }
            hit += self.cfg.block_tokens;
        }
        hit
    }

    /// Observe an admitted prompt at model time `now_s`: returns the
    /// cached-prefix token count (as [`Self::lookup`] would have),
    /// touches the hit blocks, inserts the missing full blocks, and
    /// evicts least-recently-used blocks until the byte budget holds.
    ///
    /// Blocks are ticked leaf→root so within one prompt the root is the
    /// youngest — eviction removes deep, request-specific blocks before
    /// the shared prefix roots.
    pub fn observe(&mut self, prompt: &[i32], now_s: f64) -> usize {
        let chain = self.chain(prompt);
        self.observe_chain(&chain, now_s)
    }

    /// [`Self::observe`] over a precomputed [`chain_hashes`] chain (must
    /// have been built with this cache's block size) — so an admission
    /// loop that probed with [`Self::lookup_chain`] never rehashes the
    /// prompt. Identical effect, tick for tick, to [`Self::observe`] on
    /// the prompt the chain was built from.
    pub fn observe_chain(&mut self, chain: &[u64], now_s: f64) -> usize {
        let mut hit_blocks = 0usize;
        for h in &chain {
            if !self.blocks.contains_key(h) {
                break;
            }
            hit_blocks += 1;
        }
        // Touch + insert leaf-first: the root ends with the largest tick.
        for &h in chain.iter().rev() {
            self.tick += 1;
            match self.blocks.get_mut(&h) {
                Some(block) => {
                    self.lru.remove(&block.tick);
                    block.tick = self.tick;
                    block.last_used_s = now_s;
                }
                None => {
                    self.blocks.insert(h, Block { tick: self.tick, last_used_s: now_s });
                    self.stats.inserted_blocks += 1;
                }
            }
            self.lru.insert(self.tick, h);
        }
        // Enforce the byte budget (LRU; ticks are unique so the order is
        // total and deterministic).
        let block_bytes = self.block_bytes();
        while self.blocks.len() * block_bytes > self.cfg.capacity_bytes {
            let (_, h) = self.lru.pop_first().expect("resident blocks are LRU-indexed");
            self.blocks.remove(&h);
            self.stats.evicted_blocks += 1;
        }
        let hit = hit_blocks * self.cfg.block_tokens;
        self.stats.observed += 1;
        self.stats.hit_tokens += hit as u64;
        hit
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn prompt(group: u64, shared: usize, id: u64, unique: usize) -> Vec<i32> {
        let mut p: Vec<i32> =
            (0..shared).map(|i| (splitmix64(group ^ (i as u64) << 17) & 0xFFFF) as i32).collect();
        p.extend(
            (0..unique).map(|i| (splitmix64(!id ^ (i as u64) << 23) & 0xFFFF) as i32 + 0x1_0000),
        );
        p
    }

    #[test]
    fn hits_are_leading_block_aligned_spans() {
        let mut c = PrefixCache::new(
            PrefixCacheConfig { block_tokens: 4, capacity_bytes: 1 << 20 },
            16,
        );
        let a = prompt(1, 16, 100, 6);
        assert_eq!(c.lookup(&a), 0, "cold cache");
        assert_eq!(c.observe(&a, 0.0), 0);
        // 22 tokens = 5 full blocks of 4 (the 2-token tail is not cached).
        assert_eq!(c.resident_blocks(), 5);
        // The same prompt now hits every full block.
        assert_eq!(c.lookup(&a), 20);
        // A same-group prompt with a different tail hits the shared 16
        // tokens (4 blocks) and stops at the first diverging block.
        let b = prompt(1, 16, 101, 6);
        assert_eq!(c.lookup(&b), 16);
        // A different group shares nothing.
        let d = prompt(2, 16, 102, 6);
        assert_eq!(c.lookup(&d), 0);
        // Hits never exceed the prompt and are block multiples.
        let short = &a[..10];
        assert_eq!(c.lookup(short), 8);
    }

    #[test]
    fn capacity_budget_evicts_lru_and_keeps_roots() {
        // 16 B/token, 4-token blocks = 64 B/block; budget = 4 blocks.
        let mut c = PrefixCache::new(
            PrefixCacheConfig { block_tokens: 4, capacity_bytes: 256 },
            16,
        );
        let a = prompt(1, 8, 1, 0); // 2 blocks
        let b = prompt(2, 8, 2, 0); // 2 blocks
        c.observe(&a, 0.0);
        c.observe(&b, 1.0);
        assert_eq!(c.resident_blocks(), 4);
        assert!(c.resident_bytes() <= 256);
        // A third 2-block prompt evicts prompt `a` (least recent),
        // deepest block first.
        let d = prompt(3, 8, 3, 0);
        c.observe(&d, 2.0);
        assert_eq!(c.resident_blocks(), 4);
        assert!(c.resident_bytes() <= 256);
        assert_eq!(c.lookup(&a), 0, "oldest chain evicted");
        assert_eq!(c.lookup(&b), 8, "recent chain survives");
        assert_eq!(c.lookup(&d), 8);
        assert_eq!(c.stats().evicted_blocks, 2);
        // Re-touching `b` keeps it alive through the next insertion.
        c.observe(&b, 3.0);
        c.observe(&prompt(4, 8, 4, 0), 4.0);
        assert_eq!(c.lookup(&b), 8);
        assert_eq!(c.lookup(&d), 0, "LRU chain rotated out");
    }

    #[test]
    fn within_one_chain_eviction_is_leaf_first() {
        // Budget of 3 blocks, one 4-block prompt: after observation the
        // *leaf* (deepest) block is gone and the root 3 survive, so the
        // shared head of the prefix stays hit-able.
        let mut c = PrefixCache::new(
            PrefixCacheConfig { block_tokens: 4, capacity_bytes: 192 },
            16,
        );
        let a = prompt(7, 16, 1, 0);
        c.observe(&a, 0.0);
        assert_eq!(c.resident_blocks(), 3);
        assert_eq!(c.lookup(&a), 12, "root-side blocks survive the budget");
    }

    #[test]
    fn observation_is_deterministic() {
        let run = || {
            let mut c = PrefixCache::new(
                PrefixCacheConfig { block_tokens: 4, capacity_bytes: 512 },
                16,
            );
            let mut trace = Vec::new();
            for i in 0..40u64 {
                let p = prompt(i % 3, 12, i, (i % 5) as usize);
                trace.push(c.observe(&p, i as f64));
            }
            (trace, c.stats())
        };
        let (t1, s1) = run();
        let (t2, s2) = run();
        assert_eq!(t1, t2, "hit traces are bitwise-identical");
        assert_eq!(s1, s2);
        assert!(s1.hit_tokens > 0, "repeating groups produce hits");
    }

    #[test]
    fn observe_chain_matches_observe() {
        let mk = || {
            PrefixCache::new(PrefixCacheConfig { block_tokens: 4, capacity_bytes: 512 }, 16)
        };
        let (mut a, mut b) = (mk(), mk());
        for i in 0..40u64 {
            let p = prompt(i % 3, 12, i, (i % 5) as usize);
            let chain = chain_hashes(4, &p);
            assert_eq!(a.observe(&p, i as f64), b.observe_chain(&chain, i as f64));
        }
        assert_eq!(a.stats(), b.stats());
        assert_eq!(a.resident_blocks(), b.resident_blocks());
    }

    #[test]
    fn degenerate_budgets_cache_nothing_but_stay_sane() {
        // Budget below one block: every observation inserts then evicts
        // straight back to empty — lookups never hit, bytes never exceed
        // the budget.
        let mut c = PrefixCache::new(
            PrefixCacheConfig { block_tokens: 8, capacity_bytes: 1 },
            16,
        );
        let p = prompt(1, 16, 1, 0);
        assert_eq!(c.observe(&p, 0.0), 0);
        assert_eq!(c.observe(&p, 1.0), 0, "nothing ever sticks");
        assert_eq!(c.resident_bytes(), 0);
        // A prompt shorter than one block has no cacheable span.
        let mut c = PrefixCache::new(PrefixCacheConfig::default(), 16);
        assert_eq!(c.observe(&p[..7], 0.0), 0);
        assert_eq!(c.resident_blocks(), 0);
        assert!(PrefixCacheConfig { block_tokens: 0, capacity_bytes: 1 }
            .validate()
            .is_err());
        assert!(PrefixCacheConfig { block_tokens: 1, capacity_bytes: 0 }
            .validate()
            .is_err());
    }
}
