//! Workload generation — seeded arrival processes × request-length
//! distributions.
//!
//! Serving experiments are only comparable when the request stream is a
//! pure function of a seed, so this module owns the one PRNG every
//! arrival/length draw in the crate goes through ([`Rng64`]: a
//! splitmix64-scrambled xorshift64* — the exact generator
//! `server::serve_poisson` has always used, moved here so the fleet
//! simulator and the single-replica serving path replay *bitwise
//! identical* arrival streams for a given seed).
//!
//! Three axes compose into a [`WorkloadSpec`]:
//!
//! - [`ArrivalProcess`] — open-loop request arrivals: memoryless
//!   [`ArrivalProcess::Poisson`] (the classic serving assumption) or
//!   [`ArrivalProcess::Bursty`] (arrivals land in bursts of `burst`
//!   back-to-back requests — the pattern an upstream batching gateway or
//!   a retry storm produces — at the same long-run rate).
//! - [`LengthDist`] ×2 — prompt and decode lengths per request: `Fixed`
//!   (the paper's Sp/Sd methodology), `Uniform`, or the long-tail
//!   ShareGPT-like `LongTail` mixture (mostly short chat turns, a heavy
//!   minority of long documents) that stresses continuous batching and
//!   KV admission.
//! - [`PrefixProfile`] (optional) — shared-prefix structure: a global
//!   system prompt, multi-turn conversations, or few-shot templates.
//!   Each generated request carries its prefix-group id and shared/unique
//!   token split, and its prompt *tokens* realize that structure (same
//!   group → identical leading tokens), so a content-addressed prefix
//!   cache ([`crate::server::PrefixCache`]) sees exactly the sharing the
//!   profile describes. Without a profile every prompt is unique-tokened
//!   — zero accidental sharing.
//! - request count.
//!
//! Arrival times, lengths, and prefix-group assignments draw from three
//! *independent* seeded streams, so switching a length distribution (or
//! adding a prefix profile) never perturbs the arrival process (and vice
//! versa) — A/B comparisons stay paired.

use crate::server::Request;

// Every derived PRNG stream in the crate is `Rng64::new(seed ^ SALT)`
// for a documented salt below (the arrival stream is the raw seed —
// salt 0 — for bitwise compatibility with `server::serve_poisson`).
// Distinct salts land on unrelated splitmix64 states, so the streams
// are pairwise independent under one shared seed: toggling any axis
// (lengths, prefixes, faults, autoscaling) never perturbs another, and
// A/B comparisons stay paired. `streams_are_pairwise_independent`
// below guards the invariant.

/// Seed salt of the request-length stream: prompt/decode length draws
/// in [`WorkloadSpec::generate`] run on `Rng64::new(seed ^
/// LENGTH_STREAM_SALT)`, independent of the arrival stream — swapping a
/// length distribution moves no arrival.
pub const LENGTH_STREAM_SALT: u64 = 0x5EED_FACE_CAFE_F00D;

/// Seed salt of the prefix-group stream: [`PrefixProfile`] group
/// assignments run on `Rng64::new(seed ^ PREFIX_STREAM_SALT)` — adding
/// a prefix profile moves no arrival and resizes no prompt.
pub const PREFIX_STREAM_SALT: u64 = 0x00DE_FACE_0F_C0FFEE;

/// Seed salt of the fault-injection stream ([`crate::faults`]): churn
/// failure/repair draws run on `Rng64::new(seed ^ FAULT_STREAM_SALT ^
/// mix(replica))`, a fourth independent stream next to the arrival,
/// length, and prefix-group streams — so enabling faults never
/// perturbs when requests arrive, how long they are, or which prefix
/// group they join (fault A/B comparisons stay paired).
pub const FAULT_STREAM_SALT: u64 = 0xFA17_FA17_DEAD_BEEF;

/// Seed salt of the autoscale-controller stream ([`crate::autoscale`]):
/// scale-check tick jitter runs on `Rng64::new(seed ^
/// AUTOSCALE_STREAM_SALT)`, a fifth independent stream — so attaching
/// an autoscale policy never perturbs arrivals, lengths, prefix
/// groups, or fault draws (elastic-vs-static comparisons stay paired).
pub const AUTOSCALE_STREAM_SALT: u64 = 0xE1A5_71C5_CA1E_D0D5;

/// SplitMix64 — the one-shot seed scramble (a bijection, so distinct
/// seeds stay distinct and every seed lands on a well-mixed state).
pub fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// The crate's deterministic workload PRNG: xorshift64* seeded through
/// [`splitmix64`]. The single seed whose scrambled state would be
/// xorshift's absorbing 0 is nudged, so seed 0 is as valid as any other.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Rng64 {
    state: u64,
}

impl Rng64 {
    pub fn new(seed: u64) -> Self {
        let mut state = splitmix64(seed);
        if state == 0 {
            state = 0x9E37_79B9_7F4A_7C15;
        }
        Self { state }
    }

    /// Next 64 uniform bits.
    pub fn next_u64(&mut self) -> u64 {
        self.state ^= self.state >> 12;
        self.state ^= self.state << 25;
        self.state ^= self.state >> 27;
        self.state.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform `f64` in `[0, 1)` (53 mantissa bits).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }
}

/// Open-loop request arrival process.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// Memoryless arrivals: exponential inter-arrival gaps at
    /// `rate_per_s` requests/second.
    Poisson { rate_per_s: f64 },
    /// Bursty arrivals: requests land in back-to-back groups of `burst`
    /// (all at the same instant), with exponential gaps between groups
    /// sized so the *long-run* rate is still `rate_per_s`. `burst = 1`
    /// degenerates to Poisson.
    Bursty { rate_per_s: f64, burst: usize },
}

impl ArrivalProcess {
    pub fn poisson(rate_per_s: f64) -> Self {
        Self::Poisson { rate_per_s }
    }

    pub fn bursty(rate_per_s: f64, burst: usize) -> Self {
        Self::Bursty { rate_per_s, burst }
    }

    /// Long-run request rate (req/s).
    pub fn rate_per_s(&self) -> f64 {
        match *self {
            Self::Poisson { rate_per_s } | Self::Bursty { rate_per_s, .. } => rate_per_s,
        }
    }

    pub fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(
            self.rate_per_s() > 0.0,
            "arrival rate must be positive (req/s)"
        );
        if let Self::Bursty { burst, .. } = self {
            anyhow::ensure!(*burst >= 1, "burst size must be >= 1");
        }
        Ok(())
    }

    /// Arrival offsets (seconds from the stream's epoch) of `n` requests,
    /// deterministic per `seed`. The Poisson stream is bit-for-bit the
    /// sequence `server::serve_poisson` replays for the same seed.
    pub fn offsets(&self, n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng64::new(seed);
        let mut at = 0.0f64;
        match *self {
            Self::Poisson { rate_per_s } => (0..n)
                .map(|_| {
                    let u = rng.next_f64();
                    at += -(1.0 - u).ln() / rate_per_s;
                    at
                })
                .collect(),
            Self::Bursty { rate_per_s, burst } => {
                // A silent `.max(1)` here used to paper over burst = 0;
                // degenerate bursts must be rejected by `validate()` (and
                // loudly here), never quietly reshaped.
                assert!(burst >= 1, "burst size must be >= 1 (validate() rejects 0)");
                // Gaps between bursts keep the long-run request rate.
                let burst_rate = rate_per_s / burst as f64;
                (0..n)
                    .map(|i| {
                        if i % burst == 0 {
                            let u = rng.next_f64();
                            at += -(1.0 - u).ln() / burst_rate;
                        }
                        at
                    })
                    .collect()
            }
        }
    }
}

/// Per-request length distribution (prompt or decode tokens).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LengthDist {
    /// Every request has exactly this length (the paper's methodology).
    Fixed(usize),
    /// Uniform over `lo..=hi`.
    Uniform { lo: usize, hi: usize },
    /// ShareGPT-like long-tail mixture: `short` tokens with probability
    /// `1 - long_weight`, `long` tokens with probability `long_weight`.
    LongTail { short: usize, long: usize, long_weight: f64 },
}

impl LengthDist {
    pub fn validate(&self) -> crate::Result<()> {
        match *self {
            Self::Fixed(n) => anyhow::ensure!(n >= 1, "fixed length must be >= 1"),
            Self::Uniform { lo, hi } => {
                anyhow::ensure!(lo >= 1 && lo <= hi, "uniform needs 1 <= lo <= hi");
            }
            Self::LongTail { short, long, long_weight } => {
                anyhow::ensure!(short >= 1 && long >= short, "long tail needs long >= short >= 1");
                anyhow::ensure!(
                    (0.0..=1.0).contains(&long_weight),
                    "long_weight must be in [0, 1]"
                );
            }
        }
        Ok(())
    }

    /// Draw one length. `Fixed` consumes no randomness, so swapping it in
    /// never perturbs the other distribution's stream.
    pub fn sample(&self, rng: &mut Rng64) -> usize {
        match *self {
            Self::Fixed(n) => n,
            Self::Uniform { lo, hi } => {
                let span = (hi - lo + 1) as u64;
                lo + (rng.next_u64() % span) as usize
            }
            Self::LongTail { short, long, long_weight } => {
                if rng.next_f64() < long_weight {
                    long
                } else {
                    short
                }
            }
        }
    }

    /// Largest length the distribution can produce (KV sizing).
    pub fn max_len(&self) -> usize {
        match *self {
            Self::Fixed(n) => n,
            Self::Uniform { hi, .. } => hi,
            Self::LongTail { long, .. } => long,
        }
    }

    /// Smallest length the distribution can produce (shared-prefix
    /// feasibility: a prompt must always be longer than its prefix).
    pub fn min_len(&self) -> usize {
        match *self {
            Self::Fixed(n) => n,
            Self::Uniform { lo, .. } => lo,
            Self::LongTail { short, .. } => short,
        }
    }
}

/// Shared-prefix structure of a workload — which requests share a
/// leading span of prompt tokens, and how long that span is.
///
/// A request's prefix group determines its leading `shared` tokens
/// (a pure function of the group id); the rest of the prompt is unique
/// to the request. Group assignment draws from its own seeded stream,
/// independent of arrivals and lengths.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PrefixProfile {
    /// One global system prompt: every request shares the same leading
    /// `shared` tokens (group 0).
    SystemPrompt { shared: usize },
    /// Multi-turn chat: each request belongs to one of `conversations`
    /// long-lived conversations (uniform assignment) and shares that
    /// conversation's `shared`-token history.
    MultiTurn { conversations: usize, shared: usize },
    /// Few-shot templates: with probability `zero_shot_weight` a request
    /// carries no template (prefix-free); otherwise it uses one of
    /// `templates` shared `shared`-token templates (uniform).
    FewShot { templates: usize, shared: usize, zero_shot_weight: f64 },
}

impl PrefixProfile {
    /// Shared-prefix length of a grouped request, in tokens.
    pub fn shared_tokens(&self) -> usize {
        match *self {
            Self::SystemPrompt { shared }
            | Self::MultiTurn { shared, .. }
            | Self::FewShot { shared, .. } => shared,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Self::SystemPrompt { .. } => "system-prompt",
            Self::MultiTurn { .. } => "multi-turn",
            Self::FewShot { .. } => "few-shot",
        }
    }

    pub fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(self.shared_tokens() >= 1, "shared prefix must be >= 1 token");
        match *self {
            Self::SystemPrompt { .. } => {}
            Self::MultiTurn { conversations, .. } => {
                anyhow::ensure!(conversations >= 1, "multi-turn needs >= 1 conversation");
            }
            Self::FewShot { templates, zero_shot_weight, .. } => {
                anyhow::ensure!(templates >= 1, "few-shot needs >= 1 template");
                anyhow::ensure!(
                    (0.0..=1.0).contains(&zero_shot_weight),
                    "zero_shot_weight must be in [0, 1]"
                );
            }
        }
        Ok(())
    }

    /// Draw one request's prefix group. `None` means prefix-free (only
    /// `FewShot` produces it). Consumes randomness from the profile's
    /// own stream.
    fn assign(&self, rng: &mut Rng64) -> Option<u64> {
        match *self {
            Self::SystemPrompt { .. } => Some(0),
            Self::MultiTurn { conversations, .. } => {
                Some(rng.next_u64() % conversations as u64)
            }
            Self::FewShot { templates, zero_shot_weight, .. } => {
                if rng.next_f64() < zero_shot_weight {
                    None
                } else {
                    Some(rng.next_u64() % templates as u64)
                }
            }
        }
    }
}

/// Deterministic prompt-token synthesis. Shared tokens are a pure
/// function of (group, position) — so every member of a group carries
/// bitwise-identical leading tokens — and unique tokens are a pure
/// function of (request id, position), so no two requests ever share
/// content past their group prefix (nor any content at all when
/// prefix-free).
fn shared_token(group: u64, pos: usize) -> i32 {
    (splitmix64(group.wrapping_mul(0x9E37_79B9).wrapping_add(pos as u64)) & 0x7FFF_FFFF) as i32
}

fn unique_token(id: u64, pos: usize) -> i32 {
    (splitmix64(!id.wrapping_mul(0xC2B2_AE35).wrapping_add(pos as u64)) & 0x7FFF_FFFF) as i32
}

/// One generated request with its model-time arrival offset and its
/// shared-prefix identity.
#[derive(Debug, Clone)]
pub struct TimedRequest {
    /// Seconds from the workload epoch at which the request arrives.
    pub at_s: f64,
    /// Prefix group this request belongs to (`None` when prefix-free).
    /// Every member of a group shares the same leading
    /// [`Self::shared_tokens`] prompt tokens, bit for bit.
    pub prefix_group: Option<u64>,
    /// Length of the shared leading span inside `request.prompt`
    /// (0 when prefix-free). The remainder of the prompt is unique to
    /// this request.
    pub shared_tokens: usize,
    pub request: Request,
}

/// A complete open-loop workload: arrival process × prompt/decode length
/// distributions × optional shared-prefix profile × request count.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorkloadSpec {
    pub arrivals: ArrivalProcess,
    pub prompt: LengthDist,
    pub decode: LengthDist,
    /// Shared-prefix structure; `None` generates unique-tokened prompts
    /// (zero sharing).
    pub prefix: Option<PrefixProfile>,
    pub requests: usize,
}

impl WorkloadSpec {
    pub fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(self.requests >= 1, "workload needs at least one request");
        self.arrivals.validate()?;
        self.prompt.validate()?;
        self.decode.validate()?;
        if let Some(profile) = &self.prefix {
            profile.validate()?;
            anyhow::ensure!(
                self.prompt.min_len() > profile.shared_tokens(),
                "every prompt must be longer than the {}-token shared prefix \
                 (shortest prompt: {})",
                profile.shared_tokens(),
                self.prompt.min_len()
            );
        }
        Ok(())
    }

    /// Generate the request stream: ids `0..requests` in arrival order,
    /// deterministic per `seed`. Arrival times come from the seed's
    /// arrival stream; lengths and prefix-group assignments from two
    /// further independent streams derived from the same seed, so no
    /// axis ever aliases another (changing the prefix profile moves no
    /// arrival and resizes no prompt).
    pub fn generate(&self, seed: u64) -> crate::Result<Vec<TimedRequest>> {
        self.validate()?;
        let offsets = self.arrivals.offsets(self.requests, seed);
        let mut lengths = Rng64::new(seed ^ LENGTH_STREAM_SALT);
        let mut groups = Rng64::new(seed ^ PREFIX_STREAM_SALT);
        Ok(offsets
            .into_iter()
            .enumerate()
            .map(|(i, at_s)| {
                let id = i as u64;
                let prompt_len = self.prompt.sample(&mut lengths);
                let decode_len = self.decode.sample(&mut lengths);
                let group = self.prefix.as_ref().and_then(|p| p.assign(&mut groups));
                let shared = match (&group, &self.prefix) {
                    (Some(_), Some(p)) => p.shared_tokens(),
                    _ => 0,
                };
                let mut prompt = Vec::with_capacity(prompt_len);
                if let Some(g) = group {
                    prompt.extend((0..shared).map(|pos| shared_token(g, pos)));
                }
                prompt.extend((shared..prompt_len).map(|pos| unique_token(id, pos)));
                TimedRequest {
                    at_s,
                    prefix_group: group,
                    shared_tokens: shared,
                    request: Request { id, prompt: prompt.into(), decode_len },
                }
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_seed_deterministic_and_seed_sensitive() {
        let mut a = Rng64::new(42);
        let mut b = Rng64::new(42);
        let mut c = Rng64::new(43);
        let sa: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let sb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let sc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(sa, sb);
        assert_ne!(sa, sc);
        // Seed 0 is valid (the scramble keeps xorshift off its absorbing
        // state) and uniform draws stay in [0, 1).
        let mut z = Rng64::new(0);
        for _ in 0..1000 {
            let u = z.next_f64();
            assert!((0.0..1.0).contains(&u));
        }
    }

    /// Every salted stream (arrival = salt 0, lengths, prefix groups,
    /// faults, autoscale jitter) must be pairwise independent under one
    /// shared seed: no two salts may collide, and no two streams may
    /// replay each other's draws — otherwise toggling one axis would
    /// silently perturb another and A/B comparisons would unpair.
    #[test]
    fn streams_are_pairwise_independent() {
        let salts: [(&str, u64); 5] = [
            ("arrival", 0),
            ("length", LENGTH_STREAM_SALT),
            ("prefix", PREFIX_STREAM_SALT),
            ("fault", FAULT_STREAM_SALT),
            ("autoscale", AUTOSCALE_STREAM_SALT),
        ];
        for seed in [0u64, 7, 0xDEAD_BEEF] {
            for (i, &(na, a)) in salts.iter().enumerate() {
                for &(nb, b) in &salts[i + 1..] {
                    assert_ne!(a, b, "salts {na}/{nb} collide");
                    let mut ra = Rng64::new(seed ^ a);
                    let mut rb = Rng64::new(seed ^ b);
                    let sa: Vec<u64> = (0..16).map(|_| ra.next_u64()).collect();
                    let sb: Vec<u64> = (0..16).map(|_| rb.next_u64()).collect();
                    assert_ne!(sa, sb, "streams {na}/{nb} alias under seed {seed}");
                    // No lagged replay either: stream b never starts
                    // somewhere inside stream a's first draws.
                    let mut long_a = Rng64::new(seed ^ a);
                    let la: Vec<u64> = (0..64).map(|_| long_a.next_u64()).collect();
                    assert!(
                        !la.windows(16).any(|w| w == sb.as_slice()),
                        "stream {nb} replays a window of {na} under seed {seed}"
                    );
                }
            }
        }
    }

    #[test]
    fn poisson_offsets_are_monotone_at_the_requested_rate() {
        let offsets = ArrivalProcess::poisson(100.0).offsets(2000, 7);
        assert!(offsets.windows(2).all(|w| w[1] > w[0]), "strictly increasing");
        // Mean inter-arrival gap ~ 1/rate (law of large numbers).
        let mean = offsets.last().unwrap() / 2000.0;
        assert!((mean - 0.01).abs() < 0.002, "mean gap {mean} vs 0.01");
    }

    #[test]
    fn bursty_offsets_group_and_keep_the_long_run_rate() {
        let offsets = ArrivalProcess::bursty(100.0, 4).offsets(2000, 7);
        // Within a burst, arrivals share one instant.
        for chunk in offsets.chunks(4) {
            assert!(chunk.iter().all(|&t| t == chunk[0]));
        }
        assert!(offsets.windows(2).all(|w| w[1] >= w[0]));
        let mean = offsets.last().unwrap() / 2000.0;
        assert!((mean - 0.01).abs() < 0.003, "long-run gap {mean} vs 0.01");
    }

    /// Regression: `burst = 1` must degenerate to plain Poisson *bitwise*
    /// — same PRNG draws, same gap per request — across seeds and rates,
    /// and `burst = 0` is rejected loudly instead of silently clamped.
    #[test]
    fn bursty_burst_one_reproduces_poisson_offsets_bitwise() {
        for (rate, seed, n) in [(50.0, 3u64, 64usize), (7.5, 0, 128), (2000.0, 0xC0FFEE, 17)] {
            let bursty = ArrivalProcess::bursty(rate, 1).offsets(n, seed);
            let poisson = ArrivalProcess::poisson(rate).offsets(n, seed);
            assert_eq!(bursty, poisson, "rate={rate} seed={seed}");
        }
        assert!(ArrivalProcess::bursty(10.0, 0).validate().is_err());
        let panics = std::panic::catch_unwind(|| {
            ArrivalProcess::bursty(10.0, 0).offsets(4, 1);
        });
        assert!(panics.is_err(), "burst=0 offsets must panic, not clamp");
    }

    #[test]
    fn length_dists_respect_their_support() {
        let mut rng = Rng64::new(9);
        let uni = LengthDist::Uniform { lo: 8, hi: 32 };
        let mut seen_lo = false;
        for _ in 0..2000 {
            let l = uni.sample(&mut rng);
            assert!((8..=32).contains(&l));
            seen_lo |= l < 12;
        }
        assert!(seen_lo, "uniform covers its low end");
        let lt = LengthDist::LongTail { short: 32, long: 2048, long_weight: 0.1 };
        let mut longs = 0usize;
        for _ in 0..2000 {
            let l = lt.sample(&mut rng);
            assert!(l == 32 || l == 2048);
            longs += usize::from(l == 2048);
        }
        let frac = longs as f64 / 2000.0;
        assert!((frac - 0.1).abs() < 0.04, "long fraction {frac} vs 0.1");
        assert_eq!(LengthDist::Fixed(16).sample(&mut rng), 16);
        assert_eq!(lt.max_len(), 2048);
        assert_eq!(uni.max_len(), 32);
    }

    #[test]
    fn workload_generation_is_deterministic_and_streams_are_independent() {
        let spec = WorkloadSpec {
            arrivals: ArrivalProcess::poisson(200.0),
            prompt: LengthDist::Uniform { lo: 8, hi: 64 },
            decode: LengthDist::LongTail { short: 8, long: 128, long_weight: 0.2 },
            prefix: None,
            requests: 32,
        };
        let a = spec.generate(11).unwrap();
        let b = spec.generate(11).unwrap();
        assert_eq!(a.len(), 32);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.at_s, y.at_s);
            assert_eq!(x.request.prompt.len(), y.request.prompt.len());
            assert_eq!(x.request.decode_len, y.request.decode_len);
        }
        assert_eq!(a[0].request.id, 0);
        assert_eq!(a[31].request.id, 31);
        // Swapping length distributions must not move a single arrival.
        let fixed = WorkloadSpec { prompt: LengthDist::Fixed(16), ..spec };
        let c = fixed.generate(11).unwrap();
        for (x, y) in a.iter().zip(c.iter()) {
            assert_eq!(x.at_s, y.at_s, "length dist must not perturb arrivals");
        }
        // And the arrival stream is the ArrivalProcess's own.
        let offsets = spec.arrivals.offsets(32, 11);
        for (x, &t) in a.iter().zip(offsets.iter()) {
            assert_eq!(x.at_s, t);
        }
        // Prefix-free prompts never share content: no two requests agree
        // on even their first token (so a content-addressed prefix cache
        // sees zero accidental sharing).
        for (i, x) in a.iter().enumerate() {
            assert_eq!(x.prefix_group, None);
            assert_eq!(x.shared_tokens, 0);
            for y in &a[i + 1..] {
                assert_ne!(x.request.prompt[0], y.request.prompt[0]);
            }
        }
    }

    #[test]
    fn prefix_profiles_share_group_tokens_without_perturbing_other_streams() {
        let base = WorkloadSpec {
            arrivals: ArrivalProcess::poisson(100.0),
            prompt: LengthDist::Fixed(48),
            decode: LengthDist::Fixed(4),
            prefix: None,
            requests: 40,
        };
        let multi = WorkloadSpec {
            prefix: Some(PrefixProfile::MultiTurn { conversations: 4, shared: 32 }),
            ..base
        };
        let a = base.generate(5).unwrap();
        let b = multi.generate(5).unwrap();
        // The prefix profile moves no arrival and resizes nothing.
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.at_s, y.at_s, "prefix profile must not perturb arrivals");
            assert_eq!(x.request.prompt.len(), y.request.prompt.len());
            assert_eq!(x.request.decode_len, y.request.decode_len);
        }
        // Same group -> identical shared span; different group -> split at
        // the first token; the unique tail differs even within a group.
        let mut seen_groups = std::collections::HashSet::new();
        for x in &b {
            let g = x.prefix_group.expect("multi-turn always assigns a conversation");
            assert!(g < 4);
            assert_eq!(x.shared_tokens, 32);
            seen_groups.insert(g);
        }
        assert!(seen_groups.len() > 1, "40 requests spread over conversations");
        for (i, x) in b.iter().enumerate() {
            for y in &b[i + 1..] {
                if x.prefix_group == y.prefix_group {
                    assert_eq!(x.request.prompt[..32], y.request.prompt[..32]);
                    assert_ne!(x.request.prompt[32..], y.request.prompt[32..]);
                } else {
                    assert_ne!(x.request.prompt[0], y.request.prompt[0]);
                }
            }
        }
        // System prompt: one global group.
        let sys = WorkloadSpec {
            prefix: Some(PrefixProfile::SystemPrompt { shared: 16 }),
            ..base
        };
        for x in sys.generate(5).unwrap() {
            assert_eq!(x.prefix_group, Some(0));
            assert_eq!(x.shared_tokens, 16);
        }
        // Few-shot: the zero-shot fraction is prefix-free.
        let fs = WorkloadSpec {
            prefix: Some(PrefixProfile::FewShot {
                templates: 3,
                shared: 16,
                zero_shot_weight: 0.4,
            }),
            requests: 200,
            ..base
        };
        let reqs = fs.generate(5).unwrap();
        let free = reqs.iter().filter(|r| r.prefix_group.is_none()).count();
        assert!((40..=120).contains(&free), "zero-shot fraction ~0.4 ({free}/200)");
        for r in &reqs {
            assert_eq!(r.shared_tokens, if r.prefix_group.is_some() { 16 } else { 0 });
        }
        // Determinism: same seed, same groups and tokens, bit for bit.
        let c = multi.generate(5).unwrap();
        for (x, y) in b.iter().zip(c.iter()) {
            assert_eq!(x.prefix_group, y.prefix_group);
            assert_eq!(x.request.prompt, y.request.prompt);
        }
    }

    #[test]
    fn validation_rejects_degenerate_specs() {
        assert!(ArrivalProcess::poisson(0.0).validate().is_err());
        assert!(ArrivalProcess::bursty(10.0, 0).validate().is_err());
        assert!(LengthDist::Fixed(0).validate().is_err());
        assert!(LengthDist::Uniform { lo: 4, hi: 2 }.validate().is_err());
        assert!(LengthDist::Uniform { lo: 0, hi: 2 }.validate().is_err());
        assert!(
            LengthDist::LongTail { short: 8, long: 4, long_weight: 0.1 }.validate().is_err()
        );
        assert!(
            LengthDist::LongTail { short: 8, long: 64, long_weight: 1.5 }.validate().is_err()
        );
        let bad = WorkloadSpec {
            arrivals: ArrivalProcess::poisson(10.0),
            prompt: LengthDist::Fixed(8),
            decode: LengthDist::Fixed(8),
            prefix: None,
            requests: 0,
        };
        assert!(bad.generate(0).is_err());
        // Prefix profiles: degenerate shapes are rejected...
        assert!(PrefixProfile::SystemPrompt { shared: 0 }.validate().is_err());
        assert!(PrefixProfile::MultiTurn { conversations: 0, shared: 8 }
            .validate()
            .is_err());
        assert!(PrefixProfile::FewShot { templates: 0, shared: 8, zero_shot_weight: 0.1 }
            .validate()
            .is_err());
        assert!(PrefixProfile::FewShot { templates: 2, shared: 8, zero_shot_weight: 1.5 }
            .validate()
            .is_err());
        // ...and a shared prefix must leave room for a unique suffix in
        // every possible prompt.
        let too_long = WorkloadSpec {
            prefix: Some(PrefixProfile::SystemPrompt { shared: 8 }),
            requests: 1,
            ..bad
        };
        assert!(too_long.generate(0).is_err(), "prefix == shortest prompt");
    }
}
