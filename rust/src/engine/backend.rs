//! Per-worker compute backends.
//!
//! [`ComputeBackend`] is the segment-level compute interface the worker
//! loop drives; collectives happen *between* calls, in the worker (exactly
//! where vLLM places NCCL ops). Two implementations:
//!
//! - [`PjrtBackend`] — numeric mode: executes the AOT segment executables
//!   (tiny model) on a thread-local PJRT CPU client, holding its rank's
//!   weight shard and KV cache as resident literals.
//! - [`StructuralBackend`] — structural mode: paper-scale architectures
//!   whose compute cannot run on CPU; produces zero tensors of the correct
//!   shapes so the *communication stream* (what the paper profiles) is
//!   identical while compute is a no-op.

use crate::model::ModelArch;
use crate::runtime::tensor::HostTensor;
use crate::runtime::{
    compile_hlo, execute_b_tuple, i32_to_device, to_device, ArtifactStore, Phase, ShardWeights,
};
use crate::Result;

/// Segment-level compute of one TP rank. `window` (= rows of `x`) selects
/// the prefill or decode variant.
pub trait ComputeBackend: Send {
    /// Vocab-parallel embedding partial: `tokens [S] -> [S, h]`.
    fn embed(&mut self, tokens: &[i32]) -> Result<HostTensor>;
    /// Attention block partial for `layer`: `[S, h] -> [S, h]`; updates the
    /// rank's KV cache at `pos`.
    fn attn(&mut self, layer: usize, x: &HostTensor, pos: usize) -> Result<HostTensor>;
    /// MLP block partial for `layer`: `[S, h] -> [S, h]`.
    fn mlp(&mut self, layer: usize, x: &HostTensor) -> Result<HostTensor>;
    /// Final-norm + LM-head slice on the last token: `[S, h] -> [1, v/t]`.
    fn logits(&mut self, x: &HostTensor) -> Result<HostTensor>;
    /// Batched decode attention: row `i` of `x` is an *independent*
    /// sequence whose KV cache advances at `positions[i]`. The default
    /// forwards a single-row batch to [`Self::attn`]; backends without
    /// multi-sequence KV state (the fixed-shape PJRT executables) reject
    /// larger batches — see [`Self::supports_batched_decode`].
    fn attn_batch(
        &mut self,
        layer: usize,
        x: &HostTensor,
        positions: &[usize],
    ) -> Result<HostTensor> {
        if positions.len() != 1 {
            anyhow::bail!(
                "backend does not support batched decode (batch={})",
                positions.len()
            );
        }
        self.attn(layer, x, positions[0])
    }
    /// Per-row logits for a batched decode step: `[B, h] -> [B, v/t]`
    /// (every row is some sequence's last token). Default forwards the
    /// single-row batch to [`Self::logits`].
    fn logits_batch(&mut self, x: &HostTensor) -> Result<HostTensor> {
        if x.rows() != 1 {
            anyhow::bail!("backend does not support batched decode (batch={})", x.rows());
        }
        self.logits(x)
    }
    /// Whether this backend can decode several sequences in one iteration.
    fn supports_batched_decode(&self) -> bool {
        false
    }
    /// Clear KV state between requests.
    fn reset(&mut self) -> Result<()>;
}

// ---------------------------------------------------------------------------
// Structural backend
// ---------------------------------------------------------------------------

/// Zero-compute backend for paper-scale architectures: correct shapes, no
/// FLOPs. The worker's collective sequence — the object of study — is
/// unchanged.
pub struct StructuralBackend {
    hidden: usize,
    vocab_slice: usize,
}

impl StructuralBackend {
    pub fn new(arch: &ModelArch, tp: usize) -> Self {
        assert!(arch.supports_tp(tp));
        Self { hidden: arch.hidden, vocab_slice: arch.vocab / tp }
    }
}

impl ComputeBackend for StructuralBackend {
    fn embed(&mut self, tokens: &[i32]) -> Result<HostTensor> {
        Ok(HostTensor::zeros(&[tokens.len(), self.hidden]))
    }

    fn attn(&mut self, _layer: usize, x: &HostTensor, _pos: usize) -> Result<HostTensor> {
        Ok(HostTensor::zeros(&x.shape))
    }

    fn mlp(&mut self, _layer: usize, x: &HostTensor) -> Result<HostTensor> {
        Ok(HostTensor::zeros(&x.shape))
    }

    fn logits(&mut self, _x: &HostTensor) -> Result<HostTensor> {
        Ok(HostTensor::zeros(&[1, self.vocab_slice]))
    }

    fn attn_batch(
        &mut self,
        _layer: usize,
        x: &HostTensor,
        positions: &[usize],
    ) -> Result<HostTensor> {
        debug_assert_eq!(x.rows(), positions.len());
        Ok(HostTensor::zeros(&x.shape))
    }

    fn logits_batch(&mut self, x: &HostTensor) -> Result<HostTensor> {
        Ok(HostTensor::zeros(&[x.rows(), self.vocab_slice]))
    }

    fn supports_batched_decode(&self) -> bool {
        true
    }

    fn reset(&mut self) -> Result<()> {
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// PJRT backend (numeric mode)
// ---------------------------------------------------------------------------

struct LayerBufs {
    attn_norm: xla::PjRtBuffer,
    wq: xla::PjRtBuffer,
    wk: xla::PjRtBuffer,
    wv: xla::PjRtBuffer,
    wo: xla::PjRtBuffer,
    mlp_norm: xla::PjRtBuffer,
    w_gate: xla::PjRtBuffer,
    w_up: xla::PjRtBuffer,
    w_down: xla::PjRtBuffer,
}

struct SegmentExes {
    embed: xla::PjRtLoadedExecutable,
    attn: xla::PjRtLoadedExecutable,
    mlp: xla::PjRtLoadedExecutable,
    logits: xla::PjRtLoadedExecutable,
}

/// Numeric backend over the tiny-model AOT artifacts. Not `Send` members
/// live behind thread-local construction (see `engine::worker`); the struct
/// itself is only ever used on its creating thread.
///
/// Weights live in device buffers uploaded once; executions use
/// `execute_b` — both for speed (no per-call weight re-upload) and because
/// the crate's literal-input `execute()` leaks its input device buffers
/// (~input bytes per call; see runtime::execute_tuple docs).
pub struct PjrtBackend {
    /// TP degree the executables were built for (asserted at load).
    pub tp: usize,
    prefill_len: usize,
    max_seq: usize,
    hidden: usize,
    heads_local: usize,
    head_dim: usize,
    layers: usize,
    client: xla::PjRtClient,
    prefill: SegmentExes,
    decode: SegmentExes,
    emb_weight: xla::PjRtBuffer,
    rank_offset: xla::PjRtBuffer,
    final_norm: xla::PjRtBuffer,
    lm_head: xla::PjRtBuffer,
    layer_bufs: Vec<LayerBufs>,
    /// Per-layer (K, V) caches `[T, a/t, d]`, replaced after every attn call.
    kv: Vec<(xla::PjRtBuffer, xla::PjRtBuffer)>,
}

// SAFETY: PjrtBackend is constructed and used on exactly one worker thread;
// the Send bound on ComputeBackend is satisfied because ownership moves to
// that thread before any PJRT object is created (see `new_on_thread`).
unsafe impl Send for PjrtBackend {}

impl PjrtBackend {
    /// Build on the current thread (creates the thread-local PJRT client).
    pub fn new_on_thread(store: &ArtifactStore, tp: usize, rank: usize) -> Result<Self> {
        if !store.supports_tp(tp) {
            anyhow::bail!("artifacts built without tp={tp} (have {:?})", store.meta.tp_degrees);
        }
        let meta = &store.meta;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu: {e}"))?;
        let compile_phase = |phase: Phase| -> Result<SegmentExes> {
            Ok(SegmentExes {
                embed: compile_hlo(&client, &store.hlo_path("embed", phase, tp))?,
                attn: compile_hlo(&client, &store.hlo_path("attn", phase, tp))?,
                mlp: compile_hlo(&client, &store.hlo_path("mlp", phase, tp))?,
                logits: compile_hlo(&client, &store.hlo_path("logits", phase, tp))?,
            })
        };
        let prefill = compile_phase(Phase::Prefill)?;
        let decode = compile_phase(Phase::Decode)?;

        let w = ShardWeights::load(store, tp, rank)?;
        let up = |name: &str| -> Result<xla::PjRtBuffer> { to_device(&client, w.get(name)?) };
        let mut layer_bufs = Vec::with_capacity(meta.layers);
        for l in 0..meta.layers {
            layer_bufs.push(LayerBufs {
                attn_norm: up(&format!("layer{l}.attn_norm"))?,
                wq: up(&format!("layer{l}.wq"))?,
                wk: up(&format!("layer{l}.wk"))?,
                wv: up(&format!("layer{l}.wv"))?,
                wo: up(&format!("layer{l}.wo"))?,
                mlp_norm: up(&format!("layer{l}.mlp_norm"))?,
                w_gate: up(&format!("layer{l}.w_gate"))?,
                w_up: up(&format!("layer{l}.w_up"))?,
                w_down: up(&format!("layer{l}.w_down"))?,
            });
        }

        let heads_local = meta.heads / tp;
        let emb_weight = up("embed")?;
        let final_norm = up("final_norm")?;
        let lm_head = up("lm_head")?;
        let rank_offset = i32_to_device(&client, &[(rank * meta.vocab / tp) as i32])?;
        let mut backend = Self {
            tp,
            prefill_len: meta.prefill_len,
            max_seq: meta.max_seq,
            hidden: meta.hidden,
            heads_local,
            head_dim: meta.head_dim,
            layers: meta.layers,
            client,
            prefill,
            decode,
            emb_weight,
            rank_offset,
            final_norm,
            lm_head,
            layer_bufs,
            kv: Vec::new(),
        };
        backend.reset()?;
        Ok(backend)
    }

    fn kv_shape(&self) -> [usize; 3] {
        [self.max_seq, self.heads_local, self.head_dim]
    }

    fn exes(&self, window: usize) -> Result<&SegmentExes> {
        if window == self.prefill_len {
            Ok(&self.prefill)
        } else if window == 1 {
            Ok(&self.decode)
        } else {
            anyhow::bail!(
                "window {window} has no executable (prefill_len={}, decode=1)",
                self.prefill_len
            )
        }
    }
}

impl ComputeBackend for PjrtBackend {
    fn embed(&mut self, tokens: &[i32]) -> Result<HostTensor> {
        let exe = &self.exes(tokens.len())?.embed;
        let toks = i32_to_device(&self.client, tokens)?;
        let out = execute_b_tuple(exe, &[&toks, &self.emb_weight, &self.rank_offset])?;
        HostTensor::from_literal(&out[0], &[tokens.len(), self.hidden])
    }

    fn attn(&mut self, layer: usize, x: &HostTensor, pos: usize) -> Result<HostTensor> {
        let window = x.rows();
        let exe = &self.exes(window)?.attn;
        let lw = &self.layer_bufs[layer];
        let (k, v) = &self.kv[layer];
        let x_buf = to_device(&self.client, x)?;
        let pos_buf = i32_to_device(&self.client, &[pos as i32])?;
        let inputs = [
            &x_buf, k, v, &pos_buf,
            &lw.attn_norm, &lw.wq, &lw.wk, &lw.wv, &lw.wo,
        ];
        let mut out = execute_b_tuple(exe, &inputs)?;
        let partial = HostTensor::from_literal(&out[0], &[window, self.hidden])?;
        // Tuple outputs come back as one literal; re-upload the updated
        // caches so the next step's execute_b can consume them.
        let v_new = out.pop().expect("v cache");
        let k_new = out.pop().expect("k cache");
        let kv_shape = self.kv_shape();
        let k_host = HostTensor::from_literal(&k_new, &kv_shape)?;
        let v_host = HostTensor::from_literal(&v_new, &kv_shape)?;
        self.kv[layer] = (
            to_device(&self.client, &k_host)?,
            to_device(&self.client, &v_host)?,
        );
        Ok(partial)
    }

    fn mlp(&mut self, layer: usize, x: &HostTensor) -> Result<HostTensor> {
        let window = x.rows();
        let exe = &self.exes(window)?.mlp;
        let lw = &self.layer_bufs[layer];
        let x_buf = to_device(&self.client, x)?;
        let inputs = [&x_buf, &lw.mlp_norm, &lw.w_gate, &lw.w_up, &lw.w_down];
        let out = execute_b_tuple(exe, &inputs)?;
        HostTensor::from_literal(&out[0], &[window, self.hidden])
    }

    fn logits(&mut self, x: &HostTensor) -> Result<HostTensor> {
        let window = x.rows();
        let exe = &self.exes(window)?.logits;
        let x_buf = to_device(&self.client, x)?;
        let out = execute_b_tuple(exe, &[&x_buf, &self.final_norm, &self.lm_head])?;
        let v_local = out[0].element_count(); // lm_head shard is [h, v/t]
        HostTensor::from_literal(&out[0], &[1, v_local])
    }

    fn reset(&mut self) -> Result<()> {
        let shape = self.kv_shape();
        self.kv.clear();
        for _ in 0..self.layers {
            let zeros = HostTensor::zeros(&shape);
            let k = to_device(&self.client, &zeros)?;
            let v = to_device(&self.client, &zeros)?;
            self.kv.push((k, v));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn structural_backend_shapes() {
        let arch = ModelArch::llama31_8b();
        let mut b = StructuralBackend::new(&arch, 4);
        let e = b.embed(&[1, 2, 3]).unwrap();
        assert_eq!(e.shape, vec![3, 4096]);
        let a = b.attn(0, &e, 0).unwrap();
        assert_eq!(a.shape, e.shape);
        let l = b.logits(&e).unwrap();
        assert_eq!(l.shape, vec![1, 128_256 / 4]);
        b.reset().unwrap();
    }

    #[test]
    fn structural_backend_batched_decode_shapes() {
        let arch = ModelArch::llama31_8b();
        let mut b = StructuralBackend::new(&arch, 4);
        assert!(b.supports_batched_decode());
        let x = b.embed(&[1, 2, 3]).unwrap(); // 3 independent sequences
        let a = b.attn_batch(0, &x, &[5, 9, 17]).unwrap();
        assert_eq!(a.shape, vec![3, 4096]);
        let l = b.logits_batch(&x).unwrap();
        assert_eq!(l.shape, vec![3, 128_256 / 4]);
    }
}
