//! Worker thread: one simulated GPU.
//!
//! Each worker owns a compute backend (its TP shard / PP stage) and blocks
//! on a command channel; the coordinator drives prefill/decode steps. All
//! inter-worker data flows through the traced collective library:
//!
//! ```text
//!   stage entry : Recv ×2 [S, h/t]  →  AllGather ×2 → [S, h]      (t>1, s>0)
//!   per layer   : attn partial → AllReduce [S,h] → +residual
//!                 mlp  partial → AllReduce [S,h] → deferred add
//!   stage exit  : Send ×2 [S, h/t]                                 (s<p−1)
//!   last stage  : logits slice → Gather [v/t] → coordinator samples
//! ```
//!
//! `S` is the iteration window: the prompt length for prefill, the *active
//! batch size* for decode (continuous batching — each decode row advances
//! an independent sequence, so every collective's payload scales linearly
//! with the batch; a batch of one is byte-identical to the paper's
//! single-request methodology).
//!
//! The residual of the *last* layer of a stage is deliberately left
//! un-added and shipped as the second boundary tensor ("deferred
//! residual"), matching vLLM's IntermediateTensors {hidden_states,
//! residual} — this is why the paper observes exactly two p2p tensors per
//! boundary per step (Table V).

use std::sync::mpsc::{Receiver, Sender};

use crate::comm::{GroupHandle, P2pEndpoint, Stage};
use crate::runtime::tensor::HostTensor;
use crate::Result;

use super::backend::ComputeBackend;

/// Commands from the coordinator (broadcast to every worker).
#[derive(Debug, Clone)]
pub enum WorkerCmd {
    /// Run prefill over the prompt; workers then hold KV state.
    Prefill { tokens: Vec<i32> },
    /// Run one decode iteration over the active batch: row `i` advances an
    /// independent sequence whose next input token is `tokens[i]`, cached
    /// at `positions[i]`. A single-sequence decode is the length-1 batch.
    Decode { tokens: Vec<i32>, positions: Vec<usize> },
    /// Clear KV state for the next request.
    Reset,
    /// Exit the worker loop.
    Shutdown,
}

/// Sent to the coordinator by the driver (last stage, TP rank 0).
#[derive(Debug)]
pub struct StepOutput {
    pub logits: Vec<f32>,
}

/// Everything a worker thread needs; `backend` is constructed inside the
/// thread for PJRT (non-`Send` internals).
pub struct WorkerCtx {
    pub global_rank: usize,
    pub pp_stage: usize,
    pub tp_rank: usize,
    pub tp: usize,
    pub pp: usize,
    pub hidden: usize,
    /// Global layer indices owned by this stage.
    pub layer_range: std::ops::Range<usize>,
    pub tp_group: GroupHandle,
    pub prev: Option<P2pEndpoint>,
    pub next: Option<P2pEndpoint>,
    pub cmd_rx: Receiver<WorkerCmd>,
    /// Present only on the driver (last stage, tp rank 0).
    pub out_tx: Option<Sender<Result<StepOutput>>>,
}

impl WorkerCtx {
    pub fn is_first_stage(&self) -> bool {
        self.pp_stage == 0
    }

    pub fn is_last_stage(&self) -> bool {
        self.pp_stage == self.pp - 1
    }

    /// Worker main loop. Runs until `Shutdown` or channel disconnect.
    pub fn run(mut self, mut backend: Box<dyn ComputeBackend>) {
        loop {
            let cmd = match self.cmd_rx.recv() {
                Ok(c) => c,
                Err(_) => return, // coordinator dropped
            };
            let result = match cmd {
                WorkerCmd::Prefill { tokens } => {
                    self.step(&mut *backend, &tokens, &[0], Stage::Prefill)
                }
                WorkerCmd::Decode { tokens, positions } => {
                    self.step(&mut *backend, &tokens, &positions, Stage::Decode)
                }
                WorkerCmd::Reset => backend.reset().map(|_| ()),
                WorkerCmd::Shutdown => return,
            };
            if let Err(e) = result {
                // Surface the failure to the coordinator if we're the
                // driver; otherwise panic the worker (tests will see the
                // disconnect).
                if let Some(tx) = &self.out_tx {
                    let _ = tx.send(Err(e));
                } else {
                    panic!("worker {} failed: {e:?}", self.global_rank);
                }
            }
        }
    }

    /// One forward step (prefill: window = prompt len, one sequence;
    /// decode: window = active batch size, one row per sequence).
    fn step(
        &mut self,
        backend: &mut dyn ComputeBackend,
        tokens: &[i32],
        positions: &[usize],
        stage: Stage,
    ) -> Result<()> {
        let window = tokens.len();
        let h = self.hidden;
        let full_shape = [window, h];
        let slice_shape = [window, h / self.tp];

        // --- stage entry -------------------------------------------------
        let (mut x, mut pending): (HostTensor, Option<HostTensor>) = if self.is_first_stage() {
            let mut emb = backend.embed(tokens)?;
            self.tp_group.all_reduce(&mut emb.data, &full_shape, stage);
            (emb, None)
        } else {
            let prev = self.prev.as_ref().expect("non-first stage has prev link");
            let x_slice = prev.recv(&slice_shape, stage);
            let r_slice = prev.recv(&slice_shape, stage);
            let x = self.regather(x_slice, window, stage);
            let r = self.regather(r_slice, window, stage);
            (x, Some(r))
        };

        // --- local layers --------------------------------------------------
        for layer in self.layer_range.clone() {
            if let Some(p) = pending.take() {
                x.add_assign(&p); // residual deferred across the boundary/layer
            }
            let mut pa = match stage {
                Stage::Prefill => backend.attn(layer, &x, positions[0])?,
                Stage::Decode => backend.attn_batch(layer, &x, positions)?,
            };
            self.tp_group.all_reduce(&mut pa.data, &full_shape, stage);
            x.add_assign(&pa);
            let mut pm = backend.mlp(layer, &x)?;
            self.tp_group.all_reduce(&mut pm.data, &full_shape, stage);
            pending = Some(pm);
        }

        // --- stage exit ------------------------------------------------------
        if self.is_last_stage() {
            if let Some(p) = pending.take() {
                x.add_assign(&p);
            }
            let logits_slice = match stage {
                Stage::Prefill => backend.logits(&x)?,
                Stage::Decode => backend.logits_batch(&x)?,
            };
            let v_local = logits_slice.elems();
            let gathered =
                self.tp_group
                    .gather(&logits_slice.data, &[v_local], 0, stage);
            if let Some(full) = gathered {
                if let Some(tx) = &self.out_tx {
                    tx.send(Ok(StepOutput { logits: full }))
                        .map_err(|_| anyhow::anyhow!("coordinator hung up"))?;
                }
            }
        } else {
            let next = self.next.as_ref().expect("non-last stage has next link");
            let pending = pending.take().expect("stage has >= 1 layer");
            let xs = x.column_slice(self.tp_rank, self.tp);
            let rs = pending.column_slice(self.tp_rank, self.tp);
            next.send(xs.data, &slice_shape, stage);
            next.send(rs.data, &slice_shape, stage);
        }
        Ok(())
    }

    /// AllGather a received `[S, h/t]` slice back to `[S, h]` (hybrid stage
    /// entry); identity for t=1.
    fn regather(&self, slice: Vec<f32>, window: usize, stage: Stage) -> HostTensor {
        let h = self.hidden;
        if self.tp == 1 {
            return HostTensor::from_vec(&[window, h], slice);
        }
        let full = self.tp_group.all_gather(&slice, &[window, h], stage);
        HostTensor::from_column_chunks(&full, window, h, self.tp)
    }
}
