//! Iteration-level session API — continuous batching over the engine.
//!
//! A [`Session`] owns the request lifecycle between the scheduler and the
//! worker group: sequences are [`Session::admit`]ted, and every
//! [`Session::step`] runs exactly one engine iteration — either the
//! prefill of one admitted sequence or one decode iteration over the whole
//! *active batch* (vLLM's iteration-level execution) — emitting one
//! [`TokenEvent`] per participating sequence (streaming) and a
//! [`StepOutcome`] describing the iteration.
//!
//! Every collective a step issues is tagged with the step counter and the
//! active batch size ([`crate::comm::CommRecord::step`] /
//! [`crate::comm::CommRecord::batch`]), so the trace records decode
//! all-reduce volume *as a function of batch size* — the batch dimension
//! the paper's single-request methodology (§IV.B) deliberately isolates
//! away, and the axis batching-aware models (arXiv:2408.10197,
//! arXiv:2407.14645) study.
//!
//! [`super::Engine::generate`] is a thin single-sequence wrapper over this
//! API: a batch of one issues a byte-identical command/collective stream,
//! so every trace/analyze/bench path is unchanged.
//!
//! **Model time.** On structural engines with a pricing
//! [`CostModel`] attached, the session also advances a virtual-clock
//! [`Timeline`]: every step posts its priced events (per-stage compute,
//! TP collectives, boundary handoffs, coordinator round-trip) and reports
//! the iteration's modeled duration in
//! [`StepOutcome::model_latency_s`] — what the calibrated H100 testbed
//! *would* take, deterministic for a given workload, next to the host
//! wall-clock `latency` (which, for no-op structural compute, measures
//! only thread scheduling).

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::engine::kv::SeqId;
use crate::runtime::tensor::argmax;
use crate::simtime::{CostModel, Timeline};
use crate::Result;

use super::worker::WorkerCmd;
use super::Engine;

/// Shared, immutable prompt tokens. Prompts flow from workload generation
/// through routers, pending tables, admission queues, and disaggregated
/// handoffs; an `Arc` makes every hop a refcount bump instead of a
/// token-vector copy (`Arc`, not `Rc`, so fleet sweeps can run candidates
/// on threads).
pub type PromptTokens = std::sync::Arc<Vec<i32>>;

/// One sequence admitted into a [`Session`].
#[derive(Debug, Clone)]
pub struct SequenceInput {
    pub id: SeqId,
    pub prompt: PromptTokens,
    /// First prompt position this session must prefill: tokens before
    /// `start` are already resident (a prefix-cache hit), so the engine
    /// prefills — and prices — only `prompt[start..]` without the caller
    /// copying the suffix out. 0 for ordinary admissions.
    pub start: usize,
    /// Total tokens to generate; the first comes out of prefill (the
    /// paper's S_d counting).
    pub max_new_tokens: usize,
}

/// One streamed token, emitted as soon as its iteration completes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TokenEvent {
    pub seq: SeqId,
    pub token: i32,
    /// 0-based index within the sequence's generated output.
    pub index: usize,
    /// True when this token completes the sequence.
    pub is_last: bool,
}

/// What kind of iteration a [`Session::step`] call ran.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepKind {
    /// Prefill work for one admitted sequence with nothing decoding: a
    /// one-shot prompt (emits its first token) or one chunk of a
    /// chunked prompt (only the last chunk emits).
    Prefill,
    /// One decode iteration over the whole active batch.
    Decode,
    /// One fused iteration: a prefill chunk plus a decode over the
    /// active batch — chunked prefill's mixed batch (Sarathi-style).
    Mixed,
    /// Nothing to do — no admitted or active sequences.
    Idle,
}

/// Outcome of one engine iteration.
#[derive(Debug, Clone)]
pub struct StepOutcome {
    pub kind: StepKind,
    /// Monotone iteration counter (shared across prefill and decode; this
    /// is the `step` tag on the iteration's trace records). Continues
    /// across sessions on one engine, so per-step trace aggregation never
    /// merges two sessions' iterations.
    pub step_index: u64,
    /// Sequences in this iteration's forward pass (1 for prefill, 0 for
    /// idle; this is the `batch` tag on the iteration's trace records).
    pub batch: usize,
    /// Tokens produced this iteration, one per participating sequence.
    pub events: Vec<TokenEvent>,
    /// Sequences that reached `max_new_tokens` this iteration.
    pub finished: Vec<SeqId>,
    /// Wall-clock latency of the iteration (host time; for structural
    /// no-op compute this measures thread scheduling, not serving).
    pub latency: Duration,
    /// Modeled duration of the iteration on the priced timeline — present
    /// on structural engines with a pricing cost model, `None` otherwise
    /// (numeric engines report real wall time instead).
    pub model_latency_s: Option<f64>,
    /// Model-time latency this iteration added to each mid-decode
    /// sequence on top of a pure decode step — the prefill/decode
    /// interference disaggregation removes and chunking amortizes. A
    /// one-shot prefill stalls every decoding sequence for its whole
    /// duration; a mixed step stretches them by the fused price minus
    /// the decode-alone price. Empty when nothing was decoding, on
    /// decode/idle steps, and on unpriced engines.
    pub interference: Vec<(SeqId, f64)>,
    /// Set on the iteration that finishes a chunked prefill: the owner
    /// sequence and how many chunks its prompt took.
    pub chunk_owner: Option<(SeqId, u32)>,
}

struct ActiveSeq {
    id: SeqId,
    prompt_len: usize,
    /// Tokens already cached for the sequence before its prompt (a
    /// disaggregated decode pool receives the prefill pool's KV): decode
    /// positions — and therefore priced context lengths — start past it.
    context: usize,
    max_new_tokens: usize,
    last_token: i32,
    generated: usize,
}

/// A prompt midway through Sarathi-style chunked prefill: its uncached
/// suffix is prefilled [`crate::engine::EngineConfig::chunk_tokens`]
/// tokens at a time, each chunk fused with a decode iteration of the
/// active batch (a *mixed* step) so decoding sequences keep streaming
/// while the long prompt fills in.
struct ChunkedPrefill {
    seq: SequenceInput,
    /// Tokens cached before the prompt (disaggregated intake).
    context: usize,
    /// Suffix tokens already prefilled by earlier chunks.
    done: usize,
    /// Chunks issued so far.
    chunks: u32,
}

/// The session's virtual clock: a pricing cost model plus the per-rank
/// timeline it posts each iteration onto.
struct ModelClock {
    cost: CostModel,
    timeline: Timeline,
}

/// Iteration-level view of an [`Engine`]: admitted sequences share each
/// decode iteration (continuous batching). Created by
/// [`Engine::session`]; dropping the session leaves the engine reusable.
pub struct Session<'e> {
    engine: &'e mut Engine,
    /// Admitted-but-not-prefilled sequences, each with its cached-context
    /// token count (0 for ordinary admissions).
    waiting_prefill: VecDeque<(SequenceInput, usize)>,
    active: Vec<ActiveSeq>,
    /// Chunked-prefill budget (from the engine config); `None` keeps
    /// every prompt on the one-shot prefill path bitwise.
    chunk_tokens: Option<usize>,
    /// The prompt currently being prefilled chunk by chunk, if any.
    current_chunk: Option<ChunkedPrefill>,
    step_index: u64,
    model: Option<ModelClock>,
}

impl<'e> Session<'e> {
    pub fn new(engine: &'e mut Engine) -> Self {
        // Model time is a structural-engine feature: numeric engines do
        // real compute, so their wall clocks are the meaningful latency.
        let model = match (&engine.cfg.mode, &engine.cfg.pricing) {
            (super::EngineMode::Structural, Some(cost)) => Some(ModelClock {
                cost: cost.clone(),
                timeline: Timeline::new(engine.cfg.layout.world_size()),
            }),
            _ => None,
        };
        // Step tags continue from where the engine's previous session
        // left off, so per-step trace aggregation stays unambiguous
        // across sessions on one engine.
        let step_index = engine.steps_issued;
        let chunk_tokens = engine.cfg.chunk_tokens;
        Self {
            engine,
            waiting_prefill: VecDeque::new(),
            active: Vec::new(),
            chunk_tokens,
            current_chunk: None,
            step_index,
            model,
        }
    }

    /// The model-time clock (seconds since the session opened), when this
    /// session runs on a priced structural engine.
    pub fn model_now(&self) -> Option<f64> {
        self.model.as_ref().map(|m| m.timeline.max_time())
    }

    /// Advance the model clock to at least `t` (idle time — a serving
    /// loop waiting for the next open-loop arrival). No-op without a
    /// model clock or when the clock is already past `t`.
    pub fn advance_model_time_to(&mut self, t: f64) {
        if let Some(m) = &mut self.model {
            m.timeline.advance_all_to(t);
        }
    }

    /// Sequences the session is working on (admitted + decoding).
    pub fn live(&self) -> usize {
        self.waiting_prefill.len() + self.active.len() + usize::from(self.current_chunk.is_some())
    }

    /// True when no sequence is admitted or decoding.
    pub fn is_idle(&self) -> bool {
        self.live() == 0
    }

    /// Admitted sequences that have not finished prefilling yet (a
    /// prompt midway through its chunks counts).
    pub fn pending_prefills(&self) -> usize {
        self.waiting_prefill.len() + usize::from(self.current_chunk.is_some())
    }

    /// True when the next [`Self::step`] call runs a decode iteration
    /// over the active batch — the serving loop's cue to reserve KV for
    /// the token each active sequence is about to write. Without
    /// chunked prefill this is exactly `pending_prefills() == 0`; with
    /// a chunk in progress (or a long prompt about to start one) the
    /// next step is *mixed*, so the active batch decodes alongside the
    /// chunk and still needs its per-token growth.
    pub fn decode_in_next_step(&self) -> bool {
        if self.current_chunk.is_some() {
            return !self.active.is_empty();
        }
        match self.waiting_prefill.front() {
            Some((seq, _)) => self.needs_chunking(seq) && !self.active.is_empty(),
            None => true,
        }
    }

    /// Whether a prompt's uncached suffix overflows the chunk budget
    /// and therefore prefills chunk by chunk. Always false with the
    /// budget unset — every prompt takes the one-shot path bitwise.
    fn needs_chunking(&self, seq: &SequenceInput) -> bool {
        self.chunk_tokens.is_some_and(|budget| seq.prompt.len() - seq.start > budget)
    }

    /// Ids currently in the decode batch, in batch order.
    pub fn active_ids(&self) -> Vec<SeqId> {
        self.active.iter().map(|s| s.id).collect()
    }

    /// Admit a sequence into the session. It prefills on a subsequent
    /// [`Self::step`] and then joins the decode batch. KV *accounting*
    /// (block admission/growth) is the scheduler's job — the session only
    /// drives execution.
    pub fn admit(&mut self, seq: SequenceInput) -> Result<()> {
        self.admit_with_context(seq, 0)
    }

    /// Admit a sequence whose first `cached_tokens` tokens are already in
    /// the KV cache — the disaggregated decode pool's intake, where the
    /// prompt is just the handed-off first token but every decode
    /// iteration must be priced against the shipped prefill context.
    /// Decode positions (and the model clock's per-sequence KV lengths)
    /// start past the cached span. Structural engines only: numeric
    /// backends hold real KV state and cannot fake a warm cache.
    pub fn admit_with_context(&mut self, seq: SequenceInput, cached_tokens: usize) -> Result<()> {
        if seq.prompt.len() <= seq.start {
            anyhow::bail!("empty prompt");
        }
        if seq.max_new_tokens == 0 {
            anyhow::bail!("max_new_tokens must be >= 1");
        }
        if self.waiting_prefill.iter().any(|(s, _)| s.id == seq.id)
            || self.active.iter().any(|s| s.id == seq.id)
            || self.current_chunk.as_ref().is_some_and(|cp| cp.seq.id == seq.id)
        {
            anyhow::bail!("sequence {} already live in this session", seq.id);
        }
        if let super::EngineMode::Numeric(store) = &self.engine.cfg.mode {
            if cached_tokens > 0 || seq.start > 0 {
                anyhow::bail!(
                    "cached-context admission needs a structural engine: numeric \
                     backends hold real KV state and cannot fake a warm cache"
                );
            }
            if seq.prompt.len() != store.meta.prefill_len {
                anyhow::bail!(
                    "numeric mode serves fixed prompts of {} tokens (got {})",
                    store.meta.prefill_len,
                    seq.prompt.len()
                );
            }
            if seq.prompt.len() + seq.max_new_tokens > store.meta.max_seq {
                anyhow::bail!(
                    "prompt {} + decode {} exceeds max_seq {}",
                    seq.prompt.len(),
                    seq.max_new_tokens,
                    store.meta.max_seq
                );
            }
            if self.live() > 0 {
                anyhow::bail!(
                    "numeric backends hold single-sequence KV state: the session \
                     serves one sequence at a time (batched decode needs structural mode)"
                );
            }
        }
        self.waiting_prefill.push_back((seq, cached_tokens));
        Ok(())
    }

    /// Drop a live sequence (the scheduler's bail-out path when the KV
    /// pool is exhausted mid-decode). Returns true if it was live.
    pub fn cancel(&mut self, id: SeqId) -> bool {
        if let Some(i) = self.waiting_prefill.iter().position(|(s, _)| s.id == id) {
            self.waiting_prefill.remove(i);
            return true;
        }
        if self.current_chunk.as_ref().is_some_and(|cp| cp.seq.id == id) {
            // Chunks already prefilled are wasted work — the caller's
            // KV release drops them like any bailed sequence.
            self.current_chunk = None;
            return true;
        }
        if let Some(i) = self.active.iter().position(|s| s.id == id) {
            self.active.remove(i);
            return true;
        }
        false
    }

    /// Run one engine iteration: the next chunk of an in-progress
    /// chunked prefill (fused with a decode of the active batch when
    /// one is running), else the prefill of the oldest admitted
    /// sequence — chunked when its suffix overflows the budget — else
    /// one decode iteration over the active batch, else an idle no-op.
    pub fn step(&mut self) -> Result<StepOutcome> {
        if self.current_chunk.is_some() {
            return self.chunk_step();
        }
        if let Some((seq, context)) = self.waiting_prefill.pop_front() {
            if self.needs_chunking(&seq) {
                self.current_chunk = Some(ChunkedPrefill { seq, context, done: 0, chunks: 0 });
                return self.chunk_step();
            }
            return self.prefill_step(seq, context);
        }
        if !self.active.is_empty() {
            return self.decode_step();
        }
        Ok(StepOutcome {
            kind: StepKind::Idle,
            step_index: self.step_index,
            batch: 0,
            events: Vec::new(),
            finished: Vec::new(),
            latency: Duration::ZERO,
            model_latency_s: None,
            interference: Vec::new(),
            chunk_owner: None,
        })
    }

    fn prefill_step(&mut self, seq: SequenceInput, context: usize) -> Result<StepOutcome> {
        let step_index = self.step_index;
        self.step_index += 1;
        self.engine.steps_issued = self.step_index;
        self.engine.sink.set_iteration(step_index, 1);
        // Only the uncached suffix reaches the workers: `start` tokens are
        // already resident, so length-driven pricing and decode positions
        // see exactly what a suffix-vector admission would have seen.
        let prompt_len = seq.prompt.len() - seq.start;
        let start = Instant::now();
        // Reset clears the backend's whole KV state, so it is only safe
        // when no other sequence is mid-decode: with an empty active set it
        // evicts the previous request (numeric single-sequence serving, and
        // the exact command stream `generate()` always issued — Reset,
        // Prefill, Decode…); with live sequences batching, a prefill joins
        // the batch without touching anyone's cache.
        if self.active.is_empty() {
            self.engine.broadcast(WorkerCmd::Reset)?;
        }
        self.engine.broadcast(WorkerCmd::Prefill { tokens: seq.prompt[seq.start..].to_vec() })?;
        let logits = self.engine.recv_logits()?;
        let latency = start.elapsed();
        let model_latency_s = match self.model.as_mut() {
            Some(m) => {
                let (dt, hidden) = m.cost.post_prefill(&mut m.timeline, prompt_len);
                self.engine.hidden_comm_s += hidden;
                Some(dt)
            }
            None => None,
        };
        // A one-shot prefill with sequences mid-decode stalls each of
        // them for the whole iteration — the interference that makes
        // colocated serving lose to disaggregation on TPOT.
        let interference: Vec<(SeqId, f64)> = match model_latency_s {
            Some(dt) if !self.active.is_empty() => {
                self.active.iter().map(|s| (s.id, dt)).collect()
            }
            _ => Vec::new(),
        };
        let token = argmax(&logits) as i32;
        let is_last = seq.max_new_tokens == 1;
        let events = vec![TokenEvent { seq: seq.id, token, index: 0, is_last }];
        let mut finished = Vec::new();
        if is_last {
            finished.push(seq.id);
        } else {
            self.active.push(ActiveSeq {
                id: seq.id,
                prompt_len,
                context,
                max_new_tokens: seq.max_new_tokens,
                last_token: token,
                generated: 1,
            });
        }
        Ok(StepOutcome {
            kind: StepKind::Prefill,
            step_index,
            batch: 1,
            events,
            finished,
            latency,
            model_latency_s,
            interference,
            chunk_owner: None,
        })
    }

    /// One chunk of an in-progress chunked prefill. With sequences
    /// mid-decode this is a *mixed* iteration: the chunk and one decode
    /// token per active sequence run as a single fused launch — the
    /// worker protocol has no fused command, so the decode rides the
    /// same step tag and the pricing charges [`CostModel::post_mixed`]'s
    /// single iteration instead of two. Only the final chunk emits the
    /// owner's first token.
    fn chunk_step(&mut self) -> Result<StepOutcome> {
        let mut cp = self.current_chunk.take().expect("a chunk is in progress");
        let budget = self.chunk_tokens.expect("chunked prefill enabled");
        let suffix_len = cp.seq.prompt.len() - cp.seq.start;
        let chunk_start = cp.done;
        let len = budget.min(suffix_len - cp.done);
        let last_chunk = cp.done + len == suffix_len;
        let decode_batch = self.active.len();
        let batch = 1 + decode_batch;
        let step_index = self.step_index;
        self.step_index += 1;
        self.engine.steps_issued = self.step_index;
        self.engine.sink.set_iteration(step_index, batch);
        let start = Instant::now();
        // Same safety rule as the one-shot path: Reset wipes the whole
        // KV state, so only the *first* chunk with nothing else live
        // may issue it.
        if cp.done == 0 && self.active.is_empty() {
            self.engine.broadcast(WorkerCmd::Reset)?;
        }
        let lo = cp.seq.start + cp.done;
        self.engine
            .broadcast(WorkerCmd::Prefill { tokens: cp.seq.prompt[lo..lo + len].to_vec() })?;
        let chunk_logits = self.engine.recv_logits()?;
        let mut victim_logits = None;
        let mut kv_lens = Vec::new();
        if decode_batch > 0 {
            let tokens: Vec<i32> = self.active.iter().map(|s| s.last_token).collect();
            let positions: Vec<usize> = self
                .active
                .iter()
                .map(|s| s.context + s.prompt_len + s.generated - 1)
                .collect();
            kv_lens = positions.iter().map(|&p| p + 1).collect();
            self.engine.broadcast(WorkerCmd::Decode { tokens, positions })?;
            victim_logits = Some(self.engine.recv_logits()?);
        }
        let latency = start.elapsed();
        let mut interference = Vec::new();
        let model_latency_s = match self.model.as_mut() {
            Some(m) => {
                let (dt, hidden) = if decode_batch > 0 {
                    m.cost.post_mixed(&mut m.timeline, chunk_start, len, &kv_lens)
                } else {
                    m.cost.post_prefill_chunk(&mut m.timeline, chunk_start, len)
                };
                self.engine.hidden_comm_s += hidden;
                if decode_batch > 0 {
                    // What the victims pay for sharing the iteration:
                    // the fused price minus the decode they would have
                    // run alone.
                    let stretch = dt - m.cost.decode_iteration(&kv_lens).total();
                    interference = self.active.iter().map(|s| (s.id, stretch)).collect();
                }
                Some(dt)
            }
            None => None,
        };
        cp.done += len;
        cp.chunks += 1;
        let mut events = Vec::with_capacity(batch);
        let mut finished = Vec::new();
        let mut chunk_owner = None;
        let mut owner_active = None;
        if last_chunk {
            let token = argmax(&chunk_logits) as i32;
            let is_last = cp.seq.max_new_tokens == 1;
            events.push(TokenEvent { seq: cp.seq.id, token, index: 0, is_last });
            chunk_owner = Some((cp.seq.id, cp.chunks));
            if is_last {
                finished.push(cp.seq.id);
            } else {
                owner_active = Some(ActiveSeq {
                    id: cp.seq.id,
                    prompt_len: suffix_len,
                    context: cp.context,
                    max_new_tokens: cp.seq.max_new_tokens,
                    last_token: token,
                    generated: 1,
                });
            }
        } else {
            self.current_chunk = Some(cp);
        }
        if let Some(logits) = victim_logits {
            let next = batched_argmax(&logits, self.engine.cfg.layout.tp, decode_batch);
            for (seq, &token_id) in self.active.iter_mut().zip(next.iter()) {
                let token = token_id as i32;
                seq.last_token = token;
                let index = seq.generated;
                seq.generated += 1;
                let is_last = seq.generated == seq.max_new_tokens;
                events.push(TokenEvent { seq: seq.id, token, index, is_last });
                if is_last {
                    finished.push(seq.id);
                }
            }
            self.active.retain(|s| s.generated < s.max_new_tokens);
        }
        // The owner joins the decode batch only after the victims'
        // rows were walked — its first decode token comes next step.
        if let Some(owner) = owner_active {
            self.active.push(owner);
        }
        Ok(StepOutcome {
            kind: if decode_batch > 0 { StepKind::Mixed } else { StepKind::Prefill },
            step_index,
            batch,
            events,
            finished,
            latency,
            model_latency_s,
            interference,
            chunk_owner,
        })
    }

    fn decode_step(&mut self) -> Result<StepOutcome> {
        let batch = self.active.len();
        if batch > 1 && !self.engine.supports_batched_decode() {
            anyhow::bail!("engine backend does not support batched decode (batch={batch})");
        }
        let step_index = self.step_index;
        self.step_index += 1;
        self.engine.steps_issued = self.step_index;
        self.engine.sink.set_iteration(step_index, batch);
        let tokens: Vec<i32> = self.active.iter().map(|s| s.last_token).collect();
        let positions: Vec<usize> =
            self.active.iter().map(|s| s.context + s.prompt_len + s.generated - 1).collect();
        // Context length each sequence decodes against this iteration
        // (its cached tokens plus the one being written).
        let kv_lens: Vec<usize> = positions.iter().map(|&p| p + 1).collect();
        let start = Instant::now();
        self.engine.broadcast(WorkerCmd::Decode { tokens, positions })?;
        let logits = self.engine.recv_logits()?;
        let latency = start.elapsed();
        let model_latency_s = match self.model.as_mut() {
            Some(m) => {
                let (dt, hidden) = m.cost.post_decode(&mut m.timeline, &kv_lens);
                self.engine.hidden_comm_s += hidden;
                Some(dt)
            }
            None => None,
        };
        let next = batched_argmax(&logits, self.engine.cfg.layout.tp, batch);
        let mut events = Vec::with_capacity(batch);
        let mut finished = Vec::new();
        for (seq, &token_id) in self.active.iter_mut().zip(next.iter()) {
            let token = token_id as i32;
            seq.last_token = token;
            let index = seq.generated;
            seq.generated += 1;
            let is_last = seq.generated == seq.max_new_tokens;
            events.push(TokenEvent { seq: seq.id, token, index, is_last });
            if is_last {
                finished.push(seq.id);
            }
        }
        self.active.retain(|s| s.generated < s.max_new_tokens);
        Ok(StepOutcome {
            kind: StepKind::Decode,
            step_index,
            batch,
            events,
            finished,
            latency,
            model_latency_s,
            interference: Vec::new(),
            chunk_owner: None,
        })
    }
}

impl Drop for Session<'_> {
    fn drop(&mut self) {
        // Records after the session (warmup, raw library use) are untagged.
        self.engine.sink.clear_iteration();
    }
}

/// De-interleave the gathered decode logits — rank-major `tp` blocks of
/// flattened `[B, v/tp]` — and take each sequence's argmax over the full
/// vocabulary. Scan order (rank-major, then row-major) matches the
/// single-sequence [`argmax`] tie-breaking exactly for `B = 1`.
fn batched_argmax(flat: &[f32], tp: usize, batch: usize) -> Vec<usize> {
    assert!(tp >= 1 && batch >= 1);
    assert_eq!(flat.len() % (tp * batch), 0, "logits not divisible across ranks/rows");
    let v_local = flat.len() / (tp * batch);
    (0..batch)
        .map(|row| {
            let mut best = 0usize;
            let mut best_v = f32::NEG_INFINITY;
            for r in 0..tp {
                let base = (r * batch + row) * v_local;
                for (j, &v) in flat[base..base + v_local].iter().enumerate() {
                    if v > best_v {
                        best_v = v;
                        best = r * v_local + j;
                    }
                }
            }
            best
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::ParallelLayout;
    use crate::engine::EngineConfig;
    use crate::model::ModelArch;

    fn structural_engine(tp: usize, pp: usize) -> Engine {
        Engine::new(EngineConfig::structural(ModelArch::tiny(), ParallelLayout::new(tp, pp)))
            .unwrap()
    }

    fn seq(id: SeqId, prompt: usize, max_new: usize) -> SequenceInput {
        SequenceInput { id, prompt: vec![0; prompt].into(), start: 0, max_new_tokens: max_new }
    }

    #[test]
    fn admit_validates_inputs() {
        let mut engine = structural_engine(1, 1);
        let mut s = engine.session();
        assert!(s.admit(seq(1, 0, 4)).is_err(), "empty prompt");
        assert!(s.admit(seq(1, 4, 0)).is_err(), "zero decode");
        s.admit(seq(1, 4, 2)).unwrap();
        assert!(s.admit(seq(1, 4, 2)).is_err(), "duplicate id");
        assert_eq!(s.live(), 1);
        assert!(s.cancel(1));
        assert!(!s.cancel(1), "already gone");
        assert!(s.is_idle());
    }

    #[test]
    fn streams_events_and_drains_batch() {
        let mut engine = structural_engine(2, 1);
        let mut s = engine.session();
        s.admit(seq(7, 8, 3)).unwrap();
        s.admit(seq(9, 8, 2)).unwrap();

        let p1 = s.step().unwrap();
        assert_eq!(p1.kind, StepKind::Prefill);
        assert_eq!((p1.step_index, p1.batch), (0, 1));
        assert_eq!(
            p1.events,
            vec![TokenEvent { seq: 7, token: 0, index: 0, is_last: false }]
        );
        let p2 = s.step().unwrap();
        assert_eq!(p2.kind, StepKind::Prefill);
        assert_eq!(p2.events[0].seq, 9);

        // Both prefilled: one decode iteration advances both sequences.
        let d1 = s.step().unwrap();
        assert_eq!(d1.kind, StepKind::Decode);
        assert_eq!(d1.batch, 2);
        assert_eq!(
            d1.events,
            vec![
                TokenEvent { seq: 7, token: 0, index: 1, is_last: false },
                TokenEvent { seq: 9, token: 0, index: 1, is_last: true },
            ]
        );
        assert_eq!(d1.finished, vec![9]);

        // Batch shrinks to the remaining sequence.
        let d2 = s.step().unwrap();
        assert_eq!(d2.batch, 1);
        assert_eq!(d2.finished, vec![7]);
        assert!(s.is_idle());
        let idle = s.step().unwrap();
        assert_eq!(idle.kind, StepKind::Idle);
        assert!(idle.events.is_empty());
    }

    #[test]
    fn decode_collectives_are_tagged_with_batch_size() {
        use crate::comm::{CollectiveKind, Stage};
        let mut engine = structural_engine(2, 1);
        {
            let mut s = engine.session();
            for id in 0..3u64 {
                s.admit(seq(id, 8, 4)).unwrap();
            }
            while !s.is_idle() {
                s.step().unwrap();
            }
        }
        let summary = engine.trace().summary();
        // All decode iterations ran the full batch of 3.
        assert_eq!(summary.batch_sizes(), vec![1, 3]);
        let b3 = summary.batch_view(3, CollectiveKind::AllReduce, Stage::Decode);
        assert!(b3.count > 0);
        // Payload per record is 3x the single-sequence decode AllReduce
        // ([3, h] vs [1, h]).
        let hidden = ModelArch::tiny().hidden;
        assert_eq!(b3.total_message_bytes / b3.count, 3 * hidden * 2);
        // Prefills are tagged batch=1 and stay [S, h].
        let b1 = summary.batch_view(1, CollectiveKind::AllReduce, Stage::Prefill);
        assert!(b1.count > 0);
    }

    #[test]
    fn step_tags_continue_across_sessions_on_one_engine() {
        let mut engine = structural_engine(2, 1);
        {
            let mut s = engine.session();
            s.admit(seq(0, 8, 2)).unwrap();
            while !s.is_idle() {
                s.step().unwrap();
            }
        }
        let mut s = engine.session();
        s.admit(seq(1, 8, 1)).unwrap();
        let out = s.step().unwrap();
        assert_eq!(out.step_index, 2, "second session continues the engine counter");
        drop(s);
        // Per-step trace buckets stay distinct across the two sessions.
        let summary = engine.trace().summary();
        assert_eq!(summary.step_comm_s.len(), 3);
        for step in 0..3u64 {
            assert!(summary.step_modeled_comm_s(step) > 0.0, "step {step} priced");
        }
    }

    #[test]
    fn structural_steps_advance_the_model_clock_deterministically() {
        let run = || {
            let mut engine = structural_engine(2, 1);
            let mut s = engine.session();
            s.admit(seq(0, 8, 3)).unwrap();
            s.admit(seq(1, 8, 3)).unwrap();
            let mut clocks = Vec::new();
            while !s.is_idle() {
                let out = s.step().unwrap();
                let dt = out.model_latency_s.expect("structural engines have model time");
                assert!(dt > 0.0);
                clocks.push(s.model_now().unwrap());
            }
            clocks
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "model time is a pure function of the workload");
        assert!(a.windows(2).all(|w| w[1] > w[0]), "clock is monotone");

        // The prefill iteration's modeled duration is the SLO simulator's
        // prefill total for the same prompt (one pricing core).
        let mut engine = structural_engine(2, 1);
        let mut s = engine.session();
        s.admit(seq(7, 8, 2)).unwrap();
        let out = s.step().unwrap();
        let cm = crate::simtime::CostModel::on_cardinal(
            ModelArch::tiny(),
            ParallelLayout::new(2, 1),
        );
        let closed = cm
            .prefill_breakdown(crate::analysis::InferenceShape::new(8, 2, 2))
            .total();
        let dt = out.model_latency_s.unwrap();
        assert!((dt - closed).abs() <= 1e-9 * closed, "{dt} vs {closed}");

        // Idle advance never rewinds.
        drop(s);
        let mut s = engine.session();
        s.advance_model_time_to(5.0);
        assert_eq!(s.model_now(), Some(5.0));
        s.advance_model_time_to(1.0);
        assert_eq!(s.model_now(), Some(5.0));
    }

    #[test]
    fn cached_context_prices_decode_against_the_shipped_kv() {
        // A decode-pool intake (1-token prompt, 64 cached tokens) must
        // price its decode iterations like a colocated sequence at the
        // same total context — not like a fresh 1-token sequence.
        let decode_step_cost = |context: usize| {
            let mut engine = structural_engine(2, 1);
            let mut s = engine.session();
            s.admit_with_context(seq(0, 1, 3), context).unwrap();
            s.step().unwrap(); // prefill (intake)
            let d = s.step().unwrap(); // first decode iteration
            assert_eq!(d.kind, StepKind::Decode);
            d.model_latency_s.unwrap()
        };
        let cold = decode_step_cost(0);
        let warm = decode_step_cost(64);
        assert!(
            warm > cold,
            "decode against 64 cached tokens ({warm}) must outprice a cold \
             1-token context ({cold})"
        );
        // And it matches the colocated equivalent: a 65-token prompt at
        // the same decode position streams the same KV.
        let mut engine = structural_engine(2, 1);
        let mut s = engine.session();
        s.admit(seq(1, 65, 3)).unwrap();
        s.step().unwrap();
        let colocated = s.step().unwrap().model_latency_s.unwrap();
        assert!(
            (warm - colocated).abs() <= 1e-12 * colocated.max(1.0),
            "warm intake {warm} vs colocated {colocated}"
        );
        // Numeric-style admission rules: cached context is rejected on
        // engines that hold real KV state (checked structurally via the
        // duplicate-id and empty-prompt guards still applying).
        let mut engine = structural_engine(1, 1);
        let mut s = engine.session();
        assert!(s.admit_with_context(seq(2, 0, 1), 8).is_err(), "empty prompt");
    }

    #[test]
    fn range_admission_prefills_only_the_suffix() {
        // A replica with 64 prompt tokens cached admits the *full* prompt
        // with `start: 64` instead of copying the suffix out; pricing and
        // decode positions must match a suffix-vector admission exactly.
        let run = |input: SequenceInput| {
            let mut engine = structural_engine(2, 1);
            let mut s = engine.session();
            s.admit_with_context(input, 64).unwrap();
            let p = s.step().unwrap().model_latency_s.unwrap();
            let d = s.step().unwrap().model_latency_s.unwrap();
            (p, d)
        };
        let suffix = run(seq(0, 4, 2));
        let ranged = run(SequenceInput {
            id: 0,
            prompt: vec![0; 68].into(),
            start: 64,
            max_new_tokens: 2,
        });
        assert_eq!(suffix, ranged, "range admission reprices nothing");
        // A fully-cached prompt leaves nothing to prefill — rejected like
        // an empty one.
        let mut engine = structural_engine(1, 1);
        let mut s = engine.session();
        let all_cached =
            SequenceInput { id: 1, prompt: vec![0; 8].into(), start: 8, max_new_tokens: 1 };
        assert!(s.admit(all_cached).is_err(), "empty suffix");
    }

    fn chunked_engine(tp: usize, pp: usize, budget: usize) -> Engine {
        Engine::new(
            EngineConfig::structural(ModelArch::tiny(), ParallelLayout::new(tp, pp))
                .with_chunk_tokens(Some(budget)),
        )
        .unwrap()
    }

    #[test]
    fn chunked_prefill_splits_the_prompt_and_emits_on_the_last_chunk() {
        let mut engine = chunked_engine(2, 1, 32);
        let mut s = engine.session();
        s.admit(seq(0, 100, 3)).unwrap();
        assert_eq!(s.pending_prefills(), 1);
        // 100 suffix tokens under a 32-token budget: 4 chunk iterations
        // (32+32+32+4), nothing else decoding, so all pure prefills.
        let mut dts = Vec::new();
        for i in 0..4 {
            assert!(!s.decode_in_next_step(), "no active batch during chunk {i}");
            assert_eq!(s.pending_prefills(), 1, "owner counts until its last chunk");
            let out = s.step().unwrap();
            assert_eq!(out.kind, StepKind::Prefill);
            assert_eq!(out.batch, 1);
            assert!(out.interference.is_empty(), "no victims to interfere with");
            dts.push(out.model_latency_s.unwrap());
            if i < 3 {
                assert!(out.events.is_empty(), "mid-prompt chunks emit nothing");
                assert_eq!(out.chunk_owner, None);
            } else {
                assert_eq!(
                    out.events,
                    vec![TokenEvent { seq: 0, token: 0, index: 0, is_last: false }],
                    "the last chunk emits the first token"
                );
                assert_eq!(out.chunk_owner, Some((0, 4)));
            }
        }
        // Equal-length chunks get pricier as the attended context grows.
        assert!(dts[2] > dts[0], "chunk 3 ({}) vs chunk 1 ({})", dts[2], dts[0]);
        // Interleaving never creates free work: the chunk total beats
        // the one-shot prefill price (extra launches + overheads).
        let cm = crate::simtime::CostModel::on_cardinal(
            ModelArch::tiny(),
            ParallelLayout::new(2, 1),
        );
        let one_shot =
            cm.prefill_breakdown(crate::analysis::InferenceShape::new(100, 3, 2)).total();
        let total: f64 = dts.iter().sum();
        assert!(total > one_shot, "chunked {total} must outprice one-shot {one_shot}");
        // The owner then decodes like any sequence.
        assert!(s.decode_in_next_step());
        let d = s.step().unwrap();
        assert_eq!(d.kind, StepKind::Decode);
        assert_eq!(d.events[0], TokenEvent { seq: 0, token: 0, index: 1, is_last: false });
        s.step().unwrap();
        assert!(s.is_idle());
    }

    #[test]
    fn chunk_budget_at_or_above_the_prompt_is_bitwise_unchunked() {
        let run = |chunk: Option<usize>| {
            let mut engine = Engine::new(
                EngineConfig::structural(ModelArch::tiny(), ParallelLayout::new(2, 2))
                    .with_chunk_tokens(chunk),
            )
            .unwrap();
            let mut s = engine.session();
            s.admit(seq(0, 64, 4)).unwrap();
            s.admit(seq(1, 24, 6)).unwrap();
            let mut log = Vec::new();
            while !s.is_idle() {
                let out = s.step().unwrap();
                log.push((out.kind, out.batch, out.events.clone(), out.model_latency_s));
            }
            (log, s.model_now())
        };
        let unset = run(None);
        // The longest suffix is exactly 64 tokens: a 64-token budget
        // never splits (chunking needs a strict overflow), and a huge
        // budget trivially never splits — both take the one-shot code
        // path, so every outcome and clock reading is bitwise equal.
        assert_eq!(unset, run(Some(64)));
        assert_eq!(unset, run(Some(100_000)));
    }

    #[test]
    fn mixed_steps_decode_victims_alongside_the_chunk_and_price_interference() {
        let mut engine = chunked_engine(2, 1, 32);
        let mut s = engine.session();
        // A short prompt prefills one-shot (under budget) and decodes.
        s.admit(seq(0, 8, 16)).unwrap();
        let p = s.step().unwrap();
        assert_eq!(p.kind, StepKind::Prefill);
        assert_eq!(p.chunk_owner, None, "under-budget prompts are not chunked");
        assert_eq!(s.step().unwrap().kind, StepKind::Decode);
        // A long prompt arrives: its 3 chunks (80 = 32+32+16) fuse with
        // the victim's decode stream as mixed iterations.
        s.admit(seq(1, 80, 4)).unwrap();
        for i in 0..3 {
            assert!(s.decode_in_next_step(), "a mixed step decodes the victim");
            let out = s.step().unwrap();
            assert_eq!(out.kind, StepKind::Mixed);
            assert_eq!(out.batch, 2, "chunk owner + one victim");
            let dt = out.model_latency_s.unwrap();
            // The victim advanced (its event) and paid for sharing.
            let victim: Vec<&TokenEvent> =
                out.events.iter().filter(|e| e.seq == 0).collect();
            assert_eq!(victim.len(), 1);
            assert_eq!(victim[0].index, 2 + i, "victim streams through every chunk");
            assert_eq!(out.interference.len(), 1);
            let (vid, stretch) = out.interference[0];
            assert_eq!(vid, 0);
            assert!(
                stretch > 0.0 && stretch < dt,
                "interference in (0, dt): {stretch} vs {dt}"
            );
            if i < 2 {
                assert!(out.events.iter().all(|e| e.seq != 1), "owner still prefilling");
                assert_eq!(out.chunk_owner, None);
            } else {
                assert!(out.events.iter().any(|e| e.seq == 1 && e.index == 0));
                assert_eq!(out.chunk_owner, Some((1, 3)));
            }
        }
        // Both sequences now decode together.
        let d = s.step().unwrap();
        assert_eq!((d.kind, d.batch), (StepKind::Decode, 2));
        assert!(d.interference.is_empty(), "pure decode interferes with nothing");
        while !s.is_idle() {
            s.step().unwrap();
        }
    }

    #[test]
    fn one_shot_prefill_stamps_the_stall_on_decoding_victims() {
        // Without chunking, a prefill landing mid-decode stalls the
        // running batch for its whole duration — that stall is now
        // priced interference (what disaggregation removes).
        let mut engine = structural_engine(2, 1);
        let mut s = engine.session();
        s.admit(seq(0, 8, 8)).unwrap();
        s.step().unwrap();
        s.step().unwrap();
        s.admit(seq(1, 16, 2)).unwrap();
        let out = s.step().unwrap();
        assert_eq!(out.kind, StepKind::Prefill);
        let dt = out.model_latency_s.unwrap();
        assert_eq!(out.interference, vec![(0, dt)], "victim stalled the full prefill");
        while !s.is_idle() {
            s.step().unwrap();
        }
    }

    #[test]
    fn cancel_and_duplicate_guards_cover_an_in_progress_chunk() {
        let mut engine = chunked_engine(1, 1, 16);
        let mut s = engine.session();
        s.admit(seq(5, 48, 4)).unwrap();
        let out = s.step().unwrap();
        assert!(out.events.is_empty(), "first of 3 chunks");
        assert_eq!(s.live(), 1, "mid-chunk owner is live");
        assert!(s.admit(seq(5, 8, 1)).is_err(), "duplicate of the chunking owner");
        assert!(s.cancel(5), "cancel drops the in-progress chunk");
        assert!(s.is_idle());
        assert_eq!(s.step().unwrap().kind, StepKind::Idle);
    }

    #[test]
    fn batched_argmax_deinterleaves_rank_major_blocks() {
        // tp=2, batch=2, v_local=3: rank-major blocks of [B, v/t].
        // Sequence 0 rows: rank0 [0,1,9], rank1 [2,0,0] -> argmax id 2 (9.0).
        // Sequence 1 rows: rank0 [5,0,0], rank1 [0,0,7] -> argmax id 5 (7.0).
        let flat = vec![
            0.0, 1.0, 9.0, // r0, row0
            5.0, 0.0, 0.0, // r0, row1
            2.0, 0.0, 0.0, // r1, row0
            0.0, 0.0, 7.0, // r1, row1
        ];
        assert_eq!(batched_argmax(&flat, 2, 2), vec![2, 5]);
        // B=1 matches plain argmax over the concatenated vector.
        let single = vec![0.5, 3.0, 1.0, 3.0];
        assert_eq!(batched_argmax(&single, 2, 1), vec![argmax(&single)]);
        // All-equal logits (structural zeros) pick token 0, like argmax.
        assert_eq!(batched_argmax(&[0.0; 8], 2, 2), vec![0, 0]);
    }
}
