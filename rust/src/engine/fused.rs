//! Fused single-worker fast path (L2 §Perf optimization).
//!
//! For the degenerate layout (t=1, p=1) the segment loop costs 2L+2
//! executable dispatches per step plus host↔device hops between them. The
//! AOT build also emits whole-model graphs (`full_{prefill,decode}_t1`)
//! where XLA fuses across layer boundaries; [`FusedEngine`] runs those —
//! one dispatch per step — and is the numeric oracle the segment engine is
//! compared against (identical tokens) and the perf baseline in
//! `benches/engine_micro.rs`.

use std::time::{Duration, Instant};

use crate::runtime::tensor::{argmax, HostTensor};
use crate::runtime::{
    compile_hlo, execute_b_tuple, i32_to_device, to_device, ArtifactStore, Phase, ShardWeights,
};
use crate::Result;

/// Whole-model single-device engine over the fused AOT graphs.
pub struct FusedEngine {
    store: ArtifactStore,
    client: xla::PjRtClient,
    prefill: xla::PjRtLoadedExecutable,
    decode: xla::PjRtLoadedExecutable,
    /// embed, final_norm, lm_head, then 9 tensors per layer (canonical
    /// full_step_flat order).
    weights: Vec<xla::PjRtBuffer>,
    kv_shape: [usize; 4],
    k_cache: xla::PjRtBuffer,
    v_cache: xla::PjRtBuffer,
}

impl FusedEngine {
    pub fn new(store: ArtifactStore) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu: {e}"))?;
        let prefill = compile_hlo(&client, &store.full_path(Phase::Prefill))?;
        let decode = compile_hlo(&client, &store.full_path(Phase::Decode))?;
        let w = ShardWeights::load(&store, 1, 0)?;
        let mut names = vec!["embed".to_string(), "final_norm".into(), "lm_head".into()];
        for l in 0..store.meta.layers {
            for n in [
                "attn_norm", "wq", "wk", "wv", "wo", "mlp_norm", "w_gate", "w_up", "w_down",
            ] {
                names.push(format!("layer{l}.{n}"));
            }
        }
        let weights = names
            .iter()
            .map(|n| to_device(&client, w.get(n)?))
            .collect::<Result<Vec<_>>>()?;
        let m = &store.meta;
        let kv_shape = [m.layers, m.max_seq, m.heads, m.head_dim];
        let zeros = HostTensor::zeros(&kv_shape);
        let k_cache = to_device(&client, &zeros)?;
        let v_cache = to_device(&client, &zeros)?;
        Ok(Self { store, client, prefill, decode, weights, kv_shape, k_cache, v_cache })
    }

    fn reset(&mut self) -> Result<()> {
        let zeros = HostTensor::zeros(&self.kv_shape);
        self.k_cache = to_device(&self.client, &zeros)?;
        self.v_cache = to_device(&self.client, &zeros)?;
        Ok(())
    }

    /// One forward step; returns the gathered logits.
    fn step(&mut self, tokens: &[i32], pos: usize) -> Result<Vec<f32>> {
        let exe = if tokens.len() == 1 { &self.decode } else { &self.prefill };
        let toks = i32_to_device(&self.client, tokens)?;
        let pos_buf = i32_to_device(&self.client, &[pos as i32])?;
        let mut inputs: Vec<&xla::PjRtBuffer> =
            vec![&toks, &pos_buf, &self.k_cache, &self.v_cache];
        inputs.extend(self.weights.iter());
        let mut out = execute_b_tuple(exe, &inputs)?;
        // (logits, k', v')
        let v_new = out.pop().expect("v cache");
        let k_new = out.pop().expect("k cache");
        let logits_lit = out.pop().expect("logits");
        let logits = logits_lit
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("logits to_vec: {e}"))?;
        let k_host = HostTensor::from_literal(&k_new, &self.kv_shape)?;
        let v_host = HostTensor::from_literal(&v_new, &self.kv_shape)?;
        self.k_cache = to_device(&self.client, &k_host)?;
        self.v_cache = to_device(&self.client, &v_host)?;
        Ok(logits)
    }

    /// Greedy generation with the same semantics as `Engine::generate`.
    pub fn generate(
        &mut self,
        prompt: &[i32],
        decode_len: usize,
    ) -> Result<super::GenerationResult> {
        assert!(decode_len >= 1);
        if prompt.len() != self.store.meta.prefill_len {
            anyhow::bail!(
                "fused engine serves fixed prompts of {} tokens",
                self.store.meta.prefill_len
            );
        }
        self.reset()?;
        let start = Instant::now();
        let logits = self.step(prompt, 0)?;
        let mut tokens = vec![argmax(&logits) as i32];
        let ttft = start.elapsed();
        let mut step_latencies = Vec::with_capacity(decode_len - 1);
        for i in 1..decode_len {
            let t0 = Instant::now();
            let pos = prompt.len() + i - 1;
            let logits = self.step(&[tokens[i - 1]], pos)?;
            tokens.push(argmax(&logits) as i32);
            step_latencies.push(t0.elapsed());
        }
        let e2e = start.elapsed();
        let tpot = if step_latencies.is_empty() {
            Duration::ZERO
        } else {
            step_latencies.iter().sum::<Duration>() / step_latencies.len() as u32
        };
        Ok(super::GenerationResult { tokens, ttft, tpot, e2e, step_latencies })
    }
}
