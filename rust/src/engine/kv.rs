//! Paged KV-cache block manager (vLLM-style, PagedAttention [23]).
//!
//! The paper's serving substrate manages KV memory in fixed-size token
//! blocks; the scheduler admits requests only when blocks are available and
//! may preempt when decode growth exhausts the pool. This manager is the
//! admission-control substrate for [`crate::server::scheduler`]; the tiny
//! numeric model keeps its KV dense inside PJRT literals (DESIGN.md §5).

use std::collections::HashMap;

use crate::Result;

/// Identifier of one sequence (request) holding cache blocks.
pub type SeqId = u64;

/// Paged allocator over a fixed pool of KV blocks.
#[derive(Debug)]
pub struct KvBlockManager {
    block_size: usize,
    free: Vec<usize>,
    allocated: HashMap<SeqId, SeqAlloc>,
    total_blocks: usize,
}

#[derive(Debug, Clone)]
struct SeqAlloc {
    blocks: Vec<usize>,
    tokens: usize,
}

impl KvBlockManager {
    /// Pool of `total_blocks` blocks of `block_size` tokens each.
    pub fn new(total_blocks: usize, block_size: usize) -> Self {
        assert!(block_size >= 1 && total_blocks >= 1);
        Self {
            block_size,
            free: (0..total_blocks).rev().collect(),
            allocated: HashMap::new(),
            total_blocks,
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn total_blocks(&self) -> usize {
        self.total_blocks
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn used_blocks(&self) -> usize {
        self.total_blocks - self.free.len()
    }

    fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    /// Whether a new sequence of `tokens` prompt tokens can be admitted.
    pub fn can_allocate(&self, tokens: usize) -> bool {
        self.blocks_for(tokens.max(1)) <= self.free.len()
    }

    /// Admit a sequence with its prompt tokens.
    pub fn allocate(&mut self, seq: SeqId, tokens: usize) -> Result<()> {
        if self.allocated.contains_key(&seq) {
            anyhow::bail!("seq {seq} already allocated");
        }
        let need = self.blocks_for(tokens.max(1));
        if need > self.free.len() {
            anyhow::bail!("out of KV blocks: need {need}, have {}", self.free.len());
        }
        let blocks = self.free.split_off(self.free.len() - need);
        self.allocated.insert(seq, SeqAlloc { blocks, tokens: tokens.max(1) });
        Ok(())
    }

    /// Record one generated token; allocates a new block on crossing a
    /// block boundary. Returns true if a block was consumed. A failed
    /// append (pool exhausted) leaves the sequence's footprint untouched,
    /// so `used_blocks == Σ ceil(tokens/block_size)` holds across bail-out
    /// and retry paths.
    pub fn append_token(&mut self, seq: SeqId) -> Result<bool> {
        let alloc = self
            .allocated
            .get_mut(&seq)
            .ok_or_else(|| anyhow::anyhow!("seq {seq} not allocated"))?;
        let need = (alloc.tokens + 1).div_ceil(self.block_size);
        if need > alloc.blocks.len() {
            let Some(block) = self.free.pop() else {
                anyhow::bail!("out of KV blocks appending to seq {seq}");
            };
            alloc.blocks.push(block);
            alloc.tokens += 1;
            Ok(true)
        } else {
            alloc.tokens += 1;
            Ok(false)
        }
    }

    /// Release all blocks of a finished sequence.
    pub fn release(&mut self, seq: SeqId) -> Result<()> {
        let alloc = self
            .allocated
            .remove(&seq)
            .ok_or_else(|| anyhow::anyhow!("seq {seq} not allocated"))?;
        self.free.extend(alloc.blocks);
        Ok(())
    }

    /// Tokens currently cached for a sequence.
    pub fn seq_tokens(&self, seq: SeqId) -> Option<usize> {
        self.allocated.get(&seq).map(|a| a.tokens)
    }

    /// Number of live sequences.
    pub fn live_seqs(&self) -> usize {
        self.allocated.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocate_and_release_roundtrip() {
        let mut m = KvBlockManager::new(10, 16);
        assert!(m.can_allocate(128));
        m.allocate(1, 128).unwrap(); // 8 blocks
        assert_eq!(m.used_blocks(), 8);
        assert!(!m.can_allocate(64));
        assert!(m.can_allocate(32));
        m.release(1).unwrap();
        assert_eq!(m.free_blocks(), 10);
        assert_eq!(m.live_seqs(), 0);
    }

    #[test]
    fn append_crosses_block_boundary() {
        let mut m = KvBlockManager::new(4, 4);
        m.allocate(7, 4).unwrap(); // exactly 1 block
        assert_eq!(m.used_blocks(), 1);
        assert!(m.append_token(7).unwrap(), "5th token needs a new block");
        assert!(!m.append_token(7).unwrap());
        assert!(!m.append_token(7).unwrap());
        assert!(!m.append_token(7).unwrap());
        assert!(m.append_token(7).unwrap(), "9th token needs a third block");
        assert_eq!(m.seq_tokens(7), Some(9));
        assert_eq!(m.used_blocks(), 3);
    }

    #[test]
    fn exhaustion_errors() {
        let mut m = KvBlockManager::new(2, 4);
        m.allocate(1, 8).unwrap();
        assert!(m.allocate(2, 1).is_err());
        assert!(m.append_token(1).is_err(), "no block left for growth");
        m.release(1).unwrap();
        m.allocate(2, 1).unwrap();
    }

    #[test]
    fn failed_append_leaves_footprint_unchanged() {
        let mut m = KvBlockManager::new(2, 4);
        m.allocate(1, 7).unwrap(); // 2 blocks, 1 free slot in the second
        m.allocate(2, 0).unwrap_err(); // pool full
        assert!(m.append_token(1).is_ok(), "8th token fits the last block");
        assert!(m.append_token(1).is_err(), "9th token needs a block the pool lacks");
        assert_eq!(m.seq_tokens(1), Some(8), "failed append must not count the token");
        assert_eq!(m.used_blocks(), 2);
        // After the peer workload shrinks, the same append succeeds and
        // accounting picks up exactly where it left off.
        let mut m2 = KvBlockManager::new(3, 4);
        m2.allocate(1, 8).unwrap();
        m2.allocate(9, 1).unwrap();
        assert!(m2.append_token(1).is_err(), "block held by seq 9");
        m2.release(9).unwrap();
        assert!(m2.append_token(1).unwrap(), "retry allocates the freed block");
        assert_eq!(m2.seq_tokens(1), Some(9));
    }

    #[test]
    fn double_allocate_and_unknown_seq_rejected() {
        let mut m = KvBlockManager::new(4, 4);
        m.allocate(1, 4).unwrap();
        assert!(m.allocate(1, 4).is_err());
        assert!(m.release(99).is_err());
        assert!(m.append_token(99).is_err());
    }

    #[test]
    fn zero_token_prompt_takes_one_block() {
        let mut m = KvBlockManager::new(4, 4);
        m.allocate(1, 0).unwrap();
        assert_eq!(m.used_blocks(), 1);
        assert_eq!(m.seq_tokens(1), Some(1));
    }
}
